//! Shadow promotion end-to-end: SIMD becomes the serving default by
//! *measurement*, never by assertion.
//!
//! * a server with shadow tuning on samples live traffic, re-executes it
//!   under the SIMD candidate plan off the reply path, verifies the
//!   candidate under the `fma_relaxed` contract, and — once the margin
//!   holds over enough samples — atomically promotes it in the registry;
//! * the swap is atomic with respect to in-flight traffic: a request
//!   keeps the plan `Arc` it captured at routing time, even when the
//!   promotion lands before its reply is sent;
//! * the decision is persisted to the plan DB (`mlir-gemm-plandb-v1`,
//!   byte-stable serialization) keyed by problem + hardware fingerprint;
//! * a restarted server warm-loads the DB and serves its first
//!   weight-bound request under the promoted SIMD plan with *no*
//!   re-measurement (`sampled() == 0` stays pinned);
//! * the committed golden fixture pins the DB grammar for the Rust and
//!   Python sides alike.
//!
//! Timings are pinned via [`ShadowTimes::Fixed`] and the ISA via
//! [`IsaPref::Fixed`]`(Portable)`, so every decision here replays
//! identically on any build host — real execution and `fma_relaxed`
//! verification still happen; only the stopwatch and the probe are
//! substituted.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use mlir_gemm::coordinator::{
    FaultPlan, GemmKey, GemmRequest, PlanDb, Server, ServerConfig, ShadowConfig,
    ShadowTimes, PLANDB_FORMAT,
};
use mlir_gemm::plan::IsaPref;
use mlir_gemm::runtime::nanokernel::{verify_fma_relaxed, Isa};
use mlir_gemm::runtime::{KernelPolicy, Runtime, Tensor};
use mlir_gemm::schedule::Dtype;
use mlir_gemm::util::prng::Rng;

const MANIFEST: &str = r#"{
  "version": 1,
  "artifacts": [
    {
      "name": "big",
      "file": "big.tprog.json",
      "kind": "baseline",
      "inputs": [
        {"shape": [128, 112], "dtype": "f32"},
        {"shape": [112, 96], "dtype": "f32"},
        {"shape": [128, 96], "dtype": "f32"}
      ],
      "outputs": [{"shape": [128, 96], "dtype": "f32"}],
      "m": 128, "n": 96, "k": 112, "dtype_in": "f32", "dtype_acc": "f32"
    }
  ]
}"#;

const BIG: &str = r#"{
  "format": "mlir-gemm-tprog-v1",
  "name": "big",
  "program": {
    "type": "gemm", "m": 128, "n": 96, "k": 112,
    "dtype_in": "f32", "dtype_acc": "f32", "epilogue": "none", "fused": true
  }
}"#;

fn big_key() -> GemmKey {
    GemmKey::with_dtypes(128, 96, 112, Dtype::F32, Dtype::F32)
}

fn store_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("mlir_gemm_shadow_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.json"), MANIFEST).unwrap();
    std::fs::write(dir.join("big.tprog.json"), BIG).unwrap();
    dir
}

/// Deterministic shadow config: sample every batch, decide after
/// `min_samples`, pinned stopwatch (candidate twice as fast — clears the
/// 1.1 hysteresis), pinned portable ISA.
fn shadow_cfg(dir: &std::path::Path, min_samples: u64) -> ShadowConfig {
    ShadowConfig {
        enabled: true,
        sample_one_in: 1,
        min_samples,
        hysteresis: 1.10,
        isa: IsaPref::Fixed(Isa::Portable),
        timing: ShadowTimes::Fixed { incumbent: 1.0e-3, candidate: 0.5e-3 },
        ..ShadowConfig::default()
    }
    .with_path(dir.join("reports").join("plandb.json"))
}

fn naive_reference(key: &GemmKey, a: &[f32], b: &[f32], c: &[f32]) -> Vec<f32> {
    let mut out = c.to_vec();
    mlir_gemm::runtime::kernel::matmul(
        KernelPolicy::Naive,
        &mut out,
        a,
        b,
        key.m,
        key.n,
        key.k,
    );
    out
}

struct Operands {
    a: Vec<f32>,
    b: Vec<f32>,
    c: Vec<f32>,
}

fn operands(rng: &mut Rng, key: &GemmKey) -> Operands {
    Operands {
        a: rng.normal_matrix(key.m, key.k),
        b: rng.normal_matrix(key.k, key.n),
        c: vec![0.0f32; key.m * key.n],
    }
}

fn inline_request(key: &GemmKey, ops: &Operands) -> GemmRequest {
    GemmRequest {
        key: key.clone(),
        a: Tensor::new(vec![key.m, key.k], ops.a.clone()).unwrap(),
        b: Some(Tensor::new(vec![key.k, key.n], ops.b.clone()).unwrap()),
        c: Tensor::new(vec![key.m, key.n], ops.c.clone()).unwrap(),
        bias: None,
        use_baseline: false,
        deadline: None,
    }
}

#[test]
fn shadow_promotes_the_measured_simd_winner_and_attributes_its_work() {
    let dir = store_dir("promote");
    let rt = Arc::new(Runtime::open(&dir).unwrap());
    let server = Server::start(
        rt,
        &mlir_gemm::sim::DeviceModel::rtx3090(),
        ServerConfig {
            workers: 2,
            shadow: shadow_cfg(&dir, 2),
            ..Default::default()
        },
    );
    let key = big_key();
    let incumbent = server.registry().plan(&key).unwrap();
    assert!(
        !incumbent.isa_label().starts_with("simd"),
        "the conservative default must not be SIMD before measurement"
    );
    let sh = server.shadow().expect("shadow state must exist when enabled");
    assert_eq!(sh.isa_name(), "portable");

    let mut rng = Rng::new(0x5AD);
    // Two sampled batches reach min_samples; the candidate's pinned
    // timing wins, so the decision on batch 2 is a promotion.
    for i in 0..2 {
        let ops = operands(&mut rng, &key);
        let resp = server.call(inline_request(&key, &ops)).unwrap();
        let out = resp.output.unwrap();
        // Both requests routed before (or at) the deciding sample run
        // under the scalar incumbent: bits identical to the naive oracle.
        assert_eq!(
            out.data,
            naive_reference(&key, &ops.a, &ops.b, &ops.c),
            "pre-promotion request {i} must serve incumbent (bit-exact) output"
        );
    }
    assert_eq!(sh.sampled(), 2);
    assert_eq!(sh.promoted(), 1);
    assert_eq!(sh.rejected(), 0);
    assert_eq!(server.registry().plan_epoch(&key), 1);
    let promoted =
        server.registry().promoted_plan(&key).expect("promotion must be installed");
    assert_eq!(promoted.isa_label(), "simd:portable");
    assert_eq!(
        server.registry().serving_plan(&key).unwrap().id(),
        promoted.id(),
        "the promoted plan is what new routes serve"
    );

    // Shadow work is attributed to the candidate plan with zero requests
    // (no reply was ever served off a shadow run).
    let snap = server.metrics();
    let cand_load = snap
        .per_plan
        .get(&promoted.id())
        .expect("candidate plan visible in per-plan metrics");
    assert_eq!(cand_load.requests, 0);
    assert!(cand_load.flops > 0.0, "shadow flops are real measured work");
    assert!(snap.per_plan.get(&incumbent.id()).unwrap().requests >= 2);

    // The next request serves under the promoted SIMD plan: correct to
    // the fma_relaxed contract, counted against the candidate plan id.
    let ops = operands(&mut rng, &key);
    let resp = server.call(inline_request(&key, &ops)).unwrap();
    let out = resp.output.unwrap();
    let want = naive_reference(&key, &ops.a, &ops.b, &ops.c);
    verify_fma_relaxed(
        &out.data,
        &want,
        &ops.a,
        &ops.b,
        &ops.c,
        None,
        key.m,
        key.n,
        key.k,
    )
    .expect("post-promotion output must verify under fma_relaxed");
    assert_eq!(sh.sampled(), 2, "a decided key is never re-sampled");
    assert_eq!(server.metrics().per_plan.get(&promoted.id()).unwrap().requests, 1);
}

#[test]
fn promotion_swaps_atomically_under_in_flight_traffic() {
    let dir = store_dir("atomic");
    let rt = Arc::new(Runtime::open(&dir).unwrap());
    // min_samples = 1: the very first sampled batch promotes — *before*
    // that batch's own replies are sent (the hook runs ahead of the
    // reply loop).  Routing delays are injected on every request to
    // widen the route -> execute window the swap races against.
    let server = Server::start(
        rt,
        &mlir_gemm::sim::DeviceModel::rtx3090(),
        ServerConfig {
            workers: 2,
            shadow: shadow_cfg(&dir, 1),
            faults: FaultPlan {
                delay_route_one_in: 1,
                delay_route: Duration::from_millis(20),
                ..Default::default()
            },
            ..Default::default()
        },
    );
    let key = big_key();
    let mut rng = Rng::new(0xA70);

    // R1 routes under the incumbent; the promotion lands mid-flight,
    // after R1's routing and before its reply.  R1 must still execute
    // under its routing-time plan: bits identical to the naive oracle.
    let ops1 = operands(&mut rng, &key);
    let r1 = server.call(inline_request(&key, &ops1)).unwrap();
    assert_eq!(
        r1.output.unwrap().data,
        naive_reference(&key, &ops1.a, &ops1.b, &ops1.c),
        "in-flight request must keep the plan captured at routing time"
    );
    let sh = server.shadow().unwrap();
    assert_eq!(sh.promoted(), 1, "first sampled batch decides at min_samples=1");
    assert_eq!(server.registry().plan_epoch(&key), 1);

    // R2 routes after the swap: served under the promoted SIMD plan.
    let ops2 = operands(&mut rng, &key);
    let r2 = server.call(inline_request(&key, &ops2)).unwrap();
    let out = r2.output.unwrap();
    let want = naive_reference(&key, &ops2.a, &ops2.b, &ops2.c);
    verify_fma_relaxed(
        &out.data, &want, &ops2.a, &ops2.b, &ops2.c, None, key.m, key.n, key.k,
    )
    .unwrap();
    let promoted = server.registry().promoted_plan(&key).unwrap();
    assert!(
        server.metrics().per_plan.get(&promoted.id()).unwrap().requests >= 1,
        "post-swap traffic is attributed to the promoted plan"
    );
    assert!(
        server.faults().injected_delays() >= 2,
        "the routing-delay schedule must actually have fired"
    );
}

#[test]
fn plan_db_persists_the_decision_and_round_trips_byte_stable() {
    let dir = store_dir("persist");
    let db_path = dir.join("reports").join("plandb.json");
    let rt = Arc::new(Runtime::open(&dir).unwrap());
    let server = Server::start(
        rt,
        &mlir_gemm::sim::DeviceModel::rtx3090(),
        ServerConfig {
            workers: 3,
            shadow: shadow_cfg(&dir, 1),
            ..Default::default()
        },
    );
    let key = big_key();
    let incumbent = server.registry().plan(&key).unwrap();
    let mut rng = Rng::new(0xDB);
    let ops = operands(&mut rng, &key);
    server.call(inline_request(&key, &ops)).unwrap().output.unwrap();
    assert_eq!(server.shadow().unwrap().promoted(), 1);

    // Persisted at promotion time, not shutdown: a crash after the
    // decision loses nothing.
    let text = std::fs::read_to_string(&db_path).expect("plan db written on promotion");
    let db = PlanDb::from_text(&text).unwrap();
    assert_eq!(db.len(), 1);
    let rec = db.records().next().unwrap();
    assert_eq!(rec.key, key);
    // Hardware fingerprint: pool width (max(workers, devices) = 3) and
    // the pinned portable ISA.
    assert_eq!(rec.threads, 3);
    assert_eq!(rec.isa, "portable");
    assert_eq!(rec.db_key(), "128x96x112/f32->f32+none@t3/portable");
    assert_eq!(rec.incumbent_id, incumbent.id());
    assert_eq!(rec.samples, 1);
    assert!(
        rec.candidate_gflops > rec.incumbent_gflops,
        "the persisted measurement must show the winning margin"
    );
    assert_eq!(rec.plan.isa_label(), "simd:portable");

    // Byte stability: the on-disk text IS the canonical serialization,
    // and save -> load -> save is a fixed point.
    assert_eq!(text, db.to_text());
    assert_eq!(db.to_text(), PlanDb::from_text(&db.to_text()).unwrap().to_text());
}

#[test]
fn golden_plandb_fixture_pins_the_format_for_both_mirrors() {
    let text = include_str!("golden/plandb_v1.json");
    let db = PlanDb::from_text(text).expect("committed golden DB must parse");
    assert_eq!(db.len(), 1);
    let rec = db.records().next().unwrap();
    assert_eq!(rec.db_key(), "128x96x112/f32->f32+none@t3/portable");
    assert_eq!(rec.key, big_key());
    assert_eq!(rec.plan.kernel.name(), "simd:portable:64,256,256,3");
    assert_eq!(rec.plan.isa_label(), "simd:portable");
    assert!(rec.plan.prepack);
    // Canonical round trip of the fixture's content.
    let canon = db.to_text();
    assert!(canon.contains(PLANDB_FORMAT));
    assert_eq!(canon, PlanDb::from_text(&canon).unwrap().to_text());

    // Grammar drift is a loud error, not a silent re-key: corrupt the
    // stored key and the whole DB refuses to load.
    let bad = text.replace("@t3/portable", "@t4/portable");
    let err = PlanDb::from_text(&bad).unwrap_err();
    assert!(format!("{err:#}").contains("does not match"));
}

#[test]
fn warm_restart_serves_weight_bound_traffic_on_the_promoted_plan_without_remeasuring() {
    let dir = store_dir("warm");
    let key = big_key();
    let mut rng = Rng::new(0x11A8);
    let weights = rng.normal_matrix(key.k, key.n);
    let cfg = || ServerConfig {
        workers: 2,
        shadow: shadow_cfg(&dir, 1),
        ..Default::default()
    };

    // First life: traffic measures, promotes, persists.
    {
        let rt = Arc::new(Runtime::open(&dir).unwrap());
        let mut server =
            Server::start(rt, &mlir_gemm::sim::DeviceModel::rtx3090(), cfg());
        let ops = operands(&mut rng, &key);
        server.call(inline_request(&key, &ops)).unwrap().output.unwrap();
        assert_eq!(server.shadow().unwrap().promoted(), 1);
        server.shutdown();
    }

    // Second life: the promoted plan is installed from the DB before any
    // traffic, and nothing is ever re-measured.
    let rt = Arc::new(Runtime::open(&dir).unwrap());
    let server = Server::start(rt, &mlir_gemm::sim::DeviceModel::rtx3090(), cfg());
    let sh = server.shadow().unwrap();
    assert_eq!(sh.warm_loaded(), 1, "the fingerprint-matching record installs");
    assert_eq!(sh.sampled(), 0, "warm load measures nothing");
    let promoted = server
        .registry()
        .promoted_plan(&key)
        .expect("promotion present before the first request routes");
    assert_eq!(promoted.isa_label(), "simd:portable");
    assert_eq!(server.registry().plan_epoch(&key), 1);

    // Weight binding follows the promoted plan (prepacked panels), and
    // the first weight-bound request serves under it.
    server
        .bind_weights(&key, &Tensor::new(vec![key.k, key.n], weights.clone()).unwrap())
        .unwrap();
    let a = rng.normal_matrix(key.m, key.k);
    let c = vec![0.0f32; key.m * key.n];
    let resp = server
        .call(GemmRequest {
            key: key.clone(),
            a: Tensor::new(vec![key.m, key.k], a.clone()).unwrap(),
            b: None,
            c: Tensor::new(vec![key.m, key.n], c.clone()).unwrap(),
            bias: None,
            use_baseline: false,
            deadline: None,
        })
        .unwrap();
    let out = resp.output.unwrap();
    let want = naive_reference(&key, &a, &weights, &c);
    verify_fma_relaxed(
        &out.data, &want, &a, &weights, &c, None, key.m, key.n, key.k,
    )
    .expect("warm-served weight-bound output verifies under fma_relaxed");
    assert!(
        server.metrics().per_plan.get(&promoted.id()).unwrap().requests >= 1,
        "the first request after restart runs on the promoted plan"
    );
    assert_eq!(sh.sampled(), 0, "still no re-measurement after serving");
}
