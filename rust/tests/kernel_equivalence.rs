//! Integration: the engine's bit-exactness contract, extended to
//! compiled execution plans.
//!
//! Every kernel policy (naive / tiled / tiled+threads, any blocking) must
//! produce bit-identical f32 output — that is what makes a compiled
//! [`ExecutionPlan`] a pure performance decision and keeps PR 2's
//! batching and row-sharding bit-exactness guarantees intact on top of
//! the engine.  These tests pin the contract at four levels: the raw
//! kernel, `Program::execute_planned` / `execute_batch_planned` under
//! explicit plans, the shard split/execute/reduce pipeline, and — the
//! plan-compiler pin — *every compiled plan* (across environments,
//! overrides, and the fused-epilogue write-back, including the
//! deliberately-unfused off-path) against the naive reference over the
//! shape sweep.  No global state anywhere: each comparison constructs
//! its plans explicitly.
//!
//! This whole suite is pinned to the `bit_exact` numerics class: no
//! environment here opts into SIMD, so every compiled plan must carry
//! `NumericsClass::BitExact` (asserted below).  The `fma_relaxed` half
//! of the contract lives in the `numerics_tolerance` harness.

use mlir_gemm::coordinator::sharding::{build_shard_tasks, reduce_outputs};
use mlir_gemm::coordinator::ShardPlan;
use mlir_gemm::plan::{compile, ExecutionPlan, GemmKey, NumericsClass, PlanEnv, PlanOverride};
use mlir_gemm::runtime::kernel::{self, Blocking, KernelPolicy};
use mlir_gemm::runtime::{Epilogue, Program, Tensor};
use mlir_gemm::schedule::Dtype;
use mlir_gemm::util::prng::Rng;
use mlir_gemm::util::proptest::{check, shrink_usizes, Config};

/// Policies that exercise every code path: reference, blocked with ragged
/// cache blocks, defaults, and threading with non-divisible band counts.
fn policies() -> Vec<KernelPolicy> {
    vec![
        KernelPolicy::Tiled(Blocking { mc: 8, kc: 4, nc: 16 }),
        KernelPolicy::Tiled(Blocking { mc: 7, kc: 5, nc: 11 }),
        KernelPolicy::Tiled(Blocking::default()),
        KernelPolicy::Threaded(Blocking { mc: 8, kc: 8, nc: 16 }, 2),
        KernelPolicy::Threaded(Blocking::default(), 3),
    ]
}

/// Plan environments that exercise every compiler decision: pinned auto
/// (packing threshold + thread pass), a pooled executor (single-band
/// plans), a huge L2 (everything lowers to the direct kernel), and
/// forced overrides.
fn plan_envs() -> Vec<PlanEnv> {
    let mut envs = vec![
        PlanEnv::pinned(),
        PlanEnv::for_pool(8),
        PlanEnv { l2_bytes: 1 << 30, ..PlanEnv::pinned() },
    ];
    for policy in policies() {
        envs.push(PlanEnv::pinned().with_force(PlanOverride::Force(policy)));
    }
    envs
}

fn assert_bits_eq(want: &[f32], got: &[f32], what: &str) {
    assert_eq!(want.len(), got.len(), "{what}: length");
    for (idx, (w, g)) in want.iter().zip(got).enumerate() {
        assert_eq!(
            w.to_bits(),
            g.to_bits(),
            "{what}: element {idx} drifted ({w} vs {g})"
        );
    }
}

#[test]
fn raw_kernel_bit_identical_on_large_odd_shapes() {
    for &(m, n, k) in &[
        (129usize, 65usize, 77usize), // nothing divides MR/NR/KC
        (200, 1, 300),                // skinny n=1
        (1, 257, 19),                 // skinny m=1
        (61, 61, 61),
        (96, 128, 64),                // everything aligned
    ] {
        let mut rng = Rng::new((m * 31 + n * 7 + k) as u64);
        let a = rng.normal_matrix(m, k);
        let b = rng.normal_matrix(k, n);
        let c = rng.normal_matrix(m, n);
        let mut want = c.clone();
        kernel::matmul(KernelPolicy::Naive, &mut want, &a, &b, m, n, k);
        for policy in policies() {
            let mut got = c.clone();
            kernel::matmul(policy, &mut got, &a, &b, m, n, k);
            assert_bits_eq(&want, &got, &format!("{}x{}x{} {}", m, n, k, policy.name()));
        }
    }
}

#[test]
fn raw_kernel_bit_identical_property_over_random_shapes() {
    check(
        Config { cases: 24, seed: 0x6E44, ..Default::default() },
        |rng| vec![1 + rng.below(96), 1 + rng.below(96), 1 + rng.below(96)],
        |v| shrink_usizes(v, 1),
        |dims| {
            let (m, n, k) = (dims[0], dims[1], dims[2]);
            let mut rng = Rng::new((m * 131 + n * 17 + k) as u64);
            let a = rng.normal_matrix(m, k);
            let b = rng.normal_matrix(k, n);
            let c = rng.normal_matrix(m, n);
            let mut want = c.clone();
            kernel::matmul(KernelPolicy::Naive, &mut want, &a, &b, m, n, k);
            for policy in policies() {
                let mut got = c.clone();
                kernel::matmul(policy, &mut got, &a, &b, m, n, k);
                for (idx, (w, g)) in want.iter().zip(&got).enumerate() {
                    if w.to_bits() != g.to_bits() {
                        return Err(format!(
                            "{} drifted at {m}x{n}x{k} element {idx}: {w} vs {g}",
                            policy.name()
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

fn gemm_program(m: usize, n: usize, k: usize, din: Dtype, dacc: Dtype) -> Program {
    Program::Gemm {
        m,
        n,
        k,
        dtype_in: din,
        dtype_acc: dacc,
        epilogue: Epilogue::BiasRelu,
        fused: true,
    }
}

fn gemm_inputs(m: usize, n: usize, k: usize, seed: u64) -> Vec<Tensor> {
    let mut rng = Rng::new(seed);
    vec![
        Tensor { shape: vec![m, k], data: rng.normal_matrix(m, k) },
        Tensor { shape: vec![k, n], data: rng.normal_matrix(k, n) },
        Tensor { shape: vec![m, n], data: rng.normal_matrix(m, n) },
        Tensor { shape: vec![n], data: rng.normal_matrix(1, n) },
    ]
}

/// Manual plan for a program's key with the given kernel + fusion.
fn manual_plan(p: &Program, kernel: KernelPolicy, fuse: bool) -> ExecutionPlan {
    ExecutionPlan::manual(&p.gemm_key().unwrap(), kernel, fuse).unwrap()
}

/// `Program::execute_planned` under each explicit plan: the full
/// precision pipeline (dtype casts, epilogue, rounding tail) on top of
/// the engine must stay bit-identical — plans change speed, never bits.
/// Both fusion modes run for every kernel: the fused write-back and the
/// separate-pass epilogue must agree exactly.
#[test]
fn program_execute_bit_identical_across_plans() {
    let (m, n, k) = (37, 29, 41);
    for &(din, dacc) in &[
        (Dtype::F32, Dtype::F32),
        (Dtype::F16, Dtype::F32),
        (Dtype::F16, Dtype::F16),
        (Dtype::Bf16, Dtype::F32),
    ] {
        let p = gemm_program(m, n, k, din, dacc);
        let inputs = gemm_inputs(m, n, k, 0xAB + din as u64);
        let naive = manual_plan(&p, KernelPolicy::Naive, false);
        let want = p.execute_planned(&inputs, &naive).unwrap();
        for policy in policies() {
            for fuse in [false, true] {
                let eplan = manual_plan(&p, policy, fuse);
                let got = p.execute_planned(&inputs, &eplan).unwrap();
                assert_bits_eq(
                    &want[0].data,
                    &got[0].data,
                    &format!("{din:?}/{dacc:?} via {} fuse={fuse}", policy.name()),
                );
            }
        }
    }
}

/// The batched path (stacked operands, one cast) over the engine remains
/// bit-identical to per-item execution under an explicit tiled plan,
/// fused and unfused.
#[test]
fn execute_batch_bit_identical_under_explicit_plan() {
    let (m, n, k) = (21, 18, 27);
    let p = gemm_program(m, n, k, Dtype::F16, Dtype::F32);
    let items: Vec<Vec<Tensor>> =
        (0..4).map(|i| gemm_inputs(m, n, k, 900 + i)).collect();
    for fuse in [false, true] {
        let eplan = manual_plan(
            &p,
            KernelPolicy::Tiled(Blocking { mc: 8, kc: 8, nc: 16 }),
            fuse,
        );
        let batched = p.execute_batch_planned(&items, &eplan).unwrap();
        for (bi, inputs) in items.iter().enumerate() {
            let single = p.execute_planned(inputs, &eplan).unwrap();
            assert_bits_eq(
                &single[0].data,
                &batched[bi][0].data,
                &format!("batch item {bi} fuse={fuse}"),
            );
        }
    }
}

/// A plan for the wrong GEMM contract is an explicit error on every
/// planned path — the cross-contamination guard.
#[test]
fn mismatched_plan_is_rejected() {
    let p = gemm_program(8, 8, 8, Dtype::F16, Dtype::F32);
    let other = gemm_program(8, 8, 9, Dtype::F16, Dtype::F32);
    let wrong = manual_plan(&other, KernelPolicy::Naive, false);
    let inputs = gemm_inputs(8, 8, 8, 3);
    assert!(p.execute_planned(&inputs, &wrong).is_err());
    let items = vec![gemm_inputs(8, 8, 8, 4), gemm_inputs(8, 8, 8, 5)];
    assert!(p.execute_batch_planned(&items, &wrong).is_err());
}

/// Row sharding on top of the engine: split/execute/reduce must still
/// concatenate to exactly the unsharded result whatever environment
/// compiled the shard plans.
#[test]
fn row_sharding_bit_identical_on_compiled_plans() {
    let (m, n, k) = (45, 22, 33);
    let base = Program::Gemm {
        m,
        n,
        k,
        dtype_in: Dtype::F16,
        dtype_acc: Dtype::F32,
        epilogue: Epilogue::None,
        fused: true,
    };
    let mut rng = Rng::new(77);
    let a = Tensor { shape: vec![m, k], data: rng.normal_matrix(m, k) };
    let b = Tensor { shape: vec![k, n], data: rng.normal_matrix(k, n) };
    let c = Tensor { shape: vec![m, n], data: rng.normal_matrix(m, n) };
    let naive = manual_plan(&base, KernelPolicy::Naive, false);
    let want = base.execute_planned(&[a.clone(), b.clone(), c.clone()], &naive).unwrap();
    for env in plan_envs() {
        let plan = ShardPlan::rows(m, n, k, 3, 1);
        let parts: Vec<Tensor> = build_shard_tasks(&env, &plan, &base, &a, &b, &c, None)
            .unwrap()
            .into_iter()
            .map(|(prog, eplan, inputs)| {
                prog.execute_planned(&inputs, &eplan).unwrap().remove(0)
            })
            .collect();
        let got = reduce_outputs(&plan, &base, &c, None, &parts).unwrap();
        assert_bits_eq(
            &want[0].data,
            &got.data,
            &format!("sharded under env force={}", env.force.name()),
        );
    }
}

/// The plan-compiler pin: every *compiled* plan — across environments
/// that hit each pass decision and every forced override — executes
/// bit-identically to the naive reference, for the plain, fused-epilogue,
/// and deliberately-unfused programs alike, across the shape sweep (edge
/// shapes + the random-shape property, the same 99-shape family the raw
/// kernel sweep pins).
#[test]
fn compiled_plans_bit_identical_on_edge_shapes() {
    for &(m, n, k) in &[
        (1usize, 1usize, 1usize),
        (1, 17, 5),
        (19, 1, 7),
        (5, 17, 9),
        (33, 7, 21),
        (64, 64, 64), // exactly the direct-kernel footprint region
    ] {
        assert_compiled_plans_match(m, n, k).unwrap();
    }
}

#[test]
fn compiled_plans_bit_identical_property_over_random_shapes() {
    check(
        Config { cases: 32, seed: 0x9127, ..Default::default() },
        |rng| vec![1 + rng.below(72), 1 + rng.below(72), 1 + rng.below(72)],
        |v| shrink_usizes(v, 1),
        |dims| assert_compiled_plans_match(dims[0], dims[1], dims[2]),
    );
}

fn assert_compiled_plans_match(m: usize, n: usize, k: usize) -> Result<(), String> {
    // Three program flavors: no epilogue, fused bias_relu, and the
    // deliberately-unfused comparator (epilogue after the output cast).
    let programs = [
        Program::Gemm {
            m,
            n,
            k,
            dtype_in: Dtype::F16,
            dtype_acc: Dtype::F32,
            epilogue: Epilogue::None,
            fused: true,
        },
        Program::Gemm {
            m,
            n,
            k,
            dtype_in: Dtype::F16,
            dtype_acc: Dtype::F32,
            epilogue: Epilogue::BiasRelu,
            fused: true,
        },
        Program::Gemm {
            m,
            n,
            k,
            dtype_in: Dtype::F16,
            dtype_acc: Dtype::F16,
            epilogue: Epilogue::Bias,
            fused: false,
        },
    ];
    for p in &programs {
        let Program::Gemm { epilogue, .. } = p else { unreachable!() };
        let mut inputs = gemm_inputs(m, n, k, (m * 1009 + n * 31 + k) as u64);
        if !epilogue.needs_bias() {
            inputs.truncate(3);
        }
        let naive = ExecutionPlan::manual(&p.gemm_key().unwrap(), KernelPolicy::Naive, false)
            .unwrap();
        let want = p.execute_planned(&inputs, &naive).unwrap();
        for env in plan_envs() {
            let eplan = compile(&p.gemm_key().unwrap(), &env).unwrap();
            // None of these environments opts into SIMD, so the class
            // must be bit_exact — that is what licenses the bitwise
            // comparison below.
            if eplan.numerics != NumericsClass::BitExact {
                return Err(format!(
                    "plan {} (env force={}) compiled {} without a SIMD opt-in",
                    eplan.id(),
                    env.force.name(),
                    eplan.numerics.name(),
                ));
            }
            let got = p.execute_planned(&inputs, &eplan).unwrap();
            for (idx, (w, g)) in want[0].data.iter().zip(&got[0].data).enumerate() {
                if w.to_bits() != g.to_bits() {
                    return Err(format!(
                        "plan {} (env force={}) drifted at {m}x{n}x{k} \
                         epilogue={} element {idx}: {w} vs {g}",
                        eplan.id(),
                        env.force.name(),
                        epilogue.name(),
                    ));
                }
            }
        }
    }
    Ok(())
}

/// Compiled plans honored through `GemmKey`s too: the same key always
/// compiles to the same plan under the same environment (determinism is
/// what lets the registry cache them).
#[test]
fn compilation_is_deterministic() {
    for key in [
        GemmKey::plain(512, 512, 512),
        GemmKey::plain(64, 64, 64),
        GemmKey::with_dtypes(300, 200, 100, Dtype::F32, Dtype::F32),
    ] {
        let a = compile(&key, &PlanEnv::pinned()).unwrap();
        let b = compile(&key, &PlanEnv::pinned()).unwrap();
        assert_eq!(a, b, "non-deterministic compilation for {key:?}");
        assert_eq!(a.numerics, NumericsClass::BitExact, "default compile for {key:?}");
    }
}
