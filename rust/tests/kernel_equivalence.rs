//! Integration: the micro-kernel engine's bit-exactness contract.
//!
//! Every kernel policy (naive / tiled / tiled+threads, any blocking) must
//! produce bit-identical f32 output — that is what makes `--kernel` a
//! pure performance knob and keeps PR 2's batching and row-sharding
//! bit-exactness guarantees intact on top of the new engine.  These tests
//! pin the contract at three levels: the raw kernel, `Program::execute` /
//! `execute_batch`, and the shard split/execute/reduce pipeline.

use mlir_gemm::coordinator::sharding::{build_shard_tasks, reduce_outputs};
use mlir_gemm::coordinator::ShardPlan;
use mlir_gemm::runtime::kernel::{self, Blocking, KernelPolicy};
use mlir_gemm::runtime::{Epilogue, Program, Tensor};
use mlir_gemm::schedule::Dtype;
use mlir_gemm::util::prng::Rng;
use mlir_gemm::util::proptest::{check, shrink_usizes, Config};

/// Policies that exercise every code path: reference, blocked with ragged
/// cache blocks, defaults, and threading with non-divisible band counts.
fn policies() -> Vec<KernelPolicy> {
    vec![
        KernelPolicy::Tiled(Blocking { mc: 8, kc: 4, nc: 16 }),
        KernelPolicy::Tiled(Blocking { mc: 7, kc: 5, nc: 11 }),
        KernelPolicy::Tiled(Blocking::default()),
        KernelPolicy::Threaded(Blocking { mc: 8, kc: 8, nc: 16 }, 2),
        KernelPolicy::Threaded(Blocking::default(), 3),
    ]
}

fn assert_bits_eq(want: &[f32], got: &[f32], what: &str) {
    assert_eq!(want.len(), got.len(), "{what}: length");
    for (idx, (w, g)) in want.iter().zip(got).enumerate() {
        assert_eq!(
            w.to_bits(),
            g.to_bits(),
            "{what}: element {idx} drifted ({w} vs {g})"
        );
    }
}

#[test]
fn raw_kernel_bit_identical_on_large_odd_shapes() {
    for &(m, n, k) in &[
        (129usize, 65usize, 77usize), // nothing divides MR/NR/KC
        (200, 1, 300),                // skinny n=1
        (1, 257, 19),                 // skinny m=1
        (61, 61, 61),
        (96, 128, 64),                // everything aligned
    ] {
        let mut rng = Rng::new((m * 31 + n * 7 + k) as u64);
        let a = rng.normal_matrix(m, k);
        let b = rng.normal_matrix(k, n);
        let c = rng.normal_matrix(m, n);
        let mut want = c.clone();
        kernel::matmul(KernelPolicy::Naive, &mut want, &a, &b, m, n, k);
        for policy in policies() {
            let mut got = c.clone();
            kernel::matmul(policy, &mut got, &a, &b, m, n, k);
            assert_bits_eq(&want, &got, &format!("{}x{}x{} {}", m, n, k, policy.name()));
        }
    }
}

#[test]
fn raw_kernel_bit_identical_property_over_random_shapes() {
    check(
        Config { cases: 24, seed: 0x6E44, ..Default::default() },
        |rng| vec![1 + rng.below(96), 1 + rng.below(96), 1 + rng.below(96)],
        |v| shrink_usizes(v, 1),
        |dims| {
            let (m, n, k) = (dims[0], dims[1], dims[2]);
            let mut rng = Rng::new((m * 131 + n * 17 + k) as u64);
            let a = rng.normal_matrix(m, k);
            let b = rng.normal_matrix(k, n);
            let c = rng.normal_matrix(m, n);
            let mut want = c.clone();
            kernel::matmul(KernelPolicy::Naive, &mut want, &a, &b, m, n, k);
            for policy in policies() {
                let mut got = c.clone();
                kernel::matmul(policy, &mut got, &a, &b, m, n, k);
                for (idx, (w, g)) in want.iter().zip(&got).enumerate() {
                    if w.to_bits() != g.to_bits() {
                        return Err(format!(
                            "{} drifted at {m}x{n}x{k} element {idx}: {w} vs {g}",
                            policy.name()
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

fn gemm_program(m: usize, n: usize, k: usize, din: Dtype, dacc: Dtype) -> Program {
    Program::Gemm {
        m,
        n,
        k,
        dtype_in: din,
        dtype_acc: dacc,
        epilogue: Epilogue::BiasRelu,
        fused: true,
    }
}

fn gemm_inputs(m: usize, n: usize, k: usize, seed: u64) -> Vec<Tensor> {
    let mut rng = Rng::new(seed);
    vec![
        Tensor { shape: vec![m, k], data: rng.normal_matrix(m, k) },
        Tensor { shape: vec![k, n], data: rng.normal_matrix(k, n) },
        Tensor { shape: vec![m, n], data: rng.normal_matrix(m, n) },
        Tensor { shape: vec![n], data: rng.normal_matrix(1, n) },
    ]
}

/// `Program::execute` under each global policy: the full precision
/// pipeline (dtype casts, epilogue, rounding tail) on top of the engine
/// must stay bit-identical — policies change speed, never bits.
#[test]
fn program_execute_bit_identical_across_global_policies() {
    // Serialize global-policy writers: `want` must really be the naive
    // reference, not another test's freshly installed policy.
    let _guard = kernel::policy_test_lock();
    let (m, n, k) = (37, 29, 41);
    for &(din, dacc) in &[
        (Dtype::F32, Dtype::F32),
        (Dtype::F16, Dtype::F32),
        (Dtype::F16, Dtype::F16),
        (Dtype::Bf16, Dtype::F32),
    ] {
        let p = gemm_program(m, n, k, din, dacc);
        let inputs = gemm_inputs(m, n, k, 0xAB + din as u64);
        let before = kernel::global_policy();
        kernel::set_global_policy(KernelPolicy::Naive);
        let want = p.execute(&inputs).unwrap();
        for policy in policies() {
            kernel::set_global_policy(policy);
            let got = p.execute(&inputs).unwrap();
            assert_bits_eq(
                &want[0].data,
                &got[0].data,
                &format!("{din:?}/{dacc:?} via {}", policy.name()),
            );
        }
        kernel::set_global_policy(before);
    }
}

/// The batched path (stacked operands, one cast) over the engine remains
/// bit-identical to per-item execution under a tiled policy.
#[test]
fn execute_batch_bit_identical_under_tiled_policy() {
    let _guard = kernel::policy_test_lock();
    let (m, n, k) = (21, 18, 27);
    let p = gemm_program(m, n, k, Dtype::F16, Dtype::F32);
    let items: Vec<Vec<Tensor>> =
        (0..4).map(|i| gemm_inputs(m, n, k, 900 + i)).collect();
    let before = kernel::global_policy();
    kernel::set_global_policy(KernelPolicy::Tiled(Blocking { mc: 8, kc: 8, nc: 16 }));
    let batched = p.execute_batch(&items).unwrap();
    for (bi, inputs) in items.iter().enumerate() {
        let single = p.execute(inputs).unwrap();
        assert_bits_eq(
            &single[0].data,
            &batched[bi][0].data,
            &format!("batch item {bi}"),
        );
    }
    kernel::set_global_policy(before);
}

/// Row sharding on top of the engine: split/execute/reduce must still
/// concatenate to exactly the unsharded result whatever policy runs the
/// shard GEMMs.
#[test]
fn row_sharding_bit_identical_on_engine_kernels() {
    let _guard = kernel::policy_test_lock();
    let (m, n, k) = (45, 22, 33);
    let base = Program::Gemm {
        m,
        n,
        k,
        dtype_in: Dtype::F16,
        dtype_acc: Dtype::F32,
        epilogue: Epilogue::None,
        fused: true,
    };
    let mut rng = Rng::new(77);
    let a = Tensor { shape: vec![m, k], data: rng.normal_matrix(m, k) };
    let b = Tensor { shape: vec![k, n], data: rng.normal_matrix(k, n) };
    let c = Tensor { shape: vec![m, n], data: rng.normal_matrix(m, n) };
    let before = kernel::global_policy();
    kernel::set_global_policy(KernelPolicy::Naive);
    let want = base.execute(&[a.clone(), b.clone(), c.clone()]).unwrap();
    for policy in policies() {
        kernel::set_global_policy(policy);
        let plan = ShardPlan::rows(m, n, k, 3, 1);
        let parts: Vec<Tensor> = build_shard_tasks(&plan, &base, &a, &b, &c, None)
            .unwrap()
            .into_iter()
            .map(|(prog, inputs)| prog.execute(&inputs).unwrap().remove(0))
            .collect();
        let got = reduce_outputs(&plan, &base, &c, None, &parts).unwrap();
        assert_bits_eq(&want[0].data, &got.data, &format!("sharded {}", policy.name()));
    }
    kernel::set_global_policy(before);
}
