//! Protocol-checker integration: the model checker's public API, end to
//! end — sound scenarios pass non-vacuously, every re-introducible bug
//! yields a counterexample, and the stop-flag counterexample replays
//! against the *real* server (buggy hook armed -> stranded jobs and
//! broken accounting; hook off -> the identical schedule drains clean).
//!
//! Configurations here stay tiny (2 clients) because plain
//! `cargo test` runs debug builds; the CLI (`mlir-gemm check-protocol`)
//! explores the full 3x2 bound in release.

use mlir_gemm::check::{
    explore, replay_shutdown_vs_submit, Action, Bugs, ModelConfig,
};

#[test]
fn sound_scenario_matrix_passes_without_vacuity() {
    let base = ModelConfig::new(2, 1);
    let cases: Vec<(&str, ModelConfig)> = vec![
        ("base", base.clone()),
        ("rebind", base.clone().with_rebind()),
        ("poison", base.clone().with_poison()),
        ("deadline", base.clone().with_deadline()),
        ("sharded", ModelConfig::new(2, 2).with_sharding()),
        ("overflow", base.clone().with_capacity(1)),
        // Continuous-batching admission scenarios (PR 10): priority
        // tiers with a forced pick, per-tenant quota exhaustion, and a
        // deadline lapsing inside the scheduler racing the release.
        ("priority", base.clone().with_priority().with_max_batch(1)),
        ("quota", ModelConfig::new(3, 1).with_quota(1)),
        ("late-deadline", base.with_late_deadline()),
    ];
    for (name, cfg) in cases {
        let r = explore(&cfg, 500_000)
            .unwrap_or_else(|e| panic!("{name}: exploration failed: {e}"));
        assert!(r.passed(), "{name}: {:?}", r.violation);
        assert!(r.terminals > 0, "{name}: no terminal states");
        let c = r.coverage;
        match name {
            "base" => assert!(
                c.multi_job_batch && c.shutdown_with_backlog && c.late_submit_error,
                "{name} vacuous: {c:?}"
            ),
            "rebind" => assert!(c.rebind_raced_dispatch, "{name} vacuous: {c:?}"),
            "poison" => assert!(c.poisoned_job, "{name} vacuous: {c:?}"),
            "deadline" => assert!(c.expired_job, "{name} vacuous: {c:?}"),
            "sharded" => assert!(c.shard_reduction, "{name} vacuous: {c:?}"),
            "overflow" => assert!(c.queue_full_rejection, "{name} vacuous: {c:?}"),
            "priority" => assert!(c.priority_release, "{name} vacuous: {c:?}"),
            "quota" => assert!(c.tenant_quota_rejection, "{name} vacuous: {c:?}"),
            "late-deadline" => {
                assert!(c.swept_in_scheduler, "{name} vacuous: {c:?}")
            }
            _ => unreachable!(),
        }
    }
}

#[test]
fn every_reintroduced_bug_is_caught_with_a_named_counterexample() {
    let cases: Vec<(Bugs, ModelConfig, &str)> = vec![
        (
            Bugs { stop_flag_break: true, ..Default::default() },
            ModelConfig::new(2, 1),
            "no-stranded-shutdown",
        ),
        (
            Bugs { stale_rebind: true, ..Default::default() },
            ModelConfig::new(2, 1).with_rebind(),
            "no-stale-weights",
        ),
        (
            Bugs { no_containment: true, ..Default::default() },
            ModelConfig::new(2, 1).with_poison(),
            "containment",
        ),
        (
            Bugs { fifo_release: true, ..Default::default() },
            ModelConfig::new(2, 1).with_priority().with_max_batch(1),
            "no-priority-inversion-past-deadline",
        ),
    ];
    for (bugs, cfg, want) in cases {
        let r = explore(&cfg.with_bugs(bugs), 500_000).unwrap();
        let cx = r
            .violation
            .unwrap_or_else(|| panic!("bug {bugs:?} escaped the checker"));
        assert_eq!(cx.invariant_name(), want, "{}", cx.render());
        assert!(!cx.trace.is_empty(), "counterexample must carry a schedule");
    }
}

#[test]
fn stop_flag_counterexample_replays_against_the_real_server() {
    // The model names the schedule: submit while the dispatcher is not
    // looking, shutdown, then the buggy break.
    let bugs = Bugs { stop_flag_break: true, ..Default::default() };
    let cx = explore(&ModelConfig::new(2, 1).with_bugs(bugs), 200_000)
        .unwrap()
        .violation
        .expect("model must find the stop-flag bug");
    assert!(cx.trace.contains(&Action::Shutdown));
    assert!(cx.trace.contains(&Action::StopFlagBreak));

    // Same schedule, real code, bug hook armed: every held job is
    // stranded and the accounting identity breaks.
    let buggy = replay_shutdown_vs_submit(3, true).unwrap();
    assert_eq!(buggy.lost, 3, "{buggy:?}");
    assert!(!buggy.accounting_holds(), "{buggy:?}");

    // Same schedule, shipped (fixed) code: nobody stranded, identity
    // holds, every job completed.
    let fixed = replay_shutdown_vs_submit(3, false).unwrap();
    assert_eq!(fixed.lost, 0, "{fixed:?}");
    assert_eq!(fixed.answered, 3);
    assert!(fixed.accounting_holds(), "{fixed:?}");
    assert_eq!(fixed.snapshot.completed, 3, "{fixed:?}");
}
