//! Integration: sharded multi-device execution against the unsharded
//! executor, on the same loaded artifact.
//!
//! Row sharding must be bit-identical (every output element is computed
//! by the same f32 operation sequence); split-K regroups the f32
//! reduction, so it is tolerance-bounded instead.

use std::path::PathBuf;
use std::sync::Arc;

use mlir_gemm::coordinator::{
    modeled_speedup, plan_for, ShardConfig, ShardPlan, ShardPool, ShardStrategy,
};
use mlir_gemm::runtime::{Runtime, Tensor};
use mlir_gemm::schedule::{Dtype, Schedule};
use mlir_gemm::sim::DeviceModel;
use mlir_gemm::util::prng::Rng;

const MANIFEST: &str = r#"{
  "version": 1,
  "artifacts": [
    {
      "name": "g32",
      "file": "g32.tprog.json",
      "kind": "baseline",
      "inputs": [
        {"shape": [48, 32], "dtype": "f32"},
        {"shape": [32, 40], "dtype": "f32"},
        {"shape": [48, 40], "dtype": "f32"}
      ],
      "outputs": [{"shape": [48, 40], "dtype": "f32"}],
      "m": 48, "n": 40, "k": 32, "dtype_in": "f32", "dtype_acc": "f32"
    },
    {
      "name": "g16",
      "file": "g16.tprog.json",
      "kind": "baseline",
      "inputs": [
        {"shape": [16, 64], "dtype": "f32"},
        {"shape": [64, 24], "dtype": "f32"},
        {"shape": [16, 24], "dtype": "f32"},
        {"shape": [24], "dtype": "f32"}
      ],
      "outputs": [{"shape": [16, 24], "dtype": "f32"}],
      "m": 16, "n": 24, "k": 64, "dtype_in": "f16", "dtype_acc": "f32"
    }
  ]
}"#;

const G32: &str = r#"{
  "format": "mlir-gemm-tprog-v1",
  "name": "g32",
  "program": {
    "type": "gemm", "m": 48, "n": 40, "k": 32,
    "dtype_in": "f32", "dtype_acc": "f32", "epilogue": "none", "fused": true
  }
}"#;

const G16: &str = r#"{
  "format": "mlir-gemm-tprog-v1",
  "name": "g16",
  "program": {
    "type": "gemm", "m": 16, "n": 24, "k": 64,
    "dtype_in": "f16", "dtype_acc": "f32", "epilogue": "bias_relu", "fused": true
  }
}"#;

fn artifact_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("mlir_gemm_shard_it_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.json"), MANIFEST).unwrap();
    std::fs::write(dir.join("g32.tprog.json"), G32).unwrap();
    std::fs::write(dir.join("g16.tprog.json"), G16).unwrap();
    dir
}

fn tensor(rng: &mut Rng, shape: Vec<usize>) -> Tensor {
    let n: usize = shape.iter().product();
    let data: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
    Tensor { shape, data }
}

#[test]
fn row_sharded_f32_is_bit_identical_to_unsharded_artifact() {
    let dir = artifact_dir("f32");
    let rt = Arc::new(Runtime::open(&dir).unwrap());
    let artifact = rt.load("g32").unwrap();
    let mut rng = Rng::new(31);
    let a = tensor(&mut rng, vec![48, 32]);
    let b = tensor(&mut rng, vec![32, 40]);
    let c = tensor(&mut rng, vec![48, 40]);
    let want = rt
        .execute("g32", &[a.clone(), b.clone(), c.clone()])
        .unwrap();

    let pool = ShardPool::homogeneous(&DeviceModel::rtx3090(), 4);
    let plan = ShardPlan::rows(48, 40, 32, pool.devices(), 1);
    assert_eq!(plan.shards.len(), 4);
    let got = pool
        .execute(artifact.program(), &plan, &a, &b, &c, None)
        .unwrap();
    assert_eq!(got.shape, want[0].shape);
    assert_eq!(got.data, want[0].data, "row-sharded f32 output drifted");

    let stats = pool.shutdown();
    assert_eq!(stats.iter().map(|s| s.tasks).sum::<u64>(), 4);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn split_k_sharded_f16_matches_unsharded_artifact_within_tolerance() {
    let dir = artifact_dir("f16");
    let rt = Arc::new(Runtime::open(&dir).unwrap());
    let artifact = rt.load("g16").unwrap();
    let mut rng = Rng::new(32);
    let a = tensor(&mut rng, vec![16, 64]);
    let b = tensor(&mut rng, vec![64, 24]);
    let c = tensor(&mut rng, vec![16, 24]);
    let bias = tensor(&mut rng, vec![24]);
    let want = rt
        .execute(
            "g16",
            &[a.clone(), b.clone(), c.clone(), bias.clone()],
        )
        .unwrap();

    let pool = ShardPool::homogeneous(&DeviceModel::rtx3090(), 4);
    let plan = ShardPlan::split_k(16, 24, 64, pool.devices(), 1);
    assert_eq!(plan.shards.len(), 4);
    let got = pool
        .execute(artifact.program(), &plan, &a, &b, &c, Some(&bias))
        .unwrap();
    assert_eq!(got.shape, want[0].shape);
    let mut worst = 0f64;
    for (g, w) in got.data.iter().zip(&want[0].data) {
        worst = worst.max((*g as f64 - *w as f64).abs());
    }
    // Same f16 input casts, same f32 products; only the reduction
    // grouping differs, so the drift is a few ULPs of the f32 partials.
    assert!(worst < 1e-3, "split-K drifted by {worst}");
    // bias_relu applied exactly once, in the reduction tail
    assert!(got.data.iter().all(|&v| v >= 0.0));
    pool.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn auto_planner_routes_real_artifact_programs() {
    let dir = artifact_dir("plan");
    let rt = Arc::new(Runtime::open(&dir).unwrap());
    let artifact = rt.load("g16").unwrap();
    // Tiny problems refuse to shard under the default thresholds...
    assert!(plan_for(artifact.program(), 4, &ShardConfig::default()).is_none());
    // ...but shard once the thresholds say it is worth it.
    let cfg = ShardConfig {
        strategy: ShardStrategy::Auto,
        min_rows: 4,
        min_k: 4,
        min_flops: 0.0,
    };
    let plan = plan_for(artifact.program(), 4, &cfg).expect("plan");
    assert!(plan.is_sharded());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn modeled_speedup_monotone_for_paper_shape() {
    let s = Schedule::optimized(
        8192,
        8192,
        8192,
        Dtype::F32,
        (128, 128, 64),
        (64, 32, 32),
    )
    .unwrap();
    let models: Vec<DeviceModel> = vec![DeviceModel::rtx3090(); 8];
    let mut last = 1.0;
    for devices in [2usize, 4, 8] {
        let plan = ShardPlan::rows(8192, 8192, 8192, devices, 64);
        let speedup = modeled_speedup(&s, &plan, &models);
        assert!(
            speedup > last,
            "speedup not monotone at {devices} devices: {speedup} <= {last}"
        );
        assert!(
            speedup <= devices as f64 * 1.1,
            "superlinear modeled speedup at {devices} devices: {speedup}"
        );
        last = speedup;
    }
}
