//! Integration: the GEMM service end-to-end over real artifacts, plus
//! proptest-lite invariants on the pure coordinator components.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use mlir_gemm::coordinator::{
    BatcherConfig, GemmKey, GemmRequest, Priority, Queued, Scheduler, Server,
    ServerConfig,
};
use mlir_gemm::runtime::{Runtime, Tensor};
use mlir_gemm::schedule::Dtype;
use mlir_gemm::sim::DeviceModel;
use mlir_gemm::util::prng::Rng;
use mlir_gemm::util::proptest::{check, Config};

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

macro_rules! require_artifacts {
    () => {
        match artifacts_dir() {
            Some(d) => d,
            None => {
                eprintln!("skipping: artifacts not built (run `make artifacts`)");
                return;
            }
        }
    };
}

fn gemm_request(rng: &mut Rng, m: usize, n: usize, k: usize, baseline: bool) -> GemmRequest {
    GemmRequest {
        key: GemmKey::plain(m, n, k),
        a: Tensor::new(vec![m, k], rng.normal_matrix(m, k)).unwrap(),
        b: Some(Tensor::new(vec![k, n], rng.normal_matrix(k, n)).unwrap()),
        c: Tensor::zeros(vec![m, n]),
        bias: None,
        use_baseline: baseline,
        deadline: None,
    }
}

#[test]
fn serves_concurrent_requests_correctly() {
    let dir = require_artifacts!();
    let rt = Arc::new(Runtime::open(&dir).unwrap());
    let mut server = Server::start(rt, &DeviceModel::rtx3090(), ServerConfig::default());

    let mut rng = Rng::new(10);
    let mut expected = Vec::new();
    let mut rxs = Vec::new();
    for _ in 0..12 {
        let req = gemm_request(&mut rng, 256, 256, 256, false);
        // host reference for a few spot values
        let (a, b) = (
            req.a.data.clone(),
            req.b.as_ref().expect("inline request").data.clone(),
        );
        expected.push((a, b));
        rxs.push(server.submit(req));
    }
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx.recv_timeout(Duration::from_secs(120)).unwrap();
        let out = resp.output.expect("request should succeed");
        assert_eq!(out.shape, vec![256, 256]);
        // spot-check one output element against a host dot product
        let (a, b) = &expected[i];
        let want: f64 = (0..256).map(|kk| a[kk] as f64 * b[kk * 256] as f64).sum();
        let got = out.data[0] as f64;
        assert!(
            (got - want).abs() < 0.5 + want.abs() * 0.02,
            "request {i}: out[0,0]={got} vs ref {want}"
        );
    }
    let m = server.shutdown();
    assert_eq!(m.completed, 12);
    assert_eq!(m.failed, 0);
    assert!(m.batches >= 1);
    assert!(m.mean_batch_size >= 1.0);
}

#[test]
fn routes_baseline_separately_and_unknown_shapes_fail_fast() {
    let dir = require_artifacts!();
    let rt = Arc::new(Runtime::open(&dir).unwrap());
    let mut server = Server::start(rt, &DeviceModel::rtx3090(), ServerConfig::default());

    let mut rng = Rng::new(11);
    // baseline route
    let resp = server
        .call(gemm_request(&mut rng, 256, 256, 256, true))
        .unwrap();
    assert!(resp.output.is_ok());
    assert!(resp.variant.starts_with("baseline_"), "{}", resp.variant);

    // unknown shape
    let resp = server.call(gemm_request(&mut rng, 192, 192, 192, false)).unwrap();
    assert!(resp.output.is_err());

    let m = server.shutdown();
    assert_eq!(m.failed, 1);
}

#[test]
fn routes_to_autotuned_variant_when_multiple_cover_shape() {
    let dir = require_artifacts!();
    let rt = Arc::new(Runtime::open(&dir).unwrap());
    let device = DeviceModel::rtx3090();
    let mut server = Server::start(rt, &device, ServerConfig::default());
    // 512 has two tile variants in the manifest (64^3 and 128x128x64);
    // the registry must have ranked them.
    let key = GemmKey::plain(512, 512, 512);
    let variants = server.registry().variants(&key);
    if variants.len() < 2 {
        eprintln!("skipping: only {} variants at 512 (quick artifacts?)", variants.len());
        server.shutdown();
        return;
    }
    assert!(
        variants[0].predicted_tflops.unwrap() >= variants[1].predicted_tflops.unwrap()
    );
    let mut rng = Rng::new(12);
    let resp = server.call(gemm_request(&mut rng, 512, 512, 512, false)).unwrap();
    assert_eq!(resp.variant, variants[0].artifact);
    server.shutdown();
}

#[test]
fn post_shutdown_submit_fails_explicitly_and_keeps_metrics_consistent() {
    use mlir_gemm::coordinator::Registry;
    // Regression: `submit` used to count `on_submit` and then silently
    // drop the job when the dispatcher was gone, so `submitted` could
    // permanently exceed `completed + failed` and the caller blocked on a
    // dead channel.
    let rt = Arc::new(Runtime::without_manifest().unwrap());
    let mut server =
        Server::start_with_registry(rt, Arc::new(Registry::default()), ServerConfig::default());
    server.shutdown();
    let mut rng = Rng::new(13);
    let rx = server.submit(gemm_request(&mut rng, 8, 8, 8, false));
    let resp = rx
        .recv()
        .expect("an explicit error response, not a dropped channel");
    assert!(resp.output.is_err());
    let m = server.metrics();
    assert_eq!(m.submitted, 1);
    assert_eq!(m.completed + m.failed, m.submitted);
}

#[test]
fn sharded_server_matches_unsharded_execution_bitwise() {
    use mlir_gemm::coordinator::{
        Registry, RegistryEntry, ShardConfig, ShardStrategy,
    };
    use mlir_gemm::runtime::ArtifactKind;

    const MANIFEST: &str = r#"{
      "version": 1,
      "artifacts": [
        {
          "name": "big",
          "file": "big.tprog.json",
          "kind": "baseline",
          "inputs": [
            {"shape": [64, 64], "dtype": "f32"},
            {"shape": [64, 64], "dtype": "f32"},
            {"shape": [64, 64], "dtype": "f32"}
          ],
          "outputs": [{"shape": [64, 64], "dtype": "f32"}],
          "m": 64, "n": 64, "k": 64, "dtype_in": "f32", "dtype_acc": "f32"
        }
      ]
    }"#;
    const TPROG: &str = r#"{
      "format": "mlir-gemm-tprog-v1",
      "name": "big",
      "program": {
        "type": "gemm", "m": 64, "n": 64, "k": 64,
        "dtype_in": "f32", "dtype_acc": "f32", "epilogue": "none", "fused": true
      }
    }"#;

    let dir = std::env::temp_dir()
        .join(format!("mlir_gemm_shard_srv_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.json"), MANIFEST).unwrap();
    std::fs::write(dir.join("big.tprog.json"), TPROG).unwrap();

    let rt = Arc::new(Runtime::open(&dir).unwrap());
    let mut reg = Registry::default();
    let key = GemmKey::with_dtypes(64, 64, 64, Dtype::F32, Dtype::F32);
    reg.register(
        key.clone(),
        RegistryEntry {
            artifact: "big".into(),
            kind: ArtifactKind::Baseline,
            predicted_tflops: None,
        },
    );
    let cfg = ServerConfig {
        devices: 3,
        workers: 3,
        shard: ShardConfig {
            strategy: ShardStrategy::Rows,
            min_rows: 1,
            min_k: 1,
            min_flops: 0.0,
        },
        ..Default::default()
    };
    let mut server = Server::start_with_registry(rt.clone(), Arc::new(reg), cfg);

    let mut rng = Rng::new(77);
    let n_requests = 4;
    for _ in 0..n_requests {
        let a = Tensor::new(vec![64, 64], rng.normal_matrix(64, 64)).unwrap();
        let b = Tensor::new(vec![64, 64], rng.normal_matrix(64, 64)).unwrap();
        let c = Tensor::new(vec![64, 64], rng.normal_matrix(64, 64)).unwrap();
        let want = rt
            .execute("big", &[a.clone(), b.clone(), c.clone()])
            .unwrap();
        let resp = server
            .call(GemmRequest {
                key: key.clone(),
                a,
                b: Some(b),
                c,
                bias: None,
                use_baseline: false,
                deadline: None,
            })
            .unwrap();
        let out = resp.output.expect("sharded request should succeed");
        // row sharding must be bit-identical to the unsharded executor
        assert_eq!(out.shape, want[0].shape);
        assert_eq!(out.data, want[0].data);
    }
    let m = server.shutdown();
    assert_eq!(m.completed, n_requests);
    assert_eq!(m.failed, 0);
    assert!(
        m.per_device.len() >= 2,
        "expected multi-device execution, got {:?}",
        m.per_device
    );
    let shard_tasks: u64 = m.per_device.values().map(|l| l.tasks).sum();
    assert_eq!(shard_tasks, n_requests * 3, "3 shards per request");
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------------
// proptest-lite invariants (pure components, no runtime needed)
// ---------------------------------------------------------------------------

#[test]
fn prop_scheduler_never_reorders_within_variant_and_never_drops() {
    // Uniform priority, no deadlines: release order within a variant is
    // pure FIFO, every release is immediate (continuous batching has no
    // Wait state), and nothing is ever dropped.
    check(
        Config { cases: 64, ..Default::default() },
        |rng| {
            let n = 1 + rng.below(40);
            let max_batch = 1 + rng.below(6);
            let variants = 1 + rng.below(3);
            let items: Vec<usize> = (0..n).map(|_| rng.below(variants)).collect();
            (items, max_batch)
        },
        |(items, max_batch)| {
            let mut shrunk = Vec::new();
            if items.len() > 1 {
                let mut c = items.clone();
                c.pop();
                shrunk.push((c, *max_batch));
            }
            shrunk
        },
        |(items, max_batch)| {
            let t0 = Instant::now();
            let mut s: Scheduler<usize> = Scheduler::new(BatcherConfig {
                max_batch: *max_batch,
                max_wait: Duration::ZERO,
            });
            for (id, v) in items.iter().enumerate() {
                s.push(Queued {
                    variant: format!("v{v}"),
                    enqueued_at: t0,
                    priority: Priority::Normal,
                    deadline: None,
                    payload: id,
                });
            }
            let mut seen: Vec<usize> = Vec::new();
            let mut per_variant_last: std::collections::HashMap<String, usize> =
                Default::default();
            while let Some(r) = s.next_release(t0) {
                if r.batch.is_empty() || r.batch.len() > *max_batch {
                    return Err(format!("batch size {}", r.batch.len()));
                }
                for item in r.batch {
                    if item.variant != r.variant {
                        return Err(format!(
                            "mixed-variant batch: {} in {}",
                            item.variant, r.variant
                        ));
                    }
                    // FIFO within variant
                    if let Some(&last) = per_variant_last.get(&r.variant) {
                        if item.payload <= last {
                            return Err(format!(
                                "reorder in {}: {} after {last}",
                                r.variant, item.payload
                            ));
                        }
                    }
                    per_variant_last.insert(r.variant.clone(), item.payload);
                    seen.push(item.payload);
                }
            }
            if seen.len() != items.len() {
                return Err(format!("dropped: {} of {}", seen.len(), items.len()));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_scheduler_release_head_is_globally_most_urgent() {
    // EDF within priority tiers, continuously: every release's first
    // job carries the minimum (priority, effective deadline) key over
    // everything still queued — no priority inversion, no deadline
    // inversion past a tier — and the whole queue drains.
    check(
        Config { cases: 64, ..Default::default() },
        |rng| {
            let n = 2 + rng.below(30);
            let max_batch = 1 + rng.below(4);
            let variants = 1 + rng.below(3);
            // (variant, priority 0..3, deadline offset in ms, 0 = none)
            let items: Vec<(usize, usize, u64)> = (0..n)
                .map(|_| (rng.below(variants), rng.below(3), rng.below(50) as u64))
                .collect();
            (items, max_batch)
        },
        |(items, max_batch)| {
            let mut shrunk = Vec::new();
            if items.len() > 2 {
                let mut c = items.clone();
                c.pop();
                shrunk.push((c, *max_batch));
            }
            shrunk
        },
        |(items, max_batch)| {
            let t0 = Instant::now();
            let max_wait = Duration::from_millis(10);
            let prio = |p: usize| match p {
                0 => Priority::High,
                1 => Priority::Normal,
                _ => Priority::Low,
            };
            let mut s: Scheduler<usize> = Scheduler::new(BatcherConfig {
                max_batch: *max_batch,
                max_wait,
            });
            // Shadow copy: id -> (priority, effective deadline).
            let mut live: std::collections::HashMap<usize, (Priority, Instant)> =
                Default::default();
            for (id, &(v, p, dl)) in items.iter().enumerate() {
                let deadline =
                    (dl > 0).then(|| t0 + Duration::from_millis(dl));
                s.push(Queued {
                    variant: format!("v{v}"),
                    enqueued_at: t0,
                    priority: prio(p),
                    deadline,
                    payload: id,
                });
                live.insert(id, (prio(p), deadline.unwrap_or(t0 + max_wait)));
            }
            let mut drained = 0usize;
            while let Some(r) = s.next_release(t0) {
                let head = r.batch.first().ok_or("empty release")?;
                let head_key = live[&head.payload];
                let (&best_id, &best_key) = live
                    .iter()
                    .min_by_key(|(id, &(p, d))| (p, d, **id))
                    .expect("live set can't be empty while releases continue");
                if (head_key.0, head_key.1, head.payload)
                    != (best_key.0, best_key.1, best_id)
                {
                    return Err(format!(
                        "release head {} {head_key:?} is not the most urgent \
                         queued job {best_id} {best_key:?}",
                        head.payload
                    ));
                }
                for item in &r.batch {
                    if item.variant != r.variant {
                        return Err("mixed-variant batch".into());
                    }
                    live.remove(&item.payload);
                    drained += 1;
                }
            }
            if drained != items.len() {
                return Err(format!("dropped: {drained} of {}", items.len()));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_registry_best_is_max_predicted() {
    use mlir_gemm::coordinator::{Registry, RegistryEntry};
    use mlir_gemm::runtime::ArtifactKind;

    check(
        Config { cases: 64, ..Default::default() },
        |rng| {
            let n = 1 + rng.below(8);
            (0..n).map(|_| rng.next_f64() * 40.0).collect::<Vec<f64>>()
        },
        |v| {
            if v.len() > 1 {
                vec![v[..v.len() - 1].to_vec()]
            } else {
                vec![]
            }
        },
        |tflops| {
            let mut reg = Registry::default();
            let key = GemmKey::plain(64, 64, 64);
            for (i, &t) in tflops.iter().enumerate() {
                reg.register(
                    key.clone(),
                    RegistryEntry {
                        artifact: format!("v{i}"),
                        kind: ArtifactKind::Generated,
                        predicted_tflops: Some(t),
                    },
                );
            }
            // Registry::build sorts; register() does not, so emulate the
            // invariant the router relies on: best() of a sorted registry.
            let mut sorted: Vec<f64> = tflops.clone();
            sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
            let best_idx = tflops
                .iter()
                .position(|&t| t == sorted[0])
                .unwrap();
            // variants() preserves registration order; the router uses
            // best() only on built registries.  Check the data survived.
            let vs = reg.variants(&key);
            if vs.len() != tflops.len() {
                return Err("lost variants".into());
            }
            if vs[best_idx].predicted_tflops != Some(sorted[0]) {
                return Err("predicted tflops corrupted".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_sim_monotone_in_problem_size() {
    use mlir_gemm::schedule::Schedule;
    use mlir_gemm::sim::simulate;

    let d = DeviceModel::rtx3090();
    check(
        Config { cases: 40, ..Default::default() },
        |rng| 1 + rng.below(16),
        |&n| if n > 1 { vec![n / 2, n - 1] } else { vec![] },
        |&mult| {
            let small = 1024 * mult;
            let big = 1024 * (mult + 1);
            let s1 = Schedule::optimized(small, small, small, Dtype::F32,
                                         (128, 128, 64), (64, 32, 32)).unwrap();
            let s2 = Schedule::optimized(big, big, big, Dtype::F32,
                                         (128, 128, 64), (64, 32, 32)).unwrap();
            let t1 = simulate(&s1, &d).seconds;
            let t2 = simulate(&s2, &d).seconds;
            if t2 <= t1 {
                return Err(format!("time not monotone: {t1} at {small}, {t2} at {big}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_occupancy_within_hardware_bounds() {
    use mlir_gemm::schedule::Schedule;
    use mlir_gemm::sim::occupancy;

    let d = DeviceModel::rtx3090();
    check(
        Config { cases: 128, ..Default::default() },
        |rng| {
            let tbs = [64usize, 128, 256];
            let tks = [32usize, 64];
            let ws = [32usize, 64];
            (
                *rng.choice(&tbs),
                *rng.choice(&tbs),
                *rng.choice(&tks),
                *rng.choice(&ws),
                *rng.choice(&ws),
                1 + rng.below(16),
            )
        },
        |_| vec![],
        |&(tbm, tbn, tbk, wm, wn, mult)| {
            if tbm % wm != 0 || tbn % wn != 0 {
                return Ok(()); // infeasible tile, nothing to check
            }
            let size = 1024 * mult;
            let Ok(s) = Schedule::optimized(size, size, size, Dtype::F32,
                                            (tbm, tbn, tbk), (wm, wn, 32))
            else {
                return Ok(());
            };
            let o = occupancy(&s, &d);
            if o.blocks_resident_per_sm * s.smem_bytes > d.smem_per_sm {
                return Err(format!(
                    "smem oversubscribed: {} x {} > {}",
                    o.blocks_resident_per_sm, s.smem_bytes, d.smem_per_sm
                ));
            }
            if o.blocks_resident_per_sm * s.threads_per_block > d.max_threads_per_sm
            {
                return Err("threads oversubscribed".into());
            }
            if o.active_sms > d.sms {
                return Err("more active SMs than exist".into());
            }
            if !(0.0..=1.0).contains(&o.scheduler_util) {
                return Err(format!("scheduler util {}", o.scheduler_util));
            }
            Ok(())
        },
    );
}
