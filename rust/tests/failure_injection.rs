//! Failure injection: the runtime and manifest layer must fail loudly and
//! precisely on corrupted or inconsistent artifact stores — a downstream
//! user's first contact with this system is usually a broken build tree.

use std::fs;
use std::path::PathBuf;

use mlir_gemm::runtime::manifest::parse_manifest;
use mlir_gemm::runtime::Runtime;

fn open_err(dir: &PathBuf) -> anyhow::Error {
    match Runtime::open(dir) {
        Err(e) => e,
        Ok(_) => panic!("Runtime::open must fail for {}", dir.display()),
    }
}

fn tmpdir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("mlir_gemm_fi_{name}_{}", std::process::id()));
    let _ = fs::remove_dir_all(&dir);
    fs::create_dir_all(&dir).unwrap();
    dir
}

const MINIMAL: &str = r#"{
  "version": 1,
  "artifacts": [
    {
      "name": "k",
      "file": "k.hlo.txt",
      "kind": "baseline",
      "inputs": [{"shape": [2, 2], "dtype": "f32"}],
      "outputs": [{"shape": [2, 2], "dtype": "f32"}],
      "m": 2, "n": 2, "k": 2, "dtype_acc": "f32"
    }
  ]
}"#;

#[test]
fn missing_manifest_reports_path() {
    let dir = tmpdir("nomanifest");
    let msg = format!("{:#}", open_err(&dir));
    assert!(msg.contains("manifest"), "{msg}");
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn truncated_manifest_is_a_parse_error() {
    let dir = tmpdir("truncated");
    fs::write(dir.join("manifest.json"), &MINIMAL[..60]).unwrap();
    let msg = format!("{:#}", open_err(&dir));
    assert!(msg.contains("manifest"), "{msg}");
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn manifest_entry_with_missing_hlo_file_fails_at_load_not_open() {
    let dir = tmpdir("missinghlo");
    fs::write(dir.join("manifest.json"), MINIMAL).unwrap();
    // open succeeds (lazy compilation)...
    let rt = Runtime::open(&dir).unwrap();
    assert_eq!(rt.artifacts().len(), 1);
    // ...load fails with the artifact path in the error.
    let err = match rt.load("k") {
        Err(e) => e,
        Ok(_) => panic!("load of missing HLO file must fail"),
    };
    let msg = format!("{err:#}");
    assert!(msg.contains("k.hlo.txt"), "{msg}");
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn corrupted_hlo_text_fails_to_parse() {
    let dir = tmpdir("badhlo");
    fs::write(dir.join("manifest.json"), MINIMAL).unwrap();
    fs::write(dir.join("k.hlo.txt"), "HloModule broken\n<<garbage>>\n").unwrap();
    let rt = Runtime::open(&dir).unwrap();
    assert!(rt.load("k").is_err());
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn schedule_with_inconsistent_fields_rejected() {
    // A manifest whose schedule object is missing required fields.
    let text = MINIMAL.replace(
        r#""m": 2, "n": 2, "k": 2, "dtype_acc": "f32""#,
        r#""schedule": {"name": "x"}"#,
    )
    .replace("\"baseline\"", "\"generated\"");
    let err = parse_manifest(&text, std::path::Path::new(".")).unwrap_err();
    assert!(err.0.contains("missing"), "{}", err.0);
}

#[test]
fn negative_or_fractional_shapes_rejected() {
    let text = MINIMAL.replace("[2, 2]", "[-2, 2]");
    assert!(parse_manifest(&text, std::path::Path::new(".")).is_err());
}

#[test]
fn unknown_dtype_rejected() {
    let text = MINIMAL.replace("\"f32\"", "\"f8\"");
    assert!(parse_manifest(&text, std::path::Path::new(".")).is_err());
}
