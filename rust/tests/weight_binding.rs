//! Weight-binding correctness at the server boundary: weight-bound
//! execution is bit-identical to shipping the same B inline; rebinding
//! atomically invalidates the prepacked cache (requests routed after a
//! rebind are served the new panels, never the old); shape-mismatched
//! binds are rejected at bind time; unbinding makes weight-bound
//! requests fail explicitly while inline traffic continues.

use std::sync::Arc;
use std::time::Duration;

use mlir_gemm::coordinator::{GemmKey, GemmRequest, Server, ServerConfig};
use mlir_gemm::runtime::{KernelPolicy, Runtime, Tensor};
use mlir_gemm::schedule::Dtype;
use mlir_gemm::util::prng::Rng;

const MANIFEST: &str = r#"{
  "version": 1,
  "artifacts": [
    {
      "name": "big",
      "file": "big.tprog.json",
      "kind": "baseline",
      "inputs": [
        {"shape": [128, 112], "dtype": "f32"},
        {"shape": [112, 96], "dtype": "f32"},
        {"shape": [128, 96], "dtype": "f32"}
      ],
      "outputs": [{"shape": [128, 96], "dtype": "f32"}],
      "m": 128, "n": 96, "k": 112, "dtype_in": "f32", "dtype_acc": "f32"
    }
  ]
}"#;

const BIG: &str = r#"{
  "format": "mlir-gemm-tprog-v1",
  "name": "big",
  "program": {
    "type": "gemm", "m": 128, "n": 96, "k": 112,
    "dtype_in": "f32", "dtype_acc": "f32", "epilogue": "none", "fused": true
  }
}"#;

fn start_server() -> (Server, GemmKey, std::path::PathBuf) {
    let dir = std::env::temp_dir().join(format!(
        "mlir_gemm_bind_srv_{}_{:?}",
        std::process::id(),
        std::thread::current().id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.json"), MANIFEST).unwrap();
    std::fs::write(dir.join("big.tprog.json"), BIG).unwrap();
    let rt = Arc::new(Runtime::open(&dir).unwrap());
    let server = Server::start(
        rt,
        &mlir_gemm::sim::DeviceModel::rtx3090(),
        ServerConfig { workers: 2, ..Default::default() },
    );
    let key = GemmKey::with_dtypes(128, 96, 112, Dtype::F32, Dtype::F32);
    (server, key, dir)
}

fn request(key: &GemmKey, a: &Tensor, b: Option<Tensor>, c: &Tensor) -> GemmRequest {
    GemmRequest {
        key: key.clone(),
        a: a.clone(),
        b,
        c: c.clone(),
        bias: None,
        use_baseline: true,
        deadline: None,
    }
}

fn naive_reference(key: &GemmKey, a: &[f32], b: &[f32], c: &[f32]) -> Vec<f32> {
    let mut out = c.to_vec();
    mlir_gemm::runtime::kernel::matmul(
        KernelPolicy::Naive,
        &mut out,
        a,
        b,
        key.m,
        key.n,
        key.k,
    );
    out
}

#[test]
fn bound_requests_bit_identical_to_inline_and_rebind_swaps_atomically() {
    let (mut server, key, dir) = start_server();
    // The routed plan packs (and therefore prepacks) at this shape, so
    // the bound path genuinely exercises the panel cache.
    let plan = server.registry().plan(&key).unwrap();
    assert!(plan.prepack, "128x96x112 must compile to a prepacking plan");

    let mut rng = Rng::new(0xB11D);
    let b1 = Tensor::new(vec![112, 96], rng.normal_matrix(112, 96)).unwrap();
    let b2 = Tensor::new(vec![112, 96], rng.normal_matrix(112, 96)).unwrap();
    server.bind_weights(&key, &b1).unwrap();

    // Weight-bound responses must match inline responses with the same B
    // bit for bit — across several activations.
    for i in 0..4 {
        let a = Tensor::new(vec![128, 112], rng.normal_matrix(128, 112)).unwrap();
        let c = Tensor::new(vec![128, 96], rng.normal_matrix(128, 96)).unwrap();
        let want = naive_reference(&key, &a.data, &b1.data, &c.data);
        let inline_resp = server
            .call(request(&key, &a, Some(b1.clone()), &c))
            .unwrap()
            .output
            .unwrap();
        let bound_resp =
            server.call(request(&key, &a, None, &c)).unwrap().output.unwrap();
        assert_eq!(inline_resp.data, want, "inline {i} drifted from reference");
        assert_eq!(bound_resp.data, want, "bound {i} drifted from inline");
    }

    // Rebind: requests routed afterwards are served the new panels —
    // the old B1 panels are never served again.
    server.bind_weights(&key, &b2).unwrap();
    for i in 0..3 {
        let a = Tensor::new(vec![128, 112], rng.normal_matrix(128, 112)).unwrap();
        let c = Tensor::new(vec![128, 96], rng.normal_matrix(128, 96)).unwrap();
        let want_b2 = naive_reference(&key, &a.data, &b2.data, &c.data);
        let want_b1 = naive_reference(&key, &a.data, &b1.data, &c.data);
        let got = server.call(request(&key, &a, None, &c)).unwrap().output.unwrap();
        assert_eq!(got.data, want_b2, "rebind {i}: stale panels served");
        assert_ne!(got.data, want_b1, "rebind {i}: result indistinguishable from B1");
    }

    // The pack counters saw only hits on the bound route.
    let m = server.shutdown();
    let load = &m.per_plan[&plan.id()];
    assert_eq!(load.pack_hits, 4 + 3, "every bound request served from panels");
    assert_eq!(load.pack_misses, 4, "every inline request re-packed");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn bind_rejects_mismatched_shapes_and_unbind_fails_bound_requests_explicitly() {
    let (mut server, key, dir) = start_server();
    let mut rng = Rng::new(0x0B2);

    // Shape mismatch: rejected at bind time, nothing bound.
    let wrong = Tensor::new(vec![96, 112], rng.normal_matrix(96, 112)).unwrap();
    assert!(server.bind_weights(&key, &wrong).is_err());
    let torn = Tensor { shape: vec![112, 96], data: vec![0.0; 7] };
    assert!(server.bind_weights(&key, &torn).is_err());

    // No weights bound: the weight-bound request form fails explicitly
    // (an error response, not a hang or a dead channel).
    let a = Tensor::new(vec![128, 112], rng.normal_matrix(128, 112)).unwrap();
    let c = Tensor::new(vec![128, 96], rng.normal_matrix(128, 96)).unwrap();
    let resp = server.call(request(&key, &a, None, &c)).unwrap();
    assert!(resp.output.is_err(), "unbound weight-bound request must fail");

    // Bind, verify it serves, then unbind: bound requests fail again
    // while inline traffic keeps working.
    let b = Tensor::new(vec![112, 96], rng.normal_matrix(112, 96)).unwrap();
    server.bind_weights(&key, &b).unwrap();
    let ok = server.call(request(&key, &a, None, &c)).unwrap();
    assert!(ok.output.is_ok());
    assert!(server.unbind_weights(&key));
    assert!(!server.unbind_weights(&key), "second unbind is a no-op");
    let resp = server.call(request(&key, &a, None, &c)).unwrap();
    assert!(resp.output.is_err(), "unbound weight-bound request must fail");
    let inline = server.call(request(&key, &a, Some(b.clone()), &c)).unwrap();
    assert!(inline.output.is_ok(), "inline traffic unaffected by unbind");

    let m = server.shutdown();
    assert_eq!(m.completed + m.failed, m.submitted);
    assert_eq!(m.failed, 2, "exactly the two unbound weight-bound requests failed");
    let _ = std::fs::remove_dir_all(&dir);
}

/// The server path for a *sharded* weight-bound request: row shards
/// share the bind-time panels across the device pool and stay
/// bit-identical to the unsharded inline execution.
#[test]
fn sharded_bound_requests_bit_identical_across_device_pool() {
    use mlir_gemm::coordinator::{ShardConfig, ShardStrategy};
    let dir = std::env::temp_dir()
        .join(format!("mlir_gemm_bind_shard_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.json"), MANIFEST).unwrap();
    std::fs::write(dir.join("big.tprog.json"), BIG).unwrap();
    let rt = Arc::new(Runtime::open(&dir).unwrap());
    let server = Server::start(
        rt,
        &mlir_gemm::sim::DeviceModel::rtx3090(),
        ServerConfig {
            workers: 3,
            devices: 3,
            shard: ShardConfig {
                strategy: ShardStrategy::Rows,
                min_rows: 1,
                min_k: 1,
                min_flops: 0.0,
            },
            ..Default::default()
        },
    );
    let key = GemmKey::with_dtypes(128, 96, 112, Dtype::F32, Dtype::F32);
    let mut rng = Rng::new(0x5A4D);
    let b = Tensor::new(vec![112, 96], rng.normal_matrix(112, 96)).unwrap();
    server.bind_weights(&key, &b).unwrap();
    let mut server = server;
    for i in 0..3 {
        let a = Tensor::new(vec![128, 112], rng.normal_matrix(128, 112)).unwrap();
        let c = Tensor::new(vec![128, 96], rng.normal_matrix(128, 96)).unwrap();
        let want = naive_reference(&key, &a.data, &b.data, &c.data);
        let rx = server.submit(request(&key, &a, None, &c));
        let resp = rx.recv_timeout(Duration::from_secs(120)).unwrap();
        let out = resp.output.expect("sharded bound request should succeed");
        assert_eq!(out.data, want, "sharded bound request {i} drifted");
    }
    let m = server.shutdown();
    assert_eq!(m.completed, 3);
    assert!(
        m.per_device.len() >= 2,
        "expected multi-device execution, got {:?}",
        m.per_device
    );
    let _ = std::fs::remove_dir_all(&dir);
}
