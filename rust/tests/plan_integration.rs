//! Integration: the execution-plan compiler's inspectability contract
//! (JSON round-trip + golden plans for the Table 1 shape family) and the
//! serving path's per-variant plan isolation (two variants with
//! different compiled plans interleaved on one server, no
//! cross-contamination).

use std::sync::Arc;
use std::time::Duration;

use mlir_gemm::coordinator::{GemmKey, GemmRequest, Server, ServerConfig};
use mlir_gemm::plan::{compile, ExecutionPlan, PlanEnv, PlanOverride};
use mlir_gemm::runtime::{KernelPolicy, Runtime, Tensor};
use mlir_gemm::schedule::Dtype;
use mlir_gemm::util::json::{self, Json};
use mlir_gemm::util::prng::Rng;

// ---------------------------------------------------------------------------
// JSON round-trip
// ---------------------------------------------------------------------------

#[test]
fn compiled_plans_round_trip_through_json() {
    let keys = vec![
        GemmKey::plain(512, 512, 512),
        GemmKey::plain(64, 64, 64),
        GemmKey {
            m: 1024,
            n: 768,
            k: 512,
            dtype_in: Dtype::Bf16,
            dtype_acc: Dtype::F32,
            epilogue: "bias".into(),
        },
    ];
    let envs = vec![
        PlanEnv::pinned(),
        PlanEnv::for_pool(4),
        PlanEnv::pinned().with_force(PlanOverride::parse("threaded:64,128,256,2").unwrap()),
        // SIMD opt-in: pinned ISA, fma_relaxed plans must round-trip too.
        PlanEnv::pinned().with_force(PlanOverride::Simd),
        PlanEnv::pinned().with_force(PlanOverride::parse("simd:portable:64,128,256,2").unwrap()),
    ];
    for key in &keys {
        for env in &envs {
            let plan = compile(key, env).unwrap();
            let text = plan.to_json().to_string();
            let back = ExecutionPlan::from_text(&text).unwrap();
            assert_eq!(plan, back, "round trip drifted for {key:?}");
            // and the serialized form is itself valid JSON that keeps the
            // per-pass provenance and the numerics class
            let parsed = json::parse(&text).unwrap();
            let trace = parsed.get("trace").and_then(Json::as_arr).unwrap();
            assert_eq!(trace.len(), plan.trace.len());
            assert!(plan.trace.len() >= 6, "pipeline records all six passes");
            assert_eq!(
                parsed.get("numerics").and_then(Json::as_str),
                Some(plan.numerics.name()),
                "numerics class missing from the serialized plan"
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Golden plans: the paper's Table 1 shape family under the pinned env
// (see golden/README.md; field reference in docs/PLAN_SCHEMA.md)
// ---------------------------------------------------------------------------

const GOLDENS: &[&str] = &[
    include_str!("golden/plan_512x512x512_f32_f32_none.json"),
    include_str!("golden/plan_512x512x512_f16_f32_bias_relu.json"),
    include_str!("golden/plan_256x256x256_f16_f32_none.json"),
    include_str!("golden/plan_64x64x64_f32_f32_none.json"),
    include_str!("golden/plan_512x512x512_f32_f32_none_simd.json"),
];

#[test]
fn golden_plans_for_table1_shapes() {
    for golden_text in GOLDENS {
        let g = json::parse(golden_text).unwrap();
        let get_u = |f: &str| g.get(f).and_then(Json::as_usize).unwrap();
        let get_s = |f: &str| g.get(f).and_then(Json::as_str).unwrap();
        let key = GemmKey {
            m: get_u("m"),
            n: get_u("n"),
            k: get_u("k"),
            dtype_in: Dtype::parse(get_s("dtype_in")).unwrap(),
            dtype_acc: Dtype::parse(get_s("dtype_acc")).unwrap(),
            epilogue: get_s("epilogue").to_string(),
        };
        // A golden may carry the plan override it was compiled under
        // (the simd golden does); absent means the auto pipeline.
        let force = g
            .get("force")
            .and_then(Json::as_str)
            .map(|f| PlanOverride::parse(f).unwrap())
            .unwrap_or(PlanOverride::Auto);
        let plan = compile(&key, &PlanEnv::pinned().with_force(force)).unwrap();
        assert_eq!(
            plan.kernel.name(),
            get_s("kernel"),
            "tile/packing/threading decision drifted for {key:?}"
        );
        assert_eq!(
            plan.fuse_epilogue,
            g.get("fuse_epilogue").and_then(Json::as_bool).unwrap(),
            "epilogue decision drifted for {key:?}"
        );
        assert_eq!(
            plan.prepack,
            g.get("prepack").and_then(Json::as_bool).unwrap(),
            "prepack decision drifted for {key:?}"
        );
        assert_eq!(
            plan.numerics.name(),
            get_s("numerics"),
            "numerics class drifted for {key:?}"
        );
        assert!(plan.trace.len() >= 6, "pipeline records all six passes");
    }
}

// ---------------------------------------------------------------------------
// Server: two variants with different plans, interleaved
// ---------------------------------------------------------------------------

const MANIFEST: &str = r#"{
  "version": 1,
  "artifacts": [
    {
      "name": "small",
      "file": "small.tprog.json",
      "kind": "baseline",
      "inputs": [
        {"shape": [24, 24], "dtype": "f32"},
        {"shape": [24, 24], "dtype": "f32"},
        {"shape": [24, 24], "dtype": "f32"}
      ],
      "outputs": [{"shape": [24, 24], "dtype": "f32"}],
      "m": 24, "n": 24, "k": 24, "dtype_in": "f32", "dtype_acc": "f32"
    },
    {
      "name": "big",
      "file": "big.tprog.json",
      "kind": "baseline",
      "inputs": [
        {"shape": [128, 112], "dtype": "f32"},
        {"shape": [112, 96], "dtype": "f32"},
        {"shape": [128, 96], "dtype": "f32"}
      ],
      "outputs": [{"shape": [128, 96], "dtype": "f32"}],
      "m": 128, "n": 96, "k": 112, "dtype_in": "f32", "dtype_acc": "f32"
    }
  ]
}"#;

const SMALL: &str = r#"{
  "format": "mlir-gemm-tprog-v1",
  "name": "small",
  "program": {
    "type": "gemm", "m": 24, "n": 24, "k": 24,
    "dtype_in": "f32", "dtype_acc": "f32", "epilogue": "none", "fused": true
  }
}"#;

const BIG: &str = r#"{
  "format": "mlir-gemm-tprog-v1",
  "name": "big",
  "program": {
    "type": "gemm", "m": 128, "n": 96, "k": 112,
    "dtype_in": "f32", "dtype_acc": "f32", "epilogue": "none", "fused": true
  }
}"#;

/// Two variants whose compiled plans differ (a cache-resident 24^3 lowers
/// to the direct kernel, a 128x96x112 to packed tiles) execute interleaved
/// from concurrent clients on one server.  Every response must be
/// bit-identical to the naive reference for *its* shape, and the metrics
/// must attribute work to both plan ids separately — proof the explicit
/// plans don't cross-contaminate the way a flipped global policy could.
#[test]
fn interleaved_variants_with_different_plans_do_not_cross_contaminate() {
    let dir = std::env::temp_dir()
        .join(format!("mlir_gemm_plan_srv_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.json"), MANIFEST).unwrap();
    std::fs::write(dir.join("small.tprog.json"), SMALL).unwrap();
    std::fs::write(dir.join("big.tprog.json"), BIG).unwrap();

    let rt = Arc::new(Runtime::open(&dir).unwrap());
    let mut server = Server::start(
        rt.clone(),
        &mlir_gemm::sim::DeviceModel::rtx3090(),
        ServerConfig { workers: 3, ..Default::default() },
    );

    let small_key = GemmKey::with_dtypes(24, 24, 24, Dtype::F32, Dtype::F32);
    let big_key = GemmKey::with_dtypes(128, 96, 112, Dtype::F32, Dtype::F32);
    // The two keys compile to genuinely different plans.
    let small_plan = server.registry().plan(&small_key).unwrap();
    let big_plan = server.registry().plan(&big_key).unwrap();
    assert_eq!(small_plan.kernel, KernelPolicy::Naive, "24^3 is cache-resident");
    assert!(
        !matches!(big_plan.kernel, KernelPolicy::Naive),
        "128x96x112 must pack, got {:?}",
        big_plan.kernel
    );

    // Interleave both variants from two client threads.
    let per_client = 8usize;
    let naive_reference = |key: &GemmKey, a: &Tensor, b: &Tensor, c: &Tensor| -> Vec<f32> {
        let mut out = c.data.clone();
        mlir_gemm::runtime::kernel::matmul(
            KernelPolicy::Naive,
            &mut out,
            &a.data,
            &b.data,
            key.m,
            key.n,
            key.k,
        );
        out
    };
    let mut pending = Vec::new();
    let mut rng = Rng::new(0x51);
    for i in 0..2 * per_client {
        let key = if i % 2 == 0 { small_key.clone() } else { big_key.clone() };
        let a = Tensor::new(vec![key.m, key.k], rng.normal_matrix(key.m, key.k)).unwrap();
        let b = Tensor::new(vec![key.k, key.n], rng.normal_matrix(key.k, key.n)).unwrap();
        let c = Tensor::new(vec![key.m, key.n], rng.normal_matrix(key.m, key.n)).unwrap();
        let want = naive_reference(&key, &a, &b, &c);
        let rx = server.submit(GemmRequest {
            key: key.clone(),
            a,
            b: Some(b),
            c,
            bias: None,
            use_baseline: true,
            deadline: None,
        });
        pending.push((key, want, rx));
    }
    for (key, want, rx) in pending {
        let resp = rx.recv_timeout(Duration::from_secs(120)).unwrap();
        let out = resp.output.expect("request should succeed");
        assert_eq!(out.shape, vec![key.m, key.n]);
        assert_eq!(out.data, want, "{}x{}x{} drifted", key.m, key.n, key.k);
    }
    let m = server.shutdown();
    assert_eq!(m.completed, 2 * per_client as u64);
    assert_eq!(m.failed, 0);
    // Per-plan attribution: both plan ids show up, each with its own
    // request count — no blending under one global label.
    assert_eq!(
        m.per_plan.get(&small_plan.id()).map(|l| l.requests),
        Some(per_client as u64),
        "per_plan: {:?}",
        m.per_plan
    );
    assert_eq!(
        m.per_plan.get(&big_plan.id()).map(|l| l.requests),
        Some(per_client as u64),
        "per_plan: {:?}",
        m.per_plan
    );
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------------
// Validation satellite: invalid plans fail loudly end to end
// ---------------------------------------------------------------------------

#[test]
fn invalid_blocking_rejected_everywhere() {
    // parse-level
    assert!(PlanOverride::parse("tiled:0,256,1024").is_err());
    assert!(KernelPolicy::parse("threaded:64,0,1024,2").is_err());
    // manual-plan level
    let key = GemmKey::plain(32, 32, 32);
    assert!(ExecutionPlan::manual(
        &key,
        KernelPolicy::Tiled(mlir_gemm::runtime::Blocking { mc: 4, kc: 0, nc: 8 }),
        false
    )
    .is_err());
    // deserialization level: a plan file carrying a zero tile is rejected
    let good = compile(&key, &PlanEnv::pinned()).unwrap();
    let text = good
        .to_json()
        .to_string()
        .replace(&good.kernel.name(), "tiled:0,0,0");
    assert!(ExecutionPlan::from_text(&text).is_err());
}
