//! Runtime ISA dispatch: the `MLIR_GEMM_FORCE_ISA` override and the
//! plan compiler's pass 6 around it.
//!
//! These tests mutate process environment, so they live in their own
//! integration binary (one process per binary) and serialize on a
//! mutex — `cargo test` runs tests of one binary on parallel threads,
//! and `std::env::set_var` is process-global.

use std::sync::Mutex;

use mlir_gemm::plan::{compile, GemmKey, IsaPref, NumericsClass, PlanEnv, PlanOverride};
use mlir_gemm::runtime::kernel::{self, KernelPolicy};
use mlir_gemm::runtime::nanokernel::{self, Isa, FORCE_ISA_ENV};
use mlir_gemm::util::prng::Rng;

static ENV_LOCK: Mutex<()> = Mutex::new(());

/// Run `f` with `MLIR_GEMM_FORCE_ISA` set to `value` (None = unset),
/// restoring the prior state afterwards even if `f` panics midway
/// (the lock guard is dropped poisoned; later tests recover it).
fn with_force_isa<T>(value: Option<&str>, f: impl FnOnce() -> T) -> T {
    let _g = ENV_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let prior = std::env::var(FORCE_ISA_ENV).ok();
    match value {
        Some(v) => std::env::set_var(FORCE_ISA_ENV, v),
        None => std::env::remove_var(FORCE_ISA_ENV),
    }
    let out = f();
    match prior {
        Some(v) => std::env::set_var(FORCE_ISA_ENV, v),
        None => std::env::remove_var(FORCE_ISA_ENV),
    }
    out
}

/// A PlanEnv that requests SIMD lowering and resolves the ISA through
/// the runtime probe (the env override path under test), with the rest
/// of the environment pinned for determinism.
fn simd_detect_env() -> PlanEnv {
    PlanEnv::pinned().with_force(PlanOverride::Simd).with_isa(IsaPref::Detect)
}

#[test]
fn forced_scalar_compiles_bit_exact_plans_bit_identical_to_naive() {
    with_force_isa(Some("scalar"), || {
        let plan = compile(&GemmKey::plain(96, 64, 48), &simd_detect_env()).unwrap();
        // SIMD was requested, but the override forces the fallback: the
        // plan stays in the bit_exact class on a scalar kernel...
        assert_eq!(plan.numerics, NumericsClass::BitExact);
        assert_eq!(plan.isa_label(), "scalar");
        assert!(
            !matches!(plan.kernel, KernelPolicy::Simd(..)),
            "forced scalar still lowered to {:?}",
            plan.kernel
        );
        // ...and honors the class contract: bit-identical to naive.
        let (m, n, k) = (96, 64, 48);
        let mut rng = Rng::new(0x15A);
        let a = rng.normal_matrix(m, k);
        let b = rng.normal_matrix(k, n);
        let mut got = vec![0.0f32; m * n];
        let mut want = vec![0.0f32; m * n];
        kernel::matmul(plan.kernel, &mut got, &a, &b, m, n, k);
        kernel::matmul(KernelPolicy::Naive, &mut want, &a, &b, m, n, k);
        assert_eq!(got, want, "scalar-fallback plan diverged from naive");
    });
}

#[test]
fn env_isa_name_pins_the_nanokernel_choice() {
    with_force_isa(Some("portable"), || {
        let plan = compile(&GemmKey::plain(64, 64, 64), &simd_detect_env()).unwrap();
        assert_eq!(plan.numerics, NumericsClass::FmaRelaxed);
        assert_eq!(plan.isa_label(), "simd:portable");
        assert!(matches!(plan.kernel, KernelPolicy::Simd(_, _, Isa::Portable)));
        let isa_trace = plan.trace.last().unwrap();
        assert_eq!(isa_trace.pass, "isa");
        assert!(
            isa_trace.reason.contains(FORCE_ISA_ENV),
            "trace should credit the env override: {}",
            isa_trace.reason
        );
    });
}

#[test]
fn invalid_override_fails_simd_compiles_but_never_auto() {
    with_force_isa(Some("sse9"), || {
        // Requesting SIMD consults the probe, which must refuse the
        // unparseable override loudly...
        let err = compile(&GemmKey::plain(64, 64, 64), &simd_detect_env()).unwrap_err();
        assert!(err.to_string().contains("sse9"), "unhelpful error: {err}");
        // ...but an Auto compile never reads the probe: a stray env var
        // cannot break default (bit_exact) plan compilation.
        let env = PlanEnv::pinned().with_isa(IsaPref::Detect);
        let plan = compile(&GemmKey::plain(64, 64, 64), &env).unwrap();
        assert_eq!(plan.numerics, NumericsClass::BitExact);
    });
}

#[test]
fn detection_round_trips_the_env_override() {
    with_force_isa(Some("scalar"), || {
        assert_eq!(nanokernel::detect().unwrap(), None);
    });
    with_force_isa(Some("avx512"), || {
        assert_eq!(nanokernel::detect().unwrap(), Some(Isa::Avx512));
    });
    // Empty / whitespace-only counts as unset: the auto-probe answers.
    for unset in [None, Some(""), Some("   ")] {
        with_force_isa(unset, || {
            let probed = nanokernel::detect().unwrap();
            let expect = if nanokernel::hw_available(Isa::Avx2Fma) {
                Some(Isa::Avx2Fma)
            } else {
                Some(Isa::Portable)
            };
            assert_eq!(probed, expect);
        });
    }
}

#[test]
fn dispatch_degrades_unavailable_isas_to_the_portable_body() {
    // Plans pinned to an ISA the host lacks still execute — `kernel_for`
    // hands back the portable body instead of faulting.  (On an AVX2
    // host this checks the identity resolution path instead.)
    for isa in [Isa::Avx2Fma, Isa::Avx512, Isa::Neon, Isa::Portable] {
        let nano = nanokernel::kernel_for(isa);
        if nanokernel::hw_available(isa) {
            assert_eq!(nano.isa(), isa);
        } else {
            assert_eq!(nano.isa(), Isa::Portable, "{isa:?} should degrade");
        }
    }
}

#[test]
fn env_override_pins_wide_isas_and_execution_still_degrades() {
    // Pass 6 pins exactly what the env override names — including ISAs
    // the build host may lack (a plan is a portable artifact; where it
    // *executes* decides the body).  Execution then degrades through
    // `kernel_for`, staying inside the fma_relaxed contract.
    let (m, n, k) = (48, 40, 24);
    let mut rng = Rng::new(0xEA5);
    let a = rng.normal_matrix(m, k);
    let b = rng.normal_matrix(k, n);
    let zeros = vec![0.0f32; m * n];
    let mut want = vec![0.0f32; m * n];
    kernel::matmul(KernelPolicy::Naive, &mut want, &a, &b, m, n, k);
    for (name, isa) in [("avx512", Isa::Avx512), ("neon", Isa::Neon)] {
        with_force_isa(Some(name), || {
            let plan =
                compile(&GemmKey::with_dtypes(m, n, k, mlir_gemm::schedule::Dtype::F32, mlir_gemm::schedule::Dtype::F32), &simd_detect_env()).unwrap();
            assert_eq!(plan.isa_label(), format!("simd:{name}"));
            assert!(matches!(plan.kernel, KernelPolicy::Simd(_, _, i) if i == isa));
            assert_eq!(plan.numerics, NumericsClass::FmaRelaxed);
            let mut got = vec![0.0f32; m * n];
            kernel::matmul(plan.kernel, &mut got, &a, &b, m, n, k);
            nanokernel::verify_fma_relaxed(&got, &want, &a, &b, &zeros, None, m, n, k)
                .unwrap_or_else(|e| panic!("{name}: {e}"));
        });
    }
}

#[test]
fn ragged_tails_stay_ulp_bounded_on_every_isa_body() {
    // The shapes that exercise each body's remainder machinery: the
    // AVX-512 masked j-tail (n % 32), the AVX2 24-column j-tail
    // (n % 24), the NEON 16-column j-tail (n % 16), the scalar i-tail
    // (m % 4), and odd-k unroll tails.  `verify_fma_relaxed` returns the
    // worst ULP distance it charged against the condition-scaled bound;
    // on these well-conditioned operands the reassociation error stays
    // in the hundreds-of-ULP range (the C mirror observes ~6e2 on
    // similar shapes under cancellation), so a loose absolute ceiling
    // guards against a remainder path computing garbage that still
    // sneaks under a large-k condition bound.
    let shapes = [
        (4, 32, 8),   // exact one avx512 j-block
        (5, 33, 7),   // every tail at once, odd k
        (7, 31, 16),  // j one short of the zmm block
        (4, 17, 9),   // neon j-tail + ragged k
        (9, 24, 12),  // exact avx2 j-block, i-tail
        (3, 25, 21),  // avx2 j-tail of 1
        (6, 16, 32),  // exact neon j-block
        (1, 1, 1),    // degenerate minimum
    ];
    let mut rng = Rng::new(0x01B);
    for (m, n, k) in shapes {
        let a = rng.normal_matrix(m, k);
        let b = rng.normal_matrix(k, n);
        let zeros = vec![0.0f32; m * n];
        let mut want = vec![0.0f32; m * n];
        kernel::matmul(KernelPolicy::Naive, &mut want, &a, &b, m, n, k);
        for isa in [Isa::Portable, Isa::Avx2Fma, Isa::Avx512, Isa::Neon] {
            let policy =
                KernelPolicy::parse(&format!("simd:{}:8,8,32,1", isa.name())).unwrap();
            let mut got = vec![0.0f32; m * n];
            kernel::matmul(policy, &mut got, &a, &b, m, n, k);
            let ulps = nanokernel::verify_fma_relaxed(
                &got, &want, &a, &b, &zeros, None, m, n, k,
            )
            .unwrap_or_else(|e| panic!("{isa:?} {m}x{n}x{k}: {e}"));
            assert!(
                ulps <= 4096,
                "{isa:?} {m}x{n}x{k}: worst ULP distance {ulps} is far beyond \
                 reassociation noise — a remainder lane is likely wrong"
            );
        }
    }
}

#[test]
fn forced_simd_policy_executes_on_any_host() {
    // A forced simd:<isa> kernel policy is executable regardless of the
    // host: unavailable ISAs run the portable body, and the result obeys
    // the fma_relaxed tolerance against naive (portable is exactly the
    // unfused 4-wide kernel, so this is a generous bound).
    let (m, n, k) = (40, 33, 21);
    let mut rng = Rng::new(0xD15);
    let a = rng.normal_matrix(m, k);
    let b = rng.normal_matrix(k, n);
    let zeros = vec![0.0f32; m * n];
    let mut want = vec![0.0f32; m * n];
    kernel::matmul(KernelPolicy::Naive, &mut want, &a, &b, m, n, k);
    for isa in [Isa::Portable, Isa::Avx2Fma, Isa::Avx512, Isa::Neon] {
        let policy = KernelPolicy::parse(&format!("simd:{}:8,4,16,1", isa.name())).unwrap();
        let mut got = vec![0.0f32; m * n];
        kernel::matmul(policy, &mut got, &a, &b, m, n, k);
        nanokernel::verify_fma_relaxed(&got, &want, &a, &b, &zeros, None, m, n, k)
            .unwrap_or_else(|e| panic!("{isa:?}: {e}"));
    }
}
