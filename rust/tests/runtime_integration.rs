//! Integration: PJRT runtime over real built artifacts.
//!
//! These tests require `make artifacts` to have run; they skip (pass
//! trivially with a note) when the manifest is missing so `cargo test`
//! stays meaningful on a fresh checkout.

use std::path::PathBuf;

use mlir_gemm::runtime::{ArtifactKind, Runtime, Tensor};
use mlir_gemm::util::prng::Rng;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

macro_rules! require_artifacts {
    () => {
        match artifacts_dir() {
            Some(d) => d,
            None => {
                eprintln!("skipping: artifacts not built (run `make artifacts`)");
                return;
            }
        }
    };
}

/// Host-side reference matmul C = A@B + C (f64 accumulate).
fn ref_matmul(m: usize, n: usize, k: usize, a: &[f32], b: &[f32], c: &[f32]) -> Vec<f32> {
    let mut out = vec![0f32; m * n];
    for i in 0..m {
        for j in 0..n {
            let mut acc = c[i * n + j] as f64;
            for kk in 0..k {
                acc += a[i * k + kk] as f64 * b[kk * n + j] as f64;
            }
            out[i * n + j] = acc as f32;
        }
    }
    out
}

fn rel_err(got: &[f32], want: &[f32]) -> f64 {
    let mut num = 0f64;
    let mut den = 0f64;
    for (g, w) in got.iter().zip(want) {
        num += ((g - w) as f64).powi(2);
        den += (*w as f64).powi(2);
    }
    (num / den.max(1e-30)).sqrt()
}

#[test]
fn manifest_loads_and_covers_every_kind() {
    let dir = require_artifacts!();
    let rt = Runtime::open(&dir).unwrap();
    let kinds: std::collections::HashSet<_> =
        rt.artifacts().iter().map(|a| a.kind).collect();
    for want in [
        ArtifactKind::Generated,
        ArtifactKind::Baseline,
        ArtifactKind::Ablation,
        ArtifactKind::Fused,
        ArtifactKind::Unfused,
        ArtifactKind::Hand,
        ArtifactKind::Transformer,
    ] {
        assert!(kinds.contains(&want), "missing artifact kind {want:?}");
    }
}

#[test]
fn generated_kernel_matches_host_reference() {
    let dir = require_artifacts!();
    let rt = Runtime::open(&dir).unwrap();
    let meta = rt
        .artifacts()
        .iter()
        .find(|a| a.kind == ArtifactKind::Generated && a.problem == Some((256, 256, 256)))
        .expect("256^3 generated artifact")
        .clone();
    let (m, n, k) = (256, 256, 256);
    let mut rng = Rng::new(1);
    let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32 * 0.5).collect();
    let b: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32 * 0.5).collect();
    let c: Vec<f32> = (0..m * n).map(|_| rng.normal() as f32 * 0.5).collect();
    let out = rt
        .execute(
            &meta.name,
            &[
                Tensor::new(vec![m, k], a.clone()).unwrap(),
                Tensor::new(vec![k, n], b.clone()).unwrap(),
                Tensor::new(vec![m, n], c.clone()).unwrap(),
            ],
        )
        .unwrap();
    let want = ref_matmul(m, n, k, &a, &b, &c);
    let err = rel_err(&out[0].data, &want);
    // f16 inputs with f32 accumulate at K=256
    assert!(err < 5e-3, "relative error {err}");
}

#[test]
fn generated_agrees_with_library_baseline() {
    let dir = require_artifacts!();
    let rt = Runtime::open(&dir).unwrap();
    let generated = rt
        .artifacts()
        .iter()
        .find(|a| a.kind == ArtifactKind::Generated && a.problem == Some((256, 256, 256)))
        .unwrap()
        .clone();
    let baseline = rt
        .artifacts()
        .iter()
        .find(|a| {
            a.kind == ArtifactKind::Baseline
                && a.problem == Some((256, 256, 256))
                && a.dtype_acc == Some(mlir_gemm::schedule::Dtype::F32)
        })
        .unwrap()
        .clone();
    let mut rng = Rng::new(2);
    let inputs = vec![
        Tensor::new(vec![256, 256], rng.normal_matrix(256, 256)).unwrap(),
        Tensor::new(vec![256, 256], rng.normal_matrix(256, 256)).unwrap(),
        Tensor::new(vec![256, 256], rng.normal_matrix(256, 256)).unwrap(),
    ];
    let ours = rt.execute(&generated.name, &inputs).unwrap();
    let libr = rt.execute(&baseline.name, &inputs).unwrap();
    let err = rel_err(&ours[0].data, &libr[0].data);
    assert!(err < 1e-3, "ours vs library relative error {err}");
}

#[test]
fn every_ablation_level_is_numerically_equivalent() {
    let dir = require_artifacts!();
    let rt = Runtime::open(&dir).unwrap();
    let mut ablations: Vec<_> = rt
        .artifacts()
        .iter()
        .filter(|a| a.kind == ArtifactKind::Ablation)
        .cloned()
        .collect();
    ablations.sort_by_key(|a| a.schedule.as_ref().unwrap().opt_level);
    assert_eq!(ablations.len(), 8, "expected the 8-level ladder");

    let (m, n, k) = ablations[0].problem.unwrap();
    let mut rng = Rng::new(3);
    let inputs = vec![
        Tensor::new(vec![m, k], rng.normal_matrix(m, k)).unwrap(),
        Tensor::new(vec![k, n], rng.normal_matrix(k, n)).unwrap(),
        Tensor::new(vec![m, n], rng.normal_matrix(m, n)).unwrap(),
    ];
    let reference = rt.execute(&ablations[7].name, &inputs).unwrap();
    for abl in &ablations[..7] {
        let out = rt.execute(&abl.name, &inputs).unwrap();
        let err = rel_err(&out[0].data, &reference[0].data);
        assert!(
            err < 2e-3,
            "ablation {} diverges from full pipeline: {err}",
            abl.name
        );
    }
}

#[test]
fn fused_equals_unfused_epilogue() {
    let dir = require_artifacts!();
    let rt = Runtime::open(&dir).unwrap();
    let fused = rt
        .artifacts()
        .iter()
        .find(|a| a.kind == ArtifactKind::Fused)
        .unwrap()
        .clone();
    let unfused = rt
        .artifacts()
        .iter()
        .find(|a| a.kind == ArtifactKind::Unfused)
        .unwrap()
        .clone();
    assert_eq!(fused.problem, unfused.problem);
    let (m, n, k) = fused.problem.unwrap();
    let mut rng = Rng::new(4);
    let inputs = vec![
        Tensor::new(vec![m, k], rng.normal_matrix(m, k)).unwrap(),
        Tensor::new(vec![k, n], rng.normal_matrix(k, n)).unwrap(),
        Tensor::new(vec![m, n], rng.normal_matrix(m, n)).unwrap(),
        Tensor::new(vec![n], rng.normal_matrix(1, n)).unwrap(),
    ];
    let f = rt.execute(&fused.name, &inputs).unwrap();
    let u = rt.execute(&unfused.name, &inputs).unwrap();
    let err = rel_err(&f[0].data, &u[0].data);
    assert!(err < 2e-3, "fused vs unfused relative error {err}");
    assert!(f[0].data.iter().all(|&x| x >= 0.0), "ReLU output has negatives");
}

#[test]
fn transformer_layer_executes_with_finite_output() {
    let dir = require_artifacts!();
    let rt = Runtime::open(&dir).unwrap();
    let meta = rt
        .artifacts()
        .iter()
        .find(|a| a.kind == ArtifactKind::Transformer)
        .unwrap()
        .clone();
    let mut rng = Rng::new(5);
    let inputs: Vec<Tensor> = meta
        .inputs
        .iter()
        .map(|spec| {
            let data: Vec<f32> = (0..spec.elements())
                .map(|_| rng.normal() as f32 * 0.1)
                .collect();
            Tensor { shape: spec.shape.clone(), data }
        })
        .collect();
    let out = rt.execute(&meta.name, &inputs).unwrap();
    assert_eq!(out[0].shape, meta.outputs[0].shape);
    assert!(out[0].data.iter().all(|x| x.is_finite()));
    let norm: f64 = out[0].data.iter().map(|&x| (x as f64).powi(2)).sum::<f64>();
    assert!(norm > 0.0);
}

#[test]
fn executable_cache_reuses_compilations() {
    let dir = require_artifacts!();
    let rt = Runtime::open(&dir).unwrap();
    let name = &rt
        .artifacts()
        .iter()
        .find(|a| a.kind == ArtifactKind::Baseline)
        .unwrap()
        .name
        .clone();
    let a1 = rt.load(name).unwrap();
    let a2 = rt.load(name).unwrap();
    assert!(std::sync::Arc::ptr_eq(&a1, &a2));
}

#[test]
fn shape_mismatch_is_rejected() {
    let dir = require_artifacts!();
    let rt = Runtime::open(&dir).unwrap();
    let meta = rt
        .artifacts()
        .iter()
        .find(|a| a.kind == ArtifactKind::Baseline)
        .unwrap()
        .clone();
    let bad = vec![Tensor::zeros(vec![2, 2]); meta.inputs.len()];
    let err = rt.execute(&meta.name, &bad).unwrap_err();
    assert!(err.to_string().contains("does not match"));
}

#[test]
fn unknown_artifact_is_rejected() {
    let dir = require_artifacts!();
    let rt = Runtime::open(&dir).unwrap();
    assert!(rt.execute("no_such_kernel", &[]).is_err());
}
