//! The `fma_relaxed` numerics-class contract, exercised end to end.
//!
//! `bit_exact` plans are covered by the fuzz-differential and
//! kernel-equivalence suites (bitwise identity to the naive i-k-j
//! oracle).  This harness is the other half of the contract: every
//! nanokernel result must sit within the condition-scaled tolerance
//!
//!   |got - want| <= 2 * gamma(k+2) * scale + tiny
//!   scale[i,j] = |c[i,j]| + sum_p |a[i,p]| * |b[p,j]| (+ |bias[j]|)
//!
//! derived in DESIGN.md §10, over the same shape family the fuzz
//! differential sweeps (ragged panels, degenerate dims, unit rows).
//! It also pins the structural invariants that hold even under FMA:
//! threading and prepacking never touch per-element operation order, so
//! threaded-SIMD and prepacked-SIMD remain bitwise identical to the
//! plain SIMD run — the tolerance is spent on FMA contraction only.

use mlir_gemm::plan::{compile, GemmKey, NumericsClass, PlanEnv, PlanOverride};
use mlir_gemm::runtime::kernel::{self, BOperand, Blocking, KernelPolicy, PrepackedB};
use mlir_gemm::runtime::nanokernel::{self, Isa};
use mlir_gemm::util::prng::Rng;

/// The fuzz differential's hand-picked adversarial shapes: unit dims,
/// single-row/column panels, blocks that straddle every tile boundary
/// of the default 8/4/16 test blocking, plus the 16+8+scalar j-ladder.
const SPECIAL: &[(usize, usize, usize)] = &[
    (1, 1, 1),
    (1, 17, 5),
    (19, 1, 7),
    (4, 16, 8),
    (5, 17, 9),
    (33, 7, 21),
    (40, 40, 40),
    (4, 35, 12),
];

/// ISAs every host can execute: the portable body always, AVX2 when the
/// hardware is really there (it degrades to portable otherwise, which
/// would silently test the same body twice).
fn testable_isas() -> Vec<Isa> {
    let mut isas = vec![Isa::Portable];
    if nanokernel::hw_available(Isa::Avx2Fma) {
        isas.push(Isa::Avx2Fma);
    }
    isas
}

fn naive_with_seed(c: &[f32], a: &[f32], b: &[f32], m: usize, n: usize, k: usize) -> Vec<f32> {
    let mut want = c.to_vec();
    kernel::matmul(KernelPolicy::Naive, &mut want, a, b, m, n, k);
    want
}

#[test]
fn every_nanokernel_meets_the_tolerance_over_the_fuzz_shape_family() {
    let bs = Blocking { mc: 8, kc: 4, nc: 16 };
    let mut rng = Rng::new(0xF5A2D);
    // The special shapes plus a band of random ones, like the fuzz sweep.
    let mut shapes: Vec<(usize, usize, usize)> = SPECIAL.to_vec();
    for _ in 0..12 {
        let m = 1 + (rng.next_u64() % 48) as usize;
        let n = 1 + (rng.next_u64() % 48) as usize;
        let k = 1 + (rng.next_u64() % 48) as usize;
        shapes.push((m, n, k));
    }
    for &(m, n, k) in &shapes {
        let a = rng.normal_matrix(m, k);
        let b = rng.normal_matrix(k, n);
        // Nonzero C seed: the contract covers the accumulate form.
        let c = rng.normal_matrix(m, n);
        let want = naive_with_seed(&c, &a, &b, m, n, k);
        for isa in testable_isas() {
            for t in [1usize, 3] {
                let mut got = c.clone();
                kernel::matmul(KernelPolicy::Simd(bs, t, isa), &mut got, &a, &b, m, n, k);
                let ulp =
                    nanokernel::verify_fma_relaxed(&got, &want, &a, &b, &c, None, m, n, k)
                        .unwrap_or_else(|e| {
                            panic!("{isa:?} t={t} at {m}x{n}x{k}: {e}")
                        });
                // Small-k products of N(0,1) values cannot legally drift
                // far; a huge ULP count here means a broken kernel that
                // happens to sit under a loose bound.
                assert!(ulp < 1 << 16, "{isa:?} at {m}x{n}x{k}: {ulp} ulp");
            }
        }
    }
}

#[test]
fn threading_and_prepacking_do_not_spend_any_tolerance() {
    // Row banding and panel prepacking reorder *scheduling*, never the
    // per-element operation sequence: under SIMD they stay bitwise
    // identical to the plain single-thread SIMD run.
    let bs = Blocking { mc: 8, kc: 4, nc: 16 };
    let mut rng = Rng::new(0xBEEF);
    for &(m, n, k) in &[(5usize, 17usize, 9usize), (33, 23, 21), (64, 48, 40)] {
        let a = rng.normal_matrix(m, k);
        let b = rng.normal_matrix(k, n);
        for isa in testable_isas() {
            let mut base = vec![0.0f32; m * n];
            kernel::matmul(KernelPolicy::Simd(bs, 1, isa), &mut base, &a, &b, m, n, k);
            let mut threaded = vec![0.0f32; m * n];
            kernel::matmul(KernelPolicy::Simd(bs, 3, isa), &mut threaded, &a, &b, m, n, k);
            assert_eq!(base, threaded, "{isa:?} threading changed bits at {m}x{n}x{k}");
            let packed = PrepackedB::pack(&b, k, n, bs);
            let mut pre = vec![0.0f32; m * n];
            kernel::matmul_b(
                KernelPolicy::Simd(bs, 1, isa),
                &mut pre,
                &a,
                BOperand::Prepacked(&packed),
                m,
                n,
                k,
            );
            assert_eq!(base, pre, "{isa:?} prepacking changed bits at {m}x{n}x{k}");
        }
    }
}

#[test]
fn fused_epilogue_under_simd_honors_the_bias_tolerance() {
    // The fused tail applies bias exactly once per element after the
    // relaxed GEMM; the bias term joins the tolerance scale.
    let bs = Blocking { mc: 8, kc: 4, nc: 16 };
    let mut rng = Rng::new(0xB1A5);
    for &(m, n, k) in &[(5usize, 17usize, 9usize), (33, 7, 21), (40, 40, 40)] {
        let a = rng.normal_matrix(m, k);
        let b = rng.normal_matrix(k, n);
        let bias = rng.normal_matrix(1, n);
        let tail = |out: &mut [f32]| {
            for row in out.chunks_mut(n) {
                for (v, &bv) in row.iter_mut().zip(&bias) {
                    *v += bv;
                }
            }
        };
        let zeros = vec![0.0f32; m * n];
        let mut want = zeros.clone();
        kernel::matmul(KernelPolicy::Naive, &mut want, &a, &b, m, n, k);
        tail(&mut want);
        for isa in testable_isas() {
            let mut got = zeros.clone();
            kernel::matmul_fused(
                KernelPolicy::Simd(bs, 2, isa),
                &mut got,
                &a,
                &b,
                m,
                n,
                k,
                &tail,
            );
            nanokernel::verify_fma_relaxed(
                &got,
                &want,
                &a,
                &b,
                &zeros,
                Some(&bias),
                m,
                n,
                k,
            )
            .unwrap_or_else(|e| panic!("{isa:?} fused at {m}x{n}x{k}: {e}"));
        }
    }
}

#[test]
fn compiled_simd_plans_carry_and_honor_the_fma_relaxed_class() {
    // End to end through the plan compiler: a --plan simd compile yields
    // an fma_relaxed plan whose executed kernel meets the tolerance.
    let env = PlanEnv::pinned().with_force(PlanOverride::Simd);
    let mut rng = Rng::new(0x51D);
    for &(m, n, k) in &[(24usize, 24usize, 24usize), (96, 64, 48), (128, 96, 112)] {
        let plan = compile(&GemmKey::plain(m, n, k), &env).unwrap();
        assert_eq!(plan.numerics, NumericsClass::FmaRelaxed, "{m}x{n}x{k}");
        assert!(plan.isa_label().starts_with("simd:"), "{}", plan.isa_label());
        let a = rng.normal_matrix(m, k);
        let b = rng.normal_matrix(k, n);
        let zeros = vec![0.0f32; m * n];
        let mut got = zeros.clone();
        kernel::matmul(plan.kernel, &mut got, &a, &b, m, n, k);
        let want = naive_with_seed(&zeros, &a, &b, m, n, k);
        nanokernel::verify_fma_relaxed(&got, &want, &a, &b, &zeros, None, m, n, k)
            .unwrap_or_else(|e| panic!("plan {} at {m}x{n}x{k}: {e}", plan.id()));
    }
}
