//! Graph-level `ProgramPlan` integration pins.
//!
//! The transformer program no longer executes through a hand loop: it
//! compiles a whole-program plan (op-graph extraction, cast hoisting,
//! lifetime-based buffer reuse, pipeline decisions) and both the inline
//! and weight-bound paths execute under it.  This suite pins the
//! contract at the integration level:
//!
//! * the plan is a first-class value — JSON round-trippable, with the
//!   graph passes' decisions golden-pinned for the standard shape;
//! * with the pipeline passes in their default conservative setting the
//!   planned output is bit-identical to the seed hand-loop oracle,
//!   inline and weight-bound;
//! * cast hoisting is observable: the QKV projections share exactly one
//!   A-operand cast (counted at the executor, recorded in the plan);
//! * a server interleaving a transformer variant with a plain GEMM
//!   variant attributes work to the two plans separately.

use std::sync::Arc;
use std::time::Duration;

use mlir_gemm::coordinator::{
    GemmKey, GemmRequest, ProgramRequest, Server, ServerConfig,
};
use mlir_gemm::plan::program::ProgramPlan;
use mlir_gemm::plan::{NumericsClass, PlanEnv, PlanOverride};
use mlir_gemm::runtime::kernel::{Blocking, KernelPolicy};
use mlir_gemm::runtime::{exec, Program, Runtime, Tensor};
use mlir_gemm::schedule::Dtype;
use mlir_gemm::util::prng::Rng;

/// The standard transformer shape every pin below uses (the exec.rs
/// suite's shape: 4 heads of width 4, FFN expansion 2x).
const SEQ: usize = 8;
const D_MODEL: usize = 16;
const D_FF: usize = 32;
const N_HEADS: usize = 4;

fn program(dtype_in: Dtype) -> Program {
    Program::Transformer {
        seq: SEQ,
        d_model: D_MODEL,
        d_ff: D_FF,
        n_heads: N_HEADS,
        dtype_in,
    }
}

fn inputs(seed: u64) -> Vec<Tensor> {
    let mut rng = Rng::new(seed);
    let mut mk = |shape: Vec<usize>| {
        let n: usize = shape.iter().product();
        let data: Vec<f32> = (0..n).map(|_| rng.normal() as f32 * 0.1).collect();
        Tensor { shape, data }
    };
    vec![
        mk(vec![SEQ, D_MODEL]),
        mk(vec![D_MODEL, 3 * D_MODEL]),
        mk(vec![D_MODEL, D_MODEL]),
        mk(vec![D_MODEL, D_FF]),
        mk(vec![D_FF]),
        mk(vec![D_FF, D_MODEL]),
        mk(vec![D_MODEL]),
    ]
}

// ---------------------------------------------------------------------------
// First-class value: JSON round-trip
// ---------------------------------------------------------------------------

#[test]
fn program_plan_round_trips_through_json() {
    for &dtype_in in &[Dtype::F16, Dtype::F32] {
        let pplan = program(dtype_in)
            .compile_program_plan(&PlanEnv::pinned())
            .unwrap();
        let text = pplan.to_json().to_string();
        let back = ProgramPlan::from_text(&text).unwrap();
        assert_eq!(back, pplan, "round trip dropped state for {dtype_in:?}");
        assert_eq!(back.to_json().to_string(), text, "re-serialization drifted");
    }
    // A document whose stated numerics contradict its op plans must be
    // rejected — a plan cannot promise bit-exactness its kernels break.
    let text = program(Dtype::F16)
        .compile_program_plan(&PlanEnv::pinned())
        .unwrap()
        .to_json()
        .to_string();
    // Keys serialize sorted, so the first "numerics" is the program-level
    // one ("numerics" < "ops"); the op plans keep claiming bit_exact.
    let lied = text.replacen("\"numerics\":\"bit_exact\"", "\"numerics\":\"fma_relaxed\"", 1);
    assert!(ProgramPlan::from_text(&lied).is_err());
}

// ---------------------------------------------------------------------------
// Golden: graph-pass decisions for the standard shape under the pinned env
// (decision pin, same idiom as golden/plan_*.json; see golden/README.md)
// ---------------------------------------------------------------------------

const GOLDEN: &str = include_str!("golden/program_plan_8x16x32x4_f16.json");

#[test]
fn golden_program_plan_for_the_standard_transformer_shape() {
    let golden = ProgramPlan::from_text(GOLDEN).unwrap();
    let compiled = program(Dtype::F16)
        .compile_program_plan(&PlanEnv::pinned())
        .unwrap();
    assert_eq!(compiled.id(), golden.id());
    assert_eq!(compiled.numerics, golden.numerics, "program numerics drifted");
    assert_eq!(compiled.ops.len(), golden.ops.len(), "op-graph extraction drifted");
    for (c, g) in compiled.ops.iter().zip(&golden.ops) {
        assert_eq!(c.name, g.name, "op order drifted");
        assert_eq!(c.count, g.count, "gemm count drifted for op {}", c.name);
        assert_eq!(
            (c.plan.m, c.plan.n, c.plan.k, c.plan.dtype_in),
            (g.plan.m, g.plan.n, g.plan.k, g.plan.dtype_in),
            "lowered shape drifted for op {}",
            c.name
        );
        assert_eq!(
            c.plan.kernel.name(),
            g.plan.kernel.name(),
            "kernel decision drifted for op {}",
            c.name
        );
        assert_eq!(
            c.plan.numerics, g.plan.numerics,
            "numerics class drifted for op {}",
            c.name
        );
    }
    assert_eq!(compiled.cast_hoists, golden.cast_hoists, "cast-hoist pass drifted");
    assert_eq!(compiled.arena, golden.arena, "buffer-reuse pass drifted");
    assert_eq!(compiled.pipeline, golden.pipeline, "pipeline pass drifted");
    // Provenance: the compiled plan records all four graph passes (the
    // golden pins decisions, not prose).
    for pass in ["op-graph", "cast-hoist", "buffer-reuse", "pipeline"] {
        assert!(
            compiled.trace.iter().any(|t| t.pass == pass),
            "missing trace entry for pass {pass:?}"
        );
    }
}

// ---------------------------------------------------------------------------
// Bit-exactness: planned output == seed hand-loop oracle, inline + bound
// ---------------------------------------------------------------------------

#[test]
fn planned_transformer_is_bit_identical_to_the_seed_oracle() {
    let envs = vec![
        PlanEnv::default(),
        PlanEnv::pinned(),
        PlanEnv::pinned().with_force(PlanOverride::Force(KernelPolicy::Tiled(
            Blocking { mc: 8, kc: 4, nc: 16 },
        ))),
    ];
    for &dtype_in in &[Dtype::F16, Dtype::F32] {
        let p = program(dtype_in);
        let ins = inputs(0x5EED);
        for env in &envs {
            let seed = p.execute_transformer_seed(&ins, env).unwrap();
            let pplan = p.compile_program_plan(env).unwrap();
            assert_eq!(pplan.numerics, NumericsClass::BitExact);

            let planned = p.execute_program_planned(&ins, &pplan).unwrap();
            assert_eq!(seed[0].shape, planned[0].shape);
            for (i, (w, g)) in seed[0].data.iter().zip(&planned[0].data).enumerate() {
                assert_eq!(
                    w.to_bits(),
                    g.to_bits(),
                    "inline planned drifted from seed at {i} ({dtype_in:?})"
                );
            }

            let bound = p.bind_transformer_weights(&ins[1..], env).unwrap();
            assert_eq!(bound.program_plan(), &pplan, "bind compiled a different plan");
            let got = p.execute_transformer_bound(&ins[0], &bound).unwrap();
            for (i, (w, g)) in seed[0].data.iter().zip(&got[0].data).enumerate() {
                assert_eq!(
                    w.to_bits(),
                    g.to_bits(),
                    "bound planned drifted from seed at {i} ({dtype_in:?})"
                );
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Cast hoisting: QKV shares exactly one A cast
// ---------------------------------------------------------------------------

#[test]
fn qkv_projections_share_exactly_one_hoisted_activation_cast() {
    // f16: the plan records the hoist (one shared x cast feeds q/k/v,
    // saving two of the three per-projection casts) and the executor
    // performs exactly four activation casts in total: x (shared by the
    // fused QKV gemm), ctx, hn, and up.
    let p = program(Dtype::F16);
    let pplan = p.compile_program_plan(&PlanEnv::pinned()).unwrap();
    assert_eq!(pplan.cast_hoists.len(), 1);
    let h = &pplan.cast_hoists[0];
    assert_eq!(h.operand, "x");
    assert_eq!(h.users, vec!["q", "k", "v"]);
    assert_eq!(h.casts_saved, 2);
    let hoist_trace = pplan
        .trace
        .iter()
        .find(|t| t.pass == "cast-hoist")
        .expect("cast-hoist pass must be traced");
    assert!(
        hoist_trace.decision.contains("1 shared"),
        "trace decision {:?} does not record the shared cast",
        hoist_trace.decision
    );
    p.execute_program_planned(&inputs(7), &pplan).unwrap();
    assert_eq!(
        exec::transformer_activation_casts(),
        4,
        "planned f16 execution must cast exactly x, ctx, hn, up"
    );

    // f32: nothing to hoist, nothing cast.
    let p32 = program(Dtype::F32);
    let pplan32 = p32.compile_program_plan(&PlanEnv::pinned()).unwrap();
    assert!(pplan32.cast_hoists.is_empty());
    p32.execute_program_planned(&inputs(7), &pplan32).unwrap();
    assert_eq!(exec::transformer_activation_casts(), 0);
}

// ---------------------------------------------------------------------------
// Server: transformer variant + plain GEMM variant, interleaved, with
// separate per-plan attribution
// ---------------------------------------------------------------------------

const MANIFEST: &str = r#"{
  "version": 1,
  "artifacts": [
    {
      "name": "small",
      "file": "small.tprog.json",
      "kind": "baseline",
      "inputs": [
        {"shape": [24, 24], "dtype": "f32"},
        {"shape": [24, 24], "dtype": "f32"},
        {"shape": [24, 24], "dtype": "f32"}
      ],
      "outputs": [{"shape": [24, 24], "dtype": "f32"}],
      "m": 24, "n": 24, "k": 24, "dtype_in": "f32", "dtype_acc": "f32"
    },
    {
      "name": "tf_layer",
      "file": "tf_layer.tprog.json",
      "kind": "transformer",
      "inputs": [
        {"shape": [8, 16], "dtype": "f32"},
        {"shape": [16, 48], "dtype": "f32"},
        {"shape": [16, 16], "dtype": "f32"},
        {"shape": [16, 32], "dtype": "f32"},
        {"shape": [32], "dtype": "f32"},
        {"shape": [32, 16], "dtype": "f32"},
        {"shape": [16], "dtype": "f32"}
      ],
      "outputs": [{"shape": [8, 16], "dtype": "f32"}],
      "seq": 8, "d_model": 16, "d_ff": 32
    }
  ]
}"#;

const SMALL: &str = r#"{
  "format": "mlir-gemm-tprog-v1",
  "name": "small",
  "program": {
    "type": "gemm", "m": 24, "n": 24, "k": 24,
    "dtype_in": "f32", "dtype_acc": "f32", "epilogue": "none", "fused": true
  }
}"#;

const TF: &str = r#"{
  "format": "mlir-gemm-tprog-v1",
  "name": "tf_layer",
  "program": {
    "type": "transformer",
    "seq": 8, "d_model": 16, "d_ff": 32, "n_heads": 4, "dtype_in": "f16"
  }
}"#;

#[test]
fn server_interleaves_transformer_and_gemm_with_separate_plan_metrics() {
    let dir = std::env::temp_dir()
        .join(format!("mlir_gemm_program_plan_srv_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.json"), MANIFEST).unwrap();
    std::fs::write(dir.join("small.tprog.json"), SMALL).unwrap();
    std::fs::write(dir.join("tf_layer.tprog.json"), TF).unwrap();

    let rt = Arc::new(Runtime::open(&dir).unwrap());
    // What the server must serve: the load-time compiled ProgramPlan (the
    // same Arc route_program caches in the registry).
    let tf_artifact = rt.load("tf_layer").unwrap();
    let tf_pplan = tf_artifact.program_plan().expect("transformer compiles a plan");
    let gemm_key = GemmKey::with_dtypes(24, 24, 24, Dtype::F32, Dtype::F32);

    let mut server = Server::start(
        rt.clone(),
        &mlir_gemm::sim::DeviceModel::rtx3090(),
        ServerConfig { workers: 3, ..Default::default() },
    );
    let gemm_plan = server.registry().plan(&gemm_key).unwrap();

    let per_side = 8usize;
    let mut rng = Rng::new(0x17E);
    let mut pending = Vec::new();
    for i in 0..2 * per_side {
        if i % 2 == 0 {
            let ins = inputs(1000 + i as u64);
            let want = tf_artifact
                .program()
                .execute_program_planned(&ins, tf_pplan)
                .unwrap();
            let rx = server.submit_program(ProgramRequest {
                artifact: "tf_layer".to_string(),
                inputs: ins,
            });
            pending.push((vec![8usize, 16], want[0].data.clone(), rx));
        } else {
            let a = Tensor::new(vec![24, 24], rng.normal_matrix(24, 24)).unwrap();
            let b = Tensor::new(vec![24, 24], rng.normal_matrix(24, 24)).unwrap();
            let c = Tensor::new(vec![24, 24], rng.normal_matrix(24, 24)).unwrap();
            let mut want = c.data.clone();
            mlir_gemm::runtime::kernel::matmul(
                KernelPolicy::Naive,
                &mut want,
                &a.data,
                &b.data,
                24,
                24,
                24,
            );
            let rx = server.submit(GemmRequest {
                key: gemm_key.clone(),
                a,
                b: Some(b),
                c,
                bias: None,
                use_baseline: true,
                deadline: None,
            });
            pending.push((vec![24usize, 24], want, rx));
        }
    }
    for (shape, want, rx) in pending {
        let resp = rx.recv_timeout(Duration::from_secs(120)).unwrap();
        let out = resp.output.expect("request should succeed");
        assert_eq!(out.shape, shape);
        assert_eq!(out.data, want, "served {shape:?} output drifted");
    }
    let m = server.shutdown();
    assert_eq!(m.completed, 2 * per_side as u64);
    assert_eq!(m.failed, 0);
    // Separate attribution: the transformer's work lands under the
    // program plan's id, the GEMM's under its execution plan's id.
    assert_eq!(
        m.per_plan.get(&tf_pplan.id()).map(|l| l.requests),
        Some(per_side as u64),
        "per_plan: {:?}",
        m.per_plan
    );
    assert_eq!(
        m.per_plan.get(&gemm_plan.id()).map(|l| l.requests),
        Some(per_side as u64),
        "per_plan: {:?}",
        m.per_plan
    );
    let _ = std::fs::remove_dir_all(&dir);
}
