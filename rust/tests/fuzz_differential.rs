//! Differential fuzz sweep: every execution form the stack offers —
//! planned single-call, weight-bound (prepacked), batched, bound-batched,
//! row-sharded, and bound-row-sharded — must be **bit-identical** to a
//! self-contained naive i-k-j reference (cast inputs, accumulate in
//! increasing-k order, epilogue once per element, round to the
//! accumulate dtype), across shapes (including 1x1x1, skinny, ragged),
//! dtype pairs, epilogues, and plan overrides.
//!
//! Deterministic: the whole sweep derives from one xoshiro seed, pinned
//! by default and overridable with `MLIR_GEMM_FUZZ_SEED=<decimal>` for
//! replay (`make fuzz`).  Every assertion failure prints the seed and
//! case index.

use std::sync::Arc;

use mlir_gemm::coordinator::sharding::{
    build_shard_tasks, build_shard_tasks_bound, execute_shard, reduce_outputs,
    ShardPlan,
};
use mlir_gemm::plan::{compile, GemmKey, NumericsClass, PlanEnv, PlanOverride};
use mlir_gemm::runtime::exec::round_to;
use mlir_gemm::runtime::{nanokernel, Epilogue, Program, Tensor};
use mlir_gemm::schedule::Dtype;
use mlir_gemm::util::prng::Rng;

/// Pinned sweep seed (CI runs exactly this); override for replay.
const DEFAULT_SEED: u64 = 0xF5A2D;

fn sweep_seed() -> u64 {
    std::env::var("MLIR_GEMM_FUZZ_SEED")
        .ok()
        .and_then(|v| v.parse::<u64>().ok())
        .unwrap_or(DEFAULT_SEED)
}

/// The oracle: naive i-k-j with the executor's exact precision
/// semantics — inputs rounded to `dtype_in`, C rounded to `dtype_acc`,
/// f32 accumulation in increasing-k order, epilogue applied once per
/// element after the full reduction, output rounded to `dtype_acc`.
#[allow(clippy::too_many_arguments)]
fn reference(
    m: usize,
    n: usize,
    k: usize,
    dtype_in: Dtype,
    dtype_acc: Dtype,
    epilogue: Epilogue,
    a: &[f32],
    b: &[f32],
    c: &[f32],
    bias: Option<&[f32]>,
) -> Vec<f32> {
    let cast = |d: Dtype, v: &[f32]| -> Vec<f32> {
        v.iter().map(|&x| round_to(d, x)).collect()
    };
    let a = cast(dtype_in, a);
    let b = cast(dtype_in, b);
    let mut acc = cast(dtype_acc, c);
    for i in 0..m {
        for kk in 0..k {
            let av = a[i * k + kk];
            for j in 0..n {
                acc[i * n + j] += av * b[kk * n + j];
            }
        }
    }
    match (epilogue, bias) {
        (Epilogue::Bias, Some(bv)) => {
            for row in acc.chunks_mut(n) {
                for (v, &b) in row.iter_mut().zip(bv) {
                    *v += b;
                }
            }
        }
        (Epilogue::BiasRelu, Some(bv)) => {
            for row in acc.chunks_mut(n) {
                for (v, &b) in row.iter_mut().zip(bv) {
                    *v = (*v + b).max(0.0);
                }
            }
        }
        _ => {}
    }
    for v in acc.iter_mut() {
        *v = round_to(dtype_acc, *v);
    }
    acc
}

fn assert_bits(label: &str, seed: u64, case: usize, want: &[f32], got: &[f32]) {
    assert_eq!(
        want.len(),
        got.len(),
        "fuzz case {case} [{label}]: length {} vs {}; replay with \
         MLIR_GEMM_FUZZ_SEED={seed}",
        want.len(),
        got.len()
    );
    for (i, (w, g)) in want.iter().zip(got).enumerate() {
        assert_eq!(
            w.to_bits(),
            g.to_bits(),
            "fuzz case {case} [{label}] drifted at element {i}: {w} vs {g}; \
             replay with MLIR_GEMM_FUZZ_SEED={seed}"
        );
    }
}

struct Case {
    m: usize,
    n: usize,
    k: usize,
    dtype_in: Dtype,
    dtype_acc: Dtype,
    epilogue: Epilogue,
    env: PlanEnv,
}

fn env_for(case_idx: usize) -> PlanEnv {
    match case_idx % 4 {
        0 => PlanEnv::pinned(),
        1 => PlanEnv::pinned().with_force(PlanOverride::parse("naive").unwrap()),
        2 => PlanEnv::pinned().with_force(PlanOverride::parse("tiled:8,4,16").unwrap()),
        _ => PlanEnv::pinned()
            .with_force(PlanOverride::parse("threaded:8,8,16,2").unwrap()),
    }
}

fn case_for(rng: &mut Rng, case_idx: usize) -> Case {
    const SPECIAL: &[(usize, usize, usize)] = &[
        (1, 1, 1),
        (1, 17, 5),
        (19, 1, 7),
        (4, 16, 8),
        (5, 17, 9),
        (33, 7, 21),
        (40, 40, 40),
    ];
    let (m, n, k) = if case_idx < SPECIAL.len() {
        SPECIAL[case_idx]
    } else if case_idx % 12 == 11 {
        // Large enough that the auto pipeline compiles a packing
        // (prepacking) kernel: operand footprint past L2/2.
        (100 + rng.below(21), 100 + rng.below(21), 100 + rng.below(21))
    } else {
        (1 + rng.below(40), 1 + rng.below(40), 1 + rng.below(40))
    };
    let dtypes = [
        (Dtype::F32, Dtype::F32),
        (Dtype::F16, Dtype::F32),
        (Dtype::F16, Dtype::F16),
        (Dtype::Bf16, Dtype::F32),
    ];
    let (dtype_in, dtype_acc) = dtypes[rng.below(dtypes.len())];
    let epilogue = [Epilogue::None, Epilogue::Bias, Epilogue::BiasRelu]
        [rng.below(3)];
    Case { m, n, k, dtype_in, dtype_acc, epilogue, env: env_for(case_idx) }
}

#[test]
fn fuzz_differential_sweep() {
    let seed = sweep_seed();
    let mut rng = Rng::new(seed);
    let n_cases = 200usize;
    for case_idx in 0..n_cases {
        let case = case_for(&mut rng, case_idx);
        let Case { m, n, k, dtype_in, dtype_acc, epilogue, ref env } = case;
        let key = GemmKey {
            m,
            n,
            k,
            dtype_in,
            dtype_acc,
            epilogue: epilogue.name().to_string(),
        };
        let program = Program::Gemm {
            m,
            n,
            k,
            dtype_in,
            dtype_acc,
            epilogue,
            fused: true,
        };
        let eplan = compile(&key, env).unwrap();

        let a = rng.normal_matrix(m, k);
        let b = rng.normal_matrix(k, n);
        let c = rng.normal_matrix(m, n);
        let bias_vec =
            epilogue.needs_bias().then(|| rng.normal_matrix(1, n));
        let want = reference(
            m,
            n,
            k,
            dtype_in,
            dtype_acc,
            epilogue,
            &a,
            &b,
            &c,
            bias_vec.as_deref(),
        );

        let a_t = Tensor { shape: vec![m, k], data: a.clone() };
        let b_t = Tensor { shape: vec![k, n], data: b.clone() };
        let c_t = Tensor { shape: vec![m, n], data: c.clone() };
        let bias_t = bias_vec
            .as_ref()
            .map(|v| Tensor { shape: vec![n], data: v.clone() });

        // 1. planned single-call execution
        let mut inline_inputs = vec![a_t.clone(), b_t.clone(), c_t.clone()];
        if let Some(bt) = &bias_t {
            inline_inputs.push(bt.clone());
        }
        let got = program.execute_planned(&inline_inputs, &eplan).unwrap();
        assert_bits("planned", seed, case_idx, &want, &got[0].data);

        // 2. weight-bound (prepacked when the plan says so)
        let bound = Arc::new(program.bind_b(&b_t, &eplan).unwrap());
        let mut bound_inputs = vec![a_t.clone(), c_t.clone()];
        if let Some(bt) = &bias_t {
            bound_inputs.push(bt.clone());
        }
        let got = program
            .execute_planned_bound(&bound_inputs, &eplan, &bound)
            .unwrap();
        let label = if bound.is_prepacked() { "bound+prepacked" } else { "bound" };
        assert_bits(label, seed, case_idx, &want, &got[0].data);

        // Large cases stop here (the remaining forms recompute the same
        // kernels; keep the sweep cheap enough for CI).
        if m * n * k > 64 * 64 * 64 {
            continue;
        }

        // 3. batched + bound-batched: three items sharing the bound B.
        if case_idx % 3 == 0 {
            let mut items_inline = vec![inline_inputs.clone()];
            let mut items_bound = vec![bound_inputs.clone()];
            let mut wants = vec![want.clone()];
            for _ in 0..2 {
                let a2 = rng.normal_matrix(m, k);
                let c2 = rng.normal_matrix(m, n);
                wants.push(reference(
                    m,
                    n,
                    k,
                    dtype_in,
                    dtype_acc,
                    epilogue,
                    &a2,
                    &b,
                    &c2,
                    bias_vec.as_deref(),
                ));
                let a2_t = Tensor { shape: vec![m, k], data: a2 };
                let c2_t = Tensor { shape: vec![m, n], data: c2 };
                let mut inline_item = vec![a2_t.clone(), b_t.clone(), c2_t.clone()];
                let mut bound_item = vec![a2_t, c2_t];
                if let Some(bt) = &bias_t {
                    inline_item.push(bt.clone());
                    bound_item.push(bt.clone());
                }
                items_inline.push(inline_item);
                items_bound.push(bound_item);
            }
            let outs = program.execute_batch_planned(&items_inline, &eplan).unwrap();
            for (bi, out) in outs.iter().enumerate() {
                assert_bits(
                    &format!("batched[{bi}]"),
                    seed,
                    case_idx,
                    &wants[bi],
                    &out[0].data,
                );
            }
            let outs = program
                .execute_batch_planned_bound(&items_bound, &eplan, &bound)
                .unwrap();
            for (bi, out) in outs.iter().enumerate() {
                assert_bits(
                    &format!("bound-batched[{bi}]"),
                    seed,
                    case_idx,
                    &wants[bi],
                    &out[0].data,
                );
            }
        }

        // 4. row-sharded + bound-row-sharded (bit-identical contract).
        if case_idx % 4 == 0 && m >= 2 {
            let splan = ShardPlan::rows(m, n, k, 3, 1);
            let parts: Vec<Tensor> =
                build_shard_tasks(env, &splan, &program, &a_t, &b_t, &c_t, bias_t.as_ref())
                    .unwrap()
                    .into_iter()
                    .map(|(prog, sp, inputs)| {
                        execute_shard(&prog, &sp, &inputs, None).unwrap()
                    })
                    .collect();
            let got =
                reduce_outputs(&splan, &program, &c_t, bias_t.as_ref(), &parts).unwrap();
            assert_bits("row-sharded", seed, case_idx, &want, &got.data);

            let parts: Vec<Tensor> = build_shard_tasks_bound(
                env,
                &splan,
                &program,
                &a_t,
                &c_t,
                bias_t.as_ref(),
                &bound,
            )
            .unwrap()
            .into_iter()
            .map(|(prog, sp, inputs, tb)| {
                execute_shard(&prog, &sp, &inputs, tb.as_deref()).unwrap()
            })
            .collect();
            let got =
                reduce_outputs(&splan, &program, &c_t, bias_t.as_ref(), &parts).unwrap();
            assert_bits("bound-row-sharded", seed, case_idx, &want, &got.data);
        }
    }
}

/// Assert `got` sits within the DESIGN.md §10 condition-scaled bound of
/// the bit-exact oracle.  Operands are the *dtype_in-cast* values both
/// sides actually consumed — the scale matrix must reflect the reduction
/// that ran, not the pre-cast f32 inputs.
#[allow(clippy::too_many_arguments)]
fn assert_relaxed(
    label: &str,
    seed: u64,
    case: usize,
    want: &[f32],
    got: &[f32],
    a16: &[f32],
    b16: &[f32],
    c: &[f32],
    bias: Option<&[f32]>,
    m: usize,
    n: usize,
    k: usize,
) {
    let ulp = nanokernel::verify_fma_relaxed(got, want, a16, b16, c, bias, m, n, k)
        .unwrap_or_else(|e| {
            panic!(
                "fuzz case {case} [{label}] broke the fma_relaxed contract: {e}; \
                 replay with MLIR_GEMM_FUZZ_SEED={seed}"
            )
        });
    // N(0,1) operands at these k cannot legally drift this far; a huge
    // ULP count is a broken kernel hiding under a loose bound.
    assert!(
        ulp < 1 << 16,
        "fuzz case {case} [{label}]: {ulp} ulp; replay with MLIR_GEMM_FUZZ_SEED={seed}"
    );
}

/// The relaxed half of the contract: the same 200-case execution-form
/// matrix run under a forced `--plan simd` override.  SIMD plans carry
/// the `fma_relaxed` numerics class, so every form — planned,
/// weight-bound (prepacked), batched, bound-batched, row-sharded,
/// bound-row-sharded — is verified against the naive oracle with the
/// DESIGN.md §10 condition-scaled ULP bound instead of bitwise equality.
///
/// Accumulation is pinned to f32 (the bound's derivation dtype): the
/// half-precision-accumulate pairs of the bit-exact sweep re-round every
/// element to f16 on output, which the f32 gamma bound does not model.
#[test]
fn fuzz_differential_fma_relaxed_sweep() {
    let seed = sweep_seed();
    let mut rng = Rng::new(seed);
    let env = PlanEnv::pinned().with_force(PlanOverride::Simd);
    let n_cases = 200usize;
    for case_idx in 0..n_cases {
        // Same shape/epilogue stream as the bit-exact sweep (one rng, same
        // draw order), with the accumulate dtype forced to f32.
        let case = case_for(&mut rng, case_idx);
        let Case { m, n, k, dtype_in, epilogue, .. } = case;
        let dtype_acc = Dtype::F32;
        let key = GemmKey {
            m,
            n,
            k,
            dtype_in,
            dtype_acc,
            epilogue: epilogue.name().to_string(),
        };
        let program = Program::Gemm {
            m,
            n,
            k,
            dtype_in,
            dtype_acc,
            epilogue,
            fused: true,
        };
        let eplan = compile(&key, &env).unwrap();
        assert_eq!(
            eplan.numerics,
            NumericsClass::FmaRelaxed,
            "fuzz case {case_idx}: simd override compiled a {} plan",
            eplan.numerics.name()
        );
        assert!(
            eplan.isa_label().starts_with("simd:"),
            "fuzz case {case_idx}: simd override lowered to {}",
            eplan.isa_label()
        );

        let a = rng.normal_matrix(m, k);
        let b = rng.normal_matrix(k, n);
        let c = rng.normal_matrix(m, n);
        let bias_vec = epilogue.needs_bias().then(|| rng.normal_matrix(1, n));
        let want = reference(
            m,
            n,
            k,
            dtype_in,
            dtype_acc,
            epilogue,
            &a,
            &b,
            &c,
            bias_vec.as_deref(),
        );
        // The operands the executor actually reduces over.
        let cast = |v: &[f32]| -> Vec<f32> {
            v.iter().map(|&x| round_to(dtype_in, x)).collect()
        };
        let (a16, b16) = (cast(&a), cast(&b));

        let a_t = Tensor { shape: vec![m, k], data: a.clone() };
        let b_t = Tensor { shape: vec![k, n], data: b.clone() };
        let c_t = Tensor { shape: vec![m, n], data: c.clone() };
        let bias_t = bias_vec
            .as_ref()
            .map(|v| Tensor { shape: vec![n], data: v.clone() });

        // 1. planned single-call execution
        let mut inline_inputs = vec![a_t.clone(), b_t.clone(), c_t.clone()];
        if let Some(bt) = &bias_t {
            inline_inputs.push(bt.clone());
        }
        let got = program.execute_planned(&inline_inputs, &eplan).unwrap();
        assert_relaxed(
            "simd planned",
            seed,
            case_idx,
            &want,
            &got[0].data,
            &a16,
            &b16,
            &c,
            bias_vec.as_deref(),
            m,
            n,
            k,
        );

        // 2. weight-bound (prepacked when the plan says so)
        let bound = Arc::new(program.bind_b(&b_t, &eplan).unwrap());
        let mut bound_inputs = vec![a_t.clone(), c_t.clone()];
        if let Some(bt) = &bias_t {
            bound_inputs.push(bt.clone());
        }
        let got = program
            .execute_planned_bound(&bound_inputs, &eplan, &bound)
            .unwrap();
        let label = if bound.is_prepacked() {
            "simd bound+prepacked"
        } else {
            "simd bound"
        };
        assert_relaxed(
            label,
            seed,
            case_idx,
            &want,
            &got[0].data,
            &a16,
            &b16,
            &c,
            bias_vec.as_deref(),
            m,
            n,
            k,
        );

        if m * n * k > 64 * 64 * 64 {
            continue;
        }

        // 3. batched + bound-batched: three items sharing the bound B.
        if case_idx % 3 == 0 {
            let mut items_inline = vec![inline_inputs.clone()];
            let mut items_bound = vec![bound_inputs.clone()];
            let mut wants = vec![want.clone()];
            let mut a16s = vec![a16.clone()];
            let mut cs = vec![c.clone()];
            for _ in 0..2 {
                let a2 = rng.normal_matrix(m, k);
                let c2 = rng.normal_matrix(m, n);
                wants.push(reference(
                    m,
                    n,
                    k,
                    dtype_in,
                    dtype_acc,
                    epilogue,
                    &a2,
                    &b,
                    &c2,
                    bias_vec.as_deref(),
                ));
                a16s.push(cast(&a2));
                cs.push(c2.clone());
                let a2_t = Tensor { shape: vec![m, k], data: a2 };
                let c2_t = Tensor { shape: vec![m, n], data: c2 };
                let mut inline_item = vec![a2_t.clone(), b_t.clone(), c2_t.clone()];
                let mut bound_item = vec![a2_t, c2_t];
                if let Some(bt) = &bias_t {
                    inline_item.push(bt.clone());
                    bound_item.push(bt.clone());
                }
                items_inline.push(inline_item);
                items_bound.push(bound_item);
            }
            let outs = program.execute_batch_planned(&items_inline, &eplan).unwrap();
            for (bi, out) in outs.iter().enumerate() {
                assert_relaxed(
                    &format!("simd batched[{bi}]"),
                    seed,
                    case_idx,
                    &wants[bi],
                    &out[0].data,
                    &a16s[bi],
                    &b16,
                    &cs[bi],
                    bias_vec.as_deref(),
                    m,
                    n,
                    k,
                );
            }
            let outs = program
                .execute_batch_planned_bound(&items_bound, &eplan, &bound)
                .unwrap();
            for (bi, out) in outs.iter().enumerate() {
                assert_relaxed(
                    &format!("simd bound-batched[{bi}]"),
                    seed,
                    case_idx,
                    &wants[bi],
                    &out[0].data,
                    &a16s[bi],
                    &b16,
                    &cs[bi],
                    bias_vec.as_deref(),
                    m,
                    n,
                    k,
                );
            }
        }

        // 4. row-sharded + bound-row-sharded: every output element still
        // belongs to exactly one shard's simd reduction, so the per-
        // element bound holds unchanged through the row reduce.
        if case_idx % 4 == 0 && m >= 2 {
            let splan = ShardPlan::rows(m, n, k, 3, 1);
            let parts: Vec<Tensor> =
                build_shard_tasks(&env, &splan, &program, &a_t, &b_t, &c_t, bias_t.as_ref())
                    .unwrap()
                    .into_iter()
                    .map(|(prog, sp, inputs)| {
                        execute_shard(&prog, &sp, &inputs, None).unwrap()
                    })
                    .collect();
            let got =
                reduce_outputs(&splan, &program, &c_t, bias_t.as_ref(), &parts).unwrap();
            assert_relaxed(
                "simd row-sharded",
                seed,
                case_idx,
                &want,
                &got.data,
                &a16,
                &b16,
                &c,
                bias_vec.as_deref(),
                m,
                n,
                k,
            );

            let parts: Vec<Tensor> = build_shard_tasks_bound(
                &env,
                &splan,
                &program,
                &a_t,
                &c_t,
                bias_t.as_ref(),
                &bound,
            )
            .unwrap()
            .into_iter()
            .map(|(prog, sp, inputs, tb)| {
                execute_shard(&prog, &sp, &inputs, tb.as_deref()).unwrap()
            })
            .collect();
            let got =
                reduce_outputs(&splan, &program, &c_t, bias_t.as_ref(), &parts).unwrap();
            assert_relaxed(
                "simd bound-row-sharded",
                seed,
                case_idx,
                &want,
                &got.data,
                &a16,
                &b16,
                &c,
                bias_vec.as_deref(),
                m,
                n,
                k,
            );
        }
    }
}
