//! Server stress: concurrent client threads firing a mix of weight-bound
//! and operand-carrying requests at two differently-planned variants,
//! with shutdown racing the submissions.  Invariants:
//!
//! * no lost response channels — every submit eventually yields a
//!   response (success or explicit error), never a dead channel;
//! * `submitted == completed + failed` after shutdown;
//! * per-plan request counts and per-variant counts each sum to the
//!   global `completed` counter;
//! * the pack-cache counters prove `pack_b` ran at most once per (bind,
//!   plan): every completed weight-bound request on the packing plan is
//!   a hit, every inline one a miss, and the direct-kernel plan records
//!   no hits at all;
//! * successful outputs are bit-identical to the naive reference.

use std::sync::{Arc, Mutex};
use std::time::Duration;

use mlir_gemm::coordinator::{GemmKey, GemmRequest, Server, ServerConfig};
use mlir_gemm::runtime::{KernelPolicy, Runtime, Tensor};
use mlir_gemm::schedule::Dtype;
use mlir_gemm::util::prng::Rng;

const MANIFEST: &str = r#"{
  "version": 1,
  "artifacts": [
    {
      "name": "small",
      "file": "small.tprog.json",
      "kind": "baseline",
      "inputs": [
        {"shape": [24, 24], "dtype": "f32"},
        {"shape": [24, 24], "dtype": "f32"},
        {"shape": [24, 24], "dtype": "f32"}
      ],
      "outputs": [{"shape": [24, 24], "dtype": "f32"}],
      "m": 24, "n": 24, "k": 24, "dtype_in": "f32", "dtype_acc": "f32"
    },
    {
      "name": "big",
      "file": "big.tprog.json",
      "kind": "baseline",
      "inputs": [
        {"shape": [128, 112], "dtype": "f32"},
        {"shape": [112, 96], "dtype": "f32"},
        {"shape": [128, 96], "dtype": "f32"}
      ],
      "outputs": [{"shape": [128, 96], "dtype": "f32"}],
      "m": 128, "n": 96, "k": 112, "dtype_in": "f32", "dtype_acc": "f32"
    }
  ]
}"#;

const SMALL: &str = r#"{
  "format": "mlir-gemm-tprog-v1",
  "name": "small",
  "program": {
    "type": "gemm", "m": 24, "n": 24, "k": 24,
    "dtype_in": "f32", "dtype_acc": "f32", "epilogue": "none", "fused": true
  }
}"#;

const BIG: &str = r#"{
  "format": "mlir-gemm-tprog-v1",
  "name": "big",
  "program": {
    "type": "gemm", "m": 128, "n": 96, "k": 112,
    "dtype_in": "f32", "dtype_acc": "f32", "epilogue": "none", "fused": true
  }
}"#;

fn naive_reference(key: &GemmKey, a: &[f32], b: &[f32], c: &[f32]) -> Vec<f32> {
    let mut out = c.to_vec();
    mlir_gemm::runtime::kernel::matmul(
        KernelPolicy::Naive,
        &mut out,
        a,
        b,
        key.m,
        key.n,
        key.k,
    );
    out
}

struct Record {
    big: bool,
    bound: bool,
    want: Vec<f32>,
    rx: std::sync::mpsc::Receiver<mlir_gemm::coordinator::GemmResponse>,
}

#[test]
fn stress_mixed_bound_and_inline_with_midflight_shutdown() {
    let dir = std::env::temp_dir()
        .join(format!("mlir_gemm_stress_srv_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.json"), MANIFEST).unwrap();
    std::fs::write(dir.join("small.tprog.json"), SMALL).unwrap();
    std::fs::write(dir.join("big.tprog.json"), BIG).unwrap();

    let rt = Arc::new(Runtime::open(&dir).unwrap());
    let server = Server::start(
        rt,
        &mlir_gemm::sim::DeviceModel::rtx3090(),
        ServerConfig { workers: 3, ..Default::default() },
    );

    let small_key = GemmKey::with_dtypes(24, 24, 24, Dtype::F32, Dtype::F32);
    let big_key = GemmKey::with_dtypes(128, 96, 112, Dtype::F32, Dtype::F32);
    let small_plan = server.registry().plan(&small_key).unwrap();
    let big_plan = server.registry().plan(&big_key).unwrap();
    assert!(
        matches!(small_plan.kernel, KernelPolicy::Naive) && !small_plan.prepack,
        "24^3 must compile to a direct, non-prepacking plan"
    );
    assert!(
        !matches!(big_plan.kernel, KernelPolicy::Naive) && big_plan.prepack,
        "128x96x112 must compile to a packing, prepacking plan"
    );

    // Bind constant weights for both keys.
    let mut wrng = Rng::new(0x57);
    let small_b = Tensor::new(vec![24, 24], wrng.normal_matrix(24, 24)).unwrap();
    let big_b = Tensor::new(vec![112, 96], wrng.normal_matrix(112, 96)).unwrap();
    server.bind_weights(&small_key, &small_b).unwrap();
    server.bind_weights(&big_key, &big_b).unwrap();

    // Clients hold the server behind a mutex only to call submit()/
    // shutdown(); the dispatcher and workers run lock-free of it.
    const CLIENTS: u64 = 4;
    const PER_CLIENT: usize = 24;
    let server = Mutex::new(server);
    let records: Mutex<Vec<Record>> = Mutex::new(Vec::new());
    let small_b_data = small_b.data.clone();
    let big_b_data = big_b.data.clone();
    std::thread::scope(|scope| {
        for cid in 0..CLIENTS {
            let server = &server;
            let records = &records;
            let small_key = &small_key;
            let big_key = &big_key;
            let small_b_data = &small_b_data;
            let big_b_data = &big_b_data;
            scope.spawn(move || {
                let mut rng = Rng::new(0xC11E + cid);
                for i in 0..PER_CLIENT {
                    let big = rng.below(2) == 0;
                    let bound = rng.below(2) == 0;
                    let (key, bdata) = if big {
                        (big_key.clone(), big_b_data.as_slice())
                    } else {
                        (small_key.clone(), small_b_data.as_slice())
                    };
                    let a = Tensor::new(
                        vec![key.m, key.k],
                        rng.normal_matrix(key.m, key.k),
                    )
                    .unwrap();
                    let c = Tensor::new(
                        vec![key.m, key.n],
                        rng.normal_matrix(key.m, key.n),
                    )
                    .unwrap();
                    let (b, want_b): (Option<Tensor>, Vec<f32>) = if bound {
                        (None, bdata.to_vec())
                    } else {
                        let fresh = Tensor::new(
                            vec![key.k, key.n],
                            rng.normal_matrix(key.k, key.n),
                        )
                        .unwrap();
                        let data = fresh.data.clone();
                        (Some(fresh), data)
                    };
                    let want = naive_reference(&key, &a.data, &want_b, &c.data);
                    let rx = server.lock().unwrap().submit(GemmRequest {
                        key,
                        a,
                        b,
                        c,
                        bias: None,
                        use_baseline: true,
                    });
                    records.lock().unwrap().push(Record { big, bound, want, rx });
                    if i % 8 == 7 {
                        std::thread::yield_now();
                    }
                }
            });
        }
        // Shutdown races the submitting clients: some requests complete,
        // some drain during shutdown, late ones get explicit errors.
        scope.spawn(|| {
            std::thread::sleep(Duration::from_millis(3));
            let _ = server.lock().unwrap().shutdown();
        });
    });

    // Drain every response channel: a dead channel (recv Err) means a
    // request was dropped without a response — the invariant under test.
    let records = records.into_inner().unwrap();
    assert_eq!(records.len(), (CLIENTS as usize) * PER_CLIENT);
    let mut ok = 0u64;
    let mut failed = 0u64;
    let mut ok_big_bound = 0u64;
    let mut ok_big_inline = 0u64;
    let mut ok_small_bound = 0u64;
    for rec in &records {
        let resp = rec
            .rx
            .recv_timeout(Duration::from_secs(120))
            .expect("lost response channel: request dropped without a response");
        match resp.output {
            Ok(out) => {
                ok += 1;
                assert_eq!(
                    out.data, rec.want,
                    "completed request (big={}, bound={}) not bit-identical",
                    rec.big, rec.bound
                );
                match (rec.big, rec.bound) {
                    (true, true) => ok_big_bound += 1,
                    (true, false) => ok_big_inline += 1,
                    (false, true) => ok_small_bound += 1,
                    (false, false) => {}
                }
            }
            Err(_) => failed += 1,
        }
    }

    let m = server.into_inner().unwrap().metrics();
    assert_eq!(m.submitted, records.len() as u64);
    assert_eq!(
        m.completed + m.failed,
        m.submitted,
        "submitted == completed + failed must hold through shutdown"
    );
    assert_eq!(m.completed, ok);
    assert_eq!(m.failed, failed);

    // Per-plan and per-variant tallies must sum to the global counter.
    let per_plan_sum: u64 = m.per_plan.values().map(|l| l.requests).sum();
    assert_eq!(per_plan_sum, m.completed, "per_plan: {:?}", m.per_plan);
    let per_variant_sum: u64 = m.per_variant.values().sum();
    assert_eq!(per_variant_sum, m.completed, "per_variant: {:?}", m.per_variant);
    // Bound and inline traffic segments per variant name (+bound suffix).
    assert_eq!(
        m.per_variant.get("big+bound").copied().unwrap_or(0),
        ok_big_bound,
        "per_variant: {:?}",
        m.per_variant
    );
    assert_eq!(
        m.per_variant.get("big").copied().unwrap_or(0),
        ok_big_inline,
        "per_variant: {:?}",
        m.per_variant
    );

    // Pack-cache proof that pack_b ran at most once per (bind, plan):
    // every completed bound request on the packing plan was a hit
    // (served straight off the bind-time panels), every inline one a
    // miss (packed per call), and the direct-kernel plan never hits.
    let big_load = &m.per_plan[&big_plan.id()];
    assert_eq!(big_load.pack_hits, ok_big_bound, "per_plan: {:?}", m.per_plan);
    assert_eq!(big_load.pack_misses, ok_big_inline, "per_plan: {:?}", m.per_plan);
    let want_saved = ok_big_bound as f64 * (4 * 112 * 96) as f64;
    assert!(
        (big_load.bytes_saved - want_saved).abs() < 0.5,
        "bytes_saved {} != {want_saved}",
        big_load.bytes_saved
    );
    let small_load = &m.per_plan[&small_plan.id()];
    assert_eq!(small_load.pack_hits, 0, "direct plans never pack at all");
    assert_eq!(small_load.pack_misses, 0);
    let small_saved = ok_small_bound as f64 * (4 * 24 * 24) as f64;
    assert!(
        (small_load.bytes_saved - small_saved).abs() < 0.5,
        "bytes_saved {} != {small_saved}",
        small_load.bytes_saved
    );

    let _ = std::fs::remove_dir_all(&dir);
}
