//! Server stress: concurrent client threads firing a mix of weight-bound
//! and operand-carrying requests at two differently-planned variants,
//! with shutdown racing the submissions.  Invariants:
//!
//! * no lost response channels — every submit eventually yields a
//!   response (success or explicit error), never a dead channel;
//! * `submitted == completed + failed` after shutdown;
//! * per-plan request counts and per-variant counts each sum to the
//!   global `completed` counter;
//! * the pack-cache counters prove `pack_b` ran at most once per (bind,
//!   plan): every completed weight-bound request on the packing plan is
//!   a hit, every inline one a miss, and the direct-kernel plan records
//!   no hits at all;
//! * successful outputs are bit-identical to the naive reference.
//!
//! The second half drives seeded [`FaultPlan`] schedules through the
//! server — deterministic worker panics, delayed routing racing a
//! rebind, a held dispatcher against a tiny bounded queue, expired
//! deadlines, and execution jitter under shutdown — and asserts the
//! graceful-degradation contract: every fault is an *explicit* error
//! response in its own metrics bucket, never a dropped channel, and
//! `submitted == completed + failed + rejected` always.  Every seeded
//! test prints its seed; replay any failure with
//! `MLIR_GEMM_FAULT_SEED=<seed> cargo test`.

use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use mlir_gemm::coordinator::{
    seed_from_env, silence_injected_panics, AdmissionConfig, BatcherConfig,
    FaultPlan, GemmKey, GemmRequest, Priority, Server, ServerConfig, SubmitOpts,
    ERR_DEADLINE, ERR_POISONED, ERR_QUEUE_FULL, ERR_SHUTDOWN,
};
use mlir_gemm::runtime::{KernelPolicy, Runtime, Tensor};
use mlir_gemm::schedule::Dtype;
use mlir_gemm::util::prng::Rng;

const MANIFEST: &str = r#"{
  "version": 1,
  "artifacts": [
    {
      "name": "small",
      "file": "small.tprog.json",
      "kind": "baseline",
      "inputs": [
        {"shape": [24, 24], "dtype": "f32"},
        {"shape": [24, 24], "dtype": "f32"},
        {"shape": [24, 24], "dtype": "f32"}
      ],
      "outputs": [{"shape": [24, 24], "dtype": "f32"}],
      "m": 24, "n": 24, "k": 24, "dtype_in": "f32", "dtype_acc": "f32"
    },
    {
      "name": "big",
      "file": "big.tprog.json",
      "kind": "baseline",
      "inputs": [
        {"shape": [128, 112], "dtype": "f32"},
        {"shape": [112, 96], "dtype": "f32"},
        {"shape": [128, 96], "dtype": "f32"}
      ],
      "outputs": [{"shape": [128, 96], "dtype": "f32"}],
      "m": 128, "n": 96, "k": 112, "dtype_in": "f32", "dtype_acc": "f32"
    }
  ]
}"#;

const SMALL: &str = r#"{
  "format": "mlir-gemm-tprog-v1",
  "name": "small",
  "program": {
    "type": "gemm", "m": 24, "n": 24, "k": 24,
    "dtype_in": "f32", "dtype_acc": "f32", "epilogue": "none", "fused": true
  }
}"#;

const BIG: &str = r#"{
  "format": "mlir-gemm-tprog-v1",
  "name": "big",
  "program": {
    "type": "gemm", "m": 128, "n": 96, "k": 112,
    "dtype_in": "f32", "dtype_acc": "f32", "epilogue": "none", "fused": true
  }
}"#;

fn naive_reference(key: &GemmKey, a: &[f32], b: &[f32], c: &[f32]) -> Vec<f32> {
    let mut out = c.to_vec();
    mlir_gemm::runtime::kernel::matmul(
        KernelPolicy::Naive,
        &mut out,
        a,
        b,
        key.m,
        key.n,
        key.k,
    );
    out
}

struct Record {
    big: bool,
    bound: bool,
    want: Vec<f32>,
    rx: std::sync::mpsc::Receiver<mlir_gemm::coordinator::GemmResponse>,
}

#[test]
fn stress_mixed_bound_and_inline_with_midflight_shutdown() {
    let dir = std::env::temp_dir()
        .join(format!("mlir_gemm_stress_srv_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.json"), MANIFEST).unwrap();
    std::fs::write(dir.join("small.tprog.json"), SMALL).unwrap();
    std::fs::write(dir.join("big.tprog.json"), BIG).unwrap();

    let rt = Arc::new(Runtime::open(&dir).unwrap());
    let server = Server::start(
        rt,
        &mlir_gemm::sim::DeviceModel::rtx3090(),
        ServerConfig { workers: 3, ..Default::default() },
    );

    let small_key = GemmKey::with_dtypes(24, 24, 24, Dtype::F32, Dtype::F32);
    let big_key = GemmKey::with_dtypes(128, 96, 112, Dtype::F32, Dtype::F32);
    let small_plan = server.registry().plan(&small_key).unwrap();
    let big_plan = server.registry().plan(&big_key).unwrap();
    assert!(
        matches!(small_plan.kernel, KernelPolicy::Naive) && !small_plan.prepack,
        "24^3 must compile to a direct, non-prepacking plan"
    );
    assert!(
        !matches!(big_plan.kernel, KernelPolicy::Naive) && big_plan.prepack,
        "128x96x112 must compile to a packing, prepacking plan"
    );

    // Bind constant weights for both keys.
    let mut wrng = Rng::new(0x57);
    let small_b = Tensor::new(vec![24, 24], wrng.normal_matrix(24, 24)).unwrap();
    let big_b = Tensor::new(vec![112, 96], wrng.normal_matrix(112, 96)).unwrap();
    server.bind_weights(&small_key, &small_b).unwrap();
    server.bind_weights(&big_key, &big_b).unwrap();

    // Clients hold the server behind a mutex only to call submit()/
    // shutdown(); the dispatcher and workers run lock-free of it.
    const CLIENTS: u64 = 4;
    const PER_CLIENT: usize = 24;
    let server = Mutex::new(server);
    let records: Mutex<Vec<Record>> = Mutex::new(Vec::new());
    let small_b_data = small_b.data.clone();
    let big_b_data = big_b.data.clone();
    std::thread::scope(|scope| {
        for cid in 0..CLIENTS {
            let server = &server;
            let records = &records;
            let small_key = &small_key;
            let big_key = &big_key;
            let small_b_data = &small_b_data;
            let big_b_data = &big_b_data;
            scope.spawn(move || {
                let mut rng = Rng::new(0xC11E + cid);
                for i in 0..PER_CLIENT {
                    let big = rng.below(2) == 0;
                    let bound = rng.below(2) == 0;
                    let (key, bdata) = if big {
                        (big_key.clone(), big_b_data.as_slice())
                    } else {
                        (small_key.clone(), small_b_data.as_slice())
                    };
                    let a = Tensor::new(
                        vec![key.m, key.k],
                        rng.normal_matrix(key.m, key.k),
                    )
                    .unwrap();
                    let c = Tensor::new(
                        vec![key.m, key.n],
                        rng.normal_matrix(key.m, key.n),
                    )
                    .unwrap();
                    let (b, want_b): (Option<Tensor>, Vec<f32>) = if bound {
                        (None, bdata.to_vec())
                    } else {
                        let fresh = Tensor::new(
                            vec![key.k, key.n],
                            rng.normal_matrix(key.k, key.n),
                        )
                        .unwrap();
                        let data = fresh.data.clone();
                        (Some(fresh), data)
                    };
                    let want = naive_reference(&key, &a.data, &want_b, &c.data);
                    let rx = server.lock().unwrap().submit(GemmRequest {
                        key,
                        a,
                        b,
                        c,
                        bias: None,
                        use_baseline: true,
                        deadline: None,
                    });
                    records.lock().unwrap().push(Record { big, bound, want, rx });
                    if i % 8 == 7 {
                        std::thread::yield_now();
                    }
                }
            });
        }
        // Shutdown races the submitting clients: some requests complete,
        // some drain during shutdown, late ones get explicit errors.
        scope.spawn(|| {
            std::thread::sleep(Duration::from_millis(3));
            let _ = server.lock().unwrap().shutdown();
        });
    });

    // Drain every response channel: a dead channel (recv Err) means a
    // request was dropped without a response — the invariant under test.
    let records = records.into_inner().unwrap();
    assert_eq!(records.len(), (CLIENTS as usize) * PER_CLIENT);
    let mut ok = 0u64;
    let mut failed = 0u64;
    let mut ok_big_bound = 0u64;
    let mut ok_big_inline = 0u64;
    let mut ok_small_bound = 0u64;
    for rec in &records {
        let resp = rec
            .rx
            .recv_timeout(Duration::from_secs(120))
            .expect("lost response channel: request dropped without a response");
        match resp.output {
            Ok(out) => {
                ok += 1;
                assert_eq!(
                    out.data, rec.want,
                    "completed request (big={}, bound={}) not bit-identical",
                    rec.big, rec.bound
                );
                match (rec.big, rec.bound) {
                    (true, true) => ok_big_bound += 1,
                    (true, false) => ok_big_inline += 1,
                    (false, true) => ok_small_bound += 1,
                    (false, false) => {}
                }
            }
            Err(_) => failed += 1,
        }
    }

    let m = server.into_inner().unwrap().metrics();
    assert_eq!(m.submitted, records.len() as u64);
    assert_eq!(
        m.completed + m.failed + m.rejected,
        m.submitted,
        "submitted == completed + failed + rejected must hold through shutdown"
    );
    assert_eq!(m.rejected, 0, "default capacity must absorb this load");
    assert_eq!(m.completed, ok);
    assert_eq!(m.failed, failed);

    // Per-plan and per-variant tallies must sum to the global counter.
    let per_plan_sum: u64 = m.per_plan.values().map(|l| l.requests).sum();
    assert_eq!(per_plan_sum, m.completed, "per_plan: {:?}", m.per_plan);
    let per_variant_sum: u64 = m.per_variant.values().sum();
    assert_eq!(per_variant_sum, m.completed, "per_variant: {:?}", m.per_variant);
    // Bound and inline traffic segments per variant name (+bound suffix).
    assert_eq!(
        m.per_variant.get("big+bound").copied().unwrap_or(0),
        ok_big_bound,
        "per_variant: {:?}",
        m.per_variant
    );
    assert_eq!(
        m.per_variant.get("big").copied().unwrap_or(0),
        ok_big_inline,
        "per_variant: {:?}",
        m.per_variant
    );

    // Pack-cache proof that pack_b ran at most once per (bind, plan):
    // every completed bound request on the packing plan was a hit
    // (served straight off the bind-time panels), every inline one a
    // miss (packed per call), and the direct-kernel plan never hits.
    let big_load = &m.per_plan[&big_plan.id()];
    assert_eq!(big_load.pack_hits, ok_big_bound, "per_plan: {:?}", m.per_plan);
    assert_eq!(big_load.pack_misses, ok_big_inline, "per_plan: {:?}", m.per_plan);
    let want_saved = ok_big_bound as f64 * (4 * 112 * 96) as f64;
    assert!(
        (big_load.bytes_saved - want_saved).abs() < 0.5,
        "bytes_saved {} != {want_saved}",
        big_load.bytes_saved
    );
    let small_load = &m.per_plan[&small_plan.id()];
    assert_eq!(small_load.pack_hits, 0, "direct plans never pack at all");
    assert_eq!(small_load.pack_misses, 0);
    let small_saved = ok_small_bound as f64 * (4 * 24 * 24) as f64;
    assert!(
        (small_load.bytes_saved - small_saved).abs() < 0.5,
        "bytes_saved {} != {small_saved}",
        small_load.bytes_saved
    );

    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------------
// Seeded fault schedules.
// ---------------------------------------------------------------------------

/// Fresh artifact store per test (tests share one process; each needs
/// its own directory).
fn fault_store(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("mlir_gemm_stress_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.json"), MANIFEST).unwrap();
    std::fs::write(dir.join("small.tprog.json"), SMALL).unwrap();
    std::fs::write(dir.join("big.tprog.json"), BIG).unwrap();
    dir
}

fn start_server(dir: &std::path::Path, cfg: ServerConfig) -> Server {
    let rt = Arc::new(Runtime::open(dir).unwrap());
    Server::start(rt, &mlir_gemm::sim::DeviceModel::rtx3090(), cfg)
}

fn small_request(rng: &mut Rng, key: &GemmKey, deadline: Option<Instant>) -> (Vec<f32>, GemmRequest) {
    let a = Tensor::new(vec![24, 24], rng.normal_matrix(24, 24)).unwrap();
    let b = Tensor::new(vec![24, 24], rng.normal_matrix(24, 24)).unwrap();
    let c = Tensor::new(vec![24, 24], rng.normal_matrix(24, 24)).unwrap();
    let want = naive_reference(key, &a.data, &b.data, &c.data);
    (
        want,
        GemmRequest {
            key: key.clone(),
            a,
            b: Some(b),
            c,
            bias: None,
            use_baseline: true,
            deadline,
        },
    )
}

/// Injected panics are quarantined per job: with `poison_one_in: 5`
/// over 20 sequential submits, *exactly* 4 deterministic jobs fail with
/// the explicit `ERR_POISONED` error (whatever the seed: the hit set is
/// `(id + phase) % 5 == 0`), every other job completes bit-identically,
/// and the accounting identity is exact.
#[test]
fn seeded_poison_is_quarantined_per_job() {
    silence_injected_panics();
    let seed = seed_from_env(0xF417);
    eprintln!("fault seed: {seed:#x} (replay: MLIR_GEMM_FAULT_SEED={seed})");
    let plan = FaultPlan { seed, poison_one_in: 5, ..Default::default() };
    let dir = fault_store("poison");
    let mut server = start_server(
        &dir,
        ServerConfig { workers: 2, faults: plan.clone(), ..Default::default() },
    );

    let key = GemmKey::with_dtypes(24, 24, 24, Dtype::F32, Dtype::F32);
    const N: u64 = 20;
    let mut rng = Rng::new(0x90);
    let mut pending = Vec::new();
    for id in 0..N {
        let (want, req) = small_request(&mut rng, &key, None);
        // Sequential submits from one thread: job ids are 0..N in
        // order, so the poison set is known up front.
        pending.push((plan.poisons(id), want, server.submit(req)));
    }

    let mut poisoned = 0u64;
    let mut completed = 0u64;
    for (should_poison, want, rx) in &pending {
        let resp = rx
            .recv_timeout(Duration::from_secs(120))
            .expect("lost response channel under poison faults");
        match resp.output {
            Ok(out) => {
                assert!(
                    !*should_poison,
                    "job {} was scheduled to panic but completed",
                    resp.id
                );
                assert_eq!(
                    out.data, *want,
                    "quarantine survivor {} must stay bit-identical",
                    resp.id
                );
                completed += 1;
            }
            Err(e) => {
                let msg = format!("{e:#}");
                assert!(
                    *should_poison,
                    "job {} failed without being poisoned: {msg}",
                    resp.id
                );
                assert!(
                    msg.contains(ERR_POISONED),
                    "poisoned job must fail with the explicit marker: {msg}"
                );
                poisoned += 1;
            }
        }
    }
    assert_eq!(poisoned, 4, "one in 5 of 20 ids, exactly");
    assert_eq!(completed, N - 4);
    assert!(
        server.faults().injected_panics() >= poisoned,
        "the gate must actually have fired"
    );

    let m = server.shutdown();
    assert_eq!(m.submitted, N);
    assert_eq!(m.completed, completed);
    assert_eq!(m.failed, poisoned);
    assert_eq!(m.completed + m.failed + m.rejected, m.submitted);
    let _ = std::fs::remove_dir_all(&dir);
}

/// A rebind racing dispatch (widened by the `delay_route` fault, which
/// lingers between epoch capture and the batcher) can split traffic
/// across epochs, but every response's `bound_epoch` matches the
/// weights its output was computed from, and requests submitted after
/// the rebind completed always see the new epoch — no stale panels.
#[test]
fn rebind_racing_dispatch_never_serves_stale_panels() {
    let seed = seed_from_env(0xB1D);
    eprintln!("fault seed: {seed:#x} (replay: MLIR_GEMM_FAULT_SEED={seed})");
    let plan = FaultPlan {
        seed,
        delay_route_one_in: 1,
        delay_route: Duration::from_millis(1),
        ..Default::default()
    };
    let dir = fault_store("rebind");
    let server = start_server(
        &dir,
        ServerConfig { workers: 2, faults: plan, ..Default::default() },
    );

    let key = GemmKey::with_dtypes(128, 96, 112, Dtype::F32, Dtype::F32);
    let mut wrng = Rng::new(0x1B);
    let b1 = Tensor::new(vec![112, 96], wrng.normal_matrix(112, 96)).unwrap();
    let b2 = Tensor::new(vec![112, 96], wrng.normal_matrix(112, 96)).unwrap();
    server.bind_weights(&key, &b1).unwrap();

    let mut rng = Rng::new(0x2B);

    // Wave A: fully drained before the rebind — must all be epoch 1.
    for _ in 0..4 {
        let ((want1, _), req) = bound_req_in(&key, &b1, &b2, &mut rng);
        let resp = server.submit(req).recv_timeout(Duration::from_secs(120)).unwrap();
        assert_eq!(resp.bound_epoch, Some(1), "pre-rebind traffic is epoch 1");
        assert_eq!(resp.output.unwrap().data, want1);
    }

    // Racy middle: submissions interleave with the rebind.  Each
    // response must be internally consistent: epoch 1 -> b1's output,
    // epoch 2 -> b2's output.  Anything else is a stale-panel leak.
    let racy: Vec<_> = std::thread::scope(|scope| {
        let submitter = scope.spawn(|| {
            let mut rng = Rng::new(0x3B);
            let mut out = Vec::new();
            for i in 0..8 {
                let (refs, req) = bound_req_in(&key, &b1, &b2, &mut rng);
                out.push((refs, server.submit(req)));
                if i % 2 == 1 {
                    std::thread::yield_now();
                }
            }
            out
        });
        scope.spawn(|| {
            std::thread::sleep(Duration::from_millis(1));
            server.bind_weights(&key, &b2).unwrap();
        });
        submitter.join().unwrap()
    });
    for ((want1, want2), rx) in racy {
        let resp = rx.recv_timeout(Duration::from_secs(120)).unwrap();
        let epoch = resp.bound_epoch.expect("bound job must echo its epoch");
        let out = resp.output.unwrap().data;
        match epoch {
            1 => assert_eq!(out, want1, "epoch-1 response must use b1"),
            2 => assert_eq!(out, want2, "epoch-2 response must use b2"),
            other => panic!("impossible bind epoch {other}"),
        }
    }

    // Wave C: submitted strictly after the rebind returned — the
    // registry mutex gives the happens-before, so epoch 2 always.
    for _ in 0..4 {
        let ((_, want2), req) = bound_req_in(&key, &b1, &b2, &mut rng);
        let resp = server.submit(req).recv_timeout(Duration::from_secs(120)).unwrap();
        assert_eq!(
            resp.bound_epoch,
            Some(2),
            "post-rebind traffic can never see the old panels"
        );
        assert_eq!(resp.output.unwrap().data, want2);
    }

    let mut server = server;
    let m = server.shutdown();
    assert_eq!(m.completed, 16);
    assert_eq!(m.completed + m.failed + m.rejected, m.submitted);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Weight-bound request for the rebind test, with the reference output
/// under *both* candidate weights (the response's `bound_epoch` picks
/// which one must match).
fn bound_req_in(
    key: &GemmKey,
    b1: &Tensor,
    b2: &Tensor,
    rng: &mut Rng,
) -> ((Vec<f32>, Vec<f32>), GemmRequest) {
    let a = Tensor::new(vec![128, 112], rng.normal_matrix(128, 112)).unwrap();
    let c = Tensor::new(vec![128, 96], rng.normal_matrix(128, 96)).unwrap();
    let refs = (
        naive_reference(key, &a.data, &b1.data, &c.data),
        naive_reference(key, &a.data, &b2.data, &c.data),
    );
    (
        refs,
        GemmRequest {
            key: key.clone(),
            a,
            b: None,
            c,
            bias: None,
            use_baseline: true,
            deadline: None,
        },
    )
}

/// Bounded admission is deterministic under a held dispatcher: capacity
/// 2 + 8 sequential submits = exactly 6 immediate `ERR_QUEUE_FULL`
/// rejections (already answered before shutdown), and the 2 buffered
/// jobs drain to completion through shutdown.
#[test]
fn queue_overflow_rejects_deterministically() {
    let plan = FaultPlan { hold_dispatch_until_shutdown: true, ..Default::default() };
    let dir = fault_store("overflow");
    let mut server = start_server(
        &dir,
        ServerConfig {
            workers: 1,
            queue_capacity: 2,
            faults: plan,
            ..Default::default()
        },
    );

    let key = GemmKey::with_dtypes(24, 24, 24, Dtype::F32, Dtype::F32);
    let mut rng = Rng::new(0xF0);
    let mut pending = Vec::new();
    for _ in 0..8 {
        let (want, req) = small_request(&mut rng, &key, None);
        pending.push((want, server.submit(req)));
    }

    // Rejections are synchronous: with the dispatcher parked, submits
    // 3..8 found the queue full and were answered inside submit().
    for (i, (_, rx)) in pending.iter().enumerate().skip(2) {
        let resp = rx.try_recv().unwrap_or_else(|_| {
            panic!("submit {i} over capacity must be rejected immediately")
        });
        let msg = format!("{:#}", resp.output.unwrap_err());
        assert!(msg.contains(ERR_QUEUE_FULL), "{msg}");
        assert!(msg.contains("capacity 2"), "{msg}");
    }
    let mid = server.metrics();
    assert_eq!(mid.submitted, 8);
    assert_eq!(mid.rejected, 6);

    // Shutdown releases the held dispatcher; the 2 admitted jobs drain.
    let m = server.shutdown();
    for (want, rx) in pending.iter().take(2) {
        let out = rx.try_recv().expect("admitted job lost").output.unwrap();
        assert_eq!(out.data, *want);
    }
    assert_eq!(m.completed, 2);
    assert_eq!(m.failed, 0);
    assert_eq!(m.rejected, 6);
    assert_eq!(m.completed + m.failed + m.rejected, m.submitted);
    let _ = std::fs::remove_dir_all(&dir);
}

/// A request whose deadline passes while it waits in the submit queue
/// is answered with the explicit `ERR_DEADLINE` error before any
/// execution, its burned queue wait is attributed in both the response
/// and the `expired_wait` reservoir, and expiries count as failures.
#[test]
fn expired_deadlines_fail_explicitly_before_execution() {
    let plan = FaultPlan { hold_dispatch_until_shutdown: true, ..Default::default() };
    let dir = fault_store("deadline");
    let mut server = start_server(
        &dir,
        ServerConfig { workers: 1, faults: plan, ..Default::default() },
    );

    let key = GemmKey::with_dtypes(24, 24, 24, Dtype::F32, Dtype::F32);
    let mut rng = Rng::new(0xD1);
    let deadline = Instant::now() + Duration::from_millis(3);
    let mut pending = Vec::new();
    for _ in 0..4 {
        let (_, req) = small_request(&mut rng, &key, Some(deadline));
        pending.push(server.submit(req));
    }
    // Everyone expires while the dispatcher is held.
    std::thread::sleep(Duration::from_millis(15));

    let m = server.shutdown();
    for rx in &pending {
        let resp = rx.try_recv().expect("expired job lost its channel");
        let msg = format!("{:#}", resp.output.unwrap_err());
        assert!(msg.contains(ERR_DEADLINE), "{msg}");
        assert!(
            resp.queue_wait >= Duration::from_millis(3),
            "burned queue wait must be attributed: {:?}",
            resp.queue_wait
        );
        assert_eq!(resp.exec_time, Duration::ZERO, "expired jobs never execute");
    }
    assert_eq!(m.deadline_expired, 4);
    assert_eq!(m.failed, 4);
    assert_eq!(m.completed, 0);
    assert!(
        m.expired_wait.is_some(),
        "expired queue-wait reservoir must be populated"
    );
    assert_eq!(m.completed + m.failed + m.rejected, m.submitted);
    let _ = std::fs::remove_dir_all(&dir);
}

/// A deadline already in the past is refused *at admission*, inside
/// `submit` itself: the explicit `ERR_DEADLINE` answer is synchronous,
/// no queue slot is ever consumed (the live `queue_depth` stays zero
/// throughout), and the refusals land in their own
/// `expired_at_admission` bucket — they are deadline failures, never
/// queue rejections.
#[test]
fn pre_expired_deadlines_are_refused_at_admission_without_queue_space() {
    let plan = FaultPlan { hold_dispatch_until_shutdown: true, ..Default::default() };
    let dir = fault_store("preexpired");
    let mut server = start_server(
        &dir,
        ServerConfig {
            workers: 1,
            queue_capacity: 2,
            faults: plan,
            ..Default::default()
        },
    );

    let key = GemmKey::with_dtypes(24, 24, 24, Dtype::F32, Dtype::F32);
    let mut rng = Rng::new(0xA3);
    for i in 0..6 {
        let stale = Instant::now() - Duration::from_millis(5);
        let (_, req) = small_request(&mut rng, &key, Some(stale));
        let rx = server.submit(req);
        let resp = rx
            .try_recv()
            .expect("pre-expired submit must be answered synchronously");
        let msg = format!("{:#}", resp.output.unwrap_err());
        assert!(msg.contains(ERR_DEADLINE), "{msg}");
        assert!(msg.contains("admission"), "refusal must name the stage: {msg}");
        assert_eq!(resp.queue_depth, 0, "refused before entering the queue");
        assert_eq!(
            server.queue_depth(),
            0,
            "pre-expired submit {i} must not occupy the queue"
        );
    }

    // The capacity-2 queue is fully intact: two feasible jobs still
    // admit even though six pre-expired ones were refused first.
    let mut admitted = Vec::new();
    for _ in 0..2 {
        let (want, req) = small_request(&mut rng, &key, None);
        admitted.push((want, server.submit(req)));
    }
    assert_eq!(server.queue_depth(), 2, "feasible jobs fill the queue normally");

    let mid = server.metrics();
    assert_eq!(mid.expired_at_admission, 6);
    assert_eq!(mid.deadline_expired, 6, "admission refusals are deadline failures");
    assert_eq!(mid.failed, 6);
    assert_eq!(
        mid.rejected, 0,
        "an unmeetable deadline is not a queue rejection"
    );

    let m = server.shutdown();
    for (want, rx) in &admitted {
        let out = rx.try_recv().expect("admitted job lost").output.unwrap();
        assert_eq!(out.data, *want);
    }
    assert_eq!(m.completed, 2);
    assert_eq!(m.completed + m.failed + m.rejected, m.submitted);
    let _ = std::fs::remove_dir_all(&dir);
}

/// The headline latency bugfix, as a regression test: under the old
/// fixed-window dispatcher a request whose deadline was shorter than
/// the batching window *always* expired in queue — the window was
/// charged to every request unconditionally.  Continuous batching
/// dispatches the moment a device frees, so requests with a 500 ms
/// budget complete comfortably even with a 10 s ordering window
/// configured.
#[test]
fn deadlines_shorter_than_the_batch_window_now_complete() {
    let dir = fault_store("shortdl");
    let mut server = start_server(
        &dir,
        ServerConfig {
            workers: 2,
            batcher: BatcherConfig {
                max_batch: 8,
                max_wait: Duration::from_secs(10),
            },
            ..Default::default()
        },
    );

    let key = GemmKey::with_dtypes(24, 24, 24, Dtype::F32, Dtype::F32);
    let mut rng = Rng::new(0xDD);
    let mut pending = Vec::new();
    for _ in 0..4 {
        let deadline = Instant::now() + Duration::from_millis(500);
        let (want, req) = small_request(&mut rng, &key, Some(deadline));
        pending.push((want, server.submit(req)));
    }
    for (want, rx) in &pending {
        let resp = rx.recv_timeout(Duration::from_secs(30)).unwrap();
        let out = resp.output.expect(
            "a deadline shorter than the configured window must now complete",
        );
        assert_eq!(out.data, *want);
        assert!(
            resp.total_latency < Duration::from_secs(10),
            "latency {:?} ate the ordering window",
            resp.total_latency
        );
    }
    let m = server.shutdown();
    assert_eq!(m.completed, 4);
    assert_eq!(m.deadline_expired, 0);
    assert_eq!(m.completed + m.failed + m.rejected, m.submitted);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Per-tenant quotas reject per tenant, not globally: with a quota of 2
/// admitted jobs and a held dispatcher, tenant "acme"'s third submit is
/// refused with an `ERR_QUEUE_FULL` error naming the tenant, while
/// "globex" and untenanted traffic keep flowing into the same queue.
#[test]
fn tenant_quota_exhaustion_rejects_per_tenant_not_globally() {
    let plan = FaultPlan { hold_dispatch_until_shutdown: true, ..Default::default() };
    let dir = fault_store("quota");
    let mut server = start_server(
        &dir,
        ServerConfig {
            workers: 1,
            queue_capacity: 16,
            admission: AdmissionConfig { tenant_quota: 2 },
            faults: plan,
            ..Default::default()
        },
    );

    let key = GemmKey::with_dtypes(24, 24, 24, Dtype::F32, Dtype::F32);
    let mut rng = Rng::new(0x7A);
    let acme = || SubmitOpts {
        tenant: Some("acme".to_string()),
        priority: Priority::Normal,
    };
    let globex = || SubmitOpts {
        tenant: Some("globex".to_string()),
        priority: Priority::Normal,
    };

    let mut admitted = Vec::new();
    // acme fills its quota...
    for _ in 0..2 {
        let (want, req) = small_request(&mut rng, &key, None);
        admitted.push((want, server.submit_with(req, acme())));
    }
    // ...then gets per-tenant rejections, synchronously, naming it.
    for _ in 0..3 {
        let (_, req) = small_request(&mut rng, &key, None);
        let rx = server.submit_with(req, acme());
        let resp = rx
            .try_recv()
            .expect("over-quota submit must be rejected synchronously");
        let msg = format!("{:#}", resp.output.unwrap_err());
        assert!(msg.contains(ERR_QUEUE_FULL), "{msg}");
        assert!(msg.contains("acme"), "rejection must name the tenant: {msg}");
        assert!(msg.contains("quota"), "{msg}");
    }
    // The queue itself is nowhere near full: globex and untenanted
    // traffic still admit.
    for _ in 0..2 {
        let (want, req) = small_request(&mut rng, &key, None);
        admitted.push((want, server.submit_with(req, globex())));
    }
    let (want, req) = small_request(&mut rng, &key, None);
    admitted.push((want, server.submit(req)));
    assert_eq!(server.queue_depth(), 5, "2 acme + 2 globex + 1 untenanted");

    let mid = server.metrics();
    assert_eq!(mid.rejected, 3);
    assert_eq!(mid.per_tenant_rejected["acme"], 3);
    assert!(
        !mid.per_tenant_rejected.contains_key("globex"),
        "globex was never rejected: {:?}",
        mid.per_tenant_rejected
    );

    let m = server.shutdown();
    for (want, rx) in &admitted {
        let out = rx.try_recv().expect("admitted job lost").output.unwrap();
        assert_eq!(out.data, *want);
    }
    assert_eq!(m.completed, 5);
    assert_eq!(m.rejected, 3);
    assert_eq!(m.completed + m.failed + m.rejected, m.submitted);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Dispatch order under contention is priority tier first, earliest
/// effective deadline within a tier — observable end-to-end through the
/// per-response queue waits when every release is a single job through
/// a single busy device.  A 40 ms injected execution per job spaces the
/// releases far enough apart that the ordering comparison is robust on
/// a noisy CI host.
#[test]
fn dispatch_order_is_priority_then_deadline_under_load() {
    let seed = seed_from_env(0xEDF);
    eprintln!("fault seed: {seed:#x} (replay: MLIR_GEMM_FAULT_SEED={seed})");
    let plan = FaultPlan {
        seed,
        slow_exec_one_in: 1,
        slow_exec: Duration::from_millis(40),
        ..Default::default()
    };
    let dir = fault_store("edf");
    let mut server = start_server(
        &dir,
        ServerConfig {
            workers: 1,
            batcher: BatcherConfig {
                max_batch: 1,
                // Ordering slack only: no-deadline jobs sort as if due
                // 10 s out, so explicit deadlines always beat them
                // within a tier.
                max_wait: Duration::from_secs(10),
            },
            faults: plan,
            ..Default::default()
        },
    );

    let key = GemmKey::with_dtypes(24, 24, 24, Dtype::F32, Dtype::F32);
    let mut rng = Rng::new(0xED);
    let prio = |p: Priority| SubmitOpts { tenant: None, priority: p };

    // Plug the single device, then pile up contenders while it runs.
    let (_, plug) = small_request(&mut rng, &key, None);
    let plug_rx = server.submit(plug);
    std::thread::sleep(Duration::from_millis(4));

    // Submission order is deliberately the *reverse* of the expected
    // dispatch order; 40 ms of plug execution remain, so all four are
    // in the scheduler before the device frees.
    let (_, low_req) = small_request(&mut rng, &key, None);
    let low = server.submit_with(low_req, prio(Priority::Low));
    let far_deadline = Instant::now() + Duration::from_secs(5);
    let (_, far_req) = small_request(&mut rng, &key, Some(far_deadline));
    let far = server.submit(far_req);
    let near_deadline = Instant::now() + Duration::from_secs(2);
    let (_, near_req) = small_request(&mut rng, &key, Some(near_deadline));
    let near = server.submit(near_req);
    let (_, high_req) = small_request(&mut rng, &key, None);
    let high = server.submit_with(high_req, prio(Priority::High));

    let wait_of = |rx: &std::sync::mpsc::Receiver<
        mlir_gemm::coordinator::GemmResponse,
    >| {
        let resp = rx.recv_timeout(Duration::from_secs(120)).unwrap();
        resp.output.expect("contended job must complete");
        resp.queue_wait
    };
    plug_rx
        .recv_timeout(Duration::from_secs(120))
        .unwrap()
        .output
        .expect("plug must complete");
    let (w_high, w_near, w_far, w_low) =
        (wait_of(&high), wait_of(&near), wait_of(&far), wait_of(&low));
    let margin = Duration::from_millis(20);
    assert!(
        w_high + margin < w_near,
        "high tier must dispatch before any normal job: {w_high:?} vs {w_near:?}"
    );
    assert!(
        w_near + margin < w_far,
        "within a tier the earlier deadline goes first: {w_near:?} vs {w_far:?}"
    );
    assert!(
        w_far + margin < w_low,
        "low tier dispatches last: {w_far:?} vs {w_low:?}"
    );

    let m = server.shutdown();
    assert_eq!(m.completed, 5);
    assert_eq!(m.per_priority["high"].released, 1);
    assert_eq!(m.per_priority["low"].released, 1);
    assert_eq!(m.per_priority["normal"].released, 3);
    let hi = m.per_priority["high"].queue_wait.as_ref().unwrap();
    let lo = m.per_priority["low"].queue_wait.as_ref().unwrap();
    assert!(
        hi.p50 < lo.p50,
        "per-priority queue-wait rollup must reflect the tier order: \
         high {} vs low {}",
        hi.p50,
        lo.p50
    );
    assert_eq!(m.completed + m.failed + m.rejected, m.submitted);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Bursty multi-tenant, multi-priority traffic against a tiny queue,
/// tight tenant quotas, and a seeded poison/jitter schedule, with
/// shutdown racing the last burst: every response channel answers, the
/// response-side tallies match the metrics buckets exactly, the
/// accounting identity holds, and the per-priority submit counts sum to
/// the global total.
#[test]
fn bursty_quota_and_fault_storm_keeps_accounting_exact() {
    silence_injected_panics();
    let seed = seed_from_env(0xB5457);
    eprintln!("fault seed: {seed:#x} (replay: MLIR_GEMM_FAULT_SEED={seed})");
    let plan = FaultPlan {
        seed,
        poison_one_in: 9,
        slow_exec_one_in: 4,
        slow_exec: Duration::from_millis(1),
        delay_reply_one_in: 5,
        delay_reply: Duration::from_millis(1),
        ..Default::default()
    };
    let dir = fault_store("burststorm");
    let server = start_server(
        &dir,
        ServerConfig {
            workers: 2,
            queue_capacity: 4,
            admission: AdmissionConfig { tenant_quota: 3 },
            faults: plan,
            ..Default::default()
        },
    );

    let key = GemmKey::with_dtypes(24, 24, 24, Dtype::F32, Dtype::F32);
    const CLIENTS: u64 = 3;
    const BURSTS: usize = 4;
    const BURST_LEN: usize = 4;
    let tiers = [Priority::High, Priority::Normal, Priority::Low];
    let server = Mutex::new(server);
    let rxs = Mutex::new(Vec::new());
    std::thread::scope(|scope| {
        for cid in 0..CLIENTS {
            let server = &server;
            let rxs = &rxs;
            let key = &key;
            let tiers = &tiers;
            scope.spawn(move || {
                let mut rng = Rng::new(0xB0B + cid);
                let tenant = format!("tenant{}", cid % 2);
                for burst in 0..BURSTS {
                    // Whole burst back-to-back, then a gap: the shape
                    // that overflows a capacity-4 queue and a quota of
                    // 3 in spikes rather than steadily.
                    for i in 0..BURST_LEN {
                        let (want, req) = small_request(&mut rng, key, None);
                        let opts = SubmitOpts {
                            tenant: Some(tenant.clone()),
                            priority: tiers[(burst + i) % tiers.len()],
                        };
                        let rx = server.lock().unwrap().submit_with(req, opts);
                        rxs.lock().unwrap().push((want, rx));
                    }
                    std::thread::sleep(Duration::from_millis(2));
                }
            });
        }
        scope.spawn(|| {
            std::thread::sleep(Duration::from_millis(5));
            let _ = server.lock().unwrap().shutdown();
        });
    });

    let rxs = rxs.into_inner().unwrap();
    assert_eq!(rxs.len(), CLIENTS as usize * BURSTS * BURST_LEN);
    let (mut completed, mut rejected, mut failed) = (0u64, 0u64, 0u64);
    let mut peak_depth = 0usize;
    for (want, rx) in &rxs {
        let resp = rx
            .recv_timeout(Duration::from_secs(120))
            .expect("burst storm dropped a response channel");
        peak_depth = peak_depth.max(resp.queue_depth);
        match resp.output {
            Ok(out) => {
                assert_eq!(out.data, *want, "stormy success must stay exact");
                completed += 1;
            }
            Err(e) => {
                let msg = format!("{e:#}");
                if msg.contains(ERR_QUEUE_FULL) {
                    rejected += 1;
                } else {
                    assert!(
                        msg.contains(ERR_POISONED) || msg.contains(ERR_SHUTDOWN),
                        "failure must be an explicit, classified error: {msg}"
                    );
                    failed += 1;
                }
            }
        }
    }
    // The depth signal is incremented before try_send and decremented by
    // the dispatcher just after recv, so an admitted request can observe
    // at most capacity + 1 (one job recv'd but not yet decremented) —
    // never an unbounded value.
    assert!(
        peak_depth <= 4 + 1,
        "backpressure signal must stay bounded by the configured capacity: {peak_depth}"
    );

    let m = server.into_inner().unwrap().metrics();
    assert_eq!(m.submitted, rxs.len() as u64);
    assert_eq!(m.completed, completed);
    assert_eq!(m.rejected, rejected);
    assert_eq!(m.failed, failed);
    assert_eq!(m.completed + m.failed + m.rejected, m.submitted);
    let tier_submitted: u64 = m.per_priority.values().map(|p| p.submitted).sum();
    assert_eq!(
        tier_submitted, m.submitted,
        "every submit belongs to exactly one priority tier"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// The full seeded jitter schedule — slow executions, delayed routing,
/// delayed replies, and deterministic poison — under a shutdown racing
/// the clients: every channel still gets an answer, every failure is
/// one of the explicit error classes, and the accounting identity is
/// exact.
#[test]
fn seeded_jitter_with_poison_and_shutdown_keeps_accounting_exact() {
    silence_injected_panics();
    let seed = seed_from_env(0xCAFE);
    eprintln!("fault seed: {seed:#x} (replay: MLIR_GEMM_FAULT_SEED={seed})");
    let plan = FaultPlan {
        seed,
        poison_one_in: 7,
        slow_exec_one_in: 4,
        slow_exec: Duration::from_millis(2),
        delay_route_one_in: 3,
        delay_route: Duration::from_millis(1),
        delay_reply_one_in: 3,
        delay_reply: Duration::from_millis(1),
        ..Default::default()
    };
    let dir = fault_store("jitter");
    let server = start_server(
        &dir,
        ServerConfig { workers: 3, faults: plan, ..Default::default() },
    );

    let key = GemmKey::with_dtypes(24, 24, 24, Dtype::F32, Dtype::F32);
    const CLIENTS: u64 = 3;
    const PER_CLIENT: usize = 8;
    let server = Mutex::new(server);
    let rxs = Mutex::new(Vec::new());
    std::thread::scope(|scope| {
        for cid in 0..CLIENTS {
            let server = &server;
            let rxs = &rxs;
            let key = &key;
            scope.spawn(move || {
                let mut rng = Rng::new(0x1177 + cid);
                for _ in 0..PER_CLIENT {
                    let (want, req) = small_request(&mut rng, key, None);
                    let rx = server.lock().unwrap().submit(req);
                    rxs.lock().unwrap().push((want, rx));
                }
            });
        }
        scope.spawn(|| {
            std::thread::sleep(Duration::from_millis(2));
            let _ = server.lock().unwrap().shutdown();
        });
    });

    let rxs = rxs.into_inner().unwrap();
    assert_eq!(rxs.len(), CLIENTS as usize * PER_CLIENT);
    let mut completed = 0u64;
    let mut failed = 0u64;
    for (want, rx) in &rxs {
        let resp = rx
            .recv_timeout(Duration::from_secs(120))
            .expect("jitter schedule dropped a response channel");
        match resp.output {
            Ok(out) => {
                assert_eq!(out.data, *want, "jittered success must stay exact");
                completed += 1;
            }
            Err(e) => {
                let msg = format!("{e:#}");
                assert!(
                    msg.contains(ERR_POISONED) || msg.contains(ERR_SHUTDOWN),
                    "failure must be an explicit, classified error: {msg}"
                );
                failed += 1;
            }
        }
    }
    let m = server.into_inner().unwrap().metrics();
    assert_eq!(m.submitted, rxs.len() as u64);
    assert_eq!(m.completed, completed);
    assert_eq!(m.failed, failed);
    assert_eq!(m.rejected, 0);
    assert_eq!(m.completed + m.failed + m.rejected, m.submitted);
    let _ = std::fs::remove_dir_all(&dir);
}
