//! Offline in-repo shim for the `anyhow` crate.
//!
//! The build environment has no crates.io access, so this workspace vendors
//! the small slice of the anyhow API its code actually uses: the opaque
//! [`Error`] type with a context chain, the [`Result`] alias, the
//! [`Context`] extension trait, and the `anyhow!` / `bail!` / `ensure!`
//! macros.  Semantics mirror the real crate where it matters:
//!
//! * `Display` prints the outermost message; the alternate form (`{:#}`)
//!   joins the whole chain with `": "`;
//! * `Debug` prints the message plus a `Caused by:` list;
//! * any `std::error::Error + Send + Sync + 'static` converts into
//!   [`Error`] via `?`;
//! * `Error` itself does NOT implement `std::error::Error` (exactly like
//!   the real crate, which frees the blanket `From` impl).

use std::fmt;

/// Context chain, outermost message first.
pub struct Error {
    chain: Vec<String>,
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Create an error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            chain: vec![message.to_string()],
        }
    }

    fn wrap<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context chain, outermost first (for tests/diagnostics).
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(&self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain[0])?;
        if self.chain.len() > 1 {
            f.write_str("\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(err: E) -> Error {
        let mut chain = vec![err.to_string()];
        let mut source = err.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// Extension trait adding context to fallible results (and options).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().wrap(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or printable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        let err = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        Err(err).context("reading store")
    }

    #[test]
    fn display_and_alternate() {
        let e = io_fail().unwrap_err();
        assert_eq!(e.to_string(), "reading store");
        assert_eq!(format!("{e:#}"), "reading store: gone");
    }

    #[test]
    fn debug_lists_causes() {
        let e = io_fail().unwrap_err();
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by:"), "{dbg}");
        assert!(dbg.contains("gone"), "{dbg}");
    }

    #[test]
    fn macros_construct_and_bail() {
        fn inner(flag: bool) -> Result<u32> {
            ensure!(flag, "flag was {flag}");
            bail!("unreachable {}", 42);
        }
        assert_eq!(inner(false).unwrap_err().to_string(), "flag was false");
        assert_eq!(inner(true).unwrap_err().to_string(), "unreachable 42");
        let e = anyhow!("plain");
        assert_eq!(e.to_string(), "plain");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse() -> Result<i32> {
            let v: i32 = "12x".parse()?;
            Ok(v)
        }
        assert!(parse().is_err());
    }

    #[test]
    fn option_context() {
        let none: Option<u8> = None;
        let e = none.with_context(|| "missing thing").unwrap_err();
        assert_eq!(e.root_cause(), "missing thing");
    }
}
