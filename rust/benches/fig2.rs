//! Figure 2 reproduction: mixed-precision (f16 in, f32 accumulate) TFLOPs
//! vs cuBLAS across square sizes 1024..16384 step 256 (thinned under
//! `MLIR_GEMM_SMOKE=1`).
//!
//! Simulated sweep on the modeled RTX 3090 (the paper's testbed) plus the
//! measured real-execution subset through the artifact runtime.

mod bench_common;

use mlir_gemm::harness::{figure2_sized, figure_sweep_measured};
use mlir_gemm::schedule::Dtype;
use mlir_gemm::sim::DeviceModel;

fn main() {
    let device = DeviceModel::rtx3090();
    bench_common::emit(&figure2_sized(&device, &bench_common::sweep_sizes()));
    if let Some(rt) = bench_common::open_runtime() {
        match figure_sweep_measured(
            &rt,
            Dtype::F32,
            bench_common::bench_config(),
            "figure2_measured",
        ) {
            Ok(out) => bench_common::emit(&out),
            Err(e) => eprintln!("measured subset failed: {e:#}"),
        }
    }
}
