//! Sharded multi-device scaling curve: one GEMM split across 1..=8
//! device contexts ([`mlir_gemm::coordinator::ShardPool`]), measured
//! speedup against the modeled speedup from the per-device performance
//! models.  The device contexts are host threads here, so the measured
//! curve reflects the real fan-out/reduce overheads of the sharding
//! engine while the modeled curve reflects the paper's GPU.

mod bench_common;

use mlir_gemm::coordinator::{modeled_speedup, ShardPlan, ShardPool};
use mlir_gemm::harness::{bar_chart, measure, CsvTable, FigureOutput};
use mlir_gemm::runtime::{Epilogue, Program, Tensor};
use mlir_gemm::schedule::{Dtype, Schedule};
use mlir_gemm::sim::DeviceModel;
use mlir_gemm::util::prng::Rng;

fn main() {
    let size: usize = if bench_common::smoke() { 256 } else { 1024 };
    let (m, n, k) = (size, size, size);
    let cfg = bench_common::bench_config();
    let device_counts = [1usize, 2, 4, 8];

    let program = Program::Gemm {
        m,
        n,
        k,
        dtype_in: Dtype::F16,
        dtype_acc: Dtype::F32,
        epilogue: Epilogue::None,
        fused: true,
    };
    let schedule =
        Schedule::optimized(m, n, k, Dtype::F32, (64, 64, 64), (32, 32, 32))
            .expect("bench size must fit the tile");
    let mut rng = Rng::new(5);
    let a = Tensor { shape: vec![m, k], data: rng.normal_matrix(m, k) };
    let b = Tensor { shape: vec![k, n], data: rng.normal_matrix(k, n) };
    let c = Tensor::zeros(vec![m, n]);

    let mut table = CsvTable::new(&[
        "devices",
        "p50_seconds",
        "measured_speedup",
        "modeled_speedup",
        "max_mean_shard_sec",
    ]);
    let mut bars: Vec<(String, f64)> = Vec::new();
    let mut baseline_p50 = 0.0f64;
    let mut reference: Option<Tensor> = None;

    for &devices in &device_counts {
        let pool = ShardPool::homogeneous(&DeviceModel::rtx3090(), devices);
        let plan = ShardPlan::rows(m, n, k, devices, 1);
        // correctness guard: every width must produce the 1-device result
        let out = pool
            .execute(&program, &plan, &a, &b, &c, None)
            .expect("sharded execution failed");
        match &reference {
            None => reference = Some(out),
            Some(r) => assert_eq!(
                r.data, out.data,
                "{devices}-device result drifted from 1-device"
            ),
        }
        let summary = measure(cfg, || {
            pool.execute(&program, &plan, &a, &b, &c, None).map(|_| ())
        })
        .expect("measurement failed");
        let stats = pool.shutdown();
        // Mean per-shard execution time on the busiest device: comparable
        // to p50_seconds (busy_sec alone would sum warmup + every
        // iteration).
        let busiest = stats
            .iter()
            .filter(|s| s.tasks > 0)
            .map(|s| s.busy_sec / s.tasks as f64)
            .fold(0.0f64, f64::max);
        if devices == 1 {
            baseline_p50 = summary.p50;
        }
        let measured_speedup = baseline_p50 / summary.p50.max(1e-12);
        let models: Vec<DeviceModel> = vec![DeviceModel::rtx3090(); devices];
        let modeled = modeled_speedup(&schedule, &plan, &models);
        table.row(vec![
            devices.to_string(),
            format!("{:.6}", summary.p50),
            format!("{measured_speedup:.3}"),
            format!("{modeled:.3}"),
            format!("{busiest:.6}"),
        ]);
        bars.push((format!("{devices} dev"), measured_speedup));
    }

    let bar_refs: Vec<(&str, f64)> =
        bars.iter().map(|(l, v)| (l.as_str(), *v)).collect();
    let chart = bar_chart(
        &format!("measured speedup, {size}^3 row-sharded GEMM"),
        &bar_refs,
        40,
    );
    let output = FigureOutput {
        name: "sharding_scaling",
        table,
        chart,
        summary: format!(
            "row-sharded {size}^3 GEMM across 1..=8 device contexts; \
             measured vs modeled speedup (modeled: per-device rtx3090)"
        ),
    };
    bench_common::emit(&output);
}
