//! Design-choice ablation bench (DESIGN.md): would the paper's conclusions
//! change on a data-center Ampere (A100, full-rate f32 accumulate)?
//! Regenerates the fig2-style ratio band and the fig3 ladder on both
//! device models side by side.

mod bench_common;

use mlir_gemm::harness::{ablation_schedule, figure_sweep, ABLATION_LABELS};
use mlir_gemm::schedule::Dtype;
use mlir_gemm::sim::{simulate, DeviceModel};

fn main() {
    let step = if bench_common::smoke() { 4096 } else { 1024 };
    let sizes: Vec<usize> = (1024..=16384).step_by(step).collect();
    for device in [DeviceModel::rtx3090(), DeviceModel::a100()] {
        println!("##### device: {} #####", device.name);
        let f = figure_sweep(&device, Dtype::F32, &sizes, "fig2_device_ablation");
        println!("{}", f.summary);
        println!("ablation ladder at 8192 (TFLOPs):");
        for level in 0..8u8 {
            let r = simulate(&ablation_schedule(level, 8192), &device);
            println!("  {:<24} {:>8.2}", ABLATION_LABELS[level as usize], r.tflops);
        }
        println!();
    }
    println!(
        "observation: the ladder ordering is device-independent; the fp16\n\
         advantage (fig4) shrinks on A100 because f32 accumulate is full rate."
    );
}
