//! Figure 3 reproduction: the optimization ablation at M=N=K=8192
//! (mixed precision), optimizations enabled incrementally, plus the
//! measured ablation ladder over the built artifacts.

mod bench_common;

use mlir_gemm::harness::{figure3, figure3_measured};
use mlir_gemm::sim::DeviceModel;

fn main() {
    let device = DeviceModel::rtx3090();
    bench_common::emit(&figure3(&device));
    if let Some(rt) = bench_common::open_runtime() {
        match figure3_measured(&rt, bench_common::bench_config()) {
            Ok(out) => bench_common::emit(&out),
            Err(e) => eprintln!("measured ablation failed: {e:#}"),
        }
    }
}
