//! Table 1 reproduction: library vs generated(WMMA) vs hand-written
//! kernels — measured through the identical runtime — plus the operator
//! fusion comparison (fused bias+ReLU vs dot + separate epilogue).

mod bench_common;

use mlir_gemm::harness::table1;
use mlir_gemm::sim::DeviceModel;

fn main() {
    let device = DeviceModel::rtx3090();
    match bench_common::open_runtime() {
        Some(rt) => match table1(&rt, &device, bench_common::bench_config()) {
            Ok(out) => bench_common::emit(&out),
            Err(e) => {
                eprintln!("table1 failed: {e:#}");
                std::process::exit(1);
            }
        },
        None => eprintln!("table1 needs built artifacts (`make artifacts`)"),
    }
}
