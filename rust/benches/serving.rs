//! Serving-tier latency bench: the continuous-batching dispatcher under
//! the three traffic shapes the fixed-window design got wrong, measured
//! end-to-end through a real [`Server`] over an inline two-variant
//! manifest (self-contained: no AOT artifacts needed).
//!
//! * **lone** — a single request against an idle server.  Under the old
//!   fixed-window dispatcher this paid the whole batching window before
//!   execution; under continuous batching it dispatches the moment a
//!   device is free.  The gate (enforced every run, smoke included):
//!   lone p50 must come in *under* the configured window.
//! * **paired** — two same-variant requests back-to-back.  The second
//!   joins the next micro-batch instead of waiting out a fresh window.
//! * **load** — the open-loop load generator: bursty zipfian arrivals
//!   from many client threads, mixed weight-bound / inline / composite-
//!   program traffic across tenants and priority tiers, reporting
//!   p50/p95/p99 and throughput plus the rejection/deadline buckets.
//!
//! Writes `reports/serving.json` every run; with
//! `MLIR_GEMM_RECORD_BASELINE=1` also refreshes the committed
//! `BENCH_serving.json` at the repo root.

mod bench_common;

use std::path::PathBuf;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use mlir_gemm::coordinator::{
    BatcherConfig, GemmKey, GemmRequest, Priority, Server, ServerConfig,
    SubmitOpts,
};
use mlir_gemm::harness::{
    run_load, LoadgenConfig, ProgramSpec,
};
use mlir_gemm::runtime::{Runtime, Tensor};
use mlir_gemm::schedule::Dtype;
use mlir_gemm::util::json::{self, Json};
use mlir_gemm::util::prng::Rng;
use mlir_gemm::util::stats::percentile;

/// The fixed batching window the old dispatcher always waited out.  The
/// scheduler now treats it as ordering slack only, so every latency
/// here should land far below it; the gate asserts at least "below".
const WINDOW: Duration = Duration::from_millis(25);

const MANIFEST: &str = r#"{
  "version": 1,
  "artifacts": [
    {
      "name": "small",
      "file": "small.tprog.json",
      "kind": "baseline",
      "inputs": [
        {"shape": [24, 24], "dtype": "f32"},
        {"shape": [24, 24], "dtype": "f32"},
        {"shape": [24, 24], "dtype": "f32"}
      ],
      "outputs": [{"shape": [24, 24], "dtype": "f32"}],
      "m": 24, "n": 24, "k": 24, "dtype_in": "f32", "dtype_acc": "f32"
    },
    {
      "name": "big",
      "file": "big.tprog.json",
      "kind": "baseline",
      "inputs": [
        {"shape": [128, 112], "dtype": "f32"},
        {"shape": [112, 96], "dtype": "f32"},
        {"shape": [128, 96], "dtype": "f32"}
      ],
      "outputs": [{"shape": [128, 96], "dtype": "f32"}],
      "m": 128, "n": 96, "k": 112, "dtype_in": "f32", "dtype_acc": "f32"
    },
    {
      "name": "tf_layer",
      "file": "tf_layer.tprog.json",
      "kind": "transformer",
      "inputs": [
        {"shape": [8, 16], "dtype": "f32"},
        {"shape": [16, 48], "dtype": "f32"},
        {"shape": [16, 16], "dtype": "f32"},
        {"shape": [16, 32], "dtype": "f32"},
        {"shape": [32], "dtype": "f32"},
        {"shape": [32, 16], "dtype": "f32"},
        {"shape": [16], "dtype": "f32"}
      ],
      "outputs": [{"shape": [8, 16], "dtype": "f32"}],
      "seq": 8, "d_model": 16, "d_ff": 32
    }
  ]
}"#;

const SMALL: &str = r#"{
  "format": "mlir-gemm-tprog-v1",
  "name": "small",
  "program": {
    "type": "gemm", "m": 24, "n": 24, "k": 24,
    "dtype_in": "f32", "dtype_acc": "f32", "epilogue": "none", "fused": true
  }
}"#;

const BIG: &str = r#"{
  "format": "mlir-gemm-tprog-v1",
  "name": "big",
  "program": {
    "type": "gemm", "m": 128, "n": 96, "k": 112,
    "dtype_in": "f32", "dtype_acc": "f32", "epilogue": "none", "fused": true
  }
}"#;

const TF: &str = r#"{
  "format": "mlir-gemm-tprog-v1",
  "name": "tf_layer",
  "program": {
    "type": "transformer",
    "seq": 8, "d_model": 16, "d_ff": 32, "n_heads": 4, "dtype_in": "f16"
  }
}"#;

fn start_server(workers: usize) -> Mutex<Server> {
    let dir = std::env::temp_dir()
        .join(format!("mlir_gemm_bench_serving_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.json"), MANIFEST).unwrap();
    std::fs::write(dir.join("small.tprog.json"), SMALL).unwrap();
    std::fs::write(dir.join("big.tprog.json"), BIG).unwrap();
    std::fs::write(dir.join("tf_layer.tprog.json"), TF).unwrap();
    let rt = Arc::new(Runtime::open(&dir).unwrap());
    Mutex::new(Server::start(
        rt,
        &mlir_gemm::sim::DeviceModel::rtx3090(),
        ServerConfig {
            workers,
            batcher: BatcherConfig { max_batch: 8, max_wait: WINDOW },
            queue_capacity: 256,
            ..Default::default()
        },
    ))
}

fn request(key: &GemmKey, rng: &mut Rng) -> GemmRequest {
    GemmRequest {
        key: key.clone(),
        a: Tensor::new(vec![key.m, key.k], rng.normal_matrix(key.m, key.k))
            .unwrap(),
        b: Some(
            Tensor::new(vec![key.k, key.n], rng.normal_matrix(key.k, key.n))
                .unwrap(),
        ),
        c: Tensor::new(vec![key.m, key.n], vec![0.0; key.m * key.n]).unwrap(),
        bias: None,
        use_baseline: false,
        deadline: None,
    }
}

struct ScenarioRow {
    scenario: &'static str,
    n: usize,
    p50_ms: f64,
    p95_ms: f64,
    p99_ms: f64,
    max_ms: f64,
}

fn summarize(scenario: &'static str, mut ms: Vec<f64>) -> ScenarioRow {
    assert!(!ms.is_empty(), "{scenario}: no samples");
    ms.sort_by(|a, b| a.partial_cmp(b).unwrap());
    ScenarioRow {
        scenario,
        n: ms.len(),
        p50_ms: percentile(&ms, 0.50),
        p95_ms: percentile(&ms, 0.95),
        p99_ms: percentile(&ms, 0.99),
        max_ms: *ms.last().unwrap(),
    }
}

fn main() {
    let smoke = bench_common::smoke();
    let iters = if smoke { 20 } else { 200 };
    let key = GemmKey::with_dtypes(24, 24, 24, Dtype::F32, Dtype::F32);
    let big_key = GemmKey::with_dtypes(128, 96, 112, Dtype::F32, Dtype::F32);
    let mut rng = Rng::new(0x5E41);

    // --- lone: one request, idle server, wait for the reply each time.
    let server = start_server(2);
    let mut lone_ms = Vec::with_capacity(iters);
    for _ in 0..iters {
        let rx = server.lock().unwrap().submit(request(&key, &mut rng));
        let resp = rx.recv_timeout(Duration::from_secs(60)).unwrap();
        resp.output.as_ref().expect("lone request must complete");
        lone_ms.push(resp.total_latency.as_secs_f64() * 1e3);
    }
    let lone = summarize("lone", lone_ms);

    // --- paired: two same-variant requests back-to-back; both latencies
    // count (the second must ride the next micro-batch, not a new
    // window).
    let mut paired_ms = Vec::with_capacity(2 * iters);
    for _ in 0..iters {
        let rx1 = server.lock().unwrap().submit(request(&key, &mut rng));
        let rx2 = server.lock().unwrap().submit(request(&key, &mut rng));
        for rx in [rx1, rx2] {
            let resp = rx.recv_timeout(Duration::from_secs(60)).unwrap();
            resp.output.as_ref().expect("paired request must complete");
            paired_ms.push(resp.total_latency.as_secs_f64() * 1e3);
        }
    }
    let paired = summarize("paired", paired_ms);

    // The headline gate: continuous batching must not charge the old
    // fixed window to a request that could start immediately.
    let window_ms = WINDOW.as_secs_f64() * 1e3;
    assert!(
        lone.p50_ms < window_ms,
        "lone-request p50 {:.3} ms did not beat the {window_ms:.0} ms \
         fixed window — the continuous-batching latency fix regressed",
        lone.p50_ms
    );
    assert!(
        paired.p50_ms < window_ms,
        "paired-request p50 {:.3} ms did not beat the {window_ms:.0} ms \
         fixed window",
        paired.p50_ms
    );

    // --- load: open-loop bursty zipfian mix across tenants and tiers.
    // Weights bound for both keys so the bound fraction is servable.
    {
        let s = server.lock().unwrap();
        let mut wrng = Rng::new(0x57);
        let small_b =
            Tensor::new(vec![24, 24], wrng.normal_matrix(24, 24)).unwrap();
        let big_b =
            Tensor::new(vec![112, 96], wrng.normal_matrix(112, 96)).unwrap();
        s.bind_weights(&key, &small_b).unwrap();
        s.bind_weights(&big_key, &big_b).unwrap();
    }
    let tf_shapes: [&[usize]; 7] = [
        &[8, 16],
        &[16, 48],
        &[16, 16],
        &[16, 32],
        &[32],
        &[32, 16],
        &[16],
    ];
    let mut prng = Rng::new(0x7F);
    let tf_inputs: Vec<Tensor> = tf_shapes
        .iter()
        .map(|shape| {
            let len: usize = shape.iter().product();
            Tensor::new(
                shape.to_vec(),
                (0..len).map(|_| prng.next_f32()).collect(),
            )
            .unwrap()
        })
        .collect();
    let load_cfg = LoadgenConfig {
        clients: if smoke { 8 } else { 200 },
        per_client: if smoke { 16 } else { 50 },
        mean_gap: Duration::from_micros(if smoke { 300 } else { 800 }),
        burst_prob: 0.15,
        burst_len: 4,
        zipf_s: 1.0,
        bound_fraction: 0.5,
        program_fraction: 0.1,
        program: Some(ProgramSpec {
            artifact: "tf_layer".to_string(),
            inputs: tf_inputs,
        }),
        tenants: vec!["acme".to_string(), "globex".to_string()],
        priorities: vec![Priority::High, Priority::Normal, Priority::Low],
        deadline: None,
        seed: 0xB0057,
    };
    let keys = [key.clone(), big_key.clone()];
    let started = Instant::now();
    let load = run_load(&server, &load_cfg, &keys);
    println!(
        "load scenario ({} clients x {} req): {}\n[{:.3} s total]\n",
        load_cfg.clients,
        load_cfg.per_client,
        load.render(),
        started.elapsed().as_secs_f64()
    );
    assert_eq!(
        load.submitted,
        load.completed + load.rejected + load.deadline_failed
            + load.other_failed,
        "loadgen accounting must balance"
    );

    // One direct high-priority probe after the storm: the server must
    // still answer promptly once the open loop drains.
    let rx = server.lock().unwrap().submit_with(
        request(&key, &mut rng),
        SubmitOpts { tenant: None, priority: Priority::High },
    );
    let probe = rx.recv_timeout(Duration::from_secs(60)).unwrap();
    probe.output.expect("post-load probe must complete");

    let snapshot = {
        let mut s = server.into_inner().unwrap();
        s.shutdown()
    };
    println!("{}", snapshot.report());

    // --- reports --------------------------------------------------------
    println!(
        "lone:   n {:4}  p50 {:.3} ms  p95 {:.3} ms  p99 {:.3} ms  max {:.3} ms",
        lone.n, lone.p50_ms, lone.p95_ms, lone.p99_ms, lone.max_ms
    );
    println!(
        "paired: n {:4}  p50 {:.3} ms  p95 {:.3} ms  p99 {:.3} ms  max {:.3} ms",
        paired.n, paired.p50_ms, paired.p95_ms, paired.p99_ms, paired.max_ms
    );
    println!(
        "gate: lone p50 {:.3} ms and paired p50 {:.3} ms < {:.0} ms window: ok",
        lone.p50_ms, paired.p50_ms, window_ms
    );

    let scenario_json = |r: &ScenarioRow| {
        json::obj(vec![
            ("scenario", json::s(r.scenario)),
            ("n", json::num(r.n as f64)),
            ("p50_ms", json::num((r.p50_ms * 1000.0).round() / 1000.0)),
            ("p95_ms", json::num((r.p95_ms * 1000.0).round() / 1000.0)),
            ("p99_ms", json::num((r.p99_ms * 1000.0).round() / 1000.0)),
            ("max_ms", json::num((r.max_ms * 1000.0).round() / 1000.0)),
        ])
    };
    let load_json = json::obj(vec![
        ("scenario", json::s("load")),
        ("clients", json::num(load_cfg.clients as f64)),
        ("submitted", json::num(load.submitted as f64)),
        ("completed", json::num(load.completed as f64)),
        ("rejected", json::num(load.rejected as f64)),
        ("deadline_failed", json::num(load.deadline_failed as f64)),
        ("other_failed", json::num(load.other_failed as f64)),
        ("throughput_rps", json::num(load.throughput_rps.round())),
        ("p50_ms", json::num((load.p50_ms * 1000.0).round() / 1000.0)),
        ("p95_ms", json::num((load.p95_ms * 1000.0).round() / 1000.0)),
        ("p99_ms", json::num((load.p99_ms * 1000.0).round() / 1000.0)),
        ("max_queue_depth", json::num(load.max_queue_depth as f64)),
    ]);
    let runner = std::env::var("MLIR_GEMM_RUNNER").unwrap_or_else(|_| {
        let threads = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        format!("unlabeled host, {threads} hw threads (set MLIR_GEMM_RUNNER to label)")
    });
    let doc = json::obj(vec![
        ("bench", json::s("serving")),
        ("smoke", Json::Bool(smoke)),
        ("window_ms", json::num(window_ms)),
        (
            "gate",
            json::s(
                "lone p50_ms and paired p50_ms must be < window_ms: a lone \
                 request (and the second of a back-to-back pair) dispatches \
                 as soon as a device frees instead of waiting out the old \
                 fixed batching window; asserted every run, smoke included",
            ),
        ),
        (
            "source",
            json::s(
                "rust/benches/serving.rs (make bench-serving); refresh the \
                 committed baseline with MLIR_GEMM_RECORD_BASELINE=1 \
                 cargo bench --bench serving",
            ),
        ),
        ("runner", json::s(&runner)),
        (
            "workload",
            json::s(
                "lone/paired: 24^3 f32 inline requests against an idle \
                 2-worker server, 25 ms ordering window; load: open-loop \
                 zipfian(s=1.0) bursty arrivals over {24^3, 128x96x112} \
                 with 50% weight-bound, 10% transformer-program traffic, \
                 2 tenants, 3 priority tiers",
            ),
        ),
        (
            "results",
            Json::Arr(vec![
                scenario_json(&lone),
                scenario_json(&paired),
                load_json,
            ]),
        ),
    ]);
    let text = format!("{doc}\n");
    let reports = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("reports");
    let _ = std::fs::create_dir_all(&reports);
    let json_path = reports.join("serving.json");
    match std::fs::write(&json_path, &text) {
        Ok(()) => println!("json -> {}", json_path.display()),
        Err(e) => eprintln!("warning: cannot write {}: {e}", json_path.display()),
    }
    if std::env::var("MLIR_GEMM_RECORD_BASELINE")
        .map(|v| v == "1")
        .unwrap_or(false)
    {
        let baseline =
            PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("BENCH_serving.json");
        match std::fs::write(&baseline, &text) {
            Ok(()) => println!("baseline -> {}", baseline.display()),
            Err(e) => {
                eprintln!("warning: cannot write {}: {e}", baseline.display())
            }
        }
    }
}
