//! L3 hot-path microbenchmark: how much the coordinator adds on top of
//! raw kernel execution (DESIGN.md §7 target: < 5% at 512^3).
//!
//! Measures (a) raw runtime.execute on the best 512 variant, (b) the same
//! request through the full server (route + batch + worker + channels),
//! and reports the overhead. Also times literal pack/unpack split.

mod bench_common;

use std::sync::Arc;
use std::time::Instant;

use mlir_gemm::coordinator::{GemmKey, GemmRequest, Server, ServerConfig};
use mlir_gemm::harness::{bench_artifact, random_inputs, BenchConfig};
use mlir_gemm::runtime::Tensor;
use mlir_gemm::sim::DeviceModel;
use mlir_gemm::util::prng::Rng;

fn main() {
    let Some(rt) = bench_common::open_runtime() else {
        eprintln!("runtime_overhead needs built artifacts");
        return;
    };
    let rt = Arc::new(rt);
    let device = DeviceModel::rtx3090();
    let size = 512usize;
    let mut server = Server::start(
        rt.clone(),
        &device,
        ServerConfig { rerank_measured: true, ..Default::default() },
    );
    let key = GemmKey::plain(size, size, size);
    let Some(best) = server.registry().best(&key).map(|e| e.artifact.clone()) else {
        eprintln!("no 512^3 variant (quick artifacts?); using 256");
        return;
    };

    // (a) raw artifact execution
    let artifact = rt.load(&best).unwrap();
    let inputs = random_inputs(&artifact, 3, 0.5);
    let cfg = BenchConfig { warmup: 2, iters: 10 };
    let raw = bench_artifact(&rt, &artifact, &inputs, cfg).unwrap();

    // (b) through the server
    let mut rng = Rng::new(4);
    let mk_req = |rng: &mut Rng| GemmRequest {
        key: key.clone(),
        a: Tensor::new(vec![size, size], rng.normal_matrix(size, size)).unwrap(),
        b: Some(Tensor::new(vec![size, size], rng.normal_matrix(size, size)).unwrap()),
        c: Tensor::zeros(vec![size, size]),
        bias: None,
        use_baseline: false,
        deadline: None,
    };
    for _ in 0..2 {
        server.call(mk_req(&mut rng)).unwrap().output.unwrap();
    }
    // Pre-build the requests: input generation is the client's cost, not
    // the coordinator's.
    let reqs: Vec<GemmRequest> = (0..10).map(|_| mk_req(&mut rng)).collect();
    let mut served = Vec::new();
    for req in reqs {
        let t = Instant::now();
        server.call(req).unwrap().output.unwrap();
        served.push(t.elapsed().as_secs_f64());
    }
    served.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let served_p50 = served[served.len() / 2];
    let overhead = served_p50 - raw.total.p50;

    println!("=== runtime_overhead (512^3, best variant: {best}) ===");
    println!(
        "raw execute:   exec {:.3} ms, pack {:.3} ms, total {:.3} ms",
        raw.exec.mean * 1e3,
        raw.pack.mean * 1e3,
        raw.total.mean * 1e3
    );
    println!("served (e2e):  {:.3} ms (p50)", served_p50 * 1e3);
    println!(
        "coordinator overhead: {:.3} ms ({:.1}% of raw total p50; target < 5%)",
        overhead * 1e3,
        100.0 * overhead / raw.total.p50
    );
    server.shutdown();
}
