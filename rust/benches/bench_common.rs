//! Shared scaffolding for the bench binaries (criterion is not in the
//! offline vendor set; each bench is a `harness = false` binary that
//! prints its figure and writes the CSV under reports/).

#![allow(dead_code)] // each bench binary uses a subset of these helpers

use std::path::PathBuf;

use mlir_gemm::harness::FigureOutput;
use mlir_gemm::runtime::Runtime;

pub fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

pub fn open_runtime() -> Option<Runtime> {
    let dir = artifacts_dir()?;
    match Runtime::open(&dir) {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("note: cannot open runtime ({e:#}); measured subset skipped");
            None
        }
    }
}

pub fn emit(output: &FigureOutput) {
    println!("{}", output.render());
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("reports")
        .join(format!("{}.csv", output.name));
    if let Err(e) = output.table.write_to(&path) {
        eprintln!("warning: cannot write {}: {e}", path.display());
    } else {
        println!("csv -> {}\n", path.display());
    }
}
