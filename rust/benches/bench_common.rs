//! Shared scaffolding for the bench binaries (criterion is not in the
//! offline vendor set; each bench is a `harness = false` binary that
//! prints its figure and writes the CSV under reports/).

#![allow(dead_code)] // each bench binary uses a subset of these helpers

use std::path::PathBuf;

use mlir_gemm::harness::{BenchConfig, FigureOutput};
use mlir_gemm::runtime::Runtime;

/// True when `MLIR_GEMM_SMOKE` is set to anything but ""/"0": `make
/// bench-smoke` sets it so every bench runs a thinned sweep and cannot
/// silently bit-rot.
pub fn smoke() -> bool {
    std::env::var("MLIR_GEMM_SMOKE")
        .map(|v| !v.is_empty() && v != "0")
        .unwrap_or(false)
}

/// The fig2/fig4 size sweep: the paper's full 1024..=16384 step 256, or
/// a thin subset in smoke mode.
pub fn sweep_sizes() -> Vec<usize> {
    if smoke() {
        (1024..=16384).step_by(4096).collect()
    } else {
        mlir_gemm::harness::paper_sizes()
    }
}

/// Measurement protocol for the measured (artifact-backed) subsets.
pub fn bench_config() -> BenchConfig {
    if smoke() {
        BenchConfig { warmup: 1, iters: 2 }
    } else {
        BenchConfig::default()
    }
}

pub fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

pub fn open_runtime() -> Option<Runtime> {
    let dir = artifacts_dir()?;
    match Runtime::open(&dir) {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("note: cannot open runtime ({e:#}); measured subset skipped");
            None
        }
    }
}

pub fn emit(output: &FigureOutput) {
    println!("{}", output.render());
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("reports")
        .join(format!("{}.csv", output.name));
    if let Err(e) = output.table.write_to(&path) {
        eprintln!("warning: cannot write {}: {e}", path.display());
    } else {
        println!("csv -> {}\n", path.display());
    }
}
