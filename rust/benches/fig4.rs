//! Figure 4 reproduction: half-precision (f16 throughout) TFLOPs vs
//! cuBLAS across square sizes, including the library's inconsistent
//! behaviour beyond N=8848 (§4.2).

mod bench_common;

use mlir_gemm::harness::{figure4, figure_sweep_measured, BenchConfig};
use mlir_gemm::schedule::Dtype;
use mlir_gemm::sim::DeviceModel;

fn main() {
    let device = DeviceModel::rtx3090();
    bench_common::emit(&figure4(&device));
    if let Some(rt) = bench_common::open_runtime() {
        match figure_sweep_measured(&rt, Dtype::F16, BenchConfig::default(), "figure4_measured")
        {
            Ok(out) => bench_common::emit(&out),
            Err(e) => eprintln!("measured subset failed: {e:#}"),
        }
    }
}
