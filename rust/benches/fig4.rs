//! Figure 4 reproduction: half-precision (f16 throughout) TFLOPs vs
//! cuBLAS across square sizes, including the library's inconsistent
//! behaviour beyond N=8848 (§4.2).  Thinned under `MLIR_GEMM_SMOKE=1`.

mod bench_common;

use mlir_gemm::harness::{figure4_sized, figure_sweep_measured};
use mlir_gemm::schedule::Dtype;
use mlir_gemm::sim::DeviceModel;

fn main() {
    let device = DeviceModel::rtx3090();
    bench_common::emit(&figure4_sized(&device, &bench_common::sweep_sizes()));
    if let Some(rt) = bench_common::open_runtime() {
        match figure_sweep_measured(
            &rt,
            Dtype::F16,
            bench_common::bench_config(),
            "figure4_measured",
        ) {
            Ok(out) => bench_common::emit(&out),
            Err(e) => eprintln!("measured subset failed: {e:#}"),
        }
    }
}
