//! Micro-kernel engine throughput: naive vs tiled vs threaded vs the
//! explicit-SIMD nanokernel, GFLOP/s across GEMM problem sizes, with a
//! correctness cross-check per numerics class (scalar policies must
//! reproduce the naive kernel bit-exactly; the `simd:` row must pass the
//! fma_relaxed condition-scaled tolerance before it is timed) and a
//! machine-readable JSON record.
//!
//! The JSON lands in `reports/exec_kernel.json` on every run;
//! `MLIR_GEMM_RECORD_BASELINE=1` additionally refreshes the committed
//! baseline `BENCH_exec_kernel.json` at the repo root (the acceptance
//! record for the >= 3x-over-naive-at-1024^3 criterion on the CI runner
//! class).  `make bench-smoke` runs this binary like every other bench,
//! so the engine cannot bit-rot.

mod bench_common;

use std::path::PathBuf;
use std::time::Instant;

use mlir_gemm::harness::{bar_chart, CsvTable, FigureOutput};
use mlir_gemm::plan::{compile, GemmKey, PlanEnv};
use mlir_gemm::runtime::kernel::{self, Blocking, BOperand, KernelPolicy, PrepackedB};
use mlir_gemm::runtime::nanokernel::{self, Isa};
use mlir_gemm::runtime::{Program, Tensor};
use mlir_gemm::util::json::{self, Json};
use mlir_gemm::util::prng::Rng;

struct Row {
    size: usize,
    policy: String,
    seconds: f64,
    gflops: f64,
}

fn main() {
    let smoke = bench_common::smoke();
    // 512 is in both modes: bench-smoke asserts the auto-compiled plan
    // is never slower than naive there.
    let sizes: Vec<usize> = if smoke {
        vec![256, 512, 1024]
    } else {
        vec![256, 512, 1024, 2048]
    };
    let iters = if smoke { 2 } else { 5 };
    let threads = std::thread::available_parallelism()
        .map(|v| v.get())
        .unwrap_or(1);
    // The nanokernel row competes whenever detection yields an ISA (the
    // MLIR_GEMM_FORCE_ISA=scalar CI leg drops it); the perf gates below
    // additionally require the real FMA hardware — the portable fallback
    // proves correctness, not speed.
    let simd_isa = nanokernel::detect().ok().flatten();
    let simd_real = simd_isa
        .map(|isa| isa != Isa::Portable && nanokernel::hw_available(isa))
        .unwrap_or(false);
    // The detected (best) ISA rows first — the generic simd gates and the
    // JSON speedup summary key off the first simd row per size — then the
    // AVX2 body as its own row when AVX-512 won detection, so the
    // committed baseline records the whole nanokernel tier and the
    // avx512-over-avx2 gate below has both operands.
    let mut simd_isas: Vec<Isa> = Vec::new();
    if let Some(isa) = simd_isa {
        simd_isas.push(isa);
        if isa == Isa::Avx512 && nanokernel::hw_available(Isa::Avx2Fma) {
            simd_isas.push(Isa::Avx2Fma);
        }
    }

    let mut rows: Vec<Row> = Vec::new();
    for &size in &sizes {
        let (m, n, k) = (size, size, size);
        // The compiled plan for this shape (standalone environment, f32
        // operands like the bench data) competes as its own row.
        let auto_plan = compile(
            &GemmKey::with_dtypes(
                m,
                n,
                k,
                mlir_gemm::schedule::Dtype::F32,
                mlir_gemm::schedule::Dtype::F32,
            ),
            &PlanEnv::default(),
        )
        .expect("plan compilation is infallible without an override");
        let mut policies: Vec<(String, KernelPolicy)> = vec![
            ("naive".into(), KernelPolicy::Naive),
            ("tiled".into(), KernelPolicy::Tiled(Blocking::default())),
            ("threaded".into(), KernelPolicy::Threaded(Blocking::default(), 0)),
            (format!("plan:{}", auto_plan.kernel.name()), auto_plan.kernel),
        ];
        for &isa in &simd_isas {
            policies.push((
                format!("simd:{}", isa.name()),
                KernelPolicy::Simd(Blocking::default(), 0, isa),
            ));
        }
        let mut rng = Rng::new(0xEC + size as u64);
        let a = rng.normal_matrix(m, k);
        let b = rng.normal_matrix(k, n);
        let c = rng.normal_matrix(m, n);
        let flops = 2.0 * m as f64 * n as f64 * k as f64;
        let mut reference: Option<Vec<f32>> = None;
        for (name, policy) in policies {
            let mut out = c.clone();
            // one warmup (also the correctness run) + `iters` timed
            kernel::matmul(policy, &mut out, &a, &b, m, n, k);
            match &reference {
                None => reference = Some(out.clone()),
                // fma_relaxed rows are checked against their class
                // contract — the condition-scaled tolerance vs the naive
                // oracle — before a single timed iteration runs.
                Some(r) if matches!(policy, KernelPolicy::Simd(..)) => {
                    nanokernel::verify_fma_relaxed(&out, r, &a, &b, &c, None, m, n, k)
                        .unwrap_or_else(|e| {
                            panic!("{name} at {size}^3 violated the ULP contract: {e}")
                        });
                }
                Some(r) => {
                    let ok = r
                        .iter()
                        .zip(&out)
                        .all(|(x, y)| x.to_bits() == y.to_bits());
                    assert!(ok, "{name} at {size}^3 drifted from naive");
                }
            }
            let mut best = f64::INFINITY;
            for _ in 0..iters {
                out.copy_from_slice(&c);
                let t = Instant::now();
                kernel::matmul(policy, &mut out, &a, &b, m, n, k);
                best = best.min(t.elapsed().as_secs_f64());
            }
            rows.push(Row { size, policy: name, seconds: best, gflops: flops / best / 1e9 });
        }
    }

    // Bound-vs-inline at 512^3: B prepacked once (the weight-binding
    // serving path) against per-call packing, same tiled kernel.  Bit
    // check first, then the acceptance gate: bound throughput must be at
    // least inline throughput (5% slack for shared-runner noise — the
    // panel copy is small next to the 2*512^3 flops, so the honest
    // expectation is "at least as fast", not a large multiplier; the
    // serving win is the payload + cast + pack removed per request).
    {
        let size = 512usize;
        let mut rng = Rng::new(0xB17D);
        let a = rng.normal_matrix(size, size);
        let b = rng.normal_matrix(size, size);
        let c = rng.normal_matrix(size, size);
        let bs = Blocking::default();
        let policy = KernelPolicy::Tiled(bs);
        let pre = PrepackedB::pack(&b, size, size, bs);
        let flops = 2.0 * (size as f64).powi(3);
        let mut inline_out = c.clone();
        kernel::matmul(policy, &mut inline_out, &a, &b, size, size, size);
        let mut bound_out = c.clone();
        kernel::matmul_b(
            policy,
            &mut bound_out,
            &a,
            BOperand::Prepacked(&pre),
            size,
            size,
            size,
        );
        assert!(
            inline_out
                .iter()
                .zip(&bound_out)
                .all(|(x, y)| x.to_bits() == y.to_bits()),
            "prepacked B drifted from inline B at {size}^3"
        );
        let mut out = c.clone();
        let mut best_inline = f64::INFINITY;
        let mut best_bound = f64::INFINITY;
        for _ in 0..iters {
            out.copy_from_slice(&c);
            let t = Instant::now();
            kernel::matmul(policy, &mut out, &a, &b, size, size, size);
            best_inline = best_inline.min(t.elapsed().as_secs_f64());
            out.copy_from_slice(&c);
            let t = Instant::now();
            kernel::matmul_b(
                policy,
                &mut out,
                &a,
                BOperand::Prepacked(&pre),
                size,
                size,
                size,
            );
            best_bound = best_bound.min(t.elapsed().as_secs_f64());
        }
        assert!(
            best_bound <= best_inline * 1.05,
            "bound (prepacked) B slower than inline at {size}^3: \
             {best_bound:.6}s vs {best_inline:.6}s"
        );
        rows.push(Row {
            size,
            policy: "tiled:inline-B".into(),
            seconds: best_inline,
            gflops: flops / best_inline / 1e9,
        });
        rows.push(Row {
            size,
            policy: "tiled:bound-B".into(),
            seconds: best_bound,
            gflops: flops / best_bound / 1e9,
        });
    }

    // Transformer smoke (runs in smoke mode too): the graph-level
    // ProgramPlan path (shared QKV activation cast, lifetime-based
    // scratch arena, plan-driven op loop) against the seed hand loop it
    // replaced.  Bit check first — the default-conservative plan is
    // contractually bit-identical to the seed oracle — then the gate:
    // planned throughput must be at least the seed's (5% slack for
    // shared-runner noise; the win is allocations + redundant casts
    // removed, so "never slower" is the honest claim at this scale).
    {
        let (seq, d_model, d_ff, n_heads) = (64usize, 64usize, 128usize, 4usize);
        let program = Program::Transformer {
            seq,
            d_model,
            d_ff,
            n_heads,
            dtype_in: mlir_gemm::schedule::Dtype::F16,
        };
        let mut rng = Rng::new(0x7F0); // "tf0"
        let mut mk = |shape: Vec<usize>| {
            let len: usize = shape.iter().product();
            let data: Vec<f32> = (0..len).map(|_| rng.normal() as f32 * 0.1).collect();
            Tensor { shape, data }
        };
        let inputs: Vec<Tensor> = program
            .input_shapes()
            .into_iter()
            .map(&mut mk)
            .collect();
        let env = PlanEnv::default();
        let pplan = program
            .compile_program_plan(&env)
            .expect("transformer program plan compiles");
        let seed_out = program
            .execute_transformer_seed(&inputs, &env)
            .expect("seed hand loop executes");
        let planned_out = program
            .execute_program_planned(&inputs, &pplan)
            .expect("planned transformer executes");
        assert!(
            seed_out[0]
                .data
                .iter()
                .zip(&planned_out[0].data)
                .all(|(x, y)| x.to_bits() == y.to_bits()),
            "planned transformer drifted from the seed hand loop at \
             seq={seq} d_model={d_model} d_ff={d_ff} heads={n_heads}"
        );
        let flops = pplan.flops_per_item();
        let tf_iters = iters.max(3);
        let mut best_seed = f64::INFINITY;
        let mut best_planned = f64::INFINITY;
        for _ in 0..tf_iters {
            let t = Instant::now();
            let _ = program.execute_transformer_seed(&inputs, &env).unwrap();
            best_seed = best_seed.min(t.elapsed().as_secs_f64());
            let t = Instant::now();
            let _ = program.execute_program_planned(&inputs, &pplan).unwrap();
            best_planned = best_planned.min(t.elapsed().as_secs_f64());
        }
        assert!(
            best_planned <= best_seed * 1.05,
            "ProgramPlan-driven transformer ({best_planned:.6}s) slower than the \
             seed hand loop ({best_seed:.6}s) at seq={seq} d_model={d_model} \
             d_ff={d_ff} heads={n_heads}"
        );
        rows.push(Row {
            size: seq,
            policy: "transformer:seed".into(),
            seconds: best_seed,
            gflops: flops / best_seed / 1e9,
        });
        rows.push(Row {
            size: seq,
            policy: "transformer:planned".into(),
            seconds: best_planned,
            gflops: flops / best_planned / 1e9,
        });
    }

    // Acceptance gate (runs in smoke mode too): the auto-compiled plan
    // must never be slower than naive at 512^3 — the plan compiler's
    // whole point is that its decisions dominate the reference loop.
    // 5% slack absorbs shared-runner timing noise.
    {
        let naive_512 = rows
            .iter()
            .find(|r| r.size == 512 && r.policy == "naive")
            .expect("512^3 naive row");
        let plan_512 = rows
            .iter()
            .find(|r| r.size == 512 && r.policy.starts_with("plan:"))
            .expect("512^3 plan row");
        assert!(
            plan_512.seconds <= naive_512.seconds * 1.05,
            "auto-compiled plan ({}, {:.6}s) slower than naive ({:.6}s) at 512^3",
            plan_512.policy,
            plan_512.seconds,
            naive_512.seconds
        );
    }

    // Nanokernel gates, only where the FMA hardware really exists (the
    // portable fallback and the forced-scalar CI leg are correctness
    // paths, not perf claims).  Smoke mode: simd never slower than the
    // tiled scalar kernel at 512^3.  Full mode: the acceptance target —
    // fma_relaxed at 512^3 is >= 1.5x the tiled scalar kernel.
    if simd_real {
        let tiled_512 = rows
            .iter()
            .find(|r| r.size == 512 && r.policy == "tiled")
            .expect("512^3 tiled row");
        let simd_512 = rows
            .iter()
            .find(|r| r.size == 512 && r.policy.starts_with("simd:"))
            .expect("512^3 simd row");
        assert!(
            simd_512.seconds <= tiled_512.seconds * 1.05,
            "nanokernel ({}, {:.6}s) slower than tiled scalar ({:.6}s) at 512^3",
            simd_512.policy,
            simd_512.seconds,
            tiled_512.seconds
        );
        if !smoke {
            assert!(
                simd_512.gflops >= tiled_512.gflops * 1.5,
                "nanokernel ({}, {:.2} GFLOP/s) under 1.5x tiled scalar \
                 ({:.2} GFLOP/s) at 512^3",
                simd_512.policy,
                simd_512.gflops,
                tiled_512.gflops
            );
        }
    }

    // Tier-ordering gate: on hardware with both bodies, the AVX-512
    // nanokernel (4x32 zmm tile) must pay for its existence — >= 1.3x
    // the tuned AVX2 body (4x24 ymm tile) at 512^3.  Every skip is
    // explicit, never silent: a runner that stops exercising this gate
    // should say so in its log.
    {
        let avx512_512 = rows.iter().find(|r| r.size == 512 && r.policy == "simd:avx512");
        let avx2_512 = rows.iter().find(|r| r.size == 512 && r.policy == "simd:avx2");
        match (avx512_512, avx2_512) {
            (Some(wide), Some(narrow))
                if nanokernel::hw_available(Isa::Avx512)
                    && nanokernel::hw_available(Isa::Avx2Fma) =>
            {
                if smoke {
                    println!(
                        "skip: avx512-over-avx2 1.3x gate (smoke mode; measured \
                         {:.2} vs {:.2} GFLOP/s at 512^3)",
                        wide.gflops, narrow.gflops
                    );
                } else {
                    assert!(
                        wide.gflops >= narrow.gflops * 1.3,
                        "avx512 nanokernel ({:.2} GFLOP/s) under 1.3x the tuned \
                         avx2 body ({:.2} GFLOP/s) at 512^3",
                        wide.gflops,
                        narrow.gflops
                    );
                }
            }
            _ => println!(
                "skip: avx512-over-avx2 1.3x gate (host lacks avx512f+avx2 FMA \
                 hardware, or the probe was forced off)"
            ),
        }
    }

    // Regression floor for the tuned AVX2 body, scoped to baseline
    // refreshes (absolute GFLOP/s only compare on the pinned runner
    // class, like the 3x-at-1024^3 acceptance note below): the 4x24
    // retile must hold >= 1.15x the PR-6 4x16 body's committed 512^3
    // figure.
    if std::env::var("MLIR_GEMM_RECORD_BASELINE").map(|v| v == "1").unwrap_or(false) {
        // simd:avx2 at 512^3 from the committed BENCH_exec_kernel.json
        // as of the 4x16-tile nanokernel PR.
        const PR6_AVX2_GFLOPS_512: f64 = 55.3;
        match rows
            .iter()
            .find(|r| r.size == 512 && (r.policy == "simd:avx2" || r.policy == "simd:avx512"))
        {
            Some(_) => {
                let avx2 = rows.iter().find(|r| r.size == 512 && r.policy == "simd:avx2");
                match avx2 {
                    Some(r) if nanokernel::hw_available(Isa::Avx2Fma) => assert!(
                        r.gflops >= PR6_AVX2_GFLOPS_512 * 1.15,
                        "tuned avx2 body ({:.2} GFLOP/s) under 1.15x the PR-6 \
                         baseline ({PR6_AVX2_GFLOPS_512} GFLOP/s) at 512^3 — do \
                         not commit a regressed baseline",
                        r.gflops
                    ),
                    _ => println!(
                        "skip: tuned-avx2 1.15x baseline floor (no real avx2 row \
                         on this host)"
                    ),
                }
            }
            None => println!(
                "skip: tuned-avx2 1.15x baseline floor (no simd rows measured)"
            ),
        }
    }

    // Human-readable figure + CSV like every other bench.
    let mut table = CsvTable::new(&["size", "policy", "best_seconds", "gflops", "speedup_vs_naive"]);
    for row in &rows {
        let naive = rows
            .iter()
            .find(|r| r.size == row.size && r.policy == "naive")
            .map(|r| r.gflops)
            .unwrap_or(0.0);
        table.row(vec![
            row.size.to_string(),
            row.policy.to_string(),
            format!("{:.6}", row.seconds),
            format!("{:.3}", row.gflops),
            format!("{:.3}", if naive > 0.0 { row.gflops / naive } else { 0.0 }),
        ]);
    }
    let top = *sizes.last().unwrap();
    let bars: Vec<(String, f64)> = rows
        .iter()
        .filter(|r| r.size == top)
        .map(|r| (r.policy.to_string(), r.gflops))
        .collect();
    let bar_refs: Vec<(&str, f64)> = bars.iter().map(|(l, v)| (l.as_str(), *v)).collect();
    let output = FigureOutput {
        name: "exec_kernel",
        table,
        chart: bar_chart(&format!("GFLOP/s, {top}^3 f32 GEMM by kernel policy"), &bar_refs, 40),
        summary: format!(
            "micro-kernel engine throughput, naive vs tiled vs threaded vs the \
             auto-compiled plan vs the simd nanokernel ({threads} hw threads); \
             scalar policies bit-checked against naive, the simd row checked \
             against the fma_relaxed ULP contract before timing; plan asserted \
             never slower than naive at 512^3; bound (prepacked) B asserted \
             never slower than inline B at 512^3; simd asserted never slower \
             than tiled (and >= 1.5x in full mode) at 512^3 on FMA hardware; \
             avx512 asserted >= 1.3x the tuned avx2 body at 512^3 where both \
             exist (explicit skip line otherwise); the ProgramPlan-driven \
             transformer asserted bit-identical to and never slower than the \
             seed hand loop at seq=64"
        ),
    };
    bench_common::emit(&output);

    // Machine-readable record.
    let results: Vec<Json> = rows
        .iter()
        .map(|r| {
            json::obj(vec![
                ("size", json::num(r.size as f64)),
                ("policy", json::s(&r.policy)),
                ("best_seconds", json::num(r.seconds)),
                ("gflops", json::num((r.gflops * 1000.0).round() / 1000.0)),
            ])
        })
        .collect();
    let speedup_at = |size: usize, policy: &str| -> f64 {
        let naive = rows
            .iter()
            .find(|r| r.size == size && r.policy == "naive")
            .map(|r| r.gflops)
            .unwrap_or(0.0);
        let p = rows
            .iter()
            .find(|r| {
                r.size == size
                    && (r.policy == policy
                        || (policy == "plan" && r.policy.starts_with("plan:"))
                        || (policy == "simd" && r.policy.starts_with("simd:")))
            })
            .map(|r| r.gflops)
            .unwrap_or(0.0);
        if naive > 0.0 {
            (p / naive * 1000.0).round() / 1000.0
        } else {
            0.0
        }
    };
    let headline = sizes.iter().copied().find(|&s| s == 1024).unwrap_or(top);
    // Provenance keys are part of the baseline schema: a
    // MLIR_GEMM_RECORD_BASELINE refresh must not drop them from the
    // committed BENCH_exec_kernel.json.
    let runner = std::env::var("MLIR_GEMM_RUNNER").unwrap_or_else(|_| {
        format!("unlabeled host, {threads} hw threads (set MLIR_GEMM_RUNNER to label)")
    });
    let doc = json::obj(vec![
        ("bench", json::s("exec_kernel")),
        ("smoke", Json::Bool(smoke)),
        ("hw_threads", json::num(threads as f64)),
        (
            "policies",
            json::s(
                "naive | tiled (default blocking) | threaded (auto) | \
                 plan:<compiled> | simd:<isa> (fma_relaxed nanokernel; absent \
                 under MLIR_GEMM_FORCE_ISA=scalar) | transformer:seed / \
                 transformer:planned (graph-level ProgramPlan vs the hand loop, \
                 seq=64 d_model=64 d_ff=128 heads=4 f16)",
            ),
        ),
        (
            "source",
            json::s(
                "rust/benches/exec_kernel.rs (cargo bench); refresh the committed \
                 baseline with MLIR_GEMM_RECORD_BASELINE=1 cargo bench --bench exec_kernel",
            ),
        ),
        ("runner", json::s(&runner)),
        (
            "notes",
            json::s(
                "acceptance target: best engine policy >= 3x naive GFLOP/s at 1024^3 \
                 f32 on the 4-vCPU CI runner class; small-core/shared hosts may fall \
                 short at 1024^3 while clearing 3x at 2048^3 where B leaves the LLC",
            ),
        ),
        ("results", Json::Arr(results)),
        (
            "speedup_over_naive",
            json::obj(vec![
                ("size", json::num(headline as f64)),
                ("tiled", json::num(speedup_at(headline, "tiled"))),
                ("threaded", json::num(speedup_at(headline, "threaded"))),
                ("plan", json::num(speedup_at(headline, "plan"))),
                ("simd", json::num(speedup_at(headline, "simd"))),
            ]),
        ),
        (
            "speedup_over_naive_largest",
            json::obj(vec![
                ("size", json::num(top as f64)),
                ("tiled", json::num(speedup_at(top, "tiled"))),
                ("threaded", json::num(speedup_at(top, "threaded"))),
                ("plan", json::num(speedup_at(top, "plan"))),
                ("simd", json::num(speedup_at(top, "simd"))),
            ]),
        ),
    ]);
    let text = format!("{doc}\n");
    let reports = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("reports");
    let _ = std::fs::create_dir_all(&reports);
    let json_path = reports.join("exec_kernel.json");
    match std::fs::write(&json_path, &text) {
        Ok(()) => println!("json -> {}", json_path.display()),
        Err(e) => eprintln!("warning: cannot write {}: {e}", json_path.display()),
    }
    if std::env::var("MLIR_GEMM_RECORD_BASELINE").map(|v| v == "1").unwrap_or(false) {
        let baseline = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("BENCH_exec_kernel.json");
        match std::fs::write(&baseline, &text) {
            Ok(()) => println!("baseline -> {}", baseline.display()),
            Err(e) => eprintln!("warning: cannot write {}: {e}", baseline.display()),
        }
    }
}
