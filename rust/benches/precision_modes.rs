//! Precision-mode comparison (§2.3): the paper discusses f16, bf16 and
//! TF32 tensor-core modes — "tensor cores offer the same speed in both
//! BF16 and FP16 modes, while both are faster than TF32".  This bench
//! regenerates that ordering on the modeled RTX 3090 and the A100 using
//! the autotuner's best schedule per mode.

use mlir_gemm::schedule::{Dtype, Schedule};
use mlir_gemm::sim::{simulate, DeviceModel};

fn main() {
    let size = 8192usize;
    for device in [DeviceModel::rtx3090(), DeviceModel::a100()] {
        println!("##### device: {} (M=N=K={size}) #####", device.name);
        println!("{:>22} {:>10} {:>8}", "mode", "TFLOPs", "% f16");
        let mut f16_ref = 0.0;
        for (label, din, acc) in [
            ("f16 in / f16 acc", Dtype::F16, Dtype::F16),
            ("bf16 in / f16-rate acc", Dtype::Bf16, Dtype::F16),
            ("f16 in / f32 acc", Dtype::F16, Dtype::F32),
            ("tf32 (f32 in/f32 acc)", Dtype::F32, Dtype::F32),
        ] {
            let mut s = Schedule::optimized(
                size, size, size, acc, (128, 256, 32), (64, 64, 32),
            )
            .unwrap();
            s.dtype_in = din;
            let r = simulate(&s, &device);
            if f16_ref == 0.0 {
                f16_ref = r.tflops;
            }
            println!(
                "{label:>22} {:>10.2} {:>7.0}%",
                r.tflops,
                100.0 * r.tflops / f16_ref
            );
        }
        println!();
    }
    println!(
        "paper §2.3: bf16 == f16 speed; both faster than tf32; tf32 faster\n\
         than plain f32 CUDA-core matmul.  Ordering reproduced above."
    );
}
