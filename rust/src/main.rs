//! mlir-gemm CLI — leader entrypoint.
//!
//! Subcommands:
//!   serve      run the GEMM service on synthetic traffic, print metrics
//!   loadgen    open-loop bursty zipfian load against the server across
//!              tenants and priority tiers; prints latency percentiles,
//!              throughput, and the rejection/deadline buckets
//!   bench      regenerate a paper figure/table (fig2|fig3|fig4|table1|all)
//!   autotune   search the tile space for a problem size
//!   sim        simulate one kernel configuration
//!   plan       compile the execution plan for one GEMM (or the graph-level
//!              ProgramPlan for a *.tprog.json artifact path) and print it
//!   plans      emit compiled plans for every registry key to reports/
//!   plandb     print the shadow-promoted plan DB (measured SIMD winners
//!              persisted by serve; see docs/PLAN_SCHEMA.md)
//!   program-plans  emit graph-level ProgramPlans for composite artifacts
//!   run        execute one artifact by name on random inputs
//!   list       list artifacts in the manifest
//!   check-protocol  exhaustively model-check the coordinator protocol over
//!              bounded configurations + one deterministic fault replay
//!              against the real server; --bug re-introduces a known defect
//!              and expects its counterexample

use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use mlir_gemm::autotune;
use mlir_gemm::coordinator::{
    AdmissionConfig, GemmKey, GemmRequest, PlanDb, Priority, Registry, Server,
    ServerConfig, ShadowConfig, PLANDB_FORMAT,
};
use mlir_gemm::harness::{self, run_load, BenchConfig, LoadgenConfig};
use mlir_gemm::plan::{self, PlanEnv, PlanOverride};
use mlir_gemm::runtime::{KernelPolicy, Runtime, Tensor};
use mlir_gemm::schedule::{Dtype, Schedule};
use mlir_gemm::sim::{simulate, DeviceModel};
use mlir_gemm::util::cli::{usage, Args, Spec};
use mlir_gemm::util::prng::Rng;

const SPEC: &[Spec] = &[
    ("artifacts", true, "artifacts directory (default: ./artifacts)"),
    ("device", true, "device model: rtx3090 | a100 (default rtx3090)"),
    ("size", true, "problem size for autotune/sim (default 4096)"),
    ("acc", true, "accumulate dtype: f32 | f16 (default f32)"),
    ("in", true, "plan: input dtype f16 | bf16 | f32 (default f16)"),
    ("epilogue", true, "plan: none | bias | bias_relu (default none)"),
    ("tile", true, "tile as tbm,tbn,tbk (sim; default 128,128,64)"),
    ("warp", true, "warp tile as wm,wn,wk (sim; default 64,32,32)"),
    ("iters", true, "bench iterations (default 10)"),
    ("warmup", true, "bench warmup runs (default 2)"),
    ("requests", true, "serve: number of synthetic requests (default 64)"),
    ("workers", true, "serve: worker threads (default 2)"),
    ("devices", true, "serve: device contexts; >1 shards large GEMMs (default 1)"),
    ("plan", true, "plan override: auto|naive|tiled[:MC,KC,NC]|threaded[:MC,KC,NC[,T]]|simd[:ISA[:MC,KC,NC[,T]]] (simd opts into fma_relaxed numerics; see docs/PLAN_SCHEMA.md)"),
    ("bind", false, "serve: bind each shape's B as a constant weight at startup; traffic then ships A (+C) only"),
    ("refine", false, "plan: measured refinement pass over the compiled plan"),
    ("target", true, "autotune: gpu (modeled tile space) | cpu (measured block sweep); default gpu"),
    ("threads", true, "autotune --target cpu: threads for the threaded policy (default auto)"),
    ("out-dir", true, "bench/plans: directory for output (default reports/)"),
    ("measured", false, "bench: include real-execution subsets"),
    ("top", true, "autotune: show top-N candidates (default 8)"),
    ("clients", true, "check-protocol: model clients, 1..=5 (default 3); loadgen: client threads (default 32)"),
    ("zipf", true, "loadgen: zipf exponent over registry keys (default 1.0)"),
    ("mean-gap-us", true, "loadgen: mean open-loop inter-arrival gap per client, microseconds (default 500)"),
    ("burst-prob", true, "loadgen: probability an arrival opens a zero-gap burst (default 0.15)"),
    ("tenants", true, "loadgen: comma-separated tenant names to bill requests against (default none)"),
    ("tenant-quota", true, "loadgen: per-tenant admitted-job quota, 0 = off (default 0)"),
    ("deadline-ms", true, "loadgen: per-request latency budget in ms (default none)"),
    ("seed", true, "loadgen: workload seed (default 4269)"),
    ("jobs", true, "check-protocol: jobs in the real-server fault-replay leg (default 4)"),
    ("capacity", true, "check-protocol: model submit-queue capacity (default = clients); loadgen: server queue capacity (default 512)"),
    ("max-states", true, "check-protocol: state budget per scenario (default 2000000)"),
    ("bug", true, "check-protocol: re-introduce a defect and demand its counterexample: stop-flag | stale-rebind | no-containment | fifo-release"),
    ("help", false, "show usage"),
];

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = match Args::parse(argv, SPEC) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n\n{}", usage("mlir-gemm", "MLIR GPU GEMM reproduction", SPEC));
            std::process::exit(2);
        }
    };
    if args.flag("help") || args.positional.is_empty() {
        println!("{}", usage("mlir-gemm", "MLIR GPU GEMM reproduction", SPEC));
        println!(
            "subcommands: serve | loadgen | bench <fig2|fig3|fig4|table1|all> | \
             autotune | sim | plan <MxNxK | artifact.tprog.json> | plans | plandb | \
             program-plans | run <artifact> | list | check-protocol"
        );
        return;
    }
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn device(args: &Args) -> Result<DeviceModel> {
    let name = args.get_or("device", "rtx3090");
    DeviceModel::by_name(name).ok_or_else(|| anyhow!("unknown device {name:?}"))
}

fn acc(args: &Args) -> Result<Dtype> {
    let name = args.get_or("acc", "f32");
    Dtype::parse(name).ok_or_else(|| anyhow!("unknown dtype {name:?}"))
}

fn artifacts_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.get_or("artifacts", "artifacts"))
}

fn parse_triple(s: &str) -> Result<(usize, usize, usize)> {
    let parts: Vec<usize> = s
        .split(',')
        .map(|p| p.trim().parse::<usize>())
        .collect::<Result<_, _>>()
        .map_err(|_| anyhow!("expected three comma-separated integers, got {s:?}"))?;
    if parts.len() != 3 {
        bail!("expected three comma-separated integers, got {s:?}");
    }
    Ok((parts[0], parts[1], parts[2]))
}

fn bench_cfg(args: &Args) -> Result<BenchConfig> {
    Ok(BenchConfig {
        warmup: args.get_usize("warmup", 2)?,
        iters: args.get_usize("iters", 10)?,
    })
}

fn dispatch(args: &Args) -> Result<()> {
    match args.positional[0].as_str() {
        "list" => cmd_list(args),
        "sim" => cmd_sim(args),
        "autotune" => cmd_autotune(args),
        "bench" => cmd_bench(args),
        "serve" => cmd_serve(args),
        "loadgen" => cmd_loadgen(args),
        "plan" => cmd_plan(args),
        "plans" => cmd_plans(args),
        "plandb" => cmd_plandb(args),
        "program-plans" => cmd_program_plans(args),
        "run" => cmd_run(args),
        "check-protocol" => cmd_check_protocol(args),
        other => bail!("unknown subcommand {other:?}"),
    }
}

fn plan_override(args: &Args) -> Result<PlanOverride> {
    args.get("plan")
        .map(PlanOverride::parse)
        .transpose()
        .map(|o| o.unwrap_or(PlanOverride::Auto))
}

fn cmd_list(args: &Args) -> Result<()> {
    let rt = Runtime::open(&artifacts_dir(args))?;
    println!("{:<64} {:<12} inputs", "name", "kind");
    for a in rt.artifacts() {
        println!(
            "{:<64} {:<12} {}",
            a.name,
            format!("{:?}", a.kind).to_lowercase(),
            a.inputs
                .iter()
                .map(|s| format!("{:?}", s.shape))
                .collect::<Vec<_>>()
                .join(" ")
        );
    }
    println!("{} artifacts", rt.artifacts().len());
    Ok(())
}

fn cmd_sim(args: &Args) -> Result<()> {
    let d = device(args)?;
    let size = args.get_usize("size", 4096)?;
    let tb = parse_triple(args.get_or("tile", "128,128,64"))?;
    let warp = parse_triple(args.get_or("warp", "64,32,32"))?;
    let s = Schedule::optimized(size, size, size, acc(args)?, tb, warp)
        .map_err(|e| anyhow!("{e}"))?;
    let r = simulate(&s, &d);
    println!("schedule: {}", s.name);
    println!("device:   {} ({} SMs @ {:.0} MHz)", d.name, d.sms, d.clock_hz / 1e6);
    println!("tflops:   {:.2} ({:.1}% of tensor-core peak)", r.tflops, r.frac_of_peak * 100.0);
    println!("time:     {:.3} ms", r.seconds * 1e3);
    println!("bound:    {}", r.bound);
    println!(
        "occupancy: {} blocks/SM (limited by {}), {} active SMs, {} wave(s), scheduler util {:.0}%",
        r.occupancy.blocks_resident_per_sm,
        r.occupancy.limited_by,
        r.occupancy.active_sms,
        r.occupancy.waves,
        r.occupancy.scheduler_util * 100.0
    );
    println!(
        "per-iter cycles: compute {:.0}, memory {:.0}; smem {} B",
        r.compute_cycles_per_iter, r.memory_cycles_per_iter, s.smem_bytes
    );
    Ok(())
}

fn cmd_autotune(args: &Args) -> Result<()> {
    if args.get_or("target", "gpu") == "cpu" {
        return cmd_autotune_cpu(args);
    }
    let d = device(args)?;
    let size = args.get_usize("size", 4096)?;
    let a = acc(args)?;
    let top = args.get_usize("top", 8)?;
    let cands = autotune::enumerate(size, size, size, a, &d);
    if cands.is_empty() {
        bail!("no feasible tile configuration divides {size}");
    }
    println!(
        "{:<28} {:>10} {:>10} {:>8} {:>14}",
        "tile (tb | warp)", "tflops", "% peak", "blocks", "smem"
    );
    for c in cands.iter().take(top) {
        let s = &c.schedule;
        println!(
            "{:<28} {:>10.2} {:>9.1}% {:>8} {:>12} B",
            format!(
                "{}x{}x{} | {}x{}x{}",
                s.tile_tb.0, s.tile_tb.1, s.tile_tb.2,
                s.tile_warp.0, s.tile_warp.1, s.tile_warp.2
            ),
            c.result.tflops,
            c.result.frac_of_peak * 100.0,
            s.blocks(),
            s.smem_bytes,
        );
    }
    println!("\nbest: {}", cands[0].schedule.name);
    Ok(())
}

/// CPU block-size sweep: measure the micro-kernel engine's policies the
/// way the GPU path ranks modeled tile configurations.
fn cmd_autotune_cpu(args: &Args) -> Result<()> {
    let size = args.get_usize("size", 1024)?;
    let threads = args.get_usize("threads", 0)?;
    let iters = args.get_usize("iters", 3)?;
    let top = args.get_usize("top", 8)?;
    let cands = autotune::sweep_cpu(size, size, size, threads, iters);
    let naive = cands
        .iter()
        .find(|c| c.policy == mlir_gemm::runtime::KernelPolicy::Naive)
        .map(|c| c.gflops)
        .unwrap_or(0.0);
    println!("{:<32} {:>10} {:>12} {:>10}", "policy", "gflops", "seconds", "vs naive");
    for c in cands.iter().take(top.max(1)) {
        println!(
            "{:<32} {:>10.2} {:>12.6} {:>9.2}x",
            c.policy.name(),
            c.gflops,
            c.seconds,
            if naive > 0.0 { c.gflops / naive } else { 0.0 }
        );
    }
    if let Some(best) = cands.first() {
        println!("\nbest: {}", best.policy.name());
    }
    Ok(())
}

fn cmd_bench(args: &Args) -> Result<()> {
    let which = args
        .positional
        .get(1)
        .map(String::as_str)
        .unwrap_or("all");
    let d = device(args)?;
    let out_dir = PathBuf::from(args.get_or("out-dir", "reports"));
    let cfg = bench_cfg(args)?;
    let measured = args.flag("measured");

    let mut outputs = Vec::new();
    let needs_runtime = measured || which == "table1" || which == "all";
    let runtime = if needs_runtime {
        match Runtime::open(&artifacts_dir(args)) {
            Ok(rt) => Some(rt),
            Err(e) => {
                eprintln!("note: artifacts unavailable ({e}); skipping measured subsets");
                None
            }
        }
    } else {
        None
    };

    if matches!(which, "fig2" | "all") {
        outputs.push(harness::figure2(&d));
        if let (true, Some(rt)) = (measured, &runtime) {
            outputs.push(harness::figure_sweep_measured(
                rt,
                Dtype::F32,
                cfg,
                "figure2_measured",
            )?);
        }
    }
    if matches!(which, "fig3" | "all") {
        outputs.push(harness::figure3(&d));
        if let (true, Some(rt)) = (measured, &runtime) {
            outputs.push(harness::figure3_measured(rt, cfg)?);
        }
    }
    if matches!(which, "fig4" | "all") {
        outputs.push(harness::figure4(&d));
        if let (true, Some(rt)) = (measured, &runtime) {
            outputs.push(harness::figure_sweep_measured(
                rt,
                Dtype::F16,
                cfg,
                "figure4_measured",
            )?);
        }
    }
    if matches!(which, "table1" | "all") {
        if let Some(rt) = &runtime {
            outputs.push(harness::table1(rt, &d, cfg)?);
        } else {
            eprintln!("table1 needs built artifacts; skipping");
        }
    }
    if outputs.is_empty() {
        bail!("unknown bench target {which:?} (fig2|fig3|fig4|table1|all)");
    }

    for o in &outputs {
        println!("{}", o.render());
        let path = out_dir.join(format!("{}.csv", o.name));
        o.table.write_to(&path)?;
        println!("csv -> {}\n", path.display());
    }
    Ok(())
}

/// Parse `512` or `512x384x256` into (m, n, k).
fn parse_dims(s: &str) -> Result<(usize, usize, usize)> {
    let parts: Vec<&str> = s.split('x').collect();
    let num = |p: &str| {
        p.trim()
            .parse::<usize>()
            .map_err(|_| anyhow!("bad dimension {p:?} in {s:?}"))
    };
    match parts.len() {
        1 => {
            let v = num(parts[0])?;
            Ok((v, v, v))
        }
        3 => Ok((num(parts[0])?, num(parts[1])?, num(parts[2])?)),
        _ => bail!("expected SIZE or MxNxK, got {s:?}"),
    }
}

/// Compile (and optionally refine) the execution plan for one GEMM, then
/// print the plan JSON, its per-pass provenance, and predicted-vs-
/// measured cost (plan kernel vs naive on random operands).
///
/// Alternatively takes a path to a `*.tprog.json` artifact file: a GEMM
/// descriptor plans through the same per-key pipeline; a composite
/// (transformer) descriptor compiles its graph-level [`ProgramPlan`] and
/// prints the plan JSON plus the per-pass provenance trace.
fn cmd_plan(args: &Args) -> Result<()> {
    let spec = args
        .positional
        .get(1)
        .ok_or_else(|| anyhow!("usage: plan <MxNxK | artifact.tprog.json> [--in DT] [--acc DT] [--epilogue E] [--plan OVERRIDE]"))?;
    if std::path::Path::new(spec).is_file() {
        return cmd_plan_artifact(args, spec);
    }
    let (m, n, k) = parse_dims(spec)?;
    let dtype_in = Dtype::parse(args.get_or("in", "f16"))
        .ok_or_else(|| anyhow!("unknown input dtype"))?;
    let dtype_acc = acc(args)?;
    let epilogue = args.get_or("epilogue", "none").to_string();
    if !matches!(epilogue.as_str(), "none" | "bias" | "bias_relu") {
        bail!("unknown epilogue {epilogue:?} (none | bias | bias_relu)");
    }
    let env = PlanEnv::default().with_force(plan_override(args)?);
    let key = GemmKey { m, n, k, dtype_in, dtype_acc, epilogue };
    let mut eplan = plan::compile(&key, &env)?;
    let iters = args.get_usize("iters", 3)?;
    if args.flag("refine") {
        eplan = autotune::refine_measured(&eplan, iters);
    }
    println!("{}", eplan.to_json());
    println!();
    print!("{}", eplan.render_trace());

    // Predicted vs measured: wall clock of the plan's lowered kernel and
    // the naive reference on the same random operands (min of `iters`).
    let mut rng = Rng::new(0x9A);
    let a = rng.normal_matrix(m, k);
    let b = rng.normal_matrix(k, n);
    let mut out = vec![0.0f32; m * n];
    let mut measure = |policy: KernelPolicy| -> f64 {
        let mut best = f64::INFINITY;
        for it in 0..=iters.max(1) {
            out.fill(0.0);
            let t = Instant::now();
            mlir_gemm::runtime::kernel::matmul(policy, &mut out, &a, &b, m, n, k);
            let dt = t.elapsed().as_secs_f64();
            if it > 0 {
                best = best.min(dt);
            }
        }
        best
    };
    let measured = measure(eplan.kernel);
    let naive = measure(KernelPolicy::Naive);
    println!();
    println!(
        "predicted {:.3} ms | measured {:.3} ms ({}) | naive {:.3} ms ({:.2}x)",
        eplan.predicted_seconds * 1e3,
        measured * 1e3,
        eplan.kernel.name(),
        naive * 1e3,
        if measured > 0.0 { naive / measured } else { 0.0 },
    );
    println!(
        "isa {} | numerics {}",
        eplan.isa_label(),
        eplan.numerics.name()
    );
    Ok(())
}

/// Plan a `*.tprog.json` artifact file directly: compile whichever plan
/// kind the descriptor calls for and print it with its pass trace.
fn cmd_plan_artifact(args: &Args, path: &str) -> Result<()> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow!("cannot read {path:?}: {e}"))?;
    let root = mlir_gemm::util::json::parse(&text).map_err(|e| anyhow!("{e}"))?;
    let name = root
        .get("name")
        .and_then(mlir_gemm::util::json::Json::as_str)
        .ok_or_else(|| anyhow!("{path:?} has no artifact name"))?
        .to_string();
    let program = mlir_gemm::runtime::Program::from_text(&text, &name)?;
    let env = PlanEnv::default().with_force(plan_override(args)?);
    match program.gemm_key() {
        Some(_) => {
            let eplan = program.compile_plan(&env)?;
            println!("{}", eplan.to_json());
            println!();
            print!("{}", eplan.render_trace());
            println!();
            println!("artifact {name} | isa {} | numerics {}", eplan.isa_label(), eplan.numerics.name());
        }
        None => {
            let pplan = program.compile_program_plan(&env)?;
            println!("{}", pplan.to_json());
            println!();
            print!("{}", pplan.render_trace());
            println!();
            println!(
                "artifact {name} | {} | isa {} | numerics {} | {:.1} MFLOP/item | {} scratch slots",
                pplan.id(),
                pplan.isa_label(),
                pplan.numerics.name(),
                pplan.flops_per_item() / 1e6,
                pplan.arena.len(),
            );
        }
    }
    Ok(())
}

/// Emit the compiled graph-level plan for every composite-program
/// artifact (`make program-plans`).
fn cmd_program_plans(args: &Args) -> Result<()> {
    let rt = Runtime::open(&artifacts_dir(args))?;
    let env = PlanEnv::default().with_force(plan_override(args)?);
    let out_dir = PathBuf::from(args.get_or("out-dir", "reports")).join("plans");
    std::fs::create_dir_all(&out_dir)?;
    let mut count = 0usize;
    for meta in rt.artifacts() {
        let artifact = match rt.load(&meta.name) {
            Ok(a) => a,
            Err(_) => continue,
        };
        let pplan = match artifact.program().compile_program_plan(&env) {
            Ok(p) => p,
            Err(_) => continue, // plain GEMM artifact: covered by `plans`
        };
        let fname = format!("program_plan_{}.json", meta.name.replace(['/', '.'], "_"));
        std::fs::write(out_dir.join(&fname), format!("{}\n", pplan.to_json()))?;
        println!("{:<56} {}", fname, pplan.id());
        count += 1;
    }
    if count == 0 {
        bail!("no composite-program artifacts (build artifacts first: make artifacts)");
    }
    println!("\nwrote {count} program plans -> {}", out_dir.display());
    Ok(())
}

/// Emit the compiled plan for every registry key (`make plans`).
fn cmd_plans(args: &Args) -> Result<()> {
    let d = device(args)?;
    let rt = Runtime::open(&artifacts_dir(args))?;
    let env = PlanEnv::default().with_force(plan_override(args)?);
    let reg = Registry::build(rt.artifacts(), &d, env);
    let out_dir = PathBuf::from(args.get_or("out-dir", "reports")).join("plans");
    std::fs::create_dir_all(&out_dir)?;
    let mut count = 0usize;
    for (key, p) in reg.plans() {
        let fname = format!(
            "plan_{}x{}x{}_{}_{}_{}.json",
            key.m,
            key.n,
            key.k,
            key.dtype_in.name(),
            key.dtype_acc.name(),
            key.epilogue
        );
        std::fs::write(out_dir.join(&fname), format!("{}\n", p.to_json()))?;
        println!("{:<56} {}", fname, p.id());
        count += 1;
    }
    if count == 0 {
        bail!("no registry keys (build artifacts first: make artifacts)");
    }
    println!("\nwrote {count} compiled plans -> {}", out_dir.display());
    Ok(())
}

/// Print the shadow-promoted plan DB (`make plandb`): every measured SIMD
/// winner `serve` has persisted, with the measurements that won it the
/// slot.  The DB lives next to the artifacts it was measured against
/// (`<artifacts>/reports/plandb.json`) so a restarted server warm-loads
/// exactly what it serves.
fn cmd_plandb(args: &Args) -> Result<()> {
    let path = artifacts_dir(args)
        .join(mlir_gemm::coordinator::shadow::PLANDB_DEFAULT_PATH);
    if !path.is_file() {
        println!(
            "no plan DB at {} (run `serve` with shadow tuning on — the \
             default — and traffic will populate it)",
            path.display()
        );
        return Ok(());
    }
    let db = PlanDb::load(&path)?;
    println!("plan DB {} ({}, {} records)\n", path.display(), PLANDB_FORMAT, db.len());
    println!(
        "{:<44} {:<40} {:>9} {:>9} {:>7} {:>7}",
        "key", "promoted plan", "inc GF/s", "new GF/s", "gain", "samples"
    );
    for rec in db.records() {
        let gain = if rec.incumbent_gflops > 0.0 {
            rec.candidate_gflops / rec.incumbent_gflops
        } else {
            0.0
        };
        println!(
            "{:<44} {:<40} {:>9.2} {:>9.2} {:>6.2}x {:>7}",
            rec.db_key(),
            rec.plan.id(),
            rec.incumbent_gflops,
            rec.candidate_gflops,
            gain,
            rec.samples
        );
    }
    Ok(())
}

fn cmd_run(args: &Args) -> Result<()> {
    let name = args
        .positional
        .get(1)
        .ok_or_else(|| anyhow!("usage: run <artifact-name>"))?;
    let mut rt = Runtime::open(&artifacts_dir(args))?;
    rt.set_plan_override(plan_override(args)?);
    let a = rt.load(name)?;
    let inputs = harness::random_inputs(&a, 0, 0.5);
    let (outputs, timing) = rt.execute_timed(&a, &inputs)?;
    println!(
        "{name}: exec {:.3} ms (pack {:.3} ms, unpack {:.3} ms)",
        timing.exec_seconds * 1e3,
        timing.pack_seconds * 1e3,
        timing.unpack_seconds * 1e3
    );
    for (i, o) in outputs.iter().enumerate() {
        let norm: f64 = o.data.iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt();
        println!("  out{i}: shape {:?}, l2 norm {norm:.4}", o.shape);
    }
    Ok(())
}

/// Exhaustively model-check the coordinator protocol (see
/// `src/check/`): a matrix of bounded scenarios, each explored over
/// *every* interleaving, each guarded against vacuity by its coverage
/// flags — then one deterministic fault replay of the hardest schedule
/// (shutdown racing buffered submits) against the real server.
///
/// `--bug <name>` flips the check around: re-introduce a known defect
/// in the model (and, for `stop-flag`, in the real dispatcher via the
/// `FaultPlan` hook) and *demand* the checker produce a counterexample
/// — proof the invariants have teeth.
fn cmd_check_protocol(args: &Args) -> Result<()> {
    use mlir_gemm::check::{
        explore, replay_shutdown_vs_submit, Bugs, Coverage, ModelConfig,
    };

    let clients = args.get_usize("clients", 3)?;
    let devices = args.get_usize("devices", 2)?;
    let jobs = args.get_usize("jobs", 4)?;
    let max_states = args.get_usize("max-states", 2_000_000)?;
    let capacity = args.get_usize("capacity", 0)?; // 0 -> default (= clients)
    if !(1..=5).contains(&clients) || !(1..=3).contains(&devices) {
        bail!(
            "bounded configurations only: --clients 1..=5, --devices 1..=3 \
             (got {clients} x {devices}); the soundness argument in \
             DESIGN.md S12 explains why small bounds suffice"
        );
    }
    let mut base = ModelConfig::new(clients as u8, devices as u8);
    if capacity > 0 {
        base = base.with_capacity(capacity.min(255) as u8);
    }

    if let Some(bug) = args.get("bug") {
        let (bugs, cfg) = match bug {
            "stop-flag" => (
                Bugs { stop_flag_break: true, ..Default::default() },
                base.clone(),
            ),
            "stale-rebind" => (
                Bugs { stale_rebind: true, ..Default::default() },
                base.clone().with_rebind(),
            ),
            "no-containment" => (
                Bugs { no_containment: true, ..Default::default() },
                base.clone().with_poison(),
            ),
            "fifo-release" => (
                Bugs { fifo_release: true, ..Default::default() },
                base.clone().with_priority().with_max_batch(1),
            ),
            other => bail!(
                "unknown --bug {other:?} (stop-flag | stale-rebind | \
                 no-containment | fifo-release)"
            ),
        };
        let cfg = cfg.with_bugs(bugs);
        println!("hunting re-introduced bug {bug:?} in {clients} clients x {devices} devices...");
        let t = Instant::now();
        let r = explore(&cfg, max_states)?;
        let cx = r.violation.ok_or_else(|| {
            anyhow!(
                "expected a counterexample for --bug {bug} but all {} states \
                 ({} terminal) passed — the invariant lost its teeth",
                r.states,
                r.terminals
            )
        })?;
        println!(
            "counterexample found in {} states ({:.0} ms):\n",
            r.states,
            t.elapsed().as_secs_f64() * 1e3
        );
        println!("{}", cx.render());
        if bug == "stop-flag" {
            // Close the loop: the model's schedule, replayed against
            // the real server with the dispatcher bug re-armed.
            let out = replay_shutdown_vs_submit(jobs, true)?;
            if out.lost == 0 || out.accounting_holds() {
                bail!(
                    "model found the violation but the real-server replay did \
                     not reproduce it: {out:?}"
                );
            }
            println!(
                "replayed against the real server: {} of {} held jobs stranded \
                 (reply channels dead), submitted={} but completed+failed+rejected={} \
                 — the model's violation is real",
                out.lost,
                out.jobs,
                out.snapshot.submitted,
                out.snapshot.completed + out.snapshot.failed + out.snapshot.rejected
            );
        }
        return Ok(());
    }

    // Sound matrix: every scenario must pass AND must visit the
    // situation it exists to test (the coverage closure) — a pass that
    // never opened the race window proves nothing.
    type Cov = fn(&Coverage) -> Option<&'static str>;
    let scenarios: Vec<(&str, ModelConfig, Cov)> = vec![
        ("shutdown races submit", base.clone(), |c| {
            if !c.shutdown_with_backlog {
                Some("shutdown never caught buffered jobs")
            } else if !c.late_submit_error {
                Some("no submit ever raced past the closed channel")
            } else if !c.multi_job_batch {
                Some("no multi-job batch ever formed")
            } else {
                None
            }
        }),
        ("rebind races dispatch", base.clone().with_rebind(), |c| {
            (!c.rebind_raced_dispatch)
                .then_some("no rebind ever landed between routing and execution")
        }),
        ("poisoned job is quarantined", base.clone().with_poison(), |c| {
            if !c.poisoned_job {
                Some("the poison job never executed")
            } else if !c.multi_job_batch {
                Some("the poison job never shared a batch")
            } else {
                None
            }
        }),
        ("expired deadline answered early", base.clone().with_deadline(), |c| {
            (!c.expired_job).then_some("the expired job was never swept")
        }),
        ("sharded last-finisher reduction", base.clone().with_sharding(), |c| {
            (!c.shard_reduction).then_some("no sharded job ever completed")
        }),
        ("bounded admission overflow", base.clone().with_capacity(1), |c| {
            (!c.queue_full_rejection).then_some("the queue never filled")
        }),
        (
            "priority tiers release in order",
            base.clone().with_priority().with_max_batch(1),
            |c| {
                (!c.priority_release)
                    .then_some("no release ever reordered past a low-priority job")
            },
        ),
        ("tenant quota exhaustion", base.clone().with_quota(1), |c| {
            (!c.tenant_quota_rejection).then_some("the quota never rejected")
        }),
        (
            "deadline lapses inside the scheduler",
            base.clone().with_late_deadline(),
            |c| (!c.swept_in_scheduler).then_some("no job was ever swept"),
        ),
    ];

    println!(
        "model-checking the coordinator protocol: {clients} clients x {devices} \
         devices, <= {max_states} states/scenario\n"
    );
    let mut total_states = 0usize;
    for (name, cfg, cov) in scenarios {
        let t = Instant::now();
        let r = explore(&cfg, max_states)?;
        if let Some(cx) = r.violation {
            println!("FAIL {name}\n");
            println!("{}", cx.render());
            bail!("protocol invariant violated in scenario {name:?}");
        }
        if let Some(gap) = cov(&r.coverage) {
            bail!(
                "scenario {name:?} passed vacuously: {gap} \
                 (coverage {:?})",
                r.coverage
            );
        }
        total_states += r.states;
        println!(
            "  ok {name:<34} {:>8} states, {:>9} transitions, {:>5} terminals, \
             depth {:>3}, {:>6.0} ms",
            r.states,
            r.transitions,
            r.terminals,
            r.max_depth,
            t.elapsed().as_secs_f64() * 1e3
        );
    }

    // Replay leg: the hardest schedule (every submit buffered when the
    // stop flag goes up) against the real server, bug hook OFF — every
    // held job must drain to an answer and the accounting identity
    // must hold.
    let t = Instant::now();
    let out = replay_shutdown_vs_submit(jobs, false)?;
    if !out.accounting_holds() || out.answered != out.jobs {
        bail!(
            "real-server replay violated the protocol on correct code: {out:?}"
        );
    }
    println!(
        "  ok real-server fault replay          {:>4} held jobs all answered \
         through shutdown, accounting exact, {:>6.0} ms",
        out.jobs,
        t.elapsed().as_secs_f64() * 1e3
    );

    println!(
        "\nall interleavings of {total_states} reachable states verified:\n\
         \x20 1. accounting: completed + failed + rejected == submitted\n\
         \x20 2. every submit is answered (no dropped reply channel)\n\
         \x20 3. shutdown strands no job\n\
         \x20 4. jobs execute under the weights they were routed with\n\
         \x20 5. a panicking job is quarantined; batchmates complete"
    );
    Ok(())
}

/// Open-loop load generator against a real server over the built
/// artifact set: bursty zipfian arrivals from many client threads,
/// weight-bound and inline GEMMs mixed across tenants and priority
/// tiers, with the latency percentiles and rejection buckets printed at
/// the end.  Offered load is independent of server latency (the arrival
/// clocks never wait), so queueing shows up in p95/p99, not in a
/// silently throttled request rate.
fn cmd_loadgen(args: &Args) -> Result<()> {
    let d = device(args)?;
    let rt = Arc::new(Runtime::open(&artifacts_dir(args))?);
    let clients = args.get_usize("clients", 32)?;
    let per_client = args.get_usize("requests", 64)?;
    let workers = args.get_usize("workers", 2)?;
    let devices = args.get_usize("devices", 1)?;
    let tenant_quota = args.get_usize("tenant-quota", 0)?;
    let plan = plan_override(args)?;
    let tenants: Vec<String> = args
        .get("tenants")
        .map(|t| t.split(',').map(|s| s.trim().to_string()).collect())
        .unwrap_or_default();
    if tenant_quota > 0 && tenants.is_empty() {
        bail!("--tenant-quota needs --tenants to bill against");
    }

    let server = Server::start(
        rt,
        &d,
        ServerConfig {
            workers,
            devices,
            plan,
            queue_capacity: args.get_usize("capacity", 512)?,
            admission: AdmissionConfig { tenant_quota },
            ..Default::default()
        },
    );
    let keys: Vec<GemmKey> = server.registry().keys().cloned().collect();
    if keys.is_empty() {
        bail!("no generated kernels registered (build artifacts first)");
    }
    // Bind every key's B so the weight-bound half of the mix is servable.
    let mut rng = Rng::new(0xB1);
    for key in &keys {
        let b = Tensor::new(vec![key.k, key.n], rng.normal_matrix(key.k, key.n))?;
        server.bind_weights(key, &b)?;
    }

    let cfg = LoadgenConfig {
        clients,
        per_client,
        mean_gap: Duration::from_micros(args.get_usize("mean-gap-us", 500)? as u64),
        burst_prob: args.get_f64("burst-prob", 0.15)?,
        burst_len: 4,
        zipf_s: args.get_f64("zipf", 1.0)?,
        bound_fraction: 0.5,
        program_fraction: 0.0,
        program: None,
        tenants,
        priorities: vec![Priority::High, Priority::Normal, Priority::Low],
        deadline: match args.get_usize("deadline-ms", 0)? {
            0 => None,
            ms => Some(Duration::from_millis(ms as u64)),
        },
        seed: args.get_usize("seed", 4269)? as u64,
    };
    println!(
        "loadgen: {} clients x {} requests over {} keys ({} workers, \
         zipf s={}, mean gap {:?})...",
        cfg.clients,
        cfg.per_client,
        keys.len(),
        workers,
        cfg.zipf_s,
        cfg.mean_gap,
    );
    let server = std::sync::Mutex::new(server);
    let report = run_load(&server, &cfg, &keys);
    println!("{}\n", report.render());
    let mut server = server.into_inner().unwrap();
    let snapshot = server.shutdown();
    println!("{}", snapshot.report());
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let d = device(args)?;
    let rt = Arc::new(Runtime::open(&artifacts_dir(args))?);
    let n_requests = args.get_usize("requests", 64)?;
    let workers = args.get_usize("workers", 2)?;
    let devices = args.get_usize("devices", 1)?;
    let plan = plan_override(args)?;
    let bind = args.flag("bind");

    // Shadow tuning is on by default for real serving (off with
    // MLIR_GEMM_SHADOW=off): sampled traffic is re-measured under the
    // SIMD candidate plan and winners are promoted + persisted to
    // <artifacts>/reports/plandb.json for warm restarts.
    let shadow = ShadowConfig::from_env(&artifacts_dir(args));
    let mut server = Server::start(
        rt.clone(),
        &d,
        ServerConfig {
            workers,
            devices,
            plan,
            // cmd_serve fires its whole synthetic load before draining
            // any response, so the bounded queue must hold all of it.
            queue_capacity: n_requests.max(1024),
            shadow,
            ..Default::default()
        },
    );

    // Synthetic traffic over every registered shape.
    let keys: Vec<GemmKey> = server.registry().keys().cloned().collect();
    if keys.is_empty() {
        bail!("no generated kernels registered (build artifacts first)");
    }
    let mut rng = Rng::new(99);
    if bind {
        // Model-serving mode: every shape's B is a constant weight,
        // bound (cast + prepacked) once before traffic starts.
        for key in &keys {
            let b = Tensor::new(vec![key.k, key.n], rng.normal_matrix(key.k, key.n))?;
            server.bind_weights(key, &b)?;
        }
        println!("bound constant B weights for {} shapes", keys.len());
    }
    println!(
        "serving {} synthetic requests over {} shapes with {} workers{}...",
        n_requests,
        keys.len(),
        workers,
        if bind { " (weight-bound)" } else { "" }
    );
    let mut pending = Vec::new();
    for _ in 0..n_requests {
        let key = rng.choice(&keys).clone();
        let a = Tensor::new(vec![key.m, key.k], rng.normal_matrix(key.m, key.k))?;
        let b = if bind {
            None
        } else {
            Some(Tensor::new(vec![key.k, key.n], rng.normal_matrix(key.k, key.n))?)
        };
        let c = Tensor::zeros(vec![key.m, key.n]);
        let bias = if key.epilogue != "none" {
            Some(Tensor::new(vec![key.n], rng.normal_matrix(1, key.n))?)
        } else {
            None
        };
        pending.push(server.submit(GemmRequest {
            key,
            a,
            b,
            c,
            bias,
            use_baseline: false,
            deadline: None,
        }));
    }
    let mut ok = 0usize;
    for rx in pending {
        let resp = rx.recv().map_err(|_| anyhow!("server dropped response"))?;
        if resp.output.is_ok() {
            ok += 1;
        } else if let Err(e) = resp.output {
            eprintln!("request {} failed: {e:#}", resp.id);
        }
    }
    println!("{ok}/{n_requests} requests succeeded\n");
    if let Some(sh) = server.shadow() {
        println!(
            "shadow tuning ({}): {} warm-loaded, {} sampled, {} promoted, \
             {} rejected -> {}",
            sh.isa_name(),
            sh.warm_loaded(),
            sh.sampled(),
            sh.promoted(),
            sh.rejected(),
            sh.config()
                .plandb_path
                .as_deref()
                .map(|p| p.display().to_string())
                .unwrap_or_else(|| "<unpersisted>".to_string()),
        );
    }
    let snapshot = server.shutdown();
    println!("{}", snapshot.report());
    Ok(())
}
