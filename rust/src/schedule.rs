//! Rust mirror of the Python `tileir.schedule.Schedule` — the contract
//! between the code-generation pipeline and the run-time side (simulator,
//! autotuner, coordinator).  Parsed from `artifacts/manifest.json`, or
//! constructed directly when the simulator explores candidate schedules the
//! pipeline has not (yet) emitted.

use crate::util::json::Json;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Dtype {
    F16,
    Bf16,
    F32,
}

impl Dtype {
    pub fn bytes(self) -> usize {
        match self {
            Dtype::F16 | Dtype::Bf16 => 2,
            Dtype::F32 => 4,
        }
    }

    pub fn parse(s: &str) -> Option<Dtype> {
        match s {
            "f16" => Some(Dtype::F16),
            "bf16" => Some(Dtype::Bf16),
            "f32" => Some(Dtype::F32),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Dtype::F16 => "f16",
            Dtype::Bf16 => "bf16",
            Dtype::F32 => "f32",
        }
    }
}

/// One generated kernel variant's complete structural description.
#[derive(Debug, Clone, PartialEq)]
pub struct Schedule {
    pub name: String,
    pub m: usize,
    pub n: usize,
    pub k: usize,
    pub dtype_in: Dtype,
    pub dtype_acc: Dtype,
    pub epilogue: String,
    pub opt_level: u8,
    pub tiling: bool,
    pub shared_mem: bool,
    pub wmma: bool,
    pub unroll_hoist: bool,
    pub latency_hiding: bool,
    pub padding: bool,
    pub vectorize: bool,
    pub tile_tb: (usize, usize, usize),
    pub tile_warp: (usize, usize, usize),
    pub wmma_mnk: (usize, usize, usize),
    pub pad_factor: usize,
    pub vec_width: usize,
    pub pipeline_stages: usize,
    pub grid: (usize, usize),
    pub warps_per_block: (usize, usize),
    pub threads_per_block: usize,
    pub smem_bytes: usize,
    pub accumulators_per_warp: usize,
    pub barriers_per_iteration: usize,
}

#[derive(Debug)]
pub struct ScheduleError(pub String);

impl std::fmt::Display for ScheduleError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "schedule error: {}", self.0)
    }
}

impl std::error::Error for ScheduleError {}

impl Schedule {
    /// Parse from the manifest's per-artifact "schedule" object.
    pub fn from_json(j: &Json) -> Result<Schedule, ScheduleError> {
        let e = |field: &str| ScheduleError(format!("missing/invalid field {field:?}"));
        let get_b = |f: &str| j.get(f).and_then(Json::as_bool).ok_or_else(|| e(f));
        let get_u = |f: &str| j.get(f).and_then(Json::as_usize).ok_or_else(|| e(f));
        let get_s = |f: &str| {
            j.get(f)
                .and_then(Json::as_str)
                .map(str::to_string)
                .ok_or_else(|| e(f))
        };
        let get_d = |f: &str| {
            j.get(f)
                .and_then(Json::as_str)
                .and_then(Dtype::parse)
                .ok_or_else(|| e(f))
        };
        Ok(Schedule {
            name: get_s("name")?,
            m: get_u("m")?,
            n: get_u("n")?,
            k: get_u("k")?,
            dtype_in: get_d("dtype_in")?,
            dtype_acc: get_d("dtype_acc")?,
            epilogue: get_s("epilogue")?,
            opt_level: get_u("opt_level")? as u8,
            tiling: get_b("tiling")?,
            shared_mem: get_b("shared_mem")?,
            wmma: get_b("wmma")?,
            unroll_hoist: get_b("unroll_hoist")?,
            latency_hiding: get_b("latency_hiding")?,
            padding: get_b("padding")?,
            vectorize: get_b("vectorize")?,
            tile_tb: j.get_usize3("tile_tb").ok_or_else(|| e("tile_tb"))?,
            tile_warp: j.get_usize3("tile_warp").ok_or_else(|| e("tile_warp"))?,
            wmma_mnk: j.get_usize3("wmma_mnk").ok_or_else(|| e("wmma_mnk"))?,
            pad_factor: get_u("pad_factor")?,
            vec_width: get_u("vec_width")?,
            pipeline_stages: get_u("pipeline_stages")?,
            grid: j.get_usize2("grid").ok_or_else(|| e("grid"))?,
            warps_per_block: j
                .get_usize2("warps_per_block")
                .ok_or_else(|| e("warps_per_block"))?,
            threads_per_block: get_u("threads_per_block")?,
            smem_bytes: get_u("smem_bytes")?,
            accumulators_per_warp: get_u("accumulators_per_warp")?,
            barriers_per_iteration: get_u("barriers_per_iteration")?,
        })
    }

    /// Build a fully-optimized candidate schedule for the autotuner / sim
    /// (what the pipeline would produce for this config).
    pub fn optimized(
        m: usize,
        n: usize,
        k: usize,
        dtype_acc: Dtype,
        tile_tb: (usize, usize, usize),
        tile_warp: (usize, usize, usize),
    ) -> Result<Schedule, ScheduleError> {
        let (tbm, tbn, tbk) = tile_tb;
        let (wm, wn, wk) = tile_warp;
        if tbm == 0 || tbn == 0 || tbk == 0 {
            return Err(ScheduleError("zero tile".into()));
        }
        if m % tbm != 0 || n % tbn != 0 || k % tbk != 0 {
            return Err(ScheduleError(format!(
                "problem {m}x{n}x{k} not a multiple of tile {tile_tb:?}"
            )));
        }
        if tbm % wm != 0 || tbn % wn != 0 || tbk % wk != 0 {
            return Err(ScheduleError(format!(
                "tb tile {tile_tb:?} not a multiple of warp tile {tile_warp:?}"
            )));
        }
        if wm % 16 != 0 || wn % 16 != 0 || wk % 16 != 0 {
            return Err(ScheduleError(format!(
                "warp tile {tile_warp:?} not a multiple of the 16x16x16 WMMA op"
            )));
        }
        let warps_check = (tbm / wm) * (tbn / wn);
        if warps_check * 32 > 1024 {
            return Err(ScheduleError(format!(
                "tile {tile_tb:?} with warp tile {tile_warp:?} needs \
                 {warps_check} warps = {} threads/block (hardware max 1024)",
                warps_check * 32
            )));
        }
        let pad = 8;
        let in_bytes = Dtype::F16.bytes();
        let smem = (tbm * (tbk + pad) + tbk * (tbn + pad)) * in_bytes;
        let warps = (tbm / wm, tbn / wn);
        let stages = if k / tbk >= 2 { 2 } else { 1 };
        Ok(Schedule {
            name: format!(
                "cand_m{m}n{n}k{k}_{}_tb{tbm}x{tbn}x{tbk}_w{wm}x{wn}x{wk}",
                dtype_acc.name()
            ),
            m,
            n,
            k,
            dtype_in: Dtype::F16,
            dtype_acc,
            epilogue: "none".into(),
            opt_level: 7,
            tiling: true,
            shared_mem: true,
            wmma: true,
            unroll_hoist: true,
            latency_hiding: stages > 1,
            padding: true,
            vectorize: true,
            tile_tb,
            tile_warp,
            wmma_mnk: (16, 16, 16),
            pad_factor: pad,
            vec_width: 8,
            pipeline_stages: stages,
            grid: (m / tbm, n / tbn),
            warps_per_block: warps,
            threads_per_block: warps.0 * warps.1 * 32,
            smem_bytes: smem,
            accumulators_per_warp: (wm / 16) * (wn / 16),
            barriers_per_iteration: 2,
        })
    }

    pub fn flops(&self) -> f64 {
        2.0 * self.m as f64 * self.n as f64 * self.k as f64
    }

    pub fn blocks(&self) -> usize {
        self.grid.0 * self.grid.1
    }

    pub fn warps_total_per_block(&self) -> usize {
        self.warps_per_block.0 * self.warps_per_block.1
    }

    /// Registers per thread estimate: each warp holds
    /// `accumulators_per_warp` 16x16 f32 fragments (8 regs/thread each on
    /// Ampere) plus A/B fragments and addressing registers.
    pub fn regs_per_thread(&self) -> usize {
        let acc_regs = self.accumulators_per_warp * 8 * self.dtype_acc.bytes() / 4;
        let operand_regs = 2 * 8; // one A + one B fragment in flight
        let staging = if self.pipeline_stages > 1 { 16 } else { 0 };
        32 + acc_regs + operand_regs + staging
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    fn paper_schedule() -> Schedule {
        Schedule::optimized(
            8192,
            8192,
            8192,
            Dtype::F32,
            (128, 128, 64),
            (64, 32, 32),
        )
        .unwrap()
    }

    #[test]
    fn paper_config_footprints() {
        let s = paper_schedule();
        assert_eq!(s.smem_bytes, (128 * 72 + 64 * 136) * 2);
        assert_eq!(s.accumulators_per_warp, 8);
        assert_eq!(s.threads_per_block, 256);
        assert_eq!(s.grid, (64, 64));
    }

    #[test]
    fn rejects_bad_tiles() {
        assert!(Schedule::optimized(100, 64, 64, Dtype::F32, (64, 64, 64), (32, 32, 32)).is_err());
        assert!(Schedule::optimized(128, 128, 128, Dtype::F32, (64, 64, 64), (48, 32, 32)).is_err());
        assert!(Schedule::optimized(128, 128, 128, Dtype::F32, (64, 64, 64), (24, 24, 24)).is_err());
    }

    #[test]
    fn rejects_over_1024_threads() {
        // 256x256 tile with 32x32 warps = 64 warps = 2048 threads
        assert!(Schedule::optimized(
            4096, 4096, 4096, Dtype::F32, (256, 256, 32), (32, 32, 32)
        )
        .is_err());
    }

    #[test]
    fn json_roundtrip_via_python_shape() {
        // A manifest-shaped schedule object (field names as emitted by
        // python's dataclasses.asdict).
        let text = r#"{
            "name": "t", "m": 64, "n": 64, "k": 64,
            "dtype_in": "f16", "dtype_acc": "f32", "epilogue": "none",
            "opt_level": 7, "tiling": true, "shared_mem": true, "wmma": true,
            "unroll_hoist": true, "latency_hiding": true, "padding": true,
            "vectorize": true, "tile_tb": [32, 32, 32],
            "tile_warp": [16, 16, 16], "wmma_mnk": [16, 16, 16],
            "pad_factor": 8, "vec_width": 8, "pipeline_stages": 2,
            "grid": [2, 2], "warps_per_block": [2, 2],
            "threads_per_block": 128, "smem_bytes": 5120,
            "accumulators_per_warp": 1, "barriers_per_iteration": 2
        }"#;
        let j = json::parse(text).unwrap();
        let s = Schedule::from_json(&j).unwrap();
        assert_eq!(s.grid, (2, 2));
        assert_eq!(s.dtype_acc, Dtype::F32);
        assert_eq!(s.flops(), 2.0 * 64f64.powi(3));
    }

    #[test]
    fn from_json_reports_missing_fields() {
        let j = json::parse(r#"{"name": "x"}"#).unwrap();
        let err = Schedule::from_json(&j).unwrap_err();
        assert!(err.0.contains("missing"));
    }

    #[test]
    fn regs_stay_under_ampere_cap() {
        // paper sets maxrregcount=255; our estimate for the paper config
        // must stay below it
        assert!(paper_schedule().regs_per_thread() <= 255);
    }
}
