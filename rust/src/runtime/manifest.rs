//! Parsing of `artifacts/manifest.json` (produced by `python -m compile.aot`).

use std::path::{Path, PathBuf};

use crate::schedule::{Dtype, Schedule};
use crate::util::json::{self, Json};

#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ArtifactKind {
    Generated,
    Baseline,
    Ablation,
    Fused,
    Unfused,
    Hand,
    Transformer,
}

impl ArtifactKind {
    pub fn parse(s: &str) -> Option<ArtifactKind> {
        Some(match s {
            "generated" => ArtifactKind::Generated,
            "baseline" => ArtifactKind::Baseline,
            "ablation" => ArtifactKind::Ablation,
            "fused" => ArtifactKind::Fused,
            "unfused" => ArtifactKind::Unfused,
            "hand" => ArtifactKind::Hand,
            "transformer" => ArtifactKind::Transformer,
            _ => return None,
        })
    }
}

#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub name: String,
    pub path: PathBuf,
    pub kind: ArtifactKind,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    /// Present for generated/ablation/fused kernels.
    pub schedule: Option<Schedule>,
    /// Present for baseline/unfused/hand entries.
    pub problem: Option<(usize, usize, usize)>,
    pub dtype_in: Option<Dtype>,
    pub dtype_acc: Option<Dtype>,
}

#[derive(Debug)]
pub struct ManifestError(pub String);

impl std::fmt::Display for ManifestError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "manifest error: {}", self.0)
    }
}

impl std::error::Error for ManifestError {}

fn specs(j: &Json, field: &str) -> Result<Vec<TensorSpec>, ManifestError> {
    let arr = j
        .get(field)
        .and_then(Json::as_arr)
        .ok_or_else(|| ManifestError(format!("missing {field}")))?;
    arr.iter()
        .map(|e| {
            let shape = e
                .get("shape")
                .and_then(Json::as_arr)
                .ok_or_else(|| ManifestError("missing shape".into()))?
                .iter()
                .map(|v| v.as_usize().ok_or_else(|| ManifestError("bad dim".into())))
                .collect::<Result<Vec<_>, _>>()?;
            let dtype = e
                .get("dtype")
                .and_then(Json::as_str)
                .and_then(Dtype::parse)
                .ok_or_else(|| ManifestError("bad dtype".into()))?;
            Ok(TensorSpec { shape, dtype })
        })
        .collect()
}

pub fn parse_manifest(text: &str, base_dir: &Path) -> Result<Vec<ArtifactMeta>, ManifestError> {
    let root = json::parse(text).map_err(|e| ManifestError(e.to_string()))?;
    let version = root.get("version").and_then(Json::as_i64).unwrap_or(0);
    if version != 1 {
        return Err(ManifestError(format!("unsupported manifest version {version}")));
    }
    let arts = root
        .get("artifacts")
        .and_then(Json::as_arr)
        .ok_or_else(|| ManifestError("missing artifacts".into()))?;
    // Artifact names are the runtime's routing keys (registry variants,
    // `Runtime::load`, program dispatch); a duplicate would silently
    // shadow one kernel with another — e.g. the PR 1 AOT quirk where
    // the ablation ladder's full-opt level shared its variant name with
    // the identically-configured generated kernel.  Refuse the manifest
    // outright instead.
    let mut seen = std::collections::HashSet::new();
    for a in arts {
        if let Some(name) = a.get("name").and_then(Json::as_str) {
            if !seen.insert(name) {
                return Err(ManifestError(format!(
                    "duplicate artifact name {name:?}: every manifest entry \
                     must be uniquely addressable (rebuild artifacts with a \
                     current python/compile/aot.py, which suffixes ablation \
                     variants)"
                )));
            }
        }
    }
    arts.iter()
        .map(|a| {
            let name = a
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| ManifestError("artifact missing name".into()))?
                .to_string();
            let file = a
                .get("file")
                .and_then(Json::as_str)
                .ok_or_else(|| ManifestError(format!("{name}: missing file")))?;
            let kind = a
                .get("kind")
                .and_then(Json::as_str)
                .and_then(ArtifactKind::parse)
                .ok_or_else(|| ManifestError(format!("{name}: bad kind")))?;
            let schedule = match a.get("schedule") {
                Some(sj) => Some(
                    Schedule::from_json(sj)
                        .map_err(|e| ManifestError(format!("{name}: {e}")))?,
                ),
                None => None,
            };
            let problem = match (
                a.get("m").and_then(Json::as_usize),
                a.get("n").and_then(Json::as_usize),
                a.get("k").and_then(Json::as_usize),
            ) {
                (Some(m), Some(n), Some(k)) => Some((m, n, k)),
                _ => schedule.as_ref().map(|s| (s.m, s.n, s.k)),
            };
            let dtype_in = a
                .get("dtype_in")
                .and_then(Json::as_str)
                .and_then(Dtype::parse)
                .or_else(|| schedule.as_ref().map(|s| s.dtype_in));
            let dtype_acc = a
                .get("dtype_acc")
                .and_then(Json::as_str)
                .and_then(Dtype::parse)
                .or_else(|| schedule.as_ref().map(|s| s.dtype_acc));
            Ok(ArtifactMeta {
                name,
                path: base_dir.join(file),
                kind,
                inputs: specs(a, "inputs")?,
                outputs: specs(a, "outputs")?,
                schedule,
                problem,
                dtype_in,
                dtype_acc,
            })
        })
        .collect()
}

pub fn load_manifest(dir: &Path) -> Result<Vec<ArtifactMeta>, ManifestError> {
    let path = dir.join("manifest.json");
    let text = std::fs::read_to_string(&path)
        .map_err(|e| ManifestError(format!("cannot read {}: {e}", path.display())))?;
    parse_manifest(&text, dir)
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "artifacts": [
        {
          "name": "baseline_m256n256k256_f16_f32",
          "file": "baseline.hlo.txt",
          "kind": "baseline",
          "inputs": [{"shape": [256, 256], "dtype": "f32"}],
          "outputs": [{"shape": [256, 256], "dtype": "f32"}],
          "m": 256, "n": 256, "k": 256,
          "dtype_in": "f16", "dtype_acc": "f32"
        }
      ]
    }"#;

    #[test]
    fn parses_baseline_entry() {
        let arts = parse_manifest(SAMPLE, Path::new("/tmp/a")).unwrap();
        assert_eq!(arts.len(), 1);
        let a = &arts[0];
        assert_eq!(a.kind, ArtifactKind::Baseline);
        assert_eq!(a.problem, Some((256, 256, 256)));
        assert_eq!(a.dtype_in, Some(Dtype::F16));
        assert_eq!(a.dtype_acc, Some(Dtype::F32));
        assert_eq!(a.path, Path::new("/tmp/a/baseline.hlo.txt"));
        assert_eq!(a.inputs[0].elements(), 256 * 256);
    }

    #[test]
    fn rejects_wrong_version() {
        let text = r#"{"version": 2, "artifacts": []}"#;
        assert!(parse_manifest(text, Path::new(".")).is_err());
    }

    #[test]
    fn rejects_bad_kind() {
        let text = SAMPLE.replace("baseline", "bogus_kind");
        assert!(parse_manifest(&text, Path::new(".")).is_err());
    }

    #[test]
    fn rejects_duplicate_artifact_names() {
        // Two entries sharing a name (the PR 1 ablation/generated
        // collision shape) must fail to parse, loudly and by name.
        let dup = r#"{
          "version": 1,
          "artifacts": [
            {
              "name": "matmul_m256_o1111111",
              "file": "a.tprog.json",
              "kind": "generated",
              "inputs": [{"shape": [256, 256], "dtype": "f32"}],
              "outputs": [{"shape": [256, 256], "dtype": "f32"}],
              "m": 256, "n": 256, "k": 256
            },
            {
              "name": "matmul_m256_o1111111",
              "file": "b.tprog.json",
              "kind": "ablation",
              "inputs": [{"shape": [256, 256], "dtype": "f32"}],
              "outputs": [{"shape": [256, 256], "dtype": "f32"}],
              "m": 256, "n": 256, "k": 256
            }
          ]
        }"#;
        let err = parse_manifest(dup, Path::new(".")).unwrap_err();
        assert!(
            err.0.contains("duplicate artifact name")
                && err.0.contains("matmul_m256_o1111111"),
            "{}",
            err.0
        );
    }
}
