//! Tensor-program executor: the in-process engine behind [`crate::runtime`].
//!
//! The AOT interchange format is a small JSON *program descriptor*
//! (`*.tprog.json`, emitted by `python/compile/aot.py`) rather than a
//! compiled binary: the offline vendor set has no PJRT bindings, so the
//! run-time side executes the artifact's declared semantics directly on
//! the host.  Precision behaviour mirrors the generated kernels: GEMM
//! inputs are rounded to `dtype_in` (f16/bf16 round-to-nearest-even at
//! the bit level), products are accumulated in f32, and outputs are
//! rounded to `dtype_acc` before the f32 artifact boundary — the same
//! in-graph cast structure `aot.py` builds around every kernel.
//!
//! Supported program types:
//!
//! * `gemm` — `C = cast(A) @ cast(B) + C` with an optional fused (or
//!   deliberately unfused) `bias` / `bias_relu` epilogue;
//! * `transformer` — the BERT-style encoder block of
//!   `python/compile/model.py::transformer_layer`, every GEMM routed
//!   through the same precision emulation.

use std::borrow::Cow;

use crate::plan::program::ProgramPlan;
use crate::plan::{self, ExecutionPlan, GemmKey, PlanEnv};
use crate::runtime::kernel::{BOperand, PrepackedB};
use crate::schedule::Dtype;
use crate::util::json::{self, Json};
use anyhow::{anyhow, bail, Result};

use super::Tensor;

/// Format tag every artifact program file must carry.
pub const TPROG_FORMAT: &str = "mlir-gemm-tprog-v1";

/// Input slot of a GEMM program's B operand — the slot a weight bind
/// replaces.  Every layer that derives the weight-bound input form from
/// the full contract (program shapes, manifest specs, server batches)
/// shares this one definition so they cannot drift.
pub const GEMM_B_INPUT_SLOT: usize = 1;

// ---------------------------------------------------------------------------
// Precision emulation
// ---------------------------------------------------------------------------

/// f32 -> IEEE binary16 bits, round-to-nearest-even.
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let man = bits & 0x007f_ffff;
    if exp == 0xff {
        // Inf / NaN (keep NaN-ness with a quiet payload bit).
        let payload = if man != 0 { 0x0200 } else { 0 };
        return sign | 0x7c00 | payload;
    }
    let e = exp - 127;
    if e >= 16 {
        return sign | 0x7c00; // overflow -> inf
    }
    if e >= -14 {
        // Normal half: drop 13 mantissa bits with RNE; mantissa carry
        // correctly bumps the exponent (and saturates to inf at e = 15).
        let half_exp = (e + 15) as u32;
        let mut m = man >> 13;
        let rem = man & 0x1fff;
        if rem > 0x1000 || (rem == 0x1000 && (m & 1) == 1) {
            m += 1;
        }
        return sign | ((half_exp << 10) + m) as u16;
    }
    if e >= -25 {
        // Subnormal half.
        let full = man | 0x0080_0000; // 24-bit mantissa with implicit bit
        let shift = 13 + (-14 - e) as u32;
        let mut m = full >> shift;
        let halfway = 1u32 << (shift - 1);
        let rem = full & ((1u32 << shift) - 1);
        if rem > halfway || (rem == halfway && (m & 1) == 1) {
            m += 1;
        }
        return sign | m as u16; // may round up into the smallest normal
    }
    sign // underflow to signed zero
}

/// IEEE binary16 bits -> f32 (exact).
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let man = (h & 0x03ff) as u32;
    let bits = if exp == 0x1f {
        sign | 0x7f80_0000 | (man << 13)
    } else if exp == 0 {
        if man == 0 {
            sign
        } else {
            // Subnormal: normalize into f32's implicit-bit form.
            let mut e = 113u32; // will end <= 112 after >= 1 shift
            let mut m = man << 13;
            while m & 0x0080_0000 == 0 {
                m <<= 1;
                e -= 1;
            }
            sign | (e << 23) | (m & 0x007f_ffff)
        }
    } else {
        sign | ((exp + 112) << 23) | (man << 13)
    };
    f32::from_bits(bits)
}

/// Round an f32 to the nearest f16-representable value (the kernel's
/// input cast), bit-identical to `f16_bits_to_f32(f32_to_f16_bits(x))`.
///
/// Single-pass hot path: for the f16 normal range the result is the
/// input with its low 13 mantissa bits rounded away (round-to-nearest-
/// even), entirely in f32 bits — no intermediate u16 materialized.  The
/// mantissa carry naturally bumps the f32 exponent exactly like the f16
/// conversion's carry, so the only extra check is saturation to infinity
/// at 2^16.  Zeros, subnormals, infinities, and NaNs (rare in GEMM
/// operands) fall back to the two-step conversion.
#[inline]
pub fn round_f16(x: f32) -> f32 {
    const F16_MIN_NORMAL: u32 = 0x3880_0000; // 2^-14 as f32 bits
    const F16_OVERFLOW: u32 = 0x4780_0000; // 2^16 as f32 bits
    const EXP_INF: u32 = 0x7f80_0000;
    let bits = x.to_bits();
    let mag = bits & 0x7fff_ffff;
    if (F16_MIN_NORMAL..EXP_INF).contains(&mag) {
        let rem = bits & 0x1fff;
        let mut out = bits & !0x1fff;
        if rem > 0x1000 || (rem == 0x1000 && (out & 0x2000) != 0) {
            out += 0x2000;
        }
        if (out & 0x7fff_ffff) >= F16_OVERFLOW {
            return f32::from_bits((bits & 0x8000_0000) | EXP_INF);
        }
        return f32::from_bits(out);
    }
    f16_bits_to_f32(f32_to_f16_bits(x))
}

/// Round an f32 through bfloat16 and back (round-to-nearest-even).
pub fn round_bf16(x: f32) -> f32 {
    if x.is_nan() {
        return x;
    }
    let bits = x.to_bits();
    let mut hi = bits >> 16;
    let rem = bits & 0xffff;
    if rem > 0x8000 || (rem == 0x8000 && (hi & 1) == 1) {
        hi += 1;
    }
    f32::from_bits(hi << 16)
}

/// Round a value to the given storage dtype (identity for f32).
pub fn round_to(dtype: Dtype, x: f32) -> f32 {
    match dtype {
        Dtype::F16 => round_f16(x),
        Dtype::Bf16 => round_bf16(x),
        Dtype::F32 => x,
    }
}

/// Round a slice to the storage dtype.  For `Dtype::F32` the cast is the
/// identity, so the input is *borrowed* — no allocation, no copy — which
/// removes a full operand copy from every f32 execute.
fn cast_slice(dtype: Dtype, v: &[f32]) -> Cow<'_, [f32]> {
    match dtype {
        Dtype::F32 => Cow::Borrowed(v),
        Dtype::F16 => Cow::Owned(v.iter().map(|&x| round_f16(x)).collect()),
        Dtype::Bf16 => Cow::Owned(v.iter().map(|&x| round_bf16(x)).collect()),
    }
}

/// Owned rounded copy (for the C accumulator, which is mutated in place
/// and therefore always needs its own buffer).
fn cast_owned(dtype: Dtype, v: &[f32]) -> Vec<f32> {
    cast_slice(dtype, v).into_owned()
}

/// Append `src` to `dst` rounded to `dtype`: the precision cast fused
/// into the batch-stacking copy, no intermediate buffer.
fn cast_extend(dtype: Dtype, dst: &mut Vec<f32>, src: &[f32]) {
    match dtype {
        Dtype::F32 => dst.extend_from_slice(src),
        Dtype::F16 => dst.extend(src.iter().map(|&x| round_f16(x))),
        Dtype::Bf16 => dst.extend(src.iter().map(|&x| round_bf16(x))),
    }
}

/// Compile the default execution plan for an internal GEMM of the given
/// shape: composite programs (the transformer) plan each of their
/// internal GEMMs through the same pass pipeline the serving path uses.
/// Compilation only fails for a hand-built environment forcing an
/// invalid blocking (the parse path rejects those earlier); rather than
/// panic there, fall back to the always-valid naive plan — bit-identical
/// by the engine invariant.
fn internal_plan(
    m: usize,
    n: usize,
    k: usize,
    dtype_in: Dtype,
    dtype_acc: Dtype,
    env: &PlanEnv,
) -> ExecutionPlan {
    let key = GemmKey { m, n, k, dtype_in, dtype_acc, epilogue: "none".into() };
    plan::compile(&key, env).unwrap_or_else(|_| {
        ExecutionPlan::manual(&key, crate::runtime::kernel::KernelPolicy::Naive, false)
            .expect("the naive plan is always valid")
    })
}

thread_local! {
    /// Activation (A-operand) casts performed by the most recent
    /// plan-driven transformer execution on this thread.  Casts happen
    /// on the calling thread, so the counter is race-free under the
    /// parallel test harness.
    static TF_ACTIVATION_CASTS: std::cell::Cell<usize> = std::cell::Cell::new(0);
}

/// Test/bench hook: how many activation casts the last plan-driven
/// transformer execution on this thread performed.  The cast-hoist pass
/// guarantees exactly one per GEMM-chain input (4 for the encoder block:
/// x shared across q/k/v, ctx, hn, up) — pinned by the counter test in
/// `tests/program_plan.rs`.
#[doc(hidden)]
pub fn transformer_activation_casts() -> usize {
    TF_ACTIVATION_CASTS.with(|c| c.get())
}

/// The lifetime-based scratch arena behind the ProgramPlan buffer-reuse
/// pass.  `take` hands out the first free slot (growing the pool when
/// none is free) zero-filled to `len` — bit-identical to a fresh
/// `vec![0.0; len]` — and `put` returns it.  Because the executor takes
/// and returns buffers in the exact birth/death order the compile-time
/// pass scheduled, the slot assignment it produces at run time is the
/// same first-fit assignment recorded in the plan's `arena` section.
struct ScratchArena {
    slots: Vec<Vec<f32>>,
    free: Vec<bool>,
}

impl ScratchArena {
    fn new() -> Self {
        ScratchArena { slots: Vec::new(), free: Vec::new() }
    }

    /// Claim a free slot zero-filled to `len` elements.
    fn take(&mut self, len: usize) -> (usize, Vec<f32>) {
        let slot = match self.free.iter().position(|&f| f) {
            Some(slot) => slot,
            None => {
                self.slots.push(Vec::new());
                self.free.push(true);
                self.slots.len() - 1
            }
        };
        self.free[slot] = false;
        let mut buf = std::mem::take(&mut self.slots[slot]);
        buf.clear();
        buf.resize(len, 0.0);
        (slot, buf)
    }

    /// Return a buffer claimed with [`ScratchArena::take`].
    fn put(&mut self, slot: usize, buf: Vec<f32>) {
        self.slots[slot] = buf;
        self.free[slot] = true;
    }

    /// Distinct slots ever claimed (the arena footprint).
    fn slots_used(&self) -> usize {
        self.slots.len()
    }
}

/// Run one planned GEMM body over an f32 accumulator: the matmul through
/// the plan's lowered kernel, then the epilogue/rounding tail — fused
/// into the kernel's per-band write-back when the plan says so (and the
/// program is not the deliberately-unfused Table 1 comparator), as a
/// separate whole-matrix pass otherwise.  Bit-identical either way: the
/// tail is elementwise per row and runs exactly once per element after
/// its full k-reduction.
#[allow(clippy::too_many_arguments)]
fn run_planned_gemm(
    eplan: &ExecutionPlan,
    acc: &mut [f32],
    a: &[f32],
    b: BOperand,
    bias: Option<&[f32]>,
    n: usize,
    dtype_acc: Dtype,
    epilogue: Epilogue,
    fused: bool,
) {
    if eplan.fuse_epilogue && fused {
        eplan.matmul_fused_b(acc, a, b, &|band: &mut [f32]| {
            gemm_tail(band, bias, n, dtype_acc, epilogue, fused)
        });
    } else {
        eplan.matmul_b(acc, a, b);
        gemm_tail(acc, bias, n, dtype_acc, epilogue, fused);
    }
}

// ---------------------------------------------------------------------------
// Bound weights
// ---------------------------------------------------------------------------

/// A constant B operand bound to a GEMM variant: precision-cast to the
/// program's `dtype_in` once at bind time and — when the plan's prepack
/// pass says so — materialized into kernel panel layout
/// ([`PrepackedB`]), then shared immutably across every request.  The
/// per-call path casts then packs per request; binding does both once.
/// Both steps are elementwise/rearrangement-only, so weight-bound
/// execution is bit-identical to shipping the same B inline.
#[derive(Debug, Clone)]
pub struct BoundB {
    /// The `dtype_in`-rounded B, row-major: the raw operand when no
    /// panels exist (direct-kernel plans) and the split-K slicing
    /// source for sharded execution.
    b: Vec<f32>,
    prepacked: Option<PrepackedB>,
    k: usize,
    n: usize,
}

impl BoundB {
    /// The kernel-facing operand: panels when prepacked, the cast raw
    /// slice otherwise.
    pub fn operand(&self) -> BOperand<'_> {
        match &self.prepacked {
            Some(pre) => BOperand::Prepacked(pre),
            None => BOperand::Raw(&self.b),
        }
    }

    pub fn is_prepacked(&self) -> bool {
        self.prepacked.is_some()
    }

    /// The cast (but unpacked) B, row-major k x n.
    pub fn raw(&self) -> &[f32] {
        &self.b
    }

    pub fn k(&self) -> usize {
        self.k
    }

    pub fn n(&self) -> usize {
        self.n
    }
}

/// Cast a weight to `dtype_in` and prepack it under `plan` — the one
/// bind-time construction shared by GEMM and transformer binding.
fn bind_weight(plan: &ExecutionPlan, w: &[f32], dtype_in: Dtype) -> BoundB {
    let cast = cast_owned(dtype_in, w);
    let prepacked = plan.prepack_b(&cast);
    BoundB { b: cast, prepacked, k: plan.k, n: plan.n }
}

// ---------------------------------------------------------------------------
// Program descriptor
// ---------------------------------------------------------------------------

/// Fused epilogue of a GEMM program.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Epilogue {
    None,
    Bias,
    BiasRelu,
}

impl Epilogue {
    pub fn parse(s: &str) -> Option<Epilogue> {
        match s {
            "none" => Some(Epilogue::None),
            "bias" => Some(Epilogue::Bias),
            "bias_relu" => Some(Epilogue::BiasRelu),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Epilogue::None => "none",
            Epilogue::Bias => "bias",
            Epilogue::BiasRelu => "bias_relu",
        }
    }

    pub fn needs_bias(self) -> bool {
        !matches!(self, Epilogue::None)
    }
}

/// Executable semantics of one artifact.
#[derive(Debug, Clone, PartialEq)]
pub enum Program {
    Gemm {
        m: usize,
        n: usize,
        k: usize,
        dtype_in: Dtype,
        dtype_acc: Dtype,
        epilogue: Epilogue,
        /// `false` for the deliberately-unfused Table 1 comparator: the
        /// epilogue runs as a second pass after the output cast instead
        /// of on the accumulator.
        fused: bool,
    },
    Transformer {
        seq: usize,
        d_model: usize,
        d_ff: usize,
        n_heads: usize,
        dtype_in: Dtype,
    },
}

impl Program {
    /// Parse a `*.tprog.json` artifact file, checking the format tag and
    /// that the descriptor belongs to the expected artifact.
    pub fn from_text(text: &str, expected_name: &str) -> Result<Program> {
        let root = json::parse(text).map_err(|e| anyhow!("{e}"))?;
        let format = root.get("format").and_then(Json::as_str).unwrap_or("");
        if format != TPROG_FORMAT {
            bail!("unsupported program format {format:?} (want {TPROG_FORMAT})");
        }
        let name = root.get("name").and_then(Json::as_str).unwrap_or("");
        if name != expected_name {
            bail!("program is for artifact {name:?}, expected {expected_name:?}");
        }
        let prog = root
            .get("program")
            .ok_or_else(|| anyhow!("missing program object"))?;
        Program::from_json(prog)
    }

    pub fn from_json(j: &Json) -> Result<Program> {
        let get_u = |f: &str| {
            j.get(f)
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("missing/invalid field {f:?}"))
        };
        let get_d = |f: &str| {
            j.get(f)
                .and_then(Json::as_str)
                .and_then(Dtype::parse)
                .ok_or_else(|| anyhow!("missing/invalid dtype field {f:?}"))
        };
        match j.get("type").and_then(Json::as_str) {
            Some("gemm") => {
                let epilogue = j
                    .get("epilogue")
                    .and_then(Json::as_str)
                    .and_then(Epilogue::parse)
                    .ok_or_else(|| anyhow!("missing/invalid epilogue"))?;
                Ok(Program::Gemm {
                    m: get_u("m")?,
                    n: get_u("n")?,
                    k: get_u("k")?,
                    dtype_in: get_d("dtype_in")?,
                    dtype_acc: get_d("dtype_acc")?,
                    epilogue,
                    fused: j.get("fused").and_then(Json::as_bool).unwrap_or(true),
                })
            }
            Some("transformer") => Ok(Program::Transformer {
                seq: get_u("seq")?,
                d_model: get_u("d_model")?,
                d_ff: get_u("d_ff")?,
                n_heads: get_u("n_heads")?,
                dtype_in: get_d("dtype_in")?,
            }),
            Some(other) => bail!("unknown program type {other:?}"),
            None => bail!("program object missing \"type\""),
        }
    }

    /// Input tensor shapes in call order (all f32 at the boundary).
    pub fn input_shapes(&self) -> Vec<Vec<usize>> {
        match *self {
            Program::Gemm { m, n, k, epilogue, .. } => {
                let mut shapes = vec![vec![m, k], vec![k, n], vec![m, n]];
                if epilogue.needs_bias() {
                    shapes.push(vec![n]);
                }
                shapes
            }
            Program::Transformer { seq, d_model, d_ff, .. } => vec![
                vec![seq, d_model],          // x
                vec![d_model, 3 * d_model],  // w_qkv
                vec![d_model, d_model],      // w_out
                vec![d_model, d_ff],         // w_up
                vec![d_ff],                  // b_up
                vec![d_ff, d_model],         // w_dn
                vec![d_model],               // b_dn
            ],
        }
    }

    pub fn output_shapes(&self) -> Vec<Vec<usize>> {
        match *self {
            Program::Gemm { m, n, .. } => vec![vec![m, n]],
            Program::Transformer { seq, d_model, .. } => vec![vec![seq, d_model]],
        }
    }

    /// Input shapes of the weight-bound request form: the full contract
    /// minus the B operand (bound once per variant instead of shipped
    /// per request).  GEMM programs only.
    pub fn bound_input_shapes(&self) -> Vec<Vec<usize>> {
        let mut shapes = self.input_shapes();
        if matches!(self, Program::Gemm { .. }) {
            shapes.remove(GEMM_B_INPUT_SLOT);
        }
        shapes
    }

    /// Validate inputs against the program's own contract (the runtime
    /// additionally validates against the manifest before calling in).
    fn validate_inputs(&self, inputs: &[Tensor]) -> Result<()> {
        validate_against(inputs, &self.input_shapes())
    }

    /// The GEMM routing/compilation key of this program (`None` for
    /// composite programs, which plan each internal GEMM separately).
    pub fn gemm_key(&self) -> Option<GemmKey> {
        match self {
            Program::Gemm { m, n, k, dtype_in, dtype_acc, epilogue, .. } => Some(GemmKey {
                m: *m,
                n: *n,
                k: *k,
                dtype_in: *dtype_in,
                dtype_acc: *dtype_acc,
                epilogue: epilogue.name().to_string(),
            }),
            Program::Transformer { .. } => None,
        }
    }

    /// Compile this GEMM program's execution plan under `env`.
    pub fn compile_plan(&self, env: &PlanEnv) -> Result<ExecutionPlan> {
        let key = self
            .gemm_key()
            .ok_or_else(|| anyhow!("composite programs plan per internal GEMM"))?;
        plan::compile(&key, env)
    }

    /// Compile the graph-level plan of a composite program under `env`:
    /// the whole-program analogue of [`Program::compile_plan`], running
    /// the op-graph / cast-hoist / buffer-reuse / pipeline passes on top
    /// of the per-GEMM pipeline.  GEMM programs are an error here — they
    /// compile a per-GEMM [`ExecutionPlan`] instead.
    pub fn compile_program_plan(&self, env: &PlanEnv) -> Result<ProgramPlan> {
        plan::program::compile_program(self, env)
    }

    /// Execute a composite program under an explicit, already-compiled
    /// [`ProgramPlan`] — the transformer analogue of
    /// [`Program::execute_planned`], and the serving hot path for
    /// composite variants.  The plan must describe this exact program; a
    /// mismatch is an error, never silent cross-contamination.
    pub fn execute_program_planned(
        &self,
        inputs: &[Tensor],
        pplan: &ProgramPlan,
    ) -> Result<Vec<Tensor>> {
        let Program::Transformer { seq, d_model, .. } = *self else {
            bail!("execute_program_planned is for composite programs; gemm programs take execute_planned");
        };
        self.validate_inputs(inputs)?;
        if !pplan.matches(self) {
            bail!(
                "program plan {} does not describe this transformer program",
                pplan.id()
            );
        }
        let out = exec_transformer_planned(
            &inputs[0].data,
            TfWeights {
                w_qkv: BOperand::Raw(&inputs[1].data),
                w_out: BOperand::Raw(&inputs[2].data),
                w_up: BOperand::Raw(&inputs[3].data),
                w_dn: BOperand::Raw(&inputs[5].data),
                cast_weights: true,
                b_up: &inputs[4].data,
                b_dn: &inputs[6].data,
            },
            pplan,
        )?;
        Ok(vec![Tensor { shape: vec![seq, d_model], data: out }])
    }

    /// Batched [`Program::execute_program_planned`]: one compiled graph
    /// plan drives every item (the batch analogue the per-GEMM path gets
    /// from [`Program::execute_batch_planned`]).
    pub fn execute_batch_program_planned(
        &self,
        items: &[Vec<Tensor>],
        pplan: &ProgramPlan,
    ) -> Result<Vec<Vec<Tensor>>> {
        items
            .iter()
            .map(|inputs| self.execute_program_planned(inputs, pplan))
            .collect()
    }

    /// The pre-ProgramPlan transformer hand loop: per-op plans compiled
    /// inline, per-op allocations, per-GEMM activation casts.  Kept as
    /// the seed oracle — the bit-exactness pins and the bench smoke gate
    /// compare the plan-driven path against it.
    #[doc(hidden)]
    pub fn execute_transformer_seed(
        &self,
        inputs: &[Tensor],
        env: &PlanEnv,
    ) -> Result<Vec<Tensor>> {
        let Program::Transformer { seq, d_model, d_ff, n_heads, dtype_in } = *self
        else {
            bail!("execute_transformer_seed is for transformer programs");
        };
        self.validate_inputs(inputs)?;
        let out = exec_transformer(inputs, seq, d_model, d_ff, n_heads, dtype_in, env);
        Ok(vec![Tensor { shape: vec![seq, d_model], data: out }])
    }

    /// Execute on host tensors under the default plan environment.
    pub fn execute(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        self.execute_with_env(inputs, &PlanEnv::default())
    }

    /// Execute with plans compiled from the given environment (GEMM
    /// programs compile one plan; the transformer compiles one per
    /// internal GEMM).
    pub fn execute_with_env(&self, inputs: &[Tensor], env: &PlanEnv) -> Result<Vec<Tensor>> {
        match *self {
            Program::Gemm { .. } => {
                let eplan = self.compile_plan(env)?;
                self.execute_planned(inputs, &eplan)
            }
            Program::Transformer { .. } => {
                // Composite programs compile the whole-graph plan and run
                // plan-driven.  Bit-identical to the seed hand loop: the
                // plan's per-op keys match the loop's internal plans, and
                // cast hoisting / buffer reuse do not change any bit (see
                // DESIGN.md §11).
                let pplan = self.compile_program_plan(env)?;
                self.execute_program_planned(inputs, &pplan)
            }
        }
    }

    /// Execute a GEMM program under an explicit, already-compiled
    /// [`ExecutionPlan`] — the serving hot path (the server threads the
    /// registry-cached plan through here).  The plan must describe this
    /// exact GEMM contract; a mismatch is an error, never silent
    /// cross-contamination.
    pub fn execute_planned(
        &self,
        inputs: &[Tensor],
        eplan: &ExecutionPlan,
    ) -> Result<Vec<Tensor>> {
        let Program::Gemm { m, n, k, dtype_in, dtype_acc, epilogue, fused } = *self else {
            bail!("execute_planned is for gemm programs; composite programs take execute_with_env");
        };
        self.validate_inputs(inputs)?;
        if !eplan.matches_gemm(m, n, k, dtype_in, dtype_acc, epilogue.name()) {
            bail!(
                "plan {} does not match program {m}x{n}x{k} {}->{} epilogue {}",
                eplan.id(),
                dtype_in.name(),
                dtype_acc.name(),
                epilogue.name()
            );
        }
        let out = exec_gemm(
            eplan,
            &inputs[0].data,
            &inputs[1].data,
            &inputs[2].data,
            inputs.get(3).map(|t| t.data.as_slice()),
            n,
            dtype_in,
            dtype_acc,
            epilogue,
            fused,
        );
        Ok(vec![Tensor { shape: vec![m, n], data: out }])
    }

    /// Bind a constant B for this GEMM program: validate its shape
    /// against the contract (rejected here, at bind time — never at
    /// request time), cast it to `dtype_in` once, and prepack its panels
    /// when `eplan` says so.
    pub fn bind_b(&self, b: &Tensor, eplan: &ExecutionPlan) -> Result<BoundB> {
        let Program::Gemm { m, n, k, dtype_in, dtype_acc, epilogue, .. } = *self else {
            bail!("only gemm programs bind a B weight; see bind_transformer_weights");
        };
        if !eplan.matches_gemm(m, n, k, dtype_in, dtype_acc, epilogue.name()) {
            bail!(
                "plan {} does not match program {m}x{n}x{k} for weight binding",
                eplan.id()
            );
        }
        if b.shape != [k, n] || b.data.len() != k * n {
            bail!(
                "bound B has shape {:?} ({} elements), program wants [{k}, {n}]",
                b.shape,
                b.data.len()
            );
        }
        Ok(bind_weight(eplan, &b.data, dtype_in))
    }

    /// [`Program::execute_planned`] for a weight-bound request: `inputs`
    /// is the A + C (+ bias) form — the B operand comes from `bound`,
    /// already cast and (when the plan prepacks) already in panel
    /// layout.  Bit-identical to [`Program::execute_planned`] with the
    /// same B shipped inline.
    pub fn execute_planned_bound(
        &self,
        inputs: &[Tensor],
        eplan: &ExecutionPlan,
        bound: &BoundB,
    ) -> Result<Vec<Tensor>> {
        let Program::Gemm { m, n, k, dtype_in, dtype_acc, epilogue, fused } = *self else {
            bail!("execute_planned_bound is for gemm programs");
        };
        validate_against(inputs, &self.bound_input_shapes())?;
        if !eplan.matches_gemm(m, n, k, dtype_in, dtype_acc, epilogue.name()) {
            bail!(
                "plan {} does not match program {m}x{n}x{k} {}->{} epilogue {}",
                eplan.id(),
                dtype_in.name(),
                dtype_acc.name(),
                epilogue.name()
            );
        }
        if (bound.k, bound.n) != (k, n) {
            bail!(
                "bound weights are {}x{}, program wants {k}x{n}",
                bound.k,
                bound.n
            );
        }
        let a16 = cast_slice(dtype_in, &inputs[0].data);
        let mut acc = cast_owned(dtype_acc, &inputs[1].data);
        run_planned_gemm(
            eplan,
            &mut acc,
            &a16,
            bound.operand(),
            inputs.get(2).map(|t| t.data.as_slice()),
            n,
            dtype_acc,
            epilogue,
            fused,
        );
        Ok(vec![Tensor { shape: vec![m, n], data: acc }])
    }

    /// [`Program::execute_batch_planned`] for a weight-bound batch: A
    /// and C stack and cast once across the batch, and B is neither
    /// shipped, cast, nor packed at all — every item consumes the one
    /// shared bind-time operand.
    pub fn execute_batch_planned_bound(
        &self,
        items: &[Vec<Tensor>],
        eplan: &ExecutionPlan,
        bound: &BoundB,
    ) -> Result<Vec<Vec<Tensor>>> {
        let Program::Gemm { m, n, k, dtype_in, dtype_acc, epilogue, fused } = *self else {
            bail!("execute_batch_planned_bound is for gemm programs");
        };
        if items.len() < 2 {
            return items
                .iter()
                .map(|inputs| self.execute_planned_bound(inputs, eplan, bound))
                .collect();
        }
        if !eplan.matches_gemm(m, n, k, dtype_in, dtype_acc, epilogue.name()) {
            bail!(
                "plan {} does not match program {m}x{n}x{k} {}->{} epilogue {}",
                eplan.id(),
                dtype_in.name(),
                dtype_acc.name(),
                epilogue.name()
            );
        }
        if (bound.k, bound.n) != (k, n) {
            bail!(
                "bound weights are {}x{}, program wants {k}x{n}",
                bound.k,
                bound.n
            );
        }
        let want = self.bound_input_shapes();
        for (bi, inputs) in items.iter().enumerate() {
            validate_against(inputs, &want)
                .map_err(|e| anyhow!("batch item {bi}: {e}"))?;
        }
        let bsz = items.len();
        let mut a_s = Vec::with_capacity(bsz * m * k);
        let mut acc_s = Vec::with_capacity(bsz * m * n);
        for inputs in items {
            cast_extend(dtype_in, &mut a_s, &inputs[0].data);
            cast_extend(dtype_acc, &mut acc_s, &inputs[1].data);
        }
        let mut outs = Vec::with_capacity(bsz);
        for (bi, inputs) in items.iter().enumerate() {
            let a = &a_s[bi * m * k..(bi + 1) * m * k];
            let acc = &mut acc_s[bi * m * n..(bi + 1) * m * n];
            run_planned_gemm(
                eplan,
                acc,
                a,
                bound.operand(),
                inputs.get(2).map(|t| t.data.as_slice()),
                n,
                dtype_acc,
                epilogue,
                fused,
            );
            outs.push(vec![Tensor { shape: vec![m, n], data: acc.to_vec() }]);
        }
        Ok(outs)
    }

    /// Execute a whole same-program batch in one call, under the default
    /// plan environment.
    pub fn execute_batch(&self, items: &[Vec<Tensor>]) -> Result<Vec<Vec<Tensor>>> {
        self.execute_batch_with_env(items, &PlanEnv::default())
    }

    /// [`Program::execute_batch`] with plans compiled from `env`.
    pub fn execute_batch_with_env(
        &self,
        items: &[Vec<Tensor>],
        env: &PlanEnv,
    ) -> Result<Vec<Vec<Tensor>>> {
        match self {
            Program::Gemm { .. } if items.len() >= 2 => {
                let eplan = self.compile_plan(env)?;
                self.execute_batch_planned(items, &eplan)
            }
            Program::Gemm { .. } => {
                items.iter().map(|inputs| self.execute_with_env(inputs, env)).collect()
            }
            Program::Transformer { .. } => {
                // One graph-level plan compiles once and drives the whole
                // batch.
                let pplan = self.compile_program_plan(env)?;
                self.execute_batch_program_planned(items, &pplan)
            }
        }
    }

    /// Execute a whole same-program batch under an explicit plan.
    ///
    /// For GEMM programs the operands are stacked and precision-cast once
    /// across the batch (single pack), the per-item GEMMs run over the
    /// stacked buffers, and per-item outputs materialize in one pass
    /// (single unpack).  Bit-identical to calling [`Program::execute`]
    /// once per item; composite programs fall back to exactly that.
    pub fn execute_batch_planned(
        &self,
        items: &[Vec<Tensor>],
        eplan: &ExecutionPlan,
    ) -> Result<Vec<Vec<Tensor>>> {
        let Program::Gemm { m, n, k, dtype_in, dtype_acc, epilogue, fused } = *self
        else {
            // Composite programs: compile the graph plan once (default
            // environment, matching `execute`) and drive every item with
            // it rather than re-planning per item.
            let pplan = self.compile_program_plan(&PlanEnv::default())?;
            return self.execute_batch_program_planned(items, &pplan);
        };
        if items.len() < 2 {
            return items.iter().map(|inputs| self.execute_planned(inputs, eplan)).collect();
        }
        if !eplan.matches_gemm(m, n, k, dtype_in, dtype_acc, epilogue.name()) {
            bail!(
                "plan {} does not match program {m}x{n}x{k} {}->{} epilogue {}",
                eplan.id(),
                dtype_in.name(),
                dtype_acc.name(),
                epilogue.name()
            );
        }
        let want = self.input_shapes();
        for (bi, inputs) in items.iter().enumerate() {
            if inputs.len() != want.len() {
                bail!(
                    "batch item {bi}: program expects {} inputs, got {}",
                    want.len(),
                    inputs.len()
                );
            }
            for (i, (t, w)) in inputs.iter().zip(&want).enumerate() {
                if &t.shape != w {
                    bail!(
                        "batch item {bi}: input {i} has shape {:?}, want {w:?}",
                        t.shape
                    );
                }
                let want_len: usize = w.iter().product();
                if t.data.len() != want_len {
                    bail!(
                        "batch item {bi}: input {i} has {} elements for shape {:?}",
                        t.data.len(),
                        t.shape
                    );
                }
            }
        }
        let bsz = items.len();
        // Single pack: stack each operand across the batch with the
        // precision cast fused into the copy.
        let mut a_s = Vec::with_capacity(bsz * m * k);
        let mut b_s = Vec::with_capacity(bsz * k * n);
        let mut acc_s = Vec::with_capacity(bsz * m * n);
        for inputs in items {
            cast_extend(dtype_in, &mut a_s, &inputs[0].data);
            cast_extend(dtype_in, &mut b_s, &inputs[1].data);
            cast_extend(dtype_acc, &mut acc_s, &inputs[2].data);
        }
        let mut outs = Vec::with_capacity(bsz);
        for (bi, inputs) in items.iter().enumerate() {
            let a = &a_s[bi * m * k..(bi + 1) * m * k];
            let b = &b_s[bi * k * n..(bi + 1) * k * n];
            let acc = &mut acc_s[bi * m * n..(bi + 1) * m * n];
            run_planned_gemm(
                eplan,
                acc,
                a,
                BOperand::Raw(b),
                inputs.get(3).map(|t| t.data.as_slice()),
                n,
                dtype_acc,
                epilogue,
                fused,
            );
            outs.push(vec![Tensor { shape: vec![m, n], data: acc.to_vec() }]);
        }
        Ok(outs)
    }
}

/// Shape/length validation of a tensor list against an expected-shape
/// list (the program contract, full or weight-bound form).
fn validate_against(inputs: &[Tensor], want: &[Vec<usize>]) -> Result<()> {
    if inputs.len() != want.len() {
        bail!("program expects {} inputs, got {}", want.len(), inputs.len());
    }
    for (i, (t, w)) in inputs.iter().zip(want).enumerate() {
        if &t.shape != w {
            bail!("program input {i} has shape {:?}, want {w:?}", t.shape);
        }
        let want_len: usize = w.iter().product();
        if t.data.len() != want_len {
            bail!(
                "program input {i} has {} elements for shape {:?}",
                t.data.len(),
                t.shape
            );
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Kernels
// ---------------------------------------------------------------------------

/// Epilogue + output-rounding tail shared by the single-item and batched
/// GEMM paths — and by the split-K shard reduction
/// (`coordinator::sharding`), which must reproduce this exact tail after
/// summing partial products.  `acc` holds `cast(C) + A @ B` partials in
/// f32.
pub(crate) fn gemm_tail(
    acc: &mut [f32],
    bias: Option<&[f32]>,
    n: usize,
    dtype_acc: Dtype,
    epilogue: Epilogue,
    fused: bool,
) {
    if !fused {
        // Unfused comparator: the GEMM output takes a full trip through
        // the f32 artifact boundary before the epilogue pass.
        for v in acc.iter_mut() {
            *v = round_to(dtype_acc, *v);
        }
    }
    match (epilogue, bias) {
        (Epilogue::None, _) => {}
        (Epilogue::Bias, Some(bias)) => {
            for row in acc.chunks_mut(n) {
                for (v, &bv) in row.iter_mut().zip(bias) {
                    *v += bv;
                }
            }
        }
        (Epilogue::BiasRelu, Some(bias)) => {
            for row in acc.chunks_mut(n) {
                for (v, &bv) in row.iter_mut().zip(bias) {
                    *v = (*v + bv).max(0.0);
                }
            }
        }
        // Unreachable after shape validation; keep the output defined.
        (_, None) => {}
    }
    for v in acc.iter_mut() {
        *v = round_to(dtype_acc, *v);
    }
}

#[allow(clippy::too_many_arguments)]
fn exec_gemm(
    eplan: &ExecutionPlan,
    a: &[f32],
    b: &[f32],
    c: &[f32],
    bias: Option<&[f32]>,
    n: usize,
    dtype_in: Dtype,
    dtype_acc: Dtype,
    epilogue: Epilogue,
    fused: bool,
) -> Vec<f32> {
    let a16 = cast_slice(dtype_in, a);
    let b16 = cast_slice(dtype_in, b);
    let mut acc = cast_owned(dtype_acc, c);
    run_planned_gemm(
        eplan,
        &mut acc,
        &a16,
        BOperand::Raw(&b16[..]),
        bias,
        n,
        dtype_acc,
        epilogue,
        fused,
    );
    acc
}

/// Transformer weights bound once at load: the four pipeline-GEMM
/// weights (`w_qkv`, `w_out`, `w_up`, `w_dn`) are `dtype_in`-cast and
/// prepacked under the graph plan's per-op plans, the bias vectors are
/// copied through, and [`Program::execute_transformer_bound`] then
/// serves any number of activations against the shared panels —
/// bit-identical to [`Program::execute_with_env`] with the weights
/// shipped per call (pinned by the test below).
#[derive(Debug, Clone)]
pub struct TransformerBound {
    w_qkv: BoundB,
    w_out: BoundB,
    w_up: BoundB,
    w_dn: BoundB,
    b_up: Vec<f32>,
    b_dn: Vec<f32>,
    /// The graph-level plan the weights were bound under; bound
    /// execution is driven by it.
    pplan: ProgramPlan,
}

impl TransformerBound {
    /// The compiled graph plan this binding executes under.
    pub fn program_plan(&self) -> &ProgramPlan {
        &self.pplan
    }
}

impl Program {
    /// Bind a transformer's weights once: `weights` is the input list
    /// minus the leading activation (`w_qkv, w_out, w_up, b_up, w_dn,
    /// b_dn`, the order of [`Program::input_shapes`]).  The graph plan
    /// compiles here, and each weight binds under its op's plan.
    pub fn bind_transformer_weights(
        &self,
        weights: &[Tensor],
        env: &PlanEnv,
    ) -> Result<TransformerBound> {
        let Program::Transformer { dtype_in, .. } = *self else {
            bail!("bind_transformer_weights is for transformer programs");
        };
        let all_shapes = self.input_shapes();
        validate_against(weights, &all_shapes[1..])
            .map_err(|e| anyhow!("transformer weights: {e}"))?;
        let pplan = self.compile_program_plan(env)?;
        Ok(TransformerBound {
            w_qkv: bind_weight(pplan.op_plan("qkv")?, &weights[0].data, dtype_in),
            w_out: bind_weight(pplan.op_plan("attn_out")?, &weights[1].data, dtype_in),
            w_up: bind_weight(pplan.op_plan("ffn_up")?, &weights[2].data, dtype_in),
            w_dn: bind_weight(pplan.op_plan("ffn_dn")?, &weights[4].data, dtype_in),
            b_up: weights[3].data.clone(),
            b_dn: weights[5].data.clone(),
            pplan,
        })
    }

    /// Execute the transformer against weights bound at load: only the
    /// activation travels per call, and the binding's own graph plan
    /// drives the execution.
    pub fn execute_transformer_bound(
        &self,
        x: &Tensor,
        bound: &TransformerBound,
    ) -> Result<Vec<Tensor>> {
        let Program::Transformer { seq, d_model, d_ff, .. } = *self else {
            bail!("execute_transformer_bound is for transformer programs");
        };
        if x.shape != [seq, d_model] || x.data.len() != seq * d_model {
            bail!(
                "activation has shape {:?} ({} elements), want [{seq}, {d_model}]",
                x.shape,
                x.data.len()
            );
        }
        // The binding's plan must describe this exact program, and the
        // bound operands must agree with the shape too: a bind from a
        // different-shape program would otherwise pass here and assert
        // deep in the kernel.
        if !bound.pplan.matches(self)
            || (bound.w_qkv.k, bound.w_up.n) != (d_model, d_ff)
        {
            bail!("bound transformer weights do not match this program's shape");
        }
        let out = exec_transformer_planned(
            &x.data,
            TfWeights {
                w_qkv: bound.w_qkv.operand(),
                w_out: bound.w_out.operand(),
                w_up: bound.w_up.operand(),
                w_dn: bound.w_dn.operand(),
                cast_weights: false,
                b_up: &bound.b_up,
                b_dn: &bound.b_dn,
            },
            &bound.pplan,
        )?;
        Ok(vec![Tensor { shape: vec![seq, d_model], data: out }])
    }
}

/// The transformer's weight operands, raw-per-call or bound-at-load.
struct TfWeights<'a> {
    w_qkv: BOperand<'a>,
    w_out: BOperand<'a>,
    w_up: BOperand<'a>,
    w_dn: BOperand<'a>,
    /// Cast raw weights to `dtype_in` before each GEMM.  False for
    /// bound weights, which were cast at bind time (the cast is
    /// idempotent, so either way yields the same bits — skipping it
    /// just saves work).
    cast_weights: bool,
    b_up: &'a [f32],
    b_dn: &'a [f32],
}

/// Mirror of `python/compile/model.py::transformer_layer` (f32 host math,
/// `dtype_in` rounding on every pipeline-GEMM input).  Each internal GEMM
/// runs under its own compiled plan; plan choice is bit-invisible, so the
/// output is independent of `env` (pinned by the equivalence test below).
///
/// This is the seed hand loop, kept verbatim as the oracle the
/// plan-driven path ([`exec_transformer_planned`]) must match bit for
/// bit.  Production entry points all route through the ProgramPlan path;
/// this one is reachable via [`Program::execute_transformer_seed`].
fn exec_transformer(
    inputs: &[Tensor],
    seq: usize,
    d_model: usize,
    d_ff: usize,
    n_heads: usize,
    dtype_in: Dtype,
    env: &PlanEnv,
) -> Vec<f32> {
    exec_transformer_core(
        &inputs[0].data,
        TfWeights {
            w_qkv: BOperand::Raw(&inputs[1].data),
            w_out: BOperand::Raw(&inputs[2].data),
            w_up: BOperand::Raw(&inputs[3].data),
            w_dn: BOperand::Raw(&inputs[5].data),
            cast_weights: true,
            b_up: &inputs[4].data,
            b_dn: &inputs[6].data,
        },
        seq,
        d_model,
        d_ff,
        n_heads,
        dtype_in,
        env,
    )
}

/// The seed transformer body: per-op plans compile inline from `env`
/// (deterministic, so repeated runs use identical plans).
#[allow(clippy::too_many_arguments)]
fn exec_transformer_core(
    x: &[f32],
    w: TfWeights,
    seq: usize,
    d_model: usize,
    d_ff: usize,
    n_heads: usize,
    dtype_in: Dtype,
    env: &PlanEnv,
) -> Vec<f32> {
    let b_up = w.b_up;
    let b_dn = w.b_dn;
    let d_head = d_model / n_heads;
    let d3 = 3 * d_model;

    // One compiled plan per internal GEMM shape (the attention plans are
    // reused across heads).
    let qkv_plan = &internal_plan(seq, d3, d_model, dtype_in, Dtype::F32, env);
    let attn_plan = &internal_plan(seq, d_model, d_model, dtype_in, Dtype::F32, env);
    let up_plan = &internal_plan(seq, d_ff, d_model, dtype_in, Dtype::F32, env);
    let dn_plan = &internal_plan(seq, d_model, d_ff, dtype_in, Dtype::F32, env);
    let scores_plan = internal_plan(seq, seq, d_head, Dtype::F32, Dtype::F32, env);
    let ctx_plan = internal_plan(seq, d_head, seq, Dtype::F32, Dtype::F32, env);

    // One pipeline GEMM: cast the activation, cast the weight when it is
    // still raw, run under the compiled plan.
    let gemm_w = |eplan: &ExecutionPlan, a: &[f32], wop: BOperand| -> Vec<f32> {
        let a16 = cast_slice(dtype_in, a);
        let mut out = vec![0.0f32; eplan.m * eplan.n];
        match wop {
            BOperand::Raw(wr) if !w.cast_weights => {
                // Bound-without-panels weights: already cast at bind.
                eplan.matmul_b(&mut out, &a16, BOperand::Raw(wr));
            }
            BOperand::Raw(wr) => {
                let w16 = cast_slice(dtype_in, wr);
                eplan.matmul_b(&mut out, &a16, BOperand::Raw(&w16[..]));
            }
            pre => eplan.matmul_b(&mut out, &a16, pre),
        }
        out
    };

    // QKV projection.
    let qkv = gemm_w(qkv_plan, x, w.w_qkv);

    // Scaled dot-product attention per head (plain f32, like the jnp
    // glue).  Both attention GEMMs — scores = Q_h @ K_h^T and
    // ctx = P @ V_h — route through the micro-kernel engine on gathered
    // per-head operands instead of hand-rolled loops.  The gathers
    // rearrange layout only; the engine accumulates k-terms in the same
    // increasing order the old loops used, the scale multiply still
    // happens after each dot product, and the softmax denominator still
    // divides after the P @ V accumulation, so the output is
    // bit-identical to the pre-engine implementation (pinned by the
    // equivalence test below).
    let scale = 1.0 / (d_head as f32).sqrt();
    let mut ctx = vec![0.0f32; seq * d_model];
    let mut q_h = vec![0.0f32; seq * d_head];
    let mut kt_h = vec![0.0f32; d_head * seq];
    let mut v_h = vec![0.0f32; seq * d_head];
    let mut scores = vec![0.0f32; seq * seq];
    let mut ctx_h = vec![0.0f32; seq * d_head];
    let mut denom = vec![0.0f32; seq];
    for h in 0..n_heads {
        let q_off = h * d_head;
        let k_off = d_model + h * d_head;
        let v_off = 2 * d_model + h * d_head;
        for i in 0..seq {
            for dd in 0..d_head {
                q_h[i * d_head + dd] = qkv[i * d3 + q_off + dd];
                kt_h[dd * seq + i] = qkv[i * d3 + k_off + dd];
                v_h[i * d_head + dd] = qkv[i * d3 + v_off + dd];
            }
        }
        scores.fill(0.0);
        scores_plan.matmul(&mut scores, &q_h, &kt_h);
        for (i, row) in scores.chunks_mut(seq).enumerate() {
            for s in row.iter_mut() {
                *s *= scale;
            }
            let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut den = 0.0f32;
            for s in row.iter_mut() {
                *s = (*s - max).exp();
                den += *s;
            }
            denom[i] = den;
        }
        ctx_h.fill(0.0);
        ctx_plan.matmul(&mut ctx_h, &scores, &v_h);
        for i in 0..seq {
            for dd in 0..d_head {
                ctx[i * d_model + q_off + dd] = ctx_h[i * d_head + dd] / denom[i];
            }
        }
    }

    // Attention output projection + residual.
    let attn_out = gemm_w(attn_plan, &ctx, w.w_out);
    let mut h_res = vec![0.0f32; seq * d_model];
    for ((hv, &xv), &av) in h_res.iter_mut().zip(x).zip(&attn_out) {
        *hv = xv + av;
    }

    // Pre-FFN layer norm.
    let mut hn = vec![0.0f32; seq * d_model];
    for (hn_row, h_row) in hn.chunks_mut(d_model).zip(h_res.chunks(d_model)) {
        let mu = h_row.iter().sum::<f32>() / d_model as f32;
        let var =
            h_row.iter().map(|v| (v - mu) * (v - mu)).sum::<f32>() / d_model as f32;
        let inv = 1.0 / (var + 1e-5).sqrt();
        for (o, &v) in hn_row.iter_mut().zip(h_row) {
            *o = (v - mu) * inv;
        }
    }

    // FFN up (fused bias+ReLU) and down (fused bias), then the residual.
    let mut up = gemm_w(up_plan, &hn, w.w_up);
    for row in up.chunks_mut(d_ff) {
        for (v, &bv) in row.iter_mut().zip(b_up) {
            *v = (*v + bv).max(0.0);
        }
    }
    let mut dn = gemm_w(dn_plan, &up, w.w_dn);
    for row in dn.chunks_mut(d_model) {
        for (v, &bv) in row.iter_mut().zip(b_dn) {
            *v += bv;
        }
    }
    for (o, &hv) in dn.iter_mut().zip(&h_res) {
        *o += hv;
    }
    dn
}

/// The plan-driven transformer body: every orchestration decision comes
/// from the compiled [`ProgramPlan`] instead of being hand-coded —
/// per-op kernel plans from the op-graph pass, one shared activation
/// cast per chain input from the cast-hoist pass, and scratch buffers
/// from the lifetime arena of the buffer-reuse pass.  The pipeline pass
/// is conservative (`materialize` everywhere), so the arithmetic — cast
/// values, GEMM accumulation order, softmax/layernorm/epilogue tails —
/// is exactly the seed hand loop's, and the output is bit-identical to
/// [`exec_transformer`] (pinned by tests here and in
/// `tests/program_plan.rs`).
fn exec_transformer_planned(
    x: &[f32],
    w: TfWeights,
    pplan: &ProgramPlan,
) -> Result<Vec<f32>> {
    let (seq, d_model, d_ff, n_heads) =
        (pplan.seq, pplan.d_model, pplan.d_ff, pplan.n_heads);
    let dtype_in = pplan.dtype_in;
    let b_up = w.b_up;
    let b_dn = w.b_dn;
    let d_head = d_model / n_heads;
    let d3 = 3 * d_model;

    // Pass (a): every kernel plan comes from the op graph.
    let qkv_plan = pplan.op_plan("qkv")?;
    let scores_plan = pplan.op_plan("scores")?;
    let ctx_plan = pplan.op_plan("ctx")?;
    let attn_plan = pplan.op_plan("attn_out")?;
    let up_plan = pplan.op_plan("ffn_up")?;
    let dn_plan = pplan.op_plan("ffn_dn")?;

    TF_ACTIVATION_CASTS.with(|c| c.set(0));
    let cast = dtype_in != Dtype::F32;
    let mut arena = ScratchArena::new();

    // Pass (b): one hoisted activation cast per chain input, into an
    // arena slot.  The cast values are the same round-to-nearest-even
    // bits the seed loop's per-GEMM `cast_slice` produced.
    let cast_act = |arena: &mut ScratchArena, src: &[f32]| -> (usize, Vec<f32>) {
        TF_ACTIVATION_CASTS.with(|c| c.set(c.get() + 1));
        let (slot, mut buf) = arena.take(0);
        cast_extend(dtype_in, &mut buf, src);
        (slot, buf)
    };

    // One planned GEMM over an already-cast activation; raw weights
    // still cast per GEMM on the per-call path (idempotent, so the bits
    // match bind-time casting).
    let gemm_w = |eplan: &ExecutionPlan, out: &mut [f32], a16: &[f32], wop: BOperand| {
        match wop {
            BOperand::Raw(wr) if !w.cast_weights => {
                eplan.matmul_b(out, a16, BOperand::Raw(wr));
            }
            BOperand::Raw(wr) => {
                let w16 = cast_slice(dtype_in, wr);
                eplan.matmul_b(out, a16, BOperand::Raw(&w16[..]));
            }
            pre => eplan.matmul_b(out, a16, pre),
        }
    };

    // QKV projection: q, k and v share the single hoisted x cast.
    let mut x_cast: Option<(usize, Vec<f32>)> = None;
    if cast {
        x_cast = Some(cast_act(&mut arena, x));
    }
    let x16: &[f32] = x_cast.as_ref().map(|(_, b)| b.as_slice()).unwrap_or(x);
    let (qkv_slot, mut qkv) = arena.take(seq * d3);
    gemm_w(qkv_plan, &mut qkv, x16, w.w_qkv);
    if let Some((slot, buf)) = x_cast.take() {
        arena.put(slot, buf);
    }

    // Scaled dot-product attention per head — arithmetic identical to
    // the seed loop (see the comment there); only the buffer provenance
    // differs, and arena slots are zero-filled exactly like the seed's
    // fresh vectors.  The take order matches the birth order the
    // buffer-reuse pass scheduled, so the run-time slot assignment is
    // the one recorded in `pplan.arena`.
    let scale = 1.0 / (d_head as f32).sqrt();
    let (q_slot, mut q_h) = arena.take(seq * d_head);
    let (kt_slot, mut kt_h) = arena.take(d_head * seq);
    let (v_slot, mut v_h) = arena.take(seq * d_head);
    let (sc_slot, mut scores) = arena.take(seq * seq);
    let (ch_slot, mut ctx_h) = arena.take(seq * d_head);
    let (de_slot, mut denom) = arena.take(seq);
    let (ctx_slot, mut ctx) = arena.take(seq * d_model);
    for h in 0..n_heads {
        let q_off = h * d_head;
        let k_off = d_model + h * d_head;
        let v_off = 2 * d_model + h * d_head;
        for i in 0..seq {
            for dd in 0..d_head {
                q_h[i * d_head + dd] = qkv[i * d3 + q_off + dd];
                kt_h[dd * seq + i] = qkv[i * d3 + k_off + dd];
                v_h[i * d_head + dd] = qkv[i * d3 + v_off + dd];
            }
        }
        scores.fill(0.0);
        scores_plan.matmul(&mut scores, &q_h, &kt_h);
        for (i, row) in scores.chunks_mut(seq).enumerate() {
            for s in row.iter_mut() {
                *s *= scale;
            }
            let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut den = 0.0f32;
            for s in row.iter_mut() {
                *s = (*s - max).exp();
                den += *s;
            }
            denom[i] = den;
        }
        ctx_h.fill(0.0);
        ctx_plan.matmul(&mut ctx_h, &scores, &v_h);
        for i in 0..seq {
            for dd in 0..d_head {
                ctx[i * d_model + q_off + dd] = ctx_h[i * d_head + dd] / denom[i];
            }
        }
    }
    arena.put(qkv_slot, qkv);
    arena.put(q_slot, q_h);
    arena.put(kt_slot, kt_h);
    arena.put(v_slot, v_h);
    arena.put(sc_slot, scores);
    arena.put(ch_slot, ctx_h);
    arena.put(de_slot, denom);

    // Attention output projection + residual.
    let mut ctx_cast: Option<(usize, Vec<f32>)> = None;
    if cast {
        ctx_cast = Some(cast_act(&mut arena, &ctx));
    }
    let ctx16: &[f32] = ctx_cast.as_ref().map(|(_, b)| b.as_slice()).unwrap_or(&ctx);
    let (ao_slot, mut attn_out) = arena.take(seq * d_model);
    gemm_w(attn_plan, &mut attn_out, ctx16, w.w_out);
    arena.put(ctx_slot, ctx);
    if let Some((slot, buf)) = ctx_cast.take() {
        arena.put(slot, buf);
    }
    let (hr_slot, mut h_res) = arena.take(seq * d_model);
    for ((hv, &xv), &av) in h_res.iter_mut().zip(x).zip(&attn_out) {
        *hv = xv + av;
    }
    arena.put(ao_slot, attn_out);

    // Pre-FFN layer norm.
    let (hn_slot, mut hn) = arena.take(seq * d_model);
    for (hn_row, h_row) in hn.chunks_mut(d_model).zip(h_res.chunks(d_model)) {
        let mu = h_row.iter().sum::<f32>() / d_model as f32;
        let var =
            h_row.iter().map(|v| (v - mu) * (v - mu)).sum::<f32>() / d_model as f32;
        let inv = 1.0 / (var + 1e-5).sqrt();
        for (o, &v) in hn_row.iter_mut().zip(h_row) {
            *o = (v - mu) * inv;
        }
    }

    // FFN up (fused bias+ReLU) and down (fused bias), then the residual.
    let mut hn_cast: Option<(usize, Vec<f32>)> = None;
    if cast {
        hn_cast = Some(cast_act(&mut arena, &hn));
    }
    let hn16: &[f32] = hn_cast.as_ref().map(|(_, b)| b.as_slice()).unwrap_or(&hn);
    let (up_slot, mut up) = arena.take(seq * d_ff);
    gemm_w(up_plan, &mut up, hn16, w.w_up);
    arena.put(hn_slot, hn);
    if let Some((slot, buf)) = hn_cast.take() {
        arena.put(slot, buf);
    }
    for row in up.chunks_mut(d_ff) {
        for (v, &bv) in row.iter_mut().zip(b_up) {
            *v = (*v + bv).max(0.0);
        }
    }
    let mut up_cast: Option<(usize, Vec<f32>)> = None;
    if cast {
        up_cast = Some(cast_act(&mut arena, &up));
    }
    let up16: &[f32] = up_cast.as_ref().map(|(_, b)| b.as_slice()).unwrap_or(&up);
    // The block output is returned, not scratch — it lives outside the
    // arena (and outside the plan's slot count).
    let mut dn = vec![0.0f32; seq * d_model];
    gemm_w(dn_plan, &mut dn, up16, w.w_dn);
    arena.put(up_slot, up);
    if let Some((slot, buf)) = up_cast.take() {
        arena.put(slot, buf);
    }
    for row in dn.chunks_mut(d_model) {
        for (v, &bv) in row.iter_mut().zip(b_dn) {
            *v += bv;
        }
    }
    for (o, &hv) in dn.iter_mut().zip(&h_res) {
        *o += hv;
    }
    arena.put(hr_slot, h_res);

    // The run-time footprint must be the compile-time pass's answer.
    if !pplan.arena.is_empty() {
        debug_assert_eq!(
            arena.slots_used(),
            pplan.arena.len(),
            "executor scratch footprint diverged from the buffer-reuse pass"
        );
    }
    Ok(dn)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    // -- precision emulation -----------------------------------------------

    #[test]
    fn f16_round_exact_values() {
        // Values verified against numpy.float16.
        assert_eq!(round_f16(1.0), 1.0);
        assert_eq!(round_f16(-2.5), -2.5);
        assert_eq!(round_f16(0.1), 0.099_975_586);
        assert_eq!(round_f16(1e-7), 1.192_092_9e-7); // subnormal
        assert_eq!(round_f16(65519.0), 65504.0); // below rounding midpoint
        assert_eq!(round_f16(65520.0), f32::INFINITY);
        assert_eq!(round_f16(1e-8), 0.0); // below half the smallest subnormal
        assert_eq!(round_f16(1e-30), 0.0); // underflow
        assert_eq!(round_f16(-0.0).to_bits(), (-0.0f32).to_bits());
    }

    #[test]
    fn f16_round_to_nearest_even() {
        // 2049 is exactly halfway between 2048 and 2050 in f16; RNE picks
        // the even mantissa (2048).  2051 is halfway to 2052 -> 2052.
        assert_eq!(round_f16(2049.0), 2048.0);
        assert_eq!(round_f16(2051.0), 2052.0);
    }

    #[test]
    fn f16_roundtrip_is_idempotent() {
        let mut rng = Rng::new(1);
        for _ in 0..1000 {
            let x = (rng.next_f64() as f32 - 0.5) * 100.0;
            let once = round_f16(x);
            assert_eq!(round_f16(once), once, "{x}");
            assert!((once - x).abs() <= x.abs() * 1e-3 + 1e-7, "{x} -> {once}");
        }
    }

    #[test]
    fn f16_nan_stays_nan() {
        assert!(round_f16(f32::NAN).is_nan());
        assert_eq!(round_f16(f32::INFINITY), f32::INFINITY);
        assert_eq!(round_f16(f32::NEG_INFINITY), f32::NEG_INFINITY);
    }

    #[test]
    fn bf16_round_exact_values() {
        // Values verified against jax.numpy.bfloat16.
        assert_eq!(round_bf16(1.0), 1.0);
        assert_eq!(round_bf16(0.1), 0.100_097_656);
        assert_eq!(round_bf16(3.141_592_7), 3.140_625);
        assert!(round_bf16(f32::NAN).is_nan());
    }

    /// The single-pass rounder must agree with the two-step
    /// `f32_to_f16_bits` -> `f16_bits_to_f32` conversion on every one of
    /// the 65536 f16 bit patterns, and be the identity on every non-NaN
    /// pattern (NaNs collapse to the same canonical quiet NaN on both
    /// paths).
    #[test]
    fn f16_round_exhaustive_all_bit_patterns() {
        for h in 0..=u16::MAX {
            let x = f16_bits_to_f32(h);
            let fast = round_f16(x);
            let slow = f16_bits_to_f32(f32_to_f16_bits(x));
            assert_eq!(
                fast.to_bits(),
                slow.to_bits(),
                "pattern {h:#06x}: single-pass {fast} vs two-step {slow}"
            );
            if !x.is_nan() {
                assert_eq!(fast.to_bits(), x.to_bits(), "pattern {h:#06x} not fixed");
            }
        }
    }

    /// Single-pass vs two-step over a structured f32 sweep: every
    /// exponent, mantissa patterns straddling the RNE halfway points
    /// (13-bit boundary), both signs — plus a large random sample.
    #[test]
    fn f16_round_single_pass_matches_two_step_on_f32_sweep() {
        let mantissas: &[u32] = &[
            0x0000_0000, 0x0000_0001, 0x0000_0fff, 0x0000_1000, 0x0000_1001,
            0x0000_1fff, 0x0000_2000, 0x0000_2fff, 0x0000_3000, 0x0000_3001,
            0x0000_5000, 0x0007_f000, 0x007f_e000, 0x007f_efff, 0x007f_f000,
            0x007f_f001, 0x007f_ffff,
        ];
        let mut checked = 0u64;
        let mut check = |bits: u32| {
            let x = f32::from_bits(bits);
            let fast = round_f16(x);
            let slow = f16_bits_to_f32(f32_to_f16_bits(x));
            assert_eq!(
                fast.to_bits(),
                slow.to_bits(),
                "bits {bits:#010x}: single-pass {fast} vs two-step {slow}"
            );
            checked += 1;
        };
        for exp in 0..=0xffu32 {
            for &man in mantissas {
                for sign in [0u32, 0x8000_0000] {
                    check(sign | (exp << 23) | man);
                }
            }
        }
        let mut rng = Rng::new(0xF16);
        for _ in 0..200_000 {
            check(rng.next_u64() as u32);
        }
        assert!(checked > 200_000);
    }

    #[test]
    fn f32_cast_borrows_instead_of_copying() {
        // The identity cast must not allocate: Dtype::F32 operands are
        // borrowed straight through to the kernel.
        let v = vec![1.0f32, 2.0, 3.0];
        assert!(matches!(cast_slice(Dtype::F32, &v), Cow::Borrowed(_)));
        assert!(matches!(cast_slice(Dtype::F16, &v), Cow::Owned(_)));
    }

    // -- program descriptor -------------------------------------------------

    fn gemm_tprog() -> String {
        r#"{
            "format": "mlir-gemm-tprog-v1",
            "name": "g1",
            "program": {
                "type": "gemm", "m": 4, "n": 4, "k": 4,
                "dtype_in": "f16", "dtype_acc": "f32",
                "epilogue": "none", "fused": true
            }
        }"#
        .to_string()
    }

    #[test]
    fn parses_gemm_program() {
        let p = Program::from_text(&gemm_tprog(), "g1").unwrap();
        assert_eq!(
            p,
            Program::Gemm {
                m: 4,
                n: 4,
                k: 4,
                dtype_in: Dtype::F16,
                dtype_acc: Dtype::F32,
                epilogue: Epilogue::None,
                fused: true,
            }
        );
        assert_eq!(p.input_shapes(), vec![vec![4, 4]; 3]);
        assert_eq!(p.output_shapes(), vec![vec![4, 4]]);
    }

    #[test]
    fn rejects_wrong_name_format_and_garbage() {
        assert!(Program::from_text(&gemm_tprog(), "other").is_err());
        let bad = gemm_tprog().replace("tprog-v1", "tprog-v9");
        assert!(Program::from_text(&bad, "g1").is_err());
        assert!(Program::from_text("HloModule broken\n<<garbage>>\n", "g1").is_err());
        let untyped = gemm_tprog().replace("\"type\": \"gemm\",", "");
        assert!(Program::from_text(&untyped, "g1").is_err());
    }

    #[test]
    fn bias_epilogue_extends_input_contract() {
        let p = Program::Gemm {
            m: 2,
            n: 3,
            k: 2,
            dtype_in: Dtype::F16,
            dtype_acc: Dtype::F32,
            epilogue: Epilogue::BiasRelu,
            fused: true,
        };
        assert_eq!(
            p.input_shapes(),
            vec![vec![2, 2], vec![2, 3], vec![2, 3], vec![3]]
        );
    }

    // -- gemm execution ------------------------------------------------------

    fn t(shape: Vec<usize>, data: Vec<f32>) -> Tensor {
        Tensor { shape, data }
    }

    #[test]
    fn gemm_identity_and_c_accumulation() {
        let p = Program::Gemm {
            m: 2,
            n: 2,
            k: 2,
            dtype_in: Dtype::F32,
            dtype_acc: Dtype::F32,
            epilogue: Epilogue::None,
            fused: true,
        };
        let a = t(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let id = t(vec![2, 2], vec![1.0, 0.0, 0.0, 1.0]);
        let c = t(vec![2, 2], vec![10.0, 20.0, 30.0, 40.0]);
        let out = p.execute(&[a, id, c]).unwrap();
        assert_eq!(out[0].data, vec![11.0, 22.0, 33.0, 44.0]);
    }

    #[test]
    fn gemm_bias_relu_clamps_negatives() {
        let p = Program::Gemm {
            m: 1,
            n: 2,
            k: 1,
            dtype_in: Dtype::F32,
            dtype_acc: Dtype::F32,
            epilogue: Epilogue::BiasRelu,
            fused: true,
        };
        let out = p
            .execute(&[
                t(vec![1, 1], vec![1.0]),
                t(vec![1, 2], vec![-5.0, 5.0]),
                t(vec![1, 2], vec![0.0, 0.0]),
                t(vec![2], vec![1.0, 1.0]),
            ])
            .unwrap();
        assert_eq!(out[0].data, vec![0.0, 6.0]);
    }

    #[test]
    fn gemm_f16_inputs_match_f64_reference_closely() {
        let (m, n, k) = (16, 16, 16);
        let mut rng = Rng::new(3);
        let a = rng.normal_matrix(m, k);
        let b = rng.normal_matrix(k, n);
        let c = rng.normal_matrix(m, n);
        let p = Program::Gemm {
            m,
            n,
            k,
            dtype_in: Dtype::F16,
            dtype_acc: Dtype::F32,
            epilogue: Epilogue::None,
            fused: true,
        };
        let out = p
            .execute(&[
                t(vec![m, k], a.clone()),
                t(vec![k, n], b.clone()),
                t(vec![m, n], c.clone()),
            ])
            .unwrap();
        let mut num = 0f64;
        let mut den = 0f64;
        for i in 0..m {
            for j in 0..n {
                let mut want = c[i * n + j] as f64;
                for kk in 0..k {
                    want += a[i * k + kk] as f64 * b[kk * n + j] as f64;
                }
                let got = out[0].data[i * n + j] as f64;
                num += (got - want) * (got - want);
                den += want * want;
            }
        }
        let rel = (num / den.max(1e-30)).sqrt();
        assert!(rel < 2e-3, "relative error {rel}");
    }

    #[test]
    fn gemm_rejects_wrong_shapes_and_counts() {
        let p = Program::Gemm {
            m: 2,
            n: 2,
            k: 2,
            dtype_in: Dtype::F32,
            dtype_acc: Dtype::F32,
            epilogue: Epilogue::None,
            fused: true,
        };
        let bad = vec![t(vec![2, 2], vec![0.0; 4]); 2];
        assert!(p.execute(&bad).is_err());
        let wrong = vec![t(vec![2, 3], vec![0.0; 6]); 3];
        assert!(p.execute(&wrong).is_err());
    }

    #[test]
    fn f16_accumulate_output_is_f16_representable() {
        let p = Program::Gemm {
            m: 2,
            n: 2,
            k: 2,
            dtype_in: Dtype::F16,
            dtype_acc: Dtype::F16,
            epilogue: Epilogue::None,
            fused: true,
        };
        let out = p
            .execute(&[
                t(vec![2, 2], vec![0.1, 0.2, 0.3, 0.4]),
                t(vec![2, 2], vec![0.5, 0.6, 0.7, 0.8]),
                t(vec![2, 2], vec![0.0; 4]),
            ])
            .unwrap();
        for &v in &out[0].data {
            assert_eq!(v, round_f16(v), "{v} not f16-representable");
        }
    }

    // -- batched execution ---------------------------------------------------

    #[test]
    fn execute_batch_matches_per_item_execute_bitwise() {
        let p = Program::Gemm {
            m: 8,
            n: 8,
            k: 8,
            dtype_in: Dtype::F16,
            dtype_acc: Dtype::F32,
            epilogue: Epilogue::BiasRelu,
            fused: true,
        };
        let mut rng = Rng::new(21);
        let items: Vec<Vec<Tensor>> = (0..5)
            .map(|_| {
                vec![
                    t(vec![8, 8], rng.normal_matrix(8, 8)),
                    t(vec![8, 8], rng.normal_matrix(8, 8)),
                    t(vec![8, 8], rng.normal_matrix(8, 8)),
                    t(vec![8], rng.normal_matrix(1, 8)),
                ]
            })
            .collect();
        let batched = p.execute_batch(&items).unwrap();
        assert_eq!(batched.len(), items.len());
        for (bi, inputs) in items.iter().enumerate() {
            let single = p.execute(inputs).unwrap();
            assert_eq!(batched[bi][0].shape, single[0].shape);
            assert_eq!(batched[bi][0].data, single[0].data, "item {bi}");
        }
    }

    #[test]
    fn execute_batch_handles_empty_and_singleton() {
        let p = Program::Gemm {
            m: 2,
            n: 2,
            k: 2,
            dtype_in: Dtype::F32,
            dtype_acc: Dtype::F32,
            epilogue: Epilogue::None,
            fused: true,
        };
        assert!(p.execute_batch(&[]).unwrap().is_empty());
        let item = vec![
            t(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]),
            t(vec![2, 2], vec![1.0, 0.0, 0.0, 1.0]),
            t(vec![2, 2], vec![0.0; 4]),
        ];
        let out = p.execute_batch(&[item.clone()]).unwrap();
        assert_eq!(out[0][0].data, p.execute(&item).unwrap()[0].data);
    }

    #[test]
    fn execute_batch_rejects_misshapen_item() {
        let p = Program::Gemm {
            m: 2,
            n: 2,
            k: 2,
            dtype_in: Dtype::F32,
            dtype_acc: Dtype::F32,
            epilogue: Epilogue::None,
            fused: true,
        };
        let good = vec![t(vec![2, 2], vec![0.0; 4]); 3];
        let bad = vec![t(vec![2, 3], vec![0.0; 6]); 3];
        assert!(p.execute_batch(&[good, bad]).is_err());
    }

    // -- weight binding ------------------------------------------------------

    #[test]
    fn bound_execution_bit_identical_to_inline_b() {
        use crate::plan::PlanOverride;
        // Both plan classes: forced tiled (prepacks panels) and the
        // auto direct kernel at this size (no panels, raw cast B).
        let envs = [
            PlanEnv::pinned().with_force(PlanOverride::parse("tiled:8,4,16").unwrap()),
            PlanEnv::pinned(),
            PlanEnv::pinned()
                .with_force(PlanOverride::parse("threaded:8,8,16,2").unwrap()),
        ];
        for &(din, dacc) in &[
            (Dtype::F32, Dtype::F32),
            (Dtype::F16, Dtype::F32),
            (Dtype::F16, Dtype::F16),
            (Dtype::Bf16, Dtype::F32),
        ] {
            let (m, n, k) = (13, 9, 11);
            let p = Program::Gemm {
                m,
                n,
                k,
                dtype_in: din,
                dtype_acc: dacc,
                epilogue: Epilogue::BiasRelu,
                fused: true,
            };
            let mut rng = Rng::new(0xB1D + din.name().len() as u64);
            let a = t(vec![m, k], rng.normal_matrix(m, k));
            let b = t(vec![k, n], rng.normal_matrix(k, n));
            let c = t(vec![m, n], rng.normal_matrix(m, n));
            let bias = t(vec![n], rng.normal_matrix(1, n));
            for env in &envs {
                let eplan = p.compile_plan(env).unwrap();
                let want = p
                    .execute_planned(
                        &[a.clone(), b.clone(), c.clone(), bias.clone()],
                        &eplan,
                    )
                    .unwrap();
                let bound = p.bind_b(&b, &eplan).unwrap();
                let got = p
                    .execute_planned_bound(
                        &[a.clone(), c.clone(), bias.clone()],
                        &eplan,
                        &bound,
                    )
                    .unwrap();
                assert_eq!(want[0].shape, got[0].shape);
                for (i, (w, g)) in want[0].data.iter().zip(&got[0].data).enumerate() {
                    assert_eq!(
                        w.to_bits(),
                        g.to_bits(),
                        "{din:?}/{dacc:?} under {} drifted at {i}: {w} vs {g}",
                        eplan.id()
                    );
                }
            }
        }
    }

    #[test]
    fn bound_batch_bit_identical_to_inline_batch() {
        use crate::plan::{compile, GemmKey, PlanOverride};
        let (m, n, k) = (8, 8, 8);
        let p = Program::Gemm {
            m,
            n,
            k,
            dtype_in: Dtype::F16,
            dtype_acc: Dtype::F32,
            epilogue: Epilogue::Bias,
            fused: true,
        };
        let key = GemmKey {
            m,
            n,
            k,
            dtype_in: Dtype::F16,
            dtype_acc: Dtype::F32,
            epilogue: "bias".into(),
        };
        let env =
            PlanEnv::pinned().with_force(PlanOverride::parse("tiled:4,4,4").unwrap());
        let eplan = compile(&key, &env).unwrap();
        let mut rng = Rng::new(31);
        let b = t(vec![k, n], rng.normal_matrix(k, n));
        let items_inline: Vec<Vec<Tensor>> = (0..4)
            .map(|_| {
                vec![
                    t(vec![m, k], rng.normal_matrix(m, k)),
                    b.clone(),
                    t(vec![m, n], rng.normal_matrix(m, n)),
                    t(vec![n], rng.normal_matrix(1, n)),
                ]
            })
            .collect();
        let want = p.execute_batch_planned(&items_inline, &eplan).unwrap();
        let bound = p.bind_b(&b, &eplan).unwrap();
        assert!(bound.is_prepacked(), "tiled plan must prepack");
        let items_bound: Vec<Vec<Tensor>> = items_inline
            .iter()
            .map(|v| vec![v[0].clone(), v[2].clone(), v[3].clone()])
            .collect();
        let got = p
            .execute_batch_planned_bound(&items_bound, &eplan, &bound)
            .unwrap();
        for (bi, (w, g)) in want.iter().zip(&got).enumerate() {
            assert_eq!(w[0].data, g[0].data, "batch item {bi} drifted");
        }
    }

    #[test]
    fn bind_b_rejects_shape_mismatch_and_wrong_program() {
        let p = Program::Gemm {
            m: 4,
            n: 4,
            k: 4,
            dtype_in: Dtype::F32,
            dtype_acc: Dtype::F32,
            epilogue: Epilogue::None,
            fused: true,
        };
        let eplan = p.compile_plan(&PlanEnv::pinned()).unwrap();
        assert!(p.bind_b(&t(vec![4, 4], vec![0.0; 16]), &eplan).is_ok());
        // wrong shape: rejected at bind time
        assert!(p.bind_b(&t(vec![4, 5], vec![0.0; 20]), &eplan).is_err());
        // torn tensor (shape/data mismatch via pub fields)
        let torn = Tensor { shape: vec![4, 4], data: vec![0.0; 3] };
        assert!(p.bind_b(&torn, &eplan).is_err());
        // mismatched plan
        let other = Program::Gemm {
            m: 8,
            n: 8,
            k: 8,
            dtype_in: Dtype::F32,
            dtype_acc: Dtype::F32,
            epilogue: Epilogue::None,
            fused: true,
        };
        let other_plan = other.compile_plan(&PlanEnv::pinned()).unwrap();
        assert!(p.bind_b(&t(vec![4, 4], vec![0.0; 16]), &other_plan).is_err());
        // transformer programs take the transformer binding path
        assert!(transformer_program()
            .bind_b(&t(vec![4, 4], vec![0.0; 16]), &eplan)
            .is_err());
    }

    // -- transformer ---------------------------------------------------------

    fn transformer_inputs(seq: usize, d_model: usize, d_ff: usize, seed: u64) -> Vec<Tensor> {
        let mut rng = Rng::new(seed);
        let mut mk = |shape: Vec<usize>| {
            let n: usize = shape.iter().product();
            let data: Vec<f32> = (0..n).map(|_| rng.normal() as f32 * 0.1).collect();
            Tensor { shape, data }
        };
        vec![
            mk(vec![seq, d_model]),
            mk(vec![d_model, 3 * d_model]),
            mk(vec![d_model, d_model]),
            mk(vec![d_model, d_ff]),
            mk(vec![d_ff]),
            mk(vec![d_ff, d_model]),
            mk(vec![d_model]),
        ]
    }

    fn transformer_program() -> Program {
        Program::Transformer {
            seq: 8,
            d_model: 16,
            d_ff: 32,
            n_heads: 4,
            dtype_in: Dtype::F16,
        }
    }

    #[test]
    fn transformer_output_finite_and_shaped() {
        let p = transformer_program();
        let inputs = transformer_inputs(8, 16, 32, 9);
        let out = p.execute(&inputs).unwrap();
        assert_eq!(out[0].shape, vec![8, 16]);
        assert!(out[0].data.iter().all(|v| v.is_finite()));
        let norm: f64 = out[0].data.iter().map(|&v| (v as f64) * (v as f64)).sum();
        assert!(norm > 0.0);
    }

    #[test]
    fn transformer_zero_weights_is_identity() {
        // All-zero weights: attention context and FFN vanish, both
        // residual connections pass x through exactly.
        let p = transformer_program();
        let mut inputs = transformer_inputs(8, 16, 32, 10);
        for t in inputs.iter_mut().skip(1) {
            t.data.iter_mut().for_each(|v| *v = 0.0);
        }
        let out = p.execute(&inputs).unwrap();
        assert_eq!(out[0].data, inputs[0].data);
    }

    /// The pre-engine transformer implementation, kept verbatim as the
    /// bit-exactness oracle for the rewiring: hand-rolled attention
    /// loops, naive matmuls, no packing.
    fn reference_transformer(
        inputs: &[Tensor],
        seq: usize,
        d_model: usize,
        d_ff: usize,
        n_heads: usize,
        dtype_in: Dtype,
    ) -> Vec<f32> {
        fn naive(out: &mut [f32], a: &[f32], b: &[f32], m: usize, n: usize, k: usize) {
            for i in 0..m {
                let arow = &a[i * k..(i + 1) * k];
                let orow = &mut out[i * n..(i + 1) * n];
                for (kk, &av) in arow.iter().enumerate() {
                    let brow = &b[kk * n..(kk + 1) * n];
                    for (o, &bv) in orow.iter_mut().zip(brow) {
                        *o += av * bv;
                    }
                }
            }
        }
        let cast = |v: &[f32]| -> Vec<f32> {
            v.iter().map(|&x| round_to(dtype_in, x)).collect()
        };
        let gemm = |a: &[f32], b: &[f32], m: usize, n: usize, k: usize| -> Vec<f32> {
            let mut out = vec![0.0f32; m * n];
            naive(&mut out, &cast(a), &cast(b), m, n, k);
            out
        };
        let x = &inputs[0].data;
        let d_head = d_model / n_heads;
        let d3 = 3 * d_model;
        let qkv = gemm(x, &inputs[1].data, seq, d3, d_model);
        let scale = 1.0 / (d_head as f32).sqrt();
        let mut ctx = vec![0.0f32; seq * d_model];
        let mut scores = vec![0.0f32; seq];
        for h in 0..n_heads {
            let q_off = h * d_head;
            let k_off = d_model + h * d_head;
            let v_off = 2 * d_model + h * d_head;
            for i in 0..seq {
                for (j, s) in scores.iter_mut().enumerate() {
                    let mut dot = 0.0f32;
                    for dd in 0..d_head {
                        dot += qkv[i * d3 + q_off + dd] * qkv[j * d3 + k_off + dd];
                    }
                    *s = dot * scale;
                }
                let max = scores.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                let mut denom = 0.0f32;
                for s in scores.iter_mut() {
                    *s = (*s - max).exp();
                    denom += *s;
                }
                for dd in 0..d_head {
                    let mut acc = 0.0f32;
                    for (j, &p) in scores.iter().enumerate() {
                        acc += p * qkv[j * d3 + v_off + dd];
                    }
                    ctx[i * d_model + q_off + dd] = acc / denom;
                }
            }
        }
        let attn_out = gemm(&ctx, &inputs[2].data, seq, d_model, d_model);
        let mut h_res = vec![0.0f32; seq * d_model];
        for ((hv, &xv), &av) in h_res.iter_mut().zip(x).zip(&attn_out) {
            *hv = xv + av;
        }
        let mut hn = vec![0.0f32; seq * d_model];
        for (hn_row, h_row) in hn.chunks_mut(d_model).zip(h_res.chunks(d_model)) {
            let mu = h_row.iter().sum::<f32>() / d_model as f32;
            let var =
                h_row.iter().map(|v| (v - mu) * (v - mu)).sum::<f32>() / d_model as f32;
            let inv = 1.0 / (var + 1e-5).sqrt();
            for (o, &v) in hn_row.iter_mut().zip(h_row) {
                *o = (v - mu) * inv;
            }
        }
        let mut up = gemm(&hn, &inputs[3].data, seq, d_ff, d_model);
        for row in up.chunks_mut(d_ff) {
            for (v, &bv) in row.iter_mut().zip(&inputs[4].data) {
                *v = (*v + bv).max(0.0);
            }
        }
        let mut dn = gemm(&up, &inputs[5].data, seq, d_model, d_ff);
        for row in dn.chunks_mut(d_model) {
            for (v, &bv) in row.iter_mut().zip(&inputs[6].data) {
                *v += bv;
            }
        }
        for (o, &hv) in dn.iter_mut().zip(&h_res) {
            *o += hv;
        }
        dn
    }

    /// Weight-binding pin: the transformer with weights bound once at
    /// load (cast + prepacked per internal plan) must match the
    /// ship-weights-every-call path bit-for-bit, under plan environments
    /// that do and do not prepack.
    #[test]
    fn transformer_bound_weights_bit_identical_to_per_call_weights() {
        use crate::plan::PlanOverride;
        use crate::runtime::kernel::{Blocking, KernelPolicy};
        let (seq, d_model, d_ff, n_heads) = (8, 16, 32, 4);
        let envs = vec![
            PlanEnv::default(), // small shapes: direct plans, no panels
            PlanEnv::pinned().with_force(PlanOverride::Force(KernelPolicy::Tiled(
                Blocking { mc: 8, kc: 4, nc: 16 },
            ))), // forced packing: every weight prepacks
        ];
        for &dtype_in in &[Dtype::F16, Dtype::F32] {
            let p = Program::Transformer { seq, d_model, d_ff, n_heads, dtype_in };
            let inputs = transformer_inputs(seq, d_model, d_ff, 83);
            for env in &envs {
                let want = p.execute_with_env(&inputs, env).unwrap();
                let bound = p.bind_transformer_weights(&inputs[1..], env).unwrap();
                let got = p.execute_transformer_bound(&inputs[0], &bound).unwrap();
                assert_eq!(want[0].shape, got[0].shape);
                for (i, (w, g)) in want[0].data.iter().zip(&got[0].data).enumerate() {
                    assert_eq!(
                        w.to_bits(),
                        g.to_bits(),
                        "{dtype_in:?} under {} drifted at element {i}",
                        env.force.name()
                    );
                }
            }
        }
        // weight validation happens at bind time
        let p = Program::Transformer { seq, d_model, d_ff, n_heads, dtype_in: Dtype::F16 };
        let mut bad = transformer_inputs(seq, d_model, d_ff, 84);
        bad[1] = Tensor::zeros(vec![d_model, d_model]); // wrong w_qkv shape
        assert!(p.bind_transformer_weights(&bad[1..], &PlanEnv::default()).is_err());
    }

    /// Rewiring pin: the engine-routed transformer (gathered per-head
    /// operands, two attention GEMMs through the micro-kernel engine)
    /// must match the pre-engine loop implementation bit-for-bit under
    /// every plan environment — compiled plans and forced overrides
    /// alike, with no global state anywhere.
    #[test]
    fn transformer_rewiring_is_bit_exact_under_every_plan_env() {
        use crate::plan::PlanOverride;
        use crate::runtime::kernel::{Blocking, KernelPolicy};
        let (seq, d_model, d_ff, n_heads) = (8, 16, 32, 4);
        let envs = vec![
            PlanEnv::default(),
            PlanEnv::pinned(),
            PlanEnv::pinned()
                .with_force(PlanOverride::Force(KernelPolicy::Naive)),
            PlanEnv::pinned().with_force(PlanOverride::Force(KernelPolicy::Tiled(
                Blocking { mc: 8, kc: 4, nc: 16 },
            ))),
            PlanEnv::pinned().with_force(PlanOverride::Force(KernelPolicy::Threaded(
                Blocking::default(),
                2,
            ))),
        ];
        for &dtype_in in &[Dtype::F16, Dtype::F32] {
            let p = Program::Transformer { seq, d_model, d_ff, n_heads, dtype_in };
            let inputs = transformer_inputs(seq, d_model, d_ff, 42);
            let want = reference_transformer(&inputs, seq, d_model, d_ff, n_heads, dtype_in);
            for env in &envs {
                let out = p.execute_with_env(&inputs, env).unwrap();
                assert_eq!(out[0].data.len(), want.len());
                for (idx, (g, w)) in out[0].data.iter().zip(&want).enumerate() {
                    assert_eq!(
                        g.to_bits(),
                        w.to_bits(),
                        "{dtype_in:?} under {} drifted at element {idx}: {g} vs {w}",
                        env.force.name()
                    );
                }
            }
        }
    }
}
