//! Artifact runtime: loads AOT tensor programs and executes them in
//! process.
//!
//! The interchange contract with the Python pipeline (see DESIGN.md §3)
//! is `artifacts/manifest.json` plus one `*.tprog.json` program
//! descriptor per artifact, both emitted by `python -m compile.aot`.
//! The manifest carries the structural metadata (kind, I/O specs, the
//! full [`crate::schedule::Schedule`] for generated kernels); the
//! program file carries the executable semantics.  The loader
//! cross-checks the two, so a pipeline change that breaks the contract
//! fails at `load` time with a precise message instead of producing
//! wrong numbers.
//!
//! All artifact I/O is f32 row-major (precision casts live inside the
//! programs — see aot.py), so the host-side tensor type is a plain
//! `Vec<f32>` + shape.

pub mod exec;
pub mod kernel;
pub mod manifest;
pub mod nanokernel;

use std::collections::HashMap;
use std::path::Path;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use crate::plan::program::ProgramPlan;
use crate::plan::{ExecutionPlan, PlanEnv, PlanOverride};

pub use exec::{BoundB, Epilogue, GEMM_B_INPUT_SLOT, Program, TransformerBound};
pub use kernel::{Blocking, BOperand, KernelPolicy, PrepackedB};
pub use nanokernel::Isa;
pub use manifest::{load_manifest, ArtifactKind, ArtifactMeta, TensorSpec};

/// A host-side f32 tensor (row-major).
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<Tensor> {
        let want: usize = shape.iter().product();
        if want != data.len() {
            bail!("shape {shape:?} needs {want} elements, got {}", data.len());
        }
        Ok(Tensor { shape, data })
    }

    pub fn zeros(shape: Vec<usize>) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape, data: vec![0.0; n] }
    }

    pub fn elements(&self) -> usize {
        self.data.len()
    }

    pub fn matches(&self, spec: &TensorSpec) -> bool {
        // Data length is part of the contract: a shape/data-inconsistent
        // tensor (constructible via the pub fields) must fail validation,
        // not shift every later item's window in a stacked batch.
        self.shape == spec.shape && self.data.len() == spec.elements()
    }
}

/// One loaded artifact: manifest entry + validated executable program +
/// the plan compiled for it at load time — a per-GEMM [`ExecutionPlan`]
/// for GEMM programs, a graph-level [`ProgramPlan`] for composite
/// programs.
#[derive(Debug)]
pub struct LoadedArtifact {
    pub meta: ArtifactMeta,
    program: Program,
    plan: Option<Arc<ExecutionPlan>>,
    program_plan: Option<Arc<ProgramPlan>>,
}

impl LoadedArtifact {
    pub fn program(&self) -> &Program {
        &self.program
    }

    /// The plan this artifact executes under unless a caller supplies an
    /// explicit one (`execute_timed_planned`).  GEMM programs only.
    pub fn plan(&self) -> Option<&Arc<ExecutionPlan>> {
        self.plan.as_ref()
    }

    /// The graph-level plan a composite artifact executes under (`None`
    /// for GEMM programs, which carry [`LoadedArtifact::plan`] instead).
    pub fn program_plan(&self) -> Option<&Arc<ProgramPlan>> {
        self.program_plan.as_ref()
    }
}

/// Execution statistics for one call.
#[derive(Debug, Clone, Copy)]
pub struct ExecTiming {
    /// Input validation + staging (host-side; near zero in-process).
    pub pack_seconds: f64,
    /// Program execution (the paper's "kernel runtime").
    pub exec_seconds: f64,
    /// Output materialization.
    pub unpack_seconds: f64,
}

impl ExecTiming {
    pub fn total(&self) -> f64 {
        self.pack_seconds + self.exec_seconds + self.unpack_seconds
    }
}

/// The runtime: a manifest plus a cache of loaded artifact programs and
/// their compiled execution plans.
pub struct Runtime {
    loaded: Mutex<HashMap<String, Arc<LoadedArtifact>>>,
    metas: Vec<ArtifactMeta>,
    plan_env: PlanEnv,
}

impl Runtime {
    /// Create a runtime over an artifacts directory (reads the manifest).
    pub fn open(artifacts_dir: &Path) -> Result<Runtime> {
        let metas = load_manifest(artifacts_dir)
            .map_err(|e| anyhow!("{e}"))
            .with_context(|| {
                format!("loading manifest from {}", artifacts_dir.display())
            })?;
        Ok(Runtime {
            loaded: Mutex::new(HashMap::new()),
            metas,
            plan_env: PlanEnv::default(),
        })
    }

    /// Create an empty runtime (tests can exercise programs directly).
    pub fn without_manifest() -> Result<Runtime> {
        Ok(Runtime {
            loaded: Mutex::new(HashMap::new()),
            metas: Vec::new(),
            plan_env: PlanEnv::default(),
        })
    }

    /// The environment artifact plans compile under.
    pub fn plan_env(&self) -> &PlanEnv {
        &self.plan_env
    }

    /// Replace the plan environment.  Clears the artifact cache so
    /// already-loaded artifacts recompile their plans on next use.
    pub fn set_plan_env(&mut self, env: PlanEnv) {
        self.plan_env = env;
        self.loaded.lock().unwrap().clear();
    }

    /// `--plan` CLI plumbing: force every compiled plan's lowered kernel.
    pub fn set_plan_override(&mut self, force: PlanOverride) {
        let env = self.plan_env.clone().with_force(force);
        self.set_plan_env(env);
    }

    pub fn artifacts(&self) -> &[ArtifactMeta] {
        &self.metas
    }

    pub fn find(&self, name: &str) -> Option<&ArtifactMeta> {
        self.metas.iter().find(|m| m.name == name)
    }

    /// Load (or fetch the cached) artifact by name: read the program
    /// file, parse it, and cross-check it against the manifest entry.
    pub fn load(&self, name: &str) -> Result<Arc<LoadedArtifact>> {
        {
            let cache = self.loaded.lock().unwrap();
            if let Some(a) = cache.get(name) {
                return Ok(a.clone());
            }
        }
        let meta = self
            .find(name)
            .ok_or_else(|| anyhow!("unknown artifact {name:?}"))?
            .clone();
        let text = std::fs::read_to_string(&meta.path)
            .with_context(|| format!("reading artifact program {}", meta.path.display()))?;
        let program = Program::from_text(&text, &meta.name)
            .with_context(|| format!("parsing artifact program {}", meta.path.display()))?;
        check_contract(&meta, &program)?;
        // Compile the plan once, at load time: the serving hot path never
        // recompiles.  GEMM programs get a per-GEMM ExecutionPlan,
        // composite programs a graph-level ProgramPlan.
        let plan = program.compile_plan(&self.plan_env).ok().map(Arc::new);
        let program_plan =
            program.compile_program_plan(&self.plan_env).ok().map(Arc::new);
        let arc = Arc::new(LoadedArtifact { meta, program, plan, program_plan });
        self.loaded
            .lock()
            .unwrap()
            .insert(name.to_string(), arc.clone());
        Ok(arc)
    }

    /// Eagerly load every artifact of the given kinds.
    pub fn preload(&self, kinds: &[ArtifactKind]) -> Result<usize> {
        let names: Vec<String> = self
            .metas
            .iter()
            .filter(|m| kinds.contains(&m.kind))
            .map(|m| m.name.clone())
            .collect();
        for n in &names {
            self.load(n)?;
        }
        Ok(names.len())
    }

    /// Execute a loaded artifact on host tensors under its load-time
    /// compiled plan, with phase timings.
    pub fn execute_timed(
        &self,
        artifact: &LoadedArtifact,
        inputs: &[Tensor],
    ) -> Result<(Vec<Tensor>, ExecTiming)> {
        self.execute_timed_planned(artifact, inputs, artifact.plan.as_deref())
    }

    /// [`Runtime::execute_timed`] with an explicit plan override (`None`
    /// means: whatever the artifact compiled at load, falling back to the
    /// runtime environment for composite programs).  The server threads
    /// its registry-cached plans through here.
    pub fn execute_timed_planned(
        &self,
        artifact: &LoadedArtifact,
        inputs: &[Tensor],
        eplan: Option<&ExecutionPlan>,
    ) -> Result<(Vec<Tensor>, ExecTiming)> {
        let meta = &artifact.meta;
        let t0 = Instant::now();
        if inputs.len() != meta.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                meta.name,
                meta.inputs.len(),
                inputs.len()
            );
        }
        for (i, (t, spec)) in inputs.iter().zip(&meta.inputs).enumerate() {
            if !t.matches(spec) {
                bail!(
                    "{}: input {i} shape {:?} does not match artifact spec {:?}",
                    meta.name,
                    t.shape,
                    spec.shape
                );
            }
        }
        let t1 = Instant::now();

        let outputs = match (eplan, artifact.program_plan.as_deref()) {
            (Some(p), _) => artifact.program.execute_planned(inputs, p),
            (None, Some(pp)) => artifact.program.execute_program_planned(inputs, pp),
            (None, None) => artifact.program.execute_with_env(inputs, &self.plan_env),
        }
        .with_context(|| format!("executing {}", meta.name))?;
        let t2 = Instant::now();

        if outputs.len() != meta.outputs.len() {
            bail!(
                "{}: program produced {} outputs, manifest declares {}",
                meta.name,
                outputs.len(),
                meta.outputs.len()
            );
        }
        let t3 = Instant::now();

        Ok((
            outputs,
            ExecTiming {
                pack_seconds: (t1 - t0).as_secs_f64(),
                exec_seconds: (t2 - t1).as_secs_f64(),
                unpack_seconds: (t3 - t2).as_secs_f64(),
            },
        ))
    }

    /// Execute by artifact name (loads/caches on first use).
    pub fn execute(&self, name: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let a = self.load(name)?;
        Ok(self.execute_timed(&a, inputs)?.0)
    }

    /// Execute a whole same-artifact batch in one call.
    ///
    /// Every item is validated against the manifest up front (the single
    /// pack phase), execution runs over stacked operands
    /// ([`Program::execute_batch`]), and per-item outputs come back in
    /// submission order.  The batch is all-or-nothing: callers that need
    /// per-item isolation validate shapes before batching.
    pub fn execute_batch_timed(
        &self,
        artifact: &LoadedArtifact,
        items: &[Vec<Tensor>],
    ) -> Result<(Vec<Vec<Tensor>>, ExecTiming)> {
        self.execute_batch_timed_planned(artifact, items, artifact.plan.as_deref())
    }

    /// [`Runtime::execute_batch_timed`] with an explicit plan override.
    pub fn execute_batch_timed_planned(
        &self,
        artifact: &LoadedArtifact,
        items: &[Vec<Tensor>],
        eplan: Option<&ExecutionPlan>,
    ) -> Result<(Vec<Vec<Tensor>>, ExecTiming)> {
        let meta = &artifact.meta;
        let t0 = Instant::now();
        for (bi, inputs) in items.iter().enumerate() {
            if inputs.len() != meta.inputs.len() {
                bail!(
                    "{}: batch item {bi}: expected {} inputs, got {}",
                    meta.name,
                    meta.inputs.len(),
                    inputs.len()
                );
            }
            for (i, (t, spec)) in inputs.iter().zip(&meta.inputs).enumerate() {
                if !t.matches(spec) {
                    bail!(
                        "{}: batch item {bi}: input {i} shape {:?} does not match \
                         artifact spec {:?}",
                        meta.name,
                        t.shape,
                        spec.shape
                    );
                }
            }
        }
        let t1 = Instant::now();

        let outputs = match (eplan, artifact.program_plan.as_deref()) {
            (Some(p), _) => artifact.program.execute_batch_planned(items, p),
            (None, Some(pp)) => {
                artifact.program.execute_batch_program_planned(items, pp)
            }
            (None, None) => {
                artifact.program.execute_batch_with_env(items, &self.plan_env)
            }
        }
        .with_context(|| format!("executing {} (batch of {})", meta.name, items.len()))?;
        let t2 = Instant::now();

        for out in &outputs {
            if out.len() != meta.outputs.len() {
                bail!(
                    "{}: program produced {} outputs, manifest declares {}",
                    meta.name,
                    out.len(),
                    meta.outputs.len()
                );
            }
        }
        let t3 = Instant::now();

        Ok((
            outputs,
            ExecTiming {
                pack_seconds: (t1 - t0).as_secs_f64(),
                exec_seconds: (t2 - t1).as_secs_f64(),
                unpack_seconds: (t3 - t2).as_secs_f64(),
            },
        ))
    }

    /// Execute a same-artifact batch by name (loads/caches on first use).
    pub fn execute_batch(&self, name: &str, items: &[Vec<Tensor>]) -> Result<Vec<Vec<Tensor>>> {
        let a = self.load(name)?;
        Ok(self.execute_batch_timed(&a, items)?.0)
    }

    /// Execute a weight-bound same-artifact batch: each item carries the
    /// A + C (+ bias) form and the B operand comes from `bound` (cast
    /// and prepacked once at bind time).  Validated against the manifest
    /// specs minus the B slot; bit-identical to the inline-B batch with
    /// the same weights.
    pub fn execute_batch_timed_bound(
        &self,
        artifact: &LoadedArtifact,
        items: &[Vec<Tensor>],
        eplan: &ExecutionPlan,
        bound: &BoundB,
    ) -> Result<(Vec<Vec<Tensor>>, ExecTiming)> {
        let meta = &artifact.meta;
        if !matches!(artifact.program, Program::Gemm { .. }) {
            bail!("{}: only gemm artifacts take weight-bound batches", meta.name);
        }
        let t0 = Instant::now();
        // Manifest specs minus the bound B slot.
        let specs: Vec<&TensorSpec> = meta
            .inputs
            .iter()
            .enumerate()
            .filter(|(i, _)| *i != exec::GEMM_B_INPUT_SLOT)
            .map(|(_, s)| s)
            .collect();
        for (bi, inputs) in items.iter().enumerate() {
            if inputs.len() != specs.len() {
                bail!(
                    "{}: bound batch item {bi}: expected {} inputs, got {}",
                    meta.name,
                    specs.len(),
                    inputs.len()
                );
            }
            for (i, (t, spec)) in inputs.iter().zip(specs.iter().copied()).enumerate() {
                if !t.matches(spec) {
                    bail!(
                        "{}: bound batch item {bi}: input {i} shape {:?} does not \
                         match artifact spec {:?}",
                        meta.name,
                        t.shape,
                        spec.shape
                    );
                }
            }
        }
        let t1 = Instant::now();

        let outputs = artifact
            .program
            .execute_batch_planned_bound(items, eplan, bound)
            .with_context(|| {
                format!("executing {} (bound batch of {})", meta.name, items.len())
            })?;
        let t2 = Instant::now();

        for out in &outputs {
            if out.len() != meta.outputs.len() {
                bail!(
                    "{}: program produced {} outputs, manifest declares {}",
                    meta.name,
                    out.len(),
                    meta.outputs.len()
                );
            }
        }
        let t3 = Instant::now();

        Ok((
            outputs,
            ExecTiming {
                pack_seconds: (t1 - t0).as_secs_f64(),
                exec_seconds: (t2 - t1).as_secs_f64(),
                unpack_seconds: (t3 - t2).as_secs_f64(),
            },
        ))
    }
}

/// The manifest's declared I/O and precision fields must agree with the
/// program's contract.
fn check_contract(meta: &ArtifactMeta, program: &Program) -> Result<()> {
    let want_in = program.input_shapes();
    let got_in: Vec<Vec<usize>> = meta.inputs.iter().map(|s| s.shape.clone()).collect();
    if got_in != want_in {
        bail!(
            "{}: manifest inputs {got_in:?} disagree with program contract {want_in:?}",
            meta.name
        );
    }
    let want_out = program.output_shapes();
    let got_out: Vec<Vec<usize>> = meta.outputs.iter().map(|s| s.shape.clone()).collect();
    if got_out != want_out {
        bail!(
            "{}: manifest outputs {got_out:?} disagree with program contract {want_out:?}",
            meta.name
        );
    }
    // Precision/epilogue/fusion agreement: the registry and figure
    // builders route by the manifest's fields while execution follows
    // the program's — a mismatch would silently measure the wrong mode.
    if let Program::Gemm { dtype_in, dtype_acc, epilogue, fused, .. } = program {
        if let Some(din) = meta.dtype_in {
            if din != *dtype_in {
                bail!(
                    "{}: manifest dtype_in {} disagrees with program {}",
                    meta.name,
                    din.name(),
                    dtype_in.name()
                );
            }
        }
        if let Some(acc) = meta.dtype_acc {
            if acc != *dtype_acc {
                bail!(
                    "{}: manifest dtype_acc {} disagrees with program {}",
                    meta.name,
                    acc.name(),
                    dtype_acc.name()
                );
            }
        }
        if let Some(s) = &meta.schedule {
            if s.epilogue != epilogue.name() {
                bail!(
                    "{}: schedule epilogue {:?} disagrees with program {:?}",
                    meta.name,
                    s.epilogue,
                    epilogue.name()
                );
            }
        }
        let want_fused = meta.kind != ArtifactKind::Unfused;
        if *fused != want_fused {
            bail!(
                "{}: manifest kind {:?} disagrees with program fused={fused}",
                meta.name,
                meta.kind
            );
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::Dtype;

    #[test]
    fn tensor_shape_check() {
        assert!(Tensor::new(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::new(vec![2, 3], vec![0.0; 5]).is_err());
        assert_eq!(Tensor::zeros(vec![4, 4]).elements(), 16);
    }

    #[test]
    fn tensor_matches_spec() {
        let t = Tensor::zeros(vec![2, 2]);
        let good = TensorSpec { shape: vec![2, 2], dtype: Dtype::F32 };
        let bad = TensorSpec { shape: vec![2, 3], dtype: Dtype::F32 };
        assert!(t.matches(&good));
        assert!(!t.matches(&bad));
        // shape/data inconsistency (possible via the pub fields) must fail
        let torn = Tensor { shape: vec![2, 2], data: vec![0.0; 3] };
        assert!(!torn.matches(&good));
    }

    fn write_artifact(dir: &Path, manifest: &str, file: &str, content: &str) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.json"), manifest).unwrap();
        std::fs::write(dir.join(file), content).unwrap();
    }

    const GEMM_MANIFEST: &str = r#"{
      "version": 1,
      "artifacts": [
        {
          "name": "g",
          "file": "g.tprog.json",
          "kind": "baseline",
          "inputs": [
            {"shape": [2, 2], "dtype": "f32"},
            {"shape": [2, 2], "dtype": "f32"},
            {"shape": [2, 2], "dtype": "f32"}
          ],
          "outputs": [{"shape": [2, 2], "dtype": "f32"}],
          "m": 2, "n": 2, "k": 2, "dtype_in": "f32", "dtype_acc": "f32"
        }
      ]
    }"#;

    const GEMM_TPROG: &str = r#"{
      "format": "mlir-gemm-tprog-v1",
      "name": "g",
      "program": {
        "type": "gemm", "m": 2, "n": 2, "k": 2,
        "dtype_in": "f32", "dtype_acc": "f32", "epilogue": "none"
      }
    }"#;

    fn tmpdir(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("mlir_gemm_rt_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn end_to_end_load_and_execute() {
        let dir = tmpdir("e2e");
        write_artifact(&dir, GEMM_MANIFEST, "g.tprog.json", GEMM_TPROG);
        let rt = Runtime::open(&dir).unwrap();
        let out = rt
            .execute(
                "g",
                &[
                    Tensor::new(vec![2, 2], vec![1.0, 0.0, 0.0, 1.0]).unwrap(),
                    Tensor::new(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap(),
                    Tensor::new(vec![2, 2], vec![0.5, 0.5, 0.5, 0.5]).unwrap(),
                ],
            )
            .unwrap();
        assert_eq!(out[0].data, vec![1.5, 2.5, 3.5, 4.5]);
        // cache: second load returns the same Arc
        let a1 = rt.load("g").unwrap();
        let a2 = rt.load("g").unwrap();
        assert!(Arc::ptr_eq(&a1, &a2));
        // a GEMM artifact carries its load-time compiled plan
        let plan = a1.plan().expect("gemm artifact compiles a plan at load");
        assert!(plan.matches_gemm(
            2,
            2,
            2,
            crate::schedule::Dtype::F32,
            crate::schedule::Dtype::F32,
            "none"
        ));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn batched_execute_matches_per_item() {
        let dir = tmpdir("batch");
        write_artifact(&dir, GEMM_MANIFEST, "g.tprog.json", GEMM_TPROG);
        let rt = Runtime::open(&dir).unwrap();
        let items: Vec<Vec<Tensor>> = (0..3)
            .map(|i| {
                let base = i as f32;
                vec![
                    Tensor::new(vec![2, 2], vec![base, 1.0, 2.0, base + 1.0]).unwrap(),
                    Tensor::new(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap(),
                    Tensor::new(vec![2, 2], vec![0.5; 4]).unwrap(),
                ]
            })
            .collect();
        let batched = rt.execute_batch("g", &items).unwrap();
        for (bi, inputs) in items.iter().enumerate() {
            let single = rt.execute("g", inputs).unwrap();
            assert_eq!(batched[bi][0].data, single[0].data, "item {bi}");
        }
        // a misshapen item fails validation before execution
        let bad = vec![vec![
            Tensor::zeros(vec![2, 3]),
            Tensor::zeros(vec![2, 2]),
            Tensor::zeros(vec![2, 2]),
        ]];
        assert!(rt.execute_batch("g", &bad).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn manifest_program_mismatch_rejected_at_load() {
        let dir = tmpdir("mismatch");
        // Program claims 4x4 while the manifest declares 2x2 I/O (shape
        // fields only — a blanket digit replace would corrupt "f32").
        let bad = GEMM_TPROG
            .replace("\"m\": 2", "\"m\": 4")
            .replace("\"n\": 2", "\"n\": 4")
            .replace("\"k\": 2", "\"k\": 4");
        write_artifact(&dir, GEMM_MANIFEST, "g.tprog.json", &bad);
        let rt = Runtime::open(&dir).unwrap();
        let err = rt.load("g").unwrap_err();
        assert!(format!("{err:#}").contains("disagree"), "{err:#}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn manifest_program_dtype_mismatch_rejected_at_load() {
        let dir = tmpdir("dtype_mismatch");
        // Same shapes, different accumulate precision: must fail at load
        // so measured figures can't silently run in the wrong mode.
        let bad = GEMM_TPROG.replace("\"dtype_acc\": \"f32\"", "\"dtype_acc\": \"f16\"");
        write_artifact(&dir, GEMM_MANIFEST, "g.tprog.json", &bad);
        let rt = Runtime::open(&dir).unwrap();
        let err = rt.load("g").unwrap_err();
        assert!(format!("{err:#}").contains("dtype_acc"), "{err:#}");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
