//! PJRT runtime: loads AOT artifacts (HLO text) and executes them.
//!
//! Wraps the `xla` crate: `PjRtClient::cpu()` -> `HloModuleProto::
//! from_text_file` -> `client.compile` -> `execute`.  All artifact I/O is
//! f32 row-major (precision casts live inside the graphs — see aot.py), so
//! the host-side tensor type is a plain `Vec<f32>` + shape.

pub mod manifest;

use std::collections::HashMap;
use std::path::Path;
use std::sync::Mutex;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

pub use manifest::{load_manifest, ArtifactKind, ArtifactMeta, TensorSpec};

/// A host-side f32 tensor (row-major).
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<Tensor> {
        let want: usize = shape.iter().product();
        if want != data.len() {
            bail!("shape {shape:?} needs {want} elements, got {}", data.len());
        }
        Ok(Tensor { shape, data })
    }

    pub fn zeros(shape: Vec<usize>) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape, data: vec![0.0; n] }
    }

    pub fn elements(&self) -> usize {
        self.data.len()
    }

    pub fn matches(&self, spec: &TensorSpec) -> bool {
        self.shape == spec.shape
    }
}

/// One compiled executable plus its manifest entry.
pub struct LoadedArtifact {
    pub meta: ArtifactMeta,
    exe: xla::PjRtLoadedExecutable,
}

/// Execution statistics for one call.
#[derive(Debug, Clone, Copy)]
pub struct ExecTiming {
    /// Host->device literal construction + transfer.
    pub pack_seconds: f64,
    /// Kernel execution (the paper's "kernel runtime").
    pub exec_seconds: f64,
    /// Device->host fetch + unpack.
    pub unpack_seconds: f64,
}

impl ExecTiming {
    pub fn total(&self) -> f64 {
        self.pack_seconds + self.exec_seconds + self.unpack_seconds
    }
}

/// The PJRT runtime: one CPU client + a cache of compiled artifacts.
pub struct Runtime {
    client: xla::PjRtClient,
    loaded: Mutex<HashMap<String, std::sync::Arc<LoadedArtifact>>>,
    metas: Vec<ArtifactMeta>,
}

// The underlying PJRT CPU client is thread-safe; the xla crate just doesn't
// mark its opaque pointers Send/Sync.  The coordinator executes from worker
// threads through &self only.
unsafe impl Send for Runtime {}
unsafe impl Sync for Runtime {}
unsafe impl Send for LoadedArtifact {}
unsafe impl Sync for LoadedArtifact {}

impl Runtime {
    /// Create a runtime over an artifacts directory (reads the manifest).
    pub fn open(artifacts_dir: &Path) -> Result<Runtime> {
        let metas = load_manifest(artifacts_dir)
            .map_err(|e| anyhow!("{e}"))
            .with_context(|| format!("loading manifest from {}", artifacts_dir.display()))?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime {
            client,
            loaded: Mutex::new(HashMap::new()),
            metas,
        })
    }

    /// Create an empty runtime (tests can register HLO files directly).
    pub fn without_manifest() -> Result<Runtime> {
        Ok(Runtime {
            client: xla::PjRtClient::cpu()?,
            loaded: Mutex::new(HashMap::new()),
            metas: Vec::new(),
        })
    }

    pub fn artifacts(&self) -> &[ArtifactMeta] {
        &self.metas
    }

    pub fn find(&self, name: &str) -> Option<&ArtifactMeta> {
        self.metas.iter().find(|m| m.name == name)
    }

    /// Compile (or fetch the cached) artifact by name.
    pub fn load(&self, name: &str) -> Result<std::sync::Arc<LoadedArtifact>> {
        {
            let cache = self.loaded.lock().unwrap();
            if let Some(a) = cache.get(name) {
                return Ok(a.clone());
            }
        }
        let meta = self
            .find(name)
            .ok_or_else(|| anyhow!("unknown artifact {name:?}"))?
            .clone();
        let arc = std::sync::Arc::new(self.compile_meta(meta)?);
        self.loaded
            .lock()
            .unwrap()
            .insert(name.to_string(), arc.clone());
        Ok(arc)
    }

    /// Eagerly compile every artifact of the given kinds.
    pub fn preload(&self, kinds: &[ArtifactKind]) -> Result<usize> {
        let names: Vec<String> = self
            .metas
            .iter()
            .filter(|m| kinds.contains(&m.kind))
            .map(|m| m.name.clone())
            .collect();
        for n in &names {
            self.load(n)?;
        }
        Ok(names.len())
    }

    fn compile_meta(&self, meta: ArtifactMeta) -> Result<LoadedArtifact> {
        let proto = xla::HloModuleProto::from_text_file(&meta.path)
            .with_context(|| format!("parsing HLO text {}", meta.path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", meta.name))?;
        Ok(LoadedArtifact { meta, exe })
    }

    /// Execute a loaded artifact on host tensors, with phase timings.
    pub fn execute_timed(
        &self,
        artifact: &LoadedArtifact,
        inputs: &[Tensor],
    ) -> Result<(Vec<Tensor>, ExecTiming)> {
        let meta = &artifact.meta;
        if inputs.len() != meta.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                meta.name,
                meta.inputs.len(),
                inputs.len()
            );
        }
        for (i, (t, spec)) in inputs.iter().zip(&meta.inputs).enumerate() {
            if !t.matches(spec) {
                bail!(
                    "{}: input {i} shape {:?} does not match artifact spec {:?}",
                    meta.name,
                    t.shape,
                    spec.shape
                );
            }
        }

        let t0 = Instant::now();
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| {
                let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(&t.data).reshape(&dims)
            })
            .collect::<Result<Vec<_>, _>>()?;
        let t1 = Instant::now();

        let result = artifact.exe.execute::<xla::Literal>(&literals)?;
        let root = result[0][0].to_literal_sync()?;
        let t2 = Instant::now();

        // return_tuple=True: the root literal is a tuple of outputs.
        let parts = root.to_tuple()?;
        if parts.len() != meta.outputs.len() {
            bail!(
                "{}: expected {} outputs, got {}",
                meta.name,
                meta.outputs.len(),
                parts.len()
            );
        }
        let outputs = parts
            .into_iter()
            .zip(&meta.outputs)
            .map(|(lit, spec)| {
                let data = lit.to_vec::<f32>()?;
                Tensor::new(spec.shape.clone(), data)
            })
            .collect::<Result<Vec<_>>>()?;
        let t3 = Instant::now();

        Ok((
            outputs,
            ExecTiming {
                pack_seconds: (t1 - t0).as_secs_f64(),
                exec_seconds: (t2 - t1).as_secs_f64(),
                unpack_seconds: (t3 - t2).as_secs_f64(),
            },
        ))
    }

    /// Execute by artifact name (loads/caches on first use).
    pub fn execute(&self, name: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let a = self.load(name)?;
        Ok(self.execute_timed(&a, inputs)?.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_shape_check() {
        assert!(Tensor::new(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::new(vec![2, 3], vec![0.0; 5]).is_err());
        assert_eq!(Tensor::zeros(vec![4, 4]).elements(), 16);
    }

    #[test]
    fn tensor_matches_spec() {
        use crate::schedule::Dtype;
        let t = Tensor::zeros(vec![2, 2]);
        let good = TensorSpec { shape: vec![2, 2], dtype: Dtype::F32 };
        let bad = TensorSpec { shape: vec![2, 3], dtype: Dtype::F32 };
        assert!(t.matches(&good));
        assert!(!t.matches(&bad));
    }
}
