//! GEMM micro-kernel engine: the CPU mirror of the paper's GPU tiling
//! hierarchy (DESIGN.md §6).
//!
//! The paper organizes one GEMM as thread-block tile -> warp tile ->
//! `mma.sync` tile, with operands staged global -> shared -> registers.
//! The in-process executor mirrors that layered reorganization on the
//! host (after Kuzma et al.'s compiler-only layered data reorganization
//! and Thangamani et al.'s library-liberated micro kernels):
//!
//! * **cache block** (MC x KC x NC)   ~ thread-block tile: one block of
//!   the problem sized so the packed operand panels stay cache-resident;
//! * **packed panels**                ~ shared-memory staging: A is
//!   repacked into MR-row interleaved panels and B into contiguous
//!   KC-row panels, so the micro kernel reads both operands at stride
//!   one;
//! * **register tile** (MR x NR)      ~ warp/`mma.sync` tile: the micro
//!   kernel holds MR C-row accumulators in vector registers and stages
//!   NR k-steps of A against them per pass, streaming the j extent at
//!   vector width (the CPU has no `mma.sync`; the compiler's
//!   autovectorizer is the tensor core here, so the tile is shaped for
//!   it — a long stride-one j loop instead of a fixed j sub-tile);
//! * **row-partitioned threads**      ~ the grid: each thread owns a
//!   disjoint band of C rows and runs the blocked kernel on it.
//!
//! **Bit-exactness invariant (scalar policies).**  Every *scalar*
//! kernel in this module — `Naive`, `Tiled`, `Threaded` — produces
//! output bit-identical to the naive i-k-j loop for all f32 inputs: each
//! output element accumulates its k-terms one at a time, in increasing-k
//! order, with a plain (non-fused) multiply and add.  Blocking iterates
//! KC blocks in increasing order and the micro kernel walks each block in
//! increasing k; packing rearranges i/j layout only; threads partition
//! rows, and no output element is touched by two threads.  Nothing in
//! the hierarchy regroups a sum, so the f32 rounding sequence per element
//! is exactly the naive kernel's.  Scalar `KernelPolicy` selection is
//! therefore semantically invisible — it changes speed, never bits —
//! which is what lets the plan compiler (`crate::plan`) treat kernel
//! choice as a pure performance decision and lets the autotuner sweep
//! block sizes the way the paper sweeps GPU tiles.
//!
//! The one deliberate exception is [`KernelPolicy::Simd`], which swaps
//! the innermost register tile for an explicit-SIMD nanokernel
//! ([`super::nanokernel`]).  Those bodies keep the same increasing-k
//! grouping but contract each term with a *fused* multiply-add, so
//! their output is near-but-not-bit-identical to naive; the plan
//! compiler classes such plans `fma_relaxed` and they are verified by
//! the condition-scaled tolerance contract
//! ([`super::nanokernel::verify_fma_relaxed`]), never by bits.  The
//! blocking/packing/threading layers above the micro kernel are shared
//! verbatim, which is why threaded-SIMD is bitwise identical to
//! single-thread SIMD and prepacked-SIMD to raw-SIMD (pinned below):
//! the relaxation is confined to the innermost loop's rounding.
//!
//! This module holds *mechanism only*: the raw kernels and the
//! [`KernelPolicy`] selector they lower to.  *Policy* — which kernel a
//! given GEMM should use — lives in the execution-plan compiler
//! ([`crate::plan`]); the old process-global mutable policy
//! (`set_global_policy` / `global_policy` / `policy_test_lock`) is gone,
//! every caller passes its plan's selector explicitly.

use anyhow::{anyhow, bail, Result};

use super::nanokernel::{self, Isa, Nanokernel};

/// Register-tile rows: C rows updated together by the micro kernel.
pub const MR: usize = 4;
/// Register-tile depth: k-steps of A staged per micro-kernel pass (the
/// C rows are reloaded once per NR k-steps instead of once per step).
pub const NR: usize = 4;

/// Below this many flops per thread, fan-out costs more than it saves.
/// Shared with the plan compiler's thread-partitioning pass so the
/// compiled band count and the kernel's own auto fallback agree.
pub const MIN_FLOPS_PER_THREAD: f64 = 4e6;

fn ceil_div(x: usize, d: usize) -> usize {
    x / d + usize::from(x % d != 0)
}

fn round_up(x: usize, m: usize) -> usize {
    ceil_div(x, m) * m
}

/// Cache-block sizes of the tiled kernel (the CPU analog of the paper's
/// thread-block tile): MC rows of A / KC reduction extent / NC columns
/// of B per block.  Tunable via [`KernelPolicy`] and swept by
/// `autotune::sweep_cpu`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Blocking {
    pub mc: usize,
    pub kc: usize,
    pub nc: usize,
}

/// The one default blocking, shared by `Blocking::default()` and
/// `KernelPolicy::default()` so the two cannot drift.  A panel: 128 x
/// 256 x 4 B = 128 KiB
/// (L2-resident); B panel: 256 x 1024 x 4 B = 1 MiB (L3-resident) —
/// the same sizing logic as the paper's 48 KiB shared-memory budget,
/// for a generic x86 L2/L3.
pub const DEFAULT_BLOCKING: Blocking = Blocking { mc: 128, kc: 256, nc: 1024 };

impl Default for Blocking {
    fn default() -> Self {
        DEFAULT_BLOCKING
    }
}

impl Blocking {
    /// Validated constructor: zero block sizes are a configuration error
    /// (they would loop forever), rejected here instead of silently
    /// clamped downstream.  All parse/compile paths route through this.
    pub fn new(mc: usize, kc: usize, nc: usize) -> Result<Blocking> {
        let b = Blocking { mc, kc, nc };
        b.validate()?;
        Ok(b)
    }

    /// Reject degenerate tiles.  Struct-literal construction via the pub
    /// fields can bypass this, so [`matmul`] still clamps as a last
    /// resort — but every operator-facing path (policy parse, plan
    /// compilation) errors here first.
    pub fn validate(&self) -> Result<()> {
        if self.mc == 0 || self.kc == 0 || self.nc == 0 {
            bail!(
                "invalid blocking {}x{}x{}: every block size must be >= 1",
                self.mc,
                self.kc,
                self.nc
            );
        }
        Ok(())
    }

    /// Guard degenerate block sizes (zero blocks would loop forever).
    fn clamped(self) -> Blocking {
        Blocking {
            mc: self.mc.max(MR),
            kc: self.kc.max(1),
            nc: self.nc.max(1),
        }
    }
}

/// Which kernel executes a GEMM.  The scalar policies (`Naive`,
/// `Tiled`, `Threaded`) are bit-identical and differ only in speed (see
/// the module invariant); `Simd` runs an explicit-SIMD nanokernel and
/// is `fma_relaxed` — near-identical under the tolerance contract, not
/// bitwise.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelPolicy {
    /// The reference i-k-j scalar loop.
    Naive,
    /// Cache-blocked + packed + register-tiled, single thread.
    Tiled(Blocking),
    /// Tiled with C rows partitioned across threads (0 = auto).
    Threaded(Blocking, usize),
    /// Tiled + row-banded (0 = auto, 1 = single thread) with the
    /// innermost register tile lowered to the named ISA's nanokernel.
    /// An ISA the host cannot run degrades to the portable body at
    /// dispatch time ([`nanokernel::kernel_for`]).
    Simd(Blocking, usize, Isa),
}

impl Default for KernelPolicy {
    /// Single-thread tiled: the safe fallback when no plan was compiled.
    /// The plan compiler's thread-partitioning pass makes the real
    /// decision — pooled executors (the server) keep one band, standalone
    /// callers fan out by shape (`crate::plan`).
    fn default() -> Self {
        KernelPolicy::Tiled(DEFAULT_BLOCKING)
    }
}

impl KernelPolicy {
    /// Parse an operator-facing policy string:
    /// `naive` | `tiled[:MC,KC,NC]` | `threaded[:MC,KC,NC[,T]]` |
    /// `simd:<isa>[:MC,KC,NC[,T]]`
    /// (T = thread count, 0 or omitted = auto; isa = portable | avx2 |
    /// avx512 | neon).  Bare `simd` is not a policy — it is the plan
    /// *override* that asks pass 6 to pick the ISA
    /// (`crate::plan::PlanOverride::Simd`).
    pub fn parse(text: &str) -> Result<KernelPolicy> {
        let (head, rest) = match text.split_once(':') {
            Some((h, r)) => (h, Some(r)),
            None => (text, None),
        };
        let nums = |r: &str| -> Result<Vec<usize>> {
            r.split(',')
                .map(|p| {
                    p.trim()
                        .parse::<usize>()
                        .map_err(|_| anyhow!("bad kernel block spec {r:?}"))
                })
                .collect()
        };
        match (head, rest) {
            ("naive", None) => Ok(KernelPolicy::Naive),
            ("naive", Some(_)) => bail!("naive takes no block spec"),
            ("tiled", None) => Ok(KernelPolicy::Tiled(Blocking::default())),
            ("tiled", Some(r)) => {
                let v = nums(r)?;
                if v.len() != 3 {
                    bail!("tiled wants MC,KC,NC, got {r:?}");
                }
                Ok(KernelPolicy::Tiled(Blocking::new(v[0], v[1], v[2])?))
            }
            ("threaded", None) => {
                Ok(KernelPolicy::Threaded(Blocking::default(), 0))
            }
            ("threaded", Some(r)) => {
                let v = nums(r)?;
                match v.len() {
                    3 => Ok(KernelPolicy::Threaded(Blocking::new(v[0], v[1], v[2])?, 0)),
                    4 => Ok(KernelPolicy::Threaded(
                        Blocking::new(v[0], v[1], v[2])?,
                        v[3],
                    )),
                    _ => bail!("threaded wants MC,KC,NC[,T], got {r:?}"),
                }
            }
            ("simd", Some(r)) => {
                let (isa_text, blocks) = match r.split_once(':') {
                    Some((i, b)) => (i, Some(b)),
                    None => (r, None),
                };
                let isa = Isa::parse(isa_text)?;
                match blocks {
                    None => Ok(KernelPolicy::Simd(Blocking::default(), 0, isa)),
                    Some(b) => {
                        let v = nums(b)?;
                        match v.len() {
                            3 => Ok(KernelPolicy::Simd(
                                Blocking::new(v[0], v[1], v[2])?,
                                0,
                                isa,
                            )),
                            4 => Ok(KernelPolicy::Simd(
                                Blocking::new(v[0], v[1], v[2])?,
                                v[3],
                                isa,
                            )),
                            _ => bail!("simd wants <isa>[:MC,KC,NC[,T]], got {r:?}"),
                        }
                    }
                }
            }
            ("simd", None) => bail!(
                "bare \"simd\" is a plan override, not a kernel policy; name an \
                 isa (simd:avx2[:MC,KC,NC[,T]]) or use --plan simd"
            ),
            _ => bail!(
                "unknown kernel policy {text:?} (naive | tiled[:MC,KC,NC] | \
                 threaded[:MC,KC,NC[,T]] | simd:<isa>[:MC,KC,NC[,T]])"
            ),
        }
    }

    /// Canonical name (parses back to the same policy).
    pub fn name(&self) -> String {
        match *self {
            KernelPolicy::Naive => "naive".to_string(),
            KernelPolicy::Tiled(b) => format!("tiled:{},{},{}", b.mc, b.kc, b.nc),
            KernelPolicy::Threaded(b, t) => {
                format!("threaded:{},{},{},{t}", b.mc, b.kc, b.nc)
            }
            KernelPolicy::Simd(b, t, isa) => {
                format!("simd:{}:{},{},{},{t}", isa.name(), b.mc, b.kc, b.nc)
            }
        }
    }

    /// Validate the policy's blocking (naive has none).  Plan compilation
    /// and manual plan construction call this so an invalid tile is an
    /// error at build time, never a hang or silent clamp at run time.
    pub fn validate(&self) -> Result<()> {
        match self {
            KernelPolicy::Naive => Ok(()),
            KernelPolicy::Tiled(b)
            | KernelPolicy::Threaded(b, _)
            | KernelPolicy::Simd(b, _, _) => b.validate(),
        }
    }
}

/// The B operand of one GEMM: either the raw row-major slice (the tiled
/// kernels pack it into panels per call) or a [`PrepackedB`] whose
/// panels were materialized once — the weight-binding hot path, where B
/// is a constant served to many requests and re-running [`pack_b`] per
/// call is pure overhead.  Packing is a pure i/j rearrangement, so the
/// two forms are bit-identical (pinned by the unit tests below).
#[derive(Debug, Clone, Copy)]
pub enum BOperand<'a> {
    Raw(&'a [f32]),
    Prepacked(&'a PrepackedB),
}

impl BOperand<'_> {
    fn check(&self, k: usize, n: usize) {
        match *self {
            BOperand::Raw(b) => assert_eq!(b.len(), k * n, "B length"),
            BOperand::Prepacked(p) => {
                assert_eq!((p.k, p.n), (k, n), "prepacked B shape")
            }
        }
    }
}

/// B materialized into [`pack_b`] panel layout once, ahead of time: one
/// contiguous KC-row panel per (NC column block, KC reduction block)
/// pair, in the exact layout (and therefore the exact bits) the tiled
/// kernel's per-call packing would produce.  Shared immutably across
/// calls and threads; built by [`PrepackedB::pack`] or
/// [`crate::plan::ExecutionPlan::prepack_b`].
#[derive(Debug, Clone, PartialEq)]
pub struct PrepackedB {
    k: usize,
    n: usize,
    /// The (clamped) blocking the panels were laid out for.  Kernels
    /// consuming a prepacked B iterate with *these* cache blocks, not
    /// their policy's — bit-identical either way (the module invariant),
    /// so a plan/panel blocking mismatch costs speed, never bits.
    blocking: Blocking,
    panels: Vec<f32>,
    /// Panel start offsets, indexed `jb * n_pblocks + pb`.
    offsets: Vec<usize>,
}

impl PrepackedB {
    /// Pack a full k x n B into panels under `blocking` (clamped the
    /// same way [`matmul`] clamps).  Total storage is exactly `k * n`
    /// elements: every B element lands in exactly one panel.
    pub fn pack(b: &[f32], k: usize, n: usize, blocking: Blocking) -> PrepackedB {
        assert_eq!(b.len(), k * n, "B length");
        let bs = blocking.clamped();
        let n_pb = ceil_div(k, bs.kc);
        let n_jb = ceil_div(n, bs.nc);
        let mut panels = vec![0.0f32; k * n];
        let mut offsets = vec![0usize; n_jb * n_pb];
        let mut off = 0usize;
        for (jb, jc) in (0..n).step_by(bs.nc).enumerate() {
            let ncb = bs.nc.min(n - jc);
            for (pb, pc) in (0..k).step_by(bs.kc).enumerate() {
                let kcb = bs.kc.min(k - pc);
                offsets[jb * n_pb + pb] = off;
                pack_b(&mut panels[off..off + kcb * ncb], b, n, pc, kcb, jc, ncb);
                off += kcb * ncb;
            }
        }
        PrepackedB { k, n, blocking: bs, panels, offsets }
    }

    pub fn k(&self) -> usize {
        self.k
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn blocking(&self) -> Blocking {
        self.blocking
    }

    /// Bytes held by the panel store.
    pub fn bytes(&self) -> usize {
        self.panels.len() * std::mem::size_of::<f32>()
    }

    fn panel(&self, jb: usize, pb: usize, n_pb: usize, len: usize) -> &[f32] {
        let start = self.offsets[jb * n_pb + pb];
        &self.panels[start..start + len]
    }
}

/// The register-tile engine one blocked sweep lowers to: the scalar
/// bit-exact [`macro_kernel`] or one resolved nanokernel body.  The
/// blocking/packing/banding layers are engine-agnostic — [`Micro`] is
/// the only seam where the two numerics classes diverge.
#[derive(Clone, Copy)]
enum Micro {
    Scalar,
    Nano(&'static dyn Nanokernel),
}

impl Micro {
    #[allow(clippy::too_many_arguments)]
    fn run(
        self,
        out: &mut [f32],
        ldc: usize,
        ic: usize,
        mcb: usize,
        jc: usize,
        ncb: usize,
        kcb: usize,
        apack: &[f32],
        bpack: &[f32],
    ) {
        match self {
            Micro::Scalar => {
                macro_kernel(out, ldc, ic, mcb, jc, ncb, kcb, apack, bpack)
            }
            Micro::Nano(nano) => {
                nano.macro_kernel(out, ldc, ic, mcb, jc, ncb, kcb, apack, bpack)
            }
        }
    }
}

/// `out[i, j] += sum_k a[i, k] * b[k, j]` over row-major slices, f32
/// accumulate, k-terms in increasing-k order (bit-identical across
/// scalar policies; `Simd` is tolerance-verified instead — see the
/// module doc).  The policy comes from an explicit
/// [`crate::plan::ExecutionPlan`] — there is no ambient global.
pub fn matmul(
    policy: KernelPolicy,
    out: &mut [f32],
    a: &[f32],
    b: &[f32],
    m: usize,
    n: usize,
    k: usize,
) {
    matmul_b(policy, out, a, BOperand::Raw(b), m, n, k);
}

/// [`matmul`] over an explicit [`BOperand`]: the engine's real entry
/// point.  A prepacked B skips the per-call [`pack_b`] copy and runs the
/// tiled kernel over the shared panels — under *every* policy (a naive
/// plan handed prepacked panels still consumes them through the tiled
/// loop, which is bit-identical to the naive loop by the module
/// invariant).
pub fn matmul_b(
    policy: KernelPolicy,
    out: &mut [f32],
    a: &[f32],
    b: BOperand,
    m: usize,
    n: usize,
    k: usize,
) {
    assert_eq!(out.len(), m * n, "output length");
    assert_eq!(a.len(), m * k, "A length");
    b.check(k, n);
    if m == 0 || n == 0 || k == 0 {
        return; // += 0 terms: out unchanged, like the naive loop
    }
    match (policy, b) {
        (KernelPolicy::Naive, BOperand::Raw(b)) => gemm_naive(out, a, b, m, n, k),
        (KernelPolicy::Naive, BOperand::Prepacked(pre)) => {
            gemm_tiled_pre(out, a, pre, m, n, k, Micro::Scalar)
        }
        (KernelPolicy::Tiled(bs), b) => {
            gemm_tiled_b(out, a, b, m, n, k, bs.clamped(), Micro::Scalar)
        }
        (KernelPolicy::Threaded(bs, t), b) => {
            gemm_banded(out, a, b, m, n, k, bs.clamped(), t, Micro::Scalar, None)
        }
        (KernelPolicy::Simd(bs, t, isa), b) => {
            let micro = Micro::Nano(nanokernel::kernel_for(isa));
            gemm_banded(out, a, b, m, n, k, bs.clamped(), t, micro, None)
        }
    }
}

/// [`matmul`] with a fused write-back tail: after a disjoint row band's
/// full k-reduction completes, `tail` runs over that band — in the
/// band's own thread for the threaded kernel, over the whole output for
/// the single-thread kernels.  This is how a plan's fused epilogue
/// reaches the engine: every output element sees the tail exactly once,
/// after all of its k-terms, so fusion is bit-identical to a separate
/// whole-matrix pass (the epilogue is elementwise per row).
///
/// The tail runs even for empty reductions (`k == 0`): a GEMM epilogue
/// applies to `C + 0` exactly like the unfused path does.
#[allow(clippy::too_many_arguments)]
pub fn matmul_fused(
    policy: KernelPolicy,
    out: &mut [f32],
    a: &[f32],
    b: &[f32],
    m: usize,
    n: usize,
    k: usize,
    tail: &(dyn Fn(&mut [f32]) + Sync),
) {
    matmul_fused_b(policy, out, a, BOperand::Raw(b), m, n, k, tail);
}

/// [`matmul_fused`] over an explicit [`BOperand`].
#[allow(clippy::too_many_arguments)]
pub fn matmul_fused_b(
    policy: KernelPolicy,
    out: &mut [f32],
    a: &[f32],
    b: BOperand,
    m: usize,
    n: usize,
    k: usize,
    tail: &(dyn Fn(&mut [f32]) + Sync),
) {
    assert_eq!(out.len(), m * n, "output length");
    assert_eq!(a.len(), m * k, "A length");
    b.check(k, n);
    if m == 0 || n == 0 || k == 0 {
        tail(out);
        return;
    }
    match (policy, b) {
        (KernelPolicy::Naive, BOperand::Raw(b)) => {
            gemm_naive(out, a, b, m, n, k);
            tail(out);
        }
        (KernelPolicy::Naive, BOperand::Prepacked(pre)) => {
            gemm_tiled_pre(out, a, pre, m, n, k, Micro::Scalar);
            tail(out);
        }
        (KernelPolicy::Tiled(bs), b) => {
            gemm_tiled_b(out, a, b, m, n, k, bs.clamped(), Micro::Scalar);
            tail(out);
        }
        (KernelPolicy::Threaded(bs, t), b) => {
            gemm_banded(out, a, b, m, n, k, bs.clamped(), t, Micro::Scalar, Some(tail))
        }
        (KernelPolicy::Simd(bs, t, isa), b) => {
            let micro = Micro::Nano(nanokernel::kernel_for(isa));
            gemm_banded(out, a, b, m, n, k, bs.clamped(), t, micro, Some(tail))
        }
    }
}

// ---------------------------------------------------------------------------
// Naive reference kernel
// ---------------------------------------------------------------------------

/// The scalar i-k-j loop (formerly `exec::matmul_acc`): the semantic
/// reference every other kernel must match bit-for-bit.
fn gemm_naive(out: &mut [f32], a: &[f32], b: &[f32], m: usize, n: usize, k: usize) {
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        for (kk, &av) in arow.iter().enumerate() {
            let brow = &b[kk * n..(kk + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Tiled kernel: cache blocks -> packed panels -> register tiles
// ---------------------------------------------------------------------------

/// Pack `a[ic..ic+mcb, pc..pc+kcb]` into MR-row panels, p-major inside a
/// panel (`apack[panel][p][i]`), zero-padding ragged edge rows.  Padded
/// lanes only feed accumulator entries that are never stored.
fn pack_a(
    apack: &mut [f32],
    a: &[f32],
    lda: usize,
    ic: usize,
    mcb: usize,
    pc: usize,
    kcb: usize,
) {
    let panels = ceil_div(mcb, MR);
    for pi in 0..panels {
        let dst = &mut apack[pi * MR * kcb..(pi + 1) * MR * kcb];
        let i0 = ic + pi * MR;
        let rows = MR.min(ic + mcb - i0);
        for p in 0..kcb {
            let d = &mut dst[p * MR..(p + 1) * MR];
            for (i, slot) in d.iter_mut().enumerate().take(rows) {
                *slot = a[(i0 + i) * lda + pc + p];
            }
            for slot in d.iter_mut().skip(rows) {
                *slot = 0.0;
            }
        }
    }
}

/// Pack `b[pc..pc+kcb, jc..jc+ncb]` into a contiguous panel of kcb rows
/// (`bpack[p * ncb + j]`): the micro kernel streams each row at stride
/// one regardless of the source leading dimension.
fn pack_b(
    bpack: &mut [f32],
    b: &[f32],
    ldb: usize,
    pc: usize,
    kcb: usize,
    jc: usize,
    ncb: usize,
) {
    for p in 0..kcb {
        let src = &b[(pc + p) * ldb + jc..(pc + p) * ldb + jc + ncb];
        bpack[p * ncb..(p + 1) * ncb].copy_from_slice(src);
    }
}

/// One rank-1 update row: `orow[j] += av * brow[j]` (the naive kernel's
/// inner loop; used for the MR/NR remainders, same k order).
#[inline(always)]
fn saxpy(orow: &mut [f32], av: f32, brow: &[f32]) {
    for (o, &bv) in orow.iter_mut().zip(brow) {
        *o += av * bv;
    }
}

/// The register-tile micro kernel: MR C-row accumulators x NR staged
/// k-steps, streaming j across the packed B panel.  Per output element
/// the k-terms land one at a time in increasing-k order with a plain
/// (non-fused) multiply and add — fusing or reassociating would change
/// the rounding sequence vs the naive kernel.  `ab` holds the MR x NR
/// A-scalars p-major (`ab[u * MR + i]`), `bp` the NR packed B rows.
#[allow(clippy::too_many_arguments)]
#[inline(always)]
fn micro_kernel(
    ab: &[f32; MR * NR],
    bp: &[f32],
    ncb: usize,
    o0: &mut [f32],
    o1: &mut [f32],
    o2: &mut [f32],
    o3: &mut [f32],
) {
    let (b0, rest) = bp.split_at(ncb);
    let (b1, rest) = rest.split_at(ncb);
    let (b2, rest) = rest.split_at(ncb);
    let b3 = &rest[..ncb];
    let o0 = &mut o0[..ncb];
    let o1 = &mut o1[..ncb];
    let o2 = &mut o2[..ncb];
    let o3 = &mut o3[..ncb];
    for j in 0..ncb {
        let (bv0, bv1, bv2, bv3) = (b0[j], b1[j], b2[j], b3[j]);
        let mut x0 = o0[j];
        x0 += ab[0] * bv0;
        x0 += ab[4] * bv1;
        x0 += ab[8] * bv2;
        x0 += ab[12] * bv3;
        o0[j] = x0;
        let mut x1 = o1[j];
        x1 += ab[1] * bv0;
        x1 += ab[5] * bv1;
        x1 += ab[9] * bv2;
        x1 += ab[13] * bv3;
        o1[j] = x1;
        let mut x2 = o2[j];
        x2 += ab[2] * bv0;
        x2 += ab[6] * bv1;
        x2 += ab[10] * bv2;
        x2 += ab[14] * bv3;
        o2[j] = x2;
        let mut x3 = o3[j];
        x3 += ab[3] * bv0;
        x3 += ab[7] * bv1;
        x3 += ab[11] * bv2;
        x3 += ab[15] * bv3;
        o3[j] = x3;
    }
}

/// Run the register tiles over one cache block: full MR-row panels take
/// the micro kernel (NR k-steps per pass, k remainder via [`saxpy`]);
/// the ragged row tail runs row-at-a-time saxpy in the same k order.
#[allow(clippy::too_many_arguments)]
fn macro_kernel(
    out: &mut [f32],
    ldc: usize,
    ic: usize,
    mcb: usize,
    jc: usize,
    ncb: usize,
    kcb: usize,
    apack: &[f32],
    bpack: &[f32],
) {
    let full_panels = mcb / MR;
    for pi in 0..full_panels {
        let i0 = ic + pi * MR;
        let ap = &apack[pi * MR * kcb..(pi + 1) * MR * kcb];
        let (r0, rest) = out[i0 * ldc..].split_at_mut(ldc);
        let (r1, rest) = rest.split_at_mut(ldc);
        let (r2, rest) = rest.split_at_mut(ldc);
        let r3 = &mut rest[..ldc];
        let o0 = &mut r0[jc..jc + ncb];
        let o1 = &mut r1[jc..jc + ncb];
        let o2 = &mut r2[jc..jc + ncb];
        let o3 = &mut r3[jc..jc + ncb];
        let mut p = 0;
        while p + NR <= kcb {
            let ab: &[f32; MR * NR] =
                ap[p * MR..p * MR + MR * NR].try_into().unwrap();
            micro_kernel(ab, &bpack[p * ncb..(p + NR) * ncb], ncb, o0, o1, o2, o3);
            p += NR;
        }
        while p < kcb {
            let brow = &bpack[p * ncb..(p + 1) * ncb];
            saxpy(o0, ap[p * MR], brow);
            saxpy(o1, ap[p * MR + 1], brow);
            saxpy(o2, ap[p * MR + 2], brow);
            saxpy(o3, ap[p * MR + 3], brow);
            p += 1;
        }
    }
    for i in full_panels * MR..mcb {
        let (pi, ir) = (i / MR, i % MR);
        let ap = &apack[pi * MR * kcb..];
        let orow = &mut out[(ic + i) * ldc + jc..(ic + i) * ldc + jc + ncb];
        for p in 0..kcb {
            saxpy(orow, ap[p * MR + ir], &bpack[p * ncb..(p + 1) * ncb]);
        }
    }
}

/// Zeroed scratch for a packed panel whose first element sits on a
/// 64-byte boundary: returns the backing Vec (over-allocated by up to
/// 15 elements of slack) and the element offset of the aligned start.
/// The nanokernels' full-width vector loads then never split a cache
/// line — the zmm bodies in particular lose ~30% on split 64-byte
/// loads.  Alignment is a speed contract only; every body uses
/// unaligned load instructions and is correct at any offset.
fn aligned_pack_vec(len: usize) -> (Vec<f32>, usize) {
    let v = vec![0.0f32; len + 15];
    let mis = (v.as_ptr() as usize) % 64;
    let off = if mis == 0 { 0 } else { (64 - mis) / 4 };
    (v, off)
}

#[allow(clippy::too_many_arguments)]
fn gemm_tiled(
    out: &mut [f32],
    a: &[f32],
    b: &[f32],
    m: usize,
    n: usize,
    k: usize,
    bs: Blocking,
    micro: Micro,
) {
    let Blocking { mc, kc, nc } = bs;
    let alen = round_up(mc.min(m), MR) * kc.min(k);
    let blen = nc.min(n) * kc.min(k);
    let (mut apack_buf, ao) = aligned_pack_vec(alen);
    let (mut bpack_buf, bo) = aligned_pack_vec(blen);
    let apack = &mut apack_buf[ao..ao + alen];
    let bpack = &mut bpack_buf[bo..bo + blen];
    for jc in (0..n).step_by(nc) {
        let ncb = nc.min(n - jc);
        // KC blocks in increasing-k order: the per-element accumulation
        // sequence stays the naive kernel's.
        for pc in (0..k).step_by(kc) {
            let kcb = kc.min(k - pc);
            pack_b(&mut bpack, b, n, pc, kcb, jc, ncb);
            for ic in (0..m).step_by(mc) {
                let mcb = mc.min(m - ic);
                pack_a(&mut apack, a, k, ic, mcb, pc, kcb);
                micro.run(out, n, ic, mcb, jc, ncb, kcb, &apack, &bpack);
            }
        }
    }
}

/// [`gemm_tiled`] over panels packed ahead of time: identical loop
/// structure and k order, with the per-call [`pack_b`] copy replaced by
/// a panel lookup.  The cache blocks come from the panels' own layout —
/// the policy's blocking does not apply (bit-identical regardless).
fn gemm_tiled_pre(
    out: &mut [f32],
    a: &[f32],
    pre: &PrepackedB,
    m: usize,
    n: usize,
    k: usize,
    micro: Micro,
) {
    let Blocking { mc, kc, nc } = pre.blocking;
    let n_pb = ceil_div(k, kc);
    let alen = round_up(mc.min(m), MR) * kc.min(k);
    let (mut apack_buf, ao) = aligned_pack_vec(alen);
    let apack = &mut apack_buf[ao..ao + alen];
    for (jb, jc) in (0..n).step_by(nc).enumerate() {
        let ncb = nc.min(n - jc);
        for (pb, pc) in (0..k).step_by(kc).enumerate() {
            let kcb = kc.min(k - pc);
            let bpack = pre.panel(jb, pb, n_pb, kcb * ncb);
            for ic in (0..m).step_by(mc) {
                let mcb = mc.min(m - ic);
                pack_a(&mut apack, a, k, ic, mcb, pc, kcb);
                micro.run(out, n, ic, mcb, jc, ncb, kcb, &apack, bpack);
            }
        }
    }
}

/// Dispatch one single-thread tiled GEMM over either B form.
#[allow(clippy::too_many_arguments)]
fn gemm_tiled_b(
    out: &mut [f32],
    a: &[f32],
    b: BOperand,
    m: usize,
    n: usize,
    k: usize,
    bs: Blocking,
    micro: Micro,
) {
    match b {
        BOperand::Raw(b) => gemm_tiled(out, a, b, m, n, k, bs, micro),
        BOperand::Prepacked(pre) => gemm_tiled_pre(out, a, pre, m, n, k, micro),
    }
}

/// Row-banded execution of one blocked GEMM under any [`Micro`] engine
/// (formerly `gemm_threaded`, which was scalar-only).  Band count 0 =
/// auto; 1 (or a problem too small to fan out) degrades to the
/// single-thread path.
#[allow(clippy::too_many_arguments)]
fn gemm_banded(
    out: &mut [f32],
    a: &[f32],
    b: BOperand,
    m: usize,
    n: usize,
    k: usize,
    bs: Blocking,
    threads: usize,
    micro: Micro,
    tail: Option<&(dyn Fn(&mut [f32]) + Sync)>,
) {
    let hw = if threads == 0 {
        std::thread::available_parallelism().map(|v| v.get()).unwrap_or(1)
    } else {
        threads
    };
    let flops = 2.0 * m as f64 * n as f64 * k as f64;
    let by_work = (flops / MIN_FLOPS_PER_THREAD) as usize;
    let bands = hw.min(by_work.max(1)).min(ceil_div(m, MR)).max(1);
    if bands <= 1 {
        gemm_tiled_b(out, a, b, m, n, k, bs, micro);
        if let Some(tail) = tail {
            tail(out);
        }
        return;
    }
    // MR-aligned row bands: each thread owns a disjoint band of C (and
    // the matching band of A), so no element is touched twice and the
    // per-element operation sequence is the single-thread kernel's —
    // under the scalar engine *and* under a nanokernel, which is why
    // threaded-SIMD stays bitwise identical to single-thread SIMD.  The
    // fused tail runs per band right after the band's k-reduction: still
    // exactly once per element, after all of its k-terms.  Every band
    // reads the whole of B, so a prepacked B is shared across the bands
    // as-is (`BOperand` is `Copy` over shared references).
    let rows_per = round_up(ceil_div(m, bands), MR);
    std::thread::scope(|scope| {
        for (oband, aband) in out.chunks_mut(rows_per * n).zip(a.chunks(rows_per * k)) {
            let bm = oband.len() / n;
            scope.spawn(move || {
                gemm_tiled_b(oband, aband, b, bm, n, k, bs, micro);
                if let Some(tail) = tail {
                    tail(oband);
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;
    use crate::util::proptest::{check, Config};

    fn random_case(rng: &mut Rng, m: usize, n: usize, k: usize) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        (
            rng.normal_matrix(m, k),
            rng.normal_matrix(k, n),
            rng.normal_matrix(m, n),
        )
    }

    fn run(policy: KernelPolicy, c: &[f32], a: &[f32], b: &[f32], m: usize, n: usize, k: usize) -> Vec<f32> {
        let mut out = c.to_vec();
        matmul(policy, &mut out, a, b, m, n, k);
        out
    }

    fn assert_policies_bitwise_equal(m: usize, n: usize, k: usize, seed: u64) {
        let mut rng = Rng::new(seed);
        let (a, b, c) = random_case(&mut rng, m, n, k);
        let want = run(KernelPolicy::Naive, &c, &a, &b, m, n, k);
        // Small blocks force multiple cache blocks + ragged edges even on
        // tiny shapes; defaults cover the single-block fast path.
        for bs in [
            Blocking { mc: 8, kc: 4, nc: 16 },
            Blocking { mc: 5, kc: 3, nc: 7 }, // deliberately unaligned
            Blocking::default(),
        ] {
            let got = run(KernelPolicy::Tiled(bs), &c, &a, &b, m, n, k);
            assert!(
                want.iter().zip(&got).all(|(w, g)| w.to_bits() == g.to_bits()),
                "tiled {bs:?} drifted at {m}x{n}x{k}"
            );
            for t in [2, 3] {
                let got = run(KernelPolicy::Threaded(bs, t), &c, &a, &b, m, n, k);
                assert!(
                    want.iter().zip(&got).all(|(w, g)| w.to_bits() == g.to_bits()),
                    "threaded({t}) {bs:?} drifted at {m}x{n}x{k}"
                );
            }
        }
    }

    #[test]
    fn policies_bit_identical_on_edge_shapes() {
        for &(m, n, k) in &[
            (1, 1, 1),
            (1, 17, 5),   // skinny m=1
            (19, 1, 7),   // skinny n=1
            (4, 16, 8),   // exact register tiles
            (5, 17, 9),   // every dimension ragged
            (33, 7, 21),
        ] {
            assert_policies_bitwise_equal(m, n, k, 0xC0FFEE + (m * 1000 + n * 10 + k) as u64);
        }
    }

    #[test]
    fn policies_bit_identical_property() {
        check(
            Config { cases: 48, ..Default::default() },
            |rng| {
                vec![1 + rng.below(40), 1 + rng.below(40), 1 + rng.below(40)]
            },
            |v| crate::util::proptest::shrink_usizes(v, 1),
            |dims| {
                let (m, n, k) = (dims[0], dims[1], dims[2]);
                let mut rng = Rng::new(7 + (m * 10007 + n * 101 + k) as u64);
                let (a, b, c) = random_case(&mut rng, m, n, k);
                let want = run(KernelPolicy::Naive, &c, &a, &b, m, n, k);
                let bs = Blocking { mc: 8, kc: 8, nc: 16 };
                for policy in [
                    KernelPolicy::Tiled(bs),
                    KernelPolicy::Threaded(bs, 2),
                    KernelPolicy::Tiled(Blocking::default()),
                ] {
                    let got = run(policy, &c, &a, &b, m, n, k);
                    for (idx, (w, g)) in want.iter().zip(&got).enumerate() {
                        if w.to_bits() != g.to_bits() {
                            return Err(format!(
                                "{} drifted at element {idx}: {w} vs {g}",
                                policy.name()
                            ));
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn k_zero_and_empty_dims_leave_output_unchanged() {
        let c = vec![1.5f32, -2.5, 3.5, 4.5];
        let out = run(KernelPolicy::Tiled(Blocking::default()), &c, &[], &[], 2, 2, 0);
        assert_eq!(out, c);
        let mut empty: Vec<f32> = vec![];
        matmul(KernelPolicy::Threaded(Blocking::default(), 2), &mut empty, &[], &[1.0], 0, 1, 1);
        assert!(empty.is_empty());
    }

    #[test]
    fn identity_times_matrix_is_exact() {
        let (m, n, k) = (6, 5, 6);
        let mut rng = Rng::new(11);
        let b = rng.normal_matrix(k, n);
        let mut id = vec![0.0f32; m * k];
        for i in 0..m {
            id[i * k + i] = 1.0;
        }
        let zeros = vec![0.0f32; m * n];
        let out = run(
            KernelPolicy::Tiled(Blocking { mc: 4, kc: 2, nc: 4 }),
            &zeros,
            &id,
            &b,
            m,
            n,
            k,
        );
        assert_eq!(out, b[..m * n].to_vec());
    }

    #[test]
    fn policy_parse_and_name_roundtrip() {
        for text in [
            "naive",
            "tiled",
            "tiled:64,128,256",
            "threaded",
            "threaded:64,128,256",
            "threaded:64,128,256,4",
            "simd:avx2",
            "simd:portable:32,64,128",
            "simd:avx512:64,128,256,2",
            "simd:neon:8,8,8,0",
        ] {
            let p = KernelPolicy::parse(text).unwrap();
            let p2 = KernelPolicy::parse(&p.name()).unwrap();
            assert_eq!(p, p2, "{text}");
        }
        assert_eq!(KernelPolicy::parse("naive").unwrap(), KernelPolicy::Naive);
        assert_eq!(
            KernelPolicy::parse("tiled:1,2,3").unwrap(),
            KernelPolicy::Tiled(Blocking { mc: 1, kc: 2, nc: 3 })
        );
        assert_eq!(
            KernelPolicy::parse("threaded:1,2,3,9").unwrap(),
            KernelPolicy::Threaded(Blocking { mc: 1, kc: 2, nc: 3 }, 9)
        );
        assert_eq!(
            KernelPolicy::parse("simd:avx2:1,2,3,9").unwrap(),
            KernelPolicy::Simd(Blocking { mc: 1, kc: 2, nc: 3 }, 9, Isa::Avx2Fma)
        );
        assert_eq!(
            KernelPolicy::parse("simd:portable").unwrap(),
            KernelPolicy::Simd(DEFAULT_BLOCKING, 0, Isa::Portable)
        );
    }

    #[test]
    fn policy_parse_rejects_garbage() {
        for text in [
            "",
            "fast",
            "tiled:1,2",
            "tiled:a,b,c",
            "threaded:1",
            "naive:1,2,3",
            "simd",           // bare simd is a plan override, not a policy
            "simd:sse9",      // unknown isa
            "simd:avx2:1,2",  // short block spec
            "simd:avx2:0,2,3", // zero tile
        ] {
            assert!(KernelPolicy::parse(text).is_err(), "{text:?} parsed");
        }
    }

    #[test]
    fn zero_blocking_is_rejected_at_construction() {
        // The validation satellite: a zero tile is a configuration error
        // at parse/build time, not a silent clamp (or hang) at run time.
        for text in ["tiled:0,2,3", "tiled:2,0,3", "tiled:2,3,0", "threaded:0,0,0"] {
            assert!(KernelPolicy::parse(text).is_err(), "{text:?} parsed");
        }
        assert!(Blocking::new(0, 1, 1).is_err());
        assert!(Blocking::new(1, 0, 1).is_err());
        assert!(Blocking::new(1, 1, 0).is_err());
        assert!(Blocking::new(4, 4, 4).is_ok());
        assert!(KernelPolicy::Tiled(Blocking { mc: 0, kc: 1, nc: 1 }).validate().is_err());
        assert!(KernelPolicy::Naive.validate().is_ok());
    }

    #[test]
    fn fused_tail_runs_exactly_once_per_element_after_the_reduction() {
        // matmul_fused(tail) must equal matmul followed by one
        // whole-matrix tail pass — per band, per element, no double
        // application — including under threading and for k == 0.
        let cases: &[(usize, usize, usize)] = &[(13, 9, 11), (33, 7, 21), (8, 8, 0)];
        for &(m, n, k) in cases {
            let mut rng = Rng::new((m * 100 + n * 10 + k) as u64);
            let (a, b, c) = random_case(&mut rng, m, n, k);
            for policy in [
                KernelPolicy::Naive,
                KernelPolicy::Tiled(Blocking { mc: 8, kc: 4, nc: 16 }),
                KernelPolicy::Threaded(Blocking { mc: 8, kc: 8, nc: 16 }, 3),
            ] {
                let mut want = c.clone();
                matmul(policy, &mut want, &a, &b, m, n, k);
                for v in want.iter_mut() {
                    *v = (*v + 1.0).max(0.0); // a bias_relu-shaped tail
                }
                let mut got = c.clone();
                matmul_fused(policy, &mut got, &a, &b, m, n, k, &|band: &mut [f32]| {
                    for v in band.iter_mut() {
                        *v = (*v + 1.0).max(0.0);
                    }
                });
                assert!(
                    want.iter().zip(&got).all(|(w, g)| w.to_bits() == g.to_bits()),
                    "fused tail drifted at {m}x{n}x{k} under {}",
                    policy.name()
                );
            }
        }
    }

    #[test]
    fn prepacked_b_bit_identical_to_raw_under_every_policy() {
        // The weight-binding contract: consuming panels packed once at
        // bind time must produce exactly the bits of packing per call —
        // for every policy (including naive, which falls through to the
        // tiled loop) and even when the panel blocking disagrees with
        // the policy's.
        for &(m, n, k) in &[(1, 1, 1), (5, 17, 9), (19, 1, 7), (33, 23, 21)] {
            let mut rng = Rng::new(0xB0D + (m * 1000 + n * 10 + k) as u64);
            let (a, b, c) = random_case(&mut rng, m, n, k);
            let want = run(KernelPolicy::Naive, &c, &a, &b, m, n, k);
            for pack_bs in [
                Blocking { mc: 8, kc: 4, nc: 16 },
                Blocking { mc: 5, kc: 3, nc: 7 },
                Blocking::default(),
            ] {
                let pre = PrepackedB::pack(&b, k, n, pack_bs);
                assert_eq!(pre.bytes(), k * n * 4, "panels store exactly B");
                for policy in [
                    KernelPolicy::Naive,
                    KernelPolicy::Tiled(pack_bs),
                    KernelPolicy::Tiled(Blocking { mc: 8, kc: 8, nc: 8 }), // mismatched
                    KernelPolicy::Threaded(pack_bs, 2),
                    KernelPolicy::Threaded(Blocking { mc: 16, kc: 2, nc: 4 }, 3),
                ] {
                    let mut got = c.clone();
                    matmul_b(policy, &mut got, &a, BOperand::Prepacked(&pre), m, n, k);
                    assert!(
                        want.iter().zip(&got).all(|(w, g)| w.to_bits() == g.to_bits()),
                        "prepacked {pack_bs:?} under {} drifted at {m}x{n}x{k}",
                        policy.name()
                    );
                }
            }
        }
    }

    #[test]
    fn prepacked_fused_tail_matches_raw_fused() {
        let (m, n, k) = (13, 9, 11);
        let mut rng = Rng::new(0xFB);
        let (a, b, c) = random_case(&mut rng, m, n, k);
        let pre = PrepackedB::pack(&b, k, n, Blocking { mc: 8, kc: 4, nc: 4 });
        let tail = |band: &mut [f32]| {
            for v in band.iter_mut() {
                *v = (*v + 1.0).max(0.0);
            }
        };
        for policy in [
            KernelPolicy::Naive,
            KernelPolicy::Tiled(Blocking { mc: 8, kc: 4, nc: 4 }),
            KernelPolicy::Threaded(Blocking { mc: 8, kc: 4, nc: 4 }, 2),
        ] {
            let mut want = c.clone();
            matmul_fused(policy, &mut want, &a, &b, m, n, k, &tail);
            let mut got = c.clone();
            matmul_fused_b(policy, &mut got, &a, BOperand::Prepacked(&pre), m, n, k, &tail);
            assert!(
                want.iter().zip(&got).all(|(w, g)| w.to_bits() == g.to_bits()),
                "fused prepacked drifted under {}",
                policy.name()
            );
        }
        // k == 0: the tail still runs exactly once over the untouched C.
        let pre0 = PrepackedB::pack(&[], 0, n, Blocking::default());
        let mut got = vec![-1.0f32; 2 * n];
        matmul_fused_b(
            KernelPolicy::Tiled(Blocking::default()),
            &mut got,
            &[],
            BOperand::Prepacked(&pre0),
            2,
            n,
            0,
            &tail,
        );
        assert!(got.iter().all(|&v| v == 0.0), "tail skipped on empty reduction");
    }

    /// Every ISA the dispatch layer can resolve on any host.
    fn all_isas() -> [Isa; 4] {
        [Isa::Portable, Isa::Avx2Fma, Isa::Avx512, Isa::Neon]
    }

    #[test]
    fn threaded_simd_is_bitwise_identical_to_single_thread_simd() {
        // Row banding partitions elements, never op sequences — so the
        // fma_relaxed class still gets deterministic, thread-count-
        // independent bits.  (Tolerance vs naive is pinned separately in
        // nanokernel::tests and tests/numerics_tolerance.rs.)
        for &(m, n, k) in &[(5, 17, 9), (33, 23, 21), (40, 40, 40)] {
            let mut rng = Rng::new(0x51D0 + (m * 1000 + n * 10 + k) as u64);
            let (a, b, c) = random_case(&mut rng, m, n, k);
            let bs = Blocking { mc: 8, kc: 4, nc: 16 };
            for isa in all_isas() {
                let want = run(KernelPolicy::Simd(bs, 1, isa), &c, &a, &b, m, n, k);
                for t in [2, 3] {
                    let got = run(KernelPolicy::Simd(bs, t, isa), &c, &a, &b, m, n, k);
                    assert!(
                        want.iter().zip(&got).all(|(w, g)| w.to_bits() == g.to_bits()),
                        "simd:{} bands={t} drifted from single-thread at {m}x{n}x{k}",
                        isa.name()
                    );
                }
            }
        }
    }

    #[test]
    fn prepacked_simd_is_bitwise_identical_to_raw_simd() {
        // Prepacking rearranges i/j layout only; the nanokernel reads the
        // same panel values in the same order either way.
        for &(m, n, k) in &[(5, 17, 9), (33, 23, 21)] {
            let mut rng = Rng::new(0x51D1 + (m * 1000 + n * 10 + k) as u64);
            let (a, b, c) = random_case(&mut rng, m, n, k);
            let bs = Blocking { mc: 8, kc: 4, nc: 16 };
            let pre = PrepackedB::pack(&b, k, n, bs);
            for isa in all_isas() {
                for t in [1, 2] {
                    let policy = KernelPolicy::Simd(bs, t, isa);
                    let want = run(policy, &c, &a, &b, m, n, k);
                    let mut got = c.clone();
                    matmul_b(policy, &mut got, &a, BOperand::Prepacked(&pre), m, n, k);
                    assert!(
                        want.iter().zip(&got).all(|(w, g)| w.to_bits() == g.to_bits()),
                        "prepacked simd:{} t={t} drifted at {m}x{n}x{k}",
                        isa.name()
                    );
                }
            }
        }
    }

    #[test]
    fn fused_tail_under_simd_runs_exactly_once_per_element() {
        // Same once-per-band tail contract as the scalar policies: fused
        // must equal unfused-then-tail, bitwise, per ISA and band count.
        for &(m, n, k) in &[(13, 9, 11), (33, 7, 21), (8, 8, 0)] {
            let mut rng = Rng::new(0x51D2 + (m * 100 + n * 10 + k) as u64);
            let (a, b, c) = random_case(&mut rng, m, n, k);
            let bs = Blocking { mc: 8, kc: 4, nc: 16 };
            for isa in all_isas() {
                for t in [1, 3] {
                    let policy = KernelPolicy::Simd(bs, t, isa);
                    let mut want = c.clone();
                    matmul(policy, &mut want, &a, &b, m, n, k);
                    for v in want.iter_mut() {
                        *v = (*v + 1.0).max(0.0);
                    }
                    let mut got = c.clone();
                    matmul_fused(policy, &mut got, &a, &b, m, n, k, &|band: &mut [f32]| {
                        for v in band.iter_mut() {
                            *v = (*v + 1.0).max(0.0);
                        }
                    });
                    assert!(
                        want.iter().zip(&got).all(|(w, g)| w.to_bits() == g.to_bits()),
                        "fused simd:{} t={t} drifted at {m}x{n}x{k}",
                        isa.name()
                    );
                }
            }
        }
    }

    #[test]
    fn degenerate_blocking_is_clamped() {
        // A zero block size must not hang or panic.
        let mut rng = Rng::new(3);
        let (a, b, c) = random_case(&mut rng, 9, 9, 9);
        let want = run(KernelPolicy::Naive, &c, &a, &b, 9, 9, 9);
        let got = run(
            KernelPolicy::Tiled(Blocking { mc: 0, kc: 0, nc: 0 }),
            &c,
            &a,
            &b,
            9,
            9,
            9,
        );
        assert_eq!(want, got);
    }
}
