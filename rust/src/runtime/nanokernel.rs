//! Explicit-SIMD nanokernels: the innermost register-tile bodies the
//! plan compiler's pass 6 ("isa") can lower to, replacing the scalar
//! [`crate::runtime::kernel`] micro kernel with `core::arch` intrinsics
//! (DESIGN.md §10).
//!
//! The paper's lowest lowering level maps a warp tile onto `mma.sync`
//! tensor-core ops; Thangamani et al. ("Library Liberation", arxiv
//! 2511.13764) and Kuzma et al. (arxiv 2305.18236) do the same on CPUs
//! with a small set of *nanokernels* — fixed register-shaped FMA bodies
//! selected by an explicit compiler pass rather than left to the
//! autovectorizer.  This module is that bottom layer for the host
//! engine:
//!
//! * [`Isa`] — the nanokernel instruction-set menu: AVX2+FMA (tuned
//!   4x24 ymm tile), AVX-512F (4x32 zmm tile, masked remainders), NEON
//!   (`float32x4_t` 4x16 tile on aarch64), and the portable fallback;
//! * [`detect`] — runtime CPU-feature probe
//!   (`is_x86_feature_detected!` / `target_arch`), overridable with
//!   `MLIR_GEMM_FORCE_ISA` for tests/CI;
//! * [`Nanokernel`] — the macro-kernel trait: one cache block over the
//!   exact packed-panel layouts `kernel::pack_a` / `kernel::pack_b`
//!   already produce (MR-interleaved A, row-major KCxNC B);
//! * [`gamma`] / [`verify_fma_relaxed`] — the `fma_relaxed` numerics
//!   contract: a condition-scaled error bound every SIMD kernel must
//!   satisfy against the naive oracle (see DESIGN.md §10 for the
//!   derivation), used by the tolerance harness *and* the benches.
//!
//! **Numerics.**  Each output element is accumulated as one chain of
//! fused multiply-adds: `x = fma(a_p, b_p, x)` over the k terms in some
//! fixed order.  A body may keep several *independent* accumulator
//! registers live (the k-unrolled tiles do) but never splits one
//! element's chain across registers, so every element still sees a
//! single rounded-FMA accumulation — the shape Higham's any-order bound
//! `gamma(k)` covers, regardless of term order (DESIGN.md §10).  That
//! deliberately breaks the engine's bit-exactness invariant, which is
//! why a plan lowered through here is classed `fma_relaxed`
//! (`crate::plan::NumericsClass`) and verified by tolerance, never by
//! bits.

use anyhow::{bail, Result};

use super::kernel::MR;

/// Env var overriding [`detect`]: `scalar` forces the scalar fallback
/// (pass 6 keeps the bit-exact kernel), an ISA name pins that ISA, an
/// empty value is treated as unset.  Used by the CI matrix leg and the
/// ISA-dispatch tests.
pub const FORCE_ISA_ENV: &str = "MLIR_GEMM_FORCE_ISA";

/// A nanokernel instruction set.  `Portable` is the always-available
/// safe-Rust 4-wide body; `Avx2Fma`, `Avx512`, and `Neon` are real
/// intrinsic kernels (4x24 ymm, 4x32 zmm, and 4x16 `float32x4_t` tiles
/// respectively), each degrading to the portable body through
/// [`kernel_for`] on hosts that lack the feature.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Isa {
    Portable,
    Avx2Fma,
    Avx512,
    Neon,
}

impl Isa {
    /// Canonical name, as recorded in plan JSON and metrics labels.
    pub fn name(&self) -> &'static str {
        match self {
            Isa::Portable => "portable",
            Isa::Avx2Fma => "avx2",
            Isa::Avx512 => "avx512",
            Isa::Neon => "neon",
        }
    }

    pub fn parse(text: &str) -> Result<Isa> {
        match text {
            "portable" => Ok(Isa::Portable),
            "avx2" => Ok(Isa::Avx2Fma),
            "avx512" => Ok(Isa::Avx512),
            "neon" => Ok(Isa::Neon),
            _ => bail!(
                "unknown isa {text:?} (portable | avx2 | avx512 | neon | scalar)"
            ),
        }
    }
}

/// Can `isa`'s body actually execute on this host?  Every arm probes
/// the *real* hardware requirement of its intrinsic body; a body that
/// would merely delegate no longer claims availability.
pub fn hw_available(isa: Isa) -> bool {
    match isa {
        Isa::Portable => true,
        Isa::Avx2Fma => {
            #[cfg(target_arch = "x86_64")]
            {
                is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma")
            }
            #[cfg(not(target_arch = "x86_64"))]
            {
                false
            }
        }
        Isa::Avx512 => {
            #[cfg(target_arch = "x86_64")]
            {
                is_x86_feature_detected!("avx512f")
            }
            #[cfg(not(target_arch = "x86_64"))]
            {
                false
            }
        }
        // NEON is architecturally guaranteed on aarch64 and absent
        // elsewhere; no runtime probe exists or is needed.
        Isa::Neon => cfg!(target_arch = "aarch64"),
    }
}

/// Runtime ISA selection for the plan compiler's pass 6:
/// `Ok(None)` means "stay scalar" (forced via `MLIR_GEMM_FORCE_ISA=scalar`),
/// `Ok(Some(isa))` the best nanokernel this host can run.  The
/// auto-probe walks the ladder widest-first — AVX-512F, then AVX2+FMA,
/// then NEON, then the portable body — so the shadow tuner's candidate
/// compilation naturally proposes the widest real kernel the host owns.
/// An unparseable override is an error, not a silent fallback.
pub fn detect() -> Result<Option<Isa>> {
    if let Ok(v) = std::env::var(FORCE_ISA_ENV) {
        let v = v.trim();
        if !v.is_empty() {
            if v == "scalar" {
                return Ok(None);
            }
            return Isa::parse(v).map(Some);
        }
    }
    for isa in [Isa::Avx512, Isa::Avx2Fma, Isa::Neon] {
        if hw_available(isa) {
            return Ok(Some(isa));
        }
    }
    Ok(Some(Isa::Portable))
}

/// One cache block of `out += Apanel @ Bpanel` over the packed layouts
/// of `kernel::pack_a` (MR-row interleaved, `apack[p * MR + i]`) and
/// `kernel::pack_b` (row-major, `bpack[p * ncb + j]`).  Same contract
/// as the scalar `macro_kernel`: rows `ic..ic+mcb`, columns
/// `jc..jc+ncb` of `out` (leading dimension `ldc`).  Implementations
/// may fuse each multiply-add and may apply the k terms in any fixed
/// order, but each output element must remain a *single* FMA chain —
/// never split one element's sum across partial accumulators that are
/// added together at the end.  Under that shape the `fma_relaxed`
/// bound (see [`verify_fma_relaxed`], Higham's any-order `gamma(k)`)
/// holds for every conforming body, and a body run twice on the same
/// host is deterministic.
pub trait Nanokernel: Sync {
    fn isa(&self) -> Isa;

    #[allow(clippy::too_many_arguments)]
    fn macro_kernel(
        &self,
        out: &mut [f32],
        ldc: usize,
        ic: usize,
        mcb: usize,
        jc: usize,
        ncb: usize,
        kcb: usize,
        apack: &[f32],
        bpack: &[f32],
    );
}

/// Resolve an ISA to its executable nanokernel body.  An ISA the host
/// cannot run degrades to the portable body — a plan compiled on (or
/// for) a bigger machine still executes correctly here, it just runs
/// the safe fallback.  Resolution is per-matmul-call, so the choice
/// costs one branch, not one probe per macro-kernel invocation
/// (`hw_available` memoizes inside `is_x86_feature_detected!`).
pub fn kernel_for(isa: Isa) -> &'static dyn Nanokernel {
    if !hw_available(isa) {
        return &PORTABLE;
    }
    match isa {
        Isa::Portable => &PORTABLE,
        Isa::Avx2Fma => &AVX2,
        Isa::Avx512 => &AVX512,
        Isa::Neon => &NEON,
    }
}

// ---------------------------------------------------------------------------
// Portable nanokernel: safe Rust, 4-wide accumulator tile
// ---------------------------------------------------------------------------

/// The always-available fallback: an MR x 4-lane accumulator tile in
/// safe Rust, plain multiply+add in increasing-k order.  On today's
/// compilers this is bit-identical to the scalar kernel (same ops, same
/// order) — but it is *contractually* `fma_relaxed`, so a future
/// `mul_add` or autovectorizer-friendly rewrite cannot silently break a
/// pinned promise.
pub struct PortableNano;

static PORTABLE: PortableNano = PortableNano;

/// 4 f32 lanes: the portable stand-in for one vector register.
const PW: usize = 4;

impl Nanokernel for PortableNano {
    fn isa(&self) -> Isa {
        Isa::Portable
    }

    fn macro_kernel(
        &self,
        out: &mut [f32],
        ldc: usize,
        ic: usize,
        mcb: usize,
        jc: usize,
        ncb: usize,
        kcb: usize,
        apack: &[f32],
        bpack: &[f32],
    ) {
        let full_panels = mcb / MR;
        for pi in 0..full_panels {
            let i0 = ic + pi * MR;
            let ap = &apack[pi * MR * kcb..(pi + 1) * MR * kcb];
            let mut j = 0;
            while j + PW <= ncb {
                // Load the MR x PW C tile into "registers", stream the
                // whole k block against it, store once.
                let mut acc = [[0.0f32; PW]; MR];
                for (r, lane) in acc.iter_mut().enumerate() {
                    let base = (i0 + r) * ldc + jc + j;
                    lane.copy_from_slice(&out[base..base + PW]);
                }
                for p in 0..kcb {
                    let brow = &bpack[p * ncb + j..p * ncb + j + PW];
                    for (r, lane) in acc.iter_mut().enumerate() {
                        let av = ap[p * MR + r];
                        for (x, &bv) in lane.iter_mut().zip(brow) {
                            *x += av * bv;
                        }
                    }
                }
                for (r, lane) in acc.iter().enumerate() {
                    let base = (i0 + r) * ldc + jc + j;
                    out[base..base + PW].copy_from_slice(lane);
                }
                j += PW;
            }
            while j < ncb {
                for r in 0..MR {
                    let idx = (i0 + r) * ldc + jc + j;
                    let mut x = out[idx];
                    for p in 0..kcb {
                        x += ap[p * MR + r] * bpack[p * ncb + j];
                    }
                    out[idx] = x;
                }
                j += 1;
            }
        }
        // Ragged row tail (mcb % MR != 0): scalar, same k order.
        for i in full_panels * MR..mcb {
            let (pi, ir) = (i / MR, i % MR);
            let ap = &apack[pi * MR * kcb..];
            for j in 0..ncb {
                let idx = (ic + i) * ldc + jc + j;
                let mut x = out[idx];
                for p in 0..kcb {
                    x += ap[p * MR + ir] * bpack[p * ncb + j];
                }
                out[idx] = x;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// AVX2+FMA nanokernel: tuned 4x24 register tile (12 ymm accumulators)
// ---------------------------------------------------------------------------

/// The tuned intrinsic kernel: a 4x24 C tile held in 12 ymm registers
/// across the whole k block — per k step, 3 B loads + 4 A broadcasts +
/// 12 `vfmadd231ps` (12 FMAs amortizing 7 non-FMA ops, vs 8:6 for the
/// original 4x16 tile), k-unrolled by 4 with a software prefetch of
/// the panel rows 4 k-steps ahead.  Falls back to [`PortableNano`] off
/// x86-64 (only reachable through a deliberately mis-resolved call;
/// [`kernel_for`] never hands this body to a host without AVX2+FMA).
pub struct Avx2FmaNano;

static AVX2: Avx2FmaNano = Avx2FmaNano;

impl Nanokernel for Avx2FmaNano {
    fn isa(&self) -> Isa {
        Isa::Avx2Fma
    }

    #[allow(unused_variables)]
    fn macro_kernel(
        &self,
        out: &mut [f32],
        ldc: usize,
        ic: usize,
        mcb: usize,
        jc: usize,
        ncb: usize,
        kcb: usize,
        apack: &[f32],
        bpack: &[f32],
    ) {
        #[cfg(target_arch = "x86_64")]
        {
            debug_assert!(hw_available(Isa::Avx2Fma), "AVX2 body on a non-AVX2 host");
            // SAFETY: kernel_for() only resolves to this body when the
            // host reports avx2+fma; slice extents are checked inside.
            unsafe {
                avx2::macro_kernel(out, ldc, ic, mcb, jc, ncb, kcb, apack, bpack);
            }
        }
        #[cfg(not(target_arch = "x86_64"))]
        PORTABLE.macro_kernel(out, ldc, ic, mcb, jc, ncb, kcb, apack, bpack);
    }
}

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use core::arch::x86_64::*;

    use super::MR;

    // The 12-accumulator layout below hard-codes four C rows.
    const _: () = assert!(MR == 4, "the AVX2 nanokernel is shaped for MR == 4");

    /// The tuned 4x24 FMA macro kernel.  The accumulation per output
    /// element is `x = fma(a_p, b_p, x)` for p = 0..kcb in increasing
    /// order — one chain per element, each multiply-add fused (single
    /// rounding).  The k loop is unrolled by 4 (the unroll repeats the
    /// step body; it never splits a chain) and prefetches the A/B
    /// panel rows 4 k-steps ahead.  The j remainders (8-wide, then
    /// scalar) and the ragged row tail use the original narrower
    /// bodies / scalar `f32::mul_add`, which compiles to `vfmadd`
    /// inside this `target_feature` fn — the whole block has uniform
    /// one-rounding-per-term semantics.
    ///
    /// # Safety
    /// Caller must ensure the host supports avx2+fma.
    #[target_feature(enable = "avx2,fma")]
    #[allow(clippy::too_many_arguments)]
    pub unsafe fn macro_kernel(
        out: &mut [f32],
        ldc: usize,
        ic: usize,
        mcb: usize,
        jc: usize,
        ncb: usize,
        kcb: usize,
        apack: &[f32],
        bpack: &[f32],
    ) {
        let full_panels = mcb / MR;
        for pi in 0..full_panels {
            let i0 = ic + pi * MR;
            let ap = &apack[pi * MR * kcb..(pi + 1) * MR * kcb];
            // Bounds for the whole row quad once; the pointer math below
            // stays inside out[i0*ldc .. (i0+3)*ldc + jc + ncb].
            assert!((i0 + MR - 1) * ldc + jc + ncb <= out.len(), "C tile bounds");
            assert!(kcb * ncb <= bpack.len(), "B panel bounds");
            let obase = out.as_mut_ptr();
            let o0 = obase.add(i0 * ldc + jc);
            let o1 = obase.add((i0 + 1) * ldc + jc);
            let o2 = obase.add((i0 + 2) * ldc + jc);
            let o3 = obase.add((i0 + 3) * ldc + jc);
            let bbase = bpack.as_ptr();
            let mut j = 0usize;
            while j + 24 <= ncb {
                let mut c00 = _mm256_loadu_ps(o0.add(j));
                let mut c01 = _mm256_loadu_ps(o0.add(j + 8));
                let mut c02 = _mm256_loadu_ps(o0.add(j + 16));
                let mut c10 = _mm256_loadu_ps(o1.add(j));
                let mut c11 = _mm256_loadu_ps(o1.add(j + 8));
                let mut c12 = _mm256_loadu_ps(o1.add(j + 16));
                let mut c20 = _mm256_loadu_ps(o2.add(j));
                let mut c21 = _mm256_loadu_ps(o2.add(j + 8));
                let mut c22 = _mm256_loadu_ps(o2.add(j + 16));
                let mut c30 = _mm256_loadu_ps(o3.add(j));
                let mut c31 = _mm256_loadu_ps(o3.add(j + 8));
                let mut c32 = _mm256_loadu_ps(o3.add(j + 16));
                let mut bp = bbase.add(j);
                let mut apk = ap.as_ptr();
                let mut p = 0usize;
                macro_rules! step24 {
                    () => {{
                        let b0 = _mm256_loadu_ps(bp);
                        let b1 = _mm256_loadu_ps(bp.add(8));
                        let b2 = _mm256_loadu_ps(bp.add(16));
                        let mut aa = _mm256_set1_ps(*apk);
                        c00 = _mm256_fmadd_ps(aa, b0, c00);
                        c01 = _mm256_fmadd_ps(aa, b1, c01);
                        c02 = _mm256_fmadd_ps(aa, b2, c02);
                        aa = _mm256_set1_ps(*apk.add(1));
                        c10 = _mm256_fmadd_ps(aa, b0, c10);
                        c11 = _mm256_fmadd_ps(aa, b1, c11);
                        c12 = _mm256_fmadd_ps(aa, b2, c12);
                        aa = _mm256_set1_ps(*apk.add(2));
                        c20 = _mm256_fmadd_ps(aa, b0, c20);
                        c21 = _mm256_fmadd_ps(aa, b1, c21);
                        c22 = _mm256_fmadd_ps(aa, b2, c22);
                        aa = _mm256_set1_ps(*apk.add(3));
                        c30 = _mm256_fmadd_ps(aa, b0, c30);
                        c31 = _mm256_fmadd_ps(aa, b1, c31);
                        c32 = _mm256_fmadd_ps(aa, b2, c32);
                        bp = bp.add(ncb);
                        apk = apk.add(MR);
                    }};
                }
                while p + 4 <= kcb {
                    // wrapping_add: near the end of the panel these
                    // prefetch addresses run past the pack buffer; the
                    // instruction is architecturally fault-free but the
                    // pointer must not be formed with `add`'s in-bounds
                    // contract.
                    _mm_prefetch::<_MM_HINT_T0>(bp.wrapping_add(4 * ncb).cast());
                    _mm_prefetch::<_MM_HINT_T0>(apk.wrapping_add(4 * MR).cast());
                    step24!();
                    step24!();
                    step24!();
                    step24!();
                    p += 4;
                }
                while p < kcb {
                    step24!();
                    p += 1;
                }
                _mm256_storeu_ps(o0.add(j), c00);
                _mm256_storeu_ps(o0.add(j + 8), c01);
                _mm256_storeu_ps(o0.add(j + 16), c02);
                _mm256_storeu_ps(o1.add(j), c10);
                _mm256_storeu_ps(o1.add(j + 8), c11);
                _mm256_storeu_ps(o1.add(j + 16), c12);
                _mm256_storeu_ps(o2.add(j), c20);
                _mm256_storeu_ps(o2.add(j + 8), c21);
                _mm256_storeu_ps(o2.add(j + 16), c22);
                _mm256_storeu_ps(o3.add(j), c30);
                _mm256_storeu_ps(o3.add(j + 8), c31);
                _mm256_storeu_ps(o3.add(j + 16), c32);
                j += 24;
            }
            while j + 8 <= ncb {
                let mut c0 = _mm256_loadu_ps(o0.add(j));
                let mut c1 = _mm256_loadu_ps(o1.add(j));
                let mut c2 = _mm256_loadu_ps(o2.add(j));
                let mut c3 = _mm256_loadu_ps(o3.add(j));
                let mut bp = bbase.add(j);
                let mut apk = ap.as_ptr();
                for _p in 0..kcb {
                    let b0 = _mm256_loadu_ps(bp);
                    c0 = _mm256_fmadd_ps(_mm256_set1_ps(*apk), b0, c0);
                    c1 = _mm256_fmadd_ps(_mm256_set1_ps(*apk.add(1)), b0, c1);
                    c2 = _mm256_fmadd_ps(_mm256_set1_ps(*apk.add(2)), b0, c2);
                    c3 = _mm256_fmadd_ps(_mm256_set1_ps(*apk.add(3)), b0, c3);
                    bp = bp.add(ncb);
                    apk = apk.add(MR);
                }
                _mm256_storeu_ps(o0.add(j), c0);
                _mm256_storeu_ps(o1.add(j), c1);
                _mm256_storeu_ps(o2.add(j), c2);
                _mm256_storeu_ps(o3.add(j), c3);
                j += 8;
            }
            while j < ncb {
                for r in 0..MR {
                    let op = obase.add((i0 + r) * ldc + jc + j);
                    let mut x = *op;
                    for p in 0..kcb {
                        x = ap[p * MR + r].mul_add(*bbase.add(p * ncb + j), x);
                    }
                    *op = x;
                }
                j += 1;
            }
        }
        for i in full_panels * MR..mcb {
            let (pi, ir) = (i / MR, i % MR);
            let ap = &apack[pi * MR * kcb..];
            for j in 0..ncb {
                let idx = (ic + i) * ldc + jc + j;
                let mut x = out[idx];
                for p in 0..kcb {
                    x = ap[p * MR + ir].mul_add(bpack[p * ncb + j], x);
                }
                out[idx] = x;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// AVX-512F nanokernel: 4x32 register tile (8 zmm accumulators)
// ---------------------------------------------------------------------------

/// The AVX-512F kernel: a 4x32 C tile held in 8 zmm registers (4 rows
/// x 2 zmm of 16 lanes) across the whole k block — per k step, 2 B
/// loads + 4 A broadcasts + 8 `vfmadd231ps`, k-unrolled by 4 with
/// prefetch like the AVX2 body.  The j remainder runs 16 masked lanes
/// at a time (`__mmask16` maskz load / mask store), so partial columns
/// never touch memory outside the tile; ragged rows fall back to
/// scalar `mul_add`.  Falls back to [`PortableNano`] off x86-64;
/// [`kernel_for`] never hands this body to a host without avx512f.
pub struct Avx512Nano;

static AVX512: Avx512Nano = Avx512Nano;

impl Nanokernel for Avx512Nano {
    fn isa(&self) -> Isa {
        Isa::Avx512
    }

    #[allow(unused_variables)]
    fn macro_kernel(
        &self,
        out: &mut [f32],
        ldc: usize,
        ic: usize,
        mcb: usize,
        jc: usize,
        ncb: usize,
        kcb: usize,
        apack: &[f32],
        bpack: &[f32],
    ) {
        #[cfg(target_arch = "x86_64")]
        {
            debug_assert!(hw_available(Isa::Avx512), "AVX-512 body on a non-avx512f host");
            // SAFETY: kernel_for() only resolves to this body when the
            // host reports avx512f; slice extents are checked inside.
            unsafe {
                avx512::macro_kernel(out, ldc, ic, mcb, jc, ncb, kcb, apack, bpack);
            }
        }
        #[cfg(not(target_arch = "x86_64"))]
        PORTABLE.macro_kernel(out, ldc, ic, mcb, jc, ncb, kcb, apack, bpack);
    }
}

#[cfg(target_arch = "x86_64")]
mod avx512 {
    use core::arch::x86_64::*;

    use super::MR;

    // The 8-accumulator layout below hard-codes four C rows.
    const _: () = assert!(MR == 4, "the AVX-512 nanokernel is shaped for MR == 4");

    /// The 4x32 zmm FMA macro kernel.  Per output element one FMA
    /// chain in increasing-k order; the k-unroll repeats the step
    /// body without splitting any chain (see the module numerics note).
    ///
    /// # Safety
    /// Caller must ensure the host supports avx512f.
    #[target_feature(enable = "avx512f")]
    #[allow(clippy::too_many_arguments)]
    pub unsafe fn macro_kernel(
        out: &mut [f32],
        ldc: usize,
        ic: usize,
        mcb: usize,
        jc: usize,
        ncb: usize,
        kcb: usize,
        apack: &[f32],
        bpack: &[f32],
    ) {
        let full_panels = mcb / MR;
        for pi in 0..full_panels {
            let i0 = ic + pi * MR;
            let ap = &apack[pi * MR * kcb..(pi + 1) * MR * kcb];
            assert!((i0 + MR - 1) * ldc + jc + ncb <= out.len(), "C tile bounds");
            assert!(kcb * ncb <= bpack.len(), "B panel bounds");
            let obase = out.as_mut_ptr();
            let o0 = obase.add(i0 * ldc + jc);
            let o1 = obase.add((i0 + 1) * ldc + jc);
            let o2 = obase.add((i0 + 2) * ldc + jc);
            let o3 = obase.add((i0 + 3) * ldc + jc);
            let bbase = bpack.as_ptr();
            let mut j = 0usize;
            while j + 32 <= ncb {
                let mut c00 = _mm512_loadu_ps(o0.add(j));
                let mut c01 = _mm512_loadu_ps(o0.add(j + 16));
                let mut c10 = _mm512_loadu_ps(o1.add(j));
                let mut c11 = _mm512_loadu_ps(o1.add(j + 16));
                let mut c20 = _mm512_loadu_ps(o2.add(j));
                let mut c21 = _mm512_loadu_ps(o2.add(j + 16));
                let mut c30 = _mm512_loadu_ps(o3.add(j));
                let mut c31 = _mm512_loadu_ps(o3.add(j + 16));
                let mut bp = bbase.add(j);
                let mut apk = ap.as_ptr();
                let mut p = 0usize;
                macro_rules! step512 {
                    () => {{
                        let b0 = _mm512_loadu_ps(bp);
                        let b1 = _mm512_loadu_ps(bp.add(16));
                        let a0 = _mm512_set1_ps(*apk);
                        let a1 = _mm512_set1_ps(*apk.add(1));
                        let a2 = _mm512_set1_ps(*apk.add(2));
                        let a3 = _mm512_set1_ps(*apk.add(3));
                        c00 = _mm512_fmadd_ps(a0, b0, c00);
                        c01 = _mm512_fmadd_ps(a0, b1, c01);
                        c10 = _mm512_fmadd_ps(a1, b0, c10);
                        c11 = _mm512_fmadd_ps(a1, b1, c11);
                        c20 = _mm512_fmadd_ps(a2, b0, c20);
                        c21 = _mm512_fmadd_ps(a2, b1, c21);
                        c30 = _mm512_fmadd_ps(a3, b0, c30);
                        c31 = _mm512_fmadd_ps(a3, b1, c31);
                        bp = bp.add(ncb);
                        apk = apk.add(MR);
                    }};
                }
                while p + 4 <= kcb {
                    // wrapping_add: see the AVX2 body — prefetch
                    // addresses may run past the pack buffer.
                    _mm_prefetch::<_MM_HINT_T0>(bp.wrapping_add(4 * ncb).cast());
                    _mm_prefetch::<_MM_HINT_T0>(bp.wrapping_add(4 * ncb + 16).cast());
                    _mm_prefetch::<_MM_HINT_T0>(apk.wrapping_add(4 * MR).cast());
                    step512!();
                    step512!();
                    step512!();
                    step512!();
                    p += 4;
                }
                while p < kcb {
                    step512!();
                    p += 1;
                }
                _mm512_storeu_ps(o0.add(j), c00);
                _mm512_storeu_ps(o0.add(j + 16), c01);
                _mm512_storeu_ps(o1.add(j), c10);
                _mm512_storeu_ps(o1.add(j + 16), c11);
                _mm512_storeu_ps(o2.add(j), c20);
                _mm512_storeu_ps(o2.add(j + 16), c21);
                _mm512_storeu_ps(o3.add(j), c30);
                _mm512_storeu_ps(o3.add(j + 16), c31);
                j += 32;
            }
            while j < ncb {
                let rem = ncb - j;
                let msk: __mmask16 =
                    if rem >= 16 { 0xFFFF } else { (1u16 << rem) - 1 };
                let mut c0 = _mm512_maskz_loadu_ps(msk, o0.add(j));
                let mut c1 = _mm512_maskz_loadu_ps(msk, o1.add(j));
                let mut c2 = _mm512_maskz_loadu_ps(msk, o2.add(j));
                let mut c3 = _mm512_maskz_loadu_ps(msk, o3.add(j));
                let mut bp = bbase.add(j);
                let mut apk = ap.as_ptr();
                for _p in 0..kcb {
                    let b0 = _mm512_maskz_loadu_ps(msk, bp);
                    c0 = _mm512_fmadd_ps(_mm512_set1_ps(*apk), b0, c0);
                    c1 = _mm512_fmadd_ps(_mm512_set1_ps(*apk.add(1)), b0, c1);
                    c2 = _mm512_fmadd_ps(_mm512_set1_ps(*apk.add(2)), b0, c2);
                    c3 = _mm512_fmadd_ps(_mm512_set1_ps(*apk.add(3)), b0, c3);
                    bp = bp.add(ncb);
                    apk = apk.add(MR);
                }
                _mm512_mask_storeu_ps(o0.add(j), msk, c0);
                _mm512_mask_storeu_ps(o1.add(j), msk, c1);
                _mm512_mask_storeu_ps(o2.add(j), msk, c2);
                _mm512_mask_storeu_ps(o3.add(j), msk, c3);
                j += 16;
            }
        }
        for i in full_panels * MR..mcb {
            let (pi, ir) = (i / MR, i % MR);
            let ap = &apack[pi * MR * kcb..];
            for j in 0..ncb {
                let idx = (ic + i) * ldc + jc + j;
                let mut x = out[idx];
                for p in 0..kcb {
                    x = ap[p * MR + ir].mul_add(bpack[p * ncb + j], x);
                }
                out[idx] = x;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// NEON nanokernel: 4x16 register tile (16 float32x4_t accumulators)
// ---------------------------------------------------------------------------

/// The NEON kernel: a 4x16 C tile held in 16 `float32x4_t` registers
/// (4 rows x 4 vectors of 4 lanes) across the whole k block — per k
/// step, 4 B loads + 4 A broadcasts + 16 `vfmaq_f32`.  The j
/// remainders (4-wide, then scalar) and ragged rows use `mul_add`.
/// Off aarch64 this delegates to [`PortableNano`] — and
/// [`hw_available`] reports NEON unavailable there, so [`kernel_for`]
/// routes around it anyway.
pub struct NeonNano;

static NEON: NeonNano = NeonNano;

impl Nanokernel for NeonNano {
    fn isa(&self) -> Isa {
        Isa::Neon
    }

    #[allow(unused_variables)]
    fn macro_kernel(
        &self,
        out: &mut [f32],
        ldc: usize,
        ic: usize,
        mcb: usize,
        jc: usize,
        ncb: usize,
        kcb: usize,
        apack: &[f32],
        bpack: &[f32],
    ) {
        #[cfg(target_arch = "aarch64")]
        {
            // SAFETY: NEON is architecturally guaranteed on aarch64;
            // slice extents are checked inside.
            unsafe {
                neon::macro_kernel(out, ldc, ic, mcb, jc, ncb, kcb, apack, bpack);
            }
        }
        #[cfg(not(target_arch = "aarch64"))]
        PORTABLE.macro_kernel(out, ldc, ic, mcb, jc, ncb, kcb, apack, bpack);
    }
}

#[cfg(target_arch = "aarch64")]
mod neon {
    use core::arch::aarch64::*;

    use super::MR;

    // The 16-accumulator layout below hard-codes four C rows.
    const _: () = assert!(MR == 4, "the NEON nanokernel is shaped for MR == 4");

    /// The 4x16 `float32x4_t` FMA macro kernel.  Per output element one
    /// FMA chain in increasing-k order (see the module numerics note).
    ///
    /// # Safety
    /// aarch64-only (guaranteed NEON); pointer math is bounds-checked
    /// per row quad below.
    #[target_feature(enable = "neon")]
    #[allow(clippy::too_many_arguments)]
    pub unsafe fn macro_kernel(
        out: &mut [f32],
        ldc: usize,
        ic: usize,
        mcb: usize,
        jc: usize,
        ncb: usize,
        kcb: usize,
        apack: &[f32],
        bpack: &[f32],
    ) {
        let full_panels = mcb / MR;
        for pi in 0..full_panels {
            let i0 = ic + pi * MR;
            let ap = &apack[pi * MR * kcb..(pi + 1) * MR * kcb];
            assert!((i0 + MR - 1) * ldc + jc + ncb <= out.len(), "C tile bounds");
            assert!(kcb * ncb <= bpack.len(), "B panel bounds");
            let obase = out.as_mut_ptr();
            let o0 = obase.add(i0 * ldc + jc);
            let o1 = obase.add((i0 + 1) * ldc + jc);
            let o2 = obase.add((i0 + 2) * ldc + jc);
            let o3 = obase.add((i0 + 3) * ldc + jc);
            let bbase = bpack.as_ptr();
            let mut j = 0usize;
            while j + 16 <= ncb {
                let mut c00 = vld1q_f32(o0.add(j));
                let mut c01 = vld1q_f32(o0.add(j + 4));
                let mut c02 = vld1q_f32(o0.add(j + 8));
                let mut c03 = vld1q_f32(o0.add(j + 12));
                let mut c10 = vld1q_f32(o1.add(j));
                let mut c11 = vld1q_f32(o1.add(j + 4));
                let mut c12 = vld1q_f32(o1.add(j + 8));
                let mut c13 = vld1q_f32(o1.add(j + 12));
                let mut c20 = vld1q_f32(o2.add(j));
                let mut c21 = vld1q_f32(o2.add(j + 4));
                let mut c22 = vld1q_f32(o2.add(j + 8));
                let mut c23 = vld1q_f32(o2.add(j + 12));
                let mut c30 = vld1q_f32(o3.add(j));
                let mut c31 = vld1q_f32(o3.add(j + 4));
                let mut c32 = vld1q_f32(o3.add(j + 8));
                let mut c33 = vld1q_f32(o3.add(j + 12));
                let mut bp = bbase.add(j);
                let mut apk = ap.as_ptr();
                for _p in 0..kcb {
                    let b0 = vld1q_f32(bp);
                    let b1 = vld1q_f32(bp.add(4));
                    let b2 = vld1q_f32(bp.add(8));
                    let b3 = vld1q_f32(bp.add(12));
                    let mut aa = vdupq_n_f32(*apk);
                    c00 = vfmaq_f32(c00, aa, b0);
                    c01 = vfmaq_f32(c01, aa, b1);
                    c02 = vfmaq_f32(c02, aa, b2);
                    c03 = vfmaq_f32(c03, aa, b3);
                    aa = vdupq_n_f32(*apk.add(1));
                    c10 = vfmaq_f32(c10, aa, b0);
                    c11 = vfmaq_f32(c11, aa, b1);
                    c12 = vfmaq_f32(c12, aa, b2);
                    c13 = vfmaq_f32(c13, aa, b3);
                    aa = vdupq_n_f32(*apk.add(2));
                    c20 = vfmaq_f32(c20, aa, b0);
                    c21 = vfmaq_f32(c21, aa, b1);
                    c22 = vfmaq_f32(c22, aa, b2);
                    c23 = vfmaq_f32(c23, aa, b3);
                    aa = vdupq_n_f32(*apk.add(3));
                    c30 = vfmaq_f32(c30, aa, b0);
                    c31 = vfmaq_f32(c31, aa, b1);
                    c32 = vfmaq_f32(c32, aa, b2);
                    c33 = vfmaq_f32(c33, aa, b3);
                    bp = bp.add(ncb);
                    apk = apk.add(MR);
                }
                vst1q_f32(o0.add(j), c00);
                vst1q_f32(o0.add(j + 4), c01);
                vst1q_f32(o0.add(j + 8), c02);
                vst1q_f32(o0.add(j + 12), c03);
                vst1q_f32(o1.add(j), c10);
                vst1q_f32(o1.add(j + 4), c11);
                vst1q_f32(o1.add(j + 8), c12);
                vst1q_f32(o1.add(j + 12), c13);
                vst1q_f32(o2.add(j), c20);
                vst1q_f32(o2.add(j + 4), c21);
                vst1q_f32(o2.add(j + 8), c22);
                vst1q_f32(o2.add(j + 12), c23);
                vst1q_f32(o3.add(j), c30);
                vst1q_f32(o3.add(j + 4), c31);
                vst1q_f32(o3.add(j + 8), c32);
                vst1q_f32(o3.add(j + 12), c33);
                j += 16;
            }
            while j + 4 <= ncb {
                let mut c0 = vld1q_f32(o0.add(j));
                let mut c1 = vld1q_f32(o1.add(j));
                let mut c2 = vld1q_f32(o2.add(j));
                let mut c3 = vld1q_f32(o3.add(j));
                let mut bp = bbase.add(j);
                let mut apk = ap.as_ptr();
                for _p in 0..kcb {
                    let b0 = vld1q_f32(bp);
                    c0 = vfmaq_f32(c0, vdupq_n_f32(*apk), b0);
                    c1 = vfmaq_f32(c1, vdupq_n_f32(*apk.add(1)), b0);
                    c2 = vfmaq_f32(c2, vdupq_n_f32(*apk.add(2)), b0);
                    c3 = vfmaq_f32(c3, vdupq_n_f32(*apk.add(3)), b0);
                    bp = bp.add(ncb);
                    apk = apk.add(MR);
                }
                vst1q_f32(o0.add(j), c0);
                vst1q_f32(o1.add(j), c1);
                vst1q_f32(o2.add(j), c2);
                vst1q_f32(o3.add(j), c3);
                j += 4;
            }
            while j < ncb {
                for r in 0..MR {
                    let op = obase.add((i0 + r) * ldc + jc + j);
                    let mut x = *op;
                    for p in 0..kcb {
                        x = ap[p * MR + r].mul_add(*bbase.add(p * ncb + j), x);
                    }
                    *op = x;
                }
                j += 1;
            }
        }
        for i in full_panels * MR..mcb {
            let (pi, ir) = (i / MR, i % MR);
            let ap = &apack[pi * MR * kcb..];
            for j in 0..ncb {
                let idx = (ic + i) * ldc + jc + j;
                let mut x = out[idx];
                for p in 0..kcb {
                    x = ap[p * MR + ir].mul_add(bpack[p * ncb + j], x);
                }
                out[idx] = x;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// The fma_relaxed tolerance contract
// ---------------------------------------------------------------------------

/// Higham's gamma_n for f32: `n*u / (1 - n*u)` with unit roundoff
/// `u = 2^-24`.  Bounds the relative error of an n-term dot product
/// evaluated in any order with rounded (or fused) multiply-adds.
pub fn gamma(terms: usize) -> f64 {
    const U: f64 = (f32::EPSILON as f64) / 2.0; // 2^-24
    let nu = terms as f64 * U;
    assert!(nu < 1.0, "gamma({terms}) out of range");
    nu / (1.0 - nu)
}

/// Distance between two f32s in units in the last place (monotone bit
/// mapping; 0 = bit-identical).  Reported by tolerance failures so a
/// drift reads as "N ulp", not raw decimals.
pub fn ulp_distance(x: f32, y: f32) -> u64 {
    fn ordered(v: f32) -> i64 {
        let b = v.to_bits();
        if b & 0x8000_0000 != 0 {
            -((b & 0x7FFF_FFFF) as i64)
        } else {
            b as i64
        }
    }
    (ordered(x) - ordered(y)).unsigned_abs()
}

/// Verify `got` (an `fma_relaxed` kernel's output for
/// `C + A@B [+ bias]`) against `want` (the bit-exact naive oracle)
/// under the condition-scaled bound derived in DESIGN.md §10:
///
/// ```text
/// |got[i,j] - want[i,j]| <= 2 * gamma(k + 2) * scale[i,j] + tiny
/// scale[i,j] = |c[i,j]| + sum_p |a[i,p]| * |b[p,j]|  (+ |bias[j]|)
/// ```
///
/// Both sides approximate the same exact sum; each carries at most
/// `gamma(k+2) * scale` of rounding error (k product terms + the C seed
/// + the bias term), so their difference is bounded by twice that.  The
/// scale is the *absolute-value* reduction — a raw ULP bound against
/// the oracle would be unbounded under cancellation, which is exactly
/// why the contract is condition-scaled (DESIGN.md §10).  `tiny`
/// absorbs subnormal scales.
///
/// Returns the maximum observed ULP distance (for bench reporting);
/// errors with element, ULP distance, and bound on the first violation.
#[allow(clippy::too_many_arguments)]
pub fn verify_fma_relaxed(
    got: &[f32],
    want: &[f32],
    a: &[f32],
    b: &[f32],
    c: &[f32],
    bias: Option<&[f32]>,
    m: usize,
    n: usize,
    k: usize,
) -> Result<u64> {
    assert_eq!(got.len(), m * n, "got length");
    assert_eq!(want.len(), m * n, "want length");
    assert_eq!(a.len(), m * k, "A length");
    assert_eq!(b.len(), k * n, "B length");
    assert_eq!(c.len(), m * n, "C length");
    const TINY: f64 = 1e-30;
    // The scale matrix is itself a naive i-k-j sweep, over |.| values.
    let mut scale: Vec<f64> = c.iter().map(|v| f64::from(v.abs())).collect();
    for i in 0..m {
        for (p, &av) in a[i * k..(i + 1) * k].iter().enumerate() {
            let aa = f64::from(av.abs());
            let brow = &b[p * n..(p + 1) * n];
            for (s, &bv) in scale[i * n..(i + 1) * n].iter_mut().zip(brow) {
                *s += aa * f64::from(bv.abs());
            }
        }
    }
    if let Some(bias) = bias {
        assert_eq!(bias.len(), n, "bias length");
        for row in scale.chunks_mut(n) {
            for (s, &bv) in row.iter_mut().zip(bias) {
                *s += f64::from(bv.abs());
            }
        }
    }
    let g = 2.0 * gamma(k + 2);
    let mut max_ulp = 0u64;
    for (idx, ((&gv, &wv), &s)) in got.iter().zip(want).zip(&scale).enumerate() {
        let err = (f64::from(gv) - f64::from(wv)).abs();
        let bound = g * s + TINY;
        if err > bound {
            bail!(
                "fma_relaxed tolerance violated at element {idx} \
                 ({}, {} = {} ulp apart): |diff| {err:.3e} > bound {bound:.3e} \
                 (scale {s:.3e}, 2*gamma(k+2) {g:.3e}, k {k})",
                gv,
                wv,
                ulp_distance(gv, wv)
            );
        }
        max_ulp = max_ulp.max(ulp_distance(gv, wv));
    }
    Ok(max_ulp)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::kernel::{matmul, KernelPolicy};
    use crate::util::prng::Rng;

    #[test]
    fn isa_names_round_trip() {
        for isa in [Isa::Portable, Isa::Avx2Fma, Isa::Avx512, Isa::Neon] {
            assert_eq!(Isa::parse(isa.name()).unwrap(), isa);
        }
        assert!(Isa::parse("sse9").is_err());
        assert!(Isa::parse("scalar").is_err(), "scalar is a detect() outcome, not an Isa");
    }

    #[test]
    fn kernel_for_degrades_to_portable_when_unavailable() {
        // Whatever the host, every ISA resolves to a runnable body.
        for isa in [Isa::Portable, Isa::Avx2Fma, Isa::Avx512, Isa::Neon] {
            let nano = kernel_for(isa);
            assert!(
                hw_available(nano.isa()),
                "{:?} resolved to a body the host cannot run",
                isa
            );
        }
        assert_eq!(kernel_for(Isa::Portable).isa(), Isa::Portable);
    }

    #[test]
    fn gamma_is_small_and_monotone() {
        assert!(gamma(1) > 0.0);
        assert!(gamma(512) < 1e-4);
        assert!(gamma(8) < gamma(9));
        // 512-term f32 dot product: ~3e-5 relative.
        assert!((gamma(514) - 514.0 * 5.96e-8).abs() < 1e-6);
    }

    #[test]
    fn ulp_distance_counts_representable_steps() {
        assert_eq!(ulp_distance(1.0, 1.0), 0);
        assert_eq!(ulp_distance(1.0, f32::from_bits(1.0f32.to_bits() + 1)), 1);
        assert_eq!(ulp_distance(-1.0, f32::from_bits((-1.0f32).to_bits() + 1)), 1);
        assert_eq!(ulp_distance(0.0, -0.0), 0);
        assert!(ulp_distance(1.0, -1.0) > 1 << 24);
    }

    /// Drive one nanokernel through the full packed-panel path by
    /// running the public matmul with a Simd policy pinned to it.
    /// nc = 64 so the widest register tiles (24-wide ymm, 32-wide zmm)
    /// actually run, with every remainder ladder reachable via ragged
    /// n; kc = 6 exercises the k-unroll epilogue (6 = 4 + 2).
    fn simd_vs_naive(isa: Isa, m: usize, n: usize, k: usize, seed: u64) -> u64 {
        use crate::runtime::kernel::Blocking;
        let mut rng = Rng::new(seed);
        let a = rng.normal_matrix(m, k);
        let b = rng.normal_matrix(k, n);
        let c = rng.normal_matrix(m, n);
        let mut want = c.clone();
        matmul(KernelPolicy::Naive, &mut want, &a, &b, m, n, k);
        let mut got = c.clone();
        matmul(
            KernelPolicy::Simd(Blocking { mc: 8, kc: 6, nc: 64 }, 1, isa),
            &mut got,
            &a,
            &b,
            m,
            n,
            k,
        );
        verify_fma_relaxed(&got, &want, &a, &b, &c, None, m, n, k).unwrap_or_else(|e| {
            panic!("{:?} at {m}x{n}x{k}: {e}", isa);
        })
    }

    #[test]
    fn every_nanokernel_meets_the_tolerance_contract_on_ragged_shapes() {
        for &(m, n, k) in &[
            (1, 1, 1),
            (1, 17, 5),
            (19, 1, 7),
            (4, 16, 8),
            (5, 17, 9),
            (4, 35, 12), // 24-wide + 8-wide + scalar j remainders in one row
            (33, 7, 21),
            (40, 40, 40),
            (5, 57, 13), // zmm main + full-mask + partial-mask j steps
            (7, 100, 30), // every ladder rung incl. ragged rows + k-unroll
        ] {
            for isa in [Isa::Portable, Isa::Avx2Fma, Isa::Avx512, Isa::Neon] {
                simd_vs_naive(isa, m, n, k, 0x51D + (m * 1000 + n * 10 + k) as u64);
            }
        }
    }

    #[test]
    fn portable_body_is_currently_bit_identical_to_naive() {
        // Not a contract (the contract is the tolerance above) — but the
        // portable body uses plain mul+add in naive k order today, so a
        // nonzero ULP distance means its loop structure regrouped.
        for &(m, n, k) in &[(5, 17, 9), (33, 7, 21), (40, 40, 40)] {
            let max_ulp = simd_vs_naive(Isa::Portable, m, n, k, 0x90A7);
            assert_eq!(max_ulp, 0, "portable drifted at {m}x{n}x{k}");
        }
    }

    #[test]
    fn tolerance_harness_rejects_a_genuinely_wrong_result() {
        let (m, n, k) = (6, 6, 6);
        let mut rng = Rng::new(0xBAD);
        let a = rng.normal_matrix(m, k);
        let b = rng.normal_matrix(k, n);
        let c = rng.normal_matrix(m, n);
        let mut want = c.clone();
        matmul(KernelPolicy::Naive, &mut want, &a, &b, m, n, k);
        let mut wrong = want.clone();
        wrong[7] += 0.25; // far past any rounding bound at k=6
        assert!(
            verify_fma_relaxed(&wrong, &want, &a, &b, &c, None, m, n, k).is_err(),
            "harness accepted a 0.25 absolute error"
        );
    }
}
