//! Shard planner + multi-device execution pool.
//!
//! Splits one large GEMM across a pool of N device contexts, in the
//! spirit of retargetable execution layers (*Composable and Modular Code
//! Generation in MLIR*) and hardware-agnostic dispatch (*ISA Mapper*):
//! the same compiled artifact is schedulable across devices instead of
//! pinned to one runtime.
//!
//! Two partitionings:
//!
//! * **row sharding** — split M: each shard computes a row band of C from
//!   the matching band of A and the whole of B.  Every output element is
//!   computed by exactly the same f32 operation sequence as the unsharded
//!   kernel, so row-sharded results are **bit-identical** for every
//!   precision mode.
//! * **split-K** — split the reduction dimension: each shard computes a
//!   partial product `A[:, k0..k1] @ B[k0..k1, :]` in f32 with no
//!   epilogue; the reduction step sums the partials onto `cast(C)` and
//!   then replays the kernel's own epilogue/rounding tail
//!   ([`crate::runtime::exec`]'s `gemm_tail`).  Summation grouping
//!   changes, so results are tolerance-equal, not bit-equal.
//!
//! Each pool device is backed by its own worker thread and its own
//! [`DeviceModel`], so modeled speedup ([`modeled_speedup`]) is checkable
//! against measured speedup (`benches/sharding.rs`).
//!
//! Shard execution routes through [`Program::execute_planned`]: every
//! shard carries its own compiled [`ExecutionPlan`] (derived from the
//! shard's program shape under the caller's [`PlanEnv`]), so the sharded
//! path consumes explicit plans like every other execution path.  Plans
//! are bit-identical to the naive kernel, so both invariants above hold
//! under every plan (pinned by `rust/tests/kernel_equivalence.rs`).

use std::sync::mpsc::{self, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use crate::plan::{ExecutionPlan, PlanEnv};
use crate::runtime::exec::{gemm_tail, round_to};
use crate::runtime::{BoundB, Program, Tensor};
use crate::schedule::Schedule;
use crate::sim::{simulate, DeviceModel};

use super::metrics::DeviceLoad;

/// Operator-facing sharding policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardStrategy {
    /// Pick rows when M is big enough, else split-K when K is.
    Auto,
    Rows,
    SplitK,
}

/// Resolved partition dimension of a concrete plan.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SplitDim {
    Rows,
    K,
}

#[derive(Debug, Clone)]
pub struct ShardConfig {
    pub strategy: ShardStrategy,
    /// Minimum rows per row shard (below this, fewer shards are planned).
    pub min_rows: usize,
    /// Minimum K extent per split-K shard.
    pub min_k: usize,
    /// Problems below this flop count are not worth the fan-out.
    pub min_flops: f64,
}

impl Default for ShardConfig {
    fn default() -> Self {
        ShardConfig {
            strategy: ShardStrategy::Auto,
            min_rows: 64,
            min_k: 256,
            // 512^3 GEMM: below that, shard dispatch overhead dominates.
            min_flops: 2.0 * 512.0 * 512.0 * 512.0,
        }
    }
}

/// One shard: a contiguous span of the split dimension, pinned to a
/// device slot.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Shard {
    pub device: usize,
    pub offset: usize,
    pub len: usize,
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardPlan {
    pub m: usize,
    pub n: usize,
    pub k: usize,
    pub dim: SplitDim,
    pub shards: Vec<Shard>,
}

/// Split `extent` into up to `parts` contiguous spans of at least
/// `min_len` each (never more spans than fit, never zero spans).
fn partition(extent: usize, parts: usize, min_len: usize) -> Vec<(usize, usize)> {
    let min_len = min_len.max(1);
    let n = parts.min(extent / min_len).max(1);
    let base = extent / n;
    let rem = extent % n;
    let mut out = Vec::with_capacity(n);
    let mut offset = 0;
    for i in 0..n {
        let len = base + usize::from(i < rem);
        out.push((offset, len));
        offset += len;
    }
    out
}

impl ShardPlan {
    /// Row partition of M across `devices` slots.
    pub fn rows(m: usize, n: usize, k: usize, devices: usize, min_rows: usize) -> ShardPlan {
        let shards = partition(m, devices, min_rows)
            .into_iter()
            .enumerate()
            .map(|(i, (offset, len))| Shard { device: i, offset, len })
            .collect();
        ShardPlan { m, n, k, dim: SplitDim::Rows, shards }
    }

    /// Split-K partition across `devices` slots.
    pub fn split_k(m: usize, n: usize, k: usize, devices: usize, min_k: usize) -> ShardPlan {
        let shards = partition(k, devices, min_k)
            .into_iter()
            .enumerate()
            .map(|(i, (offset, len))| Shard { device: i, offset, len })
            .collect();
        ShardPlan { m, n, k, dim: SplitDim::K, shards }
    }

    /// More than one shard (a single-shard "plan" is just the original
    /// problem).
    pub fn is_sharded(&self) -> bool {
        self.shards.len() > 1
    }
}

/// Plan sharding for a program over `devices` device slots, or `None`
/// when the program is not a GEMM, the pool is a single device, the
/// problem is too small, or no dimension splits cleanly past the
/// minimums.
pub fn plan_for(program: &Program, devices: usize, cfg: &ShardConfig) -> Option<ShardPlan> {
    let Program::Gemm { m, n, k, .. } = *program else {
        return None;
    };
    if devices < 2 {
        return None;
    }
    if 2.0 * m as f64 * n as f64 * k as f64 < cfg.min_flops {
        return None;
    }
    let plan = match cfg.strategy {
        ShardStrategy::Rows => ShardPlan::rows(m, n, k, devices, cfg.min_rows),
        ShardStrategy::SplitK => ShardPlan::split_k(m, n, k, devices, cfg.min_k),
        ShardStrategy::Auto => {
            let by_rows = ShardPlan::rows(m, n, k, devices, cfg.min_rows);
            if by_rows.is_sharded() {
                by_rows
            } else {
                ShardPlan::split_k(m, n, k, devices, cfg.min_k)
            }
        }
    };
    if plan.is_sharded() {
        Some(plan)
    } else {
        None
    }
}

/// The executable program for one shard, derived from the artifact's
/// program so precision semantics carry over exactly.
pub fn shard_program(base: &Program, plan: &ShardPlan, shard: &Shard) -> Result<Program> {
    let Program::Gemm { m: _, n, k, dtype_in, dtype_acc, epilogue, fused } = *base else {
        bail!("only gemm programs can be sharded");
    };
    Ok(match plan.dim {
        SplitDim::Rows => Program::Gemm {
            m: shard.len,
            n,
            k,
            dtype_in,
            dtype_acc,
            epilogue,
            fused,
        },
        // Partial products accumulate in f32 with no epilogue and no
        // intermediate rounding; the reduction step replays the real tail.
        SplitDim::K => Program::Gemm {
            m: plan.m,
            n,
            k: shard.len,
            dtype_in,
            dtype_acc: crate::schedule::Dtype::F32,
            epilogue: crate::runtime::Epilogue::None,
            fused: true,
        },
    })
}

/// Input tensors for one shard.
///
/// Each shard gets owned copies of its operands (row shards each carry
/// the whole of B): this models the per-device operand broadcast a real
/// multi-device system performs, and keeps shard tasks self-contained
/// for the per-device queues.  Sharing B behind an `Arc` would save host
/// memory but needs a borrowed-tensor executor API — noted as future
/// work in ROADMAP terms, not done here.
pub fn shard_inputs(
    plan: &ShardPlan,
    shard: &Shard,
    a: &Tensor,
    b: &Tensor,
    c: &Tensor,
    bias: Option<&Tensor>,
) -> Vec<Tensor> {
    let (m, n, k) = (plan.m, plan.n, plan.k);
    match plan.dim {
        SplitDim::Rows => {
            let a_rows = a.data[shard.offset * k..(shard.offset + shard.len) * k].to_vec();
            let c_rows = c.data[shard.offset * n..(shard.offset + shard.len) * n].to_vec();
            let mut inputs = vec![
                Tensor { shape: vec![shard.len, k], data: a_rows },
                b.clone(),
                Tensor { shape: vec![shard.len, n], data: c_rows },
            ];
            if let Some(bias) = bias {
                inputs.push(bias.clone());
            }
            inputs
        }
        SplitDim::K => {
            // Columns [offset, offset+len) of A: strided gather.
            let mut a_cols = Vec::with_capacity(m * shard.len);
            for i in 0..m {
                let row = &a.data[i * k..(i + 1) * k];
                a_cols.extend_from_slice(&row[shard.offset..shard.offset + shard.len]);
            }
            let b_rows = b.data[shard.offset * n..(shard.offset + shard.len) * n].to_vec();
            vec![
                Tensor { shape: vec![m, shard.len], data: a_cols },
                Tensor { shape: vec![shard.len, n], data: b_rows },
                Tensor::zeros(vec![m, n]),
            ]
        }
    }
}

/// One weight-bound shard's executable unit: derived program, compiled
/// plan, operand slice, and — for row shards — the shared bind-time
/// weights (None for split-K shards, which carry a sliced inline B).
pub type BoundShardTask =
    (Program, Arc<ExecutionPlan>, Vec<Tensor>, Option<Arc<BoundB>>);

/// The bias contract, enforced before any shard runs: split-K shards
/// execute without the epilogue (it replays in the reduction), so a
/// missing or mis-sized bias would otherwise silently skip the epilogue
/// instead of failing like the unsharded path does.
fn check_bias(
    epilogue: crate::runtime::Epilogue,
    bias: Option<&Tensor>,
    n: usize,
) -> Result<()> {
    match bias {
        Some(t) if epilogue.needs_bias() => {
            if t.shape != [n] || t.data.len() != n {
                bail!(
                    "epilogue {:?} needs a bias of shape [{n}], got {:?} ({} elements)",
                    epilogue.name(),
                    t.shape,
                    t.data.len()
                );
            }
            Ok(())
        }
        None if epilogue.needs_bias() => {
            bail!("epilogue {:?} needs a bias input", epilogue.name())
        }
        Some(_) => bail!("bias provided but the kernel has no bias epilogue"),
        None => Ok(()),
    }
}

/// A/C operand validation shared by the inline and weight-bound task
/// builders (shape *and* data length: a torn tensor must fail here, not
/// panic the splitting slice on the dispatcher thread).
fn check_a_c(a: &Tensor, c: &Tensor, m: usize, n: usize, k: usize) -> Result<()> {
    if a.shape != [m, k] || c.shape != [m, n] {
        bail!(
            "operand shapes a={:?} c={:?} do not match plan {m}x{n}x{k}",
            a.shape,
            c.shape
        );
    }
    if a.data.len() != m * k || c.data.len() != m * n {
        bail!(
            "operand data lengths a={} c={} do not match plan {m}x{n}x{k}",
            a.data.len(),
            c.data.len()
        );
    }
    Ok(())
}

/// Build the complete per-shard task list for one request: each shard's
/// derived program, its compiled execution plan (under `env`), and its
/// operand slice.
pub fn build_shard_tasks(
    env: &PlanEnv,
    plan: &ShardPlan,
    base: &Program,
    a: &Tensor,
    b: &Tensor,
    c: &Tensor,
    bias: Option<&Tensor>,
) -> Result<Vec<(Program, Arc<ExecutionPlan>, Vec<Tensor>)>> {
    let Program::Gemm { epilogue, .. } = *base else {
        bail!("only gemm programs can be sharded");
    };
    let (m, n, k) = (plan.m, plan.n, plan.k);
    check_a_c(a, c, m, n, k)?;
    if b.shape != [k, n] || b.data.len() != k * n {
        bail!(
            "operand B shape {:?} ({} elements) does not match plan {m}x{n}x{k}",
            b.shape,
            b.data.len()
        );
    }
    check_bias(epilogue, bias, n)?;
    plan.shards
        .iter()
        .map(|shard| {
            let program = shard_program(base, plan, shard)?;
            let eplan = Arc::new(program.compile_plan(env)?);
            Ok((program, eplan, shard_inputs(plan, shard, a, b, c, bias)))
        })
        .collect()
}

/// [`build_shard_tasks`] for a weight-bound request (B lives in `bound`,
/// cast and prepacked at bind time).
///
/// * **Row shards** all read the whole of B, so every task shares the
///   one bind-time [`BoundB`] by `Arc` — the per-device B broadcast copy
///   of the inline path disappears entirely, and prepacked panels are
///   consumed as-is on every device.
/// * **Split-K shards** need B rows `[offset, offset+len)`; panels are
///   laid out over the full k extent and do not align with arbitrary
///   k-splits, so each shard slices the bound *raw* (already-cast) B —
///   still skipping the per-request payload and input cast.  Re-casting
///   the slice inside the shard is the identity (rounding is
///   idempotent), so partials match the inline split-K path bit for bit.
pub fn build_shard_tasks_bound(
    env: &PlanEnv,
    plan: &ShardPlan,
    base: &Program,
    a: &Tensor,
    c: &Tensor,
    bias: Option<&Tensor>,
    bound: &Arc<BoundB>,
) -> Result<Vec<BoundShardTask>> {
    let Program::Gemm { epilogue, .. } = *base else {
        bail!("only gemm programs can be sharded");
    };
    let (m, n, k) = (plan.m, plan.n, plan.k);
    check_a_c(a, c, m, n, k)?;
    if (bound.k(), bound.n()) != (k, n) {
        bail!(
            "bound weights are {}x{}, shard plan wants {k}x{n}",
            bound.k(),
            bound.n()
        );
    }
    check_bias(epilogue, bias, n)?;
    plan.shards
        .iter()
        .map(|shard| {
            let program = shard_program(base, plan, shard)?;
            let eplan = Arc::new(program.compile_plan(env)?);
            Ok(match plan.dim {
                SplitDim::Rows => {
                    let a_rows = a.data
                        [shard.offset * k..(shard.offset + shard.len) * k]
                        .to_vec();
                    let c_rows = c.data
                        [shard.offset * n..(shard.offset + shard.len) * n]
                        .to_vec();
                    let mut inputs = vec![
                        Tensor { shape: vec![shard.len, k], data: a_rows },
                        Tensor { shape: vec![shard.len, n], data: c_rows },
                    ];
                    if let Some(bias) = bias {
                        inputs.push(bias.clone());
                    }
                    (program, eplan, inputs, Some(bound.clone()))
                }
                SplitDim::K => {
                    let mut a_cols = Vec::with_capacity(m * shard.len);
                    for i in 0..m {
                        let row = &a.data[i * k..(i + 1) * k];
                        a_cols.extend_from_slice(
                            &row[shard.offset..shard.offset + shard.len],
                        );
                    }
                    let b_rows = bound.raw()
                        [shard.offset * n..(shard.offset + shard.len) * n]
                        .to_vec();
                    let inputs = vec![
                        Tensor { shape: vec![m, shard.len], data: a_cols },
                        Tensor { shape: vec![shard.len, n], data: b_rows },
                        Tensor::zeros(vec![m, n]),
                    ];
                    (program, eplan, inputs, None)
                }
            })
        })
        .collect()
}

/// Combine per-shard outputs into the full C.
///
/// Rows: concatenate the row bands (bit-identical to the unsharded
/// kernel).  Split-K: sum partials onto `cast(C)`, then replay the
/// kernel's epilogue/rounding tail.
pub fn reduce_outputs(
    plan: &ShardPlan,
    base: &Program,
    c: &Tensor,
    bias: Option<&Tensor>,
    parts: &[Tensor],
) -> Result<Tensor> {
    let Program::Gemm { n, dtype_acc, epilogue, fused, .. } = *base else {
        bail!("only gemm programs can be sharded");
    };
    if parts.len() != plan.shards.len() {
        bail!("{} shard outputs for a {}-shard plan", parts.len(), plan.shards.len());
    }
    match plan.dim {
        SplitDim::Rows => {
            let mut data = Vec::with_capacity(plan.m * plan.n);
            for (shard, part) in plan.shards.iter().zip(parts) {
                if part.shape != [shard.len, plan.n] {
                    bail!(
                        "row shard output shape {:?}, want [{}, {}]",
                        part.shape,
                        shard.len,
                        plan.n
                    );
                }
                data.extend_from_slice(&part.data);
            }
            Ok(Tensor { shape: vec![plan.m, plan.n], data })
        }
        SplitDim::K => {
            let mut acc: Vec<f32> =
                c.data.iter().map(|&v| round_to(dtype_acc, v)).collect();
            for part in parts {
                if part.shape != [plan.m, plan.n] {
                    bail!(
                        "split-K partial shape {:?}, want [{}, {}]",
                        part.shape,
                        plan.m,
                        plan.n
                    );
                }
                for (o, &p) in acc.iter_mut().zip(&part.data) {
                    *o += p;
                }
            }
            gemm_tail(
                &mut acc,
                bias.map(|t| t.data.as_slice()),
                n,
                dtype_acc,
                epilogue,
                fused,
            );
            Ok(Tensor { shape: vec![plan.m, plan.n], data: acc })
        }
    }
}

/// Execute one shard program under its compiled plan and take its single
/// output — the one shard execution body, shared by the [`ShardPool`]
/// workers and the server's device workers so the two engines cannot
/// drift.  A weight-bound shard (`bound` set; row shards of a bound
/// request) consumes the shared bind-time operand instead of an inline
/// B tensor.
pub fn execute_shard(
    program: &Program,
    eplan: &ExecutionPlan,
    inputs: &[Tensor],
    bound: Option<&BoundB>,
) -> Result<Tensor> {
    let outs = match bound {
        Some(bw) => program.execute_planned_bound(inputs, eplan, bw),
        None => program.execute_planned(inputs, eplan),
    }?;
    outs.into_iter()
        .next()
        .ok_or_else(|| anyhow!("shard produced no output"))
}

// ---------------------------------------------------------------------------
// Device pool
// ---------------------------------------------------------------------------

struct PoolTask {
    program: Program,
    eplan: Arc<ExecutionPlan>,
    inputs: Vec<Tensor>,
    bound: Option<Arc<BoundB>>,
    shard_idx: usize,
    reply: Sender<(usize, Result<Tensor>)>,
}

struct PoolWorker {
    model: DeviceModel,
    tx: Sender<PoolTask>,
    handle: Option<JoinHandle<()>>,
    stats: Arc<Mutex<DeviceLoad>>,
}

/// A pool of device contexts, one worker thread + one [`DeviceModel`]
/// each.  Stand-alone engine for benches and integration tests; the
/// server wires the same planner/split/reduce building blocks through
/// its own per-device queues.
pub struct ShardPool {
    workers: Vec<PoolWorker>,
    plan_env: PlanEnv,
}

impl ShardPool {
    pub fn new(models: Vec<DeviceModel>) -> ShardPool {
        assert!(!models.is_empty(), "shard pool needs at least one device");
        // Shard plans compile for a pool of this size: the pool's workers
        // already parallelize across shards, so per-shard plans stay
        // single-thread.
        let plan_env = PlanEnv::for_pool(models.len());
        let workers = models
            .into_iter()
            .map(|model| {
                let (tx, rx) = mpsc::channel::<PoolTask>();
                let stats = Arc::new(Mutex::new(DeviceLoad::default()));
                let worker_stats = stats.clone();
                let handle = std::thread::spawn(move || {
                    while let Ok(task) = rx.recv() {
                        let started = Instant::now();
                        let result = execute_shard(
                            &task.program,
                            &task.eplan,
                            &task.inputs,
                            task.bound.as_deref(),
                        );
                        let busy = started.elapsed().as_secs_f64();
                        {
                            let mut g = worker_stats.lock().unwrap();
                            g.tasks += 1;
                            g.busy_sec += busy;
                        }
                        let _ = task.reply.send((task.shard_idx, result));
                    }
                });
                PoolWorker { model, tx, handle: Some(handle), stats }
            })
            .collect();
        ShardPool { workers, plan_env }
    }

    /// Pool of `n` identical devices.
    pub fn homogeneous(model: &DeviceModel, n: usize) -> ShardPool {
        ShardPool::new(vec![model.clone(); n.max(1)])
    }

    pub fn devices(&self) -> usize {
        self.workers.len()
    }

    pub fn model(&self, device: usize) -> &DeviceModel {
        &self.workers[device % self.workers.len()].model
    }

    pub fn models(&self) -> Vec<DeviceModel> {
        self.workers.iter().map(|w| w.model.clone()).collect()
    }

    /// Execute one GEMM according to `plan`, fanning shards across the
    /// device workers and reducing the partials.
    pub fn execute(
        &self,
        base: &Program,
        plan: &ShardPlan,
        a: &Tensor,
        b: &Tensor,
        c: &Tensor,
        bias: Option<&Tensor>,
    ) -> Result<Tensor> {
        let tasks: Vec<_> = build_shard_tasks(&self.plan_env, plan, base, a, b, c, bias)?
            .into_iter()
            .map(|(program, eplan, inputs)| (program, eplan, inputs, None))
            .collect();
        self.run_tasks(base, plan, c, bias, tasks)
    }

    /// [`ShardPool::execute`] for a weight-bound request: row shards
    /// share `bound`'s prepacked panels across the pool, split-K shards
    /// slice its cast raw B.
    pub fn execute_bound(
        &self,
        base: &Program,
        plan: &ShardPlan,
        a: &Tensor,
        c: &Tensor,
        bias: Option<&Tensor>,
        bound: &Arc<BoundB>,
    ) -> Result<Tensor> {
        let tasks =
            build_shard_tasks_bound(&self.plan_env, plan, base, a, c, bias, bound)?;
        self.run_tasks(base, plan, c, bias, tasks)
    }

    fn run_tasks(
        &self,
        base: &Program,
        plan: &ShardPlan,
        c: &Tensor,
        bias: Option<&Tensor>,
        tasks: Vec<BoundShardTask>,
    ) -> Result<Tensor> {
        let n_shards = tasks.len();
        let (reply_tx, reply_rx) = mpsc::channel();
        for (idx, ((program, eplan, inputs, bound), shard)) in
            tasks.into_iter().zip(&plan.shards).enumerate()
        {
            let dev = shard.device % self.workers.len();
            self.workers[dev]
                .tx
                .send(PoolTask {
                    program,
                    eplan,
                    inputs,
                    bound,
                    shard_idx: idx,
                    reply: reply_tx.clone(),
                })
                .map_err(|_| anyhow!("device {dev} worker is gone"))?;
        }
        drop(reply_tx);
        let mut parts: Vec<Option<Tensor>> = vec![None; n_shards];
        for _ in 0..n_shards {
            let (idx, result) = reply_rx
                .recv()
                .map_err(|_| anyhow!("shard workers dropped their replies"))?;
            parts[idx] = Some(result?);
        }
        let parts: Vec<Tensor> = parts.into_iter().flatten().collect();
        if parts.len() != n_shards {
            bail!("lost shard outputs: {} of {n_shards}", parts.len());
        }
        reduce_outputs(plan, base, c, bias, &parts)
    }

    /// Per-device execution tallies (device index order).
    pub fn stats(&self) -> Vec<DeviceLoad> {
        self.workers
            .iter()
            .map(|w| w.stats.lock().unwrap().clone())
            .collect()
    }

    /// Stop the workers and return the final per-device tallies.
    pub fn shutdown(mut self) -> Vec<DeviceLoad> {
        let mut out = Vec::with_capacity(self.workers.len());
        for mut w in self.workers.drain(..) {
            let (dead_tx, _) = mpsc::channel();
            drop(std::mem::replace(&mut w.tx, dead_tx));
            if let Some(h) = w.handle.take() {
                let _ = h.join();
            }
            out.push(w.stats.lock().unwrap().clone());
        }
        out
    }
}

// ---------------------------------------------------------------------------
// Modeled scaling
// ---------------------------------------------------------------------------

/// Modeled wall time of each shard on its assigned device: simulate the
/// derived shard schedule when the tile still divides it, otherwise scale
/// the full-problem simulation by the shard's flops share.
pub fn modeled_times(
    schedule: &Schedule,
    plan: &ShardPlan,
    models: &[DeviceModel],
) -> Vec<f64> {
    plan.shards
        .iter()
        .map(|shard| {
            let model = &models[shard.device % models.len()];
            let (sm, sk) = match plan.dim {
                SplitDim::Rows => (shard.len, plan.k),
                SplitDim::K => (plan.m, shard.len),
            };
            match Schedule::optimized(
                sm,
                plan.n,
                sk,
                schedule.dtype_acc,
                schedule.tile_tb,
                schedule.tile_warp,
            ) {
                Ok(sub) => simulate(&sub, model).seconds,
                Err(_) => {
                    let frac = (sm as f64 * sk as f64) / (plan.m as f64 * plan.k as f64);
                    simulate(schedule, model).seconds * frac
                }
            }
        })
        .collect()
}

/// Modeled speedup of the sharded plan over single-device execution on
/// `models[0]` (shards run concurrently, so the slowest shard bounds the
/// wall time; split-K reduction cost is ignored, matching its O(m*n)
/// scale next to the O(m*n*k) GEMM).
pub fn modeled_speedup(
    schedule: &Schedule,
    plan: &ShardPlan,
    models: &[DeviceModel],
) -> f64 {
    let single = simulate(schedule, &models[0]).seconds;
    let slowest = modeled_times(schedule, plan, models)
        .into_iter()
        .fold(0.0f64, f64::max);
    if slowest > 0.0 {
        single / slowest
    } else {
        1.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Epilogue;
    use crate::schedule::Dtype;
    use crate::util::prng::Rng;

    fn t(shape: Vec<usize>, data: Vec<f32>) -> Tensor {
        Tensor { shape, data }
    }

    fn gemm(m: usize, n: usize, k: usize, din: Dtype, dacc: Dtype) -> Program {
        Program::Gemm {
            m,
            n,
            k,
            dtype_in: din,
            dtype_acc: dacc,
            epilogue: Epilogue::None,
            fused: true,
        }
    }

    fn operands(m: usize, n: usize, k: usize, seed: u64) -> (Tensor, Tensor, Tensor) {
        let mut rng = Rng::new(seed);
        (
            t(vec![m, k], rng.normal_matrix(m, k)),
            t(vec![k, n], rng.normal_matrix(k, n)),
            t(vec![m, n], rng.normal_matrix(m, n)),
        )
    }

    #[test]
    fn partition_covers_extent_and_respects_min() {
        let p = partition(10, 4, 1);
        assert_eq!(p, vec![(0, 3), (3, 3), (6, 2), (8, 2)]);
        assert_eq!(partition(100, 8, 64), vec![(0, 100)]);
        assert_eq!(partition(128, 4, 32), vec![(0, 32), (32, 32), (64, 32), (96, 32)]);
        // never zero shards
        assert_eq!(partition(1, 8, 4).len(), 1);
    }

    #[test]
    fn plan_for_respects_thresholds_and_strategy() {
        let cfg = ShardConfig::default();
        let big = gemm(1024, 1024, 1024, Dtype::F16, Dtype::F32);
        let small = gemm(64, 64, 64, Dtype::F16, Dtype::F32);
        assert!(plan_for(&big, 4, &cfg).is_some());
        assert!(plan_for(&small, 4, &cfg).is_none(), "below min_flops");
        assert!(plan_for(&big, 1, &cfg).is_none(), "single device");
        // Auto: short M but deep K falls back to split-K
        let deep = gemm(64, 64, 65536, Dtype::F16, Dtype::F32);
        let plan = plan_for(&deep, 4, &cfg).unwrap();
        assert_eq!(plan.dim, SplitDim::K);
        let wide = plan_for(&big, 4, &cfg).unwrap();
        assert_eq!(wide.dim, SplitDim::Rows);
    }

    #[test]
    fn row_sharding_is_bit_identical_without_a_pool() {
        // Pure split/execute/reduce pipeline, no threads: shard outputs
        // concatenate to exactly the unsharded result.
        for &(din, dacc) in &[
            (Dtype::F32, Dtype::F32),
            (Dtype::F16, Dtype::F32),
            (Dtype::F16, Dtype::F16),
        ] {
            let (m, n, k) = (24, 16, 16);
            let base = gemm(m, n, k, din, dacc);
            let (a, b, c) = operands(m, n, k, 7);
            let want = base.execute(&[a.clone(), b.clone(), c.clone()]).unwrap();
            let plan = ShardPlan::rows(m, n, k, 3, 1);
            assert_eq!(plan.shards.len(), 3);
            let parts: Vec<Tensor> =
                build_shard_tasks(&PlanEnv::default(), &plan, &base, &a, &b, &c, None)
                    .unwrap()
                    .into_iter()
                    .map(|(prog, eplan, inputs)| {
                        prog.execute_planned(&inputs, &eplan).unwrap().remove(0)
                    })
                    .collect();
            let got = reduce_outputs(&plan, &base, &c, None, &parts).unwrap();
            assert_eq!(got.shape, want[0].shape);
            assert_eq!(got.data, want[0].data, "{din:?}/{dacc:?} row shard drifted");
        }
    }

    #[test]
    fn split_k_matches_within_tolerance_and_handles_epilogue() {
        let (m, n, k) = (8, 8, 32);
        let base = Program::Gemm {
            m,
            n,
            k,
            dtype_in: Dtype::F16,
            dtype_acc: Dtype::F32,
            epilogue: Epilogue::BiasRelu,
            fused: true,
        };
        let (a, b, c) = operands(m, n, k, 8);
        let bias = t(vec![n], Rng::new(9).normal_matrix(1, n));
        let want = base
            .execute(&[a.clone(), b.clone(), c.clone(), bias.clone()])
            .unwrap();
        let plan = ShardPlan::split_k(m, n, k, 4, 1);
        assert_eq!(plan.shards.len(), 4);
        let tasks =
            build_shard_tasks(&PlanEnv::default(), &plan, &base, &a, &b, &c, Some(&bias))
                .unwrap();
        // shard programs carry no epilogue and take exactly 3 inputs, and
        // each shard's plan describes the shard's own shape
        for (prog, eplan, inputs) in &tasks {
            assert_eq!(inputs.len(), 3);
            let Program::Gemm { epilogue, dtype_acc, m: sm, k: sk, .. } = *prog else {
                panic!("non-gemm shard")
            };
            assert_eq!(epilogue, Epilogue::None);
            assert_eq!(dtype_acc, Dtype::F32);
            assert_eq!((eplan.m, eplan.k), (sm, sk));
            assert!(!eplan.fuse_epilogue, "shard plans never fuse an epilogue");
        }
        let parts: Vec<Tensor> = tasks
            .into_iter()
            .map(|(prog, eplan, inputs)| {
                prog.execute_planned(&inputs, &eplan).unwrap().remove(0)
            })
            .collect();
        let got = reduce_outputs(&plan, &base, &c, Some(&bias), &parts).unwrap();
        let mut worst = 0f64;
        for (g, w) in got.data.iter().zip(&want[0].data) {
            worst = worst.max((*g as f64 - *w as f64).abs());
        }
        assert!(worst < 1e-2, "split-K drifted by {worst}");
        // relu must clamp in the reduced output too
        assert!(got.data.iter().all(|&v| v >= 0.0));
    }

    #[test]
    fn split_k_rejects_missing_or_misshapen_bias() {
        // Regression: split-K shards carry no epilogue, so without this
        // check a missing bias would silently skip the epilogue in the
        // reduction instead of failing like the unsharded path.
        let (m, n, k) = (8, 8, 32);
        let base = Program::Gemm {
            m,
            n,
            k,
            dtype_in: Dtype::F16,
            dtype_acc: Dtype::F32,
            epilogue: Epilogue::BiasRelu,
            fused: true,
        };
        let (a, b, c) = operands(m, n, k, 13);
        let env = PlanEnv::default();
        let plan = ShardPlan::split_k(m, n, k, 4, 1);
        assert!(build_shard_tasks(&env, &plan, &base, &a, &b, &c, None).is_err());
        let short = t(vec![n - 1], vec![0.0; n - 1]);
        assert!(build_shard_tasks(&env, &plan, &base, &a, &b, &c, Some(&short)).is_err());
        // and a bias on a no-epilogue kernel is rejected too
        let plain = gemm(m, n, k, Dtype::F16, Dtype::F32);
        let bias = t(vec![n], vec![0.0; n]);
        assert!(build_shard_tasks(&env, &plan, &plain, &a, &b, &c, Some(&bias)).is_err());
    }

    #[test]
    fn pool_executes_plan_and_tracks_per_device_load() {
        let (m, n, k) = (32, 16, 16);
        let base = gemm(m, n, k, Dtype::F32, Dtype::F32);
        let (a, b, c) = operands(m, n, k, 11);
        let want = base.execute(&[a.clone(), b.clone(), c.clone()]).unwrap();
        let pool = ShardPool::homogeneous(&DeviceModel::rtx3090(), 4);
        assert_eq!(pool.devices(), 4);
        let plan = ShardPlan::rows(m, n, k, pool.devices(), 1);
        let got = pool.execute(&base, &plan, &a, &b, &c, None).unwrap();
        assert_eq!(got.data, want[0].data);
        let stats = pool.shutdown();
        assert_eq!(stats.len(), 4);
        let total_tasks: u64 = stats.iter().map(|s| s.tasks).sum();
        assert_eq!(total_tasks, plan.shards.len() as u64);
        assert!(stats.iter().all(|s| s.tasks == 1), "{stats:?}");
    }

    #[test]
    fn bound_row_shards_share_panels_and_match_inline_bitwise() {
        use crate::plan::PlanOverride;
        let (m, n, k) = (24, 16, 16);
        for &(din, dacc) in &[(Dtype::F32, Dtype::F32), (Dtype::F16, Dtype::F32)] {
            let base = gemm(m, n, k, din, dacc);
            let (a, b, c) = operands(m, n, k, 41);
            // Force a packing kernel so the bind actually prepacks.
            let env = PlanEnv::default()
                .with_force(PlanOverride::parse("tiled:8,4,8").unwrap());
            let request_plan = base.compile_plan(&env).unwrap();
            let bound = Arc::new(base.bind_b(&b, &request_plan).unwrap());
            assert!(bound.is_prepacked());
            let want = base.execute(&[a.clone(), b.clone(), c.clone()]).unwrap();
            let plan = ShardPlan::rows(m, n, k, 3, 1);
            let tasks =
                build_shard_tasks_bound(&env, &plan, &base, &a, &c, None, &bound)
                    .unwrap();
            // every row shard shares the one bound operand — no B copies
            for (_, _, inputs, task_bound) in &tasks {
                assert_eq!(inputs.len(), 2, "bound row shards carry A + C only");
                let tb = task_bound.as_ref().expect("row shards share the bound B");
                assert!(Arc::ptr_eq(tb, &bound));
            }
            let parts: Vec<Tensor> = tasks
                .into_iter()
                .map(|(prog, eplan, inputs, task_bound)| {
                    execute_shard(&prog, &eplan, &inputs, task_bound.as_deref())
                        .unwrap()
                })
                .collect();
            let got = reduce_outputs(&plan, &base, &c, None, &parts).unwrap();
            assert_eq!(got.data, want[0].data, "{din:?}/{dacc:?} bound row shard drifted");
        }
    }

    #[test]
    fn bound_split_k_matches_inline_split_k_bitwise() {
        // Split-K shards slice the bound raw (cast) B; cast-then-slice
        // equals slice-then-cast elementwise, so bound and inline split-K
        // partials — and therefore the reduced outputs — are bit-equal.
        let (m, n, k) = (8, 8, 32);
        let base = gemm(m, n, k, Dtype::F16, Dtype::F32);
        let (a, b, c) = operands(m, n, k, 42);
        let env = PlanEnv::default();
        let request_plan = base.compile_plan(&env).unwrap();
        let bound = Arc::new(base.bind_b(&b, &request_plan).unwrap());
        let plan = ShardPlan::split_k(m, n, k, 4, 1);
        let run = |tasks: Vec<BoundShardTask>| {
            let parts: Vec<Tensor> = tasks
                .into_iter()
                .map(|(prog, eplan, inputs, tb)| {
                    execute_shard(&prog, &eplan, &inputs, tb.as_deref()).unwrap()
                })
                .collect();
            reduce_outputs(&plan, &base, &c, None, &parts).unwrap()
        };
        let inline_tasks: Vec<_> =
            build_shard_tasks(&env, &plan, &base, &a, &b, &c, None)
                .unwrap()
                .into_iter()
                .map(|(p, e, i)| (p, e, i, None))
                .collect();
        let bound_tasks =
            build_shard_tasks_bound(&env, &plan, &base, &a, &c, None, &bound).unwrap();
        assert!(
            bound_tasks.iter().all(|(_, _, _, tb)| tb.is_none()),
            "split-K shards slice raw B, no shared panels"
        );
        let want = run(inline_tasks);
        let got = run(bound_tasks);
        assert_eq!(got.data, want.data, "bound split-K drifted from inline split-K");
    }

    #[test]
    fn pool_executes_bound_plan_bitwise() {
        let (m, n, k) = (32, 16, 16);
        let base = gemm(m, n, k, Dtype::F32, Dtype::F32);
        let (a, b, c) = operands(m, n, k, 43);
        let want = base.execute(&[a.clone(), b.clone(), c.clone()]).unwrap();
        let pool = ShardPool::homogeneous(&DeviceModel::rtx3090(), 4);
        let request_plan = base.compile_plan(&PlanEnv::for_pool(4)).unwrap();
        let bound = Arc::new(base.bind_b(&b, &request_plan).unwrap());
        let plan = ShardPlan::rows(m, n, k, pool.devices(), 1);
        let got = pool.execute_bound(&base, &plan, &a, &c, None, &bound).unwrap();
        assert_eq!(got.data, want[0].data);
        pool.shutdown();
    }

    #[test]
    fn pool_surfaces_shard_failures() {
        let (m, n, k) = (8, 8, 8);
        let base = gemm(m, n, k, Dtype::F32, Dtype::F32);
        let (a, b, c) = operands(m, n, k, 12);
        let pool = ShardPool::homogeneous(&DeviceModel::rtx3090(), 2);
        // a plan that lies about the problem shape fails fast in split
        let bad_plan = ShardPlan::rows(m + 1, n, k, 2, 1);
        assert!(pool.execute(&base, &bad_plan, &a, &b, &c, None).is_err());
        // a shape/data-inconsistent tensor fails validation instead of
        // panicking the splitting slice
        let plan = ShardPlan::rows(m, n, k, 2, 1);
        let torn = Tensor { shape: vec![m, k], data: vec![] };
        assert!(pool.execute(&base, &plan, &torn, &b, &c, None).is_err());
        pool.shutdown();
    }

    #[test]
    fn modeled_speedup_scales_with_devices() {
        let s = Schedule::optimized(
            4096,
            4096,
            4096,
            Dtype::F32,
            (128, 128, 64),
            (64, 32, 32),
        )
        .unwrap();
        let models: Vec<DeviceModel> = vec![DeviceModel::rtx3090(); 4];
        let plan2 = ShardPlan::rows(4096, 4096, 4096, 2, 64);
        let plan4 = ShardPlan::rows(4096, 4096, 4096, 4, 64);
        let s2 = modeled_speedup(&s, &plan2, &models);
        let s4 = modeled_speedup(&s, &plan4, &models);
        assert!(s2 > 1.2, "2-way speedup {s2}");
        assert!(s4 > s2, "4-way {s4} <= 2-way {s2}");
        assert!(s4 <= 4.2, "superlinear beyond slack: {s4}");
    }
}
