//! The GEMM service: router + continuous-batching scheduler + sharded
//! multi-device worker pool over the in-process runtime.
//!
//! Requests are submitted from any thread; a dispatcher routes each to
//! the autotuned variant for its shape and admits it into the
//! continuous-batching scheduler ([`super::batcher`]).  The moment a
//! device has a free execution slot the dispatcher releases the most
//! urgent admissible micro-batch — earliest-deadline-first within the
//! highest occupied priority tier, grouped by variant — so a lone
//! request dispatches immediately instead of waiting out a batching
//! window.  Batches go to the chosen device's work queue and execute as
//! a single batched-GEMM runtime call (stacked operands, one
//! pack/unpack).  Large GEMMs are instead sharded across the whole
//! device pool ([`super::sharding`]): the dispatcher fans the per-shard
//! tasks out to every device queue and the worker that finishes the
//! last shard runs the reduction and replies.  Responses come back on
//! per-request channels, each carrying the submit-queue depth observed
//! at its admission as an explicit backpressure signal.  Admission is
//! two-tier: a global bounded queue plus optional per-tenant quotas
//! ([`AdmissionConfig`]), both rejecting explicitly, never blocking.
//! This is the paper's missing run-time half: it generated kernels, we
//! also serve them — across a pool of devices.

use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::plan::program::ProgramPlan;
use crate::plan::{self, ExecutionPlan, PlanEnv, PlanOverride};
use crate::runtime::{
    BoundB, ExecTiming, KernelPolicy, Program, Runtime, Tensor, TensorSpec,
};
use crate::sim::DeviceModel;

use super::batcher::{BatcherConfig, Priority, Queued, Scheduler};
use super::faults::{FaultPlan, FaultState};
use super::metrics::{Metrics, MetricsSnapshot};
use super::registry::{GemmKey, Registry};
use super::shadow::{ShadowConfig, ShadowState};
use super::sharding::{self, ShardConfig, ShardPlan};

/// Stable error-class prefixes.  The vendored `anyhow` shim carries no
/// typed downcast, so error classes are part of the message contract:
/// clients and tests match on these prefixes (`msg.contains(...)`), and
/// changing one is a breaking API change.
pub const ERR_QUEUE_FULL: &str = "queue full";
/// See [`ERR_QUEUE_FULL`].
pub const ERR_DEADLINE: &str = "deadline exceeded";
/// See [`ERR_QUEUE_FULL`].
pub const ERR_POISONED: &str = "poisoned job";
/// See [`ERR_QUEUE_FULL`].
pub const ERR_SHUTDOWN: &str = "server is shut down";

/// Routing-name suffix for weight-bound jobs: bound and inline requests
/// for one variant batch separately (their executable input forms
/// differ) and segment separately in the per-variant metrics.
const BOUND_SUFFIX: &str = "+bound";

/// The artifact a routed variant name loads (strips [`BOUND_SUFFIX`]).
fn artifact_of(variant: &str) -> &str {
    variant.strip_suffix(BOUND_SUFFIX).unwrap_or(variant)
}

/// A GEMM request: C = A @ B + C (+ optional fused epilogue inputs).
#[derive(Debug)]
pub struct GemmRequest {
    pub key: GemmKey,
    pub a: Tensor,
    /// The B operand.  `None` is the weight-bound form: B was bound once
    /// per variant ([`Server::bind_weights`]) and the request ships only
    /// A (+ C/bias) — the hot path skips the B payload, its precision
    /// cast, and (for packing kernels) `pack_b` entirely.
    pub b: Option<Tensor>,
    pub c: Tensor,
    pub bias: Option<Tensor>,
    /// Route to the library baseline instead of the generated kernel.
    pub use_baseline: bool,
    /// Optional latency budget.  A deadline already past at `submit` is
    /// refused at admission without consuming any queue capacity; a job
    /// whose deadline passes while it is still queued (in the submit
    /// channel, the scheduler, or a device queue) is answered with an
    /// explicit [`ERR_DEADLINE`] error before execution — stale output
    /// is never silently computed.  A deadline that expires *during*
    /// execution does not abort the kernel; the check gates execution
    /// start only.
    pub deadline: Option<Instant>,
}

/// A composite-program request (`ProgramPlan`-driven serving): run a
/// named non-GEMM artifact — today the transformer — on its full input
/// list.  Routed by artifact name instead of [`GemmKey`]; the dispatcher
/// attaches the registry-cached graph plan the same way GEMM jobs get
/// their [`ExecutionPlan`].
#[derive(Debug)]
pub struct ProgramRequest {
    pub artifact: String,
    pub inputs: Vec<Tensor>,
}

#[derive(Debug)]
pub struct GemmResponse {
    pub id: u64,
    pub output: Result<Tensor>,
    pub variant: String,
    pub queue_wait: Duration,
    pub exec_time: Duration,
    pub total_latency: Duration,
    /// For weight-bound jobs: the registry bind epoch of the `BoundB`
    /// this job was routed with *and executed under* (first bind = 1).
    /// `None` for inline and failed-before-routing jobs.  This makes the
    /// rebind contract observable end-to-end: a response produced from
    /// weights bound before the client's last completed `bind_weights`
    /// call would carry a stale (smaller) epoch.
    pub bound_epoch: Option<u64>,
    /// Submit-queue depth observed at this request's admission (counting
    /// the request itself) — the server's explicit backpressure signal.
    /// Clients shed or slow down as it approaches
    /// `ServerConfig::queue_capacity`; a rejected request reports the
    /// full capacity.  0 for requests refused before entering the queue
    /// (pre-expired deadline, tenant quota, shutdown race).
    pub queue_depth: usize,
}

impl GemmResponse {
    /// An error response with zero exec time — the shape every
    /// pre-execution failure (routing, validation, rejection, expiry)
    /// takes.  Callers that failed *during* execution override
    /// `exec_time` via struct update.
    fn failure(
        id: u64,
        variant: &str,
        err: anyhow::Error,
        submitted_at: Instant,
        queue_wait: Duration,
    ) -> GemmResponse {
        GemmResponse {
            id,
            output: Err(err),
            variant: variant.to_string(),
            queue_wait,
            exec_time: Duration::ZERO,
            total_latency: submitted_at.elapsed(),
            bound_epoch: None,
            queue_depth: 0,
        }
    }
}

/// Per-submit admission options: which tenant the request bills against
/// and which priority tier it dispatches in.  `Server::submit` uses the
/// default (untenanted, [`Priority::Normal`]); [`Server::submit_with`]
/// exposes the full surface.
#[derive(Debug, Clone, Default)]
pub struct SubmitOpts {
    /// Tenant the request's admitted occupancy bills against
    /// ([`AdmissionConfig::tenant_quota`]).  `None` bills nothing and is
    /// only subject to the global queue bound.
    pub tenant: Option<String>,
    /// Dispatch tier: the scheduler releases strictly by (priority,
    /// effective deadline) within admissible work.
    pub priority: Priority,
}

/// Admission-tier configuration: per-tenant quotas layered on the
/// global bounded submit queue.
#[derive(Debug, Clone, Default)]
pub struct AdmissionConfig {
    /// Max jobs one tenant may hold admitted (submit channel +
    /// scheduler) at once; 0 disables per-tenant quotas.  A tenant at
    /// quota gets a per-tenant [`ERR_QUEUE_FULL`] rejection naming the
    /// tenant and the quota, while other tenants keep flowing.
    pub tenant_quota: usize,
}

/// What a job asks the pool to run: a routed GEMM or a whole composite
/// program.
enum JobKind {
    Gemm(GemmRequest),
    Program(ProgramRequest),
}

struct Job {
    id: u64,
    kind: JobKind,
    submitted_at: Instant,
    reply: Sender<GemmResponse>,
    /// The compiled plan a GEMM job executes under, attached by the
    /// dispatcher at routing time (registry-cached per GemmKey).
    plan: Option<Arc<ExecutionPlan>>,
    /// The compiled graph plan a composite-program job executes under,
    /// attached at routing time (registry-cached per artifact name).
    pplan: Option<Arc<ProgramPlan>>,
    /// The bound weights a `b: None` request executes against, captured
    /// at routing time — a rebind after routing never swaps a job's
    /// operand mid-flight.
    bound: Option<Arc<BoundB>>,
    /// The registry bind epoch of `bound`, captured in the same registry
    /// lock acquisition — echoed on the response so the capture contract
    /// is checkable from outside.
    bound_epoch: Option<u64>,
    /// The request's latency budget (GEMM jobs only), checked at every
    /// queue boundary before execution.
    deadline: Option<Instant>,
    /// Dispatch tier ([`SubmitOpts::priority`]), read by the scheduler.
    priority: Priority,
    /// Tenant the job's admitted occupancy bills against; the dispatcher
    /// releases the billing the moment the job stops being admitted.
    tenant: Option<String>,
    /// Submit-queue depth sampled at admission, echoed on the response
    /// as the backpressure signal.
    admit_depth: usize,
}

#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Total worker threads, spread round-robin over the device queues
    /// (always at least one per device).
    pub workers: usize,
    /// Device contexts in the pool; above 1, large GEMMs shard across it.
    pub devices: usize,
    pub batcher: BatcherConfig,
    /// When and how to shard (`devices > 1` only).
    pub shard: ShardConfig,
    /// Measure each variant once at startup and route by measured latency
    /// instead of modeled TFLOPs (profile-guided routing; the model ranks
    /// for the paper's GPU, measurement ranks for the actual substrate).
    pub rerank_measured: bool,
    /// Execution-plan override (`--plan` CLI plumbing).  `Auto` runs the
    /// full pass pipeline per GemmKey; a forced kernel still compiles a
    /// per-key plan (with the override recorded in its trace).  Scalar
    /// overrides are bit-identical — they change throughput only, which
    /// the metrics report attributes per plan id and per ISA.  `Simd`
    /// (and forced `simd:<isa>` kernels) opt the server into the
    /// `fma_relaxed` numerics class: results honor the documented
    /// ULP-tolerance contract instead of bitwise identity (see
    /// docs/PLAN_SCHEMA.md and DESIGN.md §10).
    pub plan: PlanOverride,
    /// Bounded admission: at most this many jobs buffer in the submit
    /// channel.  `submit` never blocks — when the queue is full the
    /// request is rejected immediately with an explicit
    /// [`ERR_QUEUE_FULL`] response and counted in
    /// `MetricsSnapshot::rejected` (the accounting invariant is
    /// `submitted == completed + failed + rejected`).  Clamped to ≥ 1.
    pub queue_capacity: usize,
    /// Per-tenant admission quotas on top of the global bound (see
    /// [`AdmissionConfig`]).  Off by default.
    pub admission: AdmissionConfig,
    /// Deterministic fault-injection schedule (see [`super::faults`]).
    /// The default injects nothing.
    pub faults: FaultPlan,
    /// Shadow tuning (see [`super::shadow`]): sampled re-measurement of
    /// live traffic under the SIMD candidate plan, atomic promotion of
    /// measured winners, persistence to the plan DB.  Disabled by
    /// default (embedded/test servers opt in); production servers build
    /// from [`ShadowConfig::from_env`], where it is on unless
    /// `MLIR_GEMM_SHADOW=off`.
    pub shadow: ShadowConfig,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            workers: 2,
            devices: 1,
            batcher: BatcherConfig::default(),
            shard: ShardConfig::default(),
            rerank_measured: false,
            plan: PlanOverride::Auto,
            queue_capacity: 1024,
            admission: AdmissionConfig::default(),
            faults: FaultPlan::default(),
            shadow: ShadowConfig::default(),
        }
    }
}

impl ServerConfig {
    /// Total worker threads the server will actually spawn — the one
    /// definition shared by thread spawning and plan compilation, so the
    /// pool size the thread-partitioning pass sees can never drift from
    /// the pool that exists.
    fn total_threads(&self) -> usize {
        self.workers.max(1).max(self.devices.max(1))
    }

    /// The plan-compilation environment this configuration implies: the
    /// executor shares the host with the whole worker pool, so compiled
    /// plans stay single-thread unless the pool is a single worker.
    fn plan_env(&self) -> PlanEnv {
        PlanEnv::for_pool(self.total_threads()).with_force(self.plan)
    }
}

/// One unit of work on a device queue.
enum WorkItem {
    /// A same-variant batch: one batched-GEMM runtime call.
    Batch { variant: String, batch: Vec<Queued<Job>> },
    /// One shard of a sharded request.
    Shard(ShardTask),
}

struct ShardTask {
    job: Arc<ShardedJob>,
    shard_idx: usize,
    program: Program,
    /// The shard's own compiled plan (derived from the shard shape).
    eplan: Arc<ExecutionPlan>,
    inputs: Vec<Tensor>,
    /// For row shards of a weight-bound request: the shared bind-time
    /// operand (prepacked panels consumed as-is on every device).
    bound: Option<Arc<BoundB>>,
}

/// Shared state of one sharded request; the worker completing the final
/// shard performs the reduction and sends the response.
struct ShardedJob {
    id: u64,
    variant: String,
    /// The request-level plan id (metrics attribute the completed
    /// request here; per-shard flops go to each shard plan's id).
    plan_id: String,
    /// The request-level plan's ISA lowering label (`scalar` or
    /// `simd:<isa>`), feeding the per-ISA metrics rollup.
    isa_label: String,
    /// Pack-cache outcome of this request, recorded once on completion:
    /// (hits, misses, payload bytes saved).
    pack: (u64, u64, f64),
    /// Bind epoch of the routed weights (weight-bound requests only),
    /// echoed on the response by the last finisher.
    bound_epoch: Option<u64>,
    /// Admission-time queue depth, echoed on the response.
    admit_depth: usize,
    submitted_at: Instant,
    /// Set by the first worker to start a shard: splits queue wait from
    /// execution time the same way the batch path does.
    exec_started: Mutex<Option<Instant>>,
    plan: ShardPlan,
    base: Program,
    c: Tensor,
    bias: Option<Tensor>,
    /// Taken exactly once, by whichever worker completes the job
    /// (mutex-wrapped so the shared job is `Sync` on every toolchain).
    reply: Mutex<Option<Sender<GemmResponse>>>,
    parts: Mutex<Vec<Option<Result<Tensor>>>>,
    remaining: AtomicUsize,
}

pub struct Server {
    submit_tx: SyncSender<Job>,
    queue_capacity: usize,
    next_id: AtomicU64,
    metrics: Arc<Metrics>,
    registry: Arc<Registry>,
    faults: Arc<FaultState>,
    shadow: Option<Arc<ShadowState>>,
    /// Jobs currently buffered in the submit channel (incremented at
    /// admission, decremented when the dispatcher drains one) — the
    /// live depth behind every response's `queue_depth`.
    queue_depth: Arc<AtomicUsize>,
    /// Admitted-job count per tenant (submit channel + scheduler),
    /// maintained only when `tenant_quota > 0`.
    tenant_ledger: Arc<Mutex<HashMap<String, usize>>>,
    tenant_quota: usize,
    dispatcher: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
}

/// Release one admitted job's tenant billing.  No-op for untenanted
/// jobs and for tenants with no live entry (quota disabled).
fn tenant_unbill(ledger: &Mutex<HashMap<String, usize>>, tenant: &Option<String>) {
    if let Some(t) = tenant {
        let mut g = ledger.lock().unwrap();
        if let Some(n) = g.get_mut(t) {
            *n = n.saturating_sub(1);
            if *n == 0 {
                g.remove(t);
            }
        }
    }
}

impl Server {
    pub fn start(runtime: Arc<Runtime>, device: &DeviceModel, cfg: ServerConfig) -> Server {
        let mut registry = Registry::build(runtime.artifacts(), device, cfg.plan_env());
        if cfg.rerank_measured {
            registry.rerank_measured(|name| {
                let artifact = runtime.load(name).ok()?;
                let inputs = crate::harness::random_inputs(&artifact, 0, 0.5);
                // one warmup (compilation), one timed run
                runtime.execute_timed(&artifact, &inputs).ok()?;
                let (_, t) = runtime.execute_timed(&artifact, &inputs).ok()?;
                Some(t.exec_seconds)
            });
        }
        Self::start_with_registry(runtime, Arc::new(registry), cfg)
    }

    pub fn start_with_registry(
        runtime: Arc<Runtime>,
        registry: Arc<Registry>,
        cfg: ServerConfig,
    ) -> Server {
        let plan_env = Arc::new(cfg.plan_env());
        let metrics = Arc::new(Metrics::new());
        let faults = Arc::new(FaultState::new(cfg.faults.clone()));
        // Preseed the report with every registry-compiled plan so an idle
        // key is still visible.
        for (_key, p) in registry.plans() {
            metrics.on_plan_seen(&p.id(), &p.isa_label());
        }
        // Shadow tuning: one state shared by every worker.  Warm-load
        // persisted promotions *before* any request can route, so a
        // restarted server serves its measured plans from request one —
        // with no re-measurement (warm-loaded keys start decided).
        let shadow: Option<Arc<ShadowState>> = if cfg.shadow.enabled {
            let st = Arc::new(ShadowState::new(cfg.shadow.clone(), cfg.total_threads()));
            if let Err(e) = st.warm_load(&registry, &metrics) {
                eprintln!("shadow: plan db warm load failed: {e:#}");
            }
            Some(st)
        } else {
            None
        };
        // Bounded admission: submit() uses try_send, so a full buffer is
        // an immediate, explicit rejection — never unbounded memory and
        // never a blocked client thread.
        let queue_capacity = cfg.queue_capacity.max(1);
        let (submit_tx, submit_rx) = mpsc::sync_channel::<Job>(queue_capacity);
        // Live submit-channel depth (the backpressure signal) and the
        // per-tenant admitted-job ledger behind AdmissionConfig quotas.
        let queue_depth = Arc::new(AtomicUsize::new(0));
        let tenant_ledger: Arc<Mutex<HashMap<String, usize>>> =
            Arc::new(Mutex::new(HashMap::new()));
        let tenant_quota = cfg.admission.tenant_quota;

        // Per-device work queues; worker threads spread across them so
        // every device context has at least one executor.
        let devices = cfg.devices.max(1);
        let total_threads = cfg.total_threads();
        let threads_base = total_threads / devices;
        let threads_rem = total_threads % devices;
        // Free-slot accounting for continuous release: work items in
        // flight per device, against that device's executor-thread count.
        // Heuristic gate only (Relaxed; the worker decrements after it
        // finishes an item), never a correctness invariant.
        let device_threads: Vec<usize> = (0..devices)
            .map(|dev| threads_base + usize::from(dev < threads_rem))
            .collect();
        let inflight: Arc<Vec<AtomicUsize>> =
            Arc::new((0..devices).map(|_| AtomicUsize::new(0)).collect());
        let mut device_txs: Vec<Sender<WorkItem>> = Vec::with_capacity(devices);
        let mut workers = Vec::new();
        for dev in 0..devices {
            let (tx, rx) = mpsc::channel::<WorkItem>();
            let rx = Arc::new(Mutex::new(rx));
            device_txs.push(tx);
            for _ in 0..device_threads[dev] {
                let rt = runtime.clone();
                let rx = rx.clone();
                let m = metrics.clone();
                let worker_env = plan_env.clone();
                let flt = faults.clone();
                let reg = registry.clone();
                let sh = shadow.clone();
                let infl = inflight.clone();
                workers.push(std::thread::spawn(move || loop {
                    let msg = {
                        let guard = rx.lock().unwrap();
                        guard.recv()
                    };
                    let Ok(item) = msg else { break };
                    match item {
                        WorkItem::Batch { variant, batch } => {
                            run_batch(
                                &rt,
                                &reg,
                                &m,
                                &worker_env,
                                &flt,
                                sh.as_deref(),
                                dev,
                                &variant,
                                batch,
                            );
                        }
                        WorkItem::Shard(task) => {
                            let started = Instant::now();
                            {
                                let mut g =
                                    task.job.exec_started.lock().unwrap();
                                if g.is_none() {
                                    *g = Some(started);
                                }
                            }
                            // Shard execution is contained the same way
                            // batches are: a panic (injected poison or a
                            // real kernel bug) becomes an explicit Err for
                            // this shard, the last-finisher reduction turns
                            // it into an error response, and the worker
                            // thread survives to serve the next item.
                            let result = catch_unwind(AssertUnwindSafe(|| {
                                flt.slow_exec();
                                flt.poison_gate(&[task.job.id]);
                                sharding::execute_shard(
                                    &task.program,
                                    &task.eplan,
                                    &task.inputs,
                                    task.bound.as_deref(),
                                )
                            }))
                            .unwrap_or_else(|_| {
                                Err(anyhow!(
                                    "{ERR_POISONED}: shard {} of request {} \
                                     panicked during execution; shard failed, \
                                     worker recovered",
                                    task.shard_idx,
                                    task.job.id
                                ))
                            });
                            let busy = started.elapsed().as_secs_f64();
                            m.on_device_task(dev, busy);
                            // Per-shard plan attribution: true executor
                            // busy time under the shard's own compiled
                            // plan (shard flops sum to the whole job's
                            // across the shard set).
                            if result.is_ok() {
                                if let Program::Gemm { m: sm, n: sn, k: sk, .. } =
                                    task.program
                                {
                                    m.on_plan_work(
                                        &task.eplan.id(),
                                        &task.eplan.isa_label(),
                                        0,
                                        2.0 * sm as f64 * sn as f64 * sk as f64,
                                        busy,
                                    );
                                }
                            }
                            finish_shard(&m, &task.job, task.shard_idx, result);
                        }
                    }
                    // Free the execution slot this item occupied; the
                    // dispatcher's continuous-release gate watches it.
                    infl[dev].fetch_sub(1, Ordering::Relaxed);
                }));
            }
        }

        // Dispatcher: route + continuous-release + shard fan-out.
        let reg = registry.clone();
        let met = metrics.clone();
        let rt = runtime.clone();
        let env = plan_env.clone();
        let batcher_cfg = cfg.batcher.clone();
        let shard_cfg = cfg.shard.clone();
        let flt = faults.clone();
        let depth = queue_depth.clone();
        let ledger = tenant_ledger.clone();
        let infl = inflight.clone();
        let dispatcher = std::thread::spawn(move || {
            // Hold-until-shutdown hook: fault replays park the dispatcher
            // here so every submit of a schedule lands in the channel
            // before routing starts.  No-op unless the plan engages it.
            flt.wait_dispatch_released();
            let mut sched: Scheduler<Job> = Scheduler::new(batcher_cfg);
            let mut poll = Duration::from_millis(1);
            let mut rr = 0usize;
            // Release a job's tenant billing the moment it stops being
            // admitted: released to a device, expired, or failed at
            // routing.  Exactly once per admitted job.
            let bill_out = |job: &Job| tenant_unbill(&ledger, &job.tenant);
            'main: loop {
                // No stop-flag break in this loop: the dispatcher exits
                // only on Disconnected below.  Shutdown signals by
                // dropping the submit sender, and the channel hands over
                // every already-buffered job before reporting
                // Disconnected — so a submit that raced the shutdown can
                // never be dropped without a response (a stop-flag break
                // could strand buffered jobs and leak their reply
                // channels; pinned by the server stress test).
                //
                // TEST HOOK (FaultPlan::stop_flag_break): the protocol
                // checker proves that exact break is a bug by
                // re-introducing it here, behind an off-by-default plan
                // flag, and replaying the model's counterexample schedule
                // (hold every submit in the channel, raise the stop flag,
                // release the dispatcher) against this code.  Guarded so
                // production servers never take the branch.
                if flt.stop_flag_break_armed() && sched.is_empty() {
                    break 'main;
                }
                let mut enqueue = |mut job: Job| {
                    // Deadline gate at the channel -> scheduler boundary:
                    // a job that expired while buffered is answered now,
                    // never routed.
                    if let Some(dl) = job.deadline {
                        let now = Instant::now();
                        if dl <= now {
                            let wait = now.duration_since(job.submitted_at);
                            met.on_deadline_expired(wait.as_secs_f64());
                            met.on_priority_expired(job.priority.label());
                            bill_out(&job);
                            let _ = job.reply.send(GemmResponse::failure(
                                job.id,
                                "",
                                deadline_error(wait),
                                job.submitted_at,
                                wait,
                            ));
                            return;
                        }
                    }
                    let routed = match &job.kind {
                        JobKind::Gemm(req) => {
                            route(&reg, &env, req).map(|r| {
                                job.plan = Some(r.plan);
                                if let Some((epoch, bw)) = r.bound {
                                    job.bound_epoch = Some(epoch);
                                    job.bound = Some(bw);
                                }
                                r.variant
                            })
                        }
                        JobKind::Program(req) => {
                            route_program(&rt, &reg, req).map(|(v, pp)| {
                                job.pplan = Some(pp);
                                v
                            })
                        }
                    };
                    // Fault point: linger between capturing the routing
                    // decision (plan + bound weights + epoch) and the
                    // scheduler — the window a concurrent rebind races.
                    flt.delay_route();
                    match routed {
                        Ok(v) => sched.push(Queued {
                            variant: v,
                            enqueued_at: job.submitted_at,
                            priority: job.priority,
                            deadline: job.deadline,
                            payload: job,
                        }),
                        Err(e) => {
                            met.on_fail();
                            bill_out(&job);
                            let _ = job.reply.send(GemmResponse::failure(
                                job.id,
                                "",
                                e,
                                job.submitted_at,
                                Duration::ZERO,
                            ));
                        }
                    }
                };
                match submit_rx.recv_timeout(poll) {
                    Ok(job) => {
                        depth.fetch_sub(1, Ordering::Relaxed);
                        enqueue(job);
                        // Drain any burst that arrived together so the
                        // scheduler sees the whole group at once.
                        while let Ok(job) = submit_rx.try_recv() {
                            depth.fetch_sub(1, Ordering::Relaxed);
                            enqueue(job);
                        }
                    }
                    Err(RecvTimeoutError::Timeout) => {}
                    Err(RecvTimeoutError::Disconnected) => break,
                }
                // Deadline sweep: a job can expire *after* routing while
                // it waits in the scheduler for a device to free up.
                // Answer those now instead of burning a worker on stale
                // output.
                let now = Instant::now();
                for q in sched.take_expired(now) {
                    let prio = q.priority;
                    let job = q.payload;
                    let wait = now.duration_since(job.submitted_at);
                    met.on_deadline_expired(wait.as_secs_f64());
                    met.on_priority_expired(prio.label());
                    bill_out(&job);
                    let _ = job.reply.send(GemmResponse::failure(
                        job.id,
                        &q.variant,
                        deadline_error(wait),
                        job.submitted_at,
                        wait,
                    ));
                }
                // Continuous release: the moment a device has a free
                // execution slot, hand it the most urgent admissible
                // micro-batch.  A lone request dispatches immediately —
                // no fixed window ever holds it back.
                loop {
                    let Some(dev) = (0..infl.len())
                        .find(|&d| infl[d].load(Ordering::Relaxed) < device_threads[d])
                    else {
                        // Every executor is busy.  Poll fast while work
                        // waits so the next free slot is claimed promptly.
                        poll = if sched.is_empty() {
                            Duration::from_millis(1)
                        } else {
                            Duration::from_micros(100)
                        };
                        break;
                    };
                    let Some(rel) = sched.next_release(Instant::now()) else {
                        poll = Duration::from_millis(1);
                        break;
                    };
                    let released_at = Instant::now();
                    for q in &rel.batch {
                        met.on_priority_release(
                            q.priority.label(),
                            released_at.duration_since(q.enqueued_at).as_secs_f64(),
                        );
                        bill_out(&q.payload);
                    }
                    if !handle_run(
                        &rt, &met, &env, &shard_cfg, &device_txs, &infl, &mut rr,
                        dev, rel.variant, rel.batch,
                    ) {
                        break 'main;
                    }
                }
            }
            // Drain on shutdown: flush everything still queued, ignoring
            // the free-slot gate (workers drain their queues before they
            // exit, so queued-behind-busy is fine here).
            loop {
                let Some(rel) = sched.next_release(Instant::now()) else { break };
                let released_at = Instant::now();
                for q in &rel.batch {
                    met.on_priority_release(
                        q.priority.label(),
                        released_at.duration_since(q.enqueued_at).as_secs_f64(),
                    );
                    bill_out(&q.payload);
                }
                let dev = rr % device_txs.len();
                rr = rr.wrapping_add(1);
                if !handle_run(
                    &rt, &met, &env, &shard_cfg, &device_txs, &infl, &mut rr, dev,
                    rel.variant, rel.batch,
                ) {
                    break;
                }
            }
            // If the workers died mid-stream, jobs may still sit in the
            // scheduler after the drain bailed: fail each one explicitly
            // so submitted == completed + failed holds and callers get an
            // error response instead of a dead channel.
            while let Some(rel) = sched.next_release(Instant::now()) {
                for q in rel.batch {
                    bill_out(&q.payload);
                    let Job { id, submitted_at, reply, .. } = q.payload;
                    met.on_fail();
                    let _ = reply.send(GemmResponse::failure(
                        id,
                        "",
                        anyhow!("server worker pool is gone"),
                        submitted_at,
                        Duration::ZERO,
                    ));
                }
            }
            drop(device_txs);
        });

        Server {
            submit_tx,
            queue_capacity,
            next_id: AtomicU64::new(0),
            metrics,
            registry,
            faults,
            shadow,
            queue_depth,
            tenant_ledger,
            tenant_quota,
            dispatcher: Some(dispatcher),
            workers,
        }
    }

    /// Submit a request; the response arrives on the returned channel.
    pub fn submit(&self, request: GemmRequest) -> Receiver<GemmResponse> {
        self.submit_kind(JobKind::Gemm(request), SubmitOpts::default())
    }

    /// Submit with explicit admission options: the tenant the request
    /// bills against and its dispatch priority tier ([`SubmitOpts`]).
    pub fn submit_with(
        &self,
        request: GemmRequest,
        opts: SubmitOpts,
    ) -> Receiver<GemmResponse> {
        self.submit_kind(JobKind::Gemm(request), opts)
    }

    /// Submit a composite-program request ([`ProgramRequest`]); the
    /// response arrives on the returned channel.  Program jobs batch per
    /// artifact and execute under the registry-cached [`ProgramPlan`],
    /// with per-plan metrics attribution separate from GEMM traffic.
    pub fn submit_program(&self, request: ProgramRequest) -> Receiver<GemmResponse> {
        self.submit_kind(JobKind::Program(request), SubmitOpts::default())
    }

    /// [`Server::submit_program`] with explicit admission options.
    pub fn submit_program_with(
        &self,
        request: ProgramRequest,
        opts: SubmitOpts,
    ) -> Receiver<GemmResponse> {
        self.submit_kind(JobKind::Program(request), opts)
    }

    fn submit_kind(&self, kind: JobKind, opts: SubmitOpts) -> Receiver<GemmResponse> {
        let (tx, rx) = mpsc::channel();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.metrics.on_submit();
        self.metrics.on_priority_submit(opts.priority.label());
        let deadline = match &kind {
            JobKind::Gemm(req) => req.deadline,
            JobKind::Program(_) => None,
        };
        let submitted_at = Instant::now();
        // A deadline already in the past is answered here, at admission:
        // it can never be served in time, so it must not consume a queue
        // slot or tenant budget that a feasible request could use.
        if let Some(dl) = deadline {
            if dl <= submitted_at {
                self.metrics.on_expired_at_admission();
                self.metrics.on_priority_expired(opts.priority.label());
                let _ = tx.send(GemmResponse::failure(
                    id,
                    "",
                    anyhow!(
                        "{ERR_DEADLINE}: deadline was already past at submit; \
                         refused at admission, no queue capacity consumed"
                    ),
                    submitted_at,
                    Duration::ZERO,
                ));
                return rx;
            }
        }
        // Per-tenant quota, checked before the global try_send: one
        // tenant at its admitted-job cap is rejected by name while other
        // tenants keep flowing through the shared queue.
        if self.tenant_quota > 0 {
            if let Some(t) = &opts.tenant {
                let mut g = self.tenant_ledger.lock().unwrap();
                let n = g.entry(t.clone()).or_insert(0);
                if *n >= self.tenant_quota {
                    drop(g);
                    self.metrics.on_tenant_reject(t);
                    let _ = tx.send(GemmResponse::failure(
                        id,
                        "",
                        anyhow!(
                            "{ERR_QUEUE_FULL}: tenant {t:?} at quota {} admitted \
                             jobs; retry after its in-flight work drains",
                            self.tenant_quota
                        ),
                        submitted_at,
                        Duration::ZERO,
                    ));
                    return rx;
                }
                *n += 1;
            }
        }
        // Count the job into the live depth *before* try_send so the
        // dispatcher's decrement (which can race this submit) never
        // underflows; the failure arms below uncount it.
        let admit_depth = self.queue_depth.fetch_add(1, Ordering::Relaxed) + 1;
        self.metrics.on_queue_depth(admit_depth);
        let job = Job {
            id,
            kind,
            submitted_at,
            reply: tx,
            plan: None,  // attached by the dispatcher at routing time
            pplan: None, // ditto (composite-program jobs)
            bound: None, // ditto
            bound_epoch: None, // ditto
            deadline,
            priority: opts.priority,
            tenant: opts.tenant,
            admit_depth,
        };
        match self.submit_tx.try_send(job) {
            Ok(()) => {}
            Err(TrySendError::Full(job)) => {
                // Bounded admission: the queue is at capacity.  Reject
                // immediately and explicitly — never block the client,
                // never buffer unboundedly.  Rejections are their own
                // metrics bucket, keeping
                // `submitted == completed + failed + rejected` exact.
                self.queue_depth.fetch_sub(1, Ordering::Relaxed);
                tenant_unbill(&self.tenant_ledger, &job.tenant);
                self.metrics.on_reject();
                let _ = job.reply.send(GemmResponse {
                    queue_depth: self.queue_capacity,
                    ..GemmResponse::failure(
                        job.id,
                        "",
                        anyhow!(
                            "{ERR_QUEUE_FULL}: submit queue at capacity {}; \
                             retry later or raise ServerConfig::queue_capacity",
                            self.queue_capacity
                        ),
                        job.submitted_at,
                        Duration::ZERO,
                    )
                });
            }
            Err(TrySendError::Disconnected(job)) => {
                // The dispatcher is gone (shutdown raced the submit).
                // Account the failure so `submitted` can never permanently
                // exceed `completed + failed + rejected`, and hand the
                // caller an explicit error instead of a dropped channel.
                self.queue_depth.fetch_sub(1, Ordering::Relaxed);
                tenant_unbill(&self.tenant_ledger, &job.tenant);
                self.metrics.on_fail();
                let _ = job.reply.send(GemmResponse::failure(
                    job.id,
                    "",
                    anyhow!("{ERR_SHUTDOWN}"),
                    job.submitted_at,
                    Duration::ZERO,
                ));
            }
        }
        rx
    }

    /// Convenience: submit and block for the result.
    pub fn call(&self, request: GemmRequest) -> Result<GemmResponse> {
        let rx = self.submit(request);
        rx.recv().map_err(|_| anyhow!("server shut down"))
    }

    /// Convenience: submit a composite-program request and block.
    pub fn call_program(&self, request: ProgramRequest) -> Result<GemmResponse> {
        let rx = self.submit_program(request);
        rx.recv().map_err(|_| anyhow!("server shut down"))
    }

    pub fn metrics(&self) -> MetricsSnapshot {
        self.metrics.snapshot()
    }

    pub fn registry(&self) -> &Registry {
        &self.registry
    }

    /// The live fault-injection state (counters of injected panics and
    /// delays).  Tests use it to prove a seeded schedule actually fired.
    pub fn faults(&self) -> &FaultState {
        &self.faults
    }

    /// Jobs currently buffered in the submit channel — the live depth
    /// behind every response's `queue_depth` backpressure signal.
    pub fn queue_depth(&self) -> usize {
        self.queue_depth.load(Ordering::Relaxed)
    }

    /// The shadow-tuning state, when enabled ([`ServerConfig::shadow`]).
    /// Tests read its counters to prove sampling/promotion happened (or,
    /// after a warm restart, that it did *not* re-measure).
    pub fn shadow(&self) -> Option<&ShadowState> {
        self.shadow.as_deref()
    }

    /// Bind a constant B weight for `key` (the model-serving form: the
    /// weight matrix lives server-side).  Cast and — when the key's plan
    /// prepacks — panel-packed exactly once, here; every subsequent
    /// `GemmRequest` with `b: None` is served from the shared, immutable
    /// result.  Shape mismatches fail here, at bind time.  Rebinding
    /// swaps the weights atomically: requests routed after the rebind
    /// can never see the old panels.
    pub fn bind_weights(&self, key: &GemmKey, b: &Tensor) -> Result<()> {
        self.registry.bind_weights(key, b).map(|_| ())
    }

    /// Drop `key`'s bound weights; weight-bound requests for it fail
    /// explicitly afterwards.  Returns whether anything was bound.
    pub fn unbind_weights(&self, key: &GemmKey) -> bool {
        self.registry.unbind_weights(key)
    }

    /// Stop accepting work, drain the queues, join every thread.
    /// Idempotent; the server remains usable for `metrics()` afterwards,
    /// and late `submit` calls get explicit error responses.
    pub fn shutdown(&mut self) -> MetricsSnapshot {
        // Raise the fault layer's stop flag *before* closing the channel
        // (the PR 5 bug ordering, so the stop-flag-break hook reproduces
        // it faithfully) and release a held dispatcher.  Both are no-ops
        // under the default FaultPlan.
        self.faults.on_shutdown();
        // Closing the submit channel is the one shutdown signal: the
        // dispatcher drains every job already buffered in the channel
        // (the mpsc contract delivers them before Disconnected), then
        // flushes the batcher and exits — no stop flag that could race
        // a concurrent submit into a dropped job.
        let (dead_tx, _) = mpsc::sync_channel(1);
        let old = std::mem::replace(&mut self.submit_tx, dead_tx);
        drop(old);
        if let Some(d) = self.dispatcher.take() {
            let _ = d.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        self.metrics.snapshot()
    }
}

/// The [`ERR_DEADLINE`] error every expiry site produces, with the queue
/// wait the job burned — one shape, greppable, attributable.
fn deadline_error(queue_wait: Duration) -> anyhow::Error {
    anyhow!(
        "{ERR_DEADLINE}: request expired after {:.3} ms queued, before execution",
        queue_wait.as_secs_f64() * 1e3
    )
}

/// One routing decision: the variant name, the compiled plan, and (for
/// the weight-bound form) the captured weights with their bind epoch.
struct RoutedGemm {
    variant: String,
    plan: Arc<ExecutionPlan>,
    bound: Option<(u64, Arc<BoundB>)>,
}

/// Route a request to its artifact, its compiled plan, and (for the
/// weight-bound request form) the currently bound weights.  Plans come
/// from the registry cache; a key the registry somehow never compiled
/// (manually assembled registries) compiles on the spot under the
/// server's environment.  A `b: None` request without bound weights is
/// an explicit routing error, never a silent zero-B execution.
fn route(
    registry: &Registry,
    env: &PlanEnv,
    req: &GemmRequest,
) -> Result<RoutedGemm> {
    let artifact = if req.use_baseline {
        registry
            .baseline(&req.key)
            .map(str::to_string)
            .ok_or_else(|| anyhow!("no baseline artifact for {:?}", req.key))?
    } else {
        registry
            .best(&req.key)
            .map(|e| e.artifact.clone())
            .ok_or_else(|| anyhow!("no kernel variant registered for {:?}", req.key))?
    };
    // `serving_plan` overlays any shadow-promoted plan on the compiled
    // one; the Arc captured here is what this request executes under even
    // if a promotion lands mid-flight (swap is atomic, routing is not
    // retroactive).
    let eplan = match registry.serving_plan(&req.key) {
        Some(p) => p,
        None => Arc::new(plan::compile(&req.key, env)?),
    };
    // An inline B always wins: the request carries its own operand even
    // when weights happen to be bound (A/B testing, one-off overrides).
    // The bound form captures (epoch, Arc) in one registry lock
    // acquisition: a bind that completed before this route is visible
    // here with its own epoch, so the response's `bound_epoch` lets the
    // client verify no stale panels served its request.
    let bound = if req.b.is_none() {
        Some(registry.bound_weights_versioned(&req.key).ok_or_else(|| {
            anyhow!(
                "request for {:?} carried no B operand and no weights are bound \
                 (bind_weights first, or ship B inline)",
                req.key
            )
        })?)
    } else {
        None
    };
    let variant =
        if bound.is_some() { format!("{artifact}{BOUND_SUFFIX}") } else { artifact };
    Ok(RoutedGemm { variant, plan: eplan, bound })
}

/// Route a composite-program request: the variant is the artifact name,
/// and the plan is the graph-level [`ProgramPlan`] — registry-cached per
/// artifact, populated from the runtime's load-time compilation on first
/// route.  A GEMM artifact routed here is an explicit error (it has a
/// [`GemmKey`] and belongs on the [`GemmRequest`] path).
fn route_program(
    rt: &Runtime,
    registry: &Registry,
    req: &ProgramRequest,
) -> Result<(String, Arc<ProgramPlan>)> {
    if let Some(pp) = registry.program_plan(&req.artifact) {
        return Ok((req.artifact.clone(), pp));
    }
    let artifact = rt.load(&req.artifact)?;
    let pp = artifact.program_plan().cloned().ok_or_else(|| {
        anyhow!(
            "artifact {:?} is not a composite program (submit it as a GemmRequest)",
            req.artifact
        )
    })?;
    registry.cache_program_plan(&req.artifact, pp.clone());
    Ok((req.artifact.clone(), pp))
}

/// Dispatch one released batch: shard it across the pool when the shard
/// planner says so, otherwise send the whole batch to `dev` — the queue
/// the dispatcher's free-slot gate picked.  Every send bumps that
/// device's inflight counter (workers decrement on completion).
/// Returns false when the workers are gone.
#[allow(clippy::too_many_arguments)]
fn handle_run(
    rt: &Runtime,
    met: &Metrics,
    env: &PlanEnv,
    shard_cfg: &ShardConfig,
    device_txs: &[Sender<WorkItem>],
    inflight: &[AtomicUsize],
    rr: &mut usize,
    dev: usize,
    variant: String,
    batch: Vec<Queued<Job>>,
) -> bool {
    let devices = device_txs.len();
    // As in run_batch: the bound form comes from the jobs, the suffix is
    // only stripped when the form says so.
    let batch_is_bound =
        batch.first().map(|q| q.payload.bound.is_some()).unwrap_or(false);
    let artifact_name =
        if batch_is_bound { artifact_of(&variant) } else { variant.as_str() };
    if devices > 1 {
        if let Ok(artifact) = rt.load(artifact_name) {
            if let Some(splan) = sharding::plan_for(artifact.program(), devices, shard_cfg)
            {
                let program = artifact.program().clone();
                met.on_batch(batch.len());
                for q in batch {
                    // Rotate the shard->device base per job: a plan with
                    // fewer shards than devices would otherwise pin work
                    // to devices 0..n_shards and idle the rest.
                    let base = *rr;
                    *rr += 1;
                    dispatch_sharded(
                        q.payload, &variant, &program, env, &splan, base, device_txs,
                        inflight, met,
                    );
                }
                return true;
            }
        }
        // Load errors fall through to the batch path, which reports them
        // per item.
    }
    inflight[dev].fetch_add(1, Ordering::Relaxed);
    match device_txs[dev].send(WorkItem::Batch { variant, batch }) {
        Ok(()) => true,
        Err(mpsc::SendError(item)) => {
            inflight[dev].fetch_sub(1, Ordering::Relaxed);
            // The device's workers are gone (e.g. a panic killed them):
            // fail every job in the recovered batch explicitly so the
            // submitted == completed + failed invariant survives, then
            // stop dispatching — late submits get error responses from
            // `Server::submit` once the dispatcher exits.
            if let WorkItem::Batch { variant, batch } = item {
                for q in batch {
                    let Job { id, submitted_at, reply, .. } = q.payload;
                    met.on_fail();
                    let _ = reply.send(GemmResponse::failure(
                        id,
                        &variant,
                        anyhow!("device worker is gone"),
                        submitted_at,
                        Duration::ZERO,
                    ));
                }
            }
            false
        }
    }
}

/// Fan one job's shards out to the device queues.
///
/// The operand split (including per-shard copies of B — see
/// [`sharding::shard_inputs`]) runs on the dispatcher thread; for very
/// large sharded requests this serializes the split memcpy ahead of
/// other routing.  Moving the split into the workers (operands shared
/// via `Arc`, sliced on-device) is the known follow-up once the executor
/// grows a borrowed-tensor API.
#[allow(clippy::too_many_arguments)]
fn dispatch_sharded(
    job: Job,
    variant: &str,
    base: &Program,
    env: &PlanEnv,
    splan: &ShardPlan,
    device_base: usize,
    device_txs: &[Sender<WorkItem>],
    inflight: &[AtomicUsize],
    metrics: &Metrics,
) {
    let Job {
        id,
        kind,
        submitted_at,
        reply,
        plan: request_plan,
        bound,
        bound_epoch,
        deadline,
        admit_depth,
        ..
    } = job;
    let JobKind::Gemm(GemmRequest { a, b, c, bias, .. }) = kind else {
        // Unreachable: the shard planner only fires for GEMM programs,
        // and program jobs route to artifacts without one.  Fail loudly
        // rather than silently dropping the reply if that ever changes.
        metrics.on_fail();
        let _ = reply.send(GemmResponse::failure(
            id,
            variant,
            anyhow!("composite-program jobs cannot shard"),
            submitted_at,
            Duration::ZERO,
        ));
        return;
    };
    let now = Instant::now();
    // Deadline gate at the fan-out boundary: a job that expired between
    // routing and shard dispatch is answered, never split and executed.
    if let Some(dl) = deadline {
        if dl <= now {
            let wait = now.duration_since(submitted_at);
            metrics.on_deadline_expired(wait.as_secs_f64());
            let _ = reply.send(GemmResponse::failure(
                id,
                variant,
                deadline_error(wait),
                submitted_at,
                wait,
            ));
            return;
        }
    }
    let tasks = match (&b, &bound) {
        // Weight-bound request: row shards share the bind-time operand,
        // split-K shards slice its cast raw B — no per-request B at all.
        (_, Some(bw)) => sharding::build_shard_tasks_bound(
            env,
            splan,
            base,
            &a,
            &c,
            bias.as_ref(),
            bw,
        ),
        (Some(b), None) => sharding::build_shard_tasks(
            env,
            splan,
            base,
            &a,
            b,
            &c,
            bias.as_ref(),
        )
        .map(|ts| ts.into_iter().map(|(p, e, i)| (p, e, i, None)).collect()),
        (None, None) => {
            Err(anyhow!("request has neither an inline nor a bound B operand"))
        }
    };
    let tasks = match tasks {
        Ok(t) => t,
        Err(e) => {
            metrics.on_fail();
            let _ = reply.send(GemmResponse::failure(
                id,
                variant,
                e,
                submitted_at,
                now.duration_since(submitted_at),
            ));
            return;
        }
    };
    // Pack-cache outcome, recorded once if the request completes: a
    // bound request saves its whole B payload; it hits the panel cache
    // when row shards consume prepacked panels, and an inline request on
    // a packing plan counts one per-call pack.
    let pack = match &bound {
        Some(bw) => {
            let hits = u64::from(
                bw.is_prepacked() && splan.dim == sharding::SplitDim::Rows,
            );
            (hits, 0, (4 * bw.k() * bw.n()) as f64)
        }
        None => {
            let packs = request_plan
                .as_ref()
                .map(|p| !matches!(p.kernel, KernelPolicy::Naive))
                .unwrap_or(false);
            (0, u64::from(packs), 0.0)
        }
    };
    let n_shards = tasks.len();
    let shared = Arc::new(ShardedJob {
        id,
        variant: variant.to_string(),
        plan_id: request_plan
            .as_ref()
            .map(|p| p.id())
            .unwrap_or_else(|| "unplanned".into()),
        isa_label: request_plan
            .as_ref()
            .map(|p| p.isa_label())
            .unwrap_or_else(|| "scalar".into()),
        pack,
        bound_epoch,
        admit_depth,
        submitted_at,
        exec_started: Mutex::new(None),
        plan: splan.clone(),
        base: base.clone(),
        c,
        bias,
        reply: Mutex::new(Some(reply)),
        parts: Mutex::new((0..n_shards).map(|_| None).collect()),
        remaining: AtomicUsize::new(n_shards),
    });
    for (idx, ((program, eplan, inputs, task_bound), shard)) in
        tasks.into_iter().zip(&shared.plan.shards).enumerate()
    {
        let item = WorkItem::Shard(ShardTask {
            job: shared.clone(),
            shard_idx: idx,
            program,
            eplan,
            inputs,
            bound: task_bound,
        });
        let dev = (shard.device + device_base) % device_txs.len();
        inflight[dev].fetch_add(1, Ordering::Relaxed);
        if device_txs[dev].send(item).is_err() {
            inflight[dev].fetch_sub(1, Ordering::Relaxed);
            finish_shard(metrics, &shared, idx, Err(anyhow!("device worker is gone")));
        }
    }
}

/// Record one shard's result; the caller completing the final shard
/// reduces the partials and sends the response.
fn finish_shard(
    metrics: &Metrics,
    sj: &Arc<ShardedJob>,
    shard_idx: usize,
    result: Result<Tensor>,
) {
    {
        let mut parts = sj.parts.lock().unwrap();
        parts[shard_idx] = Some(result);
    }
    if sj.remaining.fetch_sub(1, Ordering::AcqRel) != 1 {
        return;
    }
    let mut collected = Vec::with_capacity(sj.plan.shards.len());
    let mut first_err = None;
    {
        let mut parts = sj.parts.lock().unwrap();
        for slot in parts.iter_mut() {
            match slot.take() {
                Some(Ok(t)) => collected.push(t),
                Some(Err(e)) => {
                    first_err = Some(e);
                    break;
                }
                None => {
                    first_err = Some(anyhow!("missing shard output"));
                    break;
                }
            }
        }
    }
    let output = match first_err {
        Some(e) => Err(e),
        None => sharding::reduce_outputs(
            &sj.plan,
            &sj.base,
            &sj.c,
            sj.bias.as_ref(),
            &collected,
        ),
    };
    let finished = Instant::now();
    // First-shard start splits queue wait from execution, mirroring the
    // batch path; a job whose shards never ran (workers gone) reports
    // zero exec time and a full-length wait.
    let started = sj.exec_started.lock().unwrap().unwrap_or(finished);
    let exec_time = finished.duration_since(started);
    let queue_wait = started.duration_since(sj.submitted_at);
    let total = sj.submitted_at.elapsed();
    match &output {
        Ok(_) => {
            metrics.on_complete(
                &sj.variant,
                total.as_secs_f64(),
                queue_wait.as_secs_f64(),
                exec_time.as_secs_f64(),
            );
            // Flops and busy time were attributed per shard plan as each
            // one executed; here only the completed request is counted,
            // under the request-level plan id.
            metrics.on_plan_work(&sj.plan_id, &sj.isa_label, 1, 0.0, 0.0);
            let (hits, misses, saved) = sj.pack;
            metrics.on_pack(&sj.plan_id, hits, misses, saved);
        }
        Err(_) => metrics.on_fail(),
    }
    if let Some(reply) = sj.reply.lock().unwrap().take() {
        let _ = reply.send(GemmResponse {
            id: sj.id,
            output,
            variant: sj.variant.clone(),
            queue_wait,
            exec_time,
            total_latency: total,
            bound_epoch: sj.bound_epoch,
            queue_depth: sj.admit_depth,
        });
    }
}

/// Execute one same-variant batch as a single batched runtime call.
///
/// Items are validated individually first so one malformed request fails
/// alone instead of poisoning the batch; the survivors run through
/// [`Runtime::execute_batch_timed`] (stacked operands, one pack/unpack)
/// and fan back out to their per-request channels.
///
/// Execution runs inside `catch_unwind`: a panic (an injected poison job
/// or a real executor bug) never kills the worker thread.  On panic the
/// batch is *quarantined* — every item re-executes alone, each under its
/// own containment, so the one poisoned job fails loudly with an
/// [`ERR_POISONED`] response while the rest of the batch still completes.
#[allow(clippy::too_many_arguments)]
fn run_batch(
    rt: &Runtime,
    registry: &Registry,
    metrics: &Metrics,
    env: &PlanEnv,
    faults: &FaultState,
    shadow: Option<&ShadowState>,
    device: usize,
    variant: &str,
    batch: Vec<Queued<Job>>,
) {
    metrics.on_batch(batch.len());
    let exec_started = Instant::now();
    // Program jobs never mix with GEMM jobs: the batcher groups by
    // variant, and an artifact routes exclusively down one path (a
    // composite program has no GemmKey; a GEMM has no ProgramPlan).
    let is_program = batch
        .first()
        .map(|q| matches!(q.payload.kind, JobKind::Program(_)))
        .unwrap_or(false);
    if is_program {
        run_program_batch(rt, metrics, faults, device, variant, batch, exec_started);
        return;
    }
    // Bound and inline jobs never share a batch: routing appends
    // BOUND_SUFFIX to the variant, so the batcher keeps them apart.  The
    // form itself is read off the jobs (ground truth), not the name —
    // an artifact whose manifest name happens to end in "+bound" still
    // routes inline traffic correctly, with nothing stripped.
    let is_bound = batch.first().map(|q| q.payload.bound.is_some()).unwrap_or(false);
    let artifact_name = if is_bound { artifact_of(variant) } else { variant };
    let artifact = match rt.load(artifact_name) {
        Ok(a) => a,
        Err(e) => {
            let msg = format!("{e:#}");
            for q in batch {
                let Job { id, submitted_at, reply, .. } = q.payload;
                metrics.on_fail();
                let _ = reply.send(GemmResponse::failure(
                    id,
                    variant,
                    anyhow!("{msg}"),
                    submitted_at,
                    exec_started.duration_since(submitted_at),
                ));
            }
            return;
        }
    };
    // The manifest specs each item validates against: the full contract,
    // or (weight-bound form) the contract minus the bound B slot.
    let specs: Vec<&TensorSpec> = artifact
        .meta
        .inputs
        .iter()
        .enumerate()
        .filter(|(i, _)| !(is_bound && *i == crate::runtime::GEMM_B_INPUT_SLOT))
        .map(|(_, s)| s)
        .collect();
    // (id, submitted_at, reply, routed bind epoch, admission depth) per
    // surviving item.
    let mut jobs: Vec<(u64, Instant, Sender<GemmResponse>, Option<u64>, usize)> =
        Vec::with_capacity(batch.len());
    let mut items: Vec<Vec<Tensor>> = Vec::with_capacity(batch.len());
    // For bound batches: the BoundB Arc each valid item was routed with,
    // parallel to `items`.  A rebind can land between two routings inside
    // one batch window, so jobs of one batch may carry *different* Arcs —
    // execution below honors each job's own capture.
    let mut bounds: Vec<Arc<BoundB>> = Vec::new();
    // One plan per batch: the batcher groups by variant+form and every
    // job of a variant carries the same registry-cached plan.
    let mut batch_plan: Option<Arc<ExecutionPlan>> = None;
    for q in batch {
        let Job {
            id,
            kind,
            submitted_at,
            reply,
            plan,
            bound,
            bound_epoch,
            deadline,
            admit_depth,
            ..
        } = q.payload;
        if batch_plan.is_none() {
            batch_plan = plan;
        }
        // Final deadline gate, at the queue -> executor boundary: the
        // job may have expired while sitting in the device queue.
        if let Some(dl) = deadline {
            if dl <= exec_started {
                let wait = exec_started.duration_since(submitted_at);
                metrics.on_deadline_expired(wait.as_secs_f64());
                let _ = reply.send(GemmResponse::failure(
                    id,
                    variant,
                    deadline_error(wait),
                    submitted_at,
                    wait,
                ));
                continue;
            }
        }
        // Tensors are moved, not cloned: the request is consumed (hot-path
        // allocation discipline — EXPERIMENTS.md §Perf L3).
        let JobKind::Gemm(GemmRequest { a, b, c, bias, .. }) = kind else {
            // Defensive: `is_program` keyed off the first job, and the
            // batcher never mixes variants — but a mismatch must fail
            // the job, not the process.
            metrics.on_fail();
            let _ = reply.send(GemmResponse::failure(
                id,
                variant,
                anyhow!("program job in a GEMM batch"),
                submitted_at,
                exec_started.duration_since(submitted_at),
            ));
            continue;
        };
        let (inputs, job_bound) = match (is_bound, b, bound) {
            (true, _, Some(bw)) => {
                // Weight-bound form: A + C (+ bias); B comes from the
                // Arc this job captured at routing time (an inline B on
                // a bound-routed job cannot happen — routing keys the
                // form off the request).
                let mut v = vec![a, c];
                if let Some(bias) = bias {
                    v.push(bias);
                }
                (v, Some(bw))
            }
            (true, _, None) | (false, None, _) => {
                metrics.on_fail();
                let _ = reply.send(GemmResponse::failure(
                    id,
                    variant,
                    anyhow!("request has no B operand for its routed form"),
                    submitted_at,
                    exec_started.duration_since(submitted_at),
                ));
                continue;
            }
            (false, Some(b), _) => {
                let mut v = vec![a, b, c];
                if let Some(bias) = bias {
                    v.push(bias);
                }
                (v, None)
            }
        };
        let valid = inputs.len() == specs.len()
            && inputs
                .iter()
                .zip(specs.iter().copied())
                .all(|(t, spec)| t.matches(spec));
        if valid {
            jobs.push((id, submitted_at, reply, bound_epoch, admit_depth));
            if let Some(bw) = job_bound {
                bounds.push(bw);
            }
            items.push(inputs);
        } else {
            metrics.on_fail();
            let _ = reply.send(GemmResponse::failure(
                id,
                variant,
                anyhow!("request tensors do not match artifact {variant}"),
                submitted_at,
                exec_started.duration_since(submitted_at),
            ));
        }
    }
    if items.is_empty() {
        return;
    }
    // Per-item exec_time is the batched call's wall time (the latency the
    // item actually experienced in the executor), excluding artifact load
    // and the validation pass above.
    let call_started = Instant::now();
    let item_flops = match *artifact.program() {
        Program::Gemm { m, n, k, .. } => 2.0 * m as f64 * n as f64 * k as f64,
        _ => 0.0,
    };
    // The routed plan executes the batch — but only if it actually
    // describes this artifact's program.  A legacy store can route
    // through a key whose defaulted dtype_in disagrees with the program
    // (baselines predating precision-keyed routing); rather than fail
    // every request on the plan/program mismatch, recompile from the
    // program itself under the server's environment.
    let routed_ok = match (&batch_plan, artifact.program().gemm_key()) {
        (Some(p), Some(key)) => {
            p.matches_gemm(key.m, key.n, key.k, key.dtype_in, key.dtype_acc, &key.epilogue)
        }
        _ => false,
    };
    let eplan: Option<Arc<ExecutionPlan>> = if routed_ok {
        batch_plan
    } else {
        artifact.program().compile_plan(env).ok().map(Arc::new)
    };
    let plan_id = eplan
        .as_ref()
        .map(|p| p.id())
        .unwrap_or_else(|| "unplanned".to_string());
    let isa_label = eplan
        .as_ref()
        .map(|p| p.isa_label())
        .unwrap_or_else(|| "scalar".to_string());
    // Whole-batch execution, contained.  The fault gates live *inside*
    // the closure so an injected poison panic unwinds through the same
    // path a real executor bug would.
    let ids: Vec<u64> = jobs.iter().map(|(id, _, _, _, _)| *id).collect();
    let exec_whole = || -> Result<(Vec<Vec<Tensor>>, ExecTiming)> {
        faults.slow_exec();
        faults.poison_gate(&ids);
        if is_bound {
            match &eplan {
                None => {
                    Err(anyhow!("weight-bound batch for {variant} has no compiled plan"))
                }
                Some(p) if bounds.windows(2).all(|w| Arc::ptr_eq(&w[0], &w[1])) => {
                    // The overwhelmingly common case: one bind served the
                    // whole batch — a single batched call over it.
                    rt.execute_batch_timed_bound(&artifact, &items, p, &bounds[0])
                }
                Some(p) => {
                    // A rebind landed inside this batch window, so jobs
                    // carry different BoundB Arcs.  Execute each item under
                    // exactly the weights it was routed with — the rebind
                    // contract ("old panels never served to later routings")
                    // beats the lost batching of this rare split.
                    let mut outs = Vec::with_capacity(items.len());
                    let mut exec_seconds = 0.0f64;
                    let mut first_err = None;
                    for (item, bw) in items.iter().zip(&bounds) {
                        match rt.execute_batch_timed_bound(
                            &artifact,
                            std::slice::from_ref(item),
                            p,
                            bw,
                        ) {
                            Ok((mut o, t)) => {
                                exec_seconds += t.exec_seconds;
                                outs.push(o.remove(0));
                            }
                            Err(e) => {
                                first_err = Some(e);
                                break;
                            }
                        }
                    }
                    match first_err {
                        Some(e) => Err(e),
                        None => Ok((
                            outs,
                            ExecTiming {
                                pack_seconds: 0.0,
                                exec_seconds,
                                unpack_seconds: 0.0,
                            },
                        )),
                    }
                }
            }
        } else {
            rt.execute_batch_timed_planned(&artifact, &items, eplan.as_deref())
        }
    };
    let result = match catch_unwind(AssertUnwindSafe(exec_whole)) {
        Ok(result) => result,
        Err(_) => {
            // The batched execution panicked — an injected poison job or
            // a real executor bug.  Quarantine: re-execute every item
            // alone, each under its own containment, so the poisoned job
            // fails loudly with an explicit error while the rest of the
            // batch still completes.  Correctness and isolation over
            // throughput — this path only runs after a panic.
            let mut completed = 0u64;
            let mut busy_total = 0.0f64;
            for (idx, ((id, submitted_at, reply, epoch, depth), item)) in
                jobs.into_iter().zip(items.iter()).enumerate()
            {
                let item_started = Instant::now();
                let one = catch_unwind(AssertUnwindSafe(
                    || -> Result<Vec<Vec<Tensor>>> {
                        faults.poison_gate(&[id]);
                        if is_bound {
                            let p = eplan.as_ref().ok_or_else(|| {
                                anyhow!(
                                    "weight-bound batch for {variant} has no compiled plan"
                                )
                            })?;
                            rt.execute_batch_timed_bound(
                                &artifact,
                                std::slice::from_ref(item),
                                p,
                                &bounds[idx],
                            )
                            .map(|(o, _)| o)
                        } else {
                            rt.execute_batch_timed_planned(
                                &artifact,
                                std::slice::from_ref(item),
                                eplan.as_deref(),
                            )
                            .map(|(o, _)| o)
                        }
                    },
                ));
                let busy = item_started.elapsed();
                busy_total += busy.as_secs_f64();
                let output = match one {
                    Ok(Ok(mut outs)) => {
                        if outs.is_empty() || outs[0].is_empty() {
                            Err(anyhow!("artifact {variant} returned no outputs"))
                        } else {
                            Ok(outs.remove(0).remove(0))
                        }
                    }
                    Ok(Err(e)) => Err(e),
                    Err(_) => Err(anyhow!(
                        "{ERR_POISONED}: request {id} panicked during batch \
                         execution and was quarantined; the rest of the batch \
                         was unaffected"
                    )),
                };
                let queue_wait = exec_started.duration_since(submitted_at);
                let total = submitted_at.elapsed();
                match &output {
                    Ok(_) => {
                        metrics.on_complete(
                            variant,
                            total.as_secs_f64(),
                            queue_wait.as_secs_f64(),
                            busy.as_secs_f64(),
                        );
                        if item_flops > 0.0 {
                            metrics.on_plan_work(
                                &plan_id,
                                &isa_label,
                                1,
                                item_flops,
                                busy.as_secs_f64(),
                            );
                        }
                        completed += 1;
                    }
                    Err(_) => metrics.on_fail(),
                }
                faults.delay_reply();
                let _ = reply.send(GemmResponse {
                    id,
                    output,
                    variant: variant.to_string(),
                    queue_wait,
                    exec_time: busy,
                    total_latency: total,
                    bound_epoch: epoch,
                    queue_depth: depth,
                });
            }
            metrics.on_device_task(device, busy_total);
            // Pack accounting for the completed survivors (mirrors the
            // whole-batch path below).
            match (bounds.first(), &eplan) {
                (Some(bw), _) => {
                    let hits = if bw.is_prepacked() { completed } else { 0 };
                    metrics.on_pack(
                        &plan_id,
                        hits,
                        0,
                        (4 * bw.k() * bw.n()) as f64 * completed as f64,
                    );
                }
                (None, Some(p)) if !matches!(p.kernel, KernelPolicy::Naive) => {
                    metrics.on_pack(&plan_id, 0, completed, 0.0);
                }
                _ => {}
            }
            return;
        }
    };
    match result {
        Ok((outs, timing)) => {
            metrics.on_device_task(device, timing.exec_seconds);
            if item_flops > 0.0 {
                // Attributed to the plan that ran the work: a refined
                // (swapped) plan segments instead of blending.
                metrics.on_plan_work(
                    &plan_id,
                    &isa_label,
                    outs.len() as u64,
                    item_flops * outs.len() as f64,
                    timing.exec_seconds,
                );
            }
            // Pack-cache accounting: each completed bound item skipped
            // shipping 4·k·n B payload bytes, and — when the bind
            // prepacked — skipped pack_b itself (a hit); inline items on
            // a packing kernel paid a per-call pack (a miss).
            let n_items = outs.len() as u64;
            match (bounds.first(), &eplan) {
                (Some(bw), _) => {
                    // All bounds of one batch share the key (same k·n and
                    // the same prepack decision), so the first stands in
                    // for every item.
                    let hits = if bw.is_prepacked() { n_items } else { 0 };
                    metrics.on_pack(
                        &plan_id,
                        hits,
                        0,
                        (4 * bw.k() * bw.n()) as f64 * n_items as f64,
                    );
                }
                (None, Some(p)) if !matches!(p.kernel, KernelPolicy::Naive) => {
                    metrics.on_pack(&plan_id, 0, n_items, 0.0);
                }
                _ => {}
            }
            let exec_time = call_started.elapsed();
            // Shadow tuning rides here: after the client-visible timing is
            // captured (shadow work never inflates a reported latency) and
            // before `outs` is consumed by the replies below.  The hook
            // samples, re-executes under the SIMD candidate, verifies
            // against the outputs we are about to serve, and promotes.
            if let (Some(sh), Some(inc)) = (shadow, &eplan) {
                sh.observe_batch(
                    rt,
                    registry,
                    metrics,
                    &artifact,
                    inc,
                    &items,
                    &outs,
                    bounds.first(),
                    timing.exec_seconds,
                );
            }
            for ((id, submitted_at, reply, epoch, depth), mut out) in
                jobs.into_iter().zip(outs)
            {
                let queue_wait = exec_started.duration_since(submitted_at);
                let total = submitted_at.elapsed();
                let output = if out.is_empty() {
                    Err(anyhow!("artifact {variant} returned no outputs"))
                } else {
                    Ok(out.remove(0))
                };
                match &output {
                    Ok(_) => metrics.on_complete(
                        variant,
                        total.as_secs_f64(),
                        queue_wait.as_secs_f64(),
                        exec_time.as_secs_f64(),
                    ),
                    Err(_) => metrics.on_fail(),
                }
                faults.delay_reply();
                let _ = reply.send(GemmResponse {
                    id,
                    output,
                    variant: variant.to_string(),
                    queue_wait,
                    exec_time,
                    total_latency: total,
                    bound_epoch: epoch,
                    queue_depth: depth,
                });
            }
        }
        Err(e) => {
            // Whole-batch failure after per-item validation (artifact-level
            // problem): every surviving item reports the same error.
            let msg = format!("{e:#}");
            let exec_time = call_started.elapsed();
            for (id, submitted_at, reply, _epoch, depth) in jobs {
                metrics.on_fail();
                let _ = reply.send(GemmResponse {
                    exec_time,
                    queue_depth: depth,
                    ..GemmResponse::failure(
                        id,
                        variant,
                        anyhow!("{msg}"),
                        submitted_at,
                        exec_started.duration_since(submitted_at),
                    )
                });
            }
        }
    }
}

/// Execute one batch of composite-program jobs under the graph-level
/// [`ProgramPlan`] they were routed with.
///
/// Mirrors [`run_batch`]'s shape — per-item validation first, one batched
/// execution, per-job fan-out — but attribution comes from the program
/// plan (its id, ISA label, and whole-graph flops) so transformer traffic
/// segments separately from plain GEMM traffic in the metrics.
fn run_program_batch(
    rt: &Runtime,
    metrics: &Metrics,
    faults: &FaultState,
    device: usize,
    variant: &str,
    batch: Vec<Queued<Job>>,
    exec_started: Instant,
) {
    // Program variants carry the artifact name verbatim (never
    // BOUND_SUFFIX — binding is a runtime-level form, not a route).
    let artifact = match rt.load(variant) {
        Ok(a) => a,
        Err(e) => {
            let msg = format!("{e:#}");
            for q in batch {
                let Job { id, submitted_at, reply, .. } = q.payload;
                metrics.on_fail();
                let _ = reply.send(GemmResponse::failure(
                    id,
                    variant,
                    anyhow!("{msg}"),
                    submitted_at,
                    exec_started.duration_since(submitted_at),
                ));
            }
            return;
        }
    };
    let specs: Vec<&TensorSpec> = artifact.meta.inputs.iter().collect();
    let mut jobs: Vec<(u64, Instant, Sender<GemmResponse>, usize)> =
        Vec::with_capacity(batch.len());
    let mut items: Vec<Vec<Tensor>> = Vec::with_capacity(batch.len());
    // One program plan per batch: every job of a variant carries the same
    // registry-cached Arc.
    let mut batch_pplan: Option<Arc<ProgramPlan>> = None;
    for q in batch {
        let Job { id, kind, submitted_at, reply, pplan, admit_depth, .. } = q.payload;
        if batch_pplan.is_none() {
            batch_pplan = pplan;
        }
        let JobKind::Program(ProgramRequest { inputs, .. }) = kind else {
            metrics.on_fail();
            let _ = reply.send(GemmResponse::failure(
                id,
                variant,
                anyhow!("GEMM job in a program batch"),
                submitted_at,
                exec_started.duration_since(submitted_at),
            ));
            continue;
        };
        let valid = inputs.len() == specs.len()
            && inputs
                .iter()
                .zip(specs.iter().copied())
                .all(|(t, spec)| t.matches(spec));
        if valid {
            jobs.push((id, submitted_at, reply, admit_depth));
            items.push(inputs);
        } else {
            metrics.on_fail();
            let _ = reply.send(GemmResponse::failure(
                id,
                variant,
                anyhow!("request tensors do not match artifact {variant}"),
                submitted_at,
                exec_started.duration_since(submitted_at),
            ));
        }
    }
    if items.is_empty() {
        return;
    }
    // The routed plan drives execution when it still describes this
    // artifact's program (a reload can change shapes under a cached
    // route); otherwise fall back to the artifact's load-time plan via
    // the runtime dispatcher.
    let pp = batch_pplan
        .filter(|p| p.matches(artifact.program()))
        .or_else(|| artifact.program_plan().cloned());
    let call_started = Instant::now();
    // Contained, like the GEMM path: a panic quarantines the batch into
    // per-item contained re-execution instead of killing the worker.
    let ids: Vec<u64> = jobs.iter().map(|(id, _, _, _)| *id).collect();
    let exec_one = |item: &Vec<Tensor>| -> Result<(Vec<Vec<Tensor>>, ExecTiming)> {
        let t0 = Instant::now();
        match &pp {
            Some(pp) => artifact
                .program()
                .execute_batch_program_planned(std::slice::from_ref(item), pp)
                .map(|outs| {
                    let timing = ExecTiming {
                        pack_seconds: 0.0,
                        exec_seconds: t0.elapsed().as_secs_f64(),
                        unpack_seconds: 0.0,
                    };
                    (outs, timing)
                }),
            None => rt.execute_batch_timed_planned(&artifact, std::slice::from_ref(item), None),
        }
    };
    let whole = catch_unwind(AssertUnwindSafe(|| {
        faults.slow_exec();
        faults.poison_gate(&ids);
        match &pp {
            Some(pp) => artifact
                .program()
                .execute_batch_program_planned(&items, pp)
                .map(|outs| {
                    let timing = ExecTiming {
                        pack_seconds: 0.0,
                        exec_seconds: call_started.elapsed().as_secs_f64(),
                        unpack_seconds: 0.0,
                    };
                    (outs, timing)
                }),
            None => rt.execute_batch_timed_planned(&artifact, &items, None),
        }
    }));
    let result = match whole {
        Ok(result) => result,
        Err(_) => {
            // Quarantine (see run_batch): the poisoned program job fails
            // alone and loudly, the rest complete.
            let mut busy_total = 0.0f64;
            for ((id, submitted_at, reply, depth), item) in
                jobs.into_iter().zip(items.iter())
            {
                let item_started = Instant::now();
                let one = catch_unwind(AssertUnwindSafe(|| {
                    faults.poison_gate(&[id]);
                    exec_one(item)
                }));
                let busy = item_started.elapsed();
                busy_total += busy.as_secs_f64();
                let output = match one {
                    Ok(Ok((mut outs, _))) => {
                        if outs.is_empty() || outs[0].is_empty() {
                            Err(anyhow!("artifact {variant} returned no outputs"))
                        } else {
                            Ok(outs.remove(0).remove(0))
                        }
                    }
                    Ok(Err(e)) => Err(e),
                    Err(_) => Err(anyhow!(
                        "{ERR_POISONED}: request {id} panicked during batch \
                         execution and was quarantined; the rest of the batch \
                         was unaffected"
                    )),
                };
                let queue_wait = exec_started.duration_since(submitted_at);
                let total = submitted_at.elapsed();
                match &output {
                    Ok(_) => {
                        metrics.on_complete(
                            variant,
                            total.as_secs_f64(),
                            queue_wait.as_secs_f64(),
                            busy.as_secs_f64(),
                        );
                        if let Some(pp) = &pp {
                            metrics.on_plan_work(
                                &pp.id(),
                                &pp.isa_label(),
                                1,
                                pp.flops_per_item(),
                                busy.as_secs_f64(),
                            );
                        }
                    }
                    Err(_) => metrics.on_fail(),
                }
                faults.delay_reply();
                let _ = reply.send(GemmResponse {
                    id,
                    output,
                    variant: variant.to_string(),
                    queue_wait,
                    exec_time: busy,
                    total_latency: total,
                    bound_epoch: None,
                    queue_depth: depth,
                });
            }
            metrics.on_device_task(device, busy_total);
            return;
        }
    };
    match result {
        Ok((outs, timing)) => {
            metrics.on_device_task(device, timing.exec_seconds);
            if let Some(pp) = &pp {
                metrics.on_plan_work(
                    &pp.id(),
                    &pp.isa_label(),
                    outs.len() as u64,
                    pp.flops_per_item() * outs.len() as f64,
                    timing.exec_seconds,
                );
            }
            let exec_time = call_started.elapsed();
            for ((id, submitted_at, reply, depth), mut out) in
                jobs.into_iter().zip(outs)
            {
                let queue_wait = exec_started.duration_since(submitted_at);
                let total = submitted_at.elapsed();
                let output = if out.is_empty() {
                    Err(anyhow!("artifact {variant} returned no outputs"))
                } else {
                    Ok(out.remove(0))
                };
                match &output {
                    Ok(_) => metrics.on_complete(
                        variant,
                        total.as_secs_f64(),
                        queue_wait.as_secs_f64(),
                        exec_time.as_secs_f64(),
                    ),
                    Err(_) => metrics.on_fail(),
                }
                faults.delay_reply();
                let _ = reply.send(GemmResponse {
                    id,
                    output,
                    variant: variant.to_string(),
                    queue_wait,
                    exec_time,
                    total_latency: total,
                    bound_epoch: None,
                    queue_depth: depth,
                });
            }
        }
        Err(e) => {
            let msg = format!("{e:#}");
            let exec_time = call_started.elapsed();
            for (id, submitted_at, reply, depth) in jobs {
                metrics.on_fail();
                let _ = reply.send(GemmResponse {
                    exec_time,
                    queue_depth: depth,
                    ..GemmResponse::failure(
                        id,
                        variant,
                        anyhow!("{msg}"),
                        submitted_at,
                        exec_started.duration_since(submitted_at),
                    )
                });
            }
        }
    }
}
