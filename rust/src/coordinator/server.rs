//! The GEMM service: router + batcher + worker pool over the PJRT runtime.
//!
//! Requests are submitted from any thread; a dispatcher routes each to the
//! autotuned variant for its shape, batches same-variant requests, and
//! fans batches out to worker threads that execute on the shared PJRT
//! client.  Responses come back on per-request channels.  This is the
//! paper's missing run-time half: it generated kernels, we also serve them.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::runtime::{Runtime, Tensor};
use crate::sim::DeviceModel;

use super::batcher::{BatchDecision, Batcher, BatcherConfig, Queued};
use super::metrics::{Metrics, MetricsSnapshot};
use super::registry::{GemmKey, Registry};

/// A GEMM request: C = A @ B + C (+ optional fused epilogue inputs).
#[derive(Debug)]
pub struct GemmRequest {
    pub key: GemmKey,
    pub a: Tensor,
    pub b: Tensor,
    pub c: Tensor,
    pub bias: Option<Tensor>,
    /// Route to the library baseline instead of the generated kernel.
    pub use_baseline: bool,
}

#[derive(Debug)]
pub struct GemmResponse {
    pub id: u64,
    pub output: Result<Tensor>,
    pub variant: String,
    pub queue_wait: Duration,
    pub exec_time: Duration,
    pub total_latency: Duration,
}

struct Job {
    id: u64,
    request: GemmRequest,
    submitted_at: Instant,
    reply: Sender<GemmResponse>,
}

#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub workers: usize,
    pub batcher: BatcherConfig,
    /// Measure each variant once at startup and route by measured latency
    /// instead of modeled TFLOPs (profile-guided routing; the model ranks
    /// for the paper's GPU, measurement ranks for the actual substrate).
    pub rerank_measured: bool,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            workers: 2,
            batcher: BatcherConfig::default(),
            rerank_measured: false,
        }
    }
}

pub struct Server {
    submit_tx: Sender<Job>,
    next_id: AtomicU64,
    metrics: Arc<Metrics>,
    registry: Arc<Registry>,
    shutdown: Arc<AtomicBool>,
    dispatcher: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
}

impl Server {
    pub fn start(runtime: Arc<Runtime>, device: &DeviceModel, cfg: ServerConfig) -> Server {
        let mut registry = Registry::build(runtime.artifacts(), device);
        if cfg.rerank_measured {
            registry.rerank_measured(|name| {
                let artifact = runtime.load(name).ok()?;
                let inputs = crate::harness::random_inputs(&artifact, 0, 0.5);
                // one warmup (compilation), one timed run
                runtime.execute_timed(&artifact, &inputs).ok()?;
                let (_, t) = runtime.execute_timed(&artifact, &inputs).ok()?;
                Some(t.exec_seconds)
            });
        }
        Self::start_with_registry(runtime, Arc::new(registry), cfg)
    }

    pub fn start_with_registry(
        runtime: Arc<Runtime>,
        registry: Arc<Registry>,
        cfg: ServerConfig,
    ) -> Server {
        let metrics = Arc::new(Metrics::new());
        let shutdown = Arc::new(AtomicBool::new(false));
        let (submit_tx, submit_rx) = mpsc::channel::<Job>();
        let (work_tx, work_rx) = mpsc::channel::<(String, Vec<Queued<Job>>)>();
        let work_rx = Arc::new(Mutex::new(work_rx));

        // Workers: execute batches on the shared runtime.
        let mut workers = Vec::new();
        for _ in 0..cfg.workers.max(1) {
            let rt = runtime.clone();
            let rx = work_rx.clone();
            let m = metrics.clone();
            workers.push(std::thread::spawn(move || loop {
                let msg = {
                    let guard = rx.lock().unwrap();
                    guard.recv()
                };
                let Ok((variant, batch)) = msg else { break };
                m.on_batch(batch.len());
                for item in batch {
                    let Job { id, request, submitted_at, reply } = item.payload;
                    let started = Instant::now();
                    let queue_wait = started.duration_since(submitted_at);
                    let result = execute_one(&rt, &variant, request);
                    let exec_time = started.elapsed();
                    let total = submitted_at.elapsed();
                    match &result {
                        Ok(_) => m.on_complete(
                            &variant,
                            total.as_secs_f64(),
                            queue_wait.as_secs_f64(),
                            exec_time.as_secs_f64(),
                        ),
                        Err(_) => m.on_fail(),
                    }
                    let _ = reply.send(GemmResponse {
                        id,
                        output: result,
                        variant: variant.clone(),
                        queue_wait,
                        exec_time,
                        total_latency: total,
                    });
                }
            }));
        }

        // Dispatcher: route + batch.
        let reg = registry.clone();
        let stop = shutdown.clone();
        let met = metrics.clone();
        let batcher_cfg = cfg.batcher.clone();
        let dispatcher = std::thread::spawn(move || {
            let mut batcher: Batcher<Job> = Batcher::new(batcher_cfg);
            let mut poll = Duration::from_millis(1);
            loop {
                let mut enqueue = |job: Job| {
                    match route(&reg, &job.request) {
                        Ok(v) => batcher.push(Queued {
                            variant: v,
                            enqueued_at: job.submitted_at,
                            payload: job,
                        }),
                        Err(e) => {
                            met.on_fail();
                            let _ = job.reply.send(GemmResponse {
                                id: job.id,
                                output: Err(e),
                                variant: String::new(),
                                queue_wait: Duration::ZERO,
                                exec_time: Duration::ZERO,
                                total_latency: job.submitted_at.elapsed(),
                            });
                        }
                    }
                };
                match submit_rx.recv_timeout(poll) {
                    Ok(job) => {
                        enqueue(job);
                        // Drain any burst that arrived together so the
                        // batcher sees the whole group at once.
                        while let Ok(job) = submit_rx.try_recv() {
                            enqueue(job);
                        }
                    }
                    Err(RecvTimeoutError::Timeout) => {}
                    Err(RecvTimeoutError::Disconnected) => break,
                }
                loop {
                    match batcher.next_batch(Instant::now()) {
                        BatchDecision::Idle => {
                            poll = Duration::from_millis(1);
                            break;
                        }
                        BatchDecision::Wait(d) => {
                            poll = d.min(Duration::from_millis(1)).max(Duration::from_micros(100));
                            break;
                        }
                        BatchDecision::Run { variant, batch } => {
                            if work_tx.send((variant, batch)).is_err() {
                                return;
                            }
                        }
                    }
                }
                if stop.load(Ordering::Relaxed) && batcher.is_empty() {
                    break;
                }
            }
            // Drain on shutdown: flush everything still queued.
            loop {
                match batcher.next_batch(Instant::now() + Duration::from_secs(3600)) {
                    BatchDecision::Run { variant, batch } => {
                        if work_tx.send((variant, batch)).is_err() {
                            break;
                        }
                    }
                    _ => break,
                }
            }
            drop(work_tx);
        });

        Server {
            submit_tx,
            next_id: AtomicU64::new(0),
            metrics,
            registry,
            shutdown,
            dispatcher: Some(dispatcher),
            workers,
        }
    }

    /// Submit a request; the response arrives on the returned channel.
    pub fn submit(&self, request: GemmRequest) -> Receiver<GemmResponse> {
        let (tx, rx) = mpsc::channel();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.metrics.on_submit();
        let job = Job {
            id,
            request,
            submitted_at: Instant::now(),
            reply: tx,
        };
        // A send error means the dispatcher is gone; the caller sees it as
        // a dropped response channel.
        let _ = self.submit_tx.send(job);
        rx
    }

    /// Convenience: submit and block for the result.
    pub fn call(&self, request: GemmRequest) -> Result<GemmResponse> {
        let rx = self.submit(request);
        rx.recv().map_err(|_| anyhow!("server shut down"))
    }

    pub fn metrics(&self) -> MetricsSnapshot {
        self.metrics.snapshot()
    }

    pub fn registry(&self) -> &Registry {
        &self.registry
    }

    pub fn shutdown(mut self) -> MetricsSnapshot {
        self.shutdown.store(true, Ordering::Relaxed);
        // Closing the submit channel unblocks the dispatcher.
        let (dead_tx, _) = mpsc::channel();
        let old = std::mem::replace(&mut self.submit_tx, dead_tx);
        drop(old);
        if let Some(d) = self.dispatcher.take() {
            let _ = d.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        self.metrics.snapshot()
    }
}

fn route(registry: &Registry, req: &GemmRequest) -> Result<String> {
    if req.use_baseline {
        return registry
            .baseline(&req.key)
            .map(str::to_string)
            .ok_or_else(|| anyhow!("no baseline artifact for {:?}", req.key));
    }
    registry
        .best(&req.key)
        .map(|e| e.artifact.clone())
        .ok_or_else(|| anyhow!("no kernel variant registered for {:?}", req.key))
}

fn execute_one(runtime: &Runtime, variant: &str, req: GemmRequest) -> Result<Tensor> {
    // Tensors are moved, not cloned: the request is consumed (hot-path
    // allocation discipline — EXPERIMENTS.md §Perf L3).
    let GemmRequest { a, b, c, bias, .. } = req;
    let mut inputs = vec![a, b, c];
    if let Some(bias) = bias {
        inputs.push(bias);
    }
    let outputs = runtime.execute(variant, &inputs)?;
    outputs
        .into_iter()
        .next()
        .ok_or_else(|| anyhow!("artifact {variant} returned no outputs"))
}
