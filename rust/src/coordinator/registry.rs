//! Kernel registry: which compiled artifact serves which GEMM shape.
//!
//! Mirrors a serving router's model registry: every artifact from the
//! manifest is indexed by its problem key, and when several variants cover
//! the same key (different tile configurations), the performance model
//! ranks them — the run-time half of the paper's "try tile combinations,
//! keep the best" methodology.

use std::collections::HashMap;

use crate::runtime::{ArtifactKind, ArtifactMeta};
use crate::schedule::Dtype;
use crate::sim::{simulate, DeviceModel};

/// Routing key for a GEMM request.
///
/// `dtype_in` is part of the key: an f16-input kernel and a tf32/f32-input
/// kernel at the same (m, n, k, dtype_acc, epilogue) are different
/// precision modes (§2.3 of the paper) and must never share a variant
/// list — without it, `best()` could route a request to the wrong
/// precision.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct GemmKey {
    pub m: usize,
    pub n: usize,
    pub k: usize,
    pub dtype_in: Dtype,
    pub dtype_acc: Dtype,
    pub epilogue: String,
}

impl GemmKey {
    /// The pipeline's common mode: f16 inputs, f32 accumulate, no epilogue.
    pub fn plain(m: usize, n: usize, k: usize) -> GemmKey {
        GemmKey {
            m,
            n,
            k,
            dtype_in: Dtype::F16,
            dtype_acc: Dtype::F32,
            epilogue: "none".into(),
        }
    }

    pub fn with_dtypes(
        m: usize,
        n: usize,
        k: usize,
        dtype_in: Dtype,
        dtype_acc: Dtype,
    ) -> GemmKey {
        GemmKey {
            m,
            n,
            k,
            dtype_in,
            dtype_acc,
            epilogue: "none".into(),
        }
    }
}

#[derive(Debug, Clone)]
pub struct RegistryEntry {
    pub artifact: String,
    pub kind: ArtifactKind,
    /// Model-predicted TFLOPs (used for ranking); None for non-generated
    /// kinds with no schedule.
    pub predicted_tflops: Option<f64>,
}

/// Registry: GemmKey -> ranked variants (best first).
#[derive(Debug, Default)]
pub struct Registry {
    entries: HashMap<GemmKey, Vec<RegistryEntry>>,
    baselines: HashMap<GemmKey, String>,
}

impl Registry {
    /// Build from manifest metadata, ranking variants with the device model.
    pub fn build(metas: &[ArtifactMeta], device: &DeviceModel) -> Registry {
        let mut reg = Registry::default();
        for meta in metas {
            match meta.kind {
                ArtifactKind::Generated | ArtifactKind::Fused | ArtifactKind::Ablation => {
                    let Some(s) = &meta.schedule else { continue };
                    // Only fully-optimized kernels serve traffic; ablation
                    // variants are for the fig3 bench, not the router.
                    if meta.kind == ArtifactKind::Ablation && s.opt_level < 7 {
                        continue;
                    }
                    let key = GemmKey {
                        m: s.m,
                        n: s.n,
                        k: s.k,
                        dtype_in: s.dtype_in,
                        dtype_acc: s.dtype_acc,
                        epilogue: s.epilogue.clone(),
                    };
                    let predicted = simulate(s, device).tflops;
                    reg.entries.entry(key).or_default().push(RegistryEntry {
                        artifact: meta.name.clone(),
                        kind: meta.kind,
                        predicted_tflops: Some(predicted),
                    });
                }
                ArtifactKind::Baseline => {
                    if let (Some((m, n, k)), Some(acc)) = (meta.problem, meta.dtype_acc) {
                        let key = GemmKey {
                            m,
                            n,
                            k,
                            // Baselines predate precision-keyed routing in
                            // some stores; default to the pipeline's f16.
                            dtype_in: meta.dtype_in.unwrap_or(Dtype::F16),
                            dtype_acc: acc,
                            epilogue: "none".into(),
                        };
                        reg.baselines.insert(key, meta.name.clone());
                    }
                }
                _ => {}
            }
        }
        for variants in reg.entries.values_mut() {
            variants.sort_by(|a, b| {
                b.predicted_tflops
                    .unwrap_or(0.0)
                    .partial_cmp(&a.predicted_tflops.unwrap_or(0.0))
                    .unwrap()
            });
        }
        reg
    }

    /// Profile-guided re-ranking: measure each variant once on the real
    /// runtime and reorder by measured latency.  The model ranking targets
    /// the modeled GPU; when serving on a different substrate (here: the
    /// CPU PJRT backend) measured numbers beat the model — EXPERIMENTS.md
    /// §Perf iteration 2.
    pub fn rerank_measured<F>(&mut self, mut measure: F)
    where
        F: FnMut(&str) -> Option<f64>,
    {
        for variants in self.entries.values_mut() {
            if variants.len() < 2 {
                continue;
            }
            let mut timed: Vec<(f64, RegistryEntry)> = variants
                .drain(..)
                .map(|e| {
                    let t = measure(&e.artifact).unwrap_or(f64::INFINITY);
                    (t, e)
                })
                .collect();
            timed.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            *variants = timed.into_iter().map(|(_, e)| e).collect();
        }
    }

    pub fn register(&mut self, key: GemmKey, entry: RegistryEntry) {
        self.entries.entry(key).or_default().push(entry);
    }

    /// Best variant for a key (autotuned choice).
    pub fn best(&self, key: &GemmKey) -> Option<&RegistryEntry> {
        self.entries.get(key).and_then(|v| v.first())
    }

    pub fn variants(&self, key: &GemmKey) -> &[RegistryEntry] {
        self.entries.get(key).map(|v| v.as_slice()).unwrap_or(&[])
    }

    pub fn baseline(&self, key: &GemmKey) -> Option<&str> {
        self.baselines.get(key).map(|s| s.as_str())
    }

    pub fn keys(&self) -> impl Iterator<Item = &GemmKey> {
        self.entries.keys()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::Schedule;
    use std::path::PathBuf;

    fn meta(name: &str, kind: ArtifactKind, sched: Option<Schedule>) -> ArtifactMeta {
        let problem = sched.as_ref().map(|s| (s.m, s.n, s.k));
        let acc = sched.as_ref().map(|s| s.dtype_acc).or(Some(Dtype::F32));
        ArtifactMeta {
            name: name.into(),
            path: PathBuf::from("/nonexistent"),
            kind,
            inputs: vec![],
            outputs: vec![],
            schedule: sched,
            problem: problem.or(Some((256, 256, 256))),
            dtype_in: Some(Dtype::F16),
            dtype_acc: acc,
        }
    }

    fn sched(tb: (usize, usize, usize), warp: (usize, usize, usize)) -> Schedule {
        Schedule::optimized(512, 512, 512, Dtype::F32, tb, warp).unwrap()
    }

    #[test]
    fn ranks_variants_by_predicted_tflops() {
        let d = DeviceModel::rtx3090();
        let metas = vec![
            meta("small", ArtifactKind::Generated, Some(sched((64, 64, 64), (32, 32, 32)))),
            meta("large", ArtifactKind::Generated, Some(sched((128, 128, 64), (64, 32, 32)))),
        ];
        let reg = Registry::build(&metas, &d);
        let key = GemmKey::plain(512, 512, 512);
        let best = reg.best(&key).unwrap();
        assert_eq!(reg.variants(&key).len(), 2);
        // at 512 the small tile wins on occupancy (64 vs 16 blocks)
        assert_eq!(best.artifact, "small");
    }

    #[test]
    fn rerank_measured_overrides_model_ranking() {
        let d = DeviceModel::rtx3090();
        let metas = vec![
            meta("small", ArtifactKind::Generated, Some(sched((64, 64, 64), (32, 32, 32)))),
            meta("large", ArtifactKind::Generated, Some(sched((128, 128, 64), (64, 32, 32)))),
        ];
        let mut reg = Registry::build(&metas, &d);
        let key = GemmKey::plain(512, 512, 512);
        assert_eq!(reg.best(&key).unwrap().artifact, "small");
        // measured: "large" is 2x faster on this substrate
        reg.rerank_measured(|name| Some(if name == "large" { 0.05 } else { 0.10 }));
        assert_eq!(reg.best(&key).unwrap().artifact, "large");
    }

    #[test]
    fn baseline_routed_separately() {
        let d = DeviceModel::rtx3090();
        let metas = vec![meta("base", ArtifactKind::Baseline, None)];
        let reg = Registry::build(&metas, &d);
        let key = GemmKey::plain(256, 256, 256);
        assert_eq!(reg.baseline(&key), Some("base"));
        assert!(reg.best(&key).is_none());
    }

    #[test]
    fn dtype_in_separates_precision_modes() {
        // Regression: an f16-input kernel and an f32(TF32)-input kernel at
        // the same (m, n, k, acc, epilogue) must not share a variant list.
        let d = DeviceModel::rtx3090();
        let half = sched((64, 64, 64), (32, 32, 32));
        let mut tf32 = sched((64, 64, 64), (32, 32, 32));
        tf32.dtype_in = Dtype::F32;
        let metas = vec![
            meta("half_kernel", ArtifactKind::Generated, Some(half)),
            meta("tf32_kernel", ArtifactKind::Generated, Some(tf32)),
        ];
        let reg = Registry::build(&metas, &d);
        let key_f16 = GemmKey::with_dtypes(512, 512, 512, Dtype::F16, Dtype::F32);
        let key_f32 = GemmKey::with_dtypes(512, 512, 512, Dtype::F32, Dtype::F32);
        assert_eq!(reg.variants(&key_f16).len(), 1);
        assert_eq!(reg.variants(&key_f32).len(), 1);
        assert_eq!(reg.best(&key_f16).unwrap().artifact, "half_kernel");
        assert_eq!(reg.best(&key_f32).unwrap().artifact, "tf32_kernel");
    }

    #[test]
    fn baseline_keyed_by_input_dtype() {
        let d = DeviceModel::rtx3090();
        let metas = vec![meta("base", ArtifactKind::Baseline, None)];
        let reg = Registry::build(&metas, &d);
        // meta() declares dtype_in f16: the f16 key hits, the f32 key must
        // not alias onto it.
        assert_eq!(reg.baseline(&GemmKey::plain(256, 256, 256)), Some("base"));
        let f32_key = GemmKey::with_dtypes(256, 256, 256, Dtype::F32, Dtype::F32);
        assert!(reg.baseline(&f32_key).is_none());
    }

    #[test]
    fn non_optimal_ablation_variants_not_served() {
        let d = DeviceModel::rtx3090();
        let mut s = sched((64, 64, 64), (32, 32, 32));
        s.opt_level = 3;
        let metas = vec![meta("abl3", ArtifactKind::Ablation, Some(s))];
        let reg = Registry::build(&metas, &d);
        assert!(reg.is_empty());
    }
}
