//! Kernel registry: which compiled artifact serves which GEMM shape, and
//! under which compiled execution plan.
//!
//! Mirrors a serving router's model registry: every artifact from the
//! manifest is indexed by its problem key, and when several variants cover
//! the same key (different tile configurations), the performance model
//! ranks them — the run-time half of the paper's "try tile combinations,
//! keep the best" methodology.  Alongside the variant ranking the registry
//! caches one compiled [`ExecutionPlan`] per [`GemmKey`] (the output of
//! `crate::plan`'s pass pipeline): the server threads these plans
//! explicitly through its workers, so "how should this GEMM run" lives in
//! exactly one place instead of a process-global kernel policy.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, Result};

use crate::plan::program::ProgramPlan;
use crate::plan::{self, ExecutionPlan, PlanEnv};
use crate::runtime::{ArtifactKind, ArtifactMeta, BoundB, Epilogue, Program, Tensor};
use crate::schedule::Dtype;
use crate::sim::{simulate, DeviceModel};

pub use crate::plan::GemmKey;

#[derive(Debug, Clone)]
pub struct RegistryEntry {
    pub artifact: String,
    pub kind: ArtifactKind,
    /// Model-predicted TFLOPs (used for ranking); None for non-generated
    /// kinds with no schedule.
    pub predicted_tflops: Option<f64>,
}

/// Registry: GemmKey -> ranked variants (best first) + compiled plan +
/// optionally bound constant weights.
#[derive(Debug, Default)]
pub struct Registry {
    entries: HashMap<GemmKey, Vec<RegistryEntry>>,
    baselines: HashMap<GemmKey, String>,
    plans: HashMap<GemmKey, Arc<ExecutionPlan>>,
    /// Constant B weights bound per key (`bind_weights`): cast and
    /// prepacked once, shared immutably with every in-flight request
    /// that routed after the bind.  Interior mutability so binding works
    /// through the server's `Arc<Registry>`; a rebind swaps the `Arc`,
    /// so newly routed requests can never see the old panels.  Each
    /// slot carries a monotonically increasing *bind epoch* (first bind
    /// = 1) captured at routing time and echoed on responses — the
    /// observable that lets the protocol checker's "no stale panels
    /// across a rebind" invariant be asserted end-to-end.
    bound: Mutex<HashMap<GemmKey, BoundSlot>>,
    /// Measured plan overlays (`promote_plan`): a key's shadow-promoted
    /// plan shadows the compiled default in `plans` for every *new*
    /// route and bind, while requests that captured the old `Arc` keep
    /// executing under it — promotion is an atomic pointer swap, never
    /// an in-place mutation.  Each slot carries a monotonically
    /// increasing *plan epoch* (first promotion = 1) so tests and the
    /// CLI can observe that a swap happened without racing on plan ids.
    promoted: Mutex<HashMap<GemmKey, PromotedSlot>>,
    /// Graph-level plans for composite artifacts, keyed by artifact name
    /// (composite programs have no `GemmKey`; the manifest entry alone
    /// cannot recompile them, so the server caches the load-time plan
    /// here on first route).  Interior mutability for the same reason as
    /// `bound`: caching happens through the server's `Arc<Registry>`.
    program_plans: Mutex<HashMap<String, Arc<ProgramPlan>>>,
    plan_env: PlanEnv,
}

/// One key's bound-weight slot: the current weights (None after an
/// unbind) and the bind epoch, which survives unbinds so it never
/// repeats across the key's lifetime.
#[derive(Debug, Default)]
struct BoundSlot {
    epoch: u64,
    weights: Option<Arc<BoundB>>,
}

/// One key's promoted-plan slot: the current overlay (None after a
/// demotion) and the promotion epoch, which survives demotions so it
/// never repeats across the key's lifetime — the same shape as
/// [`BoundSlot`], for the same protocol-observability reasons.
#[derive(Debug, Default)]
struct PromotedSlot {
    epoch: u64,
    plan: Option<Arc<ExecutionPlan>>,
}

impl Registry {
    /// Registry compiling plans under a specific environment (the server
    /// passes its pool size here).
    pub fn with_env(plan_env: PlanEnv) -> Registry {
        Registry { plan_env, ..Registry::default() }
    }

    /// Build from manifest metadata, ranking variants with the device
    /// model and compiling one execution plan per key under `plan_env`.
    pub fn build(metas: &[ArtifactMeta], device: &DeviceModel, plan_env: PlanEnv) -> Registry {
        let mut reg = Registry::with_env(plan_env);
        for meta in metas {
            match meta.kind {
                ArtifactKind::Generated | ArtifactKind::Fused | ArtifactKind::Ablation => {
                    let Some(s) = &meta.schedule else { continue };
                    // Only fully-optimized kernels serve traffic; ablation
                    // variants are for the fig3 bench, not the router.
                    if meta.kind == ArtifactKind::Ablation && s.opt_level < 7 {
                        continue;
                    }
                    let key = GemmKey {
                        m: s.m,
                        n: s.n,
                        k: s.k,
                        dtype_in: s.dtype_in,
                        dtype_acc: s.dtype_acc,
                        epilogue: s.epilogue.clone(),
                    };
                    let predicted = simulate(s, device).tflops;
                    reg.ensure_plan(&key);
                    reg.entries.entry(key).or_default().push(RegistryEntry {
                        artifact: meta.name.clone(),
                        kind: meta.kind,
                        predicted_tflops: Some(predicted),
                    });
                }
                ArtifactKind::Baseline => {
                    if let (Some((m, n, k)), Some(acc)) = (meta.problem, meta.dtype_acc) {
                        let key = GemmKey {
                            m,
                            n,
                            k,
                            // Baselines predate precision-keyed routing in
                            // some stores; default to the pipeline's f16.
                            dtype_in: meta.dtype_in.unwrap_or(Dtype::F16),
                            dtype_acc: acc,
                            epilogue: "none".into(),
                        };
                        reg.ensure_plan(&key);
                        reg.baselines.insert(key, meta.name.clone());
                    }
                }
                _ => {}
            }
        }
        for variants in reg.entries.values_mut() {
            variants.sort_by(|a, b| {
                b.predicted_tflops
                    .unwrap_or(0.0)
                    .partial_cmp(&a.predicted_tflops.unwrap_or(0.0))
                    .unwrap()
            });
        }
        reg
    }

    /// Compile and cache the plan for `key` if absent.  Compilation is
    /// infallible for non-forced environments; a forced-invalid override
    /// is caught at parse time, so `ok()` here cannot silently drop plans
    /// in practice.
    fn ensure_plan(&mut self, key: &GemmKey) {
        if !self.plans.contains_key(key) {
            if let Ok(p) = plan::compile(key, &self.plan_env) {
                self.plans.insert(key.clone(), Arc::new(p));
            }
        }
    }

    /// Profile-guided re-ranking: measure each variant once on the real
    /// runtime and reorder by measured latency.  The model ranking targets
    /// the modeled GPU; when serving on a different substrate (here: the
    /// CPU PJRT backend) measured numbers beat the model — EXPERIMENTS.md
    /// §Perf iteration 2.
    pub fn rerank_measured<F>(&mut self, mut measure: F)
    where
        F: FnMut(&str) -> Option<f64>,
    {
        for variants in self.entries.values_mut() {
            if variants.len() < 2 {
                continue;
            }
            let mut timed: Vec<(f64, RegistryEntry)> = variants
                .drain(..)
                .map(|e| {
                    let t = measure(&e.artifact).unwrap_or(f64::INFINITY);
                    (t, e)
                })
                .collect();
            timed.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            *variants = timed.into_iter().map(|(_, e)| e).collect();
        }
    }

    /// Plan refinement: run `refine` over every cached plan and swap in
    /// the plans it returns — the autotuner's measured sweep plugs in
    /// here (`autotune::refine_measured`), replacing *a variant's plan*
    /// instead of mutating a process-global policy.
    pub fn refine_plans<F>(&mut self, mut refine: F)
    where
        F: FnMut(&GemmKey, &ExecutionPlan) -> Option<ExecutionPlan>,
    {
        let keys: Vec<GemmKey> = self.plans.keys().cloned().collect();
        for key in keys {
            let current = self.plans[&key].clone();
            if let Some(new_plan) = refine(&key, &current) {
                self.plans.insert(key, Arc::new(new_plan));
            }
        }
    }

    /// Measured plan refinement via the autotuner: each key's plan
    /// competes against the naive and default-tiled alternatives on real
    /// wall clock; the fastest kernel wins the plan slot.
    pub fn refine_plans_measured(&mut self, iters: usize) {
        self.refine_plans(|_key, current| {
            Some(crate::autotune::refine_measured(current, iters))
        });
    }

    pub fn register(&mut self, key: GemmKey, entry: RegistryEntry) {
        self.ensure_plan(&key);
        self.entries.entry(key).or_default().push(entry);
    }

    /// Best variant for a key (autotuned choice).
    pub fn best(&self, key: &GemmKey) -> Option<&RegistryEntry> {
        self.entries.get(key).and_then(|v| v.first())
    }

    pub fn variants(&self, key: &GemmKey) -> &[RegistryEntry] {
        self.entries.get(key).map(|v| v.as_slice()).unwrap_or(&[])
    }

    pub fn baseline(&self, key: &GemmKey) -> Option<&str> {
        self.baselines.get(key).map(|s| s.as_str())
    }

    /// The compiled plan for a key (shared with the server's workers).
    pub fn plan(&self, key: &GemmKey) -> Option<Arc<ExecutionPlan>> {
        self.plans.get(key).cloned()
    }

    /// Install a measured plan overlay for `key` and return the new plan
    /// epoch.  The swap is atomic under the slot mutex: routes that read
    /// the slot after this call serve the new plan, in-flight work keeps
    /// the `Arc` it captured at routing time — old and new plans execute
    /// concurrently during the handover, observably (per-plan metrics),
    /// and neither is ever mutated.
    pub fn promote_plan(&self, key: &GemmKey, plan: Arc<ExecutionPlan>) -> u64 {
        let mut g = self.promoted.lock().unwrap();
        let slot = g.entry(key.clone()).or_default();
        slot.epoch += 1;
        slot.plan = Some(plan);
        slot.epoch
    }

    /// The key's promoted plan, if a measured overlay is installed.
    pub fn promoted_plan(&self, key: &GemmKey) -> Option<Arc<ExecutionPlan>> {
        self.promoted.lock().unwrap().get(key).and_then(|s| s.plan.clone())
    }

    /// The key's promotion epoch: 0 if never promoted, otherwise the
    /// count of `promote_plan` calls ever made for it (demotions do not
    /// reset it).
    pub fn plan_epoch(&self, key: &GemmKey) -> u64 {
        self.promoted.lock().unwrap().get(key).map(|s| s.epoch).unwrap_or(0)
    }

    /// Drop a key's promoted overlay, falling back to the compiled
    /// default for subsequent routes.  Returns whether an overlay was
    /// installed; the epoch is preserved so a later re-promotion keeps
    /// counting up.
    pub fn demote_plan(&self, key: &GemmKey) -> bool {
        self.promoted
            .lock()
            .unwrap()
            .get_mut(key)
            .map(|s| s.plan.take().is_some())
            .unwrap_or(false)
    }

    /// The plan a *new* request for `key` would execute under: the
    /// promoted overlay when one exists, the compiled default otherwise.
    /// This is the single lookup the server's routing and weight binding
    /// go through, so promotion changes both consistently.
    pub fn serving_plan(&self, key: &GemmKey) -> Option<Arc<ExecutionPlan>> {
        self.promoted_plan(key).or_else(|| self.plan(key))
    }

    /// Every key with a currently installed overlay, with its plan.
    pub fn promoted_plans(&self) -> Vec<(GemmKey, Arc<ExecutionPlan>)> {
        self.promoted
            .lock()
            .unwrap()
            .iter()
            .filter_map(|(k, s)| s.plan.clone().map(|p| (k.clone(), p)))
            .collect()
    }

    /// Bind a constant B weight for `key`: validate its shape against
    /// the key (rejected here, at bind time), cast it to the key's
    /// `dtype_in` once, and — when the key's compiled plan's prepack
    /// pass says so — materialize its kernel panels.  Rebinding replaces
    /// the shared `Arc`, invalidating the old panels for all subsequent
    /// routing.  Returns the bound weights for callers that want to
    /// inspect them.
    pub fn bind_weights(&self, key: &GemmKey, b: &Tensor) -> Result<Arc<BoundB>> {
        let eplan = match self.serving_plan(key) {
            Some(p) => p,
            // Manually assembled registries may not have compiled this
            // key yet; compile under the registry's own environment so
            // the bind and the serving plan agree.
            None => Arc::new(plan::compile(key, &self.plan_env)?),
        };
        let program = program_for(key)?;
        let bound = Arc::new(program.bind_b(b, &eplan)?);
        let mut g = self.bound.lock().unwrap();
        let slot = g.entry(key.clone()).or_default();
        slot.epoch += 1;
        slot.weights = Some(bound.clone());
        drop(g);
        Ok(bound)
    }

    /// The currently bound weights for a key (None after `unbind_weights`
    /// or when nothing was ever bound).
    pub fn bound_weights(&self, key: &GemmKey) -> Option<Arc<BoundB>> {
        self.bound.lock().unwrap().get(key).and_then(|s| s.weights.clone())
    }

    /// The bound weights *and* their bind epoch, read atomically under
    /// one lock acquisition.  The server captures this pair at routing
    /// time: because `bind_weights` publishes (epoch, Arc) together
    /// under the same mutex, any bind that completed before a route
    /// is visible to it with its own epoch — a route can never pair an
    /// old epoch with new panels or vice versa.
    pub fn bound_weights_versioned(&self, key: &GemmKey) -> Option<(u64, Arc<BoundB>)> {
        self.bound
            .lock()
            .unwrap()
            .get(key)
            .and_then(|s| s.weights.clone().map(|w| (s.epoch, w)))
    }

    /// The key's current bind epoch: 0 if never bound, otherwise the
    /// count of `bind_weights` calls ever made for it (unbinds do not
    /// reset it).
    pub fn bound_epoch(&self, key: &GemmKey) -> u64 {
        self.bound.lock().unwrap().get(key).map(|s| s.epoch).unwrap_or(0)
    }

    /// Drop a key's bound weights.  Returns whether anything was bound;
    /// weight-bound requests for the key fail explicitly afterwards.
    /// The slot's epoch is preserved so a later rebind keeps counting up.
    pub fn unbind_weights(&self, key: &GemmKey) -> bool {
        self.bound
            .lock()
            .unwrap()
            .get_mut(key)
            .map(|s| s.weights.take().is_some())
            .unwrap_or(false)
    }

    /// Cache a composite artifact's compiled graph plan under its name.
    pub fn cache_program_plan(&self, artifact: &str, pplan: Arc<ProgramPlan>) {
        self.program_plans
            .lock()
            .unwrap()
            .insert(artifact.to_string(), pplan);
    }

    /// The cached graph plan for a composite artifact (`None` until the
    /// first route or an explicit [`Registry::cache_program_plan`]).
    pub fn program_plan(&self, artifact: &str) -> Option<Arc<ProgramPlan>> {
        self.program_plans.lock().unwrap().get(artifact).cloned()
    }

    /// Every cached (key, plan) pair — `make plans` / metrics preseeding.
    pub fn plans(&self) -> impl Iterator<Item = (&GemmKey, &Arc<ExecutionPlan>)> {
        self.plans.iter()
    }

    pub fn keys(&self) -> impl Iterator<Item = &GemmKey> {
        self.entries.keys()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

/// The executable GEMM contract a key describes — what a bound weight
/// is validated and cast against.
fn program_for(key: &GemmKey) -> Result<Program> {
    let epilogue = Epilogue::parse(&key.epilogue)
        .ok_or_else(|| anyhow!("unknown epilogue {:?} in {key:?}", key.epilogue))?;
    Ok(Program::Gemm {
        m: key.m,
        n: key.n,
        k: key.k,
        dtype_in: key.dtype_in,
        dtype_acc: key.dtype_acc,
        epilogue,
        fused: true,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::KernelPolicy;
    use crate::schedule::Schedule;
    use std::path::PathBuf;

    fn meta(name: &str, kind: ArtifactKind, sched: Option<Schedule>) -> ArtifactMeta {
        let problem = sched.as_ref().map(|s| (s.m, s.n, s.k));
        let acc = sched.as_ref().map(|s| s.dtype_acc).or(Some(Dtype::F32));
        ArtifactMeta {
            name: name.into(),
            path: PathBuf::from("/nonexistent"),
            kind,
            inputs: vec![],
            outputs: vec![],
            schedule: sched,
            problem: problem.or(Some((256, 256, 256))),
            dtype_in: Some(Dtype::F16),
            dtype_acc: acc,
        }
    }

    fn sched(tb: (usize, usize, usize), warp: (usize, usize, usize)) -> Schedule {
        Schedule::optimized(512, 512, 512, Dtype::F32, tb, warp).unwrap()
    }

    #[test]
    fn ranks_variants_by_predicted_tflops() {
        let d = DeviceModel::rtx3090();
        let metas = vec![
            meta("small", ArtifactKind::Generated, Some(sched((64, 64, 64), (32, 32, 32)))),
            meta("large", ArtifactKind::Generated, Some(sched((128, 128, 64), (64, 32, 32)))),
        ];
        let reg = Registry::build(&metas, &d, PlanEnv::default());
        let key = GemmKey::plain(512, 512, 512);
        let best = reg.best(&key).unwrap();
        assert_eq!(reg.variants(&key).len(), 2);
        // at 512 the small tile wins on occupancy (64 vs 16 blocks)
        assert_eq!(best.artifact, "small");
    }

    #[test]
    fn rerank_measured_overrides_model_ranking() {
        let d = DeviceModel::rtx3090();
        let metas = vec![
            meta("small", ArtifactKind::Generated, Some(sched((64, 64, 64), (32, 32, 32)))),
            meta("large", ArtifactKind::Generated, Some(sched((128, 128, 64), (64, 32, 32)))),
        ];
        let mut reg = Registry::build(&metas, &d, PlanEnv::default());
        let key = GemmKey::plain(512, 512, 512);
        assert_eq!(reg.best(&key).unwrap().artifact, "small");
        // measured: "large" is 2x faster on this substrate
        reg.rerank_measured(|name| Some(if name == "large" { 0.05 } else { 0.10 }));
        assert_eq!(reg.best(&key).unwrap().artifact, "large");
    }

    #[test]
    fn baseline_routed_separately() {
        let d = DeviceModel::rtx3090();
        let metas = vec![meta("base", ArtifactKind::Baseline, None)];
        let reg = Registry::build(&metas, &d, PlanEnv::default());
        let key = GemmKey::plain(256, 256, 256);
        assert_eq!(reg.baseline(&key), Some("base"));
        assert!(reg.best(&key).is_none());
        // baselines get plans too: they execute through the same engine
        assert!(reg.plan(&key).is_some());
    }

    #[test]
    fn every_registered_key_gets_a_compiled_plan() {
        let d = DeviceModel::rtx3090();
        let metas = vec![
            meta("small", ArtifactKind::Generated, Some(sched((64, 64, 64), (32, 32, 32)))),
            meta("base", ArtifactKind::Baseline, None),
        ];
        let reg = Registry::build(&metas, &d, PlanEnv::pinned());
        for key in reg.keys() {
            let plan = reg.plan(key).expect("registered key without a plan");
            assert!(plan.matches_gemm(
                key.m,
                key.n,
                key.k,
                key.dtype_in,
                key.dtype_acc,
                &key.epilogue
            ));
        }
        assert!(reg.plans().count() >= reg.len());
        // register() also compiles
        let mut reg = Registry::default();
        let key = GemmKey::plain(96, 96, 96);
        reg.register(
            key.clone(),
            RegistryEntry {
                artifact: "v".into(),
                kind: ArtifactKind::Generated,
                predicted_tflops: None,
            },
        );
        assert!(reg.plan(&key).is_some());
    }

    #[test]
    fn refine_plans_swaps_a_variants_plan_not_a_global() {
        let mut reg = Registry::with_env(PlanEnv::pinned());
        let key = GemmKey::plain(512, 512, 512);
        reg.register(
            key.clone(),
            RegistryEntry {
                artifact: "v".into(),
                kind: ArtifactKind::Generated,
                predicted_tflops: None,
            },
        );
        let before = reg.plan(&key).unwrap();
        reg.refine_plans(|k, current| {
            assert_eq!(k, &key);
            let mut refined = current.clone();
            refined.kernel = KernelPolicy::Naive;
            Some(refined)
        });
        let after = reg.plan(&key).unwrap();
        assert_eq!(after.kernel, KernelPolicy::Naive);
        assert_ne!(before.kernel, after.kernel);
    }

    #[test]
    fn dtype_in_separates_precision_modes() {
        // Regression: an f16-input kernel and an f32(TF32)-input kernel at
        // the same (m, n, k, acc, epilogue) must not share a variant list.
        let d = DeviceModel::rtx3090();
        let half = sched((64, 64, 64), (32, 32, 32));
        let mut tf32 = sched((64, 64, 64), (32, 32, 32));
        tf32.dtype_in = Dtype::F32;
        let metas = vec![
            meta("half_kernel", ArtifactKind::Generated, Some(half)),
            meta("tf32_kernel", ArtifactKind::Generated, Some(tf32)),
        ];
        let reg = Registry::build(&metas, &d, PlanEnv::default());
        let key_f16 = GemmKey::with_dtypes(512, 512, 512, Dtype::F16, Dtype::F32);
        let key_f32 = GemmKey::with_dtypes(512, 512, 512, Dtype::F32, Dtype::F32);
        assert_eq!(reg.variants(&key_f16).len(), 1);
        assert_eq!(reg.variants(&key_f32).len(), 1);
        assert_eq!(reg.best(&key_f16).unwrap().artifact, "half_kernel");
        assert_eq!(reg.best(&key_f32).unwrap().artifact, "tf32_kernel");
    }

    #[test]
    fn baseline_keyed_by_input_dtype() {
        let d = DeviceModel::rtx3090();
        let metas = vec![meta("base", ArtifactKind::Baseline, None)];
        let reg = Registry::build(&metas, &d, PlanEnv::default());
        // meta() declares dtype_in f16: the f16 key hits, the f32 key must
        // not alias onto it.
        assert_eq!(reg.baseline(&GemmKey::plain(256, 256, 256)), Some("base"));
        let f32_key = GemmKey::with_dtypes(256, 256, 256, Dtype::F32, Dtype::F32);
        assert!(reg.baseline(&f32_key).is_none());
    }

    #[test]
    fn bind_rebind_unbind_weights() {
        let reg = Registry::with_env(PlanEnv::pinned());
        let key = GemmKey::with_dtypes(128, 96, 112, Dtype::F32, Dtype::F32);
        // shape mismatch is rejected at bind time
        let wrong = Tensor::zeros(vec![96, 112]);
        assert!(reg.bind_weights(&key, &wrong).is_err());
        assert!(reg.bound_weights(&key).is_none());
        // a good bind prepacks (128x96x112 compiles to a packing kernel)
        let b1 = Tensor::zeros(vec![112, 96]);
        let bound1 = reg.bind_weights(&key, &b1).unwrap();
        assert!(bound1.is_prepacked(), "packing plan must prepack at bind");
        assert!(Arc::ptr_eq(&reg.bound_weights(&key).unwrap(), &bound1));
        // rebinding swaps the Arc: old panels are no longer served
        let b2 = Tensor::new(vec![112, 96], vec![1.0; 112 * 96]).unwrap();
        let bound2 = reg.bind_weights(&key, &b2).unwrap();
        let current = reg.bound_weights(&key).unwrap();
        assert!(Arc::ptr_eq(&current, &bound2));
        assert!(!Arc::ptr_eq(&current, &bound1));
        assert_eq!(current.raw()[0], 1.0);
        // unbind drops it
        assert!(reg.unbind_weights(&key));
        assert!(!reg.unbind_weights(&key));
        assert!(reg.bound_weights(&key).is_none());
        // a direct-kernel key binds without panels (cast-only)
        let small = GemmKey::with_dtypes(24, 24, 24, Dtype::F32, Dtype::F32);
        let bs = reg.bind_weights(&small, &Tensor::zeros(vec![24, 24])).unwrap();
        assert!(!bs.is_prepacked(), "direct plans bind cast-only weights");
    }

    #[test]
    fn bind_epochs_count_monotonically_across_unbinds() {
        let reg = Registry::with_env(PlanEnv::pinned());
        let key = GemmKey::with_dtypes(24, 24, 24, Dtype::F32, Dtype::F32);
        assert_eq!(reg.bound_epoch(&key), 0, "never bound");
        assert!(reg.bound_weights_versioned(&key).is_none());
        let b = Tensor::zeros(vec![24, 24]);
        let first = reg.bind_weights(&key, &b).unwrap();
        let (e1, w1) = reg.bound_weights_versioned(&key).unwrap();
        assert_eq!(e1, 1, "first bind opens epoch 1");
        assert!(Arc::ptr_eq(&w1, &first));
        let second = reg.bind_weights(&key, &b).unwrap();
        let (e2, w2) = reg.bound_weights_versioned(&key).unwrap();
        assert_eq!(e2, 2, "rebind bumps the epoch");
        assert!(Arc::ptr_eq(&w2, &second));
        assert!(!Arc::ptr_eq(&w2, &first));
        // unbind clears weights but not the epoch counter
        assert!(reg.unbind_weights(&key));
        assert!(reg.bound_weights_versioned(&key).is_none());
        assert_eq!(reg.bound_epoch(&key), 2);
        reg.bind_weights(&key, &b).unwrap();
        assert_eq!(reg.bound_epoch(&key), 3, "epoch never repeats");
    }

    #[test]
    fn promotion_overlays_the_compiled_plan_atomically() {
        let mut reg = Registry::with_env(PlanEnv::pinned());
        let key = GemmKey::plain(512, 512, 512);
        reg.register(
            key.clone(),
            RegistryEntry {
                artifact: "v".into(),
                kind: ArtifactKind::Generated,
                predicted_tflops: None,
            },
        );
        let default_plan = reg.plan(&key).unwrap();
        assert_eq!(reg.plan_epoch(&key), 0);
        assert!(reg.promoted_plan(&key).is_none());
        assert!(Arc::ptr_eq(&reg.serving_plan(&key).unwrap(), &default_plan));
        let simd = Arc::new(
            ExecutionPlan::manual(
                &key,
                KernelPolicy::parse("simd:portable:64,512,1024,1").unwrap(),
                false,
            )
            .unwrap(),
        );
        assert_eq!(reg.promote_plan(&key, simd.clone()), 1);
        // New routes see the overlay; the compiled default is untouched,
        // so in-flight work holding its Arc is unaffected.
        assert!(Arc::ptr_eq(&reg.serving_plan(&key).unwrap(), &simd));
        assert!(Arc::ptr_eq(&reg.plan(&key).unwrap(), &default_plan));
        assert_eq!(reg.promoted_plans().len(), 1);
        // Demotion falls back; the epoch survives, like bind epochs.
        assert!(reg.demote_plan(&key));
        assert!(!reg.demote_plan(&key));
        assert!(Arc::ptr_eq(&reg.serving_plan(&key).unwrap(), &default_plan));
        assert_eq!(reg.plan_epoch(&key), 1);
        assert_eq!(reg.promote_plan(&key, simd), 2);
    }

    #[test]
    fn weight_binding_follows_the_promoted_plan() {
        // A direct-kernel key binds cast-only weights under its compiled
        // default; after promotion to a packing SIMD plan, a re-bind
        // materializes panels — binding consults the serving plan.
        let reg = Registry::with_env(PlanEnv::pinned());
        let key = GemmKey::with_dtypes(24, 24, 24, Dtype::F32, Dtype::F32);
        let b = Tensor::zeros(vec![24, 24]);
        let before = reg.bind_weights(&key, &b).unwrap();
        assert!(!before.is_prepacked());
        let simd = Arc::new(
            ExecutionPlan::manual(
                &key,
                KernelPolicy::parse("simd:portable:64,256,256,1").unwrap(),
                false,
            )
            .unwrap(),
        );
        reg.promote_plan(&key, simd);
        let after = reg.bind_weights(&key, &b).unwrap();
        assert!(after.is_prepacked(), "promoted packing plan must prepack");
    }

    #[test]
    fn non_optimal_ablation_variants_not_served() {
        let d = DeviceModel::rtx3090();
        let mut s = sched((64, 64, 64), (32, 32, 32));
        s.opt_level = 3;
        let metas = vec![meta("abl3", ArtifactKind::Ablation, Some(s))];
        let reg = Registry::build(&metas, &d, PlanEnv::default());
        assert!(reg.is_empty());
    }
}
