//! Dynamic batcher: groups queued requests by target kernel variant.
//!
//! Serving-system shape (vLLM-router-like): requests arrive on a queue;
//! the dispatcher drains up to `max_batch` requests *for the same
//! compiled variant* (or as many as are available within `max_wait`) and
//! hands the group to one worker, amortizing dispatch overhead and keeping
//! the executable's code hot.  FIFO order is preserved within a variant.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

/// A queued item tagged with its routing decision.
#[derive(Debug)]
pub struct Queued<T> {
    pub variant: String,
    pub enqueued_at: Instant,
    pub payload: T,
}

#[derive(Debug, Clone)]
pub struct BatcherConfig {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_micros(500),
        }
    }
}

/// Pure batching state machine (I/O-free, fully unit-testable).
#[derive(Debug)]
pub struct Batcher<T> {
    cfg: BatcherConfig,
    queue: VecDeque<Queued<T>>,
}

impl<T> Batcher<T> {
    pub fn new(cfg: BatcherConfig) -> Self {
        assert!(cfg.max_batch >= 1, "max_batch must be >= 1");
        Batcher {
            cfg,
            queue: VecDeque::new(),
        }
    }

    pub fn push(&mut self, item: Queued<T>) {
        self.queue.push_back(item);
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Remove and return every queued item whose deadline (as computed
    /// by `deadline_of`) is at or before `now`.  The dispatcher sweeps
    /// this between batching decisions so a job that expires *inside*
    /// the batching window is answered `DeadlineExceeded` promptly
    /// instead of burning a worker on stale output.  Relative order of
    /// survivors is preserved; expired items come back in queue order.
    pub fn take_expired<F>(&mut self, now: Instant, deadline_of: F) -> Vec<Queued<T>>
    where
        F: Fn(&T) -> Option<Instant>,
    {
        if self.queue.is_empty() {
            return Vec::new();
        }
        let mut expired = Vec::new();
        let mut rest = VecDeque::with_capacity(self.queue.len());
        while let Some(item) = self.queue.pop_front() {
            match deadline_of(&item.payload) {
                Some(dl) if dl <= now => expired.push(item),
                _ => rest.push_back(item),
            }
        }
        self.queue = rest;
        expired
    }

    /// Form the next batch at time `now`.
    ///
    /// Policy: scan the distinct variants in queue order (the head variant
    /// first — it always holds the oldest deadline) and release the first
    /// one that is *ready*: either `max_batch` items are queued for it, or
    /// its oldest item has aged past `max_wait`.  Scanning past the head
    /// fixes cross-variant head-of-line blocking: a full batch for variant
    /// B queued behind a young lone request for variant A must not sit
    /// blocked inside A's batching window.  FIFO order is preserved within
    /// each variant, and the head variant cannot starve — its deadline
    /// expires first and the scan always considers it first.
    pub fn next_batch(&mut self, now: Instant) -> BatchDecision<T> {
        if self.queue.is_empty() {
            return BatchDecision::Idle;
        }
        // A lone request with nothing behind it gains nothing from the
        // batch window: the dispatcher drains the submit channel before
        // calling us, so any burst is already visible in the queue.
        // Releasing immediately keeps single-stream latency flat
        // (EXPERIMENTS.md §Perf L3 iteration 4).
        if self.queue.len() == 1 {
            let item = self.queue.pop_front().unwrap();
            return BatchDecision::Run {
                variant: item.variant.clone(),
                batch: vec![item],
            };
        }
        // Per-variant tally in first-occurrence (queue) order.
        let mut tally: Vec<(&str, usize, Instant)> = Vec::new();
        for q in &self.queue {
            match tally.iter_mut().find(|(v, _, _)| *v == q.variant) {
                Some((_, count, _)) => *count += 1,
                None => tally.push((q.variant.as_str(), 1, q.enqueued_at)),
            }
        }
        let ready = tally.iter().find(|(_, count, first)| {
            *count >= self.cfg.max_batch
                || now.duration_since(*first) >= self.cfg.max_wait
        });
        let Some(&(variant, count, _)) = ready else {
            // Nothing ready.  The head holds the oldest item, so its
            // deadline is the earliest; had it already expired it would
            // have been ready above, making this subtraction safe.
            let head_age =
                now.duration_since(self.queue.front().unwrap().enqueued_at);
            return BatchDecision::Wait(self.cfg.max_wait - head_age);
        };
        let variant = variant.to_string();

        let mut batch = Vec::with_capacity(count.min(self.cfg.max_batch));
        let mut rest = VecDeque::with_capacity(self.queue.len());
        while let Some(item) = self.queue.pop_front() {
            if item.variant == variant && batch.len() < self.cfg.max_batch {
                batch.push(item);
            } else {
                rest.push_back(item);
            }
        }
        self.queue = rest;
        BatchDecision::Run { variant, batch }
    }
}

#[derive(Debug)]
pub enum BatchDecision<T> {
    /// Nothing queued.
    Idle,
    /// A batch could grow; revisit after the given duration.
    Wait(Duration),
    /// Execute this group now.
    Run {
        variant: String,
        batch: Vec<Queued<T>>,
    },
}

#[cfg(test)]
mod tests {
    use super::*;

    fn q(variant: &str, at: Instant, id: usize) -> Queued<usize> {
        Queued {
            variant: variant.into(),
            enqueued_at: at,
            payload: id,
        }
    }

    fn cfg(max_batch: usize, wait_ms: u64) -> BatcherConfig {
        BatcherConfig {
            max_batch,
            max_wait: Duration::from_millis(wait_ms),
        }
    }

    #[test]
    fn idle_when_empty() {
        let mut b: Batcher<usize> = Batcher::new(cfg(4, 2));
        assert!(matches!(b.next_batch(Instant::now()), BatchDecision::Idle));
    }

    #[test]
    fn waits_for_more_of_same_variant() {
        let t0 = Instant::now();
        let mut b = Batcher::new(cfg(4, 10));
        b.push(q("v1", t0, 0));
        b.push(q("v1", t0, 1));
        match b.next_batch(t0 + Duration::from_millis(1)) {
            BatchDecision::Wait(d) => assert!(d <= Duration::from_millis(9)),
            other => panic!("expected Wait, got {other:?}"),
        }
        assert_eq!(b.len(), 2); // nothing consumed
    }

    #[test]
    fn lone_request_released_immediately() {
        let t0 = Instant::now();
        let mut b = Batcher::new(cfg(4, 10));
        b.push(q("v1", t0, 0));
        match b.next_batch(t0) {
            BatchDecision::Run { variant, batch } => {
                assert_eq!(variant, "v1");
                assert_eq!(batch.len(), 1);
            }
            other => panic!("expected Run, got {other:?}"),
        }
        assert!(b.is_empty());
    }

    #[test]
    fn releases_after_max_wait() {
        let t0 = Instant::now();
        let mut b = Batcher::new(cfg(4, 10));
        b.push(q("v1", t0, 0));
        b.push(q("v1", t0, 1));
        match b.next_batch(t0 + Duration::from_millis(11)) {
            BatchDecision::Run { variant, batch } => {
                assert_eq!(variant, "v1");
                assert_eq!(batch.len(), 2);
            }
            other => panic!("expected Run, got {other:?}"),
        }
    }

    #[test]
    fn full_batch_released_immediately() {
        let t0 = Instant::now();
        let mut b = Batcher::new(cfg(2, 1000));
        b.push(q("v1", t0, 0));
        b.push(q("v1", t0, 1));
        b.push(q("v1", t0, 2));
        match b.next_batch(t0) {
            BatchDecision::Run { batch, .. } => {
                assert_eq!(batch.iter().map(|x| x.payload).collect::<Vec<_>>(), vec![0, 1]);
            }
            other => panic!("expected Run, got {other:?}"),
        }
        assert_eq!(b.len(), 1); // third stays queued
    }

    #[test]
    fn preserves_fifo_within_variant_and_leaves_others() {
        let t0 = Instant::now();
        let mut b = Batcher::new(cfg(8, 0));
        b.push(q("v1", t0, 0));
        b.push(q("v2", t0, 1));
        b.push(q("v1", t0, 2));
        match b.next_batch(t0) {
            BatchDecision::Run { variant, batch } => {
                assert_eq!(variant, "v1");
                assert_eq!(batch.iter().map(|x| x.payload).collect::<Vec<_>>(), vec![0, 2]);
            }
            other => panic!("expected Run, got {other:?}"),
        }
        // v2 remains, now at the head
        match b.next_batch(t0) {
            BatchDecision::Run { variant, batch } => {
                assert_eq!(variant, "v2");
                assert_eq!(batch[0].payload, 1);
            }
            other => panic!("expected Run, got {other:?}"),
        }
        assert!(b.is_empty());
    }

    #[test]
    fn full_batch_behind_young_head_is_not_blocked() {
        // Regression (cross-variant head-of-line blocking): v1 sits young
        // inside its batch window, but v2 behind it already has max_batch
        // ready items — v2 must run now, leaving v1 queued.
        let t0 = Instant::now();
        let mut b = Batcher::new(cfg(2, 1000));
        b.push(q("v1", t0, 0));
        b.push(q("v2", t0, 1));
        b.push(q("v2", t0, 2));
        match b.next_batch(t0 + Duration::from_millis(1)) {
            BatchDecision::Run { variant, batch } => {
                assert_eq!(variant, "v2");
                assert_eq!(batch.iter().map(|x| x.payload).collect::<Vec<_>>(), vec![1, 2]);
            }
            other => panic!("expected Run for v2, got {other:?}"),
        }
        // v1 is still queued (now a lone head, released on the next call)
        assert_eq!(b.len(), 1);
        match b.next_batch(t0 + Duration::from_millis(1)) {
            BatchDecision::Run { variant, batch } => {
                assert_eq!(variant, "v1");
                assert_eq!(batch[0].payload, 0);
            }
            other => panic!("expected Run for v1, got {other:?}"),
        }
    }

    #[test]
    fn expired_head_released_before_full_follower() {
        // No starvation: once the head's window expires, it goes first
        // even though a full batch for another variant is also ready.
        let t0 = Instant::now();
        let mut b = Batcher::new(cfg(2, 10));
        b.push(q("v1", t0, 0));
        b.push(q("v2", t0, 1));
        b.push(q("v2", t0, 2));
        match b.next_batch(t0 + Duration::from_millis(11)) {
            BatchDecision::Run { variant, batch } => {
                assert_eq!(variant, "v1");
                assert_eq!(batch.len(), 1);
            }
            other => panic!("expected Run for v1, got {other:?}"),
        }
    }

    #[test]
    fn waits_when_no_variant_is_ready() {
        let t0 = Instant::now();
        let mut b = Batcher::new(cfg(3, 10));
        b.push(q("v1", t0, 0));
        b.push(q("v2", t0, 1));
        b.push(q("v2", t0, 2));
        match b.next_batch(t0 + Duration::from_millis(2)) {
            BatchDecision::Wait(d) => assert!(d <= Duration::from_millis(8)),
            other => panic!("expected Wait, got {other:?}"),
        }
        assert_eq!(b.len(), 3);
    }

    #[test]
    fn take_expired_sweeps_only_past_deadline_items() {
        // payload = optional deadline offset in ms from t0
        let t0 = Instant::now();
        let mut b: Batcher<Option<u64>> = Batcher::new(cfg(8, 1000));
        let push = |b: &mut Batcher<Option<u64>>, dl: Option<u64>| {
            b.push(Queued {
                variant: "v1".into(),
                enqueued_at: t0,
                payload: dl,
            });
        };
        push(&mut b, Some(5)); // expires at t0+5ms
        push(&mut b, None); // no deadline
        push(&mut b, Some(50)); // still live at sweep time
        push(&mut b, Some(1)); // expires at t0+1ms
        let now = t0 + Duration::from_millis(10);
        let expired =
            b.take_expired(now, |dl: &Option<u64>| dl.map(|ms| t0 + Duration::from_millis(ms)));
        let offsets: Vec<Option<u64>> = expired.iter().map(|q| q.payload).collect();
        assert_eq!(offsets, vec![Some(5), Some(1)], "queue order preserved");
        assert_eq!(b.len(), 2, "survivors stay queued");
        // survivors still batch normally
        match b.next_batch(now + Duration::from_millis(2000)) {
            BatchDecision::Run { batch, .. } => assert_eq!(batch.len(), 2),
            other => panic!("expected Run, got {other:?}"),
        }
    }

    #[test]
    fn take_expired_on_empty_queue_is_empty() {
        let mut b: Batcher<Option<u64>> = Batcher::new(cfg(4, 10));
        assert!(b.take_expired(Instant::now(), |_| None).is_empty());
    }

    #[test]
    fn head_of_line_variant_decided_by_fifo() {
        let t0 = Instant::now();
        let mut b = Batcher::new(cfg(8, 0));
        b.push(q("v2", t0, 9));
        b.push(q("v1", t0, 1));
        match b.next_batch(t0) {
            BatchDecision::Run { variant, .. } => assert_eq!(variant, "v2"),
            other => panic!("expected Run, got {other:?}"),
        }
    }
}
