//! Continuous-batching scheduler: deadline-ordered, priority-tiered
//! grouping of queued requests by target kernel variant.
//!
//! Serving-system shape (vLLM-like continuous batching): requests
//! arrive on a queue; whenever a device has a free execution slot the
//! dispatcher asks for the *next release* and gets, immediately, the
//! most urgent admissible job plus every same-variant job that can ride
//! in its micro-batch (up to `max_batch`).  There is no batching
//! window: a lone request dispatches the moment a device is free, and
//! batches form exactly when the devices are the bottleneck — work
//! accumulates while they are busy and drains in variant groups the
//! moment they are not.  (The previous dispatcher held *every* request
//! for up to `max_wait` hoping for batchmates; a lone request with
//! co-traffic queued behind it always paid the full window.)
//!
//! Release order is earliest-deadline-first within the highest occupied
//! priority tier.  A job without a deadline is ranked as if it were due
//! `max_wait` after arrival — that keeps deadline-free traffic
//! FIFO-fair among itself and lets explicitly urgent deadlines overtake
//! it, without letting either class starve the other.  The scheduler is
//! a pure state machine (I/O-free, fully unit-testable); the model in
//! `crate::check::protocol` mirrors these semantics and the
//! no-priority-inversion-past-deadline invariant pins the pick order.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

/// Priority tier of a request.  Order matters: `High` sorts before
/// `Normal` sorts before `Low`, so ascending sort order is release
/// order.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Priority {
    High,
    Normal,
    Low,
}

impl Default for Priority {
    fn default() -> Self {
        Priority::Normal
    }
}

impl Priority {
    /// Stable label for metrics rollups.
    pub fn label(self) -> &'static str {
        match self {
            Priority::High => "high",
            Priority::Normal => "normal",
            Priority::Low => "low",
        }
    }
}

/// A queued item tagged with its routing decision and scheduling keys.
#[derive(Debug)]
pub struct Queued<T> {
    pub variant: String,
    pub enqueued_at: Instant,
    pub priority: Priority,
    pub deadline: Option<Instant>,
    pub payload: T,
}

#[derive(Debug, Clone)]
pub struct BatcherConfig {
    /// Max same-variant jobs released into one micro-batch.
    pub max_batch: usize,
    /// Deadline slack assumed for jobs that carry no explicit deadline:
    /// they are ranked as if due `max_wait` after arrival.  This is an
    /// *ordering* default only — nothing is ever held back waiting for
    /// it to elapse.  (Pre-continuous-batching, this was a real dispatch
    /// window every batch waited out; the field keeps its name so
    /// existing configs read unchanged.)
    pub max_wait: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_micros(500),
        }
    }
}

/// One scheduler decision: the most urgent admissible job's variant and
/// every same-variant job riding in its micro-batch, in release order.
#[derive(Debug)]
pub struct Release<T> {
    pub variant: String,
    pub batch: Vec<Queued<T>>,
}

struct Entry<T> {
    /// Arrival tiebreak: earlier pushes release first among equal
    /// (priority, effective-deadline) keys.
    seq: u64,
    q: Queued<T>,
}

/// Pure continuous-batching state machine (I/O-free, fully
/// unit-testable).
pub struct Scheduler<T> {
    cfg: BatcherConfig,
    queue: VecDeque<Entry<T>>,
    next_seq: u64,
}

impl<T> Scheduler<T> {
    pub fn new(cfg: BatcherConfig) -> Self {
        assert!(cfg.max_batch >= 1, "max_batch must be >= 1");
        Scheduler {
            cfg,
            queue: VecDeque::new(),
            next_seq: 0,
        }
    }

    pub fn push(&mut self, item: Queued<T>) {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.queue.push_back(Entry { seq, q: item });
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// The deadline a job is *ranked* by: its own, or arrival +
    /// `max_wait` when it has none.
    fn effective_deadline(&self, q: &Queued<T>) -> Instant {
        q.deadline.unwrap_or(q.enqueued_at + self.cfg.max_wait)
    }

    /// Remove and return every queued item whose deadline is at or
    /// before `now`.  The dispatcher sweeps this between releases so a
    /// job that expires while waiting for a device is answered
    /// `DeadlineExceeded` promptly instead of burning a worker on stale
    /// output.  Relative order of survivors is preserved; expired items
    /// come back in queue order.
    pub fn take_expired(&mut self, now: Instant) -> Vec<Queued<T>> {
        if self.queue.is_empty() {
            return Vec::new();
        }
        let mut expired = Vec::new();
        let mut rest = VecDeque::with_capacity(self.queue.len());
        while let Some(e) = self.queue.pop_front() {
            match e.q.deadline {
                Some(dl) if dl <= now => expired.push(e.q),
                _ => rest.push_back(e),
            }
        }
        self.queue = rest;
        expired
    }

    /// Release the next micro-batch, *now*.  `None` only when nothing
    /// is queued — continuous batching never asks a free device to
    /// wait.  The head job is the minimum of (priority,
    /// effective deadline, arrival); the batch is every queued job of
    /// the head's variant in that same order, up to `max_batch`.
    pub fn next_release(&mut self, _now: Instant) -> Option<Release<T>> {
        let head = self
            .queue
            .iter()
            .min_by_key(|e| {
                (e.q.priority, self.effective_deadline(&e.q), e.seq)
            })?;
        let variant = head.q.variant.clone();

        // Collect the indices of the head variant's jobs in release
        // order, cap at max_batch, then drain them preserving that
        // order.
        let mut picked: Vec<(Priority, Instant, u64)> = self
            .queue
            .iter()
            .filter(|e| e.q.variant == variant)
            .map(|e| (e.q.priority, self.effective_deadline(&e.q), e.seq))
            .collect();
        picked.sort_unstable();
        picked.truncate(self.cfg.max_batch);

        let mut batch: Vec<Queued<T>> = Vec::with_capacity(picked.len());
        let mut rest = VecDeque::with_capacity(self.queue.len());
        while let Some(e) = self.queue.pop_front() {
            let key = (e.q.priority, self.effective_deadline(&e.q), e.seq);
            if e.q.variant == variant && picked.binary_search(&key).is_ok() {
                batch.push(e.q);
            } else {
                rest.push_back(e);
            }
        }
        self.queue = rest;
        // Drain order is arrival order; present the batch in release
        // (priority, deadline) order so batch[0] is the most urgent.
        batch.sort_by_key(|q| (q.priority, self.effective_deadline(q)));
        Some(Release { variant, batch })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn q(variant: &str, at: Instant, id: usize) -> Queued<usize> {
        Queued {
            variant: variant.into(),
            enqueued_at: at,
            priority: Priority::Normal,
            deadline: None,
            payload: id,
        }
    }

    fn qd(variant: &str, at: Instant, dl: Instant, id: usize) -> Queued<usize> {
        Queued {
            variant: variant.into(),
            enqueued_at: at,
            priority: Priority::Normal,
            deadline: Some(dl),
            payload: id,
        }
    }

    fn qp(variant: &str, at: Instant, p: Priority, id: usize) -> Queued<usize> {
        Queued {
            variant: variant.into(),
            enqueued_at: at,
            priority: p,
            deadline: None,
            payload: id,
        }
    }

    fn cfg(max_batch: usize, wait_ms: u64) -> BatcherConfig {
        BatcherConfig {
            max_batch,
            max_wait: Duration::from_millis(wait_ms),
        }
    }

    fn ids(r: &Release<usize>) -> Vec<usize> {
        r.batch.iter().map(|x| x.payload).collect()
    }

    #[test]
    fn none_when_empty() {
        let mut s: Scheduler<usize> = Scheduler::new(cfg(4, 2));
        assert!(s.next_release(Instant::now()).is_none());
    }

    #[test]
    fn lone_request_released_immediately() {
        let t0 = Instant::now();
        let mut s = Scheduler::new(cfg(4, 10_000));
        s.push(q("v1", t0, 0));
        // Asked the same instant it arrived, with a 10 s window that
        // would have held it under the old dispatcher.
        let r = s.next_release(t0).expect("lone request must release now");
        assert_eq!(r.variant, "v1");
        assert_eq!(ids(&r), vec![0]);
        assert!(s.is_empty());
    }

    #[test]
    fn queued_pair_releases_without_any_window() {
        // The old dispatcher's headline bug: two same-variant requests
        // below max_batch waited out the full window.  Continuous
        // batching releases both the moment a device asks.
        let t0 = Instant::now();
        let mut s = Scheduler::new(cfg(4, 10_000));
        s.push(q("v1", t0, 0));
        s.push(q("v1", t0, 1));
        let r = s.next_release(t0).expect("must not wait for batchmates");
        assert_eq!(ids(&r), vec![0, 1]);
        assert!(s.is_empty());
    }

    #[test]
    fn batch_capped_at_max_batch_fifo_within_variant() {
        let t0 = Instant::now();
        let mut s = Scheduler::new(cfg(2, 1000));
        s.push(q("v1", t0, 0));
        s.push(q("v1", t0, 1));
        s.push(q("v1", t0, 2));
        let r = s.next_release(t0).unwrap();
        assert_eq!(ids(&r), vec![0, 1]);
        assert_eq!(s.len(), 1);
        let r2 = s.next_release(t0).unwrap();
        assert_eq!(ids(&r2), vec![2]);
    }

    #[test]
    fn gathers_same_variant_across_interleavings() {
        let t0 = Instant::now();
        let mut s = Scheduler::new(cfg(8, 0));
        s.push(q("v1", t0, 0));
        s.push(q("v2", t0, 1));
        s.push(q("v1", t0, 2));
        let r = s.next_release(t0).unwrap();
        assert_eq!(r.variant, "v1");
        assert_eq!(ids(&r), vec![0, 2]);
        let r2 = s.next_release(t0).unwrap();
        assert_eq!(r2.variant, "v2");
        assert_eq!(ids(&r2), vec![1]);
        assert!(s.is_empty());
    }

    #[test]
    fn earliest_deadline_first_overrides_arrival_order() {
        let t0 = Instant::now();
        let mut s = Scheduler::new(cfg(8, 1));
        // Far deadline arrives first, near deadline second: EDF must
        // release the near one (v2) ahead of the earlier arrival.
        s.push(qd("v1", t0, t0 + Duration::from_millis(500), 0));
        s.push(qd("v2", t0, t0 + Duration::from_millis(5), 1));
        let r = s.next_release(t0).unwrap();
        assert_eq!(r.variant, "v2");
        assert_eq!(ids(&r), vec![1]);
    }

    #[test]
    fn deadline_free_jobs_rank_by_age_with_max_wait_slack() {
        let t0 = Instant::now();
        let mut s = Scheduler::new(cfg(8, 1));
        // A deadline-free job is ranked as due at arrival + max_wait
        // (t0+1ms) — more urgent than an explicit deadline 100ms out,
        // so the deadline-free head is not starved by deadlined
        // traffic.
        s.push(q("v1", t0, 0));
        s.push(qd("v2", t0, t0 + Duration::from_millis(100), 1));
        let r = s.next_release(t0).unwrap();
        assert_eq!(r.variant, "v1");
        // ...but an explicit deadline tighter than the slack overtakes.
        s.push(q("v1", t0, 2));
        s.push(qd("v2", t0, t0 + Duration::from_micros(100), 3));
        let r2 = s.next_release(t0).unwrap();
        assert_eq!(r2.variant, "v2");
    }

    #[test]
    fn high_priority_releases_before_older_low_priority() {
        let t0 = Instant::now();
        let mut s = Scheduler::new(cfg(8, 1));
        s.push(qp("v1", t0, Priority::Low, 0));
        s.push(qp("v2", t0 + Duration::from_millis(1), Priority::High, 1));
        let r = s.next_release(t0 + Duration::from_millis(2)).unwrap();
        assert_eq!(r.variant, "v2", "high priority first despite later arrival");
        let r2 = s.next_release(t0 + Duration::from_millis(2)).unwrap();
        assert_eq!(r2.variant, "v1");
    }

    #[test]
    fn within_a_batch_release_order_is_priority_then_deadline() {
        let t0 = Instant::now();
        let mut s = Scheduler::new(cfg(8, 1));
        s.push(qp("v1", t0, Priority::Low, 0));
        s.push(qd("v1", t0, t0 + Duration::from_millis(9), 1));
        s.push(qp("v1", t0, Priority::High, 2));
        s.push(qd("v1", t0, t0 + Duration::from_millis(3), 3));
        let r = s.next_release(t0).unwrap();
        // High tier first, then the Normal tier by deadline, Low last.
        assert_eq!(ids(&r), vec![2, 3, 1, 0]);
    }

    #[test]
    fn take_expired_sweeps_only_past_deadline_items() {
        let t0 = Instant::now();
        let mut s: Scheduler<usize> = Scheduler::new(cfg(8, 1000));
        s.push(qd("v1", t0, t0 + Duration::from_millis(5), 0));
        s.push(q("v1", t0, 1)); // no deadline: never swept
        s.push(qd("v1", t0, t0 + Duration::from_millis(50), 2));
        s.push(qd("v1", t0, t0 + Duration::from_millis(1), 3));
        let now = t0 + Duration::from_millis(10);
        let expired = s.take_expired(now);
        let offsets: Vec<usize> = expired.iter().map(|q| q.payload).collect();
        assert_eq!(offsets, vec![0, 3], "queue order preserved");
        assert_eq!(s.len(), 2, "survivors stay queued");
        let r = s.next_release(now).unwrap();
        assert_eq!(ids(&r), vec![2, 1], "survivor with the deadline is more urgent");
    }

    #[test]
    fn take_expired_on_empty_queue_is_empty() {
        let mut s: Scheduler<usize> = Scheduler::new(cfg(4, 10));
        assert!(s.take_expired(Instant::now()).is_empty());
    }

    #[test]
    fn priority_order_is_high_normal_low() {
        assert!(Priority::High < Priority::Normal);
        assert!(Priority::Normal < Priority::Low);
        assert_eq!(Priority::default(), Priority::Normal);
        assert_eq!(Priority::High.label(), "high");
    }
}
