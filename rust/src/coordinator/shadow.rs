//! Shadow tuning: make SIMD the *measured* default.
//!
//! The plan compiler's default pipeline is conservative — it lowers to
//! scalar kernels so every answer stays bit-identical to the naive
//! oracle.  The nanokernel tier (`runtime::nanokernel`) is faster on
//! real hardware but carries the `fma_relaxed` numerics class, so it
//! must not become the default by assertion.  This module makes it the
//! default by *measurement*:
//!
//! 1. **Shadow** — for a sampled fraction of live traffic the worker
//!    re-executes one request of the batch under the SIMD candidate
//!    plan (same key, `PlanOverride::Simd`), off the reply path.  The
//!    candidate output is verified against the served output under the
//!    condition-scaled `fma_relaxed` bound before its timing counts;
//!    an unverifiable candidate is rejected permanently.
//! 2. **Promote** — once enough samples agree the candidate beats the
//!    incumbent by the hysteresis margin, the registry's promoted-plan
//!    slot is swapped atomically ([`Registry::promote_plan`]).
//!    In-flight requests keep the plan `Arc` they captured at routing
//!    time; new routes serve the winner.
//! 3. **Persist** — the decision is appended to a plan DB
//!    (`reports/plandb.json`, format [`PLANDB_FORMAT`]) keyed by the
//!    problem *and* a hardware fingerprint (worker-pool width + probed
//!    ISA).  A restarting server warm-loads matching records and serves
//!    the promoted plans from the first request, with no re-measurement.
//!
//! Sampling, verification, and promotion all happen on the worker that
//! ran the batch, after the batch's replies are accounted but before
//! they are sent — the shadow run is bounded extra work per sampled
//! batch, never a second thread pool.  `MLIR_GEMM_SHADOW=off` disables
//! the whole path; the served results are byte-identical either way,
//! because the shadow run only ever *times* a candidate — it never
//! contributes bits to a reply.

use std::collections::{BTreeMap, HashMap};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::metrics::Metrics;
use crate::coordinator::registry::Registry;
use crate::plan::{self, ExecutionPlan, GemmKey, IsaPref, PlanEnv, PlanOverride};
use crate::runtime::nanokernel::{self, verify_fma_relaxed};
use crate::runtime::{BoundB, LoadedArtifact, Runtime, Tensor};
use crate::schedule::Dtype;
use crate::util::json::{self, Json};

/// Format tag for serialized plan DBs.
pub const PLANDB_FORMAT: &str = "mlir-gemm-plandb-v1";

/// `MLIR_GEMM_SHADOW=off` (or `0` / `false`) disables shadow tuning in
/// environments built from [`ShadowConfig::from_env`] — serving then
/// behaves exactly like the pre-shadow server.
pub const SHADOW_ENV: &str = "MLIR_GEMM_SHADOW";

/// Default on-disk location of the plan DB, relative to the store dir.
pub const PLANDB_DEFAULT_PATH: &str = "reports/plandb.json";

/// The DB key of a promotion record: problem identity plus the hardware
/// fingerprint the measurement is valid for.  A record measured under a
/// different pool width or ISA is *not* warm-loaded — timings do not
/// transfer across substrates.
///
/// Mirrored in `python/tests/test_plan_mirror.py` (`plandb_key`); the
/// golden fixture `rust/tests/golden/plandb_v1.json` pins the grammar
/// for both sides.
pub fn db_key(key: &GemmKey, threads: usize, isa: &str) -> String {
    format!(
        "{}x{}x{}/{}->{}+{}@t{}/{}",
        key.m,
        key.n,
        key.k,
        key.dtype_in.name(),
        key.dtype_acc.name(),
        key.epilogue,
        threads,
        isa
    )
}

/// One persisted promotion decision.
#[derive(Debug, Clone, PartialEq)]
pub struct PlanRecord {
    pub key: GemmKey,
    /// Worker-pool width the measurement ran under (half the hardware
    /// fingerprint: plans compiled for a pool are pool-specific).
    pub threads: usize,
    /// Probed/pinned nanokernel ISA name (the other half).
    pub isa: String,
    /// The promoted plan, in full `mlir-gemm-plan-v1` form.
    pub plan: ExecutionPlan,
    /// Plan id of the incumbent the candidate displaced.
    pub incumbent_id: String,
    pub incumbent_gflops: f64,
    pub candidate_gflops: f64,
    /// Shadow samples behind the decision.
    pub samples: u64,
}

impl PlanRecord {
    pub fn db_key(&self) -> String {
        db_key(&self.key, self.threads, &self.isa)
    }

    fn to_json(&self) -> Json {
        json::obj(vec![
            ("key", json::s(&self.db_key())),
            ("m", json::num(self.key.m as f64)),
            ("n", json::num(self.key.n as f64)),
            ("k", json::num(self.key.k as f64)),
            ("dtype_in", json::s(self.key.dtype_in.name())),
            ("dtype_acc", json::s(self.key.dtype_acc.name())),
            ("epilogue", json::s(&self.key.epilogue)),
            ("threads", json::num(self.threads as f64)),
            ("isa", json::s(&self.isa)),
            ("plan", self.plan.to_json()),
            ("incumbent_id", json::s(&self.incumbent_id)),
            ("incumbent_gflops", json::num(self.incumbent_gflops)),
            ("candidate_gflops", json::num(self.candidate_gflops)),
            ("samples", json::num(self.samples as f64)),
        ])
    }

    fn from_json(j: &Json) -> Result<PlanRecord> {
        let get_u = |f: &str| {
            j.get(f)
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("plan db record missing/invalid field {f:?}"))
        };
        let get_s = |f: &str| {
            j.get(f)
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("plan db record missing/invalid field {f:?}"))
        };
        let get_d = |f: &str| {
            j.get(f)
                .and_then(Json::as_str)
                .and_then(Dtype::parse)
                .ok_or_else(|| anyhow!("plan db record missing/invalid dtype field {f:?}"))
        };
        let key = GemmKey {
            m: get_u("m")?,
            n: get_u("n")?,
            k: get_u("k")?,
            dtype_in: get_d("dtype_in")?,
            dtype_acc: get_d("dtype_acc")?,
            epilogue: get_s("epilogue")?.to_string(),
        };
        let rec = PlanRecord {
            threads: get_u("threads")?,
            isa: get_s("isa")?.to_string(),
            plan: ExecutionPlan::from_json(
                j.get("plan").ok_or_else(|| anyhow!("plan db record missing plan"))?,
            )?,
            incumbent_id: get_s("incumbent_id")?.to_string(),
            incumbent_gflops: j.get("incumbent_gflops").and_then(Json::as_f64).unwrap_or(0.0),
            candidate_gflops: j.get("candidate_gflops").and_then(Json::as_f64).unwrap_or(0.0),
            samples: get_u("samples")? as u64,
            key,
        };
        // Two self-consistency checks, both hard errors: a record whose
        // stored key disagrees with its fields (grammar drift — exactly
        // what the golden fixture pins), and a record whose plan
        // describes a different problem than its key (would route one
        // GEMM onto another's kernel at warm load).
        let stored = get_s("key")?;
        if stored != rec.db_key() {
            bail!(
                "plan db record key {stored:?} does not match its fields (expect {:?})",
                rec.db_key()
            );
        }
        if !rec.plan.matches_gemm(
            rec.key.m,
            rec.key.n,
            rec.key.k,
            rec.key.dtype_in,
            rec.key.dtype_acc,
            &rec.key.epilogue,
        ) {
            bail!("plan db record {stored:?}: embedded plan {} describes a different GEMM", rec.plan.id());
        }
        Ok(rec)
    }
}

/// The persisted promotion database: db-key -> record, serialized with
/// sorted keys and the shortest-roundtrip float writer, so
/// save → load → save is byte-stable (tested).
#[derive(Debug, Clone, Default)]
pub struct PlanDb {
    records: BTreeMap<String, PlanRecord>,
}

impl PlanDb {
    /// Insert (or replace — latest decision wins) a record.
    pub fn insert(&mut self, rec: PlanRecord) {
        self.records.insert(rec.db_key(), rec);
    }

    pub fn get(&self, db_key: &str) -> Option<&PlanRecord> {
        self.records.get(db_key)
    }

    pub fn records(&self) -> impl Iterator<Item = &PlanRecord> {
        self.records.values()
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    pub fn to_json(&self) -> Json {
        let records: Vec<Json> = self.records.values().map(PlanRecord::to_json).collect();
        json::obj(vec![
            ("format", json::s(PLANDB_FORMAT)),
            ("records", Json::Arr(records)),
        ])
    }

    pub fn to_text(&self) -> String {
        self.to_json().to_string()
    }

    pub fn from_text(text: &str) -> Result<PlanDb> {
        let j = json::parse(text).map_err(|e| anyhow!("{e}"))?;
        let format = j.get("format").and_then(Json::as_str).unwrap_or("");
        if format != PLANDB_FORMAT {
            bail!("unsupported plan db format {format:?} (want {PLANDB_FORMAT})");
        }
        let mut db = PlanDb::default();
        for rec in j.get("records").and_then(Json::as_arr).unwrap_or(&[]) {
            db.insert(PlanRecord::from_json(rec)?);
        }
        Ok(db)
    }

    pub fn load(path: &Path) -> Result<PlanDb> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading plan db {}", path.display()))?;
        PlanDb::from_text(&text).with_context(|| format!("parsing plan db {}", path.display()))
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("creating {}", dir.display()))?;
        }
        std::fs::write(path, self.to_text())
            .with_context(|| format!("writing plan db {}", path.display()))
    }
}

/// Where shadow timings come from.  Production measures; deterministic
/// tests pin both sides so promotion decisions replay identically on
/// any build host (real execution and verification still happen — only
/// the stopwatch is substituted).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ShadowTimes {
    Measure,
    Fixed { incumbent: f64, candidate: f64 },
}

/// Shadow-tuning knobs.  `Default` is *disabled* — embedding a server
/// in a test never grows a measurement side-channel unless the test
/// asks; production servers build from [`ShadowConfig::from_env`],
/// where shadow is on unless `MLIR_GEMM_SHADOW=off`.
#[derive(Debug, Clone)]
pub struct ShadowConfig {
    pub enabled: bool,
    /// Sample every Nth batch per key (1 = every batch).
    pub sample_one_in: u32,
    /// Samples required before a promote/reject decision.
    pub min_samples: u64,
    /// The candidate must beat the incumbent by this factor on summed
    /// sampled time: `cand * hysteresis < inc`.  Keeps noise-level wins
    /// from flapping the serving plan.
    pub hysteresis: f64,
    /// Promotion DB path; `None` = decisions are process-local only.
    pub plandb_path: Option<PathBuf>,
    /// How the candidate compile resolves its nanokernel ISA.  `Detect`
    /// in production; tests pin `Fixed(Isa::Portable)` so decisions and
    /// DB bytes are host-independent.
    pub isa: IsaPref,
    pub timing: ShadowTimes,
}

impl Default for ShadowConfig {
    fn default() -> Self {
        ShadowConfig {
            enabled: false,
            sample_one_in: 8,
            min_samples: 3,
            hysteresis: 1.10,
            plandb_path: None,
            isa: IsaPref::Detect,
            timing: ShadowTimes::Measure,
        }
    }
}

impl ShadowConfig {
    /// The production configuration: enabled unless [`SHADOW_ENV`] says
    /// `off`, persisting to `<store>/reports/plandb.json`.
    pub fn from_env(store_dir: &Path) -> ShadowConfig {
        let off = matches!(
            std::env::var(SHADOW_ENV).unwrap_or_default().trim().to_ascii_lowercase().as_str(),
            "off" | "0" | "false"
        );
        ShadowConfig {
            enabled: !off,
            plandb_path: Some(store_dir.join(PLANDB_DEFAULT_PATH)),
            ..ShadowConfig::default()
        }
    }

    pub fn with_path(mut self, path: PathBuf) -> ShadowConfig {
        self.plandb_path = Some(path);
        self
    }
}

/// Per-key shadow progress.  `decided` latches: a key is measured until
/// its first promote/reject decision and never again in this process
/// (warm-loaded keys start decided — that is the "no re-measurement"
/// guarantee).
#[derive(Debug, Default)]
struct ShadowSlot {
    seen: u64,
    samples: u64,
    inc_sec: f64,
    cand_sec: f64,
    decided: bool,
}

/// The server-wide shadow state, shared by all workers.
pub struct ShadowState {
    cfg: ShadowConfig,
    /// Environment candidate plans compile under: the server's pool
    /// width with `PlanOverride::Simd` and the configured ISA source.
    cand_env: PlanEnv,
    threads: usize,
    /// Resolved ISA half of the hardware fingerprint ("scalar" when the
    /// probe finds nothing usable — then candidates equal incumbents
    /// and every key settles as rejected).
    isa_name: String,
    slots: Mutex<HashMap<GemmKey, ShadowSlot>>,
    db: Mutex<PlanDb>,
    sampled: AtomicU64,
    promoted: AtomicU64,
    rejected: AtomicU64,
    warm_loaded: AtomicU64,
}

impl ShadowState {
    pub fn new(cfg: ShadowConfig, pool_threads: usize) -> ShadowState {
        let isa_name = match cfg.isa {
            IsaPref::Fixed(i) => i.name().to_string(),
            IsaPref::Scalar => "scalar".to_string(),
            IsaPref::Detect => match nanokernel::detect() {
                Ok(Some(i)) => i.name().to_string(),
                // An unusable or force-disabled probe measures nothing:
                // "scalar" fingerprints the absence.
                _ => "scalar".to_string(),
            },
        };
        let cand_env = PlanEnv::for_pool(pool_threads)
            .with_force(PlanOverride::Simd)
            .with_isa(cfg.isa);
        ShadowState {
            cfg,
            cand_env,
            threads: pool_threads.max(1),
            isa_name,
            slots: Mutex::new(HashMap::new()),
            db: Mutex::new(PlanDb::default()),
            sampled: AtomicU64::new(0),
            promoted: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            warm_loaded: AtomicU64::new(0),
        }
    }

    pub fn config(&self) -> &ShadowConfig {
        &self.cfg
    }

    /// The resolved ISA half of this server's hardware fingerprint.
    pub fn isa_name(&self) -> &str {
        &self.isa_name
    }

    pub fn sampled(&self) -> u64 {
        self.sampled.load(Ordering::Relaxed)
    }

    pub fn promoted(&self) -> u64 {
        self.promoted.load(Ordering::Relaxed)
    }

    pub fn rejected(&self) -> u64 {
        self.rejected.load(Ordering::Relaxed)
    }

    pub fn warm_loaded(&self) -> u64 {
        self.warm_loaded.load(Ordering::Relaxed)
    }

    /// A copy of the current promotion DB (CLI inspection).
    pub fn db_snapshot(&self) -> PlanDb {
        self.db.lock().unwrap().clone()
    }

    /// Load the plan DB (if any) and install every record matching this
    /// server's hardware fingerprint as a promoted plan — before the
    /// first request routes, with no measurement.  Warm-loaded keys
    /// start `decided`, so they are never re-sampled this process.
    /// Returns how many records were installed.
    pub fn warm_load(&self, registry: &Registry, metrics: &Metrics) -> Result<usize> {
        let Some(path) = &self.cfg.plandb_path else { return Ok(0) };
        if !path.exists() {
            return Ok(0);
        }
        let db = PlanDb::load(path)?;
        let mut installed = 0u64;
        {
            let mut slots = self.slots.lock().unwrap();
            for rec in db.records() {
                if rec.threads != self.threads || rec.isa != self.isa_name {
                    continue;
                }
                let plan = Arc::new(rec.plan.clone());
                metrics.on_plan_seen(&plan.id(), &plan.isa_label());
                registry.promote_plan(&rec.key, plan);
                slots.insert(
                    rec.key.clone(),
                    ShadowSlot { decided: true, ..ShadowSlot::default() },
                );
                installed += 1;
            }
        }
        *self.db.lock().unwrap() = db;
        self.warm_loaded.store(installed, Ordering::Relaxed);
        Ok(installed as usize)
    }

    /// Worker hook: one successfully executed batch under `incumbent`.
    /// Decides whether to shadow it, and if so re-runs the batch's first
    /// item under the SIMD candidate, verifies, accumulates timings, and
    /// on the deciding sample promotes or rejects.  Never touches
    /// `items`/`outs` mutably and never fails the serving path: every
    /// candidate error (compile, execute, panic, verification) just
    /// settles the key as rejected.
    #[allow(clippy::too_many_arguments)]
    pub fn observe_batch(
        &self,
        rt: &Runtime,
        registry: &Registry,
        metrics: &Metrics,
        artifact: &LoadedArtifact,
        incumbent: &ExecutionPlan,
        items: &[Vec<Tensor>],
        outs: &[Vec<Tensor>],
        bound: Option<&Arc<BoundB>>,
        batch_exec_seconds: f64,
    ) {
        if items.is_empty() || outs.is_empty() {
            return;
        }
        let key = incumbent.key();
        // Conservative scope: the plain-GEMM class only.  Epilogue
        // fusion interacts with band write-back; keys carrying one keep
        // their compiled plan until the shadow path learns to verify
        // fused tails.
        if key.epilogue != "none" {
            return;
        }
        // Cadence and the decided latch, under the slot lock.
        {
            let mut g = self.slots.lock().unwrap();
            let slot = g.entry(key.clone()).or_default();
            if slot.decided {
                return;
            }
            slot.seen += 1;
            let stride = self.cfg.sample_one_in.max(1) as u64;
            if (slot.seen - 1) % stride != 0 {
                return;
            }
        }
        self.sampled.fetch_add(1, Ordering::Relaxed);

        let candidate = match plan::compile(&key, &self.cand_env) {
            Ok(p) => p,
            Err(_) => {
                self.settle(&key, true);
                return;
            }
        };
        if candidate.id() == incumbent.id() {
            // Already serving the candidate form (e.g. scalar-pinned
            // probe): nothing to measure, never sample again.
            self.settle(&key, false);
            return;
        }

        // The candidate runs the batch's first item in full inline form;
        // weight-bound items get their B reconstructed from the bind-time
        // cast operand (bits match the served panels by construction).
        let Some(full) = inline_item(&key, &items[0], bound) else { return };
        let ran = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            rt.execute_batch_timed_planned(artifact, std::slice::from_ref(&full), Some(&candidate))
        }));
        let (couts, ctiming) = match ran {
            Ok(Ok(v)) => v,
            _ => {
                self.settle(&key, true);
                return;
            }
        };

        // Verify the candidate against the *served* output under the
        // fma_relaxed contract before its timing counts for anything.
        // Both outputs sit within gamma(k+2)*scale of the exact sum, so
        // their distance is within the 2*gamma(k+2)*scale bound.
        let got = match couts.first().and_then(|o| o.first()) {
            Some(t) => &t.data,
            None => {
                self.settle(&key, true);
                return;
            }
        };
        let want = &outs[0][0].data;
        let verified = verify_fma_relaxed(
            got,
            want,
            &full[0].data,
            &full[1].data,
            &full[2].data,
            full.get(3).map(|t| t.data.as_slice()),
            key.m,
            key.n,
            key.k,
        );
        if verified.is_err() {
            self.settle(&key, true);
            return;
        }

        // Attribute the real shadow work to the candidate plan (zero
        // requests: no reply was served off it), so operators can see
        // the measurement happening in `metrics`.
        let flops = 2.0 * key.m as f64 * key.n as f64 * key.k as f64;
        metrics.on_plan_seen(&candidate.id(), &candidate.isa_label());
        metrics.on_plan_work(&candidate.id(), &candidate.isa_label(), 0, flops, ctiming.exec_seconds);

        let (inc_sec, cand_sec) = match self.cfg.timing {
            ShadowTimes::Measure => {
                (batch_exec_seconds / items.len() as f64, ctiming.exec_seconds)
            }
            ShadowTimes::Fixed { incumbent, candidate } => (incumbent, candidate),
        };

        // Accumulate; on the deciding sample, promote or reject.
        let decision = {
            let mut g = self.slots.lock().unwrap();
            let slot = g.entry(key.clone()).or_default();
            if slot.decided {
                return;
            }
            slot.samples += 1;
            slot.inc_sec += inc_sec;
            slot.cand_sec += cand_sec;
            if slot.samples < self.cfg.min_samples {
                return;
            }
            slot.decided = true;
            (slot.samples, slot.inc_sec, slot.cand_sec)
        };
        let (samples, inc_total, cand_total) = decision;
        if cand_total * self.cfg.hysteresis < inc_total {
            registry.promote_plan(&key, Arc::new(candidate.clone()));
            self.promoted.fetch_add(1, Ordering::Relaxed);
            let n = samples as f64;
            let rec = PlanRecord {
                key: key.clone(),
                threads: self.threads,
                isa: self.isa_name.clone(),
                plan: candidate,
                incumbent_id: incumbent.id(),
                incumbent_gflops: gflops(flops, inc_total / n),
                candidate_gflops: gflops(flops, cand_total / n),
                samples,
            };
            let mut db = self.db.lock().unwrap();
            db.insert(rec);
            if let Some(path) = &self.cfg.plandb_path {
                if let Err(e) = db.save(path) {
                    eprintln!("shadow: persisting plan db failed: {e:#}");
                }
            }
        } else {
            self.rejected.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Latch a key's decision without promoting.  `reject` distinguishes
    /// a failed candidate (counted) from a no-op (candidate == incumbent).
    fn settle(&self, key: &GemmKey, reject: bool) {
        let mut g = self.slots.lock().unwrap();
        let slot = g.entry(key.clone()).or_default();
        if slot.decided {
            return;
        }
        slot.decided = true;
        drop(g);
        if reject {
            self.rejected.fetch_add(1, Ordering::Relaxed);
        }
    }
}

fn gflops(flops: f64, seconds: f64) -> f64 {
    if seconds > 0.0 {
        flops / seconds / 1e9
    } else {
        0.0
    }
}

/// Rebuild the full inline input form `[A, B, C, (bias)]` for a batch
/// item.  Inline items pass through; weight-bound items (`[A, C,
/// (bias)]`) get B reinserted from the bind-time cast operand.
fn inline_item(
    key: &GemmKey,
    item: &[Tensor],
    bound: Option<&Arc<BoundB>>,
) -> Option<Vec<Tensor>> {
    match bound {
        None => {
            if item.len() < 3 {
                return None;
            }
            Some(item.to_vec())
        }
        Some(bw) => {
            if item.len() < 2 {
                return None;
            }
            let b = Tensor::new(vec![key.k, key.n], bw.raw().to_vec()).ok()?;
            let mut full = Vec::with_capacity(item.len() + 1);
            full.push(item[0].clone());
            full.push(b);
            full.extend(item[1..].iter().cloned());
            Some(full)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::KernelPolicy;

    fn record(m: usize, n: usize, k: usize) -> PlanRecord {
        let key = GemmKey::with_dtypes(m, n, k, Dtype::F32, Dtype::F32);
        let plan = ExecutionPlan::manual(
            &key,
            KernelPolicy::parse("simd:portable:64,256,256,1").unwrap(),
            false,
        )
        .unwrap();
        PlanRecord {
            key,
            threads: 2,
            isa: "portable".into(),
            plan,
            incumbent_id: format!("{m}x{n}x{k}/f32->f32:naive"),
            incumbent_gflops: 1.5,
            candidate_gflops: 3.0,
            samples: 3,
        }
    }

    #[test]
    fn db_key_grammar() {
        let key = GemmKey::plain(512, 384, 256);
        assert_eq!(db_key(&key, 2, "avx512"), "512x384x256/f16->f32+none@t2/avx512");
    }

    #[test]
    fn plan_db_round_trips_byte_stable() {
        let mut db = PlanDb::default();
        db.insert(record(128, 96, 112));
        db.insert(record(24, 24, 24));
        let first = db.to_text();
        let reloaded = PlanDb::from_text(&first).unwrap();
        assert_eq!(reloaded.len(), 2);
        assert_eq!(reloaded.to_text(), first, "save -> load -> save must be byte-stable");
        // Records come back structurally identical, sorted by db key.
        let keys: Vec<String> = reloaded.records().map(PlanRecord::db_key).collect();
        let mut sorted = keys.clone();
        sorted.sort();
        assert_eq!(keys, sorted);
        assert_eq!(reloaded.get(&record(24, 24, 24).db_key()), Some(&record(24, 24, 24)));
    }

    #[test]
    fn corrupted_records_are_loud_errors() {
        let rec = record(24, 24, 24);
        // Key/fields disagreement.
        let mut j = rec.to_json();
        if let Json::Obj(o) = &mut j {
            o.insert("key".into(), json::s("64x64x64/f32->f32+none@t2/portable"));
        }
        let doc = json::obj(vec![
            ("format", json::s(PLANDB_FORMAT)),
            ("records", Json::Arr(vec![j])),
        ]);
        let err = PlanDb::from_text(&doc.to_string()).unwrap_err();
        assert!(err.to_string().contains("does not match"), "{err}");
        // Wrong format tag.
        assert!(PlanDb::from_text("{\"format\":\"nope\",\"records\":[]}").is_err());
        // Plan describing a different problem than the record's key.
        let mut j = rec.to_json();
        if let Json::Obj(o) = &mut j {
            let other = record(128, 96, 112);
            o.insert("plan".into(), other.plan.to_json());
        }
        let doc = json::obj(vec![
            ("format", json::s(PLANDB_FORMAT)),
            ("records", Json::Arr(vec![j])),
        ]);
        assert!(PlanDb::from_text(&doc.to_string()).is_err());
    }

    #[test]
    fn default_config_is_disabled_and_env_config_is_on() {
        assert!(!ShadowConfig::default().enabled);
        // from_env honors the kill switch; run both sides under a lock in
        // the integration tests — here just the parsing of "off".
        let cfg = ShadowConfig { enabled: true, ..ShadowConfig::default() };
        assert!(cfg.plandb_path.is_none());
    }
}
