//! Service metrics: request counts, latency distributions, per-variant
//! execution tallies.  Lock-guarded aggregate; snapshots are cheap copies.

use std::collections::BTreeMap;
use std::sync::Mutex;

use crate::util::stats::Summary;

#[derive(Debug, Default)]
struct Inner {
    submitted: u64,
    completed: u64,
    failed: u64,
    batches: u64,
    batch_sizes: Vec<f64>,
    latencies_sec: Vec<f64>,
    queue_waits_sec: Vec<f64>,
    exec_sec: Vec<f64>,
    per_variant: BTreeMap<String, u64>,
}

#[derive(Debug, Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

#[derive(Debug, Clone)]
pub struct MetricsSnapshot {
    pub submitted: u64,
    pub completed: u64,
    pub failed: u64,
    pub batches: u64,
    pub mean_batch_size: f64,
    pub latency: Option<Summary>,
    pub queue_wait: Option<Summary>,
    pub exec: Option<Summary>,
    pub per_variant: BTreeMap<String, u64>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn on_submit(&self) {
        self.inner.lock().unwrap().submitted += 1;
    }

    pub fn on_batch(&self, size: usize) {
        let mut g = self.inner.lock().unwrap();
        g.batches += 1;
        g.batch_sizes.push(size as f64);
    }

    pub fn on_complete(
        &self,
        variant: &str,
        latency_sec: f64,
        queue_wait_sec: f64,
        exec_sec: f64,
    ) {
        let mut g = self.inner.lock().unwrap();
        g.completed += 1;
        g.latencies_sec.push(latency_sec);
        g.queue_waits_sec.push(queue_wait_sec);
        g.exec_sec.push(exec_sec);
        *g.per_variant.entry(variant.to_string()).or_insert(0) += 1;
    }

    pub fn on_fail(&self) {
        self.inner.lock().unwrap().failed += 1;
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let g = self.inner.lock().unwrap();
        let summ = |v: &Vec<f64>| {
            if v.is_empty() {
                None
            } else {
                Some(Summary::of(v))
            }
        };
        MetricsSnapshot {
            submitted: g.submitted,
            completed: g.completed,
            failed: g.failed,
            batches: g.batches,
            mean_batch_size: if g.batch_sizes.is_empty() {
                0.0
            } else {
                g.batch_sizes.iter().sum::<f64>() / g.batch_sizes.len() as f64
            },
            latency: summ(&g.latencies_sec),
            queue_wait: summ(&g.queue_waits_sec),
            exec: summ(&g.exec_sec),
            per_variant: g.per_variant.clone(),
        }
    }
}

impl MetricsSnapshot {
    pub fn report(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "requests: {} submitted, {} completed, {} failed\n",
            self.submitted, self.completed, self.failed
        ));
        out.push_str(&format!(
            "batches: {} (mean size {:.2})\n",
            self.batches, self.mean_batch_size
        ));
        if let Some(l) = &self.latency {
            out.push_str(&format!(
                "latency: p50 {:.3} ms, p95 {:.3} ms, p99 {:.3} ms, mean {:.3} ms\n",
                l.p50 * 1e3,
                l.p95 * 1e3,
                l.p99 * 1e3,
                l.mean * 1e3
            ));
        }
        if let Some(q) = &self.queue_wait {
            out.push_str(&format!("queue wait: p50 {:.3} ms\n", q.p50 * 1e3));
        }
        for (variant, count) in &self.per_variant {
            out.push_str(&format!("  {variant}: {count}\n"));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_and_summaries() {
        let m = Metrics::new();
        m.on_submit();
        m.on_submit();
        m.on_batch(2);
        m.on_complete("v1", 0.010, 0.002, 0.008);
        m.on_complete("v1", 0.020, 0.004, 0.016);
        m.on_fail();
        let s = m.snapshot();
        assert_eq!(s.submitted, 2);
        assert_eq!(s.completed, 2);
        assert_eq!(s.failed, 1);
        assert_eq!(s.mean_batch_size, 2.0);
        assert_eq!(s.per_variant["v1"], 2);
        let l = s.latency.unwrap();
        assert!((l.mean - 0.015).abs() < 1e-12);
    }

    #[test]
    fn empty_snapshot_has_no_summaries() {
        let s = Metrics::new().snapshot();
        assert!(s.latency.is_none());
        assert_eq!(s.mean_batch_size, 0.0);
    }

    #[test]
    fn report_mentions_variants() {
        let m = Metrics::new();
        m.on_complete("kernel_x", 0.01, 0.0, 0.01);
        assert!(m.snapshot().report().contains("kernel_x"));
    }
}
