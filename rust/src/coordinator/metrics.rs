//! Service metrics: request counts, latency distributions, per-variant
//! execution tallies, per-device load.  Lock-guarded aggregate; snapshots
//! are cheap copies.
//!
//! Latency/wait/exec/batch-size streams are held in fixed-size
//! [`Reservoir`]s, not unbounded vectors: under sustained traffic the
//! metric store must stay O(capacity).  Counts, means, min/max remain
//! exact; percentiles are estimated from the uniform sample.

use std::collections::BTreeMap;
use std::sync::Mutex;

use crate::util::stats::{Reservoir, Summary};

/// Retained samples per metric stream.
const RESERVOIR_CAPACITY: usize = 1024;

/// Per-device execution tallies (multi-device sharded engine).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct DeviceLoad {
    /// Tasks (batches or shards) executed on this device.
    pub tasks: u64,
    /// Total busy wall time on this device, seconds.
    pub busy_sec: f64,
}

/// Work executed under one compiled execution plan (`plan <id>` report
/// lines).  Attributed at *execution* time, keyed by the plan id that
/// actually ran the work — a refined/swapped plan opens a new entry
/// instead of blending totals under one label.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct PlanLoad {
    /// Completed GEMM requests.
    pub requests: u64,
    /// Total GEMM flops (2·m·n·k per request; transformer programs are
    /// not counted).
    pub flops: f64,
    /// Executor busy time spent on that work, seconds.
    pub busy_sec: f64,
    /// Requests served from bind-time prepacked B panels (`pack_b`
    /// skipped entirely on the hot path).
    pub pack_hits: u64,
    /// Requests on a packing kernel that had to pack B per call
    /// (operand shipped inline).
    pub pack_misses: u64,
    /// Request payload bytes not shipped because B was bound
    /// (4·k·n per weight-bound request).
    pub bytes_saved: f64,
}

/// GEMM work rolled up by the ISA lowering that executed it (`isa
/// <label>` report lines).  The label is the plan's pass-6 decision:
/// `scalar` for bit_exact scalar kernels, `simd:<isa>` for nanokernel
/// plans — the rollup answers "how much of the served work ran on the
/// explicit-SIMD backend" without walking every plan entry.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct IsaLoad {
    /// Completed GEMM requests.
    pub requests: u64,
    /// Total GEMM flops (2·m·n·k per request).
    pub flops: f64,
    /// Executor busy time spent on that work, seconds.
    pub busy_sec: f64,
}

/// Per-priority-tier admission/dispatch tallies (`priority <tier>`
/// report lines).  Submissions, scheduler releases, and deadline
/// expiries segment per tier; the queue-wait stream (pushed at release
/// time) is what the EDF ordering tests read — under load, `high` must
/// wait less than `low`.
#[derive(Debug, Clone, Default)]
pub struct PriorityLoad {
    pub submitted: u64,
    /// Jobs released into a micro-batch.
    pub released: u64,
    /// Jobs answered `DeadlineExceeded` (at admission or queued).
    pub expired: u64,
    /// Queue wait observed at release time.
    pub queue_wait: Option<Summary>,
}

/// Mutable accumulator behind [`PriorityLoad`].
#[derive(Debug)]
struct PrioInner {
    submitted: u64,
    released: u64,
    expired: u64,
    waits_sec: Reservoir,
}

impl Default for PrioInner {
    fn default() -> Self {
        PrioInner {
            submitted: 0,
            released: 0,
            expired: 0,
            waits_sec: Reservoir::new(RESERVOIR_CAPACITY, 0x9107),
        }
    }
}

#[derive(Debug)]
struct Inner {
    submitted: u64,
    completed: u64,
    failed: u64,
    /// Submissions refused at admission (bounded queue full).  Disjoint
    /// from `failed`: the accounting invariant is
    /// `submitted == completed + failed + rejected`.
    rejected: u64,
    /// Jobs answered `DeadlineExceeded` before execution.  A subset of
    /// `failed` (every expiry also counts as a failure).
    deadline_expired: u64,
    /// Jobs whose deadline was already past at `submit` — refused at
    /// admission without consuming a queue slot.  A subset of
    /// `deadline_expired`.
    expired_at_admission: u64,
    batches: u64,
    batch_sizes: Reservoir,
    latencies_sec: Reservoir,
    queue_waits_sec: Reservoir,
    exec_sec: Reservoir,
    /// Queue wait accumulated by jobs whose deadline expired while they
    /// sat queued — attribution for *why* deadlines blew.
    expired_wait_sec: Reservoir,
    /// Submit-queue depth sampled at each admission — the backpressure
    /// stream (p95 near capacity means clients should shed).
    queue_depths: Reservoir,
    per_variant: BTreeMap<String, u64>,
    per_device: BTreeMap<usize, DeviceLoad>,
    /// GEMM work keyed by the execution plan that ran it.
    per_plan: BTreeMap<String, PlanLoad>,
    /// GEMM work keyed by the plan's ISA lowering label.
    per_isa: BTreeMap<String, IsaLoad>,
    /// Quota rejections per tenant (admission tier).
    per_tenant_rejected: BTreeMap<String, u64>,
    /// Admission/dispatch tallies per priority tier.
    per_priority: BTreeMap<String, PrioInner>,
}

impl Default for Inner {
    fn default() -> Self {
        Inner {
            submitted: 0,
            completed: 0,
            failed: 0,
            rejected: 0,
            deadline_expired: 0,
            expired_at_admission: 0,
            batches: 0,
            batch_sizes: Reservoir::new(RESERVOIR_CAPACITY, 0xB47C),
            latencies_sec: Reservoir::new(RESERVOIR_CAPACITY, 0x1A7E),
            queue_waits_sec: Reservoir::new(RESERVOIR_CAPACITY, 0x9A17),
            exec_sec: Reservoir::new(RESERVOIR_CAPACITY, 0xE7EC),
            expired_wait_sec: Reservoir::new(RESERVOIR_CAPACITY, 0xDEAD),
            queue_depths: Reservoir::new(RESERVOIR_CAPACITY, 0xD397),
            per_variant: BTreeMap::new(),
            per_device: BTreeMap::new(),
            per_plan: BTreeMap::new(),
            per_isa: BTreeMap::new(),
            per_tenant_rejected: BTreeMap::new(),
            per_priority: BTreeMap::new(),
        }
    }
}

#[derive(Debug, Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

#[derive(Debug, Clone)]
pub struct MetricsSnapshot {
    pub submitted: u64,
    pub completed: u64,
    pub failed: u64,
    /// Admission rejections (queue full); `submitted == completed +
    /// failed + rejected` once the server drains.
    pub rejected: u64,
    /// Deadline-expired responses (subset of `failed`).
    pub deadline_expired: u64,
    /// Deadlines already past at `submit`, refused at admission without
    /// consuming a queue slot (subset of `deadline_expired`).
    pub expired_at_admission: u64,
    pub batches: u64,
    pub mean_batch_size: f64,
    pub latency: Option<Summary>,
    pub queue_wait: Option<Summary>,
    pub exec: Option<Summary>,
    /// Queue wait of deadline-expired jobs.
    pub expired_wait: Option<Summary>,
    /// Submit-queue depth sampled at each admission (backpressure).
    pub queue_depth: Option<Summary>,
    pub per_variant: BTreeMap<String, u64>,
    pub per_device: BTreeMap<usize, DeviceLoad>,
    pub per_plan: BTreeMap<String, PlanLoad>,
    pub per_isa: BTreeMap<String, IsaLoad>,
    /// Quota rejections per tenant.
    pub per_tenant_rejected: BTreeMap<String, u64>,
    /// Admission/dispatch tallies per priority tier.
    pub per_priority: BTreeMap<String, PriorityLoad>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn on_submit(&self) {
        self.inner.lock().unwrap().submitted += 1;
    }

    pub fn on_batch(&self, size: usize) {
        let mut g = self.inner.lock().unwrap();
        g.batches += 1;
        g.batch_sizes.push(size as f64);
    }

    pub fn on_complete(
        &self,
        variant: &str,
        latency_sec: f64,
        queue_wait_sec: f64,
        exec_sec: f64,
    ) {
        let mut g = self.inner.lock().unwrap();
        g.completed += 1;
        g.latencies_sec.push(latency_sec);
        g.queue_waits_sec.push(queue_wait_sec);
        g.exec_sec.push(exec_sec);
        *g.per_variant.entry(variant.to_string()).or_insert(0) += 1;
    }

    pub fn on_fail(&self) {
        self.inner.lock().unwrap().failed += 1;
    }

    /// Submission refused at admission: the bounded submit queue was
    /// full.  Rejections are their own accounting bucket, never blended
    /// into `failed` — the invariant becomes
    /// `submitted == completed + failed + rejected`.
    pub fn on_reject(&self) {
        self.inner.lock().unwrap().rejected += 1;
    }

    /// A job's deadline passed while it was queued; it was answered
    /// `DeadlineExceeded` without executing.  Counts as a failure (the
    /// client got an error) and attributes the queue wait it burned.
    pub fn on_deadline_expired(&self, queue_wait_sec: f64) {
        let mut g = self.inner.lock().unwrap();
        g.failed += 1;
        g.deadline_expired += 1;
        g.expired_wait_sec.push(queue_wait_sec);
    }

    /// A request arrived with its deadline already past and was refused
    /// at admission — no queue slot or tenant budget consumed.  Counts
    /// as a failure and a deadline expiry (zero queue wait burned, so
    /// nothing lands in the expired-wait stream).
    pub fn on_expired_at_admission(&self) {
        let mut g = self.inner.lock().unwrap();
        g.failed += 1;
        g.deadline_expired += 1;
        g.expired_at_admission += 1;
    }

    /// A tenant at its admission quota was refused.  Counts in the
    /// global `rejected` bucket (the accounting invariant is unchanged)
    /// and attributes the rejection to the tenant.
    pub fn on_tenant_reject(&self, tenant: &str) {
        let mut g = self.inner.lock().unwrap();
        g.rejected += 1;
        *g.per_tenant_rejected.entry(tenant.to_string()).or_insert(0) += 1;
    }

    /// Submit-queue depth observed at one admission (counting the job
    /// being admitted).
    pub fn on_queue_depth(&self, depth: usize) {
        self.inner.lock().unwrap().queue_depths.push(depth as f64);
    }

    /// One request submitted in priority tier `tier`.
    pub fn on_priority_submit(&self, tier: &str) {
        let mut g = self.inner.lock().unwrap();
        g.per_priority.entry(tier.to_string()).or_default().submitted += 1;
    }

    /// One job released into a micro-batch after `queue_wait_sec` in
    /// tier `tier` — the per-tier wait stream the EDF/priority ordering
    /// tests read.
    pub fn on_priority_release(&self, tier: &str, queue_wait_sec: f64) {
        let mut g = self.inner.lock().unwrap();
        let p = g.per_priority.entry(tier.to_string()).or_default();
        p.released += 1;
        p.waits_sec.push(queue_wait_sec);
    }

    /// One job in tier `tier` answered `DeadlineExceeded`.
    pub fn on_priority_expired(&self, tier: &str) {
        let mut g = self.inner.lock().unwrap();
        g.per_priority.entry(tier.to_string()).or_default().expired += 1;
    }

    /// Make a compiled plan visible in the report even before (or
    /// without) any work executing under it (the server preseeds every
    /// registry plan at startup).  `isa` is the plan's pass-6 lowering
    /// label (`scalar` or `simd:<isa>`); it seeds the ISA rollup so the
    /// report shows which backends are in play from the start.
    pub fn on_plan_seen(&self, plan_id: &str, isa: &str) {
        let mut g = self.inner.lock().unwrap();
        g.per_plan.entry(plan_id.to_string()).or_default();
        g.per_isa.entry(isa.to_string()).or_default();
    }

    /// Account completed GEMM work under the plan that actually executed
    /// it (the plan travels with the work item, read at execution time),
    /// and roll the same work up under the plan's ISA lowering label.
    pub fn on_plan_work(
        &self,
        plan_id: &str,
        isa: &str,
        requests: u64,
        flops: f64,
        busy_sec: f64,
    ) {
        let mut g = self.inner.lock().unwrap();
        let load = g.per_plan.entry(plan_id.to_string()).or_default();
        load.requests += requests;
        load.flops += flops;
        load.busy_sec += busy_sec;
        let rollup = g.per_isa.entry(isa.to_string()).or_default();
        rollup.requests += requests;
        rollup.flops += flops;
        rollup.busy_sec += busy_sec;
    }

    /// Account the prepacked-panel cache outcome of completed requests
    /// under one plan: `hits` ran straight off bind-time panels, `misses`
    /// re-packed an inline B, and `bytes_saved` is operand payload that
    /// never had to ship because the weights were bound.
    pub fn on_pack(&self, plan_id: &str, hits: u64, misses: u64, bytes_saved: f64) {
        if hits == 0 && misses == 0 && bytes_saved == 0.0 {
            return;
        }
        let mut g = self.inner.lock().unwrap();
        let load = g.per_plan.entry(plan_id.to_string()).or_default();
        load.pack_hits += hits;
        load.pack_misses += misses;
        load.bytes_saved += bytes_saved;
    }

    /// One task executed on device `device`, busy for `busy_sec`.
    pub fn on_device_task(&self, device: usize, busy_sec: f64) {
        let mut g = self.inner.lock().unwrap();
        let load = g.per_device.entry(device).or_default();
        load.tasks += 1;
        load.busy_sec += busy_sec;
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let g = self.inner.lock().unwrap();
        MetricsSnapshot {
            submitted: g.submitted,
            completed: g.completed,
            failed: g.failed,
            rejected: g.rejected,
            deadline_expired: g.deadline_expired,
            expired_at_admission: g.expired_at_admission,
            batches: g.batches,
            mean_batch_size: g.batch_sizes.mean(),
            latency: g.latencies_sec.summary(),
            queue_wait: g.queue_waits_sec.summary(),
            exec: g.exec_sec.summary(),
            expired_wait: g.expired_wait_sec.summary(),
            queue_depth: g.queue_depths.summary(),
            per_variant: g.per_variant.clone(),
            per_device: g.per_device.clone(),
            per_plan: g.per_plan.clone(),
            per_isa: g.per_isa.clone(),
            per_tenant_rejected: g.per_tenant_rejected.clone(),
            per_priority: g
                .per_priority
                .iter()
                .map(|(tier, p)| {
                    (
                        tier.clone(),
                        PriorityLoad {
                            submitted: p.submitted,
                            released: p.released,
                            expired: p.expired,
                            queue_wait: p.waits_sec.summary(),
                        },
                    )
                })
                .collect(),
        }
    }
}

impl MetricsSnapshot {
    pub fn report(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "requests: {} submitted, {} completed, {} failed, {} rejected\n",
            self.submitted, self.completed, self.failed, self.rejected
        ));
        if self.deadline_expired > 0 {
            if let Some(w) = &self.expired_wait {
                out.push_str(&format!(
                    "deadline expired: {} (queue wait p50 {:.3} ms)\n",
                    self.deadline_expired,
                    w.p50 * 1e3
                ));
            } else {
                out.push_str(&format!("deadline expired: {}\n", self.deadline_expired));
            }
            if self.expired_at_admission > 0 {
                out.push_str(&format!(
                    "  refused pre-expired at admission: {}\n",
                    self.expired_at_admission
                ));
            }
        }
        if let Some(d) = &self.queue_depth {
            out.push_str(&format!(
                "queue depth at admission: p50 {:.0}, p95 {:.0}, max {:.0}\n",
                d.p50, d.p95, d.max
            ));
        }
        out.push_str(&format!(
            "batches: {} (mean size {:.2})\n",
            self.batches, self.mean_batch_size
        ));
        if let Some(l) = &self.latency {
            out.push_str(&format!(
                "latency: p50 {:.3} ms, p95 {:.3} ms, p99 {:.3} ms, mean {:.3} ms\n",
                l.p50 * 1e3,
                l.p95 * 1e3,
                l.p99 * 1e3,
                l.mean * 1e3
            ));
        }
        if let Some(q) = &self.queue_wait {
            out.push_str(&format!("queue wait: p50 {:.3} ms\n", q.p50 * 1e3));
        }
        for (tier, p) in &self.per_priority {
            match &p.queue_wait {
                Some(w) => out.push_str(&format!(
                    "priority {tier}: {} submitted, {} released, {} expired \
                     (queue wait p50 {:.3} ms)\n",
                    p.submitted,
                    p.released,
                    p.expired,
                    w.p50 * 1e3
                )),
                None => out.push_str(&format!(
                    "priority {tier}: {} submitted, {} released, {} expired\n",
                    p.submitted, p.released, p.expired
                )),
            }
        }
        for (tenant, n) in &self.per_tenant_rejected {
            out.push_str(&format!("  tenant {tenant}: {n} quota-rejected\n"));
        }
        for (plan_id, load) in &self.per_plan {
            if load.busy_sec > 0.0 && load.flops > 0.0 {
                out.push_str(&format!(
                    "plan {plan_id}: {} reqs, {:.2} GFLOP, {:.2} GFLOP/s busy-throughput\n",
                    load.requests,
                    load.flops / 1e9,
                    load.flops / load.busy_sec / 1e9
                ));
            } else {
                out.push_str(&format!(
                    "plan {plan_id}: {} reqs, {:.2} GFLOP\n",
                    load.requests,
                    load.flops / 1e9
                ));
            }
            if load.pack_hits + load.pack_misses > 0 || load.bytes_saved > 0.0 {
                out.push_str(&format!(
                    "  pack cache: {} hits, {} misses, {:.2} MB payload saved\n",
                    load.pack_hits,
                    load.pack_misses,
                    load.bytes_saved / 1e6
                ));
            }
        }
        for (isa, load) in &self.per_isa {
            if load.busy_sec > 0.0 && load.flops > 0.0 {
                out.push_str(&format!(
                    "isa {isa}: {} reqs, {:.2} GFLOP, {:.2} GFLOP/s busy-throughput\n",
                    load.requests,
                    load.flops / 1e9,
                    load.flops / load.busy_sec / 1e9
                ));
            } else {
                out.push_str(&format!(
                    "isa {isa}: {} reqs, {:.2} GFLOP\n",
                    load.requests,
                    load.flops / 1e9
                ));
            }
        }
        for (variant, count) in &self.per_variant {
            out.push_str(&format!("  {variant}: {count}\n"));
        }
        for (device, load) in &self.per_device {
            out.push_str(&format!(
                "  device {device}: {} tasks, {:.3} s busy\n",
                load.tasks, load.busy_sec
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_and_summaries() {
        let m = Metrics::new();
        m.on_submit();
        m.on_submit();
        m.on_batch(2);
        m.on_complete("v1", 0.010, 0.002, 0.008);
        m.on_complete("v1", 0.020, 0.004, 0.016);
        m.on_fail();
        let s = m.snapshot();
        assert_eq!(s.submitted, 2);
        assert_eq!(s.completed, 2);
        assert_eq!(s.failed, 1);
        assert_eq!(s.mean_batch_size, 2.0);
        assert_eq!(s.per_variant["v1"], 2);
        let l = s.latency.unwrap();
        assert!((l.mean - 0.015).abs() < 1e-12);
    }

    #[test]
    fn rejections_are_disjoint_from_failures() {
        let m = Metrics::new();
        for _ in 0..5 {
            m.on_submit();
        }
        m.on_complete("v", 0.01, 0.0, 0.01);
        m.on_fail();
        m.on_reject();
        m.on_reject();
        m.on_reject();
        let s = m.snapshot();
        assert_eq!(s.rejected, 3);
        assert_eq!(s.failed, 1);
        assert_eq!(s.completed + s.failed + s.rejected, s.submitted);
        assert!(
            s.report().contains("5 submitted, 1 completed, 1 failed, 3 rejected"),
            "{}",
            s.report()
        );
    }

    #[test]
    fn deadline_expiry_counts_as_failure_and_attributes_wait() {
        let m = Metrics::new();
        m.on_submit();
        m.on_submit();
        m.on_deadline_expired(0.004);
        m.on_deadline_expired(0.008);
        let s = m.snapshot();
        assert_eq!(s.deadline_expired, 2);
        assert_eq!(s.failed, 2, "every expiry is also a failure");
        let w = s.expired_wait.unwrap();
        assert!((w.mean - 0.006).abs() < 1e-12);
        let report = s.report();
        assert!(report.contains("deadline expired: 2"), "{report}");
    }

    #[test]
    fn admission_expiry_is_a_deadline_failure_without_wait_attribution() {
        let m = Metrics::new();
        m.on_submit();
        m.on_expired_at_admission();
        let s = m.snapshot();
        assert_eq!(s.expired_at_admission, 1);
        assert_eq!(s.deadline_expired, 1, "subset of deadline_expired");
        assert_eq!(s.failed, 1, "subset of failed");
        assert!(s.expired_wait.is_none(), "no queue wait was burned");
        assert_eq!(s.completed + s.failed + s.rejected, s.submitted);
        let report = s.report();
        assert!(report.contains("refused pre-expired at admission: 1"), "{report}");
    }

    #[test]
    fn tenant_rejections_land_in_the_global_bucket_and_per_tenant() {
        let m = Metrics::new();
        for _ in 0..4 {
            m.on_submit();
        }
        m.on_complete("v", 0.01, 0.0, 0.01);
        m.on_tenant_reject("acme");
        m.on_tenant_reject("acme");
        m.on_reject();
        let s = m.snapshot();
        assert_eq!(s.rejected, 3, "tenant rejections count as rejections");
        assert_eq!(s.per_tenant_rejected["acme"], 2);
        assert_eq!(s.completed + s.failed + s.rejected, s.submitted);
        assert!(s.report().contains("tenant acme: 2 quota-rejected"), "{}", s.report());
    }

    #[test]
    fn queue_depth_stream_summarizes_backpressure() {
        let m = Metrics::new();
        m.on_queue_depth(1);
        m.on_queue_depth(3);
        m.on_queue_depth(8);
        let s = m.snapshot();
        let d = s.queue_depth.unwrap();
        assert_eq!(d.n, 3);
        assert_eq!(d.max, 8.0);
        assert!(s.report().contains("queue depth at admission"), "{}", s.report());
    }

    #[test]
    fn priority_tiers_segment_submits_releases_and_expiries() {
        let m = Metrics::new();
        m.on_priority_submit("high");
        m.on_priority_submit("high");
        m.on_priority_submit("low");
        m.on_priority_release("high", 0.001);
        m.on_priority_release("high", 0.003);
        m.on_priority_release("low", 0.040);
        m.on_priority_expired("low");
        let s = m.snapshot();
        assert_eq!(s.per_priority["high"].submitted, 2);
        assert_eq!(s.per_priority["high"].released, 2);
        assert_eq!(s.per_priority["high"].expired, 0);
        assert_eq!(s.per_priority["low"].expired, 1);
        let hw = s.per_priority["high"].queue_wait.as_ref().unwrap();
        let lw = s.per_priority["low"].queue_wait.as_ref().unwrap();
        assert!(
            hw.p50 < lw.p50,
            "high tier must wait less than low here: {} vs {}",
            hw.p50,
            lw.p50
        );
        let report = s.report();
        assert!(report.contains("priority high: 2 submitted, 2 released"), "{report}");
        assert!(report.contains("priority low:"), "{report}");
    }

    #[test]
    fn report_omits_deadline_line_when_none_expired() {
        let m = Metrics::new();
        m.on_submit();
        m.on_complete("v", 0.01, 0.0, 0.01);
        assert!(!m.snapshot().report().contains("deadline expired"));
    }

    #[test]
    fn empty_snapshot_has_no_summaries() {
        let s = Metrics::new().snapshot();
        assert!(s.latency.is_none());
        assert_eq!(s.mean_batch_size, 0.0);
        assert!(s.per_device.is_empty());
    }

    #[test]
    fn report_mentions_variants() {
        let m = Metrics::new();
        m.on_complete("kernel_x", 0.01, 0.0, 0.01);
        assert!(m.snapshot().report().contains("kernel_x"));
    }

    #[test]
    fn sustained_traffic_keeps_exact_counts_with_bounded_memory() {
        // Regression for the unbounded-vector memory leak: the reservoirs
        // cap retained samples, but counts and means must remain exact.
        let m = Metrics::new();
        let n = 50_000u64;
        for i in 0..n {
            m.on_submit();
            m.on_batch(4);
            m.on_complete("v", 0.001 * (i % 10) as f64, 0.0001, 0.0005);
        }
        let s = m.snapshot();
        assert_eq!(s.submitted, n);
        assert_eq!(s.completed, n);
        let l = s.latency.unwrap();
        assert_eq!(l.n, n as usize);
        // exact running mean of 0.001 * (0..10 cycling) = 0.0045
        assert!((l.mean - 0.0045).abs() < 1e-9, "mean {}", l.mean);
        assert_eq!(s.mean_batch_size, 4.0);
    }

    #[test]
    fn plan_work_is_segmented_per_plan_id() {
        let m = Metrics::new();
        m.on_plan_seen("64x64x64/f16:naive", "scalar");
        m.on_plan_work("64x64x64/f16:naive", "scalar", 2, 2.0e9, 0.5);
        // A plan swap (refinement) opens a new entry instead of blending
        // the old plan's totals under the new id.
        m.on_plan_work("512x512x512/f16:tiled:128,256,1024", "scalar", 1, 3.0e9, 0.25);
        let s = m.snapshot();
        assert_eq!(s.per_plan["64x64x64/f16:naive"].requests, 2);
        assert!((s.per_plan["64x64x64/f16:naive"].flops - 2.0e9).abs() < 1.0);
        assert_eq!(s.per_plan["512x512x512/f16:tiled:128,256,1024"].requests, 1);
        let report = s.report();
        // 2 GFLOP / 0.5 s = 4 GFLOP/s; 3 GFLOP / 0.25 s = 12 GFLOP/s
        assert!(report.contains("plan 64x64x64/f16:naive: 2 reqs"), "{report}");
        assert!(report.contains("4.00 GFLOP/s"), "{report}");
        assert!(
            report.contains("plan 512x512x512/f16:tiled:128,256,1024: 1 reqs"),
            "{report}"
        );
        assert!(report.contains("12.00 GFLOP/s"), "{report}");
    }

    #[test]
    fn plan_visible_before_any_work() {
        let m = Metrics::new();
        m.on_plan_seen("1024x1024x1024/f16:threaded:128,256,1024,4", "scalar");
        let report = m.snapshot().report();
        assert!(
            report.contains("plan 1024x1024x1024/f16:threaded:128,256,1024,4: 0 reqs"),
            "{report}"
        );
        // The seeded ISA label shows up too, before any work runs.
        assert!(report.contains("isa scalar: 0 reqs"), "{report}");
    }

    #[test]
    fn isa_rollup_aggregates_across_plans() {
        // Two scalar plans and one simd plan: the per-isa rollup blends
        // same-label plans but keeps the labels apart.
        let m = Metrics::new();
        m.on_plan_work("p_naive", "scalar", 2, 2.0e9, 0.5);
        m.on_plan_work("p_tiled", "scalar", 1, 1.0e9, 0.25);
        m.on_plan_work("p_simd", "simd:avx2", 4, 8.0e9, 0.5);
        let s = m.snapshot();
        assert_eq!(s.per_isa["scalar"].requests, 3);
        assert!((s.per_isa["scalar"].flops - 3.0e9).abs() < 1.0);
        assert_eq!(s.per_isa["simd:avx2"].requests, 4);
        let report = s.report();
        // scalar: 3 GFLOP / 0.75 s = 4 GFLOP/s; simd: 8 GFLOP / 0.5 s = 16
        assert!(report.contains("isa scalar: 3 reqs"), "{report}");
        assert!(report.contains("isa simd:avx2: 4 reqs"), "{report}");
        assert!(report.contains("16.00 GFLOP/s"), "{report}");
    }

    #[test]
    fn pack_cache_counters_segment_per_plan() {
        let m = Metrics::new();
        m.on_pack("p1", 3, 0, 3.0 * 4.0 * 512.0 * 512.0);
        m.on_pack("p1", 0, 2, 0.0);
        m.on_pack("p2", 0, 0, 0.0); // no-op: must not materialize an entry
        let s = m.snapshot();
        assert_eq!(s.per_plan["p1"].pack_hits, 3);
        assert_eq!(s.per_plan["p1"].pack_misses, 2);
        assert!((s.per_plan["p1"].bytes_saved - 3.0 * 1048576.0).abs() < 0.5);
        assert!(!s.per_plan.contains_key("p2"));
        let report = s.report();
        assert!(report.contains("pack cache: 3 hits, 2 misses"), "{report}");
    }

    #[test]
    fn per_device_tallies_accumulate() {
        let m = Metrics::new();
        m.on_device_task(0, 0.5);
        m.on_device_task(1, 0.25);
        m.on_device_task(0, 0.5);
        let s = m.snapshot();
        assert_eq!(s.per_device[&0].tasks, 2);
        assert!((s.per_device[&0].busy_sec - 1.0).abs() < 1e-12);
        assert_eq!(s.per_device[&1].tasks, 1);
        assert!(s.report().contains("device 0"));
    }
}
