//! Deterministic fault injection for the serving coordinator.
//!
//! The protocol checker ([`crate::check`]) proves invariants over a
//! *model* of the coordinator; this layer is the bridge back to the real
//! code: a [`FaultPlan`] threaded through [`super::server::ServerConfig`]
//! perturbs the live `Server` at explicit fault points — poison a job so
//! its batch execution panics, slow a device, delay routing or reply
//! delivery to widen race windows, or (behind test-only hooks) re-create
//! historical bugs — so the model's counterexample schedules can be
//! replayed against the production dispatcher/worker threads.
//!
//! Every decision is a pure function of the plan's `seed` and a stable
//! event identity (the job id for poisoning, a per-fault-point event
//! counter for delays), never of wall-clock time or thread scheduling:
//! a failing stress run prints its seed and replays exactly with
//! `MLIR_GEMM_FAULT_SEED=<seed>` (see [`seed_from_env`]).
//!
//! The default plan is a no-op on every path: a production server pays
//! one branch per fault point and nothing else.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Once;
use std::time::Duration;

/// Marker prefix carried by every injected panic payload; the test-side
/// panic-hook filter ([`silence_injected_panics`]) and log scrapers key
/// off it.
pub const INJECTED_PANIC_MARK: &str = "injected fault";

/// Per-category salts so each fault point draws from an independent
/// seed-derived stream.
const POISON_TAG: u64 = 0x01;
const SLOW_TAG: u64 = 0x02;
const REPLY_TAG: u64 = 0x03;
const ROUTE_TAG: u64 = 0x04;

/// A deterministic schedule of injected faults for one server run.
///
/// `*_one_in = 0` disables that fault point entirely (the default).
/// `*_one_in = n` fires the fault on every n-th event of that category,
/// phase-shifted by the seed, so different seeds pick different victims
/// while any one seed replays bit-for-bit.
#[derive(Debug, Clone)]
pub struct FaultPlan {
    /// Root of every per-category decision stream.
    pub seed: u64,
    /// Poison one job in `n` (keyed by job id): executing any batch that
    /// contains a poisoned job panics, exercising the server's panic
    /// containment and per-item quarantine.
    pub poison_one_in: u32,
    /// Slow device: stall one batch execution in `n` by `slow_exec`.
    pub slow_exec_one_in: u32,
    pub slow_exec: Duration,
    /// Delayed channel delivery: stall one response send in `n`.
    pub delay_reply_one_in: u32,
    pub delay_reply: Duration,
    /// Stall one routing decision in `n` *after* the job captured its
    /// plan and bound weights — the window in which a concurrent rebind
    /// lands, exercising the routed-Arc capture contract.
    pub delay_route_one_in: u32,
    pub delay_route: Duration,
    /// TEST HOOK: re-introduce the PR 5 shutdown bug — the dispatcher
    /// breaks as soon as the stop flag is up and the batcher is empty,
    /// stranding jobs still buffered in the submit channel (their reply
    /// channels drop without a response).  Exists so the protocol
    /// checker's counterexample for that bug replays against the real
    /// server; never set outside tests/`check-protocol --bug`.
    pub stop_flag_break: bool,
    /// TEST HOOK: park the dispatcher until `Server::shutdown` runs, so
    /// a replay can force the "everything submitted before the
    /// dispatcher moves" schedule deterministically (the schedule the
    /// model checker's stop-flag counterexample needs).
    pub hold_dispatch_until_shutdown: bool,
}

impl Default for FaultPlan {
    fn default() -> Self {
        FaultPlan {
            seed: 0,
            poison_one_in: 0,
            slow_exec_one_in: 0,
            slow_exec: Duration::ZERO,
            delay_reply_one_in: 0,
            delay_reply: Duration::ZERO,
            delay_route_one_in: 0,
            delay_route: Duration::ZERO,
            stop_flag_break: false,
            hold_dispatch_until_shutdown: false,
        }
    }
}

impl FaultPlan {
    /// True when every fault point is disabled (the production default).
    pub fn is_noop(&self) -> bool {
        self.poison_one_in == 0
            && self.slow_exec_one_in == 0
            && self.delay_reply_one_in == 0
            && self.delay_route_one_in == 0
            && !self.stop_flag_break
            && !self.hold_dispatch_until_shutdown
    }

    /// Whether this plan poisons the job with the given id.  Pure in
    /// (seed, id): tests compute the expected poison set up front and
    /// assert the server quarantined exactly those jobs.
    pub fn poisons(&self, job_id: u64) -> bool {
        hits(self.poison_one_in, phase(self.seed, POISON_TAG), job_id)
    }
}

/// The fault seed for this process: `MLIR_GEMM_FAULT_SEED` when set (a
/// decimal or `0x`-prefixed integer), else `default`.  Stress tests
/// print the seed they resolved so a failure replays exactly.
pub fn seed_from_env(default: u64) -> u64 {
    match std::env::var("MLIR_GEMM_FAULT_SEED") {
        Ok(v) => {
            let v = v.trim();
            let parsed = if let Some(hex) = v.strip_prefix("0x") {
                u64::from_str_radix(hex, 16).ok()
            } else {
                v.parse::<u64>().ok()
            };
            parsed.unwrap_or(default)
        }
        Err(_) => default,
    }
}

/// SplitMix64 step — the same expansion the repo's PRNG uses for seeds.
fn splitmix(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E3779B97F4A7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D049BB133111EB);
    x ^ (x >> 31)
}

/// Per-category phase shift: which residue class of events fires.
fn phase(seed: u64, tag: u64) -> u64 {
    splitmix(seed ^ tag.wrapping_mul(0xA5A5_A5A5_A5A5_A5A5))
}

/// Event `n` fires iff the (phase-shifted) counter lands on the residue.
fn hits(one_in: u32, phase: u64, n: u64) -> bool {
    one_in > 0 && n.wrapping_add(phase) % u64::from(one_in) == 0
}

/// Live injection state for one server: the plan plus per-fault-point
/// event counters and the two test-hook latches.  Shared by the
/// dispatcher and every worker via `Arc`.
#[derive(Debug)]
pub struct FaultState {
    plan: FaultPlan,
    slow_ctr: AtomicU64,
    reply_ctr: AtomicU64,
    route_ctr: AtomicU64,
    injected_panics: AtomicU64,
    injected_delays: AtomicU64,
    /// Raised by `Server::shutdown` before the submit channel closes.
    /// Inert unless `plan.stop_flag_break` re-arms the PR 5 break.
    stop: AtomicBool,
    /// Parks the dispatcher while true (hold-until-shutdown hook).
    hold: AtomicBool,
}

impl FaultState {
    pub fn new(plan: FaultPlan) -> FaultState {
        let hold = plan.hold_dispatch_until_shutdown;
        FaultState {
            plan,
            slow_ctr: AtomicU64::new(0),
            reply_ctr: AtomicU64::new(0),
            route_ctr: AtomicU64::new(0),
            injected_panics: AtomicU64::new(0),
            injected_delays: AtomicU64::new(0),
            stop: AtomicBool::new(false),
            hold: AtomicBool::new(hold),
        }
    }

    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }

    /// Panic if any of `ids` is poisoned — called from inside the
    /// contained batch-execution closure, so the panic models a crash in
    /// the executor itself and takes the same unwinding path a real
    /// kernel bug would.
    pub fn poison_gate(&self, ids: &[u64]) {
        for &id in ids {
            if self.plan.poisons(id) {
                self.injected_panics.fetch_add(1, Ordering::Relaxed);
                panic!(
                    "{INJECTED_PANIC_MARK}: poison job {id} (seed {:#x})",
                    self.plan.seed
                );
            }
        }
    }

    /// Slow-device fault point: one batch execution in `n` stalls.
    pub fn slow_exec(&self) {
        let n = self.slow_ctr.fetch_add(1, Ordering::Relaxed);
        if hits(self.plan.slow_exec_one_in, phase(self.plan.seed, SLOW_TAG), n) {
            self.injected_delays.fetch_add(1, Ordering::Relaxed);
            std::thread::sleep(self.plan.slow_exec);
        }
    }

    /// Delayed-delivery fault point: one response send in `n` stalls.
    pub fn delay_reply(&self) {
        let n = self.reply_ctr.fetch_add(1, Ordering::Relaxed);
        if hits(self.plan.delay_reply_one_in, phase(self.plan.seed, REPLY_TAG), n) {
            self.injected_delays.fetch_add(1, Ordering::Relaxed);
            std::thread::sleep(self.plan.delay_reply);
        }
    }

    /// Routing-window fault point: one routed job in `n` lingers between
    /// capturing its plan/weights and entering the batcher.
    pub fn delay_route(&self) {
        let n = self.route_ctr.fetch_add(1, Ordering::Relaxed);
        if hits(self.plan.delay_route_one_in, phase(self.plan.seed, ROUTE_TAG), n) {
            self.injected_delays.fetch_add(1, Ordering::Relaxed);
            std::thread::sleep(self.plan.delay_route);
        }
    }

    /// Injected panics so far — tests assert the schedule actually fired
    /// (a green run that injected nothing proves nothing).
    pub fn injected_panics(&self) -> u64 {
        self.injected_panics.load(Ordering::Relaxed)
    }

    /// Injected delays so far (slow-exec + delayed replies + routing
    /// stalls).
    pub fn injected_delays(&self) -> u64 {
        self.injected_delays.load(Ordering::Relaxed)
    }

    /// `Server::shutdown` raises the stop flag before closing the submit
    /// channel — the exact ordering under which PR 5's break stranded
    /// buffered jobs — and releases a held dispatcher.
    pub fn on_shutdown(&self) {
        self.stop.store(true, Ordering::Release);
        self.hold.store(false, Ordering::Release);
    }

    /// True when the stop-flag-break hook is armed *and* the stop flag
    /// is up: the dispatcher re-creates the PR 5 early break.
    pub fn stop_flag_break_armed(&self) -> bool {
        self.plan.stop_flag_break && self.stop.load(Ordering::Acquire)
    }

    /// Park the calling thread while the dispatch hold is engaged.
    pub fn wait_dispatch_released(&self) {
        while self.hold.load(Ordering::Acquire) {
            std::thread::sleep(Duration::from_millis(1));
        }
    }
}

static SILENCE: Once = Once::new();

/// Install a process-wide panic-hook filter that swallows the default
/// "thread panicked" report for *injected* panics (they are expected and
/// caught) while delegating every real panic to the previous hook.
/// Idempotent; fault-injection tests call it first thing.
pub fn silence_injected_panics() {
    SILENCE.call_once(|| {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            let injected = info
                .payload()
                .downcast_ref::<String>()
                .map(|s| s.contains(INJECTED_PANIC_MARK))
                .or_else(|| {
                    info.payload()
                        .downcast_ref::<&str>()
                        .map(|s| s.contains(INJECTED_PANIC_MARK))
                })
                .unwrap_or(false);
            if !injected {
                prev(info);
            }
        }));
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_plan_is_noop() {
        let plan = FaultPlan::default();
        assert!(plan.is_noop());
        assert!((0..100).all(|id| !plan.poisons(id)));
        let st = FaultState::new(plan);
        st.slow_exec();
        st.delay_reply();
        st.delay_route();
        st.poison_gate(&[0, 1, 2]);
        assert_eq!(st.injected_panics(), 0);
        assert_eq!(st.injected_delays(), 0);
    }

    #[test]
    fn poison_set_is_deterministic_and_seed_dependent() {
        let plan_a = FaultPlan { seed: 1, poison_one_in: 4, ..FaultPlan::default() };
        let plan_b = FaultPlan { seed: 1, poison_one_in: 4, ..FaultPlan::default() };
        let set = |p: &FaultPlan| (0..64).filter(|&i| p.poisons(i)).collect::<Vec<u64>>();
        assert_eq!(set(&plan_a), set(&plan_b));
        // one in four jobs, exactly
        assert_eq!(set(&plan_a).len(), 16);
        // consecutive poisoned ids are 4 apart (residue class)
        assert!(set(&plan_a).windows(2).all(|w| w[1] - w[0] == 4));
        // a different seed picks a different residue at least sometimes
        let shifted = (2..64u64)
            .map(|s| FaultPlan { seed: s, poison_one_in: 4, ..FaultPlan::default() })
            .any(|p| set(&p) != set(&plan_a));
        assert!(shifted, "every seed chose the same victims");
    }

    #[test]
    fn poison_gate_panics_only_for_poisoned_ids() {
        silence_injected_panics();
        let plan = FaultPlan { seed: 7, poison_one_in: 3, ..FaultPlan::default() };
        let victim = (0..16).find(|&i| plan.poisons(i)).unwrap();
        let clean: Vec<u64> = (0..16).filter(|&i| !plan.poisons(i)).collect();
        let st = FaultState::new(plan);
        st.poison_gate(&clean);
        assert_eq!(st.injected_panics(), 0);
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            st.poison_gate(&[victim]);
        }));
        assert!(caught.is_err(), "poisoned id must panic");
        assert_eq!(st.injected_panics(), 1);
    }

    #[test]
    fn counters_fire_one_in_n() {
        let plan = FaultPlan {
            seed: 3,
            slow_exec_one_in: 4,
            slow_exec: Duration::ZERO,
            ..FaultPlan::default()
        };
        let st = FaultState::new(plan);
        for _ in 0..16 {
            st.slow_exec();
        }
        assert_eq!(st.injected_delays(), 4);
    }

    #[test]
    fn stop_flag_arms_only_with_the_hook() {
        let st = FaultState::new(FaultPlan::default());
        st.on_shutdown();
        assert!(!st.stop_flag_break_armed(), "hook off: flag is inert");
        let st = FaultState::new(FaultPlan {
            stop_flag_break: true,
            ..FaultPlan::default()
        });
        assert!(!st.stop_flag_break_armed(), "not raised yet");
        st.on_shutdown();
        assert!(st.stop_flag_break_armed());
    }

    #[test]
    fn hold_engages_and_releases() {
        let st = FaultState::new(FaultPlan {
            hold_dispatch_until_shutdown: true,
            ..FaultPlan::default()
        });
        assert!(st.hold.load(Ordering::Acquire));
        st.on_shutdown();
        // released: wait returns immediately
        st.wait_dispatch_released();
    }

    #[test]
    fn seed_env_parses_decimal_and_hex() {
        // Only meaningful when the replay override is not in use.
        if std::env::var("MLIR_GEMM_FAULT_SEED").is_err() {
            assert_eq!(seed_from_env(42), 42);
        }
    }
}
