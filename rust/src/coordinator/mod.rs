//! L3 coordinator: the serving layer over the generated kernels.
//!
//! * `registry` — shape -> ranked kernel variants (autotuned routing table);
//! * `batcher`  — dynamic same-variant batching (pure state machine);
//! * `server`   — dispatcher + worker pool over the PJRT runtime;
//! * `metrics`  — request/latency accounting.

pub mod batcher;
pub mod metrics;
pub mod registry;
pub mod server;

pub use batcher::{BatchDecision, Batcher, BatcherConfig, Queued};
pub use metrics::{Metrics, MetricsSnapshot};
pub use registry::{GemmKey, Registry, RegistryEntry};
pub use server::{GemmRequest, GemmResponse, Server, ServerConfig};
