//! L3 coordinator: the serving layer over the generated kernels.
//!
//! * `registry` — shape/precision -> ranked kernel variants (autotuned
//!   routing table);
//! * `batcher`  — dynamic same-variant batching (pure state machine);
//! * `sharding` — shard planner + multi-device execution pool;
//! * `server`   — dispatcher + per-device worker queues over the runtime;
//! * `metrics`  — request/latency/per-device accounting.

pub mod batcher;
pub mod metrics;
pub mod registry;
pub mod server;
pub mod sharding;

pub use batcher::{BatchDecision, Batcher, BatcherConfig, Queued};
pub use metrics::{DeviceLoad, Metrics, MetricsSnapshot, PlanLoad};
pub use registry::{GemmKey, Registry, RegistryEntry};
pub use server::{GemmRequest, GemmResponse, ProgramRequest, Server, ServerConfig};
pub use sharding::{
    modeled_speedup, modeled_times, plan_for, ShardConfig, ShardPlan, ShardPool,
    ShardStrategy, SplitDim,
};
