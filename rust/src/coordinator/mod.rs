//! L3 coordinator: the serving layer over the generated kernels.
//!
//! * `registry` — shape/precision -> ranked kernel variants (autotuned
//!   routing table);
//! * `batcher`  — continuous-batching scheduler: deadline-ordered,
//!   priority-tiered same-variant micro-batches (pure state machine);
//! * `sharding` — shard planner + multi-device execution pool;
//! * `server`   — dispatcher + per-device worker queues over the runtime;
//! * `metrics`  — request/latency/per-device accounting;
//! * `faults`   — deterministic fault-injection plan threaded through the
//!   server so model-checker counterexamples replay against real code;
//! * `shadow`   — measured SIMD promotion: sample live traffic, verify +
//!   time the SIMD candidate plan off the reply path, atomically promote
//!   winners in the registry, persist them to the plan DB.

pub mod batcher;
pub mod faults;
pub mod metrics;
pub mod registry;
pub mod server;
pub mod shadow;
pub mod sharding;

pub use batcher::{BatcherConfig, Priority, Queued, Release, Scheduler};
pub use faults::{seed_from_env, silence_injected_panics, FaultPlan, FaultState};
pub use metrics::{DeviceLoad, Metrics, MetricsSnapshot, PlanLoad, PriorityLoad};
pub use registry::{GemmKey, Registry, RegistryEntry};
pub use server::{
    AdmissionConfig, GemmRequest, GemmResponse, ProgramRequest, Server,
    ServerConfig, SubmitOpts, ERR_DEADLINE, ERR_POISONED, ERR_QUEUE_FULL,
    ERR_SHUTDOWN,
};
pub use shadow::{
    PlanDb, PlanRecord, ShadowConfig, ShadowState, ShadowTimes, PLANDB_FORMAT,
    SHADOW_ENV,
};
pub use sharding::{
    modeled_speedup, modeled_times, plan_for, ShardConfig, ShardPlan, ShardPool,
    ShardStrategy, SplitDim,
};
