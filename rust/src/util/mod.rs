//! Support substrates built in-repo (the offline vendor set has no serde,
//! clap, rand, criterion, or proptest — each has a small equivalent here).

pub mod cli;
pub mod json;
pub mod prng;
pub mod proptest;
pub mod stats;
