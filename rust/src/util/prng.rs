//! Deterministic PRNG (xoshiro256++) for workloads and property tests.
//!
//! The offline vendor set has no `rand`; this is a small, well-known
//! generator with splittable seeding, enough for synthetic matrices and
//! the proptest-lite shrinker.

#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // SplitMix64 to expand the seed, as recommended by the xoshiro authors.
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng {
            s: [next(), next(), next(), next()],
        }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, bound) without modulo bias (Lemire).
    pub fn below(&mut self, bound: usize) -> usize {
        assert!(bound > 0, "below(0)");
        let bound = bound as u64;
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(bound as u128);
            let lo = m as u64;
            if lo >= bound.wrapping_neg() % bound || bound.is_power_of_two() {
                return (m >> 64) as usize;
            }
        }
    }

    /// Uniform in [lo, hi].
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as usize) as i64
    }

    pub fn choice<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.below(items.len())]
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-300);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Matrix of standard normals (row-major), f32.
    pub fn normal_matrix(&mut self, rows: usize, cols: usize) -> Vec<f32> {
        (0..rows * cols).map(|_| self.normal() as f32).collect()
    }

    /// Fork a new independent stream (for per-worker rngs).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(4);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            seen[r.below(7)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut r = Rng::new(6);
        let mut f1 = r.fork();
        let mut f2 = r.fork();
        assert_ne!(f1.next_u64(), f2.next_u64());
    }

    #[test]
    fn golden_values_pin_the_generator() {
        // xoshiro256++ with the SplitMix64 expansion of seed 42, computed
        // by an independent implementation.  Pins cross-version stability:
        // every seeded workload in the repo depends on these streams.
        let mut r = Rng::new(42);
        assert_eq!(r.next_u64(), 0xefdb3abe2d004720);
        assert_eq!(r.next_u64(), 0x74285db8cad01896);
        assert_eq!(r.next_u64(), 0xe6026692c15933c2);
    }

    #[test]
    fn fork_is_deterministic_given_parent_seed() {
        let mut a = Rng::new(9);
        let mut b = Rng::new(9);
        let mut fa = a.fork();
        let mut fb = b.fork();
        for _ in 0..50 {
            assert_eq!(fa.next_u64(), fb.next_u64());
        }
        // parent streams also stay in lockstep after forking
        assert_eq!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn below_one_is_always_zero() {
        let mut r = Rng::new(11);
        for _ in 0..100 {
            assert_eq!(r.below(1), 0);
        }
    }

    #[test]
    fn range_hits_both_endpoints() {
        let mut r = Rng::new(12);
        let mut lo_seen = false;
        let mut hi_seen = false;
        for _ in 0..1000 {
            let v = r.range(-3, 3);
            assert!((-3..=3).contains(&v));
            lo_seen |= v == -3;
            hi_seen |= v == 3;
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn normal_matrix_has_expected_len_and_determinism() {
        let m1 = Rng::new(13).normal_matrix(4, 6);
        let m2 = Rng::new(13).normal_matrix(4, 6);
        assert_eq!(m1.len(), 24);
        assert_eq!(m1, m2);
        assert!(m1.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn choice_draws_every_item() {
        let items: [usize; 4] = [1, 2, 3, 4];
        let mut r = Rng::new(14);
        let mut seen = [false; 4];
        for _ in 0..200 {
            seen[*r.choice(&items) - 1] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }
}
