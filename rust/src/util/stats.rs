//! Timing statistics: the measurement protocol of the paper's §4
//! (10-run averages, kernel-only timing) plus percentiles for the
//! serving-latency reports.

#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

impl Summary {
    pub fn of(samples: &[f64]) -> Summary {
        assert!(!samples.is_empty(), "Summary::of on empty slice");
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean).powi(2)).sum::<f64>()
            / n as f64;
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p50: percentile(&sorted, 0.50),
            p95: percentile(&sorted, 0.95),
            p99: percentile(&sorted, 0.99),
        }
    }
}

/// Linear-interpolated percentile on a pre-sorted slice.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=1.0).contains(&q));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// TFLOPs for a GEMM given its wall time.
pub fn tflops(m: usize, n: usize, k: usize, seconds: f64) -> f64 {
    (2.0 * m as f64 * n as f64 * k as f64) / seconds / 1e12
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.p50, 3.0);
    }

    #[test]
    fn percentile_interpolates() {
        let sorted = [0.0, 10.0];
        assert_eq!(percentile(&sorted, 0.5), 5.0);
        assert_eq!(percentile(&sorted, 0.0), 0.0);
        assert_eq!(percentile(&sorted, 1.0), 10.0);
    }

    #[test]
    fn singleton() {
        let s = Summary::of(&[7.0]);
        assert_eq!(s.p99, 7.0);
        assert_eq!(s.std, 0.0);
    }

    #[test]
    fn tflops_sanity() {
        // 8192^3 GEMM in 31.3 ms ~ 35.1 TFLOPs (the paper's ballpark)
        let t = tflops(8192, 8192, 8192, 0.0313);
        assert!((t - 35.1).abs() < 0.2, "{t}");
    }

    #[test]
    #[should_panic]
    fn empty_panics() {
        Summary::of(&[]);
    }
}
