//! Timing statistics: the measurement protocol of the paper's §4
//! (10-run averages, kernel-only timing) plus percentiles for the
//! serving-latency reports and a bounded reservoir for long-running
//! metric streams.

use crate::util::prng::Rng;

#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

impl Summary {
    pub fn of(samples: &[f64]) -> Summary {
        assert!(!samples.is_empty(), "Summary::of on empty slice");
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean).powi(2)).sum::<f64>()
            / n as f64;
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p50: percentile(&sorted, 0.50),
            p95: percentile(&sorted, 0.95),
            p99: percentile(&sorted, 0.99),
        }
    }
}

/// Linear-interpolated percentile on a pre-sorted slice.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=1.0).contains(&q));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// TFLOPs for a GEMM given its wall time.
pub fn tflops(m: usize, n: usize, k: usize, seconds: f64) -> f64 {
    (2.0 * m as f64 * n as f64 * k as f64) / seconds / 1e12
}

/// Bounded sample store for unbounded metric streams (Vitter's algorithm
/// R over the in-repo PRNG).  Memory is O(capacity) no matter how many
/// values are pushed; count/mean/min/max stay exact, and percentiles come
/// from a uniform sample of the full stream.
#[derive(Debug)]
pub struct Reservoir {
    samples: Vec<f64>,
    capacity: usize,
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
    rng: Rng,
}

impl Reservoir {
    pub fn new(capacity: usize, seed: u64) -> Reservoir {
        assert!(capacity >= 1, "reservoir capacity must be >= 1");
        Reservoir {
            samples: Vec::new(),
            capacity,
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            rng: Rng::new(seed),
        }
    }

    pub fn push(&mut self, x: f64) {
        self.count += 1;
        self.sum += x;
        self.min = self.min.min(x);
        self.max = self.max.max(x);
        if self.samples.len() < self.capacity {
            self.samples.push(x);
        } else {
            // Keep each of the `count` values with equal probability.
            let j = self.rng.below(self.count as usize);
            if j < self.capacity {
                self.samples[j] = x;
            }
        }
    }

    /// Values pushed so far (exact, not sample count).
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Exact running mean over every pushed value.
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Summary over the stream: n/mean/min/max are exact; std and the
    /// percentiles are estimated from the retained sample.
    pub fn summary(&self) -> Option<Summary> {
        if self.count == 0 {
            return None;
        }
        let mut s = Summary::of(&self.samples);
        s.n = self.count as usize;
        s.mean = self.mean();
        s.min = self.min;
        s.max = self.max;
        Some(s)
    }

    /// Retained sample size (bounded by capacity).
    pub fn sample_len(&self) -> usize {
        self.samples.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.p50, 3.0);
    }

    #[test]
    fn percentile_interpolates() {
        let sorted = [0.0, 10.0];
        assert_eq!(percentile(&sorted, 0.5), 5.0);
        assert_eq!(percentile(&sorted, 0.0), 0.0);
        assert_eq!(percentile(&sorted, 1.0), 10.0);
    }

    #[test]
    fn singleton() {
        let s = Summary::of(&[7.0]);
        assert_eq!(s.p99, 7.0);
        assert_eq!(s.std, 0.0);
    }

    #[test]
    fn tflops_sanity() {
        // 8192^3 GEMM in 31.3 ms ~ 35.1 TFLOPs (the paper's ballpark)
        let t = tflops(8192, 8192, 8192, 0.0313);
        assert!((t - 35.1).abs() < 0.2, "{t}");
    }

    #[test]
    #[should_panic]
    fn empty_panics() {
        Summary::of(&[]);
    }

    #[test]
    fn reservoir_memory_is_bounded() {
        // Regression for the metrics memory leak: 100k pushes must retain
        // at most `capacity` samples while count/mean stay exact.
        let mut r = Reservoir::new(64, 1);
        for i in 0..100_000u64 {
            r.push(i as f64);
        }
        assert!(r.sample_len() <= 64);
        assert_eq!(r.count(), 100_000);
        let want_mean = (100_000.0 - 1.0) / 2.0;
        assert!((r.mean() - want_mean).abs() < 1e-6, "{}", r.mean());
        let s = r.summary().unwrap();
        assert_eq!(s.n, 100_000);
        assert_eq!(s.min, 0.0);
        assert_eq!(s.max, 99_999.0);
        // the sampled median should land near the true median
        assert!((s.p50 - want_mean).abs() < 15_000.0, "p50 {}", s.p50);
    }

    #[test]
    fn reservoir_below_capacity_is_exact() {
        let mut r = Reservoir::new(16, 2);
        for &x in &[3.0, 1.0, 2.0] {
            r.push(x);
        }
        assert_eq!(r.sample_len(), 3);
        let s = r.summary().unwrap();
        assert_eq!(s.n, 3);
        assert_eq!(s.p50, 2.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        assert!((s.mean - 2.0).abs() < 1e-12);
    }

    #[test]
    fn reservoir_empty_has_no_summary() {
        let r = Reservoir::new(8, 3);
        assert!(r.summary().is_none());
        assert!(r.is_empty());
        assert_eq!(r.mean(), 0.0);
    }
}
