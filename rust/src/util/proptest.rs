//! proptest-lite: a tiny property-based testing harness.
//!
//! The real proptest crate is not in the offline vendor set; this module
//! provides the core loop the Rust test suites need: generate N random
//! cases from a seeded [`Rng`], run the property, and on failure greedily
//! shrink the failing case before reporting.

use super::prng::Rng;

pub struct Config {
    pub cases: usize,
    pub seed: u64,
    pub max_shrink_iters: usize,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            cases: 128,
            seed: 0x5EED,
            max_shrink_iters: 256,
        }
    }
}

/// Run `property` on `cases` values drawn from `gen`.  On failure, shrink
/// with `shrink` (which proposes smaller candidates) and panic with the
/// minimal failing case's debug form.
pub fn check<T, G, S, P>(cfg: Config, mut gen: G, shrink: S, property: P)
where
    T: std::fmt::Debug + Clone,
    G: FnMut(&mut Rng) -> T,
    S: Fn(&T) -> Vec<T>,
    P: Fn(&T) -> Result<(), String>,
{
    let mut rng = Rng::new(cfg.seed);
    for case_idx in 0..cfg.cases {
        let value = gen(&mut rng);
        if let Err(first_msg) = property(&value) {
            // Greedy shrink: repeatedly take the first shrunken candidate
            // that still fails.
            let mut best = value.clone();
            let mut best_msg = first_msg;
            let mut iters = 0;
            'outer: loop {
                if iters >= cfg.max_shrink_iters {
                    break;
                }
                for cand in shrink(&best) {
                    iters += 1;
                    if let Err(msg) = property(&cand) {
                        best = cand;
                        best_msg = msg;
                        continue 'outer;
                    }
                    if iters >= cfg.max_shrink_iters {
                        break 'outer;
                    }
                }
                break;
            }
            panic!(
                "property failed (case {case_idx}, seed {:#x}):\n  value: {best:?}\n  error: {best_msg}",
                cfg.seed
            );
        }
    }
}

/// Shrinker for a usize-vector-like case: halve each element toward a floor.
pub fn shrink_usizes(v: &[usize], floor: usize) -> Vec<Vec<usize>> {
    let mut out = Vec::new();
    for i in 0..v.len() {
        if v[i] > floor {
            let mut c = v.to_vec();
            c[i] = floor.max(v[i] / 2);
            out.push(c);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_valid_property() {
        check(
            Config::default(),
            |r| r.below(100),
            |_| vec![],
            |&x| {
                if x < 100 {
                    Ok(())
                } else {
                    Err("out of range".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn fails_and_reports() {
        check(
            Config { cases: 50, ..Default::default() },
            |r| r.below(100),
            |_| vec![],
            |&x| if x < 5 { Ok(()) } else { Err(format!("{x} >= 5")) },
        );
    }

    #[test]
    fn shrinks_to_minimal() {
        // Property: x < 10.  Starting from any failing x, shrinking should
        // land near the boundary.
        let result = std::panic::catch_unwind(|| {
            check(
                Config { cases: 10, ..Default::default() },
                |r| 100 + r.below(100),
                |&x| if x > 10 { vec![x / 2, x - 1] } else { vec![] },
                |&x| if x < 10 { Ok(()) } else { Err("too big".into()) },
            );
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        // minimal failing case via halving/decrement from >=100 is <= 13
        let val: usize = msg
            .split("value: ")
            .nth(1)
            .unwrap()
            .split_whitespace()
            .next()
            .unwrap()
            .parse()
            .unwrap();
        assert!(val <= 13, "shrunk value {val} (msg: {msg})");
    }

    #[test]
    fn shrink_usizes_halves() {
        let cands = shrink_usizes(&[8, 2], 2);
        assert_eq!(cands, vec![vec![4, 2]]);
    }
}
