//! Tiny CLI argument parser (clap is not in the offline vendor set).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional args,
//! with typed getters and an auto-generated usage string.

use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

#[derive(Debug)]
pub struct CliError(pub String);

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for CliError {}

/// Option spec: (name, takes_value, help).
pub type Spec = (&'static str, bool, &'static str);

impl Args {
    /// Parse argv against a spec; unknown `--options` are errors.
    pub fn parse<I: IntoIterator<Item = String>>(
        argv: I,
        spec: &[Spec],
    ) -> Result<Args, CliError> {
        let mut args = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(body) = arg.strip_prefix("--") {
                let (name, inline_val) = match body.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let entry = spec.iter().find(|(n, _, _)| *n == name).ok_or_else(
                    || CliError(format!("unknown option --{name}")),
                )?;
                if entry.1 {
                    let val = match inline_val {
                        Some(v) => v,
                        None => it
                            .next()
                            .ok_or_else(|| CliError(format!("--{name} needs a value")))?,
                    };
                    args.options.insert(name, val);
                } else {
                    if inline_val.is_some() {
                        return Err(CliError(format!("--{name} takes no value")));
                    }
                    args.flags.push(name);
                }
            } else {
                args.positional.push(arg);
            }
        }
        Ok(args)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize, CliError> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| CliError(format!("--{name}: expected integer, got {v:?}"))),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64, CliError> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| CliError(format!("--{name}: expected number, got {v:?}"))),
        }
    }
}

pub fn usage(prog: &str, summary: &str, spec: &[Spec]) -> String {
    let mut out = format!("{prog} — {summary}\n\noptions:\n");
    for (name, takes, help) in spec {
        let lhs = if *takes {
            format!("--{name} <v>")
        } else {
            format!("--{name}")
        };
        out.push_str(&format!("  {lhs:<24} {help}\n"));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    const SPEC: &[Spec] = &[
        ("size", true, "problem size"),
        ("quick", false, "fast mode"),
        ("out", true, "output path"),
    ];

    fn parse(argv: &[&str]) -> Result<Args, CliError> {
        Args::parse(argv.iter().map(|s| s.to_string()), SPEC)
    }

    #[test]
    fn parses_mixed_forms() {
        let a = parse(&["bench", "--size", "512", "--quick", "--out=x.csv"]).unwrap();
        assert_eq!(a.positional, vec!["bench"]);
        assert_eq!(a.get("size"), Some("512"));
        assert!(a.flag("quick"));
        assert_eq!(a.get("out"), Some("x.csv"));
    }

    #[test]
    fn typed_getters() {
        let a = parse(&["--size", "512"]).unwrap();
        assert_eq!(a.get_usize("size", 0).unwrap(), 512);
        assert_eq!(a.get_usize("missing", 7).unwrap(), 7);
        assert!(parse(&["--size", "abc"]).unwrap().get_usize("size", 0).is_err());
    }

    #[test]
    fn rejects_unknown_and_malformed() {
        assert!(parse(&["--bogus"]).is_err());
        assert!(parse(&["--size"]).is_err());
        assert!(parse(&["--quick=1"]).is_err());
    }

    #[test]
    fn usage_lists_options() {
        let u = usage("mlir-gemm", "x", SPEC);
        assert!(u.contains("--size"));
        assert!(u.contains("fast mode"));
    }
}
