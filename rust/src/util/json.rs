//! Minimal JSON parser + writer.
//!
//! serde is not available in the offline vendor set, so the manifest and
//! report formats go through this small, fully-tested implementation.  It
//! supports the complete JSON grammar except exotic number forms
//! (hex/inf/nan), which none of our producers emit.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // -- accessors ---------------------------------------------------------
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|f| if f >= 0.0 { Some(f as usize) } else { None })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// `obj.get(key)` as usize triple, e.g. tile sizes.
    pub fn get_usize3(&self, key: &str) -> Option<(usize, usize, usize)> {
        let arr = self.get(key)?.as_arr()?;
        if arr.len() != 3 {
            return None;
        }
        Some((arr[0].as_usize()?, arr[1].as_usize()?, arr[2].as_usize()?))
    }

    pub fn get_usize2(&self, key: &str) -> Option<(usize, usize)> {
        let arr = self.get(key)?.as_arr()?;
        if arr.len() != 2 {
            return None;
        }
        Some((arr[0].as_usize()?, arr[1].as_usize()?))
    }

    // -- writer (via `Display`; `to_string()` comes from the blanket
    // `ToString` impl) -----------------------------------------------------
    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{}", n);
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl std::fmt::Display for Json {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut out = String::new();
        self.write(&mut out);
        f.write_str(&out)
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience constructors for report writers.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

// ---------------------------------------------------------------------------
// Parser
// ---------------------------------------------------------------------------

pub fn parse(input: &str) -> Result<Json, JsonError> {
    let mut p = Parser {
        bytes: input.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            msg: msg.to_string(),
            offset: self.pos,
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("truncated \\u escape"));
                            }
                            let hex = std::str::from_utf8(
                                &self.bytes[self.pos + 1..self.pos + 5],
                            )
                            .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // Surrogate pairs: rare in our data; map lone
                            // surrogates to the replacement character.
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let s = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("-12.5e2").unwrap(), Json::Num(-1250.0));
        assert_eq!(parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": false}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[2].get("b").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(parse("{} x").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\":}").is_err());
    }

    #[test]
    fn roundtrips() {
        let src = r#"{"arr":[1,2.5,null],"name":"m\"x","on":true}"#;
        let v = parse(src).unwrap();
        let v2 = parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn usize_helpers() {
        let v = parse(r#"{"t":[1,2,3],"g":[4,5]}"#).unwrap();
        assert_eq!(v.get_usize3("t"), Some((1, 2, 3)));
        assert_eq!(v.get_usize2("g"), Some((4, 5)));
        assert_eq!(v.get_usize3("g"), None);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(parse("\"\\u0041\"").unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn writer_escapes_round_trip() {
        // Control characters, quotes, backslashes, and non-ASCII all
        // survive a write -> parse cycle.
        let v = obj(vec![
            ("ctrl", s("a\u{1}b\tc\nd\re")),
            ("quote", s("say \"hi\" \\ done")),
            ("uni", s("π ≈ 3.14159")),
        ]);
        let text = v.to_string();
        assert_eq!(parse(&text).unwrap(), v);
        // control chars are emitted as escapes, not raw bytes
        assert!(text.contains("\\u0001"));
        assert!(text.contains("\\n"));
    }

    #[test]
    fn deep_nesting_round_trips() {
        let mut v = Json::Num(1.0);
        for _ in 0..64 {
            v = Json::Arr(vec![v, Json::Bool(true)]);
        }
        let text = v.to_string();
        assert_eq!(parse(&text).unwrap(), v);
    }

    #[test]
    fn number_forms_round_trip() {
        for src in [
            "0", "-0", "123456789012", "-1", "0.5", "-2.25", "1e3", "1E3",
            "2.5e-3", "-7.25e+2",
        ] {
            let v = parse(src).unwrap();
            let back = parse(&v.to_string()).unwrap();
            assert_eq!(v, back, "{src}");
        }
        // integers below 2^53 print without an exponent or fraction
        assert_eq!(parse("123456789012").unwrap().to_string(), "123456789012");
    }

    #[test]
    fn accessors_are_typed() {
        let v = parse(r#"{"n": 1, "s": "x", "b": true, "a": [], "o": {}}"#).unwrap();
        assert_eq!(v.get("n").unwrap().as_f64(), Some(1.0));
        assert_eq!(v.get("n").unwrap().as_str(), None);
        assert_eq!(v.get("s").unwrap().as_f64(), None);
        assert_eq!(v.get("b").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("a").unwrap().as_arr().map(|a| a.len()), Some(0));
        assert!(v.get("o").unwrap().as_obj().unwrap().is_empty());
        assert!(v.get("missing").is_none());
        // negative numbers refuse to become usize
        assert_eq!(parse("-3").unwrap().as_usize(), None);
        assert_eq!(parse("-3").unwrap().as_i64(), Some(-3));
    }

    #[test]
    fn rejects_malformed_documents() {
        for src in [
            "", "{", "[", "\"unterminated", "{\"a\" 1}", "[1 2]", "tru",
            "nul", "+1", "01x", "{\"a\":1,}", "\"bad \\q escape\"",
        ] {
            assert!(parse(src).is_err(), "{src:?} should fail");
        }
    }

    #[test]
    fn whitespace_tolerant() {
        let v = parse(" {\n\t\"a\" : [ 1 , 2 ] ,\r\n \"b\" : null } ").unwrap();
        assert_eq!(v.get_usize2("a"), Some((1, 2)));
        assert_eq!(v.get("b"), Some(&Json::Null));
    }
}
