//! Open-loop load generator for the serving tier.
//!
//! Drives a running [`Server`] with the traffic shape the serving bench
//! and the `mlir-gemm loadgen` CLI both use: many client threads, each
//! submitting on its own deterministic open-loop arrival clock (the
//! clock never waits for responses, so queueing delay shows up as
//! latency instead of silently throttling the offered load), with
//!
//! * **zipfian key popularity** — a few hot GEMM variants take most of
//!   the traffic, the tail stays warm enough to defeat a single-variant
//!   fast path;
//! * **bursty arrivals** — exponential inter-arrival gaps, with a
//!   configurable probability that an arrival opens a back-to-back
//!   burst (the fixed-window dispatcher's worst case: a lone request
//!   after a burst used to eat the whole batching window);
//! * **mixed request kinds** — weight-bound GEMMs, inline-B GEMMs, and
//!   composite-program requests interleaved on the same queue, across
//!   tenants and priority tiers.
//!
//! Everything is seeded: the same [`LoadgenConfig`] replays the same
//! arrival schedule, key sequence, and kind mix bit-for-bit (timing of
//! the *responses* of course varies with the machine).  Latency is the
//! server-observed `total_latency` (submit to reply), so draining the
//! response channels after the arrival schedule finishes does not
//! inflate the percentiles.

use std::sync::mpsc::Receiver;
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::coordinator::{
    GemmKey, GemmRequest, GemmResponse, Priority, ProgramRequest, Server,
    SubmitOpts, ERR_DEADLINE, ERR_QUEUE_FULL,
};
use crate::runtime::Tensor;
use crate::util::prng::Rng;
use crate::util::stats::percentile;

/// A composite-program leg of the traffic mix: the artifact to submit
/// and one precomputed input list (cloned per request).
#[derive(Debug, Clone)]
pub struct ProgramSpec {
    pub artifact: String,
    pub inputs: Vec<Tensor>,
}

#[derive(Debug, Clone)]
pub struct LoadgenConfig {
    /// Concurrent client threads.
    pub clients: usize,
    /// Requests per client (total offered = clients * per_client).
    pub per_client: usize,
    /// Mean exponential inter-arrival gap per client.  The offered rate
    /// is `clients / mean_gap`, independent of server latency.
    pub mean_gap: Duration,
    /// Probability that an arrival opens a burst of `burst_len`
    /// back-to-back (zero-gap) arrivals.
    pub burst_prob: f64,
    pub burst_len: usize,
    /// Zipf exponent over the key set (0 = uniform; ~1 = classic zipf).
    pub zipf_s: f64,
    /// Fraction of GEMM requests submitted weight-bound (`b: None`);
    /// the caller must have bound weights for every key first.
    pub bound_fraction: f64,
    /// Fraction of *all* requests submitted as composite programs
    /// (requires `program`); the rest are GEMMs.
    pub program_fraction: f64,
    pub program: Option<ProgramSpec>,
    /// Tenants to bill requests against, uniformly; empty = untenanted.
    pub tenants: Vec<String>,
    /// Priority tiers to draw from, uniformly; empty = all Normal.
    pub priorities: Vec<Priority>,
    /// Per-request latency budget; None = no deadline.
    pub deadline: Option<Duration>,
    pub seed: u64,
}

impl Default for LoadgenConfig {
    fn default() -> Self {
        LoadgenConfig {
            clients: 8,
            per_client: 64,
            mean_gap: Duration::from_micros(500),
            burst_prob: 0.1,
            burst_len: 4,
            zipf_s: 1.0,
            bound_fraction: 0.5,
            program_fraction: 0.0,
            program: None,
            tenants: Vec::new(),
            priorities: Vec::new(),
            deadline: None,
            seed: 0x10AD,
        }
    }
}

/// Outcome of one load run, aggregated over every client.
#[derive(Debug, Clone)]
pub struct LoadReport {
    pub submitted: usize,
    pub completed: usize,
    /// `ERR_QUEUE_FULL` responses (global capacity or tenant quota).
    pub rejected: usize,
    /// `ERR_DEADLINE` responses (admission-refused or expired in queue).
    pub deadline_failed: usize,
    pub other_failed: usize,
    /// Wall-clock from first submit to last response drained.
    pub wall: Duration,
    /// Completed requests per second of wall time.
    pub throughput_rps: f64,
    /// Server-observed submit-to-reply latency percentiles over
    /// *completed* requests, milliseconds.
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub max_ms: f64,
    /// Highest queue depth any response reported at its admission — the
    /// backpressure signal's observed peak.
    pub max_queue_depth: usize,
}

impl LoadReport {
    pub fn render(&self) -> String {
        format!(
            "{} submitted: {} completed, {} rejected, {} deadline-failed, \
             {} other-failed\n\
             throughput {:.0} req/s over {:.3} s wall\n\
             latency ms: p50 {:.3}  p95 {:.3}  p99 {:.3}  max {:.3}\n\
             peak queue depth {}",
            self.submitted,
            self.completed,
            self.rejected,
            self.deadline_failed,
            self.other_failed,
            self.throughput_rps,
            self.wall.as_secs_f64(),
            self.p50_ms,
            self.p95_ms,
            self.p99_ms,
            self.max_ms,
            self.max_queue_depth,
        )
    }
}

/// Cumulative zipf distribution over `n` ranks with exponent `s`:
/// `cdf[i]` is P(rank <= i); the last entry is exactly 1.0.
pub fn zipf_cdf(n: usize, s: f64) -> Vec<f64> {
    assert!(n > 0, "zipf over an empty key set");
    let weights: Vec<f64> =
        (1..=n).map(|rank| 1.0 / (rank as f64).powf(s)).collect();
    let total: f64 = weights.iter().sum();
    let mut acc = 0.0;
    let mut cdf: Vec<f64> = weights
        .iter()
        .map(|w| {
            acc += w / total;
            acc
        })
        .collect();
    cdf[n - 1] = 1.0;
    cdf
}

/// Rank sampled from a zipf CDF by a uniform draw in [0, 1).
pub fn zipf_sample(cdf: &[f64], u: f64) -> usize {
    cdf.partition_point(|&c| c <= u).min(cdf.len() - 1)
}

/// One client's deterministic arrival schedule: exponential gaps with
/// zero-gap bursts, as offsets from the client's start.  Exposed (and
/// unit-tested) separately from the threaded driver so the open-loop
/// shape itself is checkable without a server.
pub fn arrival_offsets(cfg: &LoadgenConfig, rng: &mut Rng) -> Vec<Duration> {
    let mut offsets = Vec::with_capacity(cfg.per_client);
    let mut t = Duration::ZERO;
    let mut burst_left = 0usize;
    for _ in 0..cfg.per_client {
        if burst_left > 0 {
            burst_left -= 1;
        } else {
            // Exponential gap via inverse CDF; clamp the log argument
            // away from 0 so the gap stays finite.
            let u = rng.next_f64().max(1e-12);
            let gap = cfg.mean_gap.as_secs_f64() * -(u.ln());
            t += Duration::from_secs_f64(gap);
            if rng.next_f64() < cfg.burst_prob {
                burst_left = cfg.burst_len.saturating_sub(1);
            }
        }
        offsets.push(t);
    }
    offsets
}

fn classify(resp: &GemmResponse, report: &mut LoadReport) {
    match &resp.output {
        Ok(_) => report.completed += 1,
        Err(e) => {
            let msg = e.to_string();
            if msg.starts_with(ERR_QUEUE_FULL) {
                report.rejected += 1;
            } else if msg.starts_with(ERR_DEADLINE) {
                report.deadline_failed += 1;
            } else {
                report.other_failed += 1;
            }
        }
    }
}

/// Drive `cfg` against `server` over `keys` and aggregate the outcome.
///
/// The server is taken behind a `Mutex` (the repo's submission idiom —
/// only the brief `submit` call itself is under the lock; dispatch and
/// execution run free of it).  Weight-bound traffic requires the caller
/// to have bound B weights for every key in `keys`.
pub fn run_load(
    server: &Mutex<Server>,
    cfg: &LoadgenConfig,
    keys: &[GemmKey],
) -> LoadReport {
    assert!(!keys.is_empty(), "loadgen needs at least one GEMM key");
    assert!(
        cfg.program_fraction == 0.0 || cfg.program.is_some(),
        "program_fraction > 0 requires a ProgramSpec"
    );
    let cdf = zipf_cdf(keys.len(), cfg.zipf_s);

    // Precompute one operand set per key; clients clone per request.
    // Contents are irrelevant to the serving-path measurement, shapes
    // are not.
    let mut trng = Rng::new(cfg.seed ^ 0x7E45);
    let operands: Vec<(Tensor, Tensor, Tensor)> = keys
        .iter()
        .map(|k| {
            let a = Tensor::new(vec![k.m, k.k], trng.normal_matrix(k.m, k.k))
                .expect("operand A");
            let b = Tensor::new(vec![k.k, k.n], trng.normal_matrix(k.k, k.n))
                .expect("operand B");
            let c = Tensor::new(vec![k.m, k.n], vec![0.0; k.m * k.n])
                .expect("operand C");
            (a, b, c)
        })
        .collect();

    let started = Instant::now();
    let mut seeder = Rng::new(cfg.seed);
    let client_rngs: Vec<Rng> = (0..cfg.clients).map(|_| seeder.fork()).collect();

    let results: Vec<(Vec<Receiver<GemmResponse>>, usize)> =
        std::thread::scope(|scope| {
            let handles: Vec<_> = client_rngs
                .into_iter()
                .map(|mut rng| {
                    let operands = &operands;
                    let cdf = &cdf;
                    scope.spawn(move || {
                        let offsets = arrival_offsets(cfg, &mut rng);
                        let begin = Instant::now();
                        let mut rxs = Vec::with_capacity(offsets.len());
                        let mut submitted = 0usize;
                        for off in offsets {
                            let due = begin + off;
                            let now = Instant::now();
                            if due > now {
                                std::thread::sleep(due - now);
                            }
                            let opts = SubmitOpts {
                                tenant: (!cfg.tenants.is_empty())
                                    .then(|| rng.choice(&cfg.tenants).clone()),
                                priority: if cfg.priorities.is_empty() {
                                    Priority::Normal
                                } else {
                                    *rng.choice(&cfg.priorities)
                                },
                            };
                            let rx = if rng.next_f64() < cfg.program_fraction {
                                let spec = cfg.program.as_ref().unwrap();
                                let req = ProgramRequest {
                                    artifact: spec.artifact.clone(),
                                    inputs: spec.inputs.clone(),
                                };
                                server
                                    .lock()
                                    .unwrap()
                                    .submit_program_with(req, opts)
                            } else {
                                let idx = zipf_sample(cdf, rng.next_f64());
                                let (a, b, c) = &operands[idx];
                                let bound = rng.next_f64() < cfg.bound_fraction;
                                let req = GemmRequest {
                                    key: keys[idx].clone(),
                                    a: a.clone(),
                                    b: (!bound).then(|| b.clone()),
                                    c: c.clone(),
                                    bias: None,
                                    use_baseline: false,
                                    deadline: cfg
                                        .deadline
                                        .map(|d| Instant::now() + d),
                                };
                                server.lock().unwrap().submit_with(req, opts)
                            };
                            submitted += 1;
                            rxs.push(rx);
                        }
                        (rxs, submitted)
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("loadgen client panicked"))
                .collect()
        });

    let mut report = LoadReport {
        submitted: 0,
        completed: 0,
        rejected: 0,
        deadline_failed: 0,
        other_failed: 0,
        wall: Duration::ZERO,
        throughput_rps: 0.0,
        p50_ms: 0.0,
        p95_ms: 0.0,
        p99_ms: 0.0,
        max_ms: 0.0,
        max_queue_depth: 0,
    };
    let mut latencies_ms: Vec<f64> = Vec::new();
    for (rxs, submitted) in results {
        report.submitted += submitted;
        for rx in rxs {
            let resp = rx
                .recv_timeout(Duration::from_secs(120))
                .expect("response channel died — the server lost a request");
            report.max_queue_depth = report.max_queue_depth.max(resp.queue_depth);
            if resp.output.is_ok() {
                latencies_ms.push(resp.total_latency.as_secs_f64() * 1e3);
            }
            classify(&resp, &mut report);
        }
    }
    report.wall = started.elapsed();
    report.throughput_rps =
        report.completed as f64 / report.wall.as_secs_f64().max(1e-9);
    if !latencies_ms.is_empty() {
        latencies_ms.sort_by(|x, y| x.partial_cmp(y).unwrap());
        report.p50_ms = percentile(&latencies_ms, 0.50);
        report.p95_ms = percentile(&latencies_ms, 0.95);
        report.p99_ms = percentile(&latencies_ms, 0.99);
        report.max_ms = *latencies_ms.last().unwrap();
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zipf_cdf_is_monotone_and_ends_at_one() {
        let cdf = zipf_cdf(16, 1.0);
        assert_eq!(cdf.len(), 16);
        for w in cdf.windows(2) {
            assert!(w[0] <= w[1], "cdf must be monotone: {cdf:?}");
        }
        assert_eq!(*cdf.last().unwrap(), 1.0);
    }

    #[test]
    fn zipf_skews_mass_to_the_head() {
        let cdf = zipf_cdf(64, 1.0);
        // With s = 1 over 64 ranks the top-4 keys carry ~44% of mass.
        assert!(cdf[3] > 0.4, "head mass {}", cdf[3]);
        let mut rng = Rng::new(9);
        let mut head = 0usize;
        let n = 10_000;
        for _ in 0..n {
            if zipf_sample(&cdf, rng.next_f64()) < 4 {
                head += 1;
            }
        }
        let frac = head as f64 / n as f64;
        assert!(
            (frac - cdf[3]).abs() < 0.05,
            "sampled head fraction {frac} vs cdf {}",
            cdf[3]
        );
    }

    #[test]
    fn zipf_zero_exponent_is_uniform() {
        let cdf = zipf_cdf(10, 0.0);
        for (i, c) in cdf.iter().enumerate() {
            assert!((c - (i + 1) as f64 / 10.0).abs() < 1e-12);
        }
    }

    #[test]
    fn zipf_sample_covers_every_rank_and_stays_in_range() {
        let cdf = zipf_cdf(5, 0.5);
        let mut rng = Rng::new(11);
        let mut seen = [false; 5];
        for _ in 0..5_000 {
            seen[zipf_sample(&cdf, rng.next_f64())] = true;
        }
        assert!(seen.iter().all(|&s| s), "unvisited ranks: {seen:?}");
        // The boundary draw u -> 1.0 must clamp into range.
        assert_eq!(zipf_sample(&cdf, 1.0 - 1e-15), 4);
    }

    #[test]
    fn arrival_schedule_is_deterministic_and_monotone() {
        let cfg = LoadgenConfig {
            per_client: 200,
            burst_prob: 0.3,
            burst_len: 5,
            ..Default::default()
        };
        let a = arrival_offsets(&cfg, &mut Rng::new(42));
        let b = arrival_offsets(&cfg, &mut Rng::new(42));
        assert_eq!(a, b, "same seed must replay the same schedule");
        assert_eq!(a.len(), 200);
        for w in a.windows(2) {
            assert!(w[0] <= w[1], "arrival times must be non-decreasing");
        }
    }

    #[test]
    fn bursts_produce_zero_gap_arrivals() {
        let cfg = LoadgenConfig {
            per_client: 400,
            burst_prob: 0.5,
            burst_len: 4,
            ..Default::default()
        };
        let offs = arrival_offsets(&cfg, &mut Rng::new(7));
        let zero_gaps =
            offs.windows(2).filter(|w| w[0] == w[1]).count();
        assert!(
            zero_gaps > 50,
            "expected many back-to-back arrivals, got {zero_gaps}"
        );
    }

    #[test]
    fn mean_gap_matches_the_configured_rate() {
        let cfg = LoadgenConfig {
            per_client: 5_000,
            mean_gap: Duration::from_micros(500),
            burst_prob: 0.0,
            ..Default::default()
        };
        let offs = arrival_offsets(&cfg, &mut Rng::new(3));
        let mean =
            offs.last().unwrap().as_secs_f64() / (offs.len() as f64 - 1.0);
        assert!(
            (mean - 500e-6).abs() < 50e-6,
            "empirical mean gap {mean}s vs configured 500us"
        );
    }
}
