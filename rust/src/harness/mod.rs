//! Benchmark harness: measurement protocol, CSV/ASCII reporting, and the
//! per-figure builders that regenerate the paper's evaluation artifacts.

pub mod bench;
pub mod csv;
pub mod figures;
pub mod loadgen;
pub mod plot;

pub use bench::{bench_artifact, measure, random_inputs, ArtifactBench, BenchConfig};
pub use csv::{pretty, CsvTable};
pub use loadgen::{
    arrival_offsets, run_load, zipf_cdf, zipf_sample, LoadReport, LoadgenConfig,
    ProgramSpec,
};
pub use figures::{
    ablation_schedule, figure2, figure2_sized, figure3, figure3_measured, figure4,
    figure4_sized, figure_sweep, figure_sweep_measured, paper_sizes, table1,
    FigureOutput, ABLATION_LABELS,
};
pub use plot::{bar_chart, line_chart};
