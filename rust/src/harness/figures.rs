//! Figure/table builders: one function per paper artifact.
//!
//! Each builder produces (a) the simulated sweep over the paper's full
//! problem range on the modeled RTX 3090 and (b), when a runtime with
//! built artifacts is supplied, the real-execution subset measured
//! through the in-process artifact executor on this machine.  Output:
//! CSV table + ASCII chart + the headline comparisons the paper's text
//! calls out.

use anyhow::Result;

use crate::autotune;
use crate::runtime::{ArtifactKind, Runtime};
use crate::schedule::{Dtype, Schedule};
use crate::sim::{simulate, simulate_library, DeviceModel};
use crate::util::stats::tflops;

use super::bench::{bench_artifact, random_inputs, BenchConfig};
use super::csv::{pretty, CsvTable};
use super::plot::{bar_chart, line_chart};

/// The paper's evaluation sweep: square sizes 1024..=16384 step 256.
pub fn paper_sizes() -> Vec<usize> {
    (1024..=16384).step_by(256).collect()
}

pub struct FigureOutput {
    pub name: &'static str,
    pub table: CsvTable,
    pub chart: String,
    pub summary: String,
}

impl FigureOutput {
    pub fn render(&self) -> String {
        format!(
            "=== {} ===\n{}\n{}\n{}",
            self.name,
            self.chart,
            pretty(&self.table),
            self.summary
        )
    }
}

// ---------------------------------------------------------------------------
// Figure 2 / Figure 4: size sweep vs library
// ---------------------------------------------------------------------------

pub fn figure_sweep(
    device: &DeviceModel,
    acc: Dtype,
    sizes: &[usize],
    name: &'static str,
) -> FigureOutput {
    let mut table = CsvTable::new(&[
        "size", "ours_tflops", "library_tflops", "ratio", "ours_tile", "lib_tile",
    ]);
    let mut xs = Vec::new();
    let mut ours_series = Vec::new();
    let mut lib_series = Vec::new();
    let mut ratios = Vec::new();

    for &size in sizes {
        let Some(best) = autotune::best(size, size, size, acc, device) else {
            continue;
        };
        let lib = simulate_library(size, size, size, acc, device);
        let ratio = best.result.tflops / lib.tflops;
        xs.push(size as f64);
        ours_series.push(best.result.tflops);
        lib_series.push(lib.tflops);
        ratios.push(ratio);
        let tb = best.schedule.tile_tb;
        let lib_tb = crate::sim::library_tile_choice(size, size, size, acc).0;
        table.row(vec![
            size.to_string(),
            format!("{:.2}", best.result.tflops),
            format!("{:.2}", lib.tflops),
            format!("{:.3}", ratio),
            format!("{}x{}x{}", tb.0, tb.1, tb.2),
            format!("{}x{}x{}", lib_tb.0, lib_tb.1, lib_tb.2),
        ]);
    }

    let chart = line_chart(
        &format!("{name}: TFLOPs vs problem size ({})", acc.name()),
        &xs,
        &[("ours (generated)", &ours_series), ("library (cuBLAS model)", &lib_series)],
        72,
        18,
    );
    let rmin = ratios.iter().cloned().fold(f64::MAX, f64::min);
    let rmax = ratios.iter().cloned().fold(f64::MIN, f64::max);
    let peak = device.peak_tc_flops(acc) / 1e12;
    let best_frac = ours_series.iter().cloned().fold(f64::MIN, f64::max) / peak;
    let paper_band = match acc {
        Dtype::F32 => "paper: 95-119% of cuBLAS, 95.4% of device peak",
        _ => "paper: 80-160% of cuBLAS",
    };
    let summary = format!(
        "ratio ours/library: min {:.2} max {:.2}  |  peak fraction (best size): {:.1}%\n{}\n",
        rmin,
        rmax,
        best_frac * 100.0,
        paper_band
    );
    FigureOutput { name, table, chart, summary }
}

pub fn figure2(device: &DeviceModel) -> FigureOutput {
    figure2_sized(device, &paper_sizes())
}

/// Figure 2 over a caller-chosen size list (the bench smoke mode); the
/// figure name and dtype live here only.
pub fn figure2_sized(device: &DeviceModel, sizes: &[usize]) -> FigureOutput {
    figure_sweep(device, Dtype::F32, sizes, "figure2_mixed_precision")
}

pub fn figure4(device: &DeviceModel) -> FigureOutput {
    figure4_sized(device, &paper_sizes())
}

/// Figure 4 over a caller-chosen size list (the bench smoke mode).
pub fn figure4_sized(device: &DeviceModel, sizes: &[usize]) -> FigureOutput {
    figure_sweep(device, Dtype::F16, sizes, "figure4_half_precision")
}

/// Real-execution subset: measured wallclock of generated artifacts vs the
/// XLA-native library baseline, through the identical runtime.
pub fn figure_sweep_measured(
    runtime: &Runtime,
    acc: Dtype,
    cfg: BenchConfig,
    name: &'static str,
) -> Result<FigureOutput> {
    let mut table = CsvTable::new(&[
        "size", "variant", "ours_ms", "ours_tflops", "lib_ms", "lib_tflops", "ratio",
    ]);
    let mut summary = String::new();

    // Collect (size -> best generated artifact name) among built artifacts.
    let mut sizes: Vec<(usize, String, String)> = Vec::new();
    for meta in runtime.artifacts() {
        if meta.kind != ArtifactKind::Generated {
            continue;
        }
        let Some(s) = &meta.schedule else { continue };
        if s.dtype_acc != acc || s.m != s.n || s.n != s.k {
            continue;
        }
        let base_name = format!(
            "baseline_m{}n{}k{}_f16_{}",
            s.m, s.n, s.k, acc.name()
        );
        if runtime.find(&base_name).is_none() {
            continue;
        }
        sizes.push((s.m, meta.name.clone(), base_name));
    }
    sizes.sort();
    sizes.dedup_by_key(|(m, _, _)| *m); // first (manifest order) variant per size

    for (size, ours_name, base_name) in &sizes {
        let ours = runtime.load(ours_name)?;
        let base = runtime.load(base_name)?;
        let inputs = random_inputs(&ours, 42, 0.5);
        let ours_bench = bench_artifact(runtime, &ours, &inputs, cfg)?;
        let base_bench = bench_artifact(runtime, &base, &inputs, cfg)?;
        let ours_tf = tflops(*size, *size, *size, ours_bench.exec.mean);
        let base_tf = tflops(*size, *size, *size, base_bench.exec.mean);
        table.row(vec![
            size.to_string(),
            ours_name.clone(),
            format!("{:.3}", ours_bench.exec.mean * 1e3),
            format!("{:.3}", ours_tf),
            format!("{:.3}", base_bench.exec.mean * 1e3),
            format!("{:.3}", base_tf),
            format!("{:.3}", ours_tf / base_tf),
        ]);
    }
    summary.push_str(
        "measured through the in-process executor: generated variant vs the\n\
         library baseline artifact.  Absolute numbers are host wallclock;\n\
         who-wins shape is NOT expected to transfer to the modeled GPU —\n\
         the paper-shape comparison lives in the simulated sweep.\n",
    );
    Ok(FigureOutput {
        name,
        table,
        chart: String::new(),
        summary,
    })
}

// ---------------------------------------------------------------------------
// Figure 3: ablation
// ---------------------------------------------------------------------------

pub const ABLATION_LABELS: [&str; 8] = [
    "naive",
    "+two-level tiling",
    "+shared memory",
    "+wmma (tensor cores)",
    "+permute/unroll/hoist",
    "+latency hiding",
    "+smem padding",
    "+vectorized copies",
];

/// Cumulative-level schedule for the paper's fig3 config.
pub fn ablation_schedule(level: u8, m: usize) -> Schedule {
    let mut s = Schedule::optimized(
        m,
        m,
        m,
        Dtype::F32,
        (128, 128, 64),
        (64, 32, 32),
    )
    .expect("ablation size must divide the paper tile");
    s.opt_level = level;
    s.tiling = level >= 1;
    s.shared_mem = level >= 2;
    s.wmma = level >= 3;
    s.unroll_hoist = level >= 4;
    s.latency_hiding = level >= 5;
    s.padding = level >= 6;
    s.vectorize = level >= 7;
    if !s.latency_hiding {
        s.pipeline_stages = 1;
    }
    if !s.padding {
        s.pad_factor = 0;
        s.smem_bytes = (128 * 64 + 64 * 128) * 2;
    }
    if !s.vectorize {
        s.vec_width = 1;
    }
    s.name = format!("ablation_l{level}_m{m}");
    s
}

pub fn figure3(device: &DeviceModel) -> FigureOutput {
    let m = 8192;
    let mut table = CsvTable::new(&["level", "optimizations", "tflops", "bound"]);
    let mut bars = Vec::new();
    let mut values = Vec::new();
    for level in 0..8u8 {
        let s = ablation_schedule(level, m);
        let r = simulate(&s, device);
        values.push(r.tflops);
        table.row(vec![
            level.to_string(),
            ABLATION_LABELS[level as usize].to_string(),
            format!("{:.2}", r.tflops),
            r.bound.to_string(),
        ]);
        bars.push((ABLATION_LABELS[level as usize], r.tflops));
    }
    let chart = bar_chart(
        "figure3: M=N=K=8192 mixed precision, optimizations enabled incrementally",
        &bars,
        50,
    );
    let lib = simulate_library(m, m, m, Dtype::F32, device);
    let summary = format!(
        "full pipeline: {:.2} TFLOPs vs library {:.2} ({:.0}% of device peak)\n\
         largest increments expected from tiling and wmma; padding and\n\
         vectorization close the last gap (paper Figure 3 shape).\n",
        values[7],
        lib.tflops,
        100.0 * values[7] / (device.peak_tc_flops(Dtype::F32) / 1e12)
    );
    FigureOutput {
        name: "figure3_ablation",
        table,
        chart,
        summary,
    }
}

/// Real-execution ablation over the built `kind=ablation` artifacts.
pub fn figure3_measured(runtime: &Runtime, cfg: BenchConfig) -> Result<FigureOutput> {
    let mut entries: Vec<(u8, String, usize)> = runtime
        .artifacts()
        .iter()
        .filter(|a| a.kind == ArtifactKind::Ablation)
        .filter_map(|a| {
            let s = a.schedule.as_ref()?;
            Some((s.opt_level, a.name.clone(), s.m))
        })
        .collect();
    entries.sort();

    let mut table = CsvTable::new(&["level", "optimizations", "ms", "cpu_gflops"]);
    let mut bars: Vec<(String, f64)> = Vec::new();
    for (level, name, m) in &entries {
        let a = runtime.load(name)?;
        let inputs = random_inputs(&a, 7, 0.5);
        let b = bench_artifact(runtime, &a, &inputs, cfg)?;
        let gflops = 2.0 * (*m as f64).powi(3) / b.exec.mean / 1e9;
        table.row(vec![
            level.to_string(),
            ABLATION_LABELS[*level as usize].to_string(),
            format!("{:.3}", b.exec.mean * 1e3),
            format!("{:.2}", gflops),
        ]);
        bars.push((ABLATION_LABELS[*level as usize].to_string(), gflops));
    }
    let bar_refs: Vec<(&str, f64)> = bars.iter().map(|(s, v)| (s.as_str(), *v)).collect();
    let chart = bar_chart(
        "figure3 (measured, in-process executor): ablation artifacts wallclock",
        &bar_refs,
        50,
    );
    Ok(FigureOutput {
        name: "figure3_measured",
        table,
        chart,
        summary: "all ablation levels share the same host semantics, so measured\n\
                  wallclock is flat by construction; the optimization ladder's\n\
                  performance shape lives in the simulator (figure3).\n"
            .into(),
    })
}

// ---------------------------------------------------------------------------
// Table 1: programming-approach comparison + operator fusion
// ---------------------------------------------------------------------------

pub fn table1(runtime: &Runtime, device: &DeviceModel, cfg: BenchConfig) -> Result<FigureOutput> {
    let mut table = CsvTable::new(&[
        "approach", "artifact", "ms", "cpu_gflops", "sim_tflops", "fusion",
    ]);

    // Find the three comparators at matching size.
    let hand = runtime
        .artifacts()
        .iter()
        .find(|a| a.kind == ArtifactKind::Hand)
        .cloned();
    let Some(hand) = hand else {
        anyhow::bail!("no hand-optimized artifact in manifest (rebuild artifacts)");
    };
    let (m, n, k) = hand.problem.unwrap();

    let generated = runtime
        .artifacts()
        .iter()
        .find(|a| {
            a.kind == ArtifactKind::Generated
                && a.problem == Some((m, n, k))
                && a.dtype_acc == Some(Dtype::F32)
        })
        .cloned()
        .ok_or_else(|| anyhow::anyhow!("no generated artifact at {m}x{n}x{k}"))?;
    let baseline = runtime
        .artifacts()
        .iter()
        .find(|a| a.kind == ArtifactKind::Baseline && a.problem == Some((m, n, k)))
        .cloned()
        .ok_or_else(|| anyhow::anyhow!("no baseline artifact at {m}x{n}x{k}"))?;

    let sim_ours = autotune::best(m, n, k, Dtype::F32, device)
        .map(|c| c.result.tflops)
        .unwrap_or(0.0);
    let sim_lib = simulate_library(m, n, k, Dtype::F32, device).tflops;

    for (approach, meta, sim_tf, fusion) in [
        ("library (XLA dot / cuBLAS row)", &baseline, sim_lib, "limited"),
        ("generated (WMMA API row)", &generated, sim_ours, "good"),
        ("hand-written (assembly row)", &hand, sim_ours / crate::sim::GENERATED_COMPUTE_EFF * crate::sim::LIBRARY_COMPUTE_EFF, "good"),
    ] {
        let a = runtime.load(&meta.name)?;
        let inputs = random_inputs(&a, 11, 0.5);
        let b = bench_artifact(runtime, &a, &inputs, cfg)?;
        let gflops = 2.0 * (m * n * k) as f64 / b.exec.mean / 1e9;
        table.row(vec![
            approach.to_string(),
            meta.name.clone(),
            format!("{:.3}", b.exec.mean * 1e3),
            format!("{:.2}", gflops),
            format!("{:.2}", sim_tf),
            fusion.to_string(),
        ]);
    }

    // Fusion comparison: fused bias+relu kernel vs dot + separate epilogue.
    let fused = runtime
        .artifacts()
        .iter()
        .find(|a| a.kind == ArtifactKind::Fused)
        .cloned();
    let unfused = runtime
        .artifacts()
        .iter()
        .find(|a| a.kind == ArtifactKind::Unfused)
        .cloned();
    let mut summary = String::new();
    if let (Some(f), Some(u)) = (fused, unfused) {
        let fa = runtime.load(&f.name)?;
        let ua = runtime.load(&u.name)?;
        let fi = random_inputs(&fa, 13, 0.5);
        let fb = bench_artifact(runtime, &fa, &fi, cfg)?;
        let ui = random_inputs(&ua, 13, 0.5);
        let ub = bench_artifact(runtime, &ua, &ui, cfg)?;
        // Sim estimate on the modeled GPU: the unfused path pays one extra
        // full read + write of the (m, n) f32 output through global memory.
        let (fm, fn_, fk) = f.problem.unwrap();
        let fused_sim = autotune::best(fm, fn_, fk, Dtype::F32, device)
            .map(|c| c.result.seconds)
            .unwrap_or(0.0);
        let extra_bytes = 2.0 * (fm * fn_) as f64 * 4.0;
        let epilogue_cost = extra_bytes / device.hbm_bytes_per_sec;
        summary.push_str(&format!(
            "operator fusion (same generated GEMM both sides, {fm}x{fn_}x{fk}):\n\
             measured (CPU): fused {:.3} ms vs unfused {:.3} ms\n\
             modeled (3090): fusion saves {:.1}% (one extra {}x{} f32 output\n\
             round-trip = {:.3} ms on a {:.3} ms kernel)\n",
            fb.exec.mean * 1e3,
            ub.exec.mean * 1e3,
            100.0 * epilogue_cost / (fused_sim + epilogue_cost),
            fm,
            fn_,
            epilogue_cost * 1e3,
            fused_sim * 1e3,
        ));
    }
    summary.push_str(
        "Table 1 qualitative columns: library=minimal conflicts/limited fusion,\n\
         WMMA-API=competitive perf/good fusion, assembly=best perf/most effort.\n",
    );

    Ok(FigureOutput {
        name: "table1_approaches",
        table,
        chart: String::new(),
        summary,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn d() -> DeviceModel {
        DeviceModel::rtx3090()
    }

    #[test]
    fn paper_sizes_range() {
        let s = paper_sizes();
        assert_eq!(*s.first().unwrap(), 1024);
        assert_eq!(*s.last().unwrap(), 16384);
        assert_eq!(s[1] - s[0], 256);
        assert_eq!(s.len(), 61);
    }

    #[test]
    fn figure2_ratio_in_paper_band() {
        // Shape check on a thinned sweep (full sweep in the bench binary).
        let sizes: Vec<usize> = (1024..=16384).step_by(1024).collect();
        let f = figure_sweep(&d(), Dtype::F32, &sizes, "fig2-test");
        for row in &f.table.rows {
            let ratio: f64 = row[3].parse().unwrap();
            assert!(
                ratio > 0.90 && ratio < 1.30,
                "mixed-precision ratio {ratio} outside plausible band at {}",
                row[0]
            );
        }
    }

    #[test]
    fn figure4_has_wider_band_and_jitter() {
        let sizes: Vec<usize> = (8960..=11264).step_by(256).collect();
        let f = figure_sweep(&d(), Dtype::F16, &sizes, "fig4-test");
        let ratios: Vec<f64> = f.table.rows.iter().map(|r| r[3].parse().unwrap()).collect();
        let rmax = ratios.iter().cloned().fold(f64::MIN, f64::max);
        assert!(rmax > 1.1, "expected ours to beat library somewhere >8848, max {rmax}");
    }

    #[test]
    fn figure3_monotone_increasing() {
        let f = figure3(&d());
        let vals: Vec<f64> = f.table.rows.iter().map(|r| r[2].parse().unwrap()).collect();
        for w in vals.windows(2) {
            assert!(w[1] >= w[0] * 0.999, "ablation regressed: {vals:?}");
        }
        // naive -> full should be orders of magnitude
        assert!(vals[7] / vals[0] > 20.0, "{vals:?}");
    }

    #[test]
    fn ablation_schedule_levels() {
        let s0 = ablation_schedule(0, 8192);
        assert!(!s0.tiling);
        let s7 = ablation_schedule(7, 8192);
        assert!(s7.vectorize && s7.padding && s7.latency_hiding);
        assert_eq!(s7.pipeline_stages, 2);
        assert_eq!(ablation_schedule(4, 8192).pipeline_stages, 1);
    }
}
