//! ASCII plotting for terminal figure output (the repo's stand-in for the
//! paper's matplotlib charts).

/// Multi-series line chart: x values shared, one glyph per series.
pub fn line_chart(
    title: &str,
    x: &[f64],
    series: &[(&str, &[f64])],
    width: usize,
    height: usize,
) -> String {
    assert!(!x.is_empty());
    for (_, ys) in series {
        assert_eq!(ys.len(), x.len(), "series length mismatch");
    }
    let glyphs = ['o', '+', 'x', '*', '#', '@'];
    let ymax = series
        .iter()
        .flat_map(|(_, ys)| ys.iter().copied())
        .fold(f64::MIN, f64::max)
        .max(1e-12);
    let ymin = 0.0f64;
    let xmin = x[0];
    let xmax = *x.last().unwrap();
    let xspan = (xmax - xmin).max(1e-12);

    let mut grid = vec![vec![' '; width]; height];
    for (si, (_, ys)) in series.iter().enumerate() {
        let g = glyphs[si % glyphs.len()];
        for (&xv, &yv) in x.iter().zip(ys.iter()) {
            let col = (((xv - xmin) / xspan) * (width - 1) as f64).round() as usize;
            let row_f = ((yv - ymin) / (ymax - ymin)) * (height - 1) as f64;
            let row = height - 1 - row_f.round().min((height - 1) as f64) as usize;
            grid[row][col.min(width - 1)] = g;
        }
    }

    let mut out = format!("{title}\n");
    for (ri, row) in grid.iter().enumerate() {
        let yv = ymax * (height - 1 - ri) as f64 / (height - 1) as f64;
        out.push_str(&format!("{yv:8.1} |"));
        out.extend(row.iter());
        out.push('\n');
    }
    out.push_str(&format!("{:8} +{}\n", "", "-".repeat(width)));
    out.push_str(&format!(
        "{:9}{:<10.0}{:>width$.0}\n",
        "",
        xmin,
        xmax,
        width = width - 10
    ));
    for (si, (name, _)) in series.iter().enumerate() {
        out.push_str(&format!("  {} {}\n", glyphs[si % glyphs.len()], name));
    }
    out
}

/// Horizontal bar chart (for the Figure 3 ablation).
pub fn bar_chart(title: &str, bars: &[(&str, f64)], width: usize) -> String {
    let maxv = bars.iter().map(|(_, v)| *v).fold(f64::MIN, f64::max).max(1e-12);
    let label_w = bars.iter().map(|(l, _)| l.len()).max().unwrap_or(0);
    let mut out = format!("{title}\n");
    for (label, v) in bars {
        let filled = ((v / maxv) * width as f64).round() as usize;
        out.push_str(&format!(
            "{label:>label_w$} | {} {v:.2}\n",
            "#".repeat(filled),
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn line_chart_renders_all_series() {
        let x = [1.0, 2.0, 3.0];
        let a = [1.0, 2.0, 3.0];
        let b = [3.0, 2.0, 1.0];
        let s = line_chart("t", &x, &[("ours", &a), ("lib", &b)], 30, 10);
        assert!(s.contains('o'));
        assert!(s.contains('+'));
        assert!(s.contains("ours"));
        assert!(s.contains("lib"));
    }

    #[test]
    fn bar_chart_scales_to_max() {
        let s = bar_chart("abl", &[("naive", 1.0), ("full", 10.0)], 20);
        let naive_len = s.lines().find(|l| l.contains("naive")).unwrap().matches('#').count();
        let full_len = s.lines().find(|l| l.contains("full")).unwrap().matches('#').count();
        assert_eq!(full_len, 20);
        assert_eq!(naive_len, 2);
    }

    #[test]
    #[should_panic]
    fn mismatched_series_panics() {
        line_chart("t", &[1.0, 2.0], &[("a", &[1.0])], 10, 5);
    }
}
