//! Measurement protocol: warmup + N timed repetitions (the paper averages
//! over ten runs and times kernels only; we separate pack/exec/unpack via
//! [`crate::runtime::ExecTiming`] and report the exec phase).

use std::time::Instant;

use anyhow::Result;

use crate::runtime::{LoadedArtifact, Runtime, Tensor};
use crate::util::prng::Rng;
use crate::util::stats::Summary;

#[derive(Debug, Clone, Copy)]
pub struct BenchConfig {
    pub warmup: usize,
    pub iters: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig { warmup: 2, iters: 10 }
    }
}

/// Time a closure `iters` times after `warmup` unrecorded calls.
pub fn measure<F: FnMut() -> Result<()>>(cfg: BenchConfig, mut f: F) -> Result<Summary> {
    for _ in 0..cfg.warmup {
        f()?;
    }
    let mut samples = Vec::with_capacity(cfg.iters);
    for _ in 0..cfg.iters {
        let t = Instant::now();
        f()?;
        samples.push(t.elapsed().as_secs_f64());
    }
    Ok(Summary::of(&samples))
}

/// Kernel-only timing of one artifact on random inputs.
pub struct ArtifactBench {
    pub exec: Summary,
    pub total: Summary,
    pub pack: Summary,
}

pub fn bench_artifact(
    runtime: &Runtime,
    artifact: &LoadedArtifact,
    inputs: &[Tensor],
    cfg: BenchConfig,
) -> Result<ArtifactBench> {
    for _ in 0..cfg.warmup {
        runtime.execute_timed(artifact, inputs)?;
    }
    let mut exec = Vec::with_capacity(cfg.iters);
    let mut total = Vec::with_capacity(cfg.iters);
    let mut pack = Vec::with_capacity(cfg.iters);
    for _ in 0..cfg.iters {
        let (_, t) = runtime.execute_timed(artifact, inputs)?;
        exec.push(t.exec_seconds);
        total.push(t.total());
        pack.push(t.pack_seconds);
    }
    Ok(ArtifactBench {
        exec: Summary::of(&exec),
        total: Summary::of(&total),
        pack: Summary::of(&pack),
    })
}

/// Random f32 inputs matching an artifact's specs (N(0, scale)).
pub fn random_inputs(artifact: &LoadedArtifact, seed: u64, scale: f32) -> Vec<Tensor> {
    let mut rng = Rng::new(seed);
    artifact
        .meta
        .inputs
        .iter()
        .map(|spec| {
            let data: Vec<f32> = (0..spec.elements())
                .map(|_| rng.normal() as f32 * scale)
                .collect();
            Tensor { shape: spec.shape.clone(), data }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_runs_warmup_plus_iters() {
        let mut calls = 0;
        let s = measure(BenchConfig { warmup: 2, iters: 5 }, || {
            calls += 1;
            Ok(())
        })
        .unwrap();
        assert_eq!(calls, 7);
        assert_eq!(s.n, 5);
    }

    #[test]
    fn measure_propagates_errors() {
        let r = measure(BenchConfig::default(), || anyhow::bail!("boom"));
        assert!(r.is_err());
    }
}
