//! CSV emission for bench results (consumable by any plotting tool).

use std::io::Write;
use std::path::Path;

#[derive(Debug, Default, Clone)]
pub struct CsvTable {
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl CsvTable {
    pub fn new(header: &[&str]) -> CsvTable {
        CsvTable {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row arity {} != header arity {}",
            cells.len(),
            self.header.len()
        );
        self.rows.push(cells);
    }

    pub fn write_to(&self, path: &Path) -> std::io::Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut f = std::fs::File::create(path)?;
        f.write_all(self.to_string().as_bytes())
    }
}

impl std::fmt::Display for CsvTable {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(f, "{}", escape_row(&self.header))?;
        for r in &self.rows {
            writeln!(f, "{}", escape_row(r))?;
        }
        Ok(())
    }
}

fn escape_row(cells: &[String]) -> String {
    cells
        .iter()
        .map(|c| {
            if c.contains(',') || c.contains('"') || c.contains('\n') {
                format!("\"{}\"", c.replace('"', "\"\""))
            } else {
                c.clone()
            }
        })
        .collect::<Vec<_>>()
        .join(",")
}

/// Pretty-print the same table for terminals.
pub fn pretty(table: &CsvTable) -> String {
    let mut widths: Vec<usize> = table.header.iter().map(|h| h.len()).collect();
    for row in &table.rows {
        for (i, c) in row.iter().enumerate() {
            widths[i] = widths[i].max(c.len());
        }
    }
    let fmt_row = |cells: &[String]| {
        cells
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{c:>w$}", w = widths[i]))
            .collect::<Vec<_>>()
            .join("  ")
    };
    let mut out = fmt_row(&table.header);
    out.push('\n');
    out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
    out.push('\n');
    for row in &table.rows {
        out.push_str(&fmt_row(row));
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_and_escapes() {
        let mut t = CsvTable::new(&["a", "b"]);
        t.row(vec!["1".into(), "x,y".into()]);
        t.row(vec!["2".into(), "q\"z".into()]);
        let s = t.to_string();
        assert_eq!(s.lines().next().unwrap(), "a,b");
        assert!(s.contains("\"x,y\""));
        assert!(s.contains("\"q\"\"z\""));
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        let mut t = CsvTable::new(&["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn pretty_aligns() {
        let mut t = CsvTable::new(&["size", "tflops"]);
        t.row(vec!["1024".into(), "30.1".into()]);
        let p = pretty(&t);
        assert!(p.contains("size"));
        assert!(p.lines().count() >= 3);
    }

    #[test]
    fn writes_file() {
        let dir = std::env::temp_dir().join("mlir_gemm_csv_test");
        let path = dir.join("t.csv");
        let mut t = CsvTable::new(&["a"]);
        t.row(vec!["1".into()]);
        t.write_to(&path).unwrap();
        assert!(std::fs::read_to_string(&path).unwrap().contains("a\n1"));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
