//! mlir-gemm: reproduction of "High Performance GPU Code Generation for
//! Matrix-Matrix Multiplication using MLIR" (Katel, Khandelwal, Bondhugula,
//! 2021) as a three-layer Rust + JAX + Pallas stack.
//!
//! Layer map (see DESIGN.md):
//!
//! * L1/L2 live in `python/` (tile-IR pipeline, Pallas kernels, jax
//!   graphs) and run only at build time (`make artifacts`);
//! * this crate is L3 plus the substitute testbed:
//!   - [`runtime`]     — loader + in-process executor for the AOT
//!     tensor-program artifacts;
//!   - [`plan`]        — execution-plan compiler: GemmKey -> compiled
//!     [`plan::ExecutionPlan`] via an explicit pass pipeline;
//!   - [`coordinator`] — GEMM service: registry, router, batcher, workers;
//!   - [`check`]       — protocol model checker + fault-schedule replay
//!     for the coordinator;
//!   - [`sim`]         — analytic RTX 3090 model (the paper's hardware);
//!   - [`autotune`]    — tile-space search over the model + plan refiner;
//!   - [`harness`]     — measurement + figure builders (Fig 2/3/4, Table 1);
//!   - [`schedule`]    — the kernel-variant contract shared with Python;
//!   - [`util`]        — in-repo substrates (json, cli, prng, stats,
//!     proptest-lite) for crates absent from the offline vendor set.

pub mod autotune;
pub mod check;
pub mod coordinator;
pub mod harness;
pub mod plan;
pub mod runtime;
pub mod schedule;
pub mod sim;
pub mod util;
