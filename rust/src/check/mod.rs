//! Protocol checker for the coordinator: an explicit-state model of
//! the serving protocol, an exhaustive interleaving explorer over
//! bounded configurations, and a replay harness that drives model
//! counterexamples against the real [`crate::coordinator::Server`]
//! through its deterministic fault-injection hooks.
//!
//! * [`protocol`] — the transition system (states, actions, the five
//!   protocol invariants, re-introducible historical bugs);
//! * [`explore`]  — BFS over every schedule of a bounded
//!   configuration, with shortest-counterexample traces and coverage
//!   flags guarding against vacuous passes;
//! * [`replay`]   — pin the real server to a counterexample schedule
//!   via [`FaultPlan`](crate::coordinator::FaultPlan) and observe the
//!   violation (or, on fixed code, its absence) for real.
//!
//! Entry point: `mlir-gemm check-protocol` (see `main.rs`), which runs
//! the sound scenario matrix plus one replay leg, or hunts a named
//! re-introduced bug with `--bug`.

pub mod explore;
pub mod protocol;
pub mod replay;

pub use explore::{explore, CheckReport, Counterexample};
pub use protocol::{
    enabled_actions, Action, Bugs, Coverage, JobState, ModelConfig, Resp, State,
};
pub use replay::{replay_shutdown_vs_submit, ReplayOutcome};
