//! Breadth-first enumeration of every interleaving of a bounded
//! protocol configuration.
//!
//! Plain explicit-state model checking: start from
//! [`State::initial`](super::protocol::State::initial), expand with
//! [`enabled_actions`](super::protocol::enabled_actions) +
//! [`apply`](super::protocol::apply), dedup states by hash, keep parent
//! pointers so a violation reconstructs its schedule as a
//! counterexample trace.  BFS (not DFS) so the first counterexample
//! found is a *shortest* one — the trace the replay harness and a human
//! reader work from.
//!
//! The state budget is a hard error, never a silent truncation: a run
//! that exhausts `max_states` proved nothing, and says so.

use std::collections::{HashMap, VecDeque};

use anyhow::{anyhow, Result};

use super::protocol::{
    apply, check_safety, check_terminal, enabled_actions, Action, Coverage,
    ModelConfig, State,
};

/// A violated invariant plus the shortest schedule reaching it.
#[derive(Clone, Debug)]
pub struct Counterexample {
    /// The violated invariant's description (starts with its stable
    /// name, e.g. `no-stranded-shutdown: ...`).
    pub invariant: String,
    /// The schedule from the initial state to the violating one.
    pub trace: Vec<Action>,
    /// The violating state itself.
    pub end: State,
}

impl Counterexample {
    /// The invariant's stable name (the part before `:`).
    pub fn invariant_name(&self) -> &str {
        self.invariant.split(':').next().unwrap_or(&self.invariant)
    }

    /// Multi-line human rendering of the schedule.
    pub fn render(&self) -> String {
        let mut out = format!("violated: {}\nschedule ({} steps):\n", self.invariant,
            self.trace.len());
        for (i, a) in self.trace.iter().enumerate() {
            out.push_str(&format!("  {:>2}. {}\n", i + 1, a.describe()));
        }
        out.push_str(&format!("end state: {:?}\n", self.end));
        out
    }
}

/// What one exhaustive exploration established.
#[derive(Clone, Debug)]
pub struct CheckReport {
    /// Distinct states reached.
    pub states: usize,
    /// Transitions taken (including ones into already-seen states).
    pub transitions: usize,
    /// Terminal states (no enabled action) found and checked.
    pub terminals: usize,
    /// Longest schedule explored (BFS depth of the deepest state).
    pub max_depth: usize,
    /// Which interesting situations actually occurred (vacuity guard).
    pub coverage: Coverage,
    /// The first (shortest) violation, if any invariant broke.
    pub violation: Option<Counterexample>,
}

impl CheckReport {
    pub fn passed(&self) -> bool {
        self.violation.is_none()
    }
}

/// Exhaustively explore `cfg`, checking the safety invariants on every
/// state and the terminal invariants on every terminal state.
///
/// Returns `Err` only when the exploration itself fails (state budget
/// exceeded, or a livelocked model with no terminal state) — a found
/// violation is a successful check run and comes back as
/// `report.violation`.
pub fn explore(cfg: &ModelConfig, max_states: usize) -> Result<CheckReport> {
    let initial = State::initial(cfg);

    let mut states: Vec<State> = vec![initial.clone()];
    let mut index: HashMap<State, usize> = HashMap::from([(initial, 0usize)]);
    let mut parent: Vec<Option<(usize, Action)>> = vec![None];
    let mut depth: Vec<usize> = vec![0];
    let mut frontier: VecDeque<usize> = VecDeque::from([0]);

    let mut transitions = 0usize;
    let mut terminals = 0usize;
    let mut max_depth = 0usize;
    let mut coverage = Coverage::default();

    let trace_to = |parent: &[Option<(usize, Action)>], mut at: usize| {
        let mut rev = Vec::new();
        while let Some((p, a)) = parent[at] {
            rev.push(a);
            at = p;
        }
        rev.reverse();
        rev
    };

    if let Some(v) = check_safety(cfg, &states[0]) {
        return Ok(CheckReport {
            states: 1,
            transitions: 0,
            terminals: 0,
            max_depth: 0,
            coverage,
            violation: Some(Counterexample {
                invariant: v,
                trace: Vec::new(),
                end: states[0].clone(),
            }),
        });
    }

    while let Some(at) = frontier.pop_front() {
        let acts = enabled_actions(cfg, &states[at]);
        if acts.is_empty() {
            terminals += 1;
            if let Some(v) = check_terminal(cfg, &states[at]) {
                return Ok(CheckReport {
                    states: states.len(),
                    transitions,
                    terminals,
                    max_depth,
                    coverage,
                    violation: Some(Counterexample {
                        invariant: v,
                        trace: trace_to(&parent, at),
                        end: states[at].clone(),
                    }),
                });
            }
            continue;
        }
        for a in acts {
            let next = apply(cfg, &states[at], &a);
            transitions += 1;
            coverage.observe(cfg, &states[at], &a, &next);
            if index.contains_key(&next) {
                continue;
            }
            if states.len() >= max_states {
                return Err(anyhow!(
                    "state budget exceeded: >{max_states} distinct states for \
                     {cfg:?} — nothing was proven; shrink the configuration or \
                     raise --max-states"
                ));
            }
            let id = states.len();
            let d = depth[at] + 1;
            max_depth = max_depth.max(d);
            index.insert(next.clone(), id);
            states.push(next.clone());
            parent.push(Some((at, a)));
            depth.push(d);
            if let Some(v) = check_safety(cfg, &next) {
                return Ok(CheckReport {
                    states: states.len(),
                    transitions,
                    terminals,
                    max_depth,
                    coverage,
                    violation: Some(Counterexample {
                        invariant: v,
                        trace: trace_to(&parent, id),
                        end: next,
                    }),
                });
            }
            frontier.push_back(id);
        }
    }

    if terminals == 0 {
        return Err(anyhow!(
            "exploration found no terminal state for {cfg:?} — the model \
             livelocks; the terminal invariants were never checked"
        ));
    }

    Ok(CheckReport {
        states: states.len(),
        transitions,
        terminals,
        max_depth,
        coverage,
        violation: None,
    })
}

#[cfg(test)]
mod tests {
    use super::super::protocol::Bugs;
    use super::*;

    // Debug builds (plain `cargo test`) run these, so every config here
    // stays tiny: 2 clients x 1-2 devices explores in well under a
    // second even unoptimized.

    #[test]
    fn base_scenario_holds_and_is_not_vacuous() {
        let cfg = ModelConfig::new(2, 1);
        let r = explore(&cfg, 200_000).unwrap();
        assert!(r.passed(), "{:?}", r.violation);
        assert!(r.terminals > 0 && r.states > 10);
        assert!(
            r.coverage.multi_job_batch,
            "two jobs must batch together in some schedule"
        );
        assert!(
            r.coverage.shutdown_with_backlog && r.coverage.late_submit_error,
            "shutdown must race both a buffered and an unsent submit: {:?}",
            r.coverage
        );
    }

    #[test]
    fn exploration_is_deterministic() {
        let cfg = ModelConfig::new(2, 2).with_rebind();
        let a = explore(&cfg, 500_000).unwrap();
        let b = explore(&cfg, 500_000).unwrap();
        assert_eq!(a.states, b.states);
        assert_eq!(a.transitions, b.transitions);
        assert_eq!(a.terminals, b.terminals);
        assert!(a.passed());
        assert!(a.coverage.rebind_raced_dispatch, "{:?}", a.coverage);
    }

    #[test]
    fn stop_flag_break_yields_a_replayable_counterexample() {
        let bugs = Bugs { stop_flag_break: true, ..Default::default() };
        let cfg = ModelConfig::new(2, 1).with_bugs(bugs);
        let r = explore(&cfg, 200_000).unwrap();
        let cx = r.violation.expect("the PR 5 bug must be found");
        assert_eq!(cx.invariant_name(), "no-stranded-shutdown");
        assert!(
            cx.trace.contains(&Action::Shutdown)
                && cx.trace.contains(&Action::StopFlagBreak),
            "trace must schedule shutdown then the buggy break: {:?}",
            cx.trace
        );
        // BFS guarantees a shortest trace.  Stranding is a *terminal*
        // invariant and a state with a Fresh client is never terminal,
        // so the shortest violating schedule is 4 steps: one submit
        // buffered, shutdown, the buggy break, and the second client's
        // late submit (answered ShutdownErr) to close the state out.
        assert_eq!(cx.trace.len(), 4, "{}", cx.render());
    }

    #[test]
    fn stale_rebind_bug_is_found() {
        let bugs = Bugs { stale_rebind: true, ..Default::default() };
        let cfg = ModelConfig::new(2, 1).with_rebind().with_bugs(bugs);
        let r = explore(&cfg, 500_000).unwrap();
        let cx = r.violation.expect("stale rebind must be found");
        assert_eq!(cx.invariant_name(), "no-stale-weights");
        assert!(cx.trace.contains(&Action::Rebind), "{}", cx.render());
    }

    #[test]
    fn no_containment_bug_is_found() {
        let bugs = Bugs { no_containment: true, ..Default::default() };
        let cfg = ModelConfig::new(2, 1).with_poison().with_bugs(bugs);
        let r = explore(&cfg, 200_000).unwrap();
        let cx = r.violation.expect("missing containment must be found");
        assert_eq!(cx.invariant_name(), "containment");
    }

    #[test]
    fn poison_with_containment_passes_and_covers() {
        let cfg = ModelConfig::new(2, 1).with_poison();
        let r = explore(&cfg, 200_000).unwrap();
        assert!(r.passed(), "{:?}", r.violation);
        assert!(r.coverage.poisoned_job && r.coverage.multi_job_batch);
    }

    #[test]
    fn deadline_and_overflow_scenarios_pass_and_cover() {
        let r = explore(&ModelConfig::new(2, 1).with_deadline(), 200_000).unwrap();
        assert!(r.passed(), "{:?}", r.violation);
        assert!(r.coverage.expired_job);

        let r = explore(&ModelConfig::new(2, 1).with_capacity(1), 200_000).unwrap();
        assert!(r.passed(), "{:?}", r.violation);
        assert!(r.coverage.queue_full_rejection);
    }

    #[test]
    fn sharded_scenario_passes_and_covers() {
        let cfg = ModelConfig::new(2, 2).with_sharding();
        let r = explore(&cfg, 500_000).unwrap();
        assert!(r.passed(), "{:?}", r.violation);
        assert!(r.coverage.shard_reduction);
    }

    #[test]
    fn state_budget_is_a_hard_error() {
        let cfg = ModelConfig::new(2, 2);
        let err = explore(&cfg, 8).unwrap_err();
        assert!(format!("{err}").contains("state budget exceeded"));
    }
}
