//! Transition-system model of the coordinator protocol.
//!
//! The model mirrors the concurrency skeleton of
//! [`crate::coordinator::server`] — not the numerics.  One `State` is a
//! snapshot of everything the real threads share:
//!
//! * per-job progress (`JobState`): fresh -> buffered in the bounded
//!   submit channel -> routed into the batcher (capturing the bind
//!   epoch, exactly where the real dispatcher's enqueue closure calls
//!   `route()`) -> executing on a device (or fanned out into shards)
//!   -> answered (`Resp`);
//! * the submit-channel FIFO and the batcher queue (job ids, in order);
//! * one in-flight batch slot per device;
//! * the registry bind epoch (first bind = 1, a rebind bumps it);
//! * the shutdown/stop flags and whether the dispatcher thread is
//!   still alive.
//!
//! `enabled_actions` + `apply` define the interleaving semantics; the
//! BFS in [`crate::check::explore`] enumerates every schedule of a
//! bounded configuration and checks the six protocol invariants:
//!
//! 1. **accounting** — at every terminal state,
//!    `completed + failed + rejected == submitted`;
//! 2. **every-submit-answered** — no response channel is ever dropped:
//!    every submitted job reaches a `Resp`;
//! 3. **no-stranded-shutdown** — a shutdown may fail late jobs
//!    explicitly but can never leave one buffered forever;
//! 4. **no-stale-weights** — a job executes under the bind epoch it was
//!    *routed* with, even when a rebind lands in between;
//! 5. **containment** — a job that panics mid-batch fails alone; its
//!    batchmates still complete.
//! 6. **no-priority-inversion-past-deadline** — a release never picks a
//!    lower-priority job while a higher-priority job sits admitted in
//!    the scheduler (which would burn the bypassed job's deadline
//!    budget under lower-priority work).
//!
//! The continuous-batching dispatcher is modelled by the same actions
//! with new admission semantics: a pre-expired deadline is answered
//! `Expired` at `Submit` (it never consumes a bounded-channel slot), a
//! per-tenant quota answers `QuotaRejected` at `Submit`, `Release`
//! picks jobs in (priority, deadline-rank) order instead of FIFO
//! prefix, and `Sweep` models `take_expired` removing a job whose
//! deadline lapsed while it waited in the scheduler.
//!
//! [`Bugs`] re-introduces four historical/candidate defects as model
//! variants (and, for the stop-flag one, as a real-code test hook in
//! `FaultPlan`), so the checker demonstrably *can* find the violation
//! and the counterexample schedule replays against the real server.
//!
//! Soundness of the bound: every shared structure in the real server is
//! symmetric in job identity and device identity, and the protocol
//! state machine is finite once job count, device count, and queue
//! capacity are fixed.  The interesting races each need at most three
//! concurrent parties (two jobs + one control action such as rebind or
//! shutdown), so a 3-client x 2-device x capacity-2 bound covers every
//! race shape the implementation can exhibit; larger configurations
//! only replicate the same shapes with more symmetric players.

/// Bounded model configuration: which scenario of the protocol to
/// explore, and which (off-by-default) historical bugs to re-introduce.
#[derive(Clone, Debug)]
pub struct ModelConfig {
    /// Number of clients; each submits exactly one job (job id = client
    /// id).
    pub clients: u8,
    /// Number of worker devices (one in-flight batch slot each; shard
    /// fan-out width in `sharded` mode).
    pub devices: u8,
    /// Bounded submit-channel capacity (`ServerConfig::queue_capacity`).
    pub queue_capacity: u8,
    /// Max jobs the batcher releases into one batch.
    pub max_batch: u8,
    /// Jobs fan out into one shard per device with a last-finisher
    /// reduction, instead of executing as whole batches.
    pub sharded: bool,
    /// Jobs route against bound weights: the bind epoch (starting at 1)
    /// is captured at routing time and must be the one they execute
    /// under.
    pub bound: bool,
    /// A one-shot concurrent rebind action exists (bumps the bind
    /// epoch; requires `bound`).
    pub rebind: bool,
    /// Job 0 panics during execution (the poison job).
    pub poison: bool,
    /// Job 0 carries an already-expired deadline and must be answered
    /// `Expired` at admission (`Submit`), never consuming a channel
    /// slot and never executing.
    pub deadline: bool,
    /// Job 0's deadline lapses while it waits *inside* the scheduler:
    /// the `Sweep` action (modelling `take_expired`) may remove it and
    /// answer `Expired` — or a `Release` may beat the sweep and the job
    /// completes.  Both orders must satisfy every invariant.
    pub late_deadline: bool,
    /// Priority tiers: job 0 is the low-priority job and every later
    /// job is high priority (the arrival order that makes inversion
    /// possible).  `Release` must pick high before low.
    pub priority: bool,
    /// Per-tenant admission quota (0 = off).  All modelled jobs share
    /// one tenant; a submit finding `quota` jobs already admitted
    /// (buffered or in the scheduler) is answered `QuotaRejected`
    /// without consuming a channel slot.
    pub quota: u8,
    /// A one-shot shutdown action exists and may interleave anywhere.
    pub shutdown: bool,
    /// Re-introduced defects under test.
    pub bugs: Bugs,
}

/// Historical/candidate defects the checker must be able to catch.
/// All off by default; each one changes the *model* semantics the same
/// way the corresponding code change would, so a violation found here
/// names a real schedule.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Bugs {
    /// The PR 5 dispatcher bug: break out of the dispatch loop as soon
    /// as the stop flag is up and the *batcher* is empty — stranding
    /// jobs still buffered in the submit channel.  Mirrored in real
    /// code by `FaultPlan::stop_flag_break`.
    pub stop_flag_break: bool,
    /// Execute under the registry's *current* weights instead of the
    /// `Arc<BoundB>` captured at routing — stale-panel hazard when a
    /// rebind races dispatch.
    pub stale_rebind: bool,
    /// No panic containment: one poisoned job takes its whole batch
    /// down instead of being quarantined.
    pub no_containment: bool,
    /// The pre-continuous-batching dispatcher's release order: take the
    /// FIFO prefix of the scheduler queue, ignoring priority tiers — a
    /// high-priority job behind a low-priority head is bypassed and its
    /// deadline budget burns under lower-priority work.
    pub fifo_release: bool,
}

impl ModelConfig {
    /// Base scenario: `clients` jobs racing `devices` workers with a
    /// concurrent shutdown, ample queue capacity, batches of up to 2.
    pub fn new(clients: u8, devices: u8) -> Self {
        ModelConfig {
            clients,
            devices: devices.max(1),
            queue_capacity: clients.max(1),
            max_batch: 2,
            sharded: false,
            bound: false,
            rebind: false,
            poison: false,
            deadline: false,
            late_deadline: false,
            priority: false,
            quota: 0,
            shutdown: true,
            bugs: Bugs::default(),
        }
    }

    /// Weight-bound jobs plus a concurrent rebind racing dispatch.
    pub fn with_rebind(mut self) -> Self {
        self.bound = true;
        self.rebind = true;
        self
    }

    /// Job 0 panics during execution.
    pub fn with_poison(mut self) -> Self {
        self.poison = true;
        self
    }

    /// Job 0 arrives with an already-expired deadline.
    pub fn with_deadline(mut self) -> Self {
        self.deadline = true;
        self
    }

    /// Job 0's deadline lapses while it waits in the scheduler.
    pub fn with_late_deadline(mut self) -> Self {
        self.late_deadline = true;
        self
    }

    /// Priority tiers: job 0 low, later jobs high.
    pub fn with_priority(mut self) -> Self {
        self.priority = true;
        self
    }

    /// Per-tenant admission quota (all modelled jobs share one tenant).
    pub fn with_quota(mut self, quota: u8) -> Self {
        self.quota = quota;
        self
    }

    /// Cap the number of jobs one release may batch together.
    pub fn with_max_batch(mut self, max_batch: u8) -> Self {
        self.max_batch = max_batch.max(1);
        self
    }

    /// Jobs fan out into per-device shards with a last-finisher
    /// reduction.
    pub fn with_sharding(mut self) -> Self {
        self.sharded = true;
        self
    }

    /// Shrink the submit queue to force `Rejected` responses.
    pub fn with_capacity(mut self, cap: u8) -> Self {
        self.queue_capacity = cap.max(1);
        self
    }

    /// Re-introduce a set of defects.
    pub fn with_bugs(mut self, bugs: Bugs) -> Self {
        self.bugs = bugs;
        self
    }

    fn poisoned(&self, job: u8) -> bool {
        self.poison && job == 0
    }

    fn expired(&self, job: u8) -> bool {
        self.deadline && job == 0
    }

    fn late_expired(&self, job: u8) -> bool {
        self.late_deadline && job == 0
    }

    /// Priority rank of a job, 0 = highest.  With `priority` on, job 0
    /// is the low tier and every later job the high tier.
    fn prio(&self, job: u8) -> u8 {
        u8::from(self.priority && job == 0)
    }

    /// Jobs currently inside the admission scope (buffered in the
    /// channel or waiting in the scheduler) — what the per-tenant
    /// ledger counts.
    fn admitted(&self, s: &State) -> usize {
        s.queue.len() + s.batcher.len()
    }
}

/// Terminal response of one job — the model's `GemmResponse`.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum Resp {
    /// Executed; carries the epoch captured at routing and the epoch of
    /// the weights actually used.  The no-stale-weights invariant is
    /// `routed == exec`.
    Completed { routed: u8, exec: u8 },
    /// The job itself panicked and was quarantined (explicit failure).
    Poisoned,
    /// Failed only because a *batchmate* panicked — produced solely by
    /// [`Bugs::no_containment`]; its existence is the containment
    /// violation.
    Collateral,
    /// Deadline expired before execution (explicit failure).
    Expired,
    /// Bounded admission: queue at capacity (explicit rejection).
    Rejected,
    /// Per-tenant admission quota exhausted (explicit rejection,
    /// counted with `Rejected` in the accounting identity).
    QuotaRejected,
    /// Submitted after shutdown closed the channel (explicit failure).
    ShutdownErr,
}

/// Where one job currently is in the pipeline.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum JobState {
    /// Client has not called submit yet.
    Fresh,
    /// Buffered in the bounded submit channel.
    Queued,
    /// Routed into the batcher; `epoch` is the bind epoch captured by
    /// `route()` at the channel -> batcher boundary.
    Routed { epoch: u8 },
    /// Member of an in-flight batch on some device.
    Executing { epoch: u8 },
    /// Fanned out; `left` shards still running (last finisher reduces).
    Sharding { epoch: u8, left: u8 },
    /// Answered.
    Done(Resp),
}

/// One interleaving step.  `Submit`/`Rebind`/`Shutdown` are client
/// threads; `Route`/`Release`/`FanOut`/`StopFlagBreak`/`DrainExit` are
/// the dispatcher; `ExecBatch`/`ExecShard` are workers.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum Action {
    Submit { client: u8 },
    Rebind,
    Shutdown,
    /// Dispatcher pops the channel head and routes it (or answers its
    /// expired deadline).
    Route,
    /// Dispatcher releases a batch (up to `max_batch` jobs, picked in
    /// (priority, deadline-rank) order) to a free device.
    Release { device: u8 },
    /// Dispatcher sweeps a job whose deadline lapsed inside the
    /// scheduler (`take_expired`) and answers it `Expired`.
    Sweep,
    /// Dispatcher fans the head job out into one shard per device.
    FanOut,
    /// A device finishes its in-flight batch.
    ExecBatch { device: u8 },
    /// One shard of `job` finishes; the last one reduces and replies.
    ExecShard { job: u8 },
    /// The re-introduced PR 5 bug: dispatcher exits on
    /// `stop && batcher.is_empty()` with jobs still in the channel.
    StopFlagBreak,
    /// Clean dispatcher exit: channel closed *and* drained, batcher
    /// flushed.
    DrainExit,
}

impl Action {
    /// Human-readable step for counterexample traces.
    pub fn describe(&self) -> String {
        match self {
            Action::Submit { client } => format!("client {client} submits job {client}"),
            Action::Rebind => "client rebinds the weights (epoch +1)".into(),
            Action::Shutdown => {
                "shutdown: stop flag raised, submit channel closed".into()
            }
            Action::Route => "dispatcher routes the channel-head job".into(),
            Action::Release { device } => {
                format!("dispatcher releases a batch to device {device}")
            }
            Action::Sweep => {
                "dispatcher sweeps the scheduler-expired job (take_expired)".into()
            }
            Action::FanOut => "dispatcher fans the head job out into shards".into(),
            Action::ExecBatch { device } => {
                format!("device {device} executes its batch")
            }
            Action::ExecShard { job } => {
                format!("one shard of job {job} finishes")
            }
            Action::StopFlagBreak => {
                "dispatcher takes the buggy stop-flag break (batcher empty, \
                 channel NOT empty)"
                    .into()
            }
            Action::DrainExit => "dispatcher drains and exits cleanly".into(),
        }
    }
}

/// Full protocol state — hashable so the explorer can dedup
/// interleavings that converge.
#[derive(Clone, PartialEq, Eq, Hash, Debug)]
pub struct State {
    pub jobs: Vec<JobState>,
    /// Submit-channel FIFO (job ids).
    pub queue: Vec<u8>,
    /// Batcher queue (job ids, routed order).
    pub batcher: Vec<u8>,
    /// Per-device in-flight batch (job ids), `None` = free.
    pub slots: Vec<Option<Vec<u8>>>,
    pub bind_epoch: u8,
    /// Shutdown happened: stop flag up, channel closed.
    pub shutdown_taken: bool,
    pub dispatcher_alive: bool,
    /// A release picked a lower-priority job while a strictly
    /// higher-priority job stayed behind in the scheduler — the
    /// no-priority-inversion-past-deadline violation.
    pub inverted: bool,
}

impl State {
    pub fn initial(cfg: &ModelConfig) -> State {
        State {
            jobs: vec![JobState::Fresh; cfg.clients as usize],
            queue: Vec::new(),
            batcher: Vec::new(),
            slots: vec![None; cfg.devices as usize],
            bind_epoch: if cfg.bound { 1 } else { 0 },
            shutdown_taken: false,
            dispatcher_alive: true,
            inverted: false,
        }
    }

    /// (submitted, completed, failed, rejected) as the real metrics
    /// would count them.
    pub fn tally(&self) -> (u64, u64, u64, u64) {
        let mut submitted = 0;
        let mut completed = 0;
        let mut failed = 0;
        let mut rejected = 0;
        for j in &self.jobs {
            if !matches!(j, JobState::Fresh) {
                submitted += 1;
            }
            match j {
                JobState::Done(Resp::Completed { .. }) => completed += 1,
                JobState::Done(Resp::Rejected | Resp::QuotaRejected) => rejected += 1,
                JobState::Done(
                    Resp::Poisoned | Resp::Collateral | Resp::Expired | Resp::ShutdownErr,
                ) => failed += 1,
                _ => {}
            }
        }
        (submitted, completed, failed, rejected)
    }
}

/// Every action enabled in `s` — the branching of the interleaving
/// exploration.  An empty result means `s` is terminal.
pub fn enabled_actions(cfg: &ModelConfig, s: &State) -> Vec<Action> {
    let mut acts = Vec::new();
    for c in 0..cfg.clients {
        if matches!(s.jobs[c as usize], JobState::Fresh) {
            acts.push(Action::Submit { client: c });
        }
    }
    if cfg.rebind && s.bind_epoch < 2 && !s.shutdown_taken {
        acts.push(Action::Rebind);
    }
    if cfg.shutdown && !s.shutdown_taken {
        acts.push(Action::Shutdown);
    }
    if s.dispatcher_alive {
        if !s.queue.is_empty() {
            acts.push(Action::Route);
        }
        if s.batcher.iter().any(|&j| cfg.late_expired(j)) {
            acts.push(Action::Sweep);
        }
        if !s.batcher.is_empty() {
            if cfg.sharded {
                acts.push(Action::FanOut);
            } else {
                for d in 0..cfg.devices {
                    if s.slots[d as usize].is_none() {
                        acts.push(Action::Release { device: d });
                    }
                }
            }
        }
        if cfg.bugs.stop_flag_break && s.shutdown_taken && s.batcher.is_empty() {
            acts.push(Action::StopFlagBreak);
        }
        if s.shutdown_taken && s.queue.is_empty() && s.batcher.is_empty() {
            acts.push(Action::DrainExit);
        }
    }
    for (d, slot) in s.slots.iter().enumerate() {
        if slot.is_some() {
            acts.push(Action::ExecBatch { device: d as u8 });
        }
    }
    for (j, js) in s.jobs.iter().enumerate() {
        if matches!(js, JobState::Sharding { left, .. } if *left > 0) {
            acts.push(Action::ExecShard { job: j as u8 });
        }
    }
    acts
}

/// The successor of `s` under `a`.  Panics on a non-enabled action —
/// the explorer only feeds it results of [`enabled_actions`].
pub fn apply(cfg: &ModelConfig, s: &State, a: &Action) -> State {
    let mut n = s.clone();
    match *a {
        Action::Submit { client } => {
            let c = client as usize;
            n.jobs[c] = if cfg.expired(client) {
                // Admission-time deadline gate: a dead-on-arrival job
                // is answered before it can consume a channel slot.
                JobState::Done(Resp::Expired)
            } else if cfg.quota > 0 && cfg.admitted(&n) >= cfg.quota as usize {
                // Per-tenant ledger checked before try_send, so quota
                // exhaustion rejects even after shutdown.
                JobState::Done(Resp::QuotaRejected)
            } else if n.shutdown_taken {
                // try_send on the swapped-out sender: Disconnected ->
                // explicit shutdown error, counted as failed.
                JobState::Done(Resp::ShutdownErr)
            } else if n.queue.len() >= cfg.queue_capacity as usize {
                // try_send Full -> bounded-admission rejection.
                JobState::Done(Resp::Rejected)
            } else {
                n.queue.push(client);
                JobState::Queued
            };
        }
        Action::Rebind => n.bind_epoch += 1,
        Action::Shutdown => n.shutdown_taken = true,
        Action::Route => {
            let j = n.queue.remove(0);
            // route() captures the bind epoch *here* — the routed
            // Arc<BoundB> travels with the job from this point on.
            // (The pre-expired deadline gate now lives at Submit.)
            n.batcher.push(j);
            n.jobs[j as usize] = JobState::Routed { epoch: n.bind_epoch };
        }
        Action::Sweep => {
            let i = n
                .batcher
                .iter()
                .position(|&j| cfg.late_expired(j))
                .expect("sweep with no scheduler-expired job");
            let j = n.batcher.remove(i);
            n.jobs[j as usize] = JobState::Done(Resp::Expired);
        }
        Action::Release { device } => {
            let take = (cfg.max_batch as usize).min(n.batcher.len());
            let batch: Vec<u8> = if cfg.bugs.fifo_release {
                // Buggy pre-continuous dispatcher: FIFO prefix,
                // priorities ignored.
                n.batcher.drain(..take).collect()
            } else {
                // Continuous scheduler: pick by (priority, arrival
                // rank) — arrival rank doubles as the deadline rank in
                // the model, so this is EDF within a priority tier.
                let mut order: Vec<usize> = (0..n.batcher.len()).collect();
                order.sort_by_key(|&i| (cfg.prio(n.batcher[i]), i));
                let picked: Vec<u8> =
                    order[..take].iter().map(|&i| n.batcher[i]).collect();
                n.batcher.retain(|j| !picked.contains(j));
                picked
            };
            // Inversion detector: a picked job with strictly lower
            // priority than something left behind burns the bypassed
            // job's deadline budget.
            if batch
                .iter()
                .any(|&p| n.batcher.iter().any(|&u| cfg.prio(u) < cfg.prio(p)))
            {
                n.inverted = true;
            }
            for &j in &batch {
                let JobState::Routed { epoch } = n.jobs[j as usize] else {
                    unreachable!("batcher held a non-routed job");
                };
                n.jobs[j as usize] = JobState::Executing { epoch };
            }
            n.slots[device as usize] = Some(batch);
        }
        Action::FanOut => {
            let j = n.batcher.remove(0);
            let JobState::Routed { epoch } = n.jobs[j as usize] else {
                unreachable!("batcher held a non-routed job");
            };
            n.jobs[j as usize] = JobState::Sharding { epoch, left: cfg.devices };
        }
        Action::ExecBatch { device } => {
            let batch = n.slots[device as usize].take().expect("exec on a free device");
            let any_poison = batch.iter().any(|&j| cfg.poisoned(j));
            for &j in &batch {
                let JobState::Executing { epoch } = n.jobs[j as usize] else {
                    unreachable!("in-flight batch held a non-executing job");
                };
                n.jobs[j as usize] = JobState::Done(if cfg.poisoned(j) {
                    // catch_unwind contains the panic; the job fails
                    // alone with an explicit ERR_POISONED response.
                    Resp::Poisoned
                } else if any_poison && cfg.bugs.no_containment {
                    // Without quarantine the whole batch dies.
                    Resp::Collateral
                } else {
                    Resp::Completed {
                        routed: epoch,
                        exec: if cfg.bugs.stale_rebind {
                            // Buggy variant: re-fetch weights from the
                            // registry at execution time.
                            n.bind_epoch
                        } else {
                            epoch
                        },
                    }
                });
            }
        }
        Action::ExecShard { job } => {
            let j = job as usize;
            let JobState::Sharding { epoch, left } = n.jobs[j] else {
                unreachable!("shard exec on a non-sharding job");
            };
            n.jobs[j] = if left > 1 {
                JobState::Sharding { epoch, left: left - 1 }
            } else {
                // Last finisher reduces the partials and replies once.
                JobState::Done(if cfg.poisoned(job) {
                    Resp::Poisoned
                } else {
                    Resp::Completed {
                        routed: epoch,
                        exec: if cfg.bugs.stale_rebind { n.bind_epoch } else { epoch },
                    }
                })
            };
        }
        Action::StopFlagBreak | Action::DrainExit => n.dispatcher_alive = false,
    }
    n
}

/// Safety invariants, checked on *every* reachable state.  Returns the
/// violated invariant's description, or `None`.
pub fn check_safety(_cfg: &ModelConfig, s: &State) -> Option<String> {
    if s.inverted {
        return Some(
            "no-priority-inversion-past-deadline: a release picked a \
             lower-priority job while a strictly higher-priority job stayed \
             admitted in the scheduler — the bypassed job's deadline budget \
             burned under lower-priority work"
                .into(),
        );
    }
    for (j, js) in s.jobs.iter().enumerate() {
        match js {
            JobState::Done(Resp::Completed { routed, exec }) if routed != exec => {
                return Some(format!(
                    "no-stale-weights: job {j} was routed with bind epoch {routed} \
                     but executed under epoch {exec} — a rebind between routing and \
                     execution leaked stale prepacked panels"
                ));
            }
            JobState::Done(Resp::Collateral) => {
                return Some(format!(
                    "containment: job {j} failed because a batchmate panicked — a \
                     poison job must be quarantined, not take its batch down"
                ));
            }
            _ => {}
        }
    }
    None
}

/// Terminal invariants, checked where no action is enabled.  Returns
/// the violated invariant's description, or `None`.
pub fn check_terminal(cfg: &ModelConfig, s: &State) -> Option<String> {
    for (j, js) in s.jobs.iter().enumerate() {
        if !matches!(js, JobState::Done(_)) {
            return Some(if s.shutdown_taken && !s.dispatcher_alive {
                format!(
                    "no-stranded-shutdown: job {j} stranded in {js:?} after shutdown \
                     — submitted, never answered, reply channel leaked"
                )
            } else {
                format!(
                    "every-submit-answered: job {j} ended in {js:?} without a \
                     response"
                )
            });
        }
    }
    let (submitted, completed, failed, rejected) = s.tally();
    if submitted != cfg.clients as u64 || completed + failed + rejected != submitted {
        return Some(format!(
            "accounting: submitted {submitted} != completed {completed} + failed \
             {failed} + rejected {rejected} (clients {})",
            cfg.clients
        ));
    }
    None
}

/// Which interesting situations the exploration actually visited — the
/// vacuity guard.  A scenario that "passes" without ever filling the
/// queue or racing a rebind proved nothing; the CLI and the tests
/// assert the flags relevant to each scenario.
#[derive(Clone, Copy, Debug, Default)]
pub struct Coverage {
    /// A batch with >= 2 jobs executed.
    pub multi_job_batch: bool,
    /// A batch or shard executed after a rebind had bumped the epoch
    /// past its routed epoch — the stale-panel race window actually
    /// opened.
    pub rebind_raced_dispatch: bool,
    /// Bounded admission rejected a submit.
    pub queue_full_rejection: bool,
    /// Shutdown fired while jobs were still buffered in the channel.
    pub shutdown_with_backlog: bool,
    /// A submit after shutdown got the explicit error.
    pub late_submit_error: bool,
    /// A deadline-expired job was answered without executing (at
    /// admission for a pre-expired deadline).
    pub expired_job: bool,
    /// A job whose deadline lapsed inside the scheduler was swept out
    /// by `take_expired` and answered `Expired`.
    pub swept_in_scheduler: bool,
    /// The per-tenant quota rejected a submit.
    pub tenant_quota_rejection: bool,
    /// A release picked a high-priority job while a lower-priority job
    /// (that arrived earlier) stayed behind — the priority path
    /// actually reordered work.
    pub priority_release: bool,
    /// A poisoned job produced its explicit quarantine failure.
    pub poisoned_job: bool,
    /// A sharded job completed via the last-finisher reduction.
    pub shard_reduction: bool,
}

impl Coverage {
    /// Fold one transition `(s, a) -> n` into the flags.
    pub fn observe(&mut self, cfg: &ModelConfig, s: &State, a: &Action, n: &State) {
        match *a {
            Action::Submit { client } => {
                match n.jobs[client as usize] {
                    JobState::Done(Resp::Rejected) => self.queue_full_rejection = true,
                    JobState::Done(Resp::QuotaRejected) => {
                        self.tenant_quota_rejection = true;
                    }
                    JobState::Done(Resp::ShutdownErr) => self.late_submit_error = true,
                    JobState::Done(Resp::Expired) => self.expired_job = true,
                    _ => {}
                }
            }
            Action::Shutdown => {
                if !s.queue.is_empty() {
                    self.shutdown_with_backlog = true;
                }
            }
            Action::Sweep => self.swept_in_scheduler = true,
            Action::Release { device } => {
                if let Some(batch) = &n.slots[device as usize] {
                    // Reordered release: a picked job outranks a job
                    // left behind that arrived earlier.
                    if batch
                        .iter()
                        .any(|&p| n.batcher.iter().any(|&u| {
                            cfg.prio(p) < cfg.prio(u) && u < p
                        }))
                    {
                        self.priority_release = true;
                    }
                }
            }
            Action::ExecBatch { device } => {
                if let Some(batch) = &s.slots[device as usize] {
                    if batch.len() >= 2 {
                        self.multi_job_batch = true;
                    }
                    for &j in batch {
                        if let JobState::Executing { epoch } = s.jobs[j as usize] {
                            if epoch < s.bind_epoch {
                                self.rebind_raced_dispatch = true;
                            }
                        }
                        if matches!(n.jobs[j as usize], JobState::Done(Resp::Poisoned))
                        {
                            self.poisoned_job = true;
                        }
                    }
                }
            }
            Action::ExecShard { job } => {
                if let JobState::Sharding { epoch, .. } = s.jobs[job as usize] {
                    if epoch < s.bind_epoch {
                        self.rebind_raced_dispatch = true;
                    }
                }
                match n.jobs[job as usize] {
                    JobState::Done(Resp::Completed { .. }) => {
                        self.shard_reduction = true;
                    }
                    JobState::Done(Resp::Poisoned) => {
                        self.poisoned_job = true;
                        self.shard_reduction = true;
                    }
                    _ => {}
                }
            }
            _ => {}
        }
        let _ = cfg;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn initial_state_matches_config() {
        let cfg = ModelConfig::new(2, 1).with_rebind();
        let s = State::initial(&cfg);
        assert_eq!(s.jobs, vec![JobState::Fresh; 2]);
        assert_eq!(s.bind_epoch, 1, "bound configs start at bind epoch 1");
        assert!(s.dispatcher_alive && !s.shutdown_taken);
        let unbound = State::initial(&ModelConfig::new(2, 1));
        assert_eq!(unbound.bind_epoch, 0);
    }

    #[test]
    fn submit_route_release_exec_answers_the_job() {
        let cfg = ModelConfig::new(1, 1);
        let s0 = State::initial(&cfg);
        let s1 = apply(&cfg, &s0, &Action::Submit { client: 0 });
        assert_eq!(s1.jobs[0], JobState::Queued);
        let s2 = apply(&cfg, &s1, &Action::Route);
        assert_eq!(s2.jobs[0], JobState::Routed { epoch: 0 });
        let s3 = apply(&cfg, &s2, &Action::Release { device: 0 });
        assert_eq!(s3.jobs[0], JobState::Executing { epoch: 0 });
        let s4 = apply(&cfg, &s3, &Action::ExecBatch { device: 0 });
        assert_eq!(
            s4.jobs[0],
            JobState::Done(Resp::Completed { routed: 0, exec: 0 })
        );
        assert!(enabled_actions(&cfg, &s4).len() == 1, "only Shutdown remains");
        assert!(check_terminal(&cfg, &apply(&cfg, &s4, &Action::Shutdown)).is_none());
    }

    #[test]
    fn queue_overflow_rejects_and_late_submit_errors() {
        let cfg = ModelConfig::new(3, 1).with_capacity(1);
        let s0 = State::initial(&cfg);
        let s1 = apply(&cfg, &s0, &Action::Submit { client: 0 });
        let s2 = apply(&cfg, &s1, &Action::Submit { client: 1 });
        assert_eq!(s2.jobs[1], JobState::Done(Resp::Rejected), "capacity 1 is full");
        let s3 = apply(&cfg, &s2, &Action::Shutdown);
        let s4 = apply(&cfg, &s3, &Action::Submit { client: 2 });
        assert_eq!(s4.jobs[2], JobState::Done(Resp::ShutdownErr));
        // Job 0 still drains after shutdown: buffered items survive.
        assert!(enabled_actions(&cfg, &s4).contains(&Action::Route));
    }

    #[test]
    fn stale_rebind_bug_produces_the_safety_violation() {
        let bugs = Bugs { stale_rebind: true, ..Default::default() };
        let cfg = ModelConfig::new(1, 1).with_rebind().with_bugs(bugs);
        let s0 = State::initial(&cfg);
        let s1 = apply(&cfg, &s0, &Action::Submit { client: 0 });
        let s2 = apply(&cfg, &s1, &Action::Route);
        let s3 = apply(&cfg, &s2, &Action::Rebind); // race lands here
        let s4 = apply(&cfg, &s3, &Action::Release { device: 0 });
        let s5 = apply(&cfg, &s4, &Action::ExecBatch { device: 0 });
        let v = check_safety(&cfg, &s5).expect("stale exec must violate");
        assert!(v.starts_with("no-stale-weights"), "{v}");
        // Same schedule without the bug: routed == exec, no violation.
        let fixed = ModelConfig::new(1, 1).with_rebind();
        let mut s = State::initial(&fixed);
        for a in [
            Action::Submit { client: 0 },
            Action::Route,
            Action::Rebind,
            Action::Release { device: 0 },
            Action::ExecBatch { device: 0 },
        ] {
            s = apply(&fixed, &s, &a);
        }
        assert!(check_safety(&fixed, &s).is_none());
        assert_eq!(
            s.jobs[0],
            JobState::Done(Resp::Completed { routed: 1, exec: 1 })
        );
    }

    #[test]
    fn poison_is_quarantined_unless_the_containment_bug_is_on() {
        let cfg = ModelConfig::new(2, 1).with_poison();
        let mut s = State::initial(&cfg);
        for a in [
            Action::Submit { client: 0 },
            Action::Submit { client: 1 },
            Action::Route,
            Action::Route,
            Action::Release { device: 0 },
        ] {
            s = apply(&cfg, &s, &a);
        }
        let done = apply(&cfg, &s, &Action::ExecBatch { device: 0 });
        assert_eq!(done.jobs[0], JobState::Done(Resp::Poisoned));
        assert!(matches!(
            done.jobs[1],
            JobState::Done(Resp::Completed { .. })
        ));
        assert!(check_safety(&cfg, &done).is_none());

        let buggy = cfg
            .clone()
            .with_bugs(Bugs { no_containment: true, ..Default::default() });
        let bad = apply(&buggy, &s, &Action::ExecBatch { device: 0 });
        assert_eq!(bad.jobs[1], JobState::Done(Resp::Collateral));
        let v = check_safety(&buggy, &bad).expect("collateral must violate");
        assert!(v.starts_with("containment"), "{v}");
    }

    #[test]
    fn stop_flag_break_strands_the_buffered_job() {
        let bugs = Bugs { stop_flag_break: true, ..Default::default() };
        let cfg = ModelConfig::new(1, 1).with_bugs(bugs);
        let s0 = State::initial(&cfg);
        let s1 = apply(&cfg, &s0, &Action::Submit { client: 0 });
        let s2 = apply(&cfg, &s1, &Action::Shutdown);
        let acts = enabled_actions(&cfg, &s2);
        assert!(acts.contains(&Action::StopFlagBreak), "{acts:?}");
        let s3 = apply(&cfg, &s2, &Action::StopFlagBreak);
        // Dispatcher dead, job 0 still queued: no action can save it.
        let remaining = enabled_actions(&cfg, &s3);
        assert!(remaining.is_empty(), "{remaining:?}");
        let v = check_terminal(&cfg, &s3).expect("stranded job must violate");
        assert!(v.starts_with("no-stranded-shutdown"), "{v}");
    }

    #[test]
    fn pre_expired_deadline_is_answered_at_submit_without_a_queue_slot() {
        // Capacity 1 + 2 clients: the dead-on-arrival job 0 must not
        // consume the only slot, so job 1 still queues.
        let cfg = ModelConfig::new(2, 1).with_deadline().with_capacity(1);
        let s0 = State::initial(&cfg);
        let s1 = apply(&cfg, &s0, &Action::Submit { client: 0 });
        assert_eq!(s1.jobs[0], JobState::Done(Resp::Expired));
        assert!(s1.queue.is_empty(), "expired submit must not occupy the queue");
        let s2 = apply(&cfg, &s1, &Action::Submit { client: 1 });
        assert_eq!(s2.jobs[1], JobState::Queued, "slot must still be free");
    }

    #[test]
    fn quota_exhaustion_rejects_at_submit() {
        let cfg = ModelConfig::new(3, 1).with_quota(1);
        let s0 = State::initial(&cfg);
        let s1 = apply(&cfg, &s0, &Action::Submit { client: 0 });
        assert_eq!(s1.jobs[0], JobState::Queued);
        let s2 = apply(&cfg, &s1, &Action::Submit { client: 1 });
        assert_eq!(s2.jobs[1], JobState::Done(Resp::QuotaRejected));
        // Routing keeps the job inside the admission scope (ledger
        // counts scheduler occupancy too)...
        let s3 = apply(&cfg, &s2, &Action::Route);
        let s4 = apply(&cfg, &s3, &Action::Submit { client: 2 });
        assert_eq!(s4.jobs[2], JobState::Done(Resp::QuotaRejected));
        // ...and QuotaRejected tallies as a rejection.
        let (submitted, _, _, rejected) = s4.tally();
        assert_eq!((submitted, rejected), (3, 2));
    }

    #[test]
    fn release_picks_priority_order_and_fifo_bug_trips_the_inversion() {
        // Job 0 = low priority, job 1 = high; max_batch 1 forces a
        // choice.  The continuous scheduler must pick job 1 first.
        let cfg = ModelConfig::new(2, 1).with_priority().with_max_batch(1);
        let mut s = State::initial(&cfg);
        for a in [
            Action::Submit { client: 0 },
            Action::Submit { client: 1 },
            Action::Route,
            Action::Route,
        ] {
            s = apply(&cfg, &s, &a);
        }
        let good = apply(&cfg, &s, &Action::Release { device: 0 });
        assert_eq!(good.slots[0], Some(vec![1]), "high priority releases first");
        assert_eq!(good.batcher, vec![0]);
        assert!(!good.inverted);
        assert!(check_safety(&cfg, &good).is_none());

        // Same schedule under the FIFO-release bug: job 0 bypasses the
        // high-priority job 1 and the inversion invariant fires.
        let buggy = cfg
            .clone()
            .with_bugs(Bugs { fifo_release: true, ..Default::default() });
        let bad = apply(&buggy, &s, &Action::Release { device: 0 });
        assert_eq!(bad.slots[0], Some(vec![0]));
        assert!(bad.inverted);
        let v = check_safety(&buggy, &bad).expect("inversion must violate");
        assert!(v.starts_with("no-priority-inversion-past-deadline"), "{v}");
    }

    #[test]
    fn late_deadline_sweep_expires_in_the_scheduler() {
        let cfg = ModelConfig::new(2, 1).with_late_deadline();
        let mut s = State::initial(&cfg);
        for a in [
            Action::Submit { client: 0 },
            Action::Submit { client: 1 },
            Action::Route,
            Action::Route,
        ] {
            s = apply(&cfg, &s, &a);
        }
        assert!(enabled_actions(&cfg, &s).contains(&Action::Sweep));
        let swept = apply(&cfg, &s, &Action::Sweep);
        assert_eq!(swept.jobs[0], JobState::Done(Resp::Expired));
        assert_eq!(swept.batcher, vec![1], "batchmate survives the sweep");
        // The race can also resolve the other way: a release beats the
        // sweep and the job completes — no Sweep remains afterwards.
        let released = apply(&cfg, &s, &Action::Release { device: 0 });
        assert!(!enabled_actions(&cfg, &released).contains(&Action::Sweep));
    }

    #[test]
    fn shard_reduction_answers_exactly_once() {
        let cfg = ModelConfig::new(1, 2).with_sharding();
        let mut s = State::initial(&cfg);
        for a in [Action::Submit { client: 0 }, Action::Route, Action::FanOut] {
            s = apply(&cfg, &s, &a);
        }
        assert_eq!(s.jobs[0], JobState::Sharding { epoch: 0, left: 2 });
        let s1 = apply(&cfg, &s, &Action::ExecShard { job: 0 });
        assert_eq!(s1.jobs[0], JobState::Sharding { epoch: 0, left: 1 });
        let s2 = apply(&cfg, &s1, &Action::ExecShard { job: 0 });
        assert!(matches!(
            s2.jobs[0],
            JobState::Done(Resp::Completed { routed: 0, exec: 0 })
        ));
        assert!(!enabled_actions(&cfg, &s2)
            .contains(&Action::ExecShard { job: 0 }));
    }
}
