//! Replay model counterexamples against the real coordinator.
//!
//! The model checker works on an abstraction; this module closes the
//! loop by driving the *real* [`Server`](crate::coordinator::Server)
//! through the schedule a counterexample names, using the
//! [`FaultPlan`](crate::coordinator::FaultPlan) hooks to pin the
//! nondeterminism the schedule depends on:
//!
//! * `hold_dispatch_until_shutdown` parks the dispatcher so every
//!   submit of the schedule lands in the bounded channel first
//!   (the model's `Submit*; Shutdown` prefix);
//! * `stop_flag_break` re-introduces the PR 5 dispatcher bug behind
//!   the off-by-default plan flag (the model's `StopFlagBreak` step).
//!
//! With the bug armed, the real server strands every held job — their
//! reply channels die unanswered and `submitted` permanently exceeds
//! `completed + failed + rejected`.  With the bug off, the *same*
//! schedule drains cleanly: every job is answered and the accounting
//! identity holds.  That pair of runs is the evidence that (a) the
//! model's violation is real, not an artifact, and (b) the shipped
//! code actually contains the fix.

use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::coordinator::{
    FaultPlan, GemmKey, GemmRequest, MetricsSnapshot, Server, ServerConfig,
};
use crate::runtime::{Runtime, Tensor};
use crate::schedule::Dtype;
use crate::sim::DeviceModel;
use crate::util::prng::Rng;

/// The one artifact the replay server loads: a 24x24x24 f32 baseline
/// GEMM — small enough that a full replay leg is milliseconds.
const MANIFEST: &str = r#"{
  "version": 1,
  "artifacts": [
    {
      "name": "replay24",
      "file": "replay24.tprog.json",
      "kind": "baseline",
      "inputs": [
        {"shape": [24, 24], "dtype": "f32"},
        {"shape": [24, 24], "dtype": "f32"},
        {"shape": [24, 24], "dtype": "f32"}
      ],
      "outputs": [{"shape": [24, 24], "dtype": "f32"}],
      "m": 24, "n": 24, "k": 24, "dtype_in": "f32", "dtype_acc": "f32"
    }
  ]
}"#;

const TPROG: &str = r#"{
  "format": "mlir-gemm-tprog-v1",
  "name": "replay24",
  "program": {
    "type": "gemm", "m": 24, "n": 24, "k": 24,
    "dtype_in": "f32", "dtype_acc": "f32", "epilogue": "none", "fused": true
  }
}"#;

/// What one replay run of the shutdown-vs-submit schedule observed.
#[derive(Clone, Debug)]
pub struct ReplayOutcome {
    /// Jobs submitted (all buffered before the dispatcher ran).
    pub jobs: usize,
    /// Reply channels that delivered a response (success or explicit
    /// error).
    pub answered: usize,
    /// Reply channels that died without any response — the stranding
    /// the stop-flag break causes.  Must be 0 on correct code.
    pub lost: usize,
    /// Server metrics after shutdown.
    pub snapshot: MetricsSnapshot,
}

impl ReplayOutcome {
    /// The protocol contract the checker proves: nobody stranded and
    /// `submitted == completed + failed + rejected`.
    pub fn accounting_holds(&self) -> bool {
        self.lost == 0
            && self.snapshot.completed + self.snapshot.failed + self.snapshot.rejected
                == self.snapshot.submitted
    }
}

/// A scratch artifact store that cleans up after itself even on panic.
struct TempStore(PathBuf);

impl TempStore {
    fn create() -> Result<TempStore> {
        static SEQ: AtomicUsize = AtomicUsize::new(0);
        let dir = std::env::temp_dir().join(format!(
            "mlir_gemm_replay_{}_{}",
            std::process::id(),
            SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating replay store {}", dir.display()))?;
        std::fs::write(dir.join("manifest.json"), MANIFEST)?;
        std::fs::write(dir.join("replay24.tprog.json"), TPROG)?;
        Ok(TempStore(dir))
    }
}

impl Drop for TempStore {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.0);
    }
}

/// Drive the real server through the model's shortest stop-flag-break
/// counterexample (`Submit x jobs; Shutdown; StopFlagBreak`), or —
/// with `stop_flag_break = false` — through the identical schedule on
/// correct code.
///
/// The schedule is made deterministic, not probabilistic: the
/// dispatcher is held until `shutdown()` releases it, so every submit
/// is buffered in the channel when the stop flag goes up, exactly the
/// state the model names.
pub fn replay_shutdown_vs_submit(
    jobs: usize,
    stop_flag_break: bool,
) -> Result<ReplayOutcome> {
    let store = TempStore::create()?;
    let rt = Arc::new(Runtime::open(&store.0)?);
    let mut server = Server::start(
        rt,
        &DeviceModel::rtx3090(),
        ServerConfig {
            workers: 1,
            queue_capacity: jobs.max(1),
            faults: FaultPlan {
                stop_flag_break,
                hold_dispatch_until_shutdown: true,
                ..Default::default()
            },
            ..Default::default()
        },
    );

    let key = GemmKey::with_dtypes(24, 24, 24, Dtype::F32, Dtype::F32);
    let mut rng = Rng::new(0x5EED_CE11);
    let mut rxs = Vec::with_capacity(jobs);
    for _ in 0..jobs {
        let a = Tensor::new(vec![24, 24], rng.normal_matrix(24, 24))?;
        let b = Tensor::new(vec![24, 24], rng.normal_matrix(24, 24))?;
        let c = Tensor::new(vec![24, 24], rng.normal_matrix(24, 24))?;
        rxs.push(server.submit(GemmRequest {
            key: key.clone(),
            a,
            b: Some(b),
            c,
            bias: None,
            use_baseline: true,
            deadline: None,
        }));
    }

    // The model's Shutdown step: raises the stop flag, releases the
    // held dispatcher, closes the channel, joins every thread.  On
    // buggy code the dispatcher wakes, sees `stop && batcher.empty()`,
    // and exits with all `jobs` submits still buffered.
    let snapshot = server.shutdown();

    let mut answered = 0usize;
    let mut lost = 0usize;
    for rx in rxs {
        // All threads are joined: each channel either already holds its
        // response or is disconnected-empty, i.e. stranded.
        match rx.try_recv() {
            Ok(_) => answered += 1,
            Err(_) => lost += 1,
        }
    }

    Ok(ReplayOutcome { jobs, answered, lost, snapshot })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clean_schedule_answers_everyone() {
        let out = replay_shutdown_vs_submit(4, false).unwrap();
        assert_eq!(out.lost, 0, "{out:?}");
        assert_eq!(out.answered, 4);
        assert!(out.accounting_holds(), "{out:?}");
        assert_eq!(out.snapshot.completed, 4, "held jobs drain through shutdown");
    }

    #[test]
    fn buggy_schedule_strands_every_held_job() {
        let out = replay_shutdown_vs_submit(4, true).unwrap();
        assert_eq!(out.lost, 4, "{out:?}");
        assert_eq!(out.answered, 0);
        assert!(
            !out.accounting_holds(),
            "the stop-flag break must break the accounting identity: {out:?}"
        );
        assert_eq!(out.snapshot.submitted, 4);
        assert_eq!(
            out.snapshot.completed + out.snapshot.failed + out.snapshot.rejected,
            0,
            "{out:?}"
        );
    }
}
